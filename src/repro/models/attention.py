"""Attention: MHA / GQA / MQA (+ QKV bias), sliding-window, MLA, cross-attn,
with full or ring-buffer KV caches for decode.

Conventions
-----------
x: (B, S, D).  q heads H, kv heads KV (H % KV == 0), head_dim hd.
RoPE is applied BEFORE caching, so ring-buffer (sliding-window) caches stay
valid regardless of slot order. Softmax in float32.

Decode: one new token per call (S == 1), `pos` is the current absolute
position (same for the whole batch — batched continuous decode).
Sliding-window layers keep only `window` KV slots (ring buffer), which is why
`long_500k` decode is memory-feasible for SWA architectures (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope
from repro.sharding.partition import shard

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array           # (B, S_slots, KV, hd)   roped keys
    v: jax.Array           # (B, S_slots, KV, hd)


class MLACache(NamedTuple):
    c_kv: jax.Array        # (B, S_slots, kv_lora_rank)
    k_rope: jax.Array      # (B, S_slots, qk_rope_dim)  shared across heads


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(slot, head) scales: halves the decode-step
    HBM traffic (the dominant roofline term for decode shapes, §Perf)."""
    qk: jax.Array          # (B, S_slots, KV, hd) int8
    qv: jax.Array          # (B, S_slots, KV, hd) int8
    k_scale: jax.Array     # (B, S_slots, KV) f32
    v_scale: jax.Array     # (B, S_slots, KV) f32


def _quantize(x: jax.Array):
    """x (B, 1, KV, hd) -> (int8, scale (B,1,KV))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


class CrossKV(NamedTuple):
    """Precomputed cross-attention keys/values over the encoder output —
    computed once at request admission instead of every decode step
    (EXPERIMENTS.md §Perf, whisper decode hillclimb)."""
    xk: jax.Array          # (B, enc_ctx, H, hd)
    xv: jax.Array          # (B, enc_ctx, H, hd)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, d_model: int, n_heads: int, kv_heads: int,
                   head_dim: int, qkv_bias: bool = False,
                   dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    sd = (2.0 / (d_model + n_heads * head_dim)) ** 0.5
    p = dict(
        wq=(jax.random.normal(ks[0], (d_model, n_heads, head_dim)) * sd).astype(dtype),
        wk=(jax.random.normal(ks[1], (d_model, kv_heads, head_dim)) * sd).astype(dtype),
        wv=(jax.random.normal(ks[2], (d_model, kv_heads, head_dim)) * sd).astype(dtype),
        wo=(jax.random.normal(ks[3], (n_heads, head_dim, d_model)) * sd).astype(dtype),
    )
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((kv_heads, head_dim), dtype)
    return p


def init_mla(key: jax.Array, d_model: int, n_heads: int,
             q_lora_rank: int, kv_lora_rank: int,
             qk_nope_dim: int, qk_rope_dim: int, v_head_dim: int,
             dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    qk_dim = qk_nope_dim + qk_rope_dim
    sd = 0.02
    return dict(
        wq_a=(jax.random.normal(ks[0], (d_model, q_lora_rank)) * sd).astype(dtype),
        wq_b=(jax.random.normal(ks[1], (q_lora_rank, n_heads, qk_dim)) * sd).astype(dtype),
        wkv_a=(jax.random.normal(ks[2], (d_model, kv_lora_rank)) * sd).astype(dtype),
        # decompression: kv_lora -> per-head (k_nope | v)
        wkv_b=(jax.random.normal(ks[3], (kv_lora_rank, n_heads,
                                         qk_nope_dim + v_head_dim)) * sd).astype(dtype),
        wk_rope=(jax.random.normal(ks[4], (d_model, qk_rope_dim)) * sd).astype(dtype),
        wo=(jax.random.normal(ks[5], (n_heads, v_head_dim, d_model)) * sd).astype(dtype),
    )


def init_kv_cache(batch: int, slots: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, quantized: bool = False):
    shp = (batch, slots, kv_heads, head_dim)
    if quantized:
        return QuantKVCache(qk=jnp.zeros(shp, jnp.int8),
                            qv=jnp.zeros(shp, jnp.int8),
                            k_scale=jnp.zeros(shp[:-1], jnp.float32),
                            v_scale=jnp.zeros(shp[:-1], jnp.float32))
    return KVCache(k=jnp.zeros(shp, dtype), v=jnp.zeros(shp, dtype))


def make_cross_kv(p: dict, enc_out: jax.Array) -> CrossKV:
    """Precompute cross-attention K/V from encoder output (once per request)."""
    xk = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    xv = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    if "bk" in p:
        xk = xk + p["bk"]
        xv = xv + p["bv"]
    return CrossKV(xk=xk, xv=xv)


def init_mla_cache(batch: int, slots: int, kv_lora_rank: int,
                   qk_rope_dim: int, dtype=jnp.bfloat16) -> MLACache:
    return MLACache(c_kv=jnp.zeros((batch, slots, kv_lora_rank), dtype),
                    k_rope=jnp.zeros((batch, slots, qk_rope_dim), dtype))


def _fill_cache(cache, k: jax.Array, v: jax.Array):
    """Block prefill: write S roped K/V positions into the cache (positions
    0..S-1). Ring caches keep the last `slots` positions at slot = pos % slots;
    int8 caches quantize on write."""
    quant = isinstance(cache, QuantKVCache)
    slots = (cache.qk if quant else cache.k).shape[1]
    S = k.shape[1]
    if S >= slots:
        keep = slice(S - slots, S)
        pos = jnp.arange(S - slots, S)
        kk, vv = k[:, keep], v[:, keep]
    else:
        pos = jnp.arange(S)
        kk, vv = k, v
    slot_idx = pos % slots
    if quant:
        qk, ks = _quantize(kk)
        qv, vs = _quantize(vv)
        return QuantKVCache(
            qk=cache.qk.at[:, slot_idx].set(qk),
            qv=cache.qv.at[:, slot_idx].set(qv),
            k_scale=cache.k_scale.at[:, slot_idx].set(ks),
            v_scale=cache.v_scale.at[:, slot_idx].set(vs))
    return KVCache(k=cache.k.at[:, slot_idx].set(kk.astype(cache.k.dtype)),
                   v=cache.v.at[:, slot_idx].set(vv.astype(cache.v.dtype)))


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _grouped_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: Optional[jax.Array], scale: float) -> jax.Array:
    """q: (B,S,H,hd) k,v: (B,T,KV,*) -> (B,S,H,v_dim); mask (B,1,S,T) or None.
    Used for decode (S==1): scores stay (B,KV,G,1,T), shardable over kv_seq."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    if mask is not None:
        # keep the mask boolean until use: a hoisted f32 mask constant would
        # cost 4x the memory as a scan-carried invariant
        scores = jnp.where(mask[:, :, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, out.shape[-1])


def _chunked_attn(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, window: Optional[int], scale: float,
                  q_offset: int = 0, chunk: int = 512) -> jax.Array:
    """Train/prefill attention without the (S,T) f32 blow-up: scan over query
    chunks; kv heads are broadcast to H so scores (B,H,c,T) shard over 'heads'
    (KV alone is often not divisible by the model axis). Under remat the
    per-chunk scores are recomputed in the backward pass — flash-style memory
    at XLA level (the Pallas kernel is the TPU hot path, kernels/flash).
    q: (B,S,H,hd); k,v: (B,T,KV,*)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    kh = jnp.broadcast_to(k[:, :, :, None], (B, T, KV, G, k.shape[-1]))
    kh = kh.reshape(B, T, H, k.shape[-1])
    vh = jnp.broadcast_to(v[:, :, :, None], (B, T, KV, G, v.shape[-1]))
    vh = vh.reshape(B, T, H, v.shape[-1])
    kh = shard(kh, "batch", "seq", "heads", "head_dim")
    vh = shard(vh, "batch", "seq", "heads", "head_dim")

    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = q.shape[1] // c
    qc = q.reshape(B, n_chunks, c, H, hd).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(T)

    @jax.checkpoint
    def chunk_attn(i, qi):
        scores = jnp.einsum("bchd,bthd->bhct", qi, kh).astype(jnp.float32) * scale
        scores = shard(scores, "batch", "heads", None, None)
        if causal:
            qpos = i * c + jnp.arange(c) + q_offset
            ok = kpos[None, :] <= qpos[:, None]
            if window is not None:
                ok &= kpos[None, :] > qpos[:, None] - window
            scores = jnp.where(ok[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(vh.dtype)
        return jnp.einsum("bhct,bthd->bchd", probs, vh)

    def body(i, qi):
        # rematerialized per chunk: backward recomputes scores/probs instead of
        # the scan saving an (S,T)-sized f32 per layer (flash-style memory)
        return i + 1, chunk_attn(i, qi)

    _, outs = jax.lax.scan(body, 0, qc)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * c, H, v.shape[-1])
    return out[:, :S]


def _causal_mask(S: int, T: int, q_offset: int = 0,
                 window: Optional[int] = None) -> jax.Array:
    """(1, 1, S, T) boolean: True = attend. T >= S; query i at abs pos q_offset+i."""
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    ok = kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= kpos[None, :] > qpos[:, None] - window
    return ok[None, None]


# ---------------------------------------------------------------------------
# GQA forward (train / prefill / decode)
# ---------------------------------------------------------------------------

def attention(p: dict, x: jax.Array, *,
              positions: Optional[jax.Array] = None,
              mode: str = "train",
              cache: Optional[KVCache] = None,
              pos: Optional[jax.Array] = None,
              window: Optional[int] = None,
              causal: bool = True,
              rope_theta: float = 10000.0,
              kv_x: Optional[jax.Array] = None,
              cross_kv: Optional["CrossKV"] = None,
              use_rope: bool = True) -> Tuple[jax.Array, Optional[KVCache]]:
    """Returns (out (B,S,D), new_cache).

    mode "train"/"prefill": full-sequence self-attention (cache ignored).
    mode "decode": S==1; reads/writes `cache` at absolute position `pos`
        (ring-buffered when `window` is set).
    kv_x: cross-attention source (B, T, D); disables causality, rope, cache.
    """
    B, S, D = x.shape
    H, hd = p["wq"].shape[1], p["wq"].shape[2]
    scale = hd ** -0.5

    if cross_kv is not None:              # precomputed cross-attention K/V
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if "bq" in p:
            q = q + p["bq"]
        out = _grouped_attn(q, cross_kv.xk, cross_kv.xv, None, scale)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), None

    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")

    if kv_x is not None:                         # cross-attention
        out = _chunked_attn(q, k, v, causal=False, window=None, scale=scale)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), None

    if mode in ("train", "prefill"):
        if positions is None:
            positions = jnp.arange(S)[None, :]
        if use_rope:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
        out = _chunked_attn(q, k, v, causal=causal, window=window, scale=scale)
        new_cache = None
        if mode == "prefill" and cache is not None:
            new_cache = _fill_cache(cache, k, v)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    # ---- decode -----------------------------------------------------------
    assert S == 1 and cache is not None and pos is not None
    if use_rope:
        pv = jnp.full((B, 1), pos)
        q = apply_rope(q, pv, rope_theta)
        k = apply_rope(k, pv, rope_theta)
    quant = isinstance(cache, QuantKVCache)
    slots = (cache.qk if quant else cache.k).shape[1]
    slot = pos % slots if window is not None else pos
    if quant:
        qk_new, ks_new = _quantize(k)
        qv_new, vs_new = _quantize(v)
        new_cache = QuantKVCache(
            qk=jax.lax.dynamic_update_slice_in_dim(cache.qk, qk_new, slot, axis=1),
            qv=jax.lax.dynamic_update_slice_in_dim(cache.qv, qv_new, slot, axis=1),
            k_scale=jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks_new, slot, axis=1),
            v_scale=jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs_new, slot, axis=1))
        k_all = _dequantize(shard(new_cache.qk, "batch", "kv_seq", "kv_heads", "head_dim"),
                            shard(new_cache.k_scale, "batch", "kv_seq", "kv_heads"), k.dtype)
        v_all = _dequantize(shard(new_cache.qv, "batch", "kv_seq", "kv_heads", "head_dim"),
                            shard(new_cache.v_scale, "batch", "kv_seq", "kv_heads"), v.dtype)
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)
        new_cache = KVCache(k=new_k, v=new_v)
        k_all = shard(new_cache.k, "batch", "kv_seq", "kv_heads", "head_dim")
        v_all = shard(new_cache.v, "batch", "kv_seq", "kv_heads", "head_dim")

    kpos_valid = jnp.arange(slots)
    if window is not None:
        valid = (kpos_valid <= pos % slots) | (pos >= slots)
    else:
        valid = kpos_valid <= pos
    mask = valid[None, None, None, :]            # (1,1,1,slots)
    out = _grouped_attn(q, k_all, v_all, mask, scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA forward (MiniCPM3-style multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_attention(p: dict, x: jax.Array, *,
                  qk_nope_dim: int, qk_rope_dim: int, v_head_dim: int,
                  mode: str = "train",
                  cache: Optional[MLACache] = None,
                  pos: Optional[jax.Array] = None,
                  window: Optional[int] = None,
                  rope_theta: float = 10000.0) -> Tuple[jax.Array, Optional[MLACache]]:
    """Latent attention: KV state is the compressed c_kv (+ shared roped key).
    The decode cache stores rank-r latents, not per-head K/V — the memory win
    that defines MLA."""
    B, S, D = x.shape
    H = p["wq_b"].shape[1]
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5

    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])          # latent
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["wk_rope"])      # shared rope key

    if mode in ("train", "prefill"):
        positions = jnp.arange(S)[None, :]
        q_rope = apply_rope(q_rope, positions, rope_theta)
        k_rope_r = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0]
        kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"])
        k_nope, v = kv[..., :qk_nope_dim], kv[..., qk_nope_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_r[:, :, None, :],
                                      (B, S, H, qk_rope_dim))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _chunked_attn(qf, k, v, causal=True, window=window, scale=scale)
        new_cache = None
        if mode == "prefill" and cache is not None:
            slots = cache.c_kv.shape[1]
            if S >= slots:
                pos = jnp.arange(S - slots, S)
                ck, kr = c_kv[:, S - slots:], k_rope_r[:, S - slots:]
            else:
                pos = jnp.arange(S)
                ck, kr = c_kv, k_rope_r
            idx = pos % slots
            new_cache = MLACache(
                c_kv=cache.c_kv.at[:, idx].set(ck.astype(cache.c_kv.dtype)),
                k_rope=cache.k_rope.at[:, idx].set(kr.astype(cache.k_rope.dtype)))
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    assert S == 1 and cache is not None and pos is not None
    pv = jnp.full((B, 1), pos)
    q_rope = apply_rope(q_rope, pv, rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], pv, rope_theta)[:, :, 0]
    slots = cache.c_kv.shape[1]
    slot = pos % slots if window is not None else pos
    c_new = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_kv.astype(cache.c_kv.dtype), slot, axis=1)
    kr_new = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope.astype(cache.k_rope.dtype), slot, axis=1)
    new_cache = MLACache(c_kv=c_new, k_rope=kr_new)

    kv = jnp.einsum("btr,rhk->bthk", c_new, p["wkv_b"])      # decompress
    k_nope, v = kv[..., :qk_nope_dim], kv[..., qk_nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_new[:, :, None, :],
                                  (B, slots, H, qk_rope_dim))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kpos = jnp.arange(slots)
    valid = ((kpos <= pos % slots) | (pos >= slots)) if window is not None else (kpos <= pos)
    out = _grouped_attn(qf, k, v, valid[None, None, None, :], scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
