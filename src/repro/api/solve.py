"""`solve(problem, spec)` — the one entry point to Algorithm 2.

The repo grew seven divergent solver signatures (`allocate`,
`allocate_fixed_deadline`, `allocate_fleet`, `allocate_region`,
`run_rounds`, `run_rounds_fleet`/`run_rounds_region`, plus the
`RegionAllocator` kwargs), each re-threading the same static options into
the jitted impls. `solve` collapses that 4x2 entry-point matrix to one
code path that routes on `Problem` topology:

    single cell        -> BCD (`BCDResult`)
    (C, N) stack       -> fleet vmap (`FleetResult`)
    + mesh             -> region shard_map (`RegionResult`)
    + rounds config    -> round-dynamics scan (`RoundsResult`)
    + deadline         -> deadline-constrained BCD (`BCDResult`; on a
                          (C, N) stack a fleet vmap with per-cell
                          deadlines -> `FleetResult`; + mesh a sharded
                          region solve -> `RegionResult`)
    + assoc config     -> BCD-over-association outer loop on a stacked
                          cross-cell system (`assoc.AssocResult`)

Weights enter the jitted solvers as a traced ``(3,)`` / ``(C, 3)`` operand
(`api.problem.weights_leaf`), so per-cell / per-request weights cost zero
extra compiles; `SolverSpec` (+ shapes) is the entire jit-cache key.

The legacy signatures survive as thin deprecation shims over this module —
each warns `DeprecationWarning` once per process and delegates verbatim, so
results are bit-identical by construction.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accuracy import default_accuracy
from repro.core.bcd import (_FIXED_COLS, _LEDGER_COLS, _allocate_fixed_impl,
                            _allocate_impl, _fleet_cell_fn, _fleet_result,
                            _init_carry_state, _materialize_history, BCDResult,
                            SolveCounters, initial_allocation)
from repro.core.types import Allocation, SystemParams

from .problem import Problem, weights_leaf
from .spec import SolverSpec, warn_tol_floor

Array = jnp.ndarray

# ---------------------------------------------------------------------------
# deprecation shims: one warning per legacy entry point per process
# ---------------------------------------------------------------------------

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(name: str, replacement: str) -> None:
    """Warn once per process that `name` is a legacy shim over `solve`."""
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"repro: {name}() is a deprecated shim; use "
        f"repro.solve({replacement}) — see the migration table in the "
        f"repro package docstring.", DeprecationWarning, stacklevel=3)


def _reset_deprecation_registry() -> None:
    """Testing hook: make every shim warn again."""
    _DEPRECATION_WARNED.clear()


# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------

def _cast_tree(tree, dtype):
    """Cast every floating leaf to `dtype` (bool masks / int leaves kept)."""
    def cast(x):
        x = jnp.asarray(x)
        return x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x
    return jax.tree_util.tree_map(cast, tree)


def _apply_dtype(system: SystemParams, init: Optional[Allocation],
                 dtype: Optional[str]):
    if dtype is None:
        return system, init
    return (_cast_tree(system, dtype),
            None if init is None else _cast_tree(init, dtype))


# ---------------------------------------------------------------------------
# the dispatcher
# ---------------------------------------------------------------------------

def _topology_label(problem: Problem) -> str:
    """Deterministic topology tag for the solve span (shape metadata only —
    reading `.ndim` never syncs the device)."""
    if problem.assoc is not None:
        return "assoc"
    base = ("rounds" if problem.rounds is not None
            else "fixed" if problem.deadline is not None else "bcd")
    if problem.mesh is not None:
        return base + "_region"
    if jnp.asarray(problem.system.gain).ndim == 2:
        return base + "_fleet"
    return base


def solve(problem: Problem, spec: Optional[SolverSpec] = None):
    """Solve one `Problem` under one `SolverSpec`; route on topology.

    Returns the per-topology result type (`BCDResult`, `FleetResult`,
    `RegionResult`, or `RoundsResult`) — bit-identical to the legacy entry
    point it replaces (parity-tested in tests/test_api_parity.py).

    When a `repro.obs` recorder is enabled the whole call is wrapped in a
    `solve` span tagged with the routed topology; with the default no-op
    recorder this is one predicate check (see tests/test_obs.py for the
    jit-cache guard: the span changes no compiled shapes either way).
    """
    from repro import obs

    if not obs.enabled():
        return _solve_routed(problem, spec)
    with obs.span("solve", topology=_topology_label(problem)):
        return _solve_routed(problem, spec)


def _solve_routed(problem: Problem, spec: Optional[SolverSpec]):
    spec = SolverSpec() if spec is None else spec
    cells = problem.cells   # also validates system.gain is 1-D or 2-D
    sysp, init = _apply_dtype(problem.system, problem.init, spec.dtype)
    if problem.rounds is None:
        # rounds problems take their BCD tol from the RoundsConfig instead
        warn_tol_floor(spec.tol, jnp.asarray(sysp.gain).dtype)
    if spec.lockstep and problem.mesh is None:
        # lockstep selects the GSPMD execution mode of a mesh solve; on a
        # meshless problem it would silently do nothing
        raise ValueError("solve: SolverSpec.lockstep requires Problem.mesh")
    if problem.assoc is not None:
        from repro.assoc.loop import solve_assoc

        if problem.rounds is not None or problem.deadline is not None:
            raise ValueError(
                "solve: assoc is exclusive with rounds/deadline (the "
                "association loop owns the outer iteration)")
        if cells is None:
            raise ValueError(
                "solve: assoc requires a stacked (C, N) cross-cell system "
                "(assoc.make_multicell)")
        return solve_assoc(
            dataclasses.replace(problem, system=sysp, init=init), spec)
    if problem.rounds is not None:
        if problem.deadline is not None:
            raise ValueError("solve: rounds and deadline are exclusive")
        if problem.key is None:
            raise ValueError(
                "solve: a rounds problem needs problem.key (PRNG key for "
                "the channel / participation sampling)")
        # the per-round solver options live on RoundsConfig (itself the
        # scan's static jit key); silently dropping a tuned spec here
        # would mislead, so only the fields the rounds paths actually
        # consult (lockstep, dtype) may differ from the defaults
        ref = SolverSpec(lockstep=spec.lockstep, dtype=spec.dtype)
        if spec != ref:
            raise ValueError(
                "solve: a rounds problem takes its BCD options "
                "(bcd_iters/bcd_tol/sp*_method) from the RoundsConfig, "
                "not from SolverSpec — configure problem.rounds instead "
                "(only SolverSpec.lockstep and .dtype apply here)")
        if problem.mesh is not None:
            if cells is None:
                raise ValueError("solve: mesh requires a stacked (C, N) "
                                 "system (stack_systems / make_fleet)")
            return _solve_rounds_region(problem, spec, sysp, init)
        if cells is None:
            return _solve_rounds(problem, spec, sysp, init)
        return _solve_rounds_fleet(problem, spec, sysp, init)
    if problem.deadline is not None:
        if problem.mesh is not None:
            if cells is None:
                raise ValueError("solve: mesh requires a stacked (C, N) "
                                 "system (stack_systems / make_fleet)")
            return _solve_fixed_region(problem, spec, sysp, init)
        if cells is not None:
            return _solve_fixed_fleet(problem, spec, sysp, init)
        return _solve_fixed(problem, spec, sysp, init)
    if problem.mesh is not None:
        if cells is None:
            raise ValueError("solve: mesh requires a stacked (C, N) system "
                             "(stack_systems / make_fleet)")
        return _solve_region(problem, spec, sysp, init)
    if cells is None:
        return _solve_single(problem, spec, sysp, init)
    return _solve_fleet(problem, spec, sysp, init)


# ---------------------------------------------------------------------------
# per-topology drivers (the former entry-point bodies, now the only copy)
# ---------------------------------------------------------------------------

def _bcd_result(out, alloc0, spec: SolverSpec, cols, objective_col: str,
                with_s_relaxed: bool) -> BCDResult:
    """Shared single-cell result assembly: materialize the ledger (or, with
    keep_history=False, pull only the objective scalar — cols[0] is the
    objective column for the free solve and "energy" for the fixed one,
    both at ledger index of `objective_col`), and hand back the untouched
    init when max_iters=0 ran nothing (objective NaN, the PR 1 regression
    contract)."""
    B, pw, f, s, s_hat, T, iters, conv, ledger, counters = out
    iters = int(iters)
    if spec.keep_history:
        history = _materialize_history(np.asarray(ledger), iters, cols)
        objective = history[-1][objective_col] if history else float("nan")
    else:
        history = []
        col = cols.index(objective_col)
        objective = float(ledger[iters - 1, col]) if iters else float("nan")
    allocation = Allocation(bandwidth=B, power=pw, freq=f, resolution=s,
                            s_relaxed=s_hat if with_s_relaxed else None,
                            T=T) if iters else alloc0
    return BCDResult(allocation=allocation, objective=objective,
                     history=history, iters=iters, converged=bool(conv),
                     counters=SolveCounters(data=counters))


def _solve_single(p: Problem, spec: SolverSpec, sysp, init) -> BCDResult:
    acc = p.acc if p.acc is not None else default_accuracy()
    alloc0 = init if init is not None else initial_allocation(sysp)
    state0 = _init_carry_state(sysp, alloc0)
    warr = weights_leaf(p.weights, state0[0].dtype)
    out = _allocate_impl(
        sysp, warr, acc, state0, spec.max_iters, spec.tol,
        spec.sp1_method, spec.sp2_method, spec.sp2_iters)
    return _bcd_result(out, alloc0, spec, _LEDGER_COLS, "objective",
                       with_s_relaxed=True)


def _solve_fixed(p: Problem, spec: SolverSpec, sysp, init) -> BCDResult:
    acc = p.acc if p.acc is not None else default_accuracy()
    T_round = p.deadline / sysp.global_rounds
    alloc0 = init if init is not None else initial_allocation(
        sysp, bandwidth_frac=p.bandwidth_frac)
    state0 = _init_carry_state(sysp, alloc0)
    dtype = state0[0].dtype
    warr = weights_leaf(p.weights, dtype)
    out = _allocate_fixed_impl(
        sysp, warr, acc, jnp.asarray(T_round, dtype), state0,
        spec.max_iters, spec.tol, spec.sp2_method, spec.sp2_iters)
    return _bcd_result(out, alloc0, spec, _FIXED_COLS, "energy",
                       with_s_relaxed=False)


def _solve_fixed_fleet(p: Problem, spec: SolverSpec, sysp, init):
    """Deadline-constrained BCD vmapped over a stacked (C, N) fleet.

    `Problem.deadline` may be a scalar (one total budget for every cell)
    or a (C,) array of per-cell budgets; either way the per-round deadline
    T_total / global_rounds enters the compiled solve as a traced per-cell
    operand — heterogeneous deadlines never recompile. Returns a
    `FleetResult` with the fixed-variant ledger columns (col 0 "energy" is
    the per-cell objective, matching the single-cell path)."""
    from repro.core.bcd import _FIXED_COLS, _fleet_fixed_cell_fn

    acc = p.acc if p.acc is not None else default_accuracy()
    dtype = jnp.asarray(sysp.gain).dtype
    C = int(jnp.asarray(sysp.gain).shape[0])
    warr = weights_leaf(p.weights, dtype, cells=C)
    T_round = _per_cell_T_round(p, sysp, C, dtype)
    alloc0 = init if init is not None else jax.vmap(
        lambda sysc: initial_allocation(
            sysc, bandwidth_frac=p.bandwidth_frac))(sysp)
    fn = _fleet_fixed_cell_fn(acc, spec.max_iters, spec.tol,
                              spec.sp2_method, spec.sp2_iters)
    out = jax.vmap(fn)(sysp, warr, T_round, alloc0)
    return _fleet_result(out, spec.max_iters, dtype, cols=_FIXED_COLS)


def _per_cell_T_round(p: Problem, sysp, C: int, dtype):
    """Per-round deadline (C,) operand: scalar budgets broadcast, (C,)
    budgets pass through — traced either way, never a recompile."""
    deadline = jnp.asarray(p.deadline, dtype)
    if deadline.ndim not in (0, 1) or (deadline.ndim == 1
                                       and deadline.shape[0] != C):
        raise ValueError(
            f"solve: deadline must be a scalar or a ({C},) per-cell "
            f"array, got shape {deadline.shape}")
    return jnp.broadcast_to(deadline, (C,)) \
        / jnp.asarray(sysp.global_rounds, dtype)


def _solve_fixed_region(p: Problem, spec: SolverSpec, sysp, init):
    """Deadline-constrained fleet solve sharded over `Problem.mesh`: the
    vmapped `_fleet_fixed_cell_fn` under the region shard_map, exactly the
    free-variant `_solve_region` layout — pad the cell axis to a mesh
    multiple, place, solve (shard-local convergence exit unless
    `SolverSpec.lockstep`), slice. Per-cell results are bit-identical to
    the unsharded `_solve_fixed_fleet` path (sharding moves work, not
    math; parity-tested in tests/test_region.py)."""
    from repro.region.mesh import (RegionResult, _pack_stats,
                                   _region_fixed_impl, _slice_fleet,
                                   pad_cells, place_cells)

    mesh = p.mesh
    acc = p.acc if p.acc is not None else default_accuracy()
    C = int(jnp.asarray(sysp.gain).shape[0])
    D = int(mesh.devices.size)
    Cp = -(-C // D) * D
    dtype = jnp.asarray(sysp.gain).dtype
    T_round = _per_cell_T_round(p, sysp, C, dtype)
    alloc0 = init if init is not None else jax.vmap(
        lambda sysc: initial_allocation(
            sysc, bandwidth_frac=p.bandwidth_frac))(sysp)
    sysb = place_cells(pad_cells(sysp, Cp), mesh)
    warr = place_cells(pad_cells(weights_leaf(p.weights, dtype, cells=C),
                                 Cp), mesh)
    T_b = place_cells(pad_cells(T_round, Cp), mesh)
    alloc0b = place_cells(pad_cells(alloc0, Cp), mesh)
    out = _region_fixed_impl(sysb, warr, T_b, alloc0b,
                             jnp.asarray(spec.tol, dtype), acc,
                             spec.max_iters, spec.sp2_method, spec.sp2_iters,
                             mesh, spec.lockstep)
    fleet = _slice_fleet(
        _fleet_result(out, spec.max_iters, dtype, cols=_FIXED_COLS), C)
    return RegionResult(fleet=fleet,
                        _stats_packed=_pack_stats(fleet, n_shards=D),
                        _n_cells=C, _mesh_devices=D)


def _solve_fleet(p: Problem, spec: SolverSpec, sysp, init):
    acc = p.acc if p.acc is not None else default_accuracy()
    dtype = jnp.asarray(sysp.gain).dtype
    C = int(jnp.asarray(sysp.gain).shape[0])
    warr = weights_leaf(p.weights, dtype, cells=C)
    fn = _fleet_cell_fn(acc, spec.max_iters, spec.tol, spec.sp1_method,
                        spec.sp2_method, spec.sp2_iters,
                        with_init=init is not None)
    out = jax.vmap(fn)(sysp, warr) if init is None \
        else jax.vmap(fn)(sysp, warr, init)
    return _fleet_result(out, spec.max_iters, dtype)


def _solve_region(p: Problem, spec: SolverSpec, sysp, init):
    from repro.region.mesh import (RegionResult, _pack_stats,
                                   _region_solve_impl, _slice_fleet,
                                   pad_cells, place_cells)

    mesh = p.mesh
    acc = p.acc if p.acc is not None else default_accuracy()
    C = int(jnp.asarray(sysp.gain).shape[0])
    D = int(mesh.devices.size)
    Cp = -(-C // D) * D
    dtype = jnp.asarray(sysp.gain).dtype
    sysb = place_cells(pad_cells(sysp, Cp), mesh)
    initb = None if init is None else place_cells(pad_cells(init, Cp), mesh)
    warr = place_cells(pad_cells(weights_leaf(p.weights, dtype, cells=C),
                                 Cp), mesh)
    out = _region_solve_impl(sysb, warr, initb, jnp.asarray(spec.tol, dtype),
                             acc, spec.max_iters, spec.sp1_method,
                             spec.sp2_method, spec.sp2_iters, mesh,
                             spec.lockstep, init is not None)
    fleet = _slice_fleet(_fleet_result(out, spec.max_iters, dtype), C)
    return RegionResult(fleet=fleet,
                        _stats_packed=_pack_stats(fleet, n_shards=D),
                        _n_cells=C, _mesh_devices=D)


def _solve_rounds(p: Problem, spec: SolverSpec, sysp, init):
    from repro.dynamics.engine import (_check_simulation_init, _result,
                                       _run_rounds_impl)

    acc = p.acc if p.acc is not None else default_accuracy()
    cfg = p.rounds
    _check_simulation_init(cfg, init)
    alloc0 = init if init is not None else initial_allocation(sysp)
    state0 = _init_carry_state(sysp, alloc0)
    warr = weights_leaf(p.weights, state0[0].dtype)
    return _result(_run_rounds_impl(sysp, warr, acc, p.key, state0, cfg))


def _solve_rounds_fleet(p: Problem, spec: SolverSpec, sysp, init):
    from repro.dynamics.engine import (_check_simulation_init, _result,
                                       _run_rounds_fleet_impl)

    acc = p.acc if p.acc is not None else default_accuracy()
    cfg = p.rounds
    _check_simulation_init(cfg, init)
    dtype = jnp.asarray(sysp.gain).dtype
    C = int(jnp.asarray(sysp.gain).shape[0])
    warr = weights_leaf(p.weights, dtype, cells=C)
    keys = jax.random.split(p.key, C)
    init_state = None if init is None else jax.vmap(_init_carry_state)(
        sysp, init)
    return _result(_run_rounds_fleet_impl(sysp, warr, acc, keys, init_state,
                                          cfg))


def _solve_rounds_region(p: Problem, spec: SolverSpec, sysp, init):
    from repro.dynamics.config import RoundsResult
    from repro.dynamics.engine import _check_simulation_init, _result
    from repro.region.mesh import (_region_rounds_impl, pad_cells,
                                   place_cells)

    mesh = p.mesh
    acc = p.acc if p.acc is not None else default_accuracy()
    cfg = p.rounds
    _check_simulation_init(cfg, init)
    C = int(jnp.asarray(sysp.gain).shape[0])
    D = int(mesh.devices.size)
    Cp = -(-C // D) * D
    dtype = jnp.asarray(sysp.gain).dtype
    warr = place_cells(pad_cells(weights_leaf(p.weights, dtype, cells=C),
                                 Cp), mesh)
    keys = pad_cells(jax.random.split(p.key, C), Cp)
    sysb = place_cells(pad_cells(sysp, Cp), mesh)
    keysb = place_cells(keys, mesh)
    init_state = None if init is None else jax.vmap(_init_carry_state)(
        sysp, init)
    initb = None if init_state is None else place_cells(
        pad_cells(init_state, Cp), mesh)
    out = _region_rounds_impl(sysb, warr, keysb, initb, acc, cfg, mesh,
                              spec.lockstep, init_state is not None)
    res = _result(out)
    cut = lambda x: x[:C]
    return RoundsResult(
        allocation=jax.tree_util.tree_map(cut, res.allocation),
        ledger=cut(res.ledger), staleness=cut(res.staleness),
        gains=cut(res.gains), resolutions=cut(res.resolutions),
        columns=res.columns)
