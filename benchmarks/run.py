"""Benchmark harness — one function per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run                   # all
    PYTHONPATH=src python -m benchmarks.run fig3 fig8         # subset
    PYTHONPATH=src python -m benchmarks.run --json out.json fleet

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's metric).
``--json PATH`` additionally writes the rows as a BENCH_*.json-style artifact
for the perf trajectory (list of {name, us_per_call, derived} objects).
``--metrics PATH`` writes the run's `repro.obs` metrics registry (latency
histograms with derived p50/p90/p99) as metrics JSONL — the CI artifact.
Scaled down from the paper's N=50/100-rep setup to run on one CPU core; the
trends, not the absolute magnitudes, are the reproduction target
(EXPERIMENTS.md compares against the paper's claims).
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import (Problem, SolverSpec, Weights, make_fleet, make_system,
                   obs, solve)
from repro.core import total_energy, total_time
from repro.core.baselines import comm_only, comp_only, min_pixel, rand_pixel, scheme1
from repro.core.types import dbm_to_watt

N_DEV = 12
REPS = 2

_ROWS: list = []


def _row(name, t0, t1, derived, calls=1):
    us = (t1 - t0) / max(calls, 1) * 1e6
    _ROWS.append(dict(name=name, us_per_call=round(us), derived=str(derived)))
    print(f"{name},{us:.0f},{derived}", flush=True)


def _lat_pcts(lat):
    """p50/p99 of a latency sample through the repo's fixed-bucket
    `repro.obs` Histogram — the same layout (and thus the same ~7%
    quantization) as the live metrics and the compare.py gate, replacing
    the ad-hoc np.percentile math the rows used to carry."""
    h = obs.Histogram("lat")
    h.observe_many(float(x) for x in lat)
    return dict(p50=h.percentile(50), p99=h.percentile(99))


def _mean_over_seeds(fn, reps=REPS):
    es, ts = [], []
    for r in range(reps):
        e, t = fn(jax.random.PRNGKey(100 + r))
        es.append(e)
        ts.append(t)
    return sum(es) / len(es), sum(ts) / len(ts)


def fig3_weight_sweep_power():
    """Fig. 3: energy/time vs p_max for three (w1,w2) pairs + MinPixel (rho=1)."""
    for pmax_dbm in [4.0, 8.0, 12.0]:
        for w1, w2 in [(0.9, 0.1), (0.5, 0.5), (0.1, 0.9)]:
            def run(key, w1=w1, w2=w2):
                sysp = make_system(key, n_devices=N_DEV, p_max=dbm_to_watt(pmax_dbm))
                res = solve(Problem(system=sysp, weights=Weights(w1, w2, 1.0)),
                            SolverSpec(max_iters=6))
                return (float(total_energy(sysp, res.allocation)),
                        float(total_time(sysp, res.allocation)))
            t0 = time.time()
            e, t = _mean_over_seeds(run)
            _row(f"fig3.w{w1}-{w2}.pmax{pmax_dbm:g}dBm", t0, time.time(),
                 f"E={e:.4g}J;T={t:.4g}s", REPS)

        def run_bench(key):
            sysp = make_system(key, n_devices=N_DEV, p_max=dbm_to_watt(pmax_dbm))
            a = min_pixel(sysp, key, sweep="power")
            return (float(total_energy(sysp, a)), float(total_time(sysp, a)))
        t0 = time.time()
        e, t = _mean_over_seeds(run_bench)
        _row(f"fig3.MinPixel.pmax{pmax_dbm:g}dBm", t0, time.time(),
             f"E={e:.4g}J;T={t:.4g}s", REPS)


def fig4_weight_sweep_freq():
    """Fig. 4: energy/time vs f_max (rho=10)."""
    for fmax in [0.5e9, 1.0e9, 2.0e9]:
        for w1, w2 in [(0.9, 0.1), (0.5, 0.5), (0.1, 0.9)]:
            def run(key, w1=w1, w2=w2):
                sysp = make_system(key, n_devices=N_DEV, f_max=fmax)
                res = solve(Problem(system=sysp, weights=Weights(w1, w2, 10.0)),
                            SolverSpec(max_iters=6))
                return (float(total_energy(sysp, res.allocation)),
                        float(total_time(sysp, res.allocation)))
            t0 = time.time()
            e, t = _mean_over_seeds(run)
            _row(f"fig4.w{w1}-{w2}.fmax{fmax/1e9:g}GHz", t0, time.time(),
                 f"E={e:.4g}J;T={t:.4g}s", REPS)

        def run_bench(key):
            sysp = make_system(key, n_devices=N_DEV, f_max=fmax)
            a = min_pixel(sysp, key, sweep="freq")
            return (float(total_energy(sysp, a)), float(total_time(sysp, a)))
        t0 = time.time()
        e, t = _mean_over_seeds(run_bench)
        _row(f"fig4.MinPixel.fmax{fmax/1e9:g}GHz", t0, time.time(),
             f"E={e:.4g}J;T={t:.4g}s", REPS)


def fig5_rho_sweep():
    """Fig. 5: energy/time vs rho, + MinPixel/RandPixel, (w1,w2)=(0.5,0.5)."""
    for rho in [1.0, 10.0, 30.0, 50.0]:
        def run(key, rho=rho):
            sysp = make_system(key, n_devices=N_DEV)
            res = solve(Problem(system=sysp, weights=Weights(0.5, 0.5, rho)),
                        SolverSpec(max_iters=6))
            a = res.allocation
            return (float(total_energy(sysp, a)), float(total_time(sysp, a)),
                    float(jnp.mean(a.resolution)))
        t0 = time.time()
        outs = [run(jax.random.PRNGKey(100 + r)) for r in range(REPS)]
        e = sum(o[0] for o in outs) / REPS
        t = sum(o[1] for o in outs) / REPS
        s = sum(o[2] for o in outs) / REPS
        _row(f"fig5.rho{rho:g}", t0, time.time(),
             f"E={e:.4g}J;T={t:.4g}s;mean_s={s:.0f}px", REPS)
    for name, fn in [("MinPixel", min_pixel), ("RandPixel", rand_pixel)]:
        def run(key, fn=fn):
            sysp = make_system(key, n_devices=N_DEV)
            a = fn(sysp, key)
            return (float(total_energy(sysp, a)), float(total_time(sysp, a)))
        t0 = time.time()
        e, t = _mean_over_seeds(run)
        _row(f"fig5.{name}", t0, time.time(), f"E={e:.4g}J;T={t:.4g}s", REPS)


def fig7_rho_vs_fl_accuracy():
    """Fig. 6/7: rho -> chosen resolutions -> actual FedAvg accuracy
    (synthetic resolution-sensitive dataset; see DESIGN.md §6)."""
    from repro.fl import make_federated_dataset, simulate

    key = jax.random.PRNGKey(0)
    ds = make_federated_dataset(jax.random.fold_in(key, 1), n_clients=6,
                                per_client=64, base_resolution=16)
    ds_unb = make_federated_dataset(jax.random.fold_in(key, 1), n_clients=6,
                                    per_client=64, base_resolution=16,
                                    unbalanced=True)
    for tag, dset in [("", ds), (".unbalanced", ds_unb)]:
        for rho in [1.0, 30.0, 60.0]:
            if tag and rho != 60.0:
                continue   # one unbalanced point suffices for the trend
            sysp = make_system(key, n_devices=6)
            t0 = time.time()
            res = simulate(jax.random.fold_in(key, 2), sysp,
                           Weights(0.5, 0.5, rho), dataset=dset,
                           dataset_resolutions=(4, 8, 12, 16),
                           global_rounds=12, local_iters=4)
            _row(f"fig7.rho{rho:g}{tag}", t0, time.time(),
                 f"acc={res.ledger['final_accuracy']:.3f};"
                 f"mean_s={res.ledger['mean_resolution']:.0f}px;"
                 f"E={res.ledger['energy_total_J']:.4g}J")


def fig8_joint_vs_single():
    """Fig. 8: joint optimization vs communication-only vs computation-only."""
    for T_total in [80.0, 120.0, 200.0]:
        key = jax.random.PRNGKey(7)
        sysp = make_system(key, n_devices=N_DEV, p_max=dbm_to_watt(10.0))
        w = Weights(0.99, 0.01, 1.0)
        t0 = time.time()
        ours = solve(Problem(system=sysp, weights=w, deadline=T_total),
                     SolverSpec(max_iters=6))
        e_ours = float(total_energy(sysp, ours.allocation))
        a_comm = comm_only(sysp, w, T_total, jax.random.fold_in(key, 1))
        e_comm = float(total_energy(sysp, a_comm))
        a_comp = comp_only(sysp, w, T_total)
        e_comp = float(total_energy(sysp, a_comp))
        _row(f"fig8.T{T_total:g}s", t0, time.time(),
             f"joint={e_ours:.4g}J;comm_only={e_comm:.4g}J;"
             f"comp_only={e_comp:.4g}J")


def fig9_vs_scheme1():
    """Fig. 9: deadline-constrained energy, the paper's conference algorithm
    (joint p/B/f, s pinned) vs Scheme 1 (Yang et al. [11] proxy)."""
    from repro.core.baselines import conference_version

    for T_total in [80.0, 150.0]:
        for pmax_dbm in [6.0, 12.0]:
            key = jax.random.PRNGKey(9)
            sysp = make_system(key, n_devices=N_DEV, p_max=dbm_to_watt(pmax_dbm))
            w = Weights(0.99, 0.01, 0.0)
            t0 = time.time()
            ours = conference_version(sysp, w, T_total, max_iters=6)
            s1 = scheme1(sysp, w, T_total)
            _row(f"fig9.T{T_total:g}s.pmax{pmax_dbm:g}dBm", t0, time.time(),
                 f"ours={float(total_energy(sysp, ours.allocation)):.4g}J;"
                 f"scheme1={float(total_energy(sysp, s1)):.4g}J")


def table_allocator_scaling():
    """Complexity: paper's CVX path is O(N^4.5); ours is closed-form —
    measure wall time vs N."""
    from repro.core.energy import t_cmp
    from repro.core.sp2 import r_min, solve_sp2_direct

    for N in [64, 1024, 16384]:
        key = jax.random.PRNGKey(11)
        sysp = make_system(key, n_devices=N, bandwidth_total=20e6 * N / 50)
        f = jnp.full((N,), 1e9)
        s = jnp.full((N,), 320.0)
        T = float(jnp.max(t_cmp(sysp, f, s))) * 1.2
        rmin = r_min(sysp, f, s, jnp.asarray(T))
        p, B = solve_sp2_direct(sysp, rmin)    # compile
        jax.block_until_ready(B)
        t0 = time.time()
        p, B = solve_sp2_direct(sysp, rmin)
        jax.block_until_ready(B)
        t1 = time.time()
        _row(f"scaling.N{N}", t0, t1, f"sp2_direct={1e3*(t1-t0):.1f}ms")


def fleet_scale():
    """Fleet allocation: one vmap'd BCD solve across C cells x N devices —
    the fleet acceptance row (>= 64 cells x 2048 devices), now through the
    unified `solve()` dispatcher (median-of-3 protocol: one compile/warm
    call, then the median of 3 timed solves — the recorded wall is the
    steady-state dispatcher cost, so a solve()-layer regression shows up
    directly against the BENCH_fleet.json baseline).
    max_iters=8 is calibrated to the fleet regime: the BCD rel-step contracts
    ~5x per iteration and hits the f32 convergence floor around iteration 6
    (the old max_iters=3 could not converge any cell except by luck)."""
    import statistics

    C, N = 64, 2048
    key = jax.random.PRNGKey(31)
    fleet = make_fleet(key, n_cells=C, n_devices=N,
                       bandwidth_total=20e6 * N / 50)
    problem = Problem(system=fleet, weights=Weights(0.5, 0.5, 1.0))
    spec = SolverSpec(max_iters=8)
    res = solve(problem, spec)   # compile / warm
    jax.block_until_ready(res.allocation.bandwidth)
    walls = []
    for _ in range(3):
        t0 = time.time()
        jax.block_until_ready(solve(problem, spec).allocation.bandwidth)
        walls.append(time.time() - t0)
    wall = statistics.median(walls)
    conv = int(jnp.sum(res.converged))
    t0 = time.time()
    _row(f"fleet.C{C}.N{N}", t0, t0 + wall,
         f"devices={C * N};cells_converged={conv}/{C};"
         f"mean_obj={float(jnp.mean(res.objective)):.4g};"
         f"wall_s={wall:.1f}")


def region_scale():
    """Region sharding acceptance row: the fleet row's 64 x 2048 workload
    solved on 1 device via `allocate_fleet` vs sharded over all local
    devices via `allocate_region` (shard_map: each shard's BCD while_loop
    exits when its own cells converge instead of the global lockstep — on
    the 2-core recording host that early exit is what pushes the speedup
    past the core-count ceiling). Run under
    XLA_FLAGS=--xla_force_host_platform_device_count=8 to expose a mesh on
    one CPU host. Also reports the SP2-direct carried-bracket dual-search
    eval count (ledger `sp2_iters` column) vs the non-carried reference."""
    from repro.core.sp2 import direct_eval_counts
    from repro.region import region_mesh

    import os
    import statistics

    C, N = 64, 2048
    key = jax.random.PRNGKey(31)
    fleet = make_fleet(key, n_cells=C, n_devices=N,
                       bandwidth_total=20e6 * N / 50)
    w = Weights(0.5, 0.5, 1.0)
    spec = SolverSpec(max_iters=8)
    ndev = jax.device_count()
    cores = os.cpu_count() or 1

    def median_wall(fn, reps=3):
        fn()   # compile / warm
        walls = []
        for _ in range(reps):
            t0 = time.time()
            fn()
            walls.append(time.time() - t0)
        return statistics.median(walls)

    res1 = solve(Problem(system=fleet, weights=w), spec)
    t_1dev = median_wall(lambda: jax.block_until_ready(
        solve(Problem(system=fleet, weights=w),
              spec).allocation.bandwidth))
    walls = {}
    for nd in sorted({min(4, ndev), ndev}):
        if nd <= 1:
            continue
        mesh = region_mesh(nd)
        walls[nd] = median_wall(lambda m=mesh: jax.block_until_ready(
            solve(Problem(system=fleet, weights=w, mesh=m),
                  spec).fleet.allocation.bandwidth))
    reg = solve(Problem(system=fleet, weights=w, mesh=region_mesh()), spec)

    # measured SP2 dual-search evals (sp2_iters ledger col) vs reference
    led = jnp.asarray(res1.history)                      # (C, it, cols)
    ev = float(jnp.nanmean(led[..., 4]))
    ev_ref = direct_eval_counts(res1.objective.dtype)
    conv = int(jnp.sum(reg.converged))
    t_shard = walls.get(ndev, t_1dev)
    scaling = ";".join(
        f"speedup_{nd}dev={t_1dev / max(wl, 1e-9):.2f}x"
        for nd, wl in sorted(walls.items()))
    t0 = time.time()
    _row(f"region.C{C}.N{N}", t0, t0 + t_shard,
         f"devices={C * N};mesh={ndev};host_cores={cores};"
         f"wall_1dev_s={t_1dev:.1f};wall_shard_s={t_shard:.1f};{scaling};"
         f"cells_converged={conv}/{C};"
         f"mean_obj={float(jnp.nanmean(reg.objective)):.4g};"
         f"sp2_evals_per_iter={ev:.0f}_vs_ref_{ev_ref}"
         f"({ev_ref / max(ev, 1.0):.1f}x)")


def rounds_dynamics():
    """Round-dynamics engine acceptance row: R=32 rounds x C=64 cells x
    N=2048 devices as ONE jitted scan (vmap'd over cells, no per-round host
    sync), Gauss-Markov fading + stragglers/staleness + dropouts.

    Warm-vs-cold: the warm engine re-allocates each round from the previous
    round's allocation (bcd_iters=3, tol=1e-3 — the per-round solve residual
    only needs to sit well below the percent-scale channel drift); the cold
    reference is the SAME engine with warm_start=False, i.e. a cold
    `allocate_fleet` (paper init, fleet-row max_iters=8 calibration) every
    round. Both walls include one compile amortized over the 32 rounds."""
    from repro.dynamics import RoundsConfig

    R, C, N = 32, 64, 2048
    key = jax.random.PRNGKey(51)
    fleet = make_fleet(key, n_cells=C, n_devices=N,
                       bandwidth_total=20e6 * N / 50)
    w = Weights(0.5, 0.5, 1.0)

    # round-0 allocation the warm engine starts from (one cold fleet solve)
    t0 = time.time()
    base = solve(Problem(system=fleet, weights=w), SolverSpec(max_iters=8))
    jax.block_until_ready(base.allocation.bandwidth)
    t_base = time.time() - t0

    kw = dict(rounds=R, channel_mode="markov", drift_rho=0.95,
              participation="stale", dropout_prob=0.02, bcd_tol=1e-3)
    walls, conv_min, iters_mean, rr_warm = {}, {}, {}, None
    for tag, cfg in [
        ("warm", RoundsConfig(bcd_iters=3, **kw)),
        ("cold", RoundsConfig(bcd_iters=8, warm_start=False, **kw)),
    ]:
        t0 = time.time()
        rr = solve(Problem(system=fleet, weights=w, rounds=cfg,
                           key=jax.random.PRNGKey(52),
                           init=base.allocation))
        jax.block_until_ready(rr.ledger)
        walls[tag] = time.time() - t0
        per_round_cells = jnp.mean(rr.col("bcd_converged"), axis=0)
        conv_min[tag] = float(jnp.min(per_round_cells))
        iters_mean[tag] = float(jnp.mean(rr.col("bcd_iters")))
        if tag == "warm":
            rr_warm = rr
        del rr   # don't retain the cold run's (C, R, N) arrays

    rr = rr_warm
    t0 = time.time()
    _row(f"rounds.R{R}.C{C}.N{N}", t0, t0 + walls["warm"],
         f"devices={C * N};s_per_round={walls['warm'] / R:.2f};"
         f"warm_vs_cold={walls['cold'] / walls['warm']:.1f}x;"
         f"conv_min={conv_min['warm']:.3f};"
         f"mean_bcd_iters={iters_mean['warm']:.2f};"
         f"arrived_frac={float(jnp.mean(rr.col('arrived_frac'))):.3f};"
         f"mean_obj={float(jnp.mean(rr.col('objective'))):.4g};"
         f"fleet_solve_s={t_base:.1f}")
    t0 = time.time()
    _row(f"rounds.cold_restart.R{R}.C{C}.N{N}", t0, t0 + walls["cold"],
         f"s_per_round={walls['cold'] / R:.2f};"
         f"conv_min={conv_min['cold']:.3f};"
         f"mean_bcd_iters={iters_mean['cold']:.2f}")


def serve_latency():
    """Pipelined region serving acceptance: p50/p99 request latency and
    sustained req/s on a 256-request mixed-size trace (4 device buckets ->
    <= 4 compiled shapes), under Poisson and bursty arrivals.

    `sync` replays the trace through the pre-pipeline monolith loop (the
    PR 4-5 `RegionAllocator._solve_chunk`, reconstructed below verbatim):
    eager jnp padding/stacking enqueued on the device stream, one blocking
    solve per chunk, then a per-cell jnp-slice gather — host assembly and
    device compute strictly serialized. `pipelined` is the four-layer
    `RegionPipeline` at depth 2: numpy host assembly, async dispatch,
    double-buffered batches, one deferred numpy gather per batch. The
    acceptance gate is pipelined >= 1.3x the sync req/s on the Poisson
    trace (checked by compare.py --strict via the speedup_vs_sync field).

    Arrival offsets span half the pipelined serial drain wall, so both
    paths run saturated and the sustained rate reflects each path's
    capacity; request latency = completion - arrival. All cell ids are
    unique (every solve cold) so both paths do identical device work."""
    import numpy as np

    from repro.core.bcd import initial_allocation, stack_systems
    from repro.core.types import Allocation
    from repro.region import AllocationRequest, MaxWait, RegionPipeline
    from repro.region.batch import bucket_size, pad_allocation, pad_system

    n_req, cells_per_batch, min_bucket = 256, 16, 16
    spec = SolverSpec(max_iters=8, tol=1e-4)
    w = Weights(0.5, 0.5, 1.0)
    # paper-scale cells (~N=50 pools): buckets 16, 32, 64, 128
    sizes = [12, 24, 48, 90]
    key = jax.random.PRNGKey(61)
    systems = [make_system(jax.random.fold_in(key, i),
                           n_devices=sizes[i % len(sizes)])
               for i in range(n_req)]

    def pipe(depth):
        return RegionPipeline(w, cells_per_batch=cells_per_batch,
                              min_bucket=min_bucket, spec=spec,
                              policy=MaxWait(0.05), max_in_flight=depth)

    def trace():
        return [AllocationRequest(cell_id=i, sys=systems[i])
                for i in range(n_req)]

    # ---------------- the PR 4-5 synchronous monolith, reconstructed ----
    class _LegacyAllocator:
        """The pre-pipeline `RegionAllocator` chunk loop: eager jnp
        assembly, blocking solve, immediate per-cell jnp-slice gather."""

        def __init__(self):
            self._cache = {}
            self.shapes = set()

        def solve_chunk(self, chunk, bucket):
            C = cells_per_batch
            padded = [pad_system(r.sys, bucket) for r in chunk]
            inits = []
            for r, ps in zip(chunk, padded):
                got = self._cache.get(r.cell_id)
                init = pad_allocation(got[1], bucket, ps) \
                    if got is not None and got[0] == r.sys.n \
                    else initial_allocation(ps)
                if init.s_relaxed is None or init.T is None:
                    dt = jnp.asarray(init.bandwidth).dtype
                    init = Allocation(
                        bandwidth=init.bandwidth, power=init.power,
                        freq=init.freq, resolution=init.resolution,
                        s_relaxed=init.resolution if init.s_relaxed is None
                        else init.s_relaxed,
                        T=jnp.zeros((), dt) if init.T is None else init.T)
                inits.append(init)
            n_real = len(chunk)
            while len(padded) < C:   # short chunks replicated cell 0
                padded.append(padded[0])
                inits.append(inits[0])
            sys_batch = stack_systems(padded)
            init_batch = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *inits)
            res = solve(Problem(system=sys_batch, weights=[w] * C,
                                init=init_batch), spec)
            self.shapes.add((C, bucket))
            objs = np.asarray(res.objective[:n_real])
            for c, r in enumerate(chunk):
                n = r.sys.n
                a = res.allocation
                alloc = Allocation(
                    bandwidth=a.bandwidth[c, :n], power=a.power[c, :n],
                    freq=a.freq[c, :n], resolution=a.resolution[c, :n],
                    s_relaxed=None if a.s_relaxed is None
                    else a.s_relaxed[c, :n],
                    T=None if a.T is None else a.T[c])
                self._cache[r.cell_id] = (n, alloc)
                float(objs[c])   # the old CellResponse sync point

    # compile the bucket menu for BOTH paths once, outside every timed
    # replay: the first 4 * cells_per_batch requests cover all four
    # buckets exactly. The paths do NOT share compiled programs — the
    # monolith's eager-jnp operands carry weak_type leaves (python-float
    # scalars), the planner's numpy operands are strong-typed, and the
    # jit cache keys on weak_type.
    warm = pipe(1)
    for r in trace()[:4 * cells_per_batch]:
        warm.submit(r)
    warm.drain()
    warm_legacy = _LegacyAllocator()
    by_bucket = {}
    for r in trace()[:4 * cells_per_batch]:
        by_bucket.setdefault(bucket_size(r.sys.n, min_bucket), []).append(r)
    for b, chunk in sorted(by_bucket.items()):
        warm_legacy.solve_chunk(chunk, b)

    def replay_sync(arrivals):
        alloc = _LegacyAllocator()
        reqs = trace()
        done_t = np.full(n_req, np.nan)
        queues = {}
        i, completed = 0, 0
        t0 = time.monotonic()
        while completed < n_req:
            now = time.monotonic() - t0
            while i < n_req and arrivals[i] <= now:
                b = bucket_size(reqs[i].sys.n, min_bucket)
                queues.setdefault(b, []).append((i, reqs[i]))
                i += 1
            full = [b for b, q in queues.items()
                    if len(q) >= cells_per_batch]
            if full:
                b = full[0]
            elif i >= n_req and any(queues.values()):
                # end of trace: flush leftovers, still one chunk at a time
                b = max(queues, key=lambda k: len(queues[k]))
            else:
                time.sleep(5e-4)   # idle until the next arrival is due
                continue
            batch = queues[b][:cells_per_batch]
            queues[b] = queues[b][cells_per_batch:]
            alloc.solve_chunk([r for _, r in batch], b)
            stamp = time.monotonic() - t0
            for k, _ in batch:
                done_t[k] = stamp
            completed += len(batch)
        lat = done_t - np.asarray(arrivals)
        wall = float(np.max(done_t))
        assert len(alloc.shapes) <= 4, alloc.shapes
        return dict(lat=lat, req_s=n_req / wall, wall=wall,
                    **_lat_pcts(lat))

    def replay(arrivals, depth):
        p = pipe(depth)
        reqs = trace()
        futs = [None] * n_req
        done_t = np.full(n_req, np.nan)
        open_idx = set(range(n_req))
        i = 0
        t0 = time.monotonic()
        while open_idx:
            now = time.monotonic() - t0
            n_new = 0
            while i < n_req and arrivals[i] <= now:
                futs[i] = p.submit(reqs[i])
                i += 1
                n_new += 1
            p.pump(force=(i >= n_req))
            if i >= n_req and p.in_flight:
                # no more arrivals: block on the oldest open future so
                # completions keep getting per-batch timestamps
                j = min(k for k in open_idx if futs[k].dispatched)
                futs[j].result()
            stamp = time.monotonic() - t0
            resolved = [k for k in open_idx
                        if futs[k] is not None and futs[k].done()]
            for k in resolved:
                done_t[k] = stamp
                open_idx.discard(k)
            if not resolved and not n_new and i < n_req:
                time.sleep(5e-4)   # idle until the next arrival is due
        lat = done_t - np.asarray(arrivals)
        wall = float(np.max(done_t))
        assert len(p.compiled_shapes) <= 4, p.compiled_shapes
        return dict(lat=lat, req_s=n_req / wall, wall=wall,
                    **_lat_pcts(lat))

    # the pipelined drain wall calibrates the arrival span: arrivals must
    # outpace the FASTER path so both replays measure capacity, not the
    # arrival rate
    t0 = time.monotonic()
    replay(np.zeros(n_req), 2)
    span = 0.5 * (time.monotonic() - t0)

    rng = np.random.RandomState(3)
    ia = rng.exponential(1.0, n_req)
    arrivals = dict(
        poisson=np.cumsum(ia) * (span / np.sum(ia)),
        bursty=np.repeat(np.arange(8), n_req // 8) * (span / 8))

    for trace_name, arr in arrivals.items():
        out_sync = replay_sync(arr)
        out_pipe = replay(arr, 2)
        for tag, out in (("sync", out_sync), ("pipelined", out_pipe)):
            # metric plane: the same latencies land in the global registry
            # so --metrics exports them with derived percentiles
            obs.REGISTRY.histogram("serve_latency_seconds",
                                   trace=trace_name, path=tag
                                   ).observe_many(float(x)
                                                  for x in out["lat"])
            extra = ""
            if tag == "pipelined":
                speedup = out["req_s"] / out_sync["req_s"]
                extra = f";speedup_vs_sync={speedup:.2f}x"
            t0 = time.time()
            _row(f"serve_latency.{trace_name}.{tag}.R{n_req}",
                 t0, t0 + out["wall"],
                 f"p50_ms={1e3 * out['p50']:.0f};"
                 f"p99_ms={1e3 * out['p99']:.0f};"
                 f"req_s={out['req_s']:.1f}{extra}")


def obs_overhead():
    """Telemetry overhead acceptance (the `repro.obs` rows): one saturated
    serving trace replayed under three recorder arms — off (the default
    no-op), on (a memory recorder), jsonl (a streaming `JsonlRecorder`) —
    for Poisson and bursty arrivals. Rows carry req/s plus
    histogram-derived p50/p99 and the enabled arms' measured slowdown vs
    the off arm.

    The hard gate is the *no-op* overhead: the measured per-call cost of
    a disabled span/point site times the trace's telemetry site count
    must stay under 2% of the off arm's wall time (asserted here and in
    tests/test_obs.py). The enabled arms are informational — they pay for
    real event capture."""
    import os
    import tempfile

    from repro.region import AllocationRequest, MaxWait, RegionPipeline

    n_req, cells_per_batch, min_bucket = 64, 8, 16
    spec = SolverSpec(max_iters=8, tol=1e-4)
    w = Weights(0.5, 0.5, 1.0)
    sizes = [12, 24]
    key = jax.random.PRNGKey(71)
    systems = [make_system(jax.random.fold_in(key, i),
                           n_devices=sizes[i % len(sizes)])
               for i in range(n_req)]

    def pipe():
        return RegionPipeline(w, cells_per_batch=cells_per_batch,
                              min_bucket=min_bucket, spec=spec,
                              policy=MaxWait(0.02), max_in_flight=2)

    def trace():
        return [AllocationRequest(cell_id=i, sys=systems[i])
                for i in range(n_req)]

    def replay(arrivals):
        p = pipe()
        reqs = trace()
        futs = [None] * n_req
        done_t = np.full(n_req, np.nan)
        open_idx = set(range(n_req))
        i = 0
        t0 = time.monotonic()
        while open_idx:
            now = time.monotonic() - t0
            n_new = 0
            while i < n_req and arrivals[i] <= now:
                futs[i] = p.submit(reqs[i])
                i += 1
                n_new += 1
            p.pump(force=(i >= n_req))
            if i >= n_req and p.in_flight:
                j = min(k for k in open_idx if futs[k].dispatched)
                futs[j].result()
            stamp = time.monotonic() - t0
            resolved = [k for k in open_idx
                        if futs[k] is not None and futs[k].done()]
            for k in resolved:
                done_t[k] = stamp
                open_idx.discard(k)
            if not resolved and not n_new and i < n_req:
                time.sleep(5e-4)   # idle until the next arrival is due
        lat = done_t - np.asarray(arrivals)
        wall = float(np.max(done_t))
        return dict(lat=lat, req_s=n_req / wall, wall=wall,
                    **_lat_pcts(lat))

    # compile the bucket menu + warm every cache outside the timed arms,
    # then calibrate the arrival span off a saturated drain
    replay(np.zeros(n_req))
    t0 = time.monotonic()
    replay(np.zeros(n_req))
    span = 0.5 * (time.monotonic() - t0)

    rng = np.random.RandomState(5)
    ia = rng.exponential(1.0, n_req)
    arrivals = dict(
        poisson=np.cumsum(ia) * (span / np.sum(ia)),
        bursty=np.repeat(np.arange(4), n_req // 4) * (span / 4))

    # measured per-call cost of a DISABLED span/point site, and the site
    # count of one enabled trace: together they bound the no-op overhead
    reps = 20000
    t0 = time.monotonic()
    for _ in range(reps):
        with obs.span("x"):
            pass
        obs.point("x")
    per_site = (time.monotonic() - t0) / (2 * reps)
    rec = obs.MemoryRecorder()
    with obs.recording(rec):
        replay(np.zeros(n_req))
    n_sites = len(rec.events)

    tmp = tempfile.mkdtemp(prefix="obs_overhead_")
    for trace_name, arr in arrivals.items():
        out_off = replay(arr)
        with obs.recording(obs.MemoryRecorder()):
            out_on = replay(arr)
        with obs.recording(obs.JsonlRecorder(
                os.path.join(tmp, f"{trace_name}.jsonl"))):
            out_jsonl = replay(arr)

        noop_overhead = n_sites * per_site / out_off["wall"]
        assert noop_overhead < 0.02, (
            f"no-op telemetry overhead {noop_overhead:.2%} "
            f"({n_sites} sites x {per_site * 1e9:.0f}ns) >= 2%")

        for tag, out in (("off", out_off), ("on", out_on),
                         ("jsonl", out_jsonl)):
            obs.REGISTRY.histogram("obs_overhead_latency_seconds",
                                   trace=trace_name, recorder=tag
                                   ).observe_many(float(x)
                                                  for x in out["lat"])
            extra = (f";noop_overhead_pct={100 * noop_overhead:.3f}"
                     if tag == "off" else
                     f";slowdown_vs_off="
                     f"{out_off['req_s'] / out['req_s']:.2f}x")
            t0 = time.time()
            _row(f"obs_overhead.{trace_name}.{tag}.R{n_req}",
                 t0, t0 + out["wall"],
                 f"p50_ms={1e3 * out['p50']:.0f};"
                 f"p99_ms={1e3 * out['p99']:.0f};"
                 f"req_s={out['req_s']:.1f}{extra}")


def slo():
    """SLO plane acceptance rows: a deadlined serving trace replayed
    through the pipeline with the default SLO set evaluated live over the
    global registry (the same wiring `examples/serve_observed.py` and the
    `/slo` endpoint use). The row's derived fields are the gate inputs for
    `compare.py --slo`: `slo_breaches` (total breach verdicts) and one
    `slo_<name>_ok` flag per objective (1 = verdict was not a breach), so
    a baseline-vs-fresh comparison fails --strict when an objective that
    used to hold starts breaching."""
    from repro.region import AllocationRequest, MaxWait, RegionPipeline

    n_req, cells_per_batch, min_bucket = 48, 8, 16
    spec = SolverSpec(max_iters=8, tol=1e-4)
    w = Weights(0.5, 0.5, 1.0)
    sizes = [12, 24]
    key = jax.random.PRNGKey(81)
    systems = [make_system(jax.random.fold_in(key, i),
                           n_devices=sizes[i % len(sizes)])
               for i in range(n_req)]

    def pipe():
        return RegionPipeline(w, cells_per_batch=cells_per_batch,
                              min_bucket=min_bucket, spec=spec,
                              policy=MaxWait(0.02), max_in_flight=2)

    def replay(deadline_budget=None, plane=None):
        p = pipe()
        t_start = time.monotonic()
        futs = []
        for i in range(n_req):
            dl = None if deadline_budget is None \
                else time.monotonic() + deadline_budget
            futs.append(p.submit(AllocationRequest(
                cell_id=i, sys=systems[i], deadline=dl)))
            if i % cells_per_batch == 0:
                p.poll()
                if plane is not None:
                    plane.observe()
        p.drain()
        return time.monotonic() - t_start, p.stats

    replay()   # compile the bucket menu + warm caches, no deadlines

    plane = obs.SloPlane(obs.default_slos(
        latency_threshold_s=2.0, latency_objective=0.9,
        deadline_objective=0.9, convergence_objective=0.5))
    plane.observe()
    t0 = time.time()
    wall, stats = replay(deadline_budget=10.0, plane=plane)
    verdicts = plane.check()
    breaches = sum(v["verdict"] == "breach" for v in verdicts)
    flags = ";".join(
        f"slo_{v['name']}_ok={0 if v['verdict'] == 'breach' else 1}"
        for v in verdicts)
    hit = stats["deadline_hits"]
    total = stats["deadline_requests"]
    _row(f"slo.serve.R{n_req}", t0, t0 + wall,
         f"slo_breaches={breaches};{flags};"
         f"deadline_hit_rate={hit / max(total, 1):.3f};"
         f"cells_converged={stats['cells_converged']}/"
         f"{stats['cells_solved']}")


def xla_cost():
    """XLA compiled-cost trajectory rows: AOT-lower the solver's
    single-cell and fleet programs and record the backend cost model's
    FLOPs / bytes-accessed per compiled shape (`repro.obs.profile`).
    Nothing executes — the rows track compute-per-shape across PRs, so an
    algorithmic change that bloats the compiled program shows up in the
    BENCH artifact even when wall time hides it."""
    from repro.obs import profile

    spec = SolverSpec(max_iters=8, tol=1e-4)
    w = Weights(0.5, 0.5, 1.0)
    key = jax.random.PRNGKey(91)

    shapes = [("bcd", make_system(key, n_devices=N_DEV), f"N{N_DEV}"),
              ("fleet", make_fleet(jax.random.fold_in(key, 1), n_cells=8,
                                   n_devices=N_DEV), f"C8.N{N_DEV}")]
    for kind, sysp, tag in shapes:
        t0 = time.time()
        cost = profile.solve_cost(Problem(system=sysp, weights=w),
                                  spec=spec)
        t1 = time.time()
        if cost is None:
            _row(f"xla_cost.{kind}.{tag}", t0, t1, "flops=nan;bytes=nan")
            continue
        _row(f"xla_cost.{kind}.{tag}", t0, t1,
             f"flops={cost['flops']:.4g};bytes={cost['bytes_accessed']:.4g}")


def assoc_mobility():
    """Cross-cell association + mobility churn acceptance rows.

    Row 1: BCD-over-association vs the static nearest-cell (max-gain)
    baseline on a bandwidth-heterogeneous region — the realized global
    weighted objective after per-cell re-solves must improve on the
    baseline (objectives[0] IS the nearest-assignment solve, so the win is
    measured on identical solver settings).

    Row 2: a seeded random-waypoint trace replayed through
    `RegionAllocator` — handovers purge warm-cache entries on both sides
    of each move, and the row records the measured hit rate and mean
    warm/cold re-solve iterations under churn, with the compiled batch
    shape count bounded (<= 5)."""
    from repro import (AssocConfig, MobilityConfig, RegionAllocator,
                      make_multicell, replay_mobility, simulate_mobility)

    C, N, R = 6, 48, 10
    w = Weights(0.5, 0.5, 5.0)
    spec = SolverSpec(max_iters=6, tol=1e-4)
    key = jax.random.PRNGKey(71)
    bands = [5e6 * (1 + 7 * c / (C - 1)) for c in range(C)]
    sysb = make_multicell(key, n_cells=C, n_devices=N,
                          bandwidth_total=bands)

    t0 = time.time()
    res = solve(Problem(system=sysb, weights=w,
                        assoc=AssocConfig(outer_iters=8)), spec)
    t1 = time.time()
    nearest_obj, assoc_obj = res.objectives[0], res.objective
    assert assoc_obj <= nearest_obj
    win = 100.0 * (nearest_obj - assoc_obj) / abs(nearest_obj)
    _row(f"assoc_mobility.bcd_vs_nearest.C{C}.N{N}", t0, t1,
         f"nearest_obj={nearest_obj:.4g};assoc_obj={assoc_obj:.4g};"
         f"win={win:.1f}%;outer_iters={res.outer_iters};"
         f"moves={sum(res.moves)}")

    # drift_rho=0.98: step-to-step shadowing stays correlated so handovers
    # come from movement, not fading noise — the hit rate under churn is
    # then a real cache measurement instead of ~0
    cfg = MobilityConfig(model="rwp", steps=R, dt=2.0, v_min=2.0,
                         v_max=20.0, drift_rho=0.98)
    trace = simulate_mobility(jax.random.PRNGKey(72), n_devices=N,
                              n_cells=C, cfg=cfg)
    base = make_system(jax.random.PRNGKey(73), n_devices=N)
    svc = RegionAllocator(w, cells_per_batch=4, min_bucket=16, spec=spec)
    t0 = time.time()
    rep = replay_mobility(svc, trace, base)
    t1 = time.time()
    assert rep["handover_purges"] <= 2 * rep["handovers"]
    assert len(rep["compiled_shapes"]) <= 5, rep["compiled_shapes"]
    _row(f"assoc_mobility.churn.R{R}.C{C}.N{N}", t0, t1,
         f"handovers={rep['handovers']};purges={rep['handover_purges']};"
         f"hit_rate={rep['hit_rate']:.2f};"
         f"warm_iters={rep['mean_warm_iters']:.1f};"
         f"cold_iters={rep['mean_cold_iters']:.1f};"
         f"shapes={len(rep['compiled_shapes'])}")


def sp1_sweep_scale():
    """SP1 engines head-to-head: the batched T-grid dual sweep vs the nested
    56x56 bisection oracle, one solve at region scale (per-iteration SP1 cost
    inside the fleet BCD). Reports the wall-time ratio and the relative
    deadline parity between the two engines."""
    from repro.core.accuracy import default_accuracy
    from repro.core.sp1 import solve_sp1

    N = 1 << 15
    key = jax.random.PRNGKey(41)
    sysp = make_system(key, n_devices=N, bandwidth_total=20e6 * N / 50)
    acc = default_accuracy()
    w = Weights(0.5, 0.5, 1.0).normalized()
    B = jnp.full((N,), sysp.bandwidth_total / N)
    p = jnp.full((N,), sysp.p_max)

    walls, T_by = {}, {}
    for method in ("sweep", "bisect"):
        out = solve_sp1(sysp, w, acc, B, p, method=method)   # compile
        jax.block_until_ready(out[0])
        t0 = time.time()
        out = solve_sp1(sysp, w, acc, B, p, method=method)
        jax.block_until_ready(out[0])
        walls[method] = time.time() - t0
        T_by[method] = float(out[3])
    rel = abs(T_by["sweep"] - T_by["bisect"]) / abs(T_by["bisect"])
    t0 = time.time()
    _row(f"sp1_sweep.N{N}", t0, t0 + walls["sweep"],
         f"sweep_ms={1e3 * walls['sweep']:.1f};"
         f"bisect_ms={1e3 * walls['bisect']:.1f};"
         f"speedup={walls['bisect'] / max(walls['sweep'], 1e-9):.1f}x;"
         f"T_rel_err={rel:.2e}")


def autodiff():
    """Implicit-KKT gradient overhead (PR 10): `diff.solve_and_grad` vs the
    forward `solve()` on the same spec/shape. The differentiable path
    re-runs the fixed point under one linearization and pulls 4 metric
    cotangents through the Neumann adjoint, so the budget is <= 3x a
    forward solve — exported as an `slo_grad_overhead_ok` flag for the
    compare.py --slo/--strict gate. A second row times the 17-point
    Pareto weight sweep (one vmapped fleet program)."""
    from repro.diff import pareto_sweep, solve_and_grad

    key = jax.random.PRNGKey(7)
    sysp = make_system(key, n_devices=N_DEV)
    prob = Problem(system=sysp, weights=Weights(0.5, 0.5, 0.3))
    spec = SolverSpec(sp1_method="bisect", tol=1e-5, max_iters=200)

    r = solve(prob, spec)                                  # compile both
    jax.block_until_ready(r.objective)
    g = solve_and_grad(prob, spec)
    jax.block_until_ready(g.value["objective"])

    reps = 5
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(solve(prob, spec).objective)
    fwd_s = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(solve_and_grad(prob, spec).value["objective"])
    grad_s = (time.time() - t0) / reps
    overhead = grad_s / max(fwd_s, 1e-9)
    _row(f"autodiff.grad_overhead.N{N_DEV}", t0, t0 + grad_s,
         f"fwd_ms={1e3 * fwd_s:.2f};grad_ms={1e3 * grad_s:.2f};"
         f"overhead={overhead:.2f}x;"
         f"slo_grad_overhead_ok={1 if overhead <= 3.0 else 0}")

    t0 = time.time()
    sweep = pareto_sweep(prob, spec, n=17)
    t1 = time.time()
    _row("autodiff.pareto_sweep.n17", t0, t1,
         f"front_points={int(sweep.front.sum())};"
         f"converged={int(np.asarray(sweep.converged).sum())}/17")


def roofline_table():
    """Dry-run roofline summary (reads dryrun_baseline.jsonl if present)."""
    import os

    from repro.roofline import full_table

    path = "dryrun_baseline.jsonl" if os.path.exists("dryrun_baseline.jsonl") else None
    t0 = time.time()
    rows = full_table(path)
    for r in rows:
        _row(f"roofline.{r['arch']}.{r['shape']}", t0, time.time(),
             f"dominant={r['dominant']};tc={r['t_compute_s']:.2e};"
             f"tm={r['t_memory_s']:.2e};tx={r['t_collective_s']:.2e};"
             f"useful={r['useful_ratio']:.2f}")


def ablations():
    """Component ablations of the allocator (beyond-paper analyses)."""
    from repro.core.accuracy import log_fit
    from repro.core.baselines import scheme1

    # (a) SP2 engine: exact direct vs paper's Algorithm 1 (damped)
    key = jax.random.PRNGKey(21)
    sysp = make_system(key, n_devices=N_DEV)
    w = Weights(0.5, 0.5, 1.0)
    t0 = time.time()
    r_dir = solve(Problem(system=sysp, weights=w),
                  SolverSpec(max_iters=6, sp2_method="direct"))
    r_jng = solve(Problem(system=sysp, weights=w),
                  SolverSpec(max_iters=6, sp2_method="jong"))
    _row("ablation.sp2_engine", t0, time.time(),
         f"direct_E={r_dir.history[-1]['energy']:.4g}J;"
         f"jong_E={r_jng.history[-1]['energy']:.4g}J")

    # (b) deadline split optimization on/off (the BCD deadlock fix)
    t0 = time.time()
    with_split = solve(Problem(system=sysp, weights=Weights(0.99, 0.01, 0.0),
                               deadline=150.0), SolverSpec(max_iters=6))
    s1 = scheme1(sysp, Weights(0.99, 0.01, 0.0), 150.0)
    _row("ablation.deadline_split", t0, time.time(),
         f"with_split={float(total_energy(sysp, with_split.allocation)):.4g}J;"
         f"stuck_baseline~scheme1={float(total_energy(sysp, s1)):.4g}J")

    # (b2) SP2-direct dual search: Newton polish on the pmin-branch
    # stationarity vs the bisection-only carried bracket (PR 10 satellite;
    # gated by the measured dE/dB eval counter the ledger already carries)
    from repro.core.energy import t_cmp
    from repro.core.sp2 import _sp2_direct_impl, r_min

    sys_n = make_system(jax.random.PRNGKey(11), n_devices=50,
                        bandwidth_total=20e6)
    f_n = jnp.full((50,), 1e9)
    s_n = jnp.full((50,), 320.0)
    rmin = r_min(sys_n, f_n, s_n,
                 jnp.asarray(float(jnp.max(t_cmp(sys_n, f_n, s_n))) * 1.1))
    t0 = time.time()
    _, _, ev_newton = _sp2_direct_impl(sys_n, rmin, True, True)
    _, _, ev_bisect = _sp2_direct_impl(sys_n, rmin, True, False)
    _row("ablation.sp2_newton", t0, time.time(),
         f"newton_evals={int(ev_newton)};bisect_evals={int(ev_bisect)};"
         f"saved={int(ev_bisect) - int(ev_newton)}")

    # (c) accuracy model: linear (paper) vs concave log fit
    t0 = time.time()
    r_lin = solve(Problem(system=sysp, weights=Weights(0.5, 0.5, 40.0)),
                  SolverSpec(max_iters=6))
    r_log = solve(Problem(system=sysp, weights=Weights(0.5, 0.5, 40.0),
                          acc=log_fit()), SolverSpec(max_iters=6))
    _row("ablation.accuracy_model", t0, time.time(),
         f"linear_mean_s={float(jnp.mean(r_lin.allocation.resolution)):.0f}px;"
         f"logfit_mean_s={float(jnp.mean(r_log.allocation.resolution)):.0f}px")


BENCHES = {
    "fig3": fig3_weight_sweep_power,
    "fig4": fig4_weight_sweep_freq,
    "fig5": fig5_rho_sweep,
    "fig7": fig7_rho_vs_fl_accuracy,
    "fig8": fig8_joint_vs_single,
    "fig9": fig9_vs_scheme1,
    "scaling": table_allocator_scaling,
    "fleet": fleet_scale,
    "region": region_scale,
    "rounds": rounds_dynamics,
    "serve_latency": serve_latency,
    "obs_overhead": obs_overhead,
    "slo": slo,
    "xla_cost": xla_cost,
    "assoc_mobility": assoc_mobility,
    "sp1_sweep": sp1_sweep_scale,
    "autodiff": autodiff,
    "ablations": ablations,
    "roofline": roofline_table,
}


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args):
            sys.exit("--json requires a path argument")
        json_path = args[i + 1]
        args = args[:i] + args[i + 2:]
    metrics_path = None
    if "--metrics" in args:
        i = args.index("--metrics")
        if i + 1 >= len(args):
            sys.exit("--metrics requires a path argument")
        metrics_path = args[i + 1]
        args = args[:i] + args[i + 2:]
    which = args or list(BENCHES)
    unknown = [n for n in which if n not in BENCHES]
    if unknown:
        sys.exit(f"unknown bench {unknown}; available: {', '.join(BENCHES)}")
    print("name,us_per_call,derived")
    for name in which:
        BENCHES[name]()
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(dict(rows=_ROWS, benches=which), fh, indent=1)
        print(f"# wrote {len(_ROWS)} rows to {json_path}", file=sys.stderr)
    if metrics_path:
        n = obs.write_metrics_jsonl(metrics_path)
        print(f"# wrote {n} metrics to {metrics_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
