"""SP1 KKT invariants + batched-sweep parity (paper Appendix B, eqs. A.2-A.7).

Three layers:
  * deterministic KKT invariant checks (run everywhere): dual feasibility
    Sigma_n lambda_n = w2 Rg at the returned deadline, primal box
    feasibility of (f, s_hat), monotonicity of the makespan map
    T_n(lambda), and per-device makespans <= the returned T;
  * the same invariants as hypothesis property tests (degrade to skips via
    tests/_hypothesis_stub.py when hypothesis is absent);
  * parity of the batched T-grid sweep engine vs the nested-bisection
    oracle across weight regimes (energy-, latency-, accuracy-heavy), both
    LinearAccuracy and the concave log model, at f32 and f64 — the
    <=1e-5 relative-objective acceptance bound.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.core import Weights, make_system
from repro.core.accuracy import default_accuracy, log_fit
from repro.core.sp1 import (_coeffs, _lambda_of_T, _makespan_of_lambda,
                            _sp1_bounds, solve_sp1)
from repro.kernels import ops
from repro.kernels.ref import sp1_lambda_sum_ref
from repro.kernels.sp1_sweep import (N_CONSTS, lambda_of_T_linear,
                                     sp1_lambda_sum)


def _setup(seed=0, n=10, w=(0.5, 0.5, 1.0), **overrides):
    sysp = make_system(jax.random.PRNGKey(seed), n_devices=n, **overrides)
    weights = Weights(*w).normalized()
    B = jnp.full((n,), sysp.bandwidth_total / n)
    p = jnp.full((n,), sysp.p_max)
    return sysp, weights, B, p


def _tt(sysp, B, p):
    from repro.core.energy import rate

    return sysp.bits / jnp.maximum(rate(sysp, B, p), 1e-12)


def _sp1_objective(sysp, w, acc, f, s, T):
    alpha, _ = _coeffs(sysp, w)
    return (float(jnp.sum(alpha * s ** 2 * f ** 2))
            + float(w.w2 * sysp.global_rounds * T)
            - float(w.rho * jnp.sum(acc.value(s))))


def _continuous_objective(sysp, w, acc, B, p, method):
    """SP1 objective at the continuous KKT point: T is the s_hat makespan
    (engine differences are second-order there — the returned
    max(T, T_out_discrete) moves the w2 Rg T term first-order with the
    engine's T resolution, which is not an engine-parity signal)."""
    f, s, s_hat, _ = solve_sp1(sysp, w, acc, B, p, method=method)
    _, q = _coeffs(sysp, w)
    tt = _tt(sysp, B, p)
    T_root = float(jnp.max(q * s_hat ** 2 / jnp.maximum(f, 1e-9) + tt))
    return _sp1_objective(sysp, w, acc, f, s_hat, T_root)


def _check_kkt(sysp, w, acc, B, p, method, lam_tol=1e-3):
    """The Appendix-B KKT invariants at the solution of `solve_sp1`."""
    f, s, s_hat, T = solve_sp1(sysp, w, acc, B, p, method=method)
    f, s_hat = np.asarray(f), np.asarray(s_hat)
    tt = _tt(sysp, B, p)
    _, q = _coeffs(sysp, w)

    # primal box feasibility (A.2/A.3 clip ranges)
    assert (f >= sysp.f_min * (1 - 1e-9)).all()
    assert (f <= sysp.f_max * (1 + 1e-9)).all()
    assert (s_hat >= sysp.s_lo * (1 - 1e-9)).all()
    assert (s_hat <= sysp.s_hi * (1 + 1e-9)).all()

    # every device finishes inside the returned round deadline
    mk_hat = np.asarray(q) * s_hat ** 2 / np.maximum(f, 1e-9) + np.asarray(tt)
    assert (mk_hat <= float(T) * (1 + 1e-6)).all()
    mk_disc = np.asarray(q) * np.asarray(s) ** 2 / np.maximum(f, 1e-9) \
        + np.asarray(tt)
    assert (mk_disc <= float(T) * (1 + 1e-6)).all()

    # dual feasibility (A.7): Sigma lambda_n = w2 Rg at the continuous root
    # T_root = max_n makespan_hat (tight for every device with lambda_n > 0).
    # When T pins at its lower bound T_lo (every device at s_lo / f_max — the
    # latency-heavy regime) complementary slackness only requires
    # Sigma lambda <= w2 Rg, with the deficit absorbed by the box multipliers.
    T_root = jnp.asarray(mk_hat.max())
    lam_hi, target, T_lo, _ = _sp1_bounds(sysp, w, q, tt)
    lam = _lambda_of_T(sysp, w, acc, T_root, tt, float(lam_hi))
    total, target = float(jnp.sum(lam)), float(target)
    if float(T_root) <= float(T_lo) * (1 + 1e-9):
        assert total <= target * (1 + lam_tol)
    else:
        assert total == pytest.approx(target, rel=lam_tol)


# ---------------------------------------------------------------------------
# deterministic KKT invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["sweep", "bisect"])
@pytest.mark.parametrize("wts", [(0.9, 0.1, 1.0), (0.5, 0.5, 10.0),
                                 (0.1, 0.9, 1.0)])
def test_kkt_invariants_linear(method, wts):
    sysp, w, B, p = _setup(seed=1, n=12, w=wts)
    _check_kkt(sysp, w, default_accuracy(), B, p, method)


@pytest.mark.parametrize("method", ["sweep", "bisect"])
def test_kkt_invariants_log_model(method):
    sysp, w, B, p = _setup(seed=2, n=9, w=(0.5, 0.5, 20.0))
    _check_kkt(sysp, w, log_fit(), B, p, method)


def test_makespan_monotone_decreasing_in_lambda():
    """T_n(lambda) must be nonincreasing — the premise of the inversion."""
    sysp, w, B, p = _setup(seed=3, n=8)
    tt = _tt(sysp, B, p)
    acc = default_accuracy()
    lams = jnp.logspace(-8, 8, 120)
    mk = jnp.stack([_makespan_of_lambda(sysp, w, acc,
                                        jnp.full((sysp.n,), lam), tt)
                    for lam in lams])            # (120, N)
    diffs = np.diff(np.asarray(mk), axis=0)
    assert (diffs <= 1e-9 * np.abs(np.asarray(mk[:-1]))).all()


def test_closed_form_lambda_matches_bisection():
    """lambda_of_T_linear (the sweep's exact inversion) vs `_lambda_of_T`."""
    sysp, w, B, p = _setup(seed=4, n=16)
    acc = default_accuracy()
    tt = _tt(sysp, B, p)
    _, q = _coeffs(sysp, w)
    lam_hi = float(_sp1_bounds(sysp, w, q, tt)[0])
    k3 = 2.0 * w.w1 * sysp.global_rounds * sysp.kappa
    for T in [float(jnp.max(tt)) * 1.7, 0.1, 0.5, 3.0]:
        lam_bis = _lambda_of_T(sysp, w, acc, jnp.asarray(T), tt, lam_hi)
        lam_cf = lambda_of_T_linear(jnp.asarray(T), q, tt, k3,
                                    w.rho * acc.slope, sysp.f_min, sysp.f_max,
                                    sysp.s_lo, sysp.s_hi, lam_hi)
        np.testing.assert_allclose(np.asarray(lam_cf), np.asarray(lam_bis),
                                   rtol=1e-6, atol=1e-9 * lam_hi)


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
@pytest.mark.parametrize("method", ["sweep", "bisect"])
def test_pure_latency_weighting_is_finite(dtype, method):
    """w1 = 0 makes k3 = 2 w1 Rg kappa exactly 0; the division guards must
    not underflow to 0 in f32 (cbrt(0/0) = NaN used to poison the sweep's
    candidate argmin and nan the whole solve)."""
    sysp, w, B, p = _setup(seed=13, n=8, w=(0.0, 1.0, 1.0))
    sysp = jax.tree_util.tree_map(lambda x: jnp.asarray(x, dtype), sysp)
    B, p = jnp.asarray(B, dtype), jnp.asarray(p, dtype)
    f, s, s_hat, T = solve_sp1(sysp, w, default_accuracy(), B, p,
                               method=method)
    assert np.isfinite(np.asarray(f)).all()
    assert np.isfinite(np.asarray(s_hat)).all()
    assert np.isfinite(float(T))


# ---------------------------------------------------------------------------
# hypothesis property tests (skip when hypothesis is absent)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(w1=st.floats(0.05, 0.95), rho=st.floats(0.0, 50.0),
       seed=st.integers(0, 31))
def test_kkt_property_sweep(w1, rho, seed):
    sysp, w, B, p = _setup(seed=seed, n=7, w=(w1, 1.0 - w1, rho))
    _check_kkt(sysp, w, default_accuracy(), B, p, "sweep")


@settings(max_examples=10, deadline=None)
@given(w1=st.floats(0.05, 0.95), rho=st.floats(0.5, 40.0),
       seed=st.integers(0, 15))
def test_kkt_property_parity(w1, rho, seed):
    """Sweep and bisection oracles agree on the objective, any weights."""
    sysp, w, B, p = _setup(seed=seed, n=6, w=(w1, 1.0 - w1, rho))
    acc = default_accuracy()
    objs = {m: _continuous_objective(sysp, w, acc, B, p, m)
            for m in ("sweep", "bisect")}
    assert objs["sweep"] == pytest.approx(objs["bisect"], rel=1e-5)


# ---------------------------------------------------------------------------
# sweep-vs-oracle parity across regimes, models, dtypes (acceptance bound)
# ---------------------------------------------------------------------------

def _cast_system(sysp, dtype):
    return jax.tree_util.tree_map(lambda x: jnp.asarray(x, dtype), sysp)


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
@pytest.mark.parametrize("wts", [(0.9, 0.1, 1.0),     # energy-heavy w1
                                 (0.1, 0.9, 1.0),     # latency-heavy w2
                                 (0.5, 0.5, 50.0)])   # accuracy-heavy rho
@pytest.mark.parametrize("model", ["linear", "log"])
def test_sweep_parity_regimes(dtype, wts, model):
    sysp, w, B, p = _setup(seed=7, n=24, w=wts)
    sysp = _cast_system(sysp, dtype)
    B, p = jnp.asarray(B, dtype), jnp.asarray(p, dtype)
    acc = default_accuracy() if model == "linear" else log_fit()
    out = {m: _continuous_objective(sysp, w, acc, B, p, m)
           for m in ("sweep", "bisect")}
    rel = abs(out["sweep"] - out["bisect"]) / max(abs(out["bisect"]), 1e-30)
    assert rel <= 1e-5


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_sweep_parity_large(dtype):
    """Region-scale parity: the acceptance bound at N = 8192 devices."""
    n = 8192
    sysp, w, B, p = _setup(seed=11, n=n, w=(0.5, 0.5, 1.0),
                           bandwidth_total=20e6 * n / 50)
    sysp = _cast_system(sysp, dtype)
    B, p = jnp.asarray(B, dtype), jnp.asarray(p, dtype)
    acc = default_accuracy()
    out = {m: _continuous_objective(sysp, w, acc, B, p, m)
           for m in ("sweep", "bisect")}
    rel = abs(out["sweep"] - out["bisect"]) / max(abs(out["bisect"]), 1e-30)
    assert rel <= 1e-5


# ---------------------------------------------------------------------------
# the batched op itself: Pallas kernel vs ref oracle, padded tails
# ---------------------------------------------------------------------------

def _sweep_inputs(seed=5, n=1000, w=(0.5, 0.5, 1.0)):
    sysp, wts, B, p = _setup(seed=seed, n=n, w=w,
                             bandwidth_total=20e6 * n / 50)
    tt = _tt(sysp, B, p)
    _, q = _coeffs(sysp, wts)
    lam_hi = _sp1_bounds(sysp, wts, q, tt)[0]
    consts = jnp.zeros((N_CONSTS,), tt.dtype).at[:7].set(jnp.asarray(
        [2.0 * wts.w1 * sysp.global_rounds * sysp.kappa,
         wts.rho * default_accuracy().slope, sysp.f_min, sysp.f_max,
         sysp.s_lo, sysp.s_hi, float(lam_hi)], tt.dtype))
    T_grid = jnp.geomspace(float(jnp.max(tt)) * 1.01, 1e4, 24).astype(tt.dtype)
    return T_grid, q, tt, consts


@pytest.mark.parametrize("N,block", [(1000, 256), (5, 1024), (1500, 1024)])
def test_sp1_sweep_padded_tail_matches_ref(N, block):
    """The (q=0, tt=0) tail padding must contribute exactly zero."""
    T_grid, q, tt, consts = _sweep_inputs(n=N)
    s_pal = sp1_lambda_sum(T_grid, q, tt, consts, block_n=block,
                           interpret=True, dtype=jnp.float64)
    s_ref = sp1_lambda_sum_ref(T_grid, q, tt, consts)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=1e-12)


def test_sp1_sweep_ops_entry_matches_bisection_sum():
    """ops.sp1_lambda_sum (the production entry) vs a per-point bisection."""
    sysp, w, B, p = _setup(seed=6, n=64)
    acc = default_accuracy()
    T_grid, q, tt, consts = _sweep_inputs(seed=6, n=64)
    # _sweep_inputs used a wider-band system; rebuild tt/q for sysp instead
    tt = _tt(sysp, B, p)
    _, q = _coeffs(sysp, w)
    T_grid = jnp.geomspace(float(jnp.max(tt)) * 1.02, 1e4, 16)
    lam_hi = float(consts[6])
    s_op = ops.sp1_lambda_sum(T_grid, q, tt, consts)
    s_bis = jnp.stack([jnp.sum(_lambda_of_T(sysp, w, acc, T_grid[i], tt,
                                            lam_hi))
                       for i in range(T_grid.shape[0])])
    np.testing.assert_allclose(np.asarray(s_op), np.asarray(s_bis),
                               rtol=1e-5, atol=1e-7 * lam_hi)
