"""Algorithm 2: full BCD resource-allocation loop (paper §V-D).

The outer loop is a single jitted `lax.while_loop` with an on-device
convergence check: no Python-level `float()` / `.tolist()` syncs inside the
iteration. Per-iteration metrics accumulate into a fixed-size traced ledger
(one row per iteration) that is materialized into `BCDResult.history`
exactly once, after the loop finishes. Because the whole solve is one traced
computation, it `vmap`s across base-station cells.

This module now holds the jitted *impls* plus the shared result types; the
drivers live behind the unified entry point `repro.solve(Problem, SolverSpec)`
(`repro.api.solve`). The historical signatures `allocate` /
`allocate_fixed_deadline` / `allocate_fleet` remain as thin deprecation
shims over it — same results, bit-identical, one `DeprecationWarning` per
process. Objective weights are a traced `(3,)` (per cell) operand of
`_allocate_impl`, never part of the jit-cache key.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import energy as en
from .accuracy import AccuracyModel
from .energy import rate as _rate
from .sp1 import _SP1_IMPLS, _solve_sp1_fixed_impl
from .sp2 import _golden_argmin, _sp2_direct_impl, _sp2_jong_core, r_min
from .types import Allocation, SystemParams, Weights

Array = jnp.ndarray

# ledger column order (one row per BCD iteration). sp2_iters: Jong outer
# iterations for sp2_method="jong"; for "direct" it carries the measured
# dE/dB evaluation count of the carried-bracket dual search (compare
# against sp2.direct_eval_counts for the non-carried reference).
_LEDGER_COLS = ("objective", "energy", "time", "accuracy",
                "sp2_iters", "sp2_residual", "rel_step")
_FIXED_COLS = ("energy", "time", "accuracy", "sp2_evals", "rel_step")

# solver-effort counter order (SolveCounters.data last axis): BCD outer
# iterations, SP1 dual (Sigma-lambda(T) candidate) evaluations, SP2 dual
# evaluations (dE/dB evals for "direct" / Jong outer iterations for
# "jong"), and the final relative-step convergence residual.
_COUNTER_COLS = ("bcd_iters", "sp1_evals", "sp2_evals", "residual")


@dataclasses.dataclass
class SolveCounters:
    """Device-resident solver-effort counters for one solve.

    `data` is a `(len(columns),)` array for a single-cell solve, `(C,
    len(columns))` for fleet/region results — computed inside the jitted
    solve from the iteration ledger, so constructing this object adds no
    host sync and no compiled shapes. Reading `as_dict()` (or numpy-ing
    `data`) is the one deliberate device->host transfer; the serving hot
    path never takes it. `repro.obs` feeds these into per-request events.
    """
    data: Array
    columns: tuple = _COUNTER_COLS

    def col(self, name: str) -> Array:
        """One counter by name, still on device; leading cell axis kept."""
        return self.data[..., self.columns.index(name)]

    @property
    def bcd_iters(self) -> Array:
        return self.col("bcd_iters")

    @property
    def sp1_evals(self) -> Array:
        return self.col("sp1_evals")

    @property
    def sp2_evals(self) -> Array:
        return self.col("sp2_evals")

    @property
    def residual(self) -> Array:
        return self.col("residual")

    def as_dict(self) -> dict:
        """{name: float | (C,) ndarray} — one blocking transfer."""
        vals = np.asarray(self.data)
        out = {}
        for i, c in enumerate(self.columns):
            v = vals[..., i]
            out[c] = float(v) if v.ndim == 0 else v
        return out


@dataclasses.dataclass
class BCDResult:
    allocation: Allocation
    objective: float
    history: List[dict]
    iters: int
    converged: bool
    counters: Optional[SolveCounters] = None


@dataclasses.dataclass
class FleetResult:
    """Batched BCD solve across C independent base-station cells.

    All leaves carry a leading cell axis: allocation arrays are (C, N),
    per-cell scalars are (C,). `history` is the raw iteration ledger
    (C, max_iters, len(columns)); rows past a cell's `iters` are NaN.
    """
    allocation: Allocation   # (C, N) leaves
    objective: Array         # (C,)
    iters: Array             # (C,) int32
    converged: Array         # (C,) bool
    history: Array           # (C, max_iters, len(columns))
    columns: tuple = _LEDGER_COLS
    counters: Optional[SolveCounters] = None   # (C, 4) device counters


def initial_allocation(sys: SystemParams, key: Optional[jax.Array] = None,
                       bandwidth_frac: float = 1.0, xp=jnp) -> Allocation:
    """Feasible start: p = pmax, B = B/N (paper init; Fig. 9 uses B/(2N)).

    On a padded system (`sys.active` set) the bandwidth split divides by the
    ACTIVE device count and pad lanes start at B = 0, so the active prefix
    of a padded solve starts (and therefore iterates) bit-identically to the
    unpadded one.

    `xp` picks the array namespace (default jnp). The region planning
    layer passes numpy so the init is assembled host-side without touching
    the device stream — full/where/one scalar divide are IEEE-exact
    elementwise ops, so both namespaces are bit-identical."""
    n = sys.n
    if sys.active is None:
        bw = xp.full((n,), sys.bandwidth_total / n * bandwidth_frac)
    else:
        n_eff = xp.sum(xp.asarray(sys.active).astype(
            xp.asarray(sys.gain).dtype))
        # n_eff == 0 (all-inactive filler cell) divides to inf, masked to
        # 0 by the where below — identical in both namespaces, but numpy
        # warns where jnp is silent
        with np.errstate(divide="ignore"):
            share = sys.bandwidth_total / n_eff * bandwidth_frac
        bw = xp.where(sys.active, share,
                      xp.zeros((), xp.asarray(share).dtype))
    return Allocation(
        bandwidth=bw,
        power=xp.full((n,), sys.p_max),
        freq=xp.full((n,), sys.f_max),
        resolution=xp.full((n,), sys.s_lo),
    )


def _init_carry_state(sys: SystemParams, alloc: Allocation):
    """(B, p, f, s, s_hat, T) arrays for the while_loop carry."""
    dtype = jnp.asarray(alloc.bandwidth).dtype
    s_hat = alloc.s_relaxed if alloc.s_relaxed is not None else alloc.resolution
    T = alloc.T if alloc.T is not None else jnp.zeros((), dtype)
    return (alloc.bandwidth, alloc.power, alloc.freq, alloc.resolution,
            jnp.asarray(s_hat), jnp.asarray(T, dtype))


def _bcd_while(state0, max_iters: int, ncols: int, tol, step, mask=None):
    """Shared BCD driver: fixed-size NaN ledger, on-device convergence on the
    relative (B, p, f, s) step, one `lax.while_loop`. `step(state)` performs
    one block-coordinate update and returns (new_state, metric scalars); the
    driver appends the rel-step column and writes the ledger row.

    The tolerance is floored at 64 ulps of the carry dtype: in f32 the
    iterate movement plateaus around ~10 eps (solver bracketing noise, not
    progress), so the old raw tol=1e-6 sat exactly at the noise floor and
    fleet cells reported "not converged" forever — the 12/64 fleet
    convergence-rate bug. Movement below the floor is numerical noise.

    `mask` (an (N,) bool, `sys.active`) zeroes padded-out devices in the
    rel-step norms: their (constant) iterates would otherwise inflate the
    denominator and desync the convergence trajectory from the unpadded
    solve. Returns (*state, iters, converged, ledger)."""
    dtype = state0[0].dtype
    m4 = None if mask is None else jnp.concatenate([mask] * 4)

    def flat(state):
        v = jnp.concatenate([state[0], state[1], state[2], state[3]])
        return v if m4 is None else jnp.where(m4, v, jnp.zeros((), dtype))

    ledger0 = jnp.full((max_iters, ncols), jnp.nan, dtype)
    if max_iters == 0:   # nothing to iterate: return the start point untouched
        return (*state0, jnp.zeros((), jnp.int32), jnp.zeros((), bool), ledger0)
    tol = jnp.maximum(jnp.asarray(tol, dtype), 64.0 * jnp.finfo(dtype).eps)
    prev0 = flat(state0)

    def cond(c):
        k, _, _, conv, _ = c
        return (k < max_iters) & (~conv)

    def body(c):
        k, state, prev, _, ledger = c
        state, metrics = step(state)
        cur = flat(state)
        rel = jnp.linalg.norm(cur - prev) \
            / jnp.maximum(jnp.linalg.norm(prev), 1e-12)
        row = jnp.stack([*(m.astype(dtype) for m in metrics),
                         rel.astype(dtype)])
        ledger = ledger.at[k].set(row)
        return k + 1, state, cur, rel <= tol, ledger

    k, state, _, conv, ledger = lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), state0, prev0,
                     jnp.zeros((), bool), ledger0))
    return (*state, k, conv, ledger)


def _pack_counters(iters, ledger, max_iters: int, sp2_col: int,
                   rel_col: int, sp1_per_iter: int):
    """(len(_COUNTER_COLS),) device array of solver-effort counters,
    reduced from the iteration ledger inside the traced solve — pure
    device ops on values the ledger already carries, so surfacing the
    counters adds no host syncs and no new compiled shapes.

    `sp1_per_iter` is the statically-known SP1 dual-eval count per BCD
    iteration (`sp1.dual_evals_per_iter`; 0 for the closed-form fixed-T
    subproblem) — the sweep/bisect grids have fixed trip counts, so the
    total is exactly `iters * sp1_per_iter`. NaN ledger rows (beyond
    `iters`) drop out of the nansum; residual is the rel-step of the last
    executed iteration (NaN when nothing ran)."""
    dtype = ledger.dtype
    it = iters.astype(dtype)
    sp1 = it * sp1_per_iter
    if max_iters > 0:
        sp2 = jnp.nansum(ledger[:, sp2_col]).astype(dtype)
        last = jnp.clip(iters.astype(jnp.int32) - 1, 0, max_iters - 1)
        residual = jnp.where(iters > 0, ledger[last, rel_col], jnp.nan)
    else:
        sp2 = jnp.zeros((), dtype)
        residual = jnp.full((), jnp.nan, dtype)
    return jnp.stack([it, sp1, sp2, residual.astype(dtype)])


@partial(jax.jit, static_argnames=("acc", "max_iters", "sp1_method",
                                   "sp2_method", "sp2_iters"))
def _allocate_impl(sys: SystemParams, warr: Array, acc: AccuracyModel,
                   state0, max_iters: int, tol,
                   sp1_method: str, sp2_method: str, sp2_iters: int):
    """Device-resident Algorithm 2. Returns
    (B, p, f, s, s_hat, T, iters, converged, ledger, counters) — the
    trailing `counters` is the packed `_COUNTER_COLS` effort vector."""
    from .sp1 import dual_evals_per_iter

    dtype = state0[0].dtype
    warr_sp1 = jnp.stack([warr[0], jnp.maximum(warr[1], 1e-9), warr[2]])
    solve_sp1 = _SP1_IMPLS[sp1_method]

    def step(state):
        B, p, _, _, _, _ = state
        tt = sys.bits / jnp.maximum(_rate(sys, B, p), 1e-12)
        f, s, s_hat, T = solve_sp1(sys, warr_sp1, acc, tt)
        rmin = r_min(sys, f, s, T)
        if sp2_method == "direct":
            # sp2_iters ledger column = measured dE/dB eval count of the
            # carried-bracket dual search (vs `sp2.direct_eval_counts`)
            p_new, B_new, ev = _sp2_direct_impl(sys, rmin)
            sp2_it = ev.astype(dtype)
            sp2_res = jnp.zeros((), dtype)
        else:
            p_new, B_new, _, _, it2, res2 = _sp2_jong_core(
                sys, warr[0], rmin, p, B, max_iters=sp2_iters)
            sp2_it = it2.astype(dtype)
            sp2_res = res2.astype(dtype)
        w = Weights(warr[0], warr[1], warr[2])
        alloc = Allocation(bandwidth=B_new, power=p_new, freq=f, resolution=s,
                           s_relaxed=s_hat, T=T)
        metrics = (en.objective(sys, w, acc, alloc),
                   en.total_energy(sys, alloc),
                   en.total_time(sys, alloc),
                   en.total_accuracy(acc, alloc, sys.active),
                   sp2_it, sp2_res)
        return (B_new, p_new, f, s, s_hat, T), metrics

    out = _bcd_while(state0, max_iters, len(_LEDGER_COLS), tol, step,
                     mask=sys.active)
    counters = _pack_counters(out[6], out[8], max_iters,
                              _LEDGER_COLS.index("sp2_iters"),
                              _LEDGER_COLS.index("rel_step"),
                              dual_evals_per_iter(sp1_method, acc))
    return (*out, counters)


def _materialize_history(ledger: np.ndarray, iters: int,
                         cols: Sequence[str]) -> List[dict]:
    out = []
    for i in range(iters):
        row = dict(iter=i + 1)
        for c, v in zip(cols, ledger[i]):
            row[c] = int(v) if c in ("sp2_iters", "sp2_evals") else float(v)
        out.append(row)
    return out


def allocate(sys: SystemParams, w: Weights, acc: Optional[AccuracyModel] = None,
             max_iters: int = 20, tol: float = 1e-6,
             init: Optional[Allocation] = None,
             sp2_iters: int = 30, sp2_method: str = "direct",
             sp1_method: str = "sweep",
             keep_history: bool = True) -> BCDResult:
    """Deprecated shim: Algorithm 2 through `repro.solve`.

    Equivalent to ``solve(Problem(system=sys, weights=w, acc=acc, init=init),
    SolverSpec(max_iters=..., tol=..., ...))`` — bit-identical results, one
    `DeprecationWarning` per process.
    """
    from repro.api import Problem, SolverSpec, solve
    from repro.api.solve import _warn_deprecated

    _warn_deprecated("allocate", "Problem(system, weights), SolverSpec(...)")
    return solve(Problem(system=sys, weights=w, acc=acc, init=init),
                 SolverSpec(max_iters=max_iters, tol=tol,
                            sp1_method=sp1_method, sp2_method=sp2_method,
                            sp2_iters=sp2_iters, keep_history=keep_history))


def _optimal_split(sys: SystemParams, s: Array, bandwidth: Array,
                   T_round: Array, iters: int = 48) -> Array:
    """Per-device golden-section over the transmission-time share tt of the
    round deadline:  E(tt) = kappa cyc^3 / (T-tt)^2 + E_trans_min(tt | B),
    both terms convex. Returns tt* clipped to the feasible window."""
    cyc = sys.local_iters * sys.zeta * s ** 2 * sys.cycles * sys.samples

    def energy(tt):
        f = jnp.clip(cyc / jnp.maximum(T_round - tt, 1e-9), sys.f_min, sys.f_max)
        e_cmp = sys.kappa * cyc * f ** 2
        r_req = sys.bits / jnp.maximum(tt, 1e-9)
        theta = jnp.exp2(r_req / jnp.maximum(bandwidth, 1e-9)) - 1.0
        p = jnp.clip(theta * sys.noise_psd * bandwidth / sys.gain,
                     sys.p_min, sys.p_max)
        return e_cmp + p * tt

    tt_min = sys.bits / jnp.maximum(
        bandwidth * jnp.log2(1.0 + sys.gain * sys.p_max
                             / (sys.noise_psd * jnp.maximum(bandwidth, 1e-9))),
        1e-12)
    a0 = jnp.minimum(tt_min, 0.95 * T_round)
    b0 = jnp.broadcast_to(jnp.asarray(0.95 * T_round, a0.dtype), a0.shape)
    tt = _golden_argmin(energy, a0, b0, iters=iters)
    return jnp.clip(tt, tt_min, 0.95 * T_round)


@partial(jax.jit, static_argnames=("acc", "max_iters", "sp2_method",
                                   "sp2_iters"))
def _allocate_fixed_impl(sys: SystemParams, warr: Array, acc: AccuracyModel,
                         T_round, state0, max_iters: int, tol,
                         sp2_method: str = "direct", sp2_iters: int = 30):
    """Device-resident deadline-constrained BCD (Figs. 8-9 variant).

    Takes the same SolverSpec-sourced sp2 options as `_allocate_impl`
    (`sp1_method` does not apply: the fixed-T subproblem has no T search to
    sweep or bisect, `_solve_sp1_fixed_impl` is closed-form)."""
    dtype = state0[0].dtype

    def step(state):
        B, p, _, _, s_hat, _ = state
        tt = sys.bits / jnp.maximum(_rate(sys, B, p), 1e-12)
        f, s = _solve_sp1_fixed_impl(sys, warr, acc, tt, T_round)
        # Break the BCD split deadlock: with a hard deadline, SP1 pins
        # t_cmp = T - t_trans(current p, B), so SP2's rate floor equals the
        # current rate and (p, B) can never move. Re-derive the floor from the
        # per-device OPTIMAL compute/transmit split (convex in t_trans:
        # E_cmp = kappa cyc^3/(T-tt)^2 rises, E_trans falls; golden section).
        tt_opt = _optimal_split(sys, s, B, T_round)
        rmin = sys.bits / tt_opt
        if sp2_method == "direct":
            p_new, B_new, ev = _sp2_direct_impl(sys, rmin)
            sp2_ev = ev.astype(dtype)
        else:
            p_new, B_new, _, _, it2, _ = _sp2_jong_core(
                sys, warr[0], rmin, p, B, max_iters=sp2_iters)
            sp2_ev = it2.astype(dtype)
        # recompute f against the achieved transmission time
        tt_new = sys.bits / jnp.maximum(_rate(sys, B_new, p_new), 1e-12)
        cyc = sys.local_iters * sys.zeta * s ** 2 * sys.cycles * sys.samples
        f = jnp.clip(cyc / jnp.maximum(T_round - tt_new, 1e-9),
                     sys.f_min, sys.f_max)
        alloc = Allocation(bandwidth=B_new, power=p_new, freq=f, resolution=s,
                           T=jnp.asarray(T_round, dtype))
        metrics = (en.total_energy(sys, alloc),
                   en.total_time(sys, alloc),
                   en.total_accuracy(acc, alloc, sys.active),
                   sp2_ev)
        return (B_new, p_new, f, s, s_hat,
                jnp.asarray(T_round, dtype)), metrics

    # sp1_per_iter = 0: _solve_sp1_fixed_impl enumerates the discrete
    # resolution menu in closed form — no dual search to count
    out = _bcd_while(state0, max_iters, len(_FIXED_COLS), tol, step,
                     mask=sys.active)
    counters = _pack_counters(out[6], out[8], max_iters,
                              _FIXED_COLS.index("sp2_evals"),
                              _FIXED_COLS.index("rel_step"), 0)
    return (*out, counters)


def allocate_fixed_deadline(sys: SystemParams, w: Weights, T_total: float,
                            acc: Optional[AccuracyModel] = None,
                            max_iters: int = 20, tol: float = 1e-6,
                            init: Optional[Allocation] = None,
                            bandwidth_frac: float = 1.0,
                            sp2_iters: int = 30, sp2_method: str = "direct",
                            keep_history: bool = True) -> BCDResult:
    """Deprecated shim: the deadline-constrained variant through `repro.solve`.

    Equivalent to ``solve(Problem(system=sys, weights=w, deadline=T_total,
    ...), SolverSpec(...))``. Now wired through the same SolverSpec path as
    every other entry point, so it accepts the warm-start ``init`` and the
    sp2 engine options the free-deadline solver grew (the fixed-T
    subproblem has no T search, so ``sp1_method`` does not apply).
    """
    from repro.api import Problem, SolverSpec, solve
    from repro.api.solve import _warn_deprecated

    _warn_deprecated("allocate_fixed_deadline",
                     "Problem(system, weights, deadline=T_total), "
                     "SolverSpec(...)")
    return solve(Problem(system=sys, weights=w, acc=acc, init=init,
                         deadline=T_total, bandwidth_frac=bandwidth_frac),
                 SolverSpec(max_iters=max_iters, tol=tol,
                            sp2_method=sp2_method, sp2_iters=sp2_iters,
                            keep_history=keep_history))


# ----------------------------------------------------------------------------
# Fleet-scale batched allocation (beyond paper): one vmap'd BCD solve across
# C independent base-station cells — the ROADMAP path to millions of clients.
# ----------------------------------------------------------------------------

def stack_systems(systems: Sequence[SystemParams], xp=jnp) -> SystemParams:
    """Stack per-cell SystemParams into one batched pytree: per-device arrays
    become (C, N), per-cell scalars become (C,). Cells may differ in any
    numeric scalar (bandwidth_total, p_max, ... are traced leaves), so mixed
    cell classes batch through one vmap'd solve; only the static aux data —
    the discrete resolution menu — must match across cells.

    Pad-safe: if any cell carries an `active` mask (`pad_system`), cells
    without one get an all-True mask so the pytree structures agree — a
    bucketed batch may mix padded and exactly-sized cells."""
    from .types import _SYS_STATIC

    aux = tuple(getattr(systems[0], k) for k in _SYS_STATIC)
    for s_ in systems[1:]:
        if tuple(getattr(s_, k) for k in _SYS_STATIC) != aux:
            raise ValueError(
                "stack_systems: cells differ in static config (resolutions)")
    if any(s_.active is not None for s_ in systems):
        systems = [s_ if s_.active is not None else
                   s_.replace(active=xp.ones(xp.asarray(s_.gain).shape,
                                             bool))
                   for s_ in systems]
    return jax.tree_util.tree_map(lambda *xs: xp.stack(xs), *systems)


def _fleet_cell_fn(acc, max_iters, tol, sp1_method, sp2_method,
                   sp2_iters, with_init: bool):
    """Per-cell solver closure shared by the fleet vmap and the region
    shard_map (`api.solve._solve_fleet` / `_solve_region`). The weights
    array is a *vmapped operand* — each cell carries its own traced (3,)
    row of a (C, 3) stack, so per-cell/per-request weights share one
    compiled program."""
    def warm(sysc, warr_c, alloc0):
        state0 = _init_carry_state(sysc, alloc0)
        return _allocate_impl(sysc, warr_c, acc, state0, max_iters, tol,
                              sp1_method, sp2_method, sp2_iters)

    if with_init:
        return warm
    return lambda sysc, warr_c: warm(sysc, warr_c,
                                     initial_allocation(sysc))


def _fleet_fixed_cell_fn(acc, max_iters, tol, sp2_method, sp2_iters):
    """Per-cell deadline-constrained solver closure for the fleet vmap
    (`api.solve._solve_fixed_fleet`): the fixed-T sibling of
    `_fleet_cell_fn`. The per-round deadline rides as a vmapped per-cell
    scalar operand, so heterogeneous deadlines (or heterogeneous
    `global_rounds`) share one compiled program."""
    def fn(sysc, warr_c, T_round_c, alloc0):
        state0 = _init_carry_state(sysc, alloc0)
        return _allocate_fixed_impl(sysc, warr_c, acc, T_round_c, state0,
                                    max_iters, tol, sp2_method, sp2_iters)
    return fn


def _fleet_result(out, max_iters: int, dtype,
                  cols: Sequence[str] = _LEDGER_COLS) -> FleetResult:
    """Assemble a FleetResult from the stacked raw `_allocate_impl` (or
    `_allocate_fixed_impl`, with cols=_FIXED_COLS) outputs — all leaves
    carry a leading cell axis. Ledger column 0 is the per-iteration
    objective for both column sets ("objective" free / "energy" fixed)."""
    B, p, f, s, s_hat, T, iters, conv, ledger, counters = out
    if max_iters > 0:
        idx = jnp.clip(iters.astype(jnp.int32) - 1, 0, max_iters - 1)
        last = jnp.take_along_axis(ledger[..., 0], idx[:, None], axis=1)[:, 0]
        objective = jnp.where(iters > 0, last, jnp.nan)
    else:
        objective = jnp.full(iters.shape, jnp.nan, dtype)
    allocation = Allocation(bandwidth=B, power=p, freq=f, resolution=s,
                            s_relaxed=s_hat if cols is _LEDGER_COLS else None,
                            T=T)
    return FleetResult(allocation=allocation, objective=objective,
                       iters=iters, converged=conv, history=ledger,
                       columns=tuple(cols),
                       counters=SolveCounters(data=counters))


def allocate_fleet(sys_batch: SystemParams, w: Weights,
                   acc: Optional[AccuracyModel] = None,
                   max_iters: int = 20, tol: float = 1e-6,
                   init: Optional[Allocation] = None,
                   sp2_iters: int = 30,
                   sp2_method: str = "direct",
                   sp1_method: str = "sweep") -> FleetResult:
    """Deprecated shim: batched Algorithm 2 through `repro.solve`.

    Equivalent to ``solve(Problem(system=sys_batch, weights=w, ...),
    SolverSpec(...))`` on a stacked (C, N) system (`stack_systems` /
    `make_fleet`). The new path also takes per-cell weights — pass a
    sequence of `Weights` (or a (C, 3) array) as `Problem.weights`.
    To shard the cell axis across a device mesh, set `Problem.mesh`.
    """
    from repro.api import Problem, SolverSpec, solve
    from repro.api.solve import _warn_deprecated

    _warn_deprecated("allocate_fleet",
                     "Problem(system=sys_batch, weights), SolverSpec(...)")
    return solve(Problem(system=sys_batch, weights=w, acc=acc, init=init),
                 SolverSpec(max_iters=max_iters, tol=tol,
                            sp1_method=sp1_method, sp2_method=sp2_method,
                            sp2_iters=sp2_iters))
