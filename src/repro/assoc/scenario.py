"""Geometry-consistent multi-cell scenarios for cross-cell association.

`make_fleet` draws C *independent* cells — fine for batched serving, but
cross-cell association needs one shared geometry: every device has a gain
to EVERY cell, correlated through its position. `make_multicell` builds
that stacked (C, N) system: devices uniform over the region, base stations
on a grid (`bs_grid`), row c = expected pathloss+shadowing gain of all N
devices to cell c, device attributes (cycles/samples/bits) shared across
rows, per-cell scalars broadcast (or overridden per cell).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (device_positions, make_system, pathloss_db,
                                shadowing_sigma)
from repro.core.types import SystemParams

Array = jnp.ndarray


def bs_grid(n_cells: int, area_m: float, dtype=jnp.float32) -> Array:
    """(C, 2) base-station positions on a centered square grid covering
    [-area/2, area/2]^2 (C=1 puts the single BS at the origin, matching
    the paper's single-cell layout)."""
    if n_cells < 1:
        raise ValueError("bs_grid: n_cells must be >= 1")
    g = int(np.ceil(np.sqrt(n_cells)))
    idx = np.arange(n_cells)
    xs = ((idx % g) + 0.5) / g * area_m - area_m / 2.0
    ys = ((idx // g) + 0.5) / g * area_m - area_m / 2.0
    return jnp.asarray(np.stack([xs, ys], axis=-1), dtype)


def cross_gains(positions: Array, bs_xy: Array,
                shadowing_db: float) -> Array:
    """(..., C, N) expected gains of devices at `positions` (..., N, 2) to
    base stations `bs_xy` (C, 2) — pathloss with the lognormal shadowing
    mean folded in, exactly `channel.expected_gain`'s model."""
    positions = jnp.asarray(positions)
    bs_xy = jnp.asarray(bs_xy, positions.dtype)
    d = jnp.linalg.norm(positions[..., None, :, :]
                        - bs_xy[:, None, :], axis=-1)       # (..., C, N)
    sigma = shadowing_sigma(shadowing_db)
    shadow_mean = jnp.exp(jnp.asarray(sigma, positions.dtype) ** 2 / 2.0)
    return 10.0 ** (-pathloss_db(d) / 10.0) * shadow_mean


def make_multicell(key: jax.Array, n_cells: int, n_devices: int,
                   area_m: float = 1000.0,
                   positions: Optional[Array] = None,
                   **overrides) -> SystemParams:
    """Stacked (C, N) system over one shared device geometry.

    Any `make_system` scalar override may also be a length-C sequence to
    make the cells heterogeneous (e.g. ``bandwidth_total=[10e6, 40e6]`` —
    the capacity pressure that makes association bite). Device attributes
    are drawn once and shared across rows.
    """
    per_cell = {}
    for k, v in list(overrides.items()):
        if isinstance(v, (list, tuple, np.ndarray)) and k != "resolutions" \
                and np.ndim(v) > 0:
            vals = [float(x) for x in np.asarray(v).ravel()]
            if len(vals) != n_cells:
                raise ValueError(
                    f"make_multicell: per-cell override {k!r} has "
                    f"{len(vals)} entries for {n_cells} cells")
            per_cell[k] = vals
            del overrides[k]
    kp, ka = jax.random.split(key)
    base = make_system(ka, n_devices=n_devices, area_m=area_m, **overrides)
    if positions is None:
        positions = device_positions(kp, n_devices, area_m)
    dtype = jnp.asarray(base.gain).dtype
    bs = bs_grid(n_cells, area_m, dtype)
    gain = cross_gains(jnp.asarray(positions, dtype), bs,
                       float(overrides.get("shadowing_db", 8.0)))

    def col(name):
        if name in per_cell:
            return jnp.asarray(per_cell[name], dtype)
        return jnp.full((n_cells,), getattr(base, name), dtype)

    rep = lambda x: jnp.broadcast_to(jnp.asarray(x), (n_cells, n_devices))
    return SystemParams(
        gain=gain, cycles=rep(base.cycles), samples=rep(base.samples),
        bits=rep(base.bits),
        bandwidth_total=col("bandwidth_total"), noise_psd=col("noise_psd"),
        p_min=col("p_min"), p_max=col("p_max"), f_min=col("f_min"),
        f_max=col("f_max"), kappa=col("kappa"),
        local_iters=col("local_iters"), global_rounds=col("global_rounds"),
        resolutions=base.resolutions, s_standard=col("s_standard"))
