"""Learned accuracy surrogate: fit A(s) from realized FL training curves.

The paper's accuracy term is a fixed linear fit through two Fig. 7
operating points. A deployment has something better: its OWN training
runs. This module fits a monotone concave surrogate a(s) to measured
(resolution, accuracy) pairs — e.g. the final eval accuracies of
`fl.server.run_federated` at each rendering resolution — and threads it
back into the allocator as a drop-in `AccuracyModel`.

Model class: piecewise-linear in x = log s through the fitted menu knots,
linearly extrapolated with the end-segment slopes. With knot values
nondecreasing and knot slopes nonincreasing (both enforced by
pool-adjacent-violators projections at fit time), the surrogate is
nondecreasing and concave in x; concavity in s itself follows from
A''(s) = -P'(x)/s^2 <= 0 for P piecewise linear with P' >= 0 — exactly
the regularity SP1's water-filling requires of A'. The dataclass is
frozen with tuple fields, so it hashes and keys the solvers' jit caches
like every other accuracy model (a refit means a new menu of floats and
hence a legitimate recompile).

The fitted model carries its `menu` (the solver-unit resolutions it was
measured at); `problem_with_surrogate` installs model AND menu on a
`Problem` so `round_resolution` / `map_resolution_to_dataset` snap onto
the fitted operating points instead of the Fig. 7 grid
(`core.accuracy.system_with_menu`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.accuracy import FIG7_RESOLUTIONS, system_with_menu

Array = jnp.ndarray

__all__ = ["SurrogateAccuracy", "fit_from_training", "fit_surrogate",
           "problem_with_surrogate"]


@dataclasses.dataclass(frozen=True)
class SurrogateAccuracy:
    """Monotone concave piecewise-log-linear accuracy model (module
    docstring). `knots` are log-resolutions (strictly increasing),
    `values` the fitted accuracies (nondecreasing, concave over knots),
    `menu` the resolutions fitted on (solver units)."""
    knots: Tuple[float, ...]
    values: Tuple[float, ...]
    menu: Tuple[float, ...]

    def __post_init__(self):
        if len(self.knots) != len(self.values) or len(self.knots) < 2:
            raise ValueError(
                f"SurrogateAccuracy: need >= 2 matching knots/values, got "
                f"{len(self.knots)}/{len(self.values)}")

    def _segment(self, x: Array):
        kx = jnp.asarray(self.knots, x.dtype)
        kv = jnp.asarray(self.values, x.dtype)
        i = jnp.clip(jnp.searchsorted(kx, x, side="right") - 1,
                     0, len(self.knots) - 2)
        slope = (kv[i + 1] - kv[i]) / (kx[i + 1] - kx[i])
        return kv[i] + slope * (x - kx[i]), slope

    def value(self, s: Array) -> Array:
        s = jnp.asarray(s)
        v, _ = self._segment(jnp.log(jnp.maximum(s, 1e-12)))
        return v

    def deriv(self, s: Array) -> Array:
        s = jnp.asarray(s)
        safe = jnp.maximum(s, 1e-12)
        _, slope = self._segment(jnp.log(safe))
        return slope / safe          # dA/ds = P'(log s) / s


def _pav_nonincreasing(y: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Weighted pool-adjacent-violators: the nonincreasing sequence
    closest to `y` in the `w`-weighted least-squares sense."""
    vals, wts, sizes = [], [], []
    for yi, wi in zip(y, w):
        vals.append(float(yi)); wts.append(float(wi)); sizes.append(1)
        while len(vals) > 1 and vals[-2] < vals[-1]:
            v2, w2, n2 = vals.pop(), wts.pop(), sizes.pop()
            v1, w1, n1 = vals.pop(), wts.pop(), sizes.pop()
            wt = w1 + w2
            vals.append((v1 * w1 + v2 * w2) / wt)
            wts.append(wt); sizes.append(n1 + n2)
    return np.concatenate([np.full(n, v) for v, n in zip(vals, sizes)])


def fit_surrogate(resolutions: Sequence[float],
                  accuracies: Sequence[float],
                  menu: Optional[Sequence[float]] = None
                  ) -> SurrogateAccuracy:
    """Fit the monotone concave surrogate to measured (s, a) pairs.

    Two projection passes in log-s space: isotonic regression makes the
    knot values nondecreasing (measurement noise routinely produces a
    dip), then a slope-space PAV (weighted by segment width) makes the
    segment slopes nonincreasing — concavity. Slopes are floored at 0 and
    the rebuilt curve is re-centered to the projected values' mean, so
    both shape constraints hold exactly while the level stays unbiased.
    `menu` overrides the stored operating points (defaults to the fitted
    resolutions themselves).
    """
    res = np.asarray(resolutions, float)
    acc = np.asarray(accuracies, float)
    if res.shape != acc.shape or res.ndim != 1 or res.size < 2:
        raise ValueError(
            f"fit_surrogate: need matching 1-D arrays of >= 2 points, got "
            f"{res.shape} vs {acc.shape}")
    order = np.argsort(res)
    res, acc = res[order], acc[order]
    if np.any(np.diff(res) <= 0):
        raise ValueError("fit_surrogate: duplicate resolutions")

    x = np.log(res)
    # monotone: nondecreasing values = -PAV_nonincreasing(-y)
    y = -_pav_nonincreasing(-acc, np.ones_like(acc))
    # concave: nonincreasing (and nonnegative) segment slopes
    dx = np.diff(x)
    m = np.maximum(_pav_nonincreasing(np.diff(y) / dx, dx), 0.0)
    v = np.concatenate([[0.0], np.cumsum(m * dx)])
    v += y.mean() - v.mean()

    menu = res if menu is None else np.asarray(menu, float)
    if menu.shape != res.shape:
        raise ValueError(
            f"fit_surrogate: menu must match the fitted points "
            f"({res.shape}), got {menu.shape}")
    return SurrogateAccuracy(knots=tuple(float(k) for k in x),
                             values=tuple(float(a) for a in v),
                             menu=tuple(float(s) for s in menu))


def fit_from_training(key, menu: Sequence[float] = FIG7_RESOLUTIONS,
                      dataset_resolutions: Sequence[int] = (8, 16, 24, 32),
                      n_clients: int = 6, per_client: int = 96,
                      num_classes: int = 4, global_rounds: int = 3,
                      local_iters: int = 2, lr: float = 0.05,
                      eval_n: int = 192, split: str = "iid"
                      ) -> SurrogateAccuracy:
    """Fit the surrogate from realized `fl` training curves.

    One FedAvg run per dataset resolution (every client rendered at that
    resolution, evaluated at it too); the final round's eval accuracy
    becomes that operating point's measurement. `menu` gives the solver-
    unit resolution of each dataset grid point (rank for rank, the same
    correspondence `map_resolution_to_dataset` uses), so the fitted model
    plugs straight into the allocator via `problem_with_surrogate`.
    """
    import jax
    from ..fl.data import make_federated_dataset
    from ..fl.server import run_federated

    if len(menu) != len(dataset_resolutions):
        raise ValueError(
            f"fit_from_training: menu ({len(menu)}) and "
            f"dataset_resolutions ({len(dataset_resolutions)}) must "
            f"correspond rank for rank")
    k_ds, k_run = jax.random.split(jax.random.PRNGKey(key)
                                   if isinstance(key, int) else key)
    ds = make_federated_dataset(
        k_ds, n_clients=n_clients, per_client=per_client,
        num_classes=num_classes,
        base_resolution=int(max(dataset_resolutions)), split=split)
    accs = []
    for i, r in enumerate(dataset_resolutions):
        run = run_federated(
            jax.random.fold_in(k_run, i), ds, [int(r)] * n_clients,
            global_rounds=global_rounds, local_iters=local_iters, lr=lr,
            eval_n=eval_n, eval_resolution=int(r))
        accs.append(run.round_accuracy[-1])
    return fit_surrogate(menu, accs, menu=menu)


def problem_with_surrogate(problem, acc: SurrogateAccuracy):
    """Install a fitted surrogate on a `Problem`: accuracy model AND its
    resolution menu (so the discrete snap targets the fitted operating
    points — satellite of the menu round-trip fix)."""
    return dataclasses.replace(
        problem, acc=acc, system=system_with_menu(problem.system, acc))
