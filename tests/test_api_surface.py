"""Public-API surface snapshot: `repro.__all__` is pinned, the unified
entry point is importable from the top level, and every legacy shim fires
its DeprecationWarning exactly once per process.

Signature drift (adding/removing/renaming public names) must break THIS
test, not downstream users.
"""
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import pytest

import repro
from repro import Problem, SolverSpec, Weights, make_fleet, make_system
from repro.api.solve import _reset_deprecation_registry
from repro.dynamics import RoundsConfig

# the pinned surface — update deliberately, with a migration note
EXPECTED_ALL = {
    # unified API
    "Problem", "SolverSpec", "TolFloorWarning", "WeightsLike",
    "rel_step_floor", "solve", "weights_leaf",
    # core types + builders
    "AccuracyModel", "Allocation", "BCDResult", "FleetResult",
    "SystemParams", "Weights", "default_accuracy", "make_fleet",
    "make_system", "stack_systems",
    # dynamics / region
    "RoundsConfig", "RoundsResult", "AllocationRequest", "CellResponse",
    "RegionAllocator", "RegionResult", "region_mesh",
    # cross-cell association + mobility churn (PR 7)
    "AssocConfig", "AssocResult", "solve_assoc", "make_multicell",
    "MobilityConfig", "MobilityTrace", "simulate_mobility",
    "replay_mobility",
    # region serving pipeline (admission policies + async futures)
    "RegionPipeline", "PendingResponse", "StageClocks",
    "CloseOnFull", "MaxWait", "DeadlineSlack",
    # legacy shims (deprecated)
    "allocate", "allocate_fixed_deadline", "allocate_fleet",
    "allocate_region", "run_rounds", "run_rounds_fleet",
    "run_rounds_region",
}


def test_top_level_all_snapshot():
    assert set(repro.__all__) == EXPECTED_ALL
    for name in repro.__all__:
        assert getattr(repro, name, None) is not None, name


def test_core_all_still_exports_legacy_names():
    from repro import core
    for name in ("allocate", "allocate_fixed_deadline", "allocate_fleet",
                 "stack_systems", "Weights", "SystemParams"):
        assert name in core.__all__


@pytest.mark.parametrize("call", [
    "allocate", "allocate_fleet", "allocate_fixed_deadline", "run_rounds",
])
def test_each_shim_warns_exactly_once(call):
    key = jax.random.PRNGKey(0)
    sysp = make_system(key, n_devices=4)
    fleet = make_fleet(key, n_cells=2, n_devices=4)
    w = Weights(0.5, 0.5, 1.0)

    def invoke():
        if call == "allocate":
            repro.allocate(sysp, w, max_iters=0)
        elif call == "allocate_fleet":
            repro.allocate_fleet(fleet, w, max_iters=0)
        elif call == "allocate_fixed_deadline":
            repro.allocate_fixed_deadline(sysp, w, 100.0, max_iters=0)
        else:
            repro.run_rounds(key, sysp, w, RoundsConfig(rounds=1),
                             init=repro.solve(
                                 Problem(system=sysp, weights=w),
                                 SolverSpec(max_iters=2)).allocation)

    _reset_deprecation_registry()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        invoke()
        invoke()
    dep = [r for r in rec if issubclass(r.category, DeprecationWarning)
           and f"{call}()" in str(r.message)]
    assert len(dep) == 1, f"{call}: {len(dep)} warnings"
    assert "repro.solve" in str(dep[0].message)


def test_solve_itself_never_warns_deprecation():
    sysp = make_system(jax.random.PRNGKey(1), n_devices=4)
    _reset_deprecation_registry()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        repro.solve(Problem(system=sysp, weights=Weights(0.5, 0.5, 1.0)),
                    SolverSpec(max_iters=2, tol=1e-4))
    assert not [r for r in rec if issubclass(r.category, DeprecationWarning)]
