"""Mobility traces: moving devices, per-cell gains, handover churn.

The region serving stack so far re-requests cells under iid Poisson
arrivals; real load comes from *movement* — devices walk, their pathloss
to every base station drifts, their strongest cell changes, and each
handover invalidates the warm-start cache of BOTH cells involved. This
module generates that load:

  * position models (one jitted `lax.scan` each, seeded and
    bit-deterministic per key/dtype):
      - `"rwp"` — random waypoint: pick a uniform waypoint, walk to it at
        a uniform speed, repeat;
      - `"gauss_markov"` — AR(1) velocity (memory `alpha`), walls
        reflecting;
  * gain mapping: positions -> distance to every `bs_grid` station ->
    pathloss (128.1 + 37.6 log10 d_km) with AR(1) lognormal shadowing
    (`drift_rho`, the Gudmundson model `channel.drift_shadowing`);
  * event streams: per-step serving cell (argmax gain) and handover flags.

`replay_mobility` drives a `RegionAllocator` (or anything with the same
submit/solve/invalidate surface) with the trace: handovers flow in as
warm-cache invalidations (`service.invalidate`), every non-empty cell
re-requests with its members' realized gains, and the measured hit rate /
re-solve cost under movement comes back as a summary dict.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import drift_shadowing, pathloss_db, shadowing_sigma
from repro.core.types import SystemParams

Array = jnp.ndarray

_MODELS = ("rwp", "gauss_markov")


@dataclasses.dataclass(frozen=True)
class MobilityConfig:
    """Static (hashable) knobs of a mobility trace — the jit key of the
    trace scan, like `RoundsConfig` for the rounds engine.

    model : "rwp" (random waypoint) or "gauss_markov" (AR(1) velocity).
    steps / dt : trace length R and seconds per step.
    area_m : side of the centered square region (devices stay inside).
    v_min, v_max : waypoint leg speeds (rwp), m/s.
    alpha / v_sigma : Gauss-Markov velocity memory and asymptotic per-axis
        speed std (m/s).
    shadowing_db : lognormal shadowing std in dB (0 = pure pathloss).
    drift_rho : per-step AR(1) correlation of the shadowing state.
    """
    model: str = "rwp"
    steps: int = 50
    dt: float = 1.0
    area_m: float = 1000.0
    v_min: float = 0.5
    v_max: float = 2.0
    alpha: float = 0.85
    v_sigma: float = 1.5
    shadowing_db: float = 8.0
    drift_rho: float = 0.9

    def __post_init__(self):
        if self.model not in _MODELS:
            raise ValueError(f"MobilityConfig: model must be one of "
                             f"{_MODELS}, got {self.model!r}")
        if self.steps < 1:
            raise ValueError("MobilityConfig: steps must be >= 1")
        if not (0.0 < self.v_min <= self.v_max):
            raise ValueError("MobilityConfig: need 0 < v_min <= v_max")
        if not (0.0 <= self.alpha <= 1.0 and 0.0 <= self.drift_rho <= 1.0):
            raise ValueError("MobilityConfig: alpha/drift_rho in [0, 1]")
        if self.dt <= 0 or self.area_m <= 0 or self.v_sigma < 0 \
                or self.shadowing_db < 0:
            raise ValueError("MobilityConfig: dt/area_m/v_sigma/"
                             "shadowing_db out of range")


@dataclasses.dataclass
class MobilityTrace:
    """One realized trace. Rows are post-step snapshots r = 0..R-1."""
    positions: Array   # (R, N, 2) meters, centered region
    gains: Array       # (R, C, N) realized linear gains to every cell
    serving: Array     # (R, N) int32 argmax-gain serving cell
    handover: Array    # (R, N) bool, serving changed vs previous row
    bs_xy: Array       # (C, 2) base-station positions

    @property
    def steps(self) -> int:
        return int(self.positions.shape[0])

    @property
    def n_cells(self) -> int:
        return int(self.gains.shape[1])


# ------------------------------------------------------------ position scans

def _rwp_positions(key: jax.Array, n: int, cfg: MobilityConfig,
                   dtype) -> Array:
    half = cfg.area_m / 2.0
    k0, k1, k2, ks = jax.random.split(key, 4)
    u = lambda k, shape: jax.random.uniform(k, shape, dtype)
    pos0 = (u(k0, (n, 2)) - 0.5) * cfg.area_m
    wp0 = (u(k1, (n, 2)) - 0.5) * cfg.area_m
    v0 = cfg.v_min + (cfg.v_max - cfg.v_min) * u(k2, (n,))
    tiny = jnp.asarray(1e-12, dtype)

    def step(carry, kr):
        pos, wp, v = carry
        kw, kv = jax.random.split(kr)
        delta = wp - pos
        dist = jnp.linalg.norm(delta, axis=-1)
        leg = v * jnp.asarray(cfg.dt, dtype)
        frac = jnp.minimum(leg, dist) / jnp.maximum(dist, tiny)
        pos = pos + delta * frac[:, None]
        arrive = dist <= leg
        wp = jnp.where(arrive[:, None], (jax.random.uniform(
            kw, (n, 2), dtype) - 0.5) * cfg.area_m, wp)
        v = jnp.where(arrive, cfg.v_min + (cfg.v_max - cfg.v_min)
                      * jax.random.uniform(kv, (n,), dtype), v)
        pos = jnp.clip(pos, -half, half)
        return (pos, wp, v), pos

    _, trace = jax.lax.scan(step, (pos0, wp0, v0),
                            jax.random.split(ks, cfg.steps))
    return trace


def _gm_positions(key: jax.Array, n: int, cfg: MobilityConfig,
                  dtype) -> Array:
    half = jnp.asarray(cfg.area_m / 2.0, dtype)
    k0, kv, ks = jax.random.split(key, 3)
    pos0 = (jax.random.uniform(k0, (n, 2), dtype) - 0.5) * cfg.area_m
    v0 = cfg.v_sigma * jax.random.normal(kv, (n, 2), dtype)
    a = jnp.asarray(cfg.alpha, dtype)
    sig = jnp.asarray(cfg.v_sigma * np.sqrt(max(1.0 - cfg.alpha ** 2, 0.0)),
                      dtype)

    def step(carry, kr):
        pos, v = carry
        v = a * v + sig * jax.random.normal(kr, (n, 2), dtype)
        nxt = pos + v * jnp.asarray(cfg.dt, dtype)
        hit = (nxt > half) | (nxt < -half)
        nxt = jnp.where(nxt > half, 2.0 * half - nxt, nxt)
        nxt = jnp.where(nxt < -half, -2.0 * half - nxt, nxt)
        nxt = jnp.clip(nxt, -half, half)   # extreme overshoot guard
        v = jnp.where(hit, -v, v)          # reflect the wall component
        return (nxt, v), nxt

    _, trace = jax.lax.scan(step, (pos0, v0),
                            jax.random.split(ks, cfg.steps))
    return trace


# ------------------------------------------------------------ gains / events

def _shadow_states(key: jax.Array, steps: int, shape, rho, dtype) -> Array:
    """(R, *shape) AR(1) standard-normal shadowing states (row 0 is the
    stationary draw; `drift_shadowing` keeps the law N(0, 1) per step)."""
    k0, ks = jax.random.split(key)
    x0 = jax.random.normal(k0, shape, dtype)

    def step(x, kr):
        x2 = drift_shadowing(kr, x, rho)
        return x2, x2

    _, xs = jax.lax.scan(step, x0, jax.random.split(ks, steps - 1))
    return jnp.concatenate([x0[None], xs], axis=0)


def trace_gains(key: jax.Array, positions: Array, bs_xy: Array,
                cfg: MobilityConfig) -> Array:
    """(R, C, N) realized gains: pathloss at each step's distances times
    AR(1)-correlated lognormal shadowing per (cell, device) link."""
    positions = jnp.asarray(positions)
    dtype = positions.dtype
    bs_xy = jnp.asarray(bs_xy, dtype)
    d = jnp.linalg.norm(positions[:, None, :, :]
                        - bs_xy[None, :, None, :], axis=-1)   # (R, C, N)
    base = 10.0 ** (-pathloss_db(d) / 10.0)
    if cfg.shadowing_db == 0.0:
        return base
    R, C, N = d.shape
    x = _shadow_states(key, R, (C, N), cfg.drift_rho, dtype)
    sigma = jnp.asarray(shadowing_sigma(cfg.shadowing_db), dtype)
    return base * jnp.exp(sigma * x)


@partial(jax.jit, static_argnames=("n", "cfg", "dtype"))
def _trace_impl(key, bs_xy, n: int, cfg: MobilityConfig, dtype: str):
    dt = jnp.dtype(dtype)
    kp, kg = jax.random.split(key)
    mover = _rwp_positions if cfg.model == "rwp" else _gm_positions
    pos = mover(kp, n, cfg, dt)
    gains = trace_gains(kg, pos, bs_xy, cfg)
    serving = jnp.argmax(gains, axis=1).astype(jnp.int32)     # (R, N)
    prev = jnp.concatenate([serving[:1], serving[:-1]], axis=0)
    handover = serving != prev                                # row 0 False
    return pos, gains, serving, handover


def simulate_mobility(key: jax.Array, n_devices: int, n_cells: int = 1,
                      cfg: Optional[MobilityConfig] = None,
                      bs_xy: Optional[Array] = None,
                      dtype: str = "float32") -> MobilityTrace:
    """Generate one mobility trace: R steps of N devices across C cells.

    Same key (and cfg/dtype) -> bit-identical positions, gains, serving
    cells, and handover streams, every run — the whole pipeline is one
    jitted scan keyed by the PRNG key. `bs_xy` defaults to the centered
    `assoc.bs_grid` layout.
    """
    cfg = cfg if cfg is not None else MobilityConfig()
    if bs_xy is None:
        from repro.assoc.scenario import bs_grid
        bs_xy = bs_grid(n_cells, cfg.area_m, jnp.dtype(dtype))
    bs_xy = jnp.asarray(bs_xy, jnp.dtype(dtype))
    if bs_xy.shape != (n_cells, 2):
        raise ValueError(f"simulate_mobility: bs_xy must be ({n_cells}, 2),"
                         f" got {bs_xy.shape}")
    pos, gains, serving, handover = _trace_impl(key, bs_xy, int(n_devices),
                                                cfg, str(dtype))
    return MobilityTrace(positions=pos, gains=gains, serving=serving,
                         handover=handover, bs_xy=bs_xy)


# ------------------------------------------------------------ serving replay

def replay_mobility(service, trace: MobilityTrace, base: SystemParams,
                    w=None) -> dict:
    """Drive a region serving front-end with a mobility trace.

    Per step: cells whose member set changed since the previous step (either
    side of a handover) are invalidated (`service.invalidate` ->
    `handover_purges`), then every non-empty cell re-requests an allocation
    with its members' realized gains. `base` is a single-cell
    `SystemParams` carrying the N devices' attributes (cycles/samples/bits
    and the cell scalars, reused for every cell); `w` optionally overrides
    the service's default weights per request.

    Returns the churn summary: handover counts, purges, warm-cache hit
    rate, mean warm/cold re-solve iterations, and the compiled shapes.
    """
    from repro.region.admission import AllocationRequest

    serving = np.asarray(trace.serving)
    gains = np.asarray(trace.gains)
    R, C, N = gains.shape
    if base.n != N:
        raise ValueError(f"replay_mobility: base system has {base.n} "
                         f"devices, trace has {N}")
    host = {k: np.asarray(getattr(base, k))
            for k in ("cycles", "samples", "bits")}
    warm_iters, cold_iters = [], []
    handovers = 0
    for r in range(R):
        if r:
            moved = np.nonzero(serving[r] != serving[r - 1])[0]
            handovers += int(moved.size)
            touched = set(serving[r - 1][moved].tolist()) \
                | set(serving[r][moved].tolist())
            for cid in sorted(touched):
                service.invalidate(int(cid))
        reqs = []
        for cid in range(C):
            members = np.nonzero(serving[r] == cid)[0]
            if members.size == 0:
                continue
            sysc = base.replace(
                gain=gains[r, cid, members],
                cycles=host["cycles"][members],
                samples=host["samples"][members],
                bits=host["bits"][members], active=None)
            reqs.append(AllocationRequest(cell_id=cid, sys=sysc, w=w))
        for resp in service.solve(reqs).values():
            (warm_iters if resp.warm else cold_iters).append(int(resp.iters))
    s = service.stats
    return dict(
        steps=R, cells=C, devices=N, handovers=handovers,
        handover_purges=int(s.get("handover_purges", 0)),
        requests=int(s["requests"]),
        hit_rate=s["cache_hits"] / max(s["requests"], 1),
        warm_solves=len(warm_iters), cold_solves=len(cold_iters),
        mean_warm_iters=float(np.mean(warm_iters)) if warm_iters
        else float("nan"),
        mean_cold_iters=float(np.mean(cold_iters)) if cold_iters
        else float("nan"),
        compiled_shapes=sorted(service.compiled_shapes))
