"""Admission layer: the request queue in front of the region pipeline.

Requests enter the pipeline here and wait — per bucket — until a
*batch-closing policy* decides their batch is worth dispatching. The queue
tracks per-request enqueue times, deadlines, and priorities; when a batch
closes, its members are handed to the planning layer in
(priority desc, arrival) order and their queue wait is charged to the
pipeline's `StageClocks`.

Policies (`AllocationRequest.deadline`/`priority` feed them):

  * `CloseOnFull`   — close only when `cells_per_batch` requests are
    queued (plus the forced close of a `flush`). The throughput-greedy
    default: every dispatched chunk is fully occupied, so the compiled
    batch shape never solves avoidable pad cells.
  * `MaxWait`       — close-on-full OR when the oldest queued request has
    waited `max_wait` (in the caller's clock units — wall seconds with the
    default clock, logical ticks if the caller passes its own `now`).
    Bounds queue latency under trickle traffic.
  * `DeadlineSlack` — close-on-full OR when any queued request's deadline
    is within `slack` of `now`. The SLO-shaped policy: a batch closes
    exactly early enough for its tightest request.

The clock is caller-defined: every entry point takes `now` (defaulting to
`time.monotonic()`), so tests and benchmarks can drive the policies with
logical ticks and deadlines stay in one consistent unit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Hashable, List, Optional, Tuple

from repro import obs
from repro.core.types import SystemParams, Weights

from .batch import DEFAULT_MIN_BUCKET, bucket_size


@dataclasses.dataclass
class AllocationRequest:
    """One cell asking for a (re-)allocation against its current channel
    snapshot. `cell_id` keys the warm-start cache: re-requests of the same
    cell (drifted gains, same device pool) re-solve from the previous
    solution. `w`, if set, overrides the allocator's default weights for
    this request only (traced — never a recompile). `deadline` (absolute,
    in the admission clock's units) and `priority` (larger first) feed the
    batch-closing policy and the within-batch ordering."""
    cell_id: Hashable
    sys: SystemParams
    w: Optional[Weights] = None
    deadline: Optional[float] = None
    priority: int = 0


class StageClocks:
    """Per-stage wall-time **samples** for the pipeline (seconds, except
    the queue_wait stage, which is in the admission clock's units — wall
    seconds unless the caller drives `now` itself).

      queue_wait : per request, batch close - submit
      plan       : per batch, host-side pad/stack/warm-init assembly
      dispatch   : per batch, host time to trace/enqueue the solve
      device     : per batch, dispatch -> compute observed ready (an upper
                   bound measured at the batch's first blocking poll)
      gather     : per batch, device->host materialization of responses

    Stages record individual durations via `record(stage, dur)` — the raw
    samples feed real latency distributions (`samples`, `histogram`,
    `percentiles`) instead of only a monotone sum, and each `record` also
    emits a `repro.obs` "stage" point when a recorder is enabled.

    The historical aggregate fields (`queue_wait_s`, `plan_s`, ...) are
    deprecated shims: reading one sums the stage's samples; augmented
    assignment (`clocks.plan_s += dt`) still works by recording the delta
    as one sample, so pre-existing callers keep functioning while losing
    no distribution data. `as_dict()` keeps its historical aggregate key
    set."""

    STAGES = ("queue_wait", "plan", "dispatch", "device", "gather")

    def __init__(self):
        self._samples: Dict[str, List[float]] = {s: [] for s in self.STAGES}

    def record(self, stage: str, dur: float) -> None:
        """Record one duration sample for `stage` (and, with a recorder
        enabled, emit it as an obs "stage" point)."""
        self._samples[stage].append(float(dur))
        from repro import obs

        if obs.enabled():
            obs.point("stage", stage=stage, dur_s=float(dur))

    def samples(self, stage: str) -> List[float]:
        """The stage's raw duration samples (a copy)."""
        return list(self._samples[stage])

    def total(self, stage: str) -> float:
        return float(sum(self._samples[stage]))

    def count(self, stage: str) -> int:
        return len(self._samples[stage])

    def histogram(self, stage: str):
        """The stage's samples in a fixed-bucket `repro.obs` Histogram
        (the same layout every latency metric in the repo uses)."""
        from repro.obs.metrics import Histogram

        h = Histogram("stage_seconds", (("stage", stage),))
        h.observe_many(self._samples[stage])
        return h

    def percentiles(self, stage: str, qs=(50.0, 90.0, 99.0)) -> dict:
        """{p50: ..., p90: ..., p99: ...} of the stage's samples (NaN when
        the stage has none)."""
        return self.histogram(stage).percentiles(qs)

    def as_dict(self) -> dict:
        """Historical aggregate view: {stage}_s -> summed seconds."""
        return {f"{s}_s": self.total(s) for s in self.STAGES}

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v:.6g}" for k, v in self.as_dict().items())
        return f"StageClocks({body})"


def _aggregate_shim(stage: str):
    """Deprecated `{stage}_s` aggregate property: read sums the samples;
    write (only sensible as `+=`) records the delta as one sample."""

    def get(self: StageClocks) -> float:
        return self.total(stage)

    def set_(self: StageClocks, value: float) -> None:
        delta = float(value) - self.total(stage)
        if delta != 0.0:
            self.record(stage, delta)

    return property(get, set_, doc=f"Deprecated: summed {stage} seconds "
                    f"(use samples({stage!r}) / histogram({stage!r})).")


for _stage in StageClocks.STAGES:
    setattr(StageClocks, f"{_stage}_s", _aggregate_shim(_stage))
del _stage


@dataclasses.dataclass
class QueuedRequest:
    """A request waiting for its batch to close. `token` is an opaque
    caller payload carried through the queue — the pipeline stores the
    request's `PendingResponse` there so a closed batch can be bound back
    to the futures it serves."""
    request: AllocationRequest
    t_enqueue: float
    seq: int    # global arrival order: the FIFO tiebreak within a priority
    token: object = None


class BatchPolicy:
    """Decides when a bucket's pending requests close into a batch.

    `ready(queued, now, cells_per_batch)` sees the bucket's queue in
    arrival order and returns True to close a batch of (up to)
    `cells_per_batch` requests now. A forced `flush` closes everything
    regardless of the policy."""

    def ready(self, queued: List[QueuedRequest], now: float,
              cells_per_batch: int) -> bool:
        raise NotImplementedError


class CloseOnFull(BatchPolicy):
    """Close only full batches (flush drains the rest)."""

    def ready(self, queued, now, cells_per_batch):
        return len(queued) >= cells_per_batch


class MaxWait(BatchPolicy):
    """Close on full, or when the oldest request has waited `max_wait`."""

    def __init__(self, max_wait: float):
        if max_wait < 0:
            raise ValueError(f"MaxWait: max_wait must be >= 0, got {max_wait}")
        self.max_wait = float(max_wait)

    def ready(self, queued, now, cells_per_batch):
        if len(queued) >= cells_per_batch:
            return True
        return bool(queued) and now - queued[0].t_enqueue >= self.max_wait


class DeadlineSlack(BatchPolicy):
    """Close on full, or when any queued deadline is within `slack` of now.

    Requests without a deadline never trigger the early close (they ride
    along when a deadlined neighbor closes the batch, or when it fills)."""

    def __init__(self, slack: float = 0.0):
        self.slack = float(slack)

    def ready(self, queued, now, cells_per_batch):
        if len(queued) >= cells_per_batch:
            return True
        return any(q.request.deadline is not None
                   and q.request.deadline - now <= self.slack
                   for q in queued)


class AdmissionQueue:
    """Per-bucket request queues + the batch-closing policy.

    `submit` files a request under its device-count bucket;
    `close_ready(now)` asks the policy which batches to close and returns
    them as `(bucket, [QueuedRequest, ...])` groups — each at most
    `cells_per_batch` long, ordered by (priority desc, arrival), buckets in
    ascending order (the same deterministic grouping the synchronous
    `RegionAllocator.solve` always produced for equal priorities)."""

    def __init__(self, cells_per_batch: int,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 policy: Optional[BatchPolicy] = None,
                 clocks: Optional[StageClocks] = None):
        if cells_per_batch < 1:
            raise ValueError("cells_per_batch must be >= 1")
        self.cells_per_batch = int(cells_per_batch)
        self.min_bucket = int(min_bucket)
        self.policy = policy if policy is not None else CloseOnFull()
        self.clocks = clocks if clocks is not None else StageClocks()
        self._queues: Dict[int, List[QueuedRequest]] = {}
        self._seq = 0

    def submit(self, request: AllocationRequest,
               now: Optional[float] = None, token: object = None) -> int:
        """Queue a request; returns the bucket it was filed under."""
        now = time.monotonic() if now is None else now
        bucket = bucket_size(request.sys.n, self.min_bucket)
        self._queues.setdefault(bucket, []).append(
            QueuedRequest(request, now, self._seq, token))
        self._seq += 1
        return bucket

    @property
    def pending(self) -> int:
        """Requests queued but not yet closed into a batch."""
        return sum(len(q) for q in self._queues.values())

    def close_ready(self, now: Optional[float] = None, force: bool = False
                    ) -> List[Tuple[int, List[QueuedRequest]]]:
        """Close every batch the policy (or `force`) says is ready.

        Returns `(bucket, [QueuedRequest, ...])` groups — each at most
        `cells_per_batch` long, ordered by (priority desc, arrival),
        buckets ascending (the deterministic grouping the synchronous
        `RegionAllocator.solve` always produced for equal priorities)."""
        now = time.monotonic() if now is None else now
        closed: List[Tuple[int, List[QueuedRequest]]] = []
        for bucket in sorted(self._queues):
            queue = self._queues[bucket]
            while queue and (force or self.policy.ready(
                    queue, now, self.cells_per_batch)):
                # stable sort: FIFO within equal priorities, so the default
                # (all priority 0) reproduces pure arrival order
                queue.sort(key=lambda e: (-e.request.priority, e.seq))
                take = queue[:self.cells_per_batch]
                queue = queue[self.cells_per_batch:]
                self._queues[bucket] = queue
                late = 0
                for e in take:
                    self.clocks.record("queue_wait",
                                       max(0.0, now - e.t_enqueue))
                    if (e.request.deadline is not None
                            and e.request.deadline < now):
                        late += 1
                if late:
                    # the deadline expired while the request was still
                    # QUEUED — in the admission clock's own units, so the
                    # count is meaningful even under logical-tick clocks
                    # (unlike the completion layer's wall-clock hit check)
                    obs.counter("region_admission_deadline_late").inc(late)
                closed.append((bucket, take))
        return closed
