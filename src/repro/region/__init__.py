"""repro.region — region-scale sharded allocation service (beyond paper).

The paper solves one cell of N MAR devices; this package turns the
single-host `allocate_fleet`/`run_rounds_fleet` pair into a service for a
*region* — many heterogeneous cells, millions of clients — in three layers:

  * mesh   (`region.mesh`):  shard the cell axis of a stacked fleet across
    a device mesh (`region_mesh`, `allocate_region`, `run_rounds_region`);
  * batch  (`region.batch`): pad mixed-size cell pools onto a power-of-two
    bucket menu with masked devices (`pad_system`, `bucket_size`) so real
    traffic compiles into a handful of shapes;
  * service (`region.service`): a streaming front-end (`RegionAllocator`)
    that coalesces allocation requests into bucketed shard-ready batches
    and warm-starts re-requests from an LRU cache of previous solutions.

CPU dev recipe: XLA_FLAGS=--xla_force_host_platform_device_count=8 makes
one host expose 8 devices for the mesh (see ROADMAP "Region service").
"""
from .batch import bucket_size, pad_allocation, pad_system
from .mesh import (RegionResult, allocate_region, cell_specs, pad_cells,
                   place_cells, region_mesh, run_rounds_region)
from .service import AllocationRequest, CellResponse, RegionAllocator

__all__ = [
    "bucket_size", "pad_allocation", "pad_system",
    "RegionResult", "allocate_region", "cell_specs", "pad_cells",
    "place_cells", "region_mesh", "run_rounds_region",
    "AllocationRequest", "CellResponse", "RegionAllocator",
]
