"""`python -m repro.obs.report` — summarize a recorded JSONL event stream.

Reads the span/point events written by `JsonlRecorder` (e.g. by
`examples/serve_observed.py` or `benchmarks.run serve_latency --events`)
and prints:

  * per-span-name latency tables: count, p50/p90/p99, mean, max —
    rebuilt through the same fixed-bucket `Histogram` the live metrics
    use, so the report and the Prometheus/JSONL exports agree;
  * per-stage tables from "stage" points (the region pipeline's
    queue_wait/plan/dispatch/device/gather samples);
  * per-request solver-effort counters from "request" points: BCD
    iterations, SP1/SP2 dual evals, final residual, end-to-end latency;
  * a deadline-hit line when any request carried a deadline (the
    completion layer stamps `deadline_hit` on those "request" points —
    the same facts the SLO plane's deadline-hit-rate objective counts).

Usage:
    python -m repro.obs.report events.jsonl
    python -m repro.obs.report events.jsonl --percentiles 50,95,99.9
"""
from __future__ import annotations

import argparse
import math
import sys
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Sequence

from .metrics import Histogram
from .recorder import read_jsonl

__all__ = ["summarize", "format_report", "main"]

_MS = 1e3


def _hist_of(values: Iterable[float]) -> Histogram:
    h = Histogram("report")
    h.observe_many(values)
    return h


def summarize(events: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Aggregate an event stream into the report's table inputs.

    Returns {"spans": {name: Histogram_of_dur_s},
             "stages": {stage: Histogram_of_dur_s},
             "requests": {"latency": Histogram, "counters": {k: [v...]}},
             "deadlines": {"hits": int, "total": int},
             "counts": {event name: occurrences}}.
    """
    span_durs: Dict[str, List[float]] = defaultdict(list)
    stage_durs: Dict[str, List[float]] = defaultdict(list)
    req_lat: List[float] = []
    req_counters: Dict[str, List[float]] = defaultdict(list)
    counts: Dict[str, int] = defaultdict(int)
    dl_hits = dl_total = 0

    for ev in events:
        counts[ev.get("name", "?")] += 1
        t = ev.get("type")
        if t == "span":
            span_durs[ev["name"]].append(float(ev.get("dur_s", 0.0)))
        elif t == "point" and ev.get("name") == "stage":
            stage_durs[ev["stage"]].append(float(ev.get("dur_s", 0.0)))
        elif t == "point" and ev.get("name") == "request":
            if "latency_s" in ev:
                req_lat.append(float(ev["latency_s"]))
            if "deadline_hit" in ev:
                dl_total += 1
                dl_hits += bool(ev["deadline_hit"])
            for k, v in ev.items():
                if k in ("type", "name", "span", "parent") or k == "ts":
                    continue
                if isinstance(v, (int, float)) and not k.endswith("_s"):
                    req_counters[k].append(float(v))

    return {
        "spans": {k: _hist_of(v) for k, v in sorted(span_durs.items())},
        "stages": {k: _hist_of(v) for k, v in sorted(stage_durs.items())},
        "requests": {"latency": _hist_of(req_lat),
                     "counters": dict(sorted(req_counters.items()))},
        "deadlines": {"hits": dl_hits, "total": dl_total},
        "counts": dict(counts),
    }


def _table(title: str, rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    fmt = "  ".join("{:>%d}" % w for w in widths)
    out = [title, fmt.format(*header)]
    out += [fmt.format(*r) for r in rows]
    return "\n".join(out)


def _lat_rows(hists: Dict[str, Histogram], qs: Sequence[float]
              ) -> List[List[str]]:
    rows = []
    for name, h in hists.items():
        if not h.count:
            continue
        row = [name, str(h.count)]
        row += [f"{h.percentile(q) * _MS:.3f}" for q in qs]
        row += [f"{h.mean * _MS:.3f}", f"{h.max * _MS:.3f}"]
        rows.append(row)
    return rows


def format_report(summary: Dict[str, Any],
                  qs: Sequence[float] = (50.0, 90.0, 99.0)) -> str:
    """Render the `summarize` output as aligned text tables (ms units)."""
    header = ["name", "n"] + [f"p{q:g}_ms" for q in qs] + ["mean_ms", "max_ms"]
    blocks: List[str] = []

    span_rows = _lat_rows(summary["spans"], qs)
    if span_rows:
        blocks.append(_table("== spans ==", span_rows, header))

    stage_rows = _lat_rows(summary["stages"], qs)
    if stage_rows:
        blocks.append(_table("== pipeline stages ==", stage_rows, header))

    req = summary["requests"]
    if req["latency"].count:
        blocks.append(_table(
            "== request latency ==",
            _lat_rows({"end_to_end": req["latency"]}, qs), header))

    ctr_rows = []
    for k, vals in req["counters"].items():
        h = _hist_of(vals)
        ctr_rows.append([k, str(h.count), f"{h.mean:.3f}",
                         f"{h.percentile(50):.3f}", f"{h.max:.3f}"])
    if ctr_rows:
        blocks.append(_table("== per-request solver counters ==",
                             ctr_rows, ["counter", "n", "mean", "p50", "max"]))

    dl = summary.get("deadlines", {"total": 0})
    if dl["total"]:
        blocks.append(f"== deadlines == {dl['hits']}/{dl['total']} hit "
                      f"({100.0 * dl['hits'] / dl['total']:.1f}%)")

    if not blocks:
        blocks.append("(no span/stage/request events found)")
    return "\n\n".join(blocks) + "\n"


def main(argv: Sequence[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs JSONL event stream.")
    ap.add_argument("events", help="path to a JSONL event file")
    ap.add_argument("--percentiles", default="50,90,99",
                    help="comma-separated percentiles (default 50,90,99)")
    args = ap.parse_args(argv)

    qs = tuple(float(q) for q in args.percentiles.split(","))
    events = read_jsonl(args.events)
    sys.stdout.write(format_report(summarize(events), qs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
