"""Synthetic resolution-sensitive federated datasets (paper §VII-B).

No dataset downloads are possible in-container; we reproduce the *mechanism*
the paper studies — accuracy rises with video-frame resolution, degrades under
non-IID and unbalanced splits — with a controlled generator:

  * each class has a random high-frequency template at base resolution;
  * a sample is template + per-sample shift deformation + pixel noise;
  * rendering at resolution s average-pools the base frame down to s x s,
    destroying high-frequency class evidence (low s -> lower attainable
    accuracy), the same causal path as the paper's resized YOLO frames.

Splits: "iid", "noniid-1" (1 class/client), "noniid-2" (2 classes/client),
and `unbalanced=True` resamples client data down to Dirichlet-drawn sizes,
matching §VII-B.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FLDataset:
    """Per-client arrays: images at BASE resolution; render at train time."""
    images: jax.Array          # (clients, per_client, H, H, 1) base frames
    labels: jax.Array          # (clients, per_client)
    templates: jax.Array       # (num_classes, H, H, 1) generative templates
    noise: float
    base_resolution: int
    num_classes: int

    @property
    def n_clients(self) -> int:
        return self.images.shape[0]


def render(images: jax.Array, resolution: int) -> jax.Array:
    """Average-pool base frames (..., H, H, 1) down to (..., s, s, 1)."""
    H = images.shape[-3]
    if resolution >= H:
        return images
    k = H // resolution
    s = resolution
    x = images[..., : s * k, : s * k, :]
    x = x.reshape(*x.shape[:-3], s, k, s, k, 1).mean(axis=(-4, -2))
    return x


def _upsample(grid: jax.Array, factor: int) -> jax.Array:
    """Nearest-neighbour upsample of (..., s, s, 1) by `factor`."""
    return jnp.repeat(jnp.repeat(grid, factor, axis=-3), factor, axis=-2)


def _make_templates(key: jax.Array, num_classes: int, base: int) -> jax.Array:
    """Class evidence split across spatial scales: block-constant components at
    scales 4, 8, ..., base. Rendering at resolution r preserves exactly the
    components with scale <= r and (mostly) destroys finer ones — so accuracy
    rises monotonically with the allocated frame resolution (paper Fig. 6/7
    mechanism)."""
    scales = [s for s in (4, 8, 16, 32, 64) if s <= base]
    parts = []
    for i, s in enumerate(scales):
        k = jax.random.fold_in(key, i)
        parts.append(_upsample(jax.random.normal(k, (num_classes, s, s, 1)),
                               base // s))
    return sum(parts) / jnp.sqrt(float(len(scales)))


def _sample(key, templates, labels, noise):
    k_shift, k_smooth, k_pix = jax.random.split(key, 3)
    base = templates.shape[-3]
    imgs = templates[labels]
    shift = jax.random.randint(k_shift, labels.shape + (2,), -1, 2)
    roll = lambda im, sh: jnp.roll(im, sh, axis=(0, 1))
    for _ in range(labels.ndim):
        roll = jax.vmap(roll)
    imgs = roll(imgs, shift)
    # smooth noise survives pooling (so low resolutions don't get a free SNR
    # boost); a little pixel noise on top.
    smooth = _upsample(jax.random.normal(k_smooth, labels.shape + (4, 4, 1)),
                       base // 4)
    pix = jax.random.normal(k_pix, imgs.shape)
    return imgs + noise * (2.2 * smooth + 0.3 * pix)


def make_federated_dataset(key: jax.Array, n_clients: int = 10,
                           per_client: int = 256, num_classes: int = 8,
                           base_resolution: int = 32, split: str = "iid",
                           unbalanced: bool = False,
                           noise: float = 0.35) -> FLDataset:
    k_tpl, k_lbl, k_draw, k_sizes = jax.random.split(key, 4)
    templates = _make_templates(k_tpl, num_classes, base_resolution)

    if split == "iid":
        labels = jax.random.randint(k_lbl, (n_clients, per_client), 0, num_classes)
    elif split in ("noniid-1", "noniid-2"):
        per_cls = 1 if split == "noniid-1" else 2
        rng = np.random.default_rng(int(jax.random.randint(k_lbl, (), 0, 2 ** 31 - 1)))
        owned = np.stack([rng.choice(num_classes, size=per_cls, replace=False)
                          for _ in range(n_clients)])
        pick = rng.integers(0, per_cls, size=(n_clients, per_client))
        labels = jnp.asarray(np.take_along_axis(owned, pick, axis=1))
    else:
        raise ValueError(f"unknown split {split!r}")

    imgs = _sample(k_draw, templates, labels, noise)

    if unbalanced:
        # resample each client's data down to a Dirichlet-drawn effective size
        frac = jax.random.dirichlet(k_sizes, jnp.ones((n_clients,)))
        frac = jnp.clip(frac * n_clients, 0.2, 1.0)
        idx = jnp.where(jnp.arange(per_client)[None, :]
                        < (frac[:, None] * per_client),
                        jnp.arange(per_client)[None, :], 0)
        imgs = jnp.take_along_axis(imgs, idx[..., None, None, None], axis=1)
        labels = jnp.take_along_axis(labels, idx, axis=1)

    return FLDataset(images=imgs, labels=labels, templates=templates,
                     noise=noise, base_resolution=base_resolution,
                     num_classes=num_classes)


def make_eval_set(key: jax.Array, ds: FLDataset, n: int = 512
                  ) -> Tuple[jax.Array, jax.Array]:
    """Held-out IID eval set drawn from the dataset's generative process."""
    k_lbl, k_draw = jax.random.split(key)
    labels = jax.random.randint(k_lbl, (n,), 0, ds.num_classes)
    imgs = _sample(k_draw, ds.templates, labels, ds.noise)
    return imgs, labels
