"""Metaverse-scale allocator kernel: batched SP2 dual sweep (paper eq. A.23).

Evaluates g'(mu) for M candidate multipliers over N devices in one pass —
the inner loop of the bandwidth waterfilling at fleet scale (N ~ 10^5..10^6
AR clients per base-station region). Grid (N/bn,), VMEM block of device
parameters, Lambert-W by Halley iteration on VREGs, partial sums accumulated
into the (M,) output across sequential grid steps.

Numerics: the Lambert argument z = (mu - j)/(e j) sits right at the branch
point -1/e when mu << j, where forming e*z + 1 loses all significant bits to
cancellation. The kernel therefore works with the cancellation-free ratio
q = mu / j (so e*z + 1 == q exactly) and seeds the branch-point series with
p = sqrt(2 q). Any N is accepted: the tail block is padded with (j=1,
rmin=0) lanes whose summand rmin ln2/(W+1) is exactly 0.

Oracle: kernels.ref.waterfill_gprime_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lambertw_vec(q, iters: int = 24):
    """W0(z) for z = (q - 1)/e, q >= 0 — branch-point-stable in f32.

    Clamps respect the compute dtype: an f32 lane at z ~ -1/e would
    otherwise round W to exactly -1, making Halley's wp1 divisor 0 (-> NaN).
    """
    dt = q.dtype
    eps = jnp.asarray(jnp.finfo(dt).eps, dt)
    tiny = jnp.asarray(jnp.finfo(dt).tiny, dt)
    qc = jnp.maximum(q, 0.0)
    zc = (qc - 1.0) / jnp.e
    # branch-point series in p = sqrt(2(e z + 1)) = sqrt(2 q)  (no cancellation)
    p = jnp.sqrt(2.0 * qc)
    w_branch = -1.0 + p * (1.0 - p / 3.0 + 11.0 * p * p / 72.0
                           - 43.0 * p * p * p / 540.0)
    lz = jnp.log(jnp.maximum(zc, tiny))
    llz = jnp.log(jnp.maximum(lz, tiny))
    w_big = lz - llz + llz / jnp.maximum(lz, eps)
    w_small = zc * (1.0 - zc + 1.5 * zc * zc)
    w = jnp.where(zc < -0.25, w_branch, jnp.where(zc > 3.0, w_big, w_small))
    w = jnp.maximum(w, -1.0 + eps)
    for _ in range(iters):
        ew = jnp.exp(w)
        f = w * ew - zc
        wp1 = w + 1.0
        denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1)
        w = jnp.maximum(w - f / jnp.where(jnp.abs(denom) < tiny, tiny, denom),
                        -1.0 + eps)
    # Halley's f = w e^w - z cancels catastrophically near the branch point;
    # there the p-series is the accurate evaluation, so keep it.
    return jnp.where(qc < 1e-3, w_branch, w)


def _waterfill_kernel(mu_ref, j_ref, rmin_ref, out_ref, *, dtype):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mu = mu_ref[...].astype(dtype)       # (M,)
    j = j_ref[...].astype(dtype)         # (bn,)
    rmin = rmin_ref[...].astype(dtype)   # (bn,)
    q = mu[:, None] / j[None, :]         # (M, bn): e z + 1, exactly
    w = _lambertw_vec(q)
    part = jnp.sum(rmin[None, :] * jnp.log(2.0)
                   / jnp.maximum(w + 1.0, jnp.finfo(dtype).eps ** 2), axis=1)
    out_ref[...] += part.astype(out_ref.dtype)


def waterfill_gprime(mu: jax.Array, j: jax.Array, rmin: jax.Array,
                     B_total, *, block_n: int = 1024,
                     interpret: bool = False,
                     dtype=jnp.float32) -> jax.Array:
    """g'(mu) per candidate: mu (M,), j/rmin (N,) -> (M,). Any N: the tail
    block is padded with (j=1, rmin=0) lanes, whose summand
    rmin ln2 / (W+1) is exactly 0 — an implicit mask of the partial sum.

    dtype: in-kernel compute/output dtype. f32 is the TPU-native default;
    f64 is only meaningful in interpret mode (CPU parity checks).
    """
    N = j.shape[0]
    rem = (-N) % block_n
    if rem:
        j = jnp.concatenate([j, jnp.ones((rem,), j.dtype)])
        rmin = jnp.concatenate([rmin, jnp.zeros((rem,), rmin.dtype)])
        N += rem
    M = mu.shape[0]
    sums = pl.pallas_call(
        functools.partial(_waterfill_kernel, dtype=dtype),
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((M,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((M,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((M,), dtype),
        interpret=interpret,
    )(mu.astype(dtype), j.astype(dtype), rmin.astype(dtype))
    return sums - B_total
