"""Jit-cache discipline of the unified API: compiles are keyed by
(bucket x topology x SolverSpec) — weights are traced operands and NEVER
trigger a recompile.

Compilations are counted through `jax.monitoring`'s backend-compile
duration events (every XLA backend compile fires one), measured as deltas
around a warmed mixed-weights / mixed-bucket request trace. The listener
(`CompileCounter`) lives in `tests/conftest.py` as the shared
`compile_counter` fixture — `test_obs` reuses it to prove the telemetry
plumbing adds no compiled shapes.
"""
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import (AllocationRequest, Problem, RegionAllocator, SolverSpec,
                   Weights, make_system, solve)


def _mk_cells(sizes, seed=0):
    key = jax.random.PRNGKey(seed)
    return {f"cell{i}-{n}": make_system(jax.random.fold_in(key, i),
                                        n_devices=n)
            for i, n in enumerate(sizes)}


def _drift(sys, scale):
    """Host-side gain drift: no eager jnp ops, so it cannot compile."""
    return sys.replace(gain=np.asarray(sys.gain) * scale)


def _submit_all(svc, cells, weights_of):
    for i, (cid, s) in enumerate(sorted(cells.items())):
        svc.submit(AllocationRequest(cell_id=cid, sys=s, w=weights_of(i)))
    return svc.flush()


def test_mixed_weights_trace_compiles_only_per_bucket(compile_counter):
    """The acceptance trace: mixed device counts (2 buckets) x mixed
    per-request weights compile once per (bucket, spec) and ZERO extra
    shapes for any weight change — the PR 4 fragmentation caveat closed."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        spec = SolverSpec(max_iters=4, tol=1e-4)
        svc = RegionAllocator(Weights(0.5, 0.5, 1.0), cells_per_batch=2,
                              min_bucket=8, spec=spec)
        # sizes straddling two power-of-two buckets: 8 and 16
        cells = _mk_cells([5, 7, 8, 9, 12, 16])
        w0 = Weights(0.5, 0.5, 1.0)

        # warm-up: cold pass, then a warm re-request pass (exercises the
        # warm-init padding host ops) — all compilation happens here
        _submit_all(svc, cells, lambda i: w0)
        cells = {cid: _drift(s, 1.01) for cid, s in cells.items()}
        _submit_all(svc, cells, lambda i: w0)
        assert svc.compiled_shapes == {(2, 8), (2, 16)}   # == #buckets

        # measurement: three more passes, every request with NEW weights
        before = compile_counter.count
        for k in range(3):
            cells = {cid: _drift(s, 1.0 + 0.01 * (k + 1))
                     for cid, s in cells.items()}
            out = _submit_all(
                svc, cells,
                lambda i, k=k: Weights(0.1 + 0.1 * i + 0.01 * k,
                                       0.9 - 0.1 * i, 1.0 + i + k))
            assert all(r.warm for r in out.values())
        assert compile_counter.count == before, (
            f"{compile_counter.count - before} recompiles triggered by "
            f"weight-only changes")
        assert svc.compiled_shapes == {(2, 8), (2, 16)}

        # a NEW spec is a new cache key: the same trace recompiles...
        svc2 = RegionAllocator(w0, cells_per_batch=2, min_bucket=8,
                               spec=SolverSpec(max_iters=5, tol=1e-4))
        before = compile_counter.count
        _submit_all(svc2, cells, lambda i: w0)
        assert compile_counter.count > before
        # ...and an equal spec in a fresh allocator hits the global cache
        svc3 = RegionAllocator(w0, cells_per_batch=2, min_bucket=8,
                               spec=SolverSpec(max_iters=5, tol=1e-4))
        cells = {cid: _drift(s, 1.005) for cid, s in cells.items()}
        before = compile_counter.count
        _submit_all(svc3, cells, lambda i: w0)
        assert compile_counter.count == before


def test_single_cell_weight_changes_do_not_recompile(compile_counter):
    """Same discipline on the single-cell topology through bare solve()."""
    sysp = make_system(jax.random.PRNGKey(3), n_devices=6)
    spec = SolverSpec(max_iters=3, tol=1e-4)
    solve(Problem(system=sysp, weights=Weights(0.5, 0.5, 1.0)), spec)
    solve(Problem(system=sysp, weights=Weights(0.4, 0.6, 2.0)), spec)  # warm
    before = compile_counter.count
    for i in range(4):
        solve(Problem(system=sysp,
                      weights=Weights(0.1 + 0.2 * i, 0.9 - 0.2 * i,
                                      float(i))), spec)
    assert compile_counter.count == before
