"""Arch cost model + allocator integration (DESIGN.md §2)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.core import Weights, allocate, feasible
from repro.core.costmodel import (arch_system, from_config,
                                  tokens_for_resolution)
from repro.roofline import params_active, params_total


def test_param_counts_match_model_cards():
    """Analytic parameter counts within 10% of the source model cards
    (granite excepted: the assigned dims imply 47B, noted in EXPERIMENTS)."""
    expected = {
        "qwen2-72b": 72e9, "mixtral-8x7b": 47e9, "dbrx-132b": 132e9,
        "internlm2-20b": 20e9, "jamba-1.5-large-398b": 398e9,
        "minicpm3-4b": 4e9, "llava-next-34b": 34e9,
    }
    for arch, exp in expected.items():
        got = params_total(get_config(arch))
        assert abs(got - exp) / exp < 0.1, (arch, got, exp)


def test_active_less_than_total_for_moe():
    for arch in ["mixtral-8x7b", "dbrx-132b", "jamba-1.5-large-398b"]:
        cfg = get_config(arch)
        assert params_active(cfg) < 0.6 * params_total(cfg)
    cfg = get_config("qwen2-72b")
    assert params_active(cfg) == pytest.approx(params_total(cfg), rel=0.01)


def test_tokens_for_resolution_quadratic():
    assert tokens_for_resolution(320) == 4 * tokens_for_resolution(160)


def test_arch_system_allocates_feasibly():
    key = jax.random.PRNGKey(0)
    sysp = arch_system(key, "rwkv6-1.6b", n_devices=6)
    res = allocate(sysp, Weights(0.5, 0.5, 1.0), max_iters=4)
    assert feasible(sysp, res.allocation)


def test_heavier_arch_prefers_lower_resolution():
    """At equal weights, a 20B local model must not choose higher frame
    resolutions than a 1.6B one (the c_n integration doing its job)."""
    key = jax.random.PRNGKey(1)
    rho = 2e4   # accuracy pressure strong enough to matter for the light arch
    s_light = arch_system(key, "rwkv6-1.6b", n_devices=6)
    s_heavy = arch_system(key, "internlm2-20b", n_devices=6)
    r_light = allocate(s_light, Weights(0.5, 0.5, rho), max_iters=4)
    r_heavy = allocate(s_heavy, Weights(0.5, 0.5, rho), max_iters=4)
    assert float(jnp.mean(r_heavy.allocation.resolution)) <= \
        float(jnp.mean(r_light.allocation.resolution)) + 1e-6
