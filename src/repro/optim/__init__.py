from .adamw import (AdamW, AdamWState, SGD, clip_by_global_norm,
                    cosine_schedule, global_norm, linear_schedule)
