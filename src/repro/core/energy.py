"""System model: rate, time, energy, and the paper's objective (eqs. 1-13)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .types import Allocation, SystemParams, Weights
from .accuracy import AccuracyModel

Array = jnp.ndarray


def _masked(x: Array, active) -> Array:
    """Zero out padded-out devices before a sum/max reduction.

    `active=None` returns `x` untouched (the mask-free path); an all-True
    mask multiplies through `where(True, x, 0) == x` bit-exactly, and time/
    energy/accuracy are nonnegative so 0 is neutral for both sum and max —
    the active prefix of a padded system reduces identically."""
    if active is None:
        return x
    return jnp.where(active, x, jnp.zeros((), jnp.asarray(x).dtype))


def rate(sys: SystemParams, bandwidth: Array, power: Array) -> Array:
    """Shannon uplink rate r_n = B_n log2(1 + g_n p_n / (N0 B_n))  (eq. 1)."""
    b = jnp.maximum(bandwidth, 1e-9)
    snr = sys.gain * power / (sys.noise_psd * b)
    return b * jnp.log2(1.0 + snr)


def t_trans(sys: SystemParams, bandwidth: Array, power: Array) -> Array:
    """Uplink transmission time per global round T_n^trans = d_n / r_n  (eq. 2)."""
    return sys.bits / jnp.maximum(rate(sys, bandwidth, power), 1e-12)


def cycles_per_round(sys: SystemParams, resolution: Array) -> Array:
    """R_l * zeta * s_n^2 * c_n * D_n  (eqs. 7, 10): CPU cycles per global round."""
    return sys.local_iters * sys.zeta * resolution ** 2 * sys.cycles * sys.samples


def t_cmp(sys: SystemParams, freq: Array, resolution: Array) -> Array:
    """Local computation time per global round (eq. 10)."""
    return cycles_per_round(sys, resolution) / jnp.maximum(freq, 1e-9)


def e_cmp(sys: SystemParams, freq: Array, resolution: Array) -> Array:
    """Local computation energy per global round (eq. 8)."""
    return sys.kappa * cycles_per_round(sys, resolution) * freq ** 2


def e_trans(sys: SystemParams, bandwidth: Array, power: Array) -> Array:
    """Transmission energy per global round (eq. 3)."""
    return power * t_trans(sys, bandwidth, power)


def total_energy(sys: SystemParams, alloc: Allocation) -> Array:
    """E = R_g sum_n (E_trans + E_cmp)  (eq. 9). Padded devices excluded."""
    return sys.global_rounds * jnp.sum(_masked(
        e_trans(sys, alloc.bandwidth, alloc.power)
        + e_cmp(sys, alloc.freq, alloc.resolution), sys.active))


def round_time(sys: SystemParams, alloc: Allocation) -> Array:
    """Per-round makespan max_n (T_cmp + T_trans). Padded devices excluded."""
    return jnp.max(_masked(t_cmp(sys, alloc.freq, alloc.resolution)
                           + t_trans(sys, alloc.bandwidth, alloc.power),
                           sys.active))


def total_time(sys: SystemParams, alloc: Allocation) -> Array:
    """T = R_g max_n (T_cmp + T_trans)  (eq. 11)."""
    return sys.global_rounds * round_time(sys, alloc)


def total_accuracy(acc: AccuracyModel, alloc: Allocation,
                   active: Optional[Array] = None) -> Array:
    """A = sum_n A_n(s_n)  (§III-C). `active` excludes padded devices (their
    resolution clips to s_hi during the solve, which would otherwise add a
    phantom accuracy term per pad lane)."""
    return jnp.sum(_masked(acc.value(alloc.resolution), active))


def objective(sys: SystemParams, w: Weights, acc: AccuracyModel, alloc: Allocation) -> Array:
    """w1 E + w2 T - rho A  (eq. 12)."""
    return (w.w1 * total_energy(sys, alloc)
            + w.w2 * total_time(sys, alloc)
            - w.rho * total_accuracy(acc, alloc, sys.active))


def feasible(sys: SystemParams, alloc: Allocation, atol: float = 1e-6) -> bool:
    """Check constraints (12a)-(12d)."""
    b_ok = bool(jnp.all(alloc.bandwidth >= -atol)
                and jnp.sum(alloc.bandwidth) <= sys.bandwidth_total * (1 + 1e-6) + atol)
    p_ok = bool(jnp.all(alloc.power >= sys.p_min - atol)
                and jnp.all(alloc.power <= sys.p_max * (1 + 1e-9) + atol))
    f_ok = bool(jnp.all(alloc.freq >= sys.f_min - atol)
                and jnp.all(alloc.freq <= sys.f_max * (1 + 1e-9) + atol))
    res = jnp.asarray(sys.resolutions)
    s_ok = bool(jnp.all(jnp.min(jnp.abs(alloc.resolution[:, None] - res[None, :]), axis=1) < 1e-3))
    return b_ok and p_ok and f_ok and s_ok


def summarize(sys: SystemParams, w: Weights, acc: AccuracyModel, alloc: Allocation) -> dict:
    return dict(
        energy_J=float(total_energy(sys, alloc)),
        time_s=float(total_time(sys, alloc)),
        accuracy=float(total_accuracy(acc, alloc, sys.active)),
        objective=float(objective(sys, w, acc, alloc)),
        energy_trans_J=float(sys.global_rounds * jnp.sum(e_trans(sys, alloc.bandwidth, alloc.power))),
        energy_cmp_J=float(sys.global_rounds * jnp.sum(e_cmp(sys, alloc.freq, alloc.resolution))),
    )
