"""Serve a reduced Mixtral (SWA ring cache) with batched greedy decode.

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main

main(["--arch", "mixtral-8x7b", "--reduced", "--batch", "2",
      "--prompt-len", "16", "--gen", "12"])
