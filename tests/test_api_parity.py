"""Unified-API acceptance: one `solve(problem, spec)` reproduces every
legacy entry point bit-identically, per topology, and per-cell traced
weights match per-cell single solves exactly.

Also covers the SolverSpec construction-time validation (tol vs the
64-ulp rel-step floor) and the `allocate_fixed_deadline` parity satellite
(max_iters=0 returns NaN, spec options are honored).
"""
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import (Problem, SolverSpec, Weights, make_fleet, make_system,
                   rel_step_floor, solve)
from repro.api.solve import _reset_deprecation_registry
from repro.core import allocate, allocate_fixed_deadline, allocate_fleet
from repro.dynamics import RoundsConfig, run_rounds_fleet
from repro.region import allocate_region, region_mesh

W = Weights(0.5, 0.5, 1.0)


def _shim(fn, *args, **kw):
    """Call a legacy shim with its DeprecationWarning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


def _tree_equal(a, b) -> bool:
    eq = jax.tree_util.tree_map(
        lambda x, y: bool(jnp.all(jnp.asarray(x) == jnp.asarray(y))), a, b)
    return all(jax.tree_util.tree_leaves(eq))


# ---------------------------------------------------------------------------
# per-topology bit parity
# ---------------------------------------------------------------------------

def test_solve_matches_allocate_bit_identical():
    sysp = make_system(jax.random.PRNGKey(0), n_devices=10)
    old = _shim(allocate, sysp, W, max_iters=6, tol=1e-5)
    new = solve(Problem(system=sysp, weights=W),
                SolverSpec(max_iters=6, tol=1e-5))
    assert _tree_equal(old.allocation, new.allocation)
    assert old.objective == new.objective
    assert old.iters == new.iters and old.converged == new.converged
    assert old.history == new.history


def test_solve_matches_allocate_fleet_bit_identical():
    fleet = make_fleet(jax.random.PRNGKey(1), n_cells=4, n_devices=12)
    old = _shim(allocate_fleet, fleet, W, max_iters=6)
    new = solve(Problem(system=fleet, weights=W), SolverSpec(max_iters=6))
    assert _tree_equal(old.allocation, new.allocation)
    assert bool(jnp.all(old.objective == new.objective))
    assert bool(jnp.all(old.iters == new.iters))
    assert np.array_equal(np.asarray(old.history), np.asarray(new.history),
                          equal_nan=True)   # rows past iters are NaN-padded


def test_solve_matches_allocate_region_bit_identical():
    fleet = make_fleet(jax.random.PRNGKey(2), n_cells=3, n_devices=12)
    mesh = region_mesh()
    old = _shim(allocate_region, fleet, W, mesh=mesh, max_iters=6)
    new = solve(Problem(system=fleet, weights=W, mesh=mesh),
                SolverSpec(max_iters=6))
    assert _tree_equal(old.allocation, new.allocation)
    assert bool(jnp.all(old.fleet.objective == new.fleet.objective))
    assert old.stats["cells"] == new.stats["cells"]


def test_solve_matches_run_rounds_fleet_bit_identical():
    fleet = make_fleet(jax.random.PRNGKey(3), n_cells=3, n_devices=10)
    base = _shim(allocate_fleet, fleet, W, max_iters=6)
    cfg = RoundsConfig(rounds=3, channel_mode="markov", bcd_iters=2,
                       participation="stale", dropout_prob=0.05)
    key = jax.random.PRNGKey(7)
    old = _shim(run_rounds_fleet, key, fleet, W, cfg, init=base.allocation)
    new = solve(Problem(system=fleet, weights=W, rounds=cfg, key=key,
                        init=base.allocation))
    assert bool(jnp.all(old.ledger == new.ledger))
    assert bool(jnp.all(old.staleness == new.staleness))
    assert _tree_equal(old.allocation, new.allocation)


def test_solve_matches_fixed_deadline_bit_identical():
    sysp = make_system(jax.random.PRNGKey(4), n_devices=8)
    w = Weights(0.99, 0.01, 1.0)
    old = _shim(allocate_fixed_deadline, sysp, w, 120.0, max_iters=6)
    new = solve(Problem(system=sysp, weights=w, deadline=120.0),
                SolverSpec(max_iters=6))
    assert _tree_equal(old.allocation, new.allocation)
    assert old.objective == new.objective
    assert old.history == new.history


def test_fixed_deadline_fleet_matches_per_cell_single_solves():
    """A (C, N) stack with `deadline` vmaps the fixed-deadline BCD: every
    cell must match its own single-cell solve bit-for-bit, including with
    per-cell (C,) deadline budgets."""
    C = 3
    fleet = make_fleet(jax.random.PRNGKey(5), n_cells=C, n_devices=8)
    w = Weights(0.99, 0.01, 1.0)
    deadlines = jnp.asarray([90.0, 120.0, 150.0])
    spec = SolverSpec(max_iters=6)
    res = solve(Problem(system=fleet, weights=w, deadline=deadlines), spec)
    assert res.objective.shape == (C,)
    assert res.columns[0] == "energy"
    for c in range(C):
        cell = jax.tree_util.tree_map(lambda x: x[c], fleet)
        single = solve(Problem(system=cell, weights=w,
                               deadline=float(deadlines[c])), spec)
        got = jax.tree_util.tree_map(lambda x: x[c], res.allocation)
        assert _tree_equal(got, single.allocation), c
        assert bool(res.objective[c] == single.objective), c
        assert int(res.iters[c]) == single.iters, c
    # a scalar deadline broadcasts to every cell
    flat = solve(Problem(system=fleet, weights=w, deadline=120.0), spec)
    one = solve(Problem(
        system=jax.tree_util.tree_map(lambda x: x[1], fleet),
        weights=w, deadline=120.0), spec)
    assert bool(flat.objective[1] == one.objective)


# ---------------------------------------------------------------------------
# per-cell traced weights: the PR 4 fragmentation caveat, closed
# ---------------------------------------------------------------------------

def test_per_cell_weights_match_per_cell_single_solves():
    """A (C, 3) weights stack solves each cell exactly as a single-cell
    solve with that cell's weights — weights are data, not config."""
    fleet = make_fleet(jax.random.PRNGKey(5), n_cells=3, n_devices=12)
    ws = [Weights(0.9, 0.1, 1.0), Weights(0.5, 0.5, 10.0),
          Weights(0.1, 0.9, 30.0)]
    mixed = solve(Problem(system=fleet, weights=ws), SolverSpec(max_iters=6))
    for c, wc in enumerate(ws):
        cell = jax.tree_util.tree_map(lambda x: x[c], fleet)
        single = solve(Problem(system=cell, weights=wc),
                       SolverSpec(max_iters=6))
        assert bool(jnp.all(
            mixed.allocation.bandwidth[c] == single.allocation.bandwidth))
        assert bool(jnp.all(
            mixed.allocation.power[c] == single.allocation.power))
        assert bool(jnp.all(
            mixed.allocation.resolution[c] == single.allocation.resolution))
        assert int(mixed.iters[c]) == single.iters


def test_broadcast_weights_match_shared_weights():
    """Scalar weights broadcast to (C, 3) solve identically to the legacy
    shared-weights path (same compiled program, same values)."""
    fleet = make_fleet(jax.random.PRNGKey(6), n_cells=3, n_devices=10)
    shared = solve(Problem(system=fleet, weights=W), SolverSpec(max_iters=5))
    listed = solve(Problem(system=fleet, weights=[W, W, W]),
                   SolverSpec(max_iters=5))
    assert _tree_equal(shared.allocation, listed.allocation)


def test_weights_array_forms_agree():
    """Raw (3,) arrays and Weights normalize to the same solve."""
    sysp = make_system(jax.random.PRNGKey(8), n_devices=8)
    a = solve(Problem(system=sysp, weights=Weights(1.0, 1.0, 2.0)),
              SolverSpec(max_iters=5))
    b = solve(Problem(system=sysp, weights=jnp.asarray([1.0, 1.0, 2.0])),
              SolverSpec(max_iters=5))
    assert a.objective == pytest.approx(b.objective, rel=1e-12)


# ---------------------------------------------------------------------------
# fixed-deadline satellite: SolverSpec path + max_iters=0 regression
# ---------------------------------------------------------------------------

def test_fixed_deadline_zero_iters_nan_through_solve():
    """max_iters=0 returns the untouched init with a NaN objective (the
    PR 1 IndexError regression), now through the unified path."""
    sysp = make_system(jax.random.PRNGKey(9), n_devices=4)
    res = solve(Problem(system=sysp, weights=Weights(0.99, 0.01, 1.0),
                        deadline=100.0), SolverSpec(max_iters=0))
    assert res.iters == 0
    assert res.history == []
    assert np.isnan(res.objective)
    assert res.allocation.bandwidth.shape == (4,)


def test_fixed_deadline_accepts_spec_options():
    """The deadline variant rides the same SolverSpec path: warm-start
    init and keep_history are honored (the old signature lacked them)."""
    sysp = make_system(jax.random.PRNGKey(10), n_devices=6)
    w = Weights(0.99, 0.01, 0.0)
    cold = solve(Problem(system=sysp, weights=w, deadline=150.0),
                 SolverSpec(max_iters=8))
    warm = solve(Problem(system=sysp, weights=w, deadline=150.0,
                         init=cold.allocation), SolverSpec(max_iters=8))
    assert warm.iters <= cold.iters
    quiet = solve(Problem(system=sysp, weights=w, deadline=150.0),
                  SolverSpec(max_iters=8, keep_history=False))
    assert quiet.history == []
    assert quiet.objective == pytest.approx(cold.objective, rel=1e-12)


# ---------------------------------------------------------------------------
# SolverSpec construction validation (tol floor satellite)
# ---------------------------------------------------------------------------

def test_spec_rejects_tol_below_explicit_dtype_floor():
    floor = rel_step_floor(np.float32)
    with pytest.raises(ValueError, match="64 ulps"):
        SolverSpec(tol=floor / 2, dtype="float32")
    # the same tol is fine under f64
    SolverSpec(tol=floor / 2, dtype="float64")


def test_spec_rejects_tol_below_any_floor():
    with pytest.raises(ValueError, match="float64 rel-step floor"):
        SolverSpec(tol=1e-16)


def test_solve_warns_once_when_tol_below_resolved_floor():
    from repro.api.spec import _TOL_WARNED

    sysp = make_system(jax.random.PRNGKey(11), n_devices=4)
    sys32 = jax.tree_util.tree_map(
        lambda x: jnp.asarray(x).astype(jnp.float32)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x, sysp)
    _TOL_WARNED.clear()
    spec = SolverSpec(max_iters=1, tol=2e-6)   # chosen, below the f32 floor
    with pytest.warns(UserWarning, match="rel-step floor"):
        solve(Problem(system=sys32, weights=W), spec)
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)   # second call: silent
        solve(Problem(system=sys32, weights=W), spec)
        # the library DEFAULT tol is exempt (floor-or-1e-6 semantics):
        # a default-configured f32 solve must not warn about a tolerance
        # the user never chose
        solve(Problem(system=sys32, weights=W), SolverSpec(max_iters=1))


def test_weights_leaf_rejects_nonpositive_raw_arrays():
    """Raw arrays share the Weights.normalized() contract: w1 + w2 <= 0
    raises instead of silently normalizing to inf/NaN."""
    from repro import weights_leaf
    with pytest.raises(ValueError, match="must be positive"):
        weights_leaf(jnp.asarray([0.0, 0.0, 1.0]), jnp.float64)
    with pytest.raises(ValueError, match="must be positive"):
        weights_leaf(jnp.asarray([[0.5, 0.5, 1.0], [-1.0, 0.5, 1.0]]),
                     jnp.float64, cells=2)


def test_region_allocator_rejects_spec_plus_legacy_kwargs():
    from repro import RegionAllocator
    with pytest.raises(ValueError, match="not both"):
        RegionAllocator(W, spec=SolverSpec(), tol=1e-3)
    # either form alone is fine
    RegionAllocator(W, spec=SolverSpec(tol=1e-3))
    RegionAllocator(W, tol=1e-3, max_iters=5)


def test_spec_validates_methods_and_iters():
    with pytest.raises(ValueError, match="sp1_method"):
        SolverSpec(sp1_method="newton")
    with pytest.raises(ValueError, match="sp2_method"):
        SolverSpec(sp2_method="cvx")
    with pytest.raises(ValueError, match="max_iters"):
        SolverSpec(max_iters=-1)
    with pytest.raises(ValueError, match="dtype"):
        SolverSpec(dtype="bfloat16")


def test_spec_is_hashable_and_comparable():
    a = SolverSpec(max_iters=8, tol=1e-4)
    b = SolverSpec(max_iters=8, tol=1e-4)
    assert a == b and hash(a) == hash(b)
    assert len({a, b, SolverSpec()}) == 2


def test_spec_dtype_policy_casts_the_solve():
    sysp = make_system(jax.random.PRNGKey(12), n_devices=6)
    res32 = solve(Problem(system=sysp, weights=W),
                  SolverSpec(max_iters=4, tol=1e-4, dtype="float32"))
    assert res32.allocation.bandwidth.dtype == jnp.float32
    res64 = solve(Problem(system=sysp, weights=W),
                  SolverSpec(max_iters=4, tol=1e-4, dtype="float64"))
    assert res64.allocation.bandwidth.dtype == jnp.float64


# ---------------------------------------------------------------------------
# dispatcher routing errors
# ---------------------------------------------------------------------------

def test_dispatcher_rejects_bad_combinations():
    sysp = make_system(jax.random.PRNGKey(13), n_devices=4)
    fleet = make_fleet(jax.random.PRNGKey(13), n_cells=2, n_devices=4)
    with pytest.raises(ValueError, match="needs problem.key"):
        solve(Problem(system=sysp, weights=W, rounds=RoundsConfig(rounds=2)))
    with pytest.raises(ValueError, match="stacked"):
        solve(Problem(system=sysp, weights=W, mesh=region_mesh()))
    # mesh + deadline used to be NotImplementedError; it now shards the
    # fixed-deadline fleet solve (parity-tested in tests/test_region.py)
    reg = solve(Problem(system=fleet, weights=W, deadline=100.0,
                        mesh=region_mesh()), SolverSpec(max_iters=2))
    assert reg.stats["cells"] == 2
    # a deadline on a single cell still cannot take a mesh
    with pytest.raises(ValueError, match="stacked"):
        solve(Problem(system=sysp, weights=W, deadline=100.0,
                      mesh=region_mesh()))
    with pytest.raises(ValueError, match="cell axis"):
        solve(Problem(system=sysp, weights=[W, W]))
    # a tuned spec on a rounds problem would be silently ignored — reject
    with pytest.raises(ValueError, match="RoundsConfig"):
        solve(Problem(system=sysp, weights=W, rounds=RoundsConfig(rounds=2),
                      key=jax.random.PRNGKey(0)), SolverSpec(max_iters=3))
    # lockstep picks the mesh execution mode; meshless it would no-op
    with pytest.raises(ValueError, match="lockstep"):
        solve(Problem(system=fleet, weights=W), SolverSpec(lockstep=True))


def test_deprecation_warns_exactly_once_per_shim():
    sysp = make_system(jax.random.PRNGKey(14), n_devices=4)
    _reset_deprecation_registry()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        allocate(sysp, W, max_iters=1)
        allocate(sysp, W, max_iters=1)
    dep = [r for r in rec if issubclass(r.category, DeprecationWarning)
           and "allocate()" in str(r.message)]
    assert len(dep) == 1
