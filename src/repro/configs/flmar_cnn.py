"""The paper's own client model: resolution-agnostic CNN standing in for the
modified YOLOv5m of §VII-B (see repro.models.cnn)."""
CONFIG = dict(
    name="flmar-cnn",
    num_classes=8,
    widths=(16, 32, 64),
    base_resolution=32,
    dataset_resolutions=(8, 16, 24, 32),
    source="paper §VII-B / arXiv:2209 (this paper)",
)
