"""Region allocation service demo: a synthetic Poisson request trace
through `repro.region.RegionAllocator`.

A region's cells (base stations) re-request allocations as their channels
drift and their device pools churn. The service:

  * buckets mixed-size pools onto a power-of-two shape menu (masked
    padding), so the whole trace compiles a handful of XLA programs;
  * coalesces concurrent requests into fixed-shape cell batches, sharded
    over the local device mesh (`allocate_region`, shard-local early exit);
  * warm-starts re-requests from an LRU cache of previous solutions —
    a drifted cell re-solves in ~2 BCD iterations instead of a cold ~8+;
  * accepts PER-REQUEST weights: every cell weighs energy/latency/accuracy
    differently (the multi-cell mixed-demand deployments of the
    arXiv:2212.08324 / 2301.12085 follow-ups). Weights are a traced (C, 3)
    operand of the compiled solve, so the mixed-weights trace compiles
    exactly as many shapes as the fixed-weights one.

Acceptance trace: 256 mixed-size, mixed-WEIGHTS requests -> <= 4 distinct
compiled batch shapes, warm-cache hits re-solving in <= 3 BCD iterations.

    # multi-device mesh on one CPU host:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/region_serve.py

REPRO_SMOKE=1 shrinks the trace for CI.
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import SolverSpec, Weights, make_system
from repro.region import AllocationRequest, RegionAllocator, region_mesh

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
RATE = 8.0          # mean requests per service tick (Poisson)
TICKS = 40          # trace length: ~RATE * TICKS total requests
N_CELLS = 12 if SMOKE else 48    # distinct cells in the region
TARGET_REQUESTS = 24 if SMOKE else 256
DRIFT = 0.01        # per-re-request channel drift (fractional)

rng = np.random.default_rng(7)
key = jax.random.PRNGKey(0)

# the region's cell population: mixed pool sizes, 9..500 devices, and a
# mixed demand profile — every cell carries its OWN objective weights
pool_sizes = rng.choice([9, 14, 23, 40, 65, 90, 150, 260, 410, 500],
                        size=N_CELLS)
cells, cell_w = {}, {}
for cid in range(N_CELLS):
    cells[cid] = make_system(jax.random.fold_in(key, cid),
                             n_devices=int(pool_sizes[cid]))
    w1 = float(rng.uniform(0.1, 0.9))            # energy vs latency mix
    cell_w[cid] = Weights(w1, 1.0 - w1, float(rng.uniform(1.0, 30.0)))

mesh = region_mesh()
# tol=1e-4: the serving hot path re-solves against percent-scale channel
# drift, so the solve residual only needs to sit well below that (the same
# calibration as the rounds-dynamics bench). The default 1e-6 would spend
# extra BCD iterations polishing digits the next drift immediately erases.
svc = RegionAllocator(Weights(0.5, 0.5, 1.0),
                      mesh=mesh if mesh.devices.size > 1 else None,
                      cells_per_batch=8, min_bucket=64,
                      spec=SolverSpec(tol=1e-4))
print(f"region: {N_CELLS} cells, pools {pool_sizes.min()}-{pool_sizes.max()} "
      f"devices, per-cell weights, mesh of {mesh.devices.size} device(s)")

served = 0
warm_iters, cold_iters = [], []
t0 = time.time()
for tick in range(TICKS):
    if served >= TARGET_REQUESTS:
        break
    k = min(rng.poisson(RATE), TARGET_REQUESTS - served, N_CELLS)
    for cid in rng.choice(N_CELLS, size=k, replace=False):
        cid = int(cid)
        # channel drift since the last request (AR(1)-ish multiplicative)
        sys_c = cells[cid]
        drift = 1.0 + DRIFT * rng.standard_normal(sys_c.n).astype(
            np.asarray(sys_c.gain).dtype)
        cells[cid] = sys_c.replace(gain=sys_c.gain * jnp.abs(
            jnp.asarray(drift)))
        svc.submit(AllocationRequest(cell_id=cid, sys=cells[cid],
                                     w=cell_w[cid]))
    res = svc.flush()
    served += len(res)
    for r in res.values():
        (warm_iters if r.warm else cold_iters).append(r.iters)
wall = time.time() - t0

shapes = sorted(svc.compiled_shapes)
hit_rate = svc.stats["cache_hits"] / max(svc.stats["requests"], 1)
print(f"\nserved {served} requests in {wall:.1f}s "
      f"({served / wall:.1f} req/s incl. {len(shapes)} compiles)")
print(f"compiled batch shapes (cells x devices): {shapes}")
print(f"warm-cache hit rate: {hit_rate:.0%} "
      f"({svc.stats['cache_hits']}/{svc.stats['requests']})")
if cold_iters:
    print(f"cold solves: {len(cold_iters)}, mean {np.mean(cold_iters):.1f} "
          f"BCD iters")
if warm_iters:
    print(f"warm solves: {len(warm_iters)}, mean {np.mean(warm_iters):.1f} "
          f"BCD iters (max {max(warm_iters)})")

assert len(shapes) <= 4, f"bucketing broke: {len(shapes)} shapes"
if warm_iters:
    assert max(warm_iters) <= 3, f"warm re-solve too slow: {max(warm_iters)}"
print("\nacceptance: <= 4 compiled shapes and warm hits <= 3 BCD iters OK")

# ---------------------------------------------------------------- async
# The same trace, served through the pipeline directly: `submit` returns
# futures, `pump` closes batches per the admission policy (here max-wait)
# and keeps up to 2 batches in flight — batch k+1's host assembly overlaps
# batch k's device compute. Futures resolve out of order, on demand.
from repro.region import MaxWait, RegionPipeline

pipe = RegionPipeline(Weights(0.5, 0.5, 1.0),
                      mesh=mesh if mesh.devices.size > 1 else None,
                      cells_per_batch=8, min_bucket=64,
                      spec=SolverSpec(tol=1e-4),
                      policy=MaxWait(0.02), max_in_flight=2)
n_async = min(TARGET_REQUESTS, 4 * N_CELLS)
futures = []
t0 = time.time()
for i in range(n_async):
    cid = int(rng.integers(N_CELLS))
    futures.append(pipe.submit(AllocationRequest(
        cell_id=cid, sys=cells[cid], w=cell_w[cid])))
    pipe.pump()            # non-blocking: dispatches any closed batches
# consume newest-first — materializing batch k+1 never waits on batch k
for fut in reversed(futures):
    r = fut.result()
    assert r.cell_id == fut.cell_id
pipe.drain()
wall_async = time.time() - t0

print(f"\npipelined: {n_async} requests in {wall_async:.1f}s "
      f"({n_async / wall_async:.1f} req/s), "
      f"{pipe.in_flight} in flight after drain")
clocks = pipe.clocks.as_dict()
print("stage clocks (s): " + ", ".join(
    f"{k[:-2]}={v:.2f}" for k, v in clocks.items()))
assert len(pipe.compiled_shapes) <= 4, pipe.compiled_shapes
assert all(f.done() for f in futures)
print("acceptance: pipelined trace served, <= 4 compiled shapes OK")
