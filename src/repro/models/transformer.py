"""The model stack: scanned layer periods covering every zoo architecture.

A config's `block_pattern` lists the layer kinds in one period (e.g. jamba:
one attention layer among seven mamba layers); parameters for each slot are
STACKED across periods and the stack runs under `jax.lax.scan`, so the lowered
HLO contains one period body regardless of depth — essential for tractable
multi-pod dry-run compiles.

Modes:
  train    — full-seq forward, returns logits (+ MoE aux loss)
  prefill  — same math, serving entry point
  decode   — one token per call against a cache pytree (KV ring buffers for
             sliding-window attention, O(1) states for SSM/RWKV)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (embed_tokens, init_embed, init_mlp,
                                 init_rms_norm, apply_mlp, lm_logits, rms_norm,
                                 softmax_xent)
from repro.sharding.partition import shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_sublayer(kind: str, key: jax.Array, cfg: ModelConfig) -> Params:
    dt = cfg.np_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": init_rms_norm(cfg.d_model),
                 "norm2": init_rms_norm(cfg.d_model)}
    if kind in ("attn", "attn_moe", "enc_attn", "attn_cross"):
        if cfg.attention == "mla" and kind != "enc_attn":
            p["attn"] = attn_lib.init_mla(
                k1, cfg.d_model, cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank,
                cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, dt)
        else:
            p["attn"] = attn_lib.init_attention(
                k1, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim,
                cfg.qkv_bias, dt)
        if kind == "attn_cross":
            p["xattn"] = attn_lib.init_attention(
                jax.random.fold_in(k1, 1), cfg.d_model, cfg.n_heads,
                cfg.n_heads, cfg.head_dim, False, dt)
            p["norm3"] = init_rms_norm(cfg.d_model)
    elif kind in ("mamba", "mamba_moe"):
        p["mamba"] = ssm_lib.init_mamba(k1, cfg.d_model, cfg.d_inner,
                                        cfg.d_state, cfg.d_conv, dtype=dt)
    elif kind == "rwkv":
        p["rwkv"] = {**init_rwkv(k1, cfg)}
    else:
        raise ValueError(f"unknown layer kind {kind}")

    if kind.endswith("_moe"):
        p["moe"] = moe_lib.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
    elif kind != "rwkv":
        p["mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, dt)
    return p


def init_rwkv(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    tm = ssm_lib.init_rwkv_time_mix(k1, cfg.d_model, cfg.n_heads, cfg.head_dim,
                                    dtype=cfg.np_dtype)
    cm = ssm_lib.init_rwkv_channel_mix(k2, cfg.d_model, cfg.d_ff, cfg.np_dtype)
    return {f"tm_{k}": v for k, v in tm.items()} | {f"cm_{k}": v for k, v in cm.items()}


def init_model(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 4)
    params: Params = init_embed(keys[0], cfg.vocab_size, cfg.d_model,
                                cfg.np_dtype, cfg.tied_embeddings)
    params["final_norm"] = init_rms_norm(cfg.d_model)

    def init_period(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return {f"s{i}_{kind}": _init_sublayer(kind, ks[i], cfg)
                for i, kind in enumerate(cfg.block_pattern)}

    pkeys = jax.random.split(keys[1], cfg.n_periods)
    params["layers"] = jax.vmap(init_period)(pkeys)     # stacked over periods

    if cfg.encoder_layers:
        ekeys = jax.random.split(keys[2], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_sublayer("enc_attn", k, cfg))(ekeys)
        params["enc_final_norm"] = init_rms_norm(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    """Cache pytree stacked over periods. Sliding-window attention gets a
    ring buffer of `window` slots; full attention gets `max_seq` slots;
    SSM/RWKV layers carry O(1) state."""
    dt = cfg.np_dtype

    def one_period():
        c: Params = {}
        for i, kind in enumerate(cfg.block_pattern):
            nm = f"s{i}_{kind}"
            if kind in ("attn", "attn_moe", "attn_cross"):
                slots = min(cfg.sliding_window, max_seq) if cfg.sliding_window else max_seq
                if cfg.attention == "mla":
                    c[nm] = attn_lib.init_mla_cache(batch, slots, cfg.kv_lora_rank,
                                                    cfg.qk_rope_dim, dt)
                else:
                    c[nm] = attn_lib.init_kv_cache(batch, slots, cfg.kv_heads,
                                                   cfg.head_dim, dt,
                                                   quantized=cfg.kv_cache_int8)
                if kind == "attn_cross" and cfg.cross_kv_cache:
                    c[nm] = {"self": c[nm],
                             "cross": attn_lib.CrossKV(
                                 xk=jnp.zeros((batch, cfg.encoder_ctx,
                                               cfg.n_heads, cfg.head_dim), dt),
                                 xv=jnp.zeros((batch, cfg.encoder_ctx,
                                               cfg.n_heads, cfg.head_dim), dt))}
            elif kind in ("mamba", "mamba_moe"):
                c[nm] = ssm_lib.init_mamba_cache(batch, cfg.d_inner, cfg.d_state,
                                                 cfg.d_conv, dt)
            elif kind == "rwkv":
                c[nm] = ssm_lib.init_rwkv_cache(batch, cfg.d_model, cfg.n_heads,
                                                cfg.head_dim)
        return c

    proto = one_period()
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape).copy(), proto)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _sublayer(kind: str, p: Params, cfg: ModelConfig, x: jax.Array, *,
              mode: str, cache, pos, enc_out) -> Tuple[jax.Array, Any, jax.Array]:
    """Apply one sublayer. Returns (x, new_cache, aux_loss)."""
    aux = jnp.asarray(0.0, jnp.float32)
    window = cfg.sliding_window

    if kind in ("attn", "attn_moe", "enc_attn", "attn_cross"):
        cross_c = None
        if kind == "attn_cross" and isinstance(cache, dict):
            cross_c, cache = cache.get("cross"), cache.get("self")
        h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
        if cfg.attention == "mla" and kind != "enc_attn":
            o, new_c = attn_lib.mla_attention(
                p["attn"], h, qk_nope_dim=cfg.qk_nope_dim,
                qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
                mode=mode, cache=cache, pos=pos, window=window,
                rope_theta=cfg.rope_theta)
        else:
            o, new_c = attn_lib.attention(
                p["attn"], h, mode=mode, cache=cache, pos=pos,
                window=None if kind == "enc_attn" else window,
                causal=(kind != "enc_attn"),
                rope_theta=cfg.rope_theta,
                use_rope=(kind != "enc_attn"))
        x = x + o
        if kind == "attn_cross":
            h = rms_norm(x, p["norm3"]["scale"], cfg.norm_eps)
            if cross_c is not None:
                o, _ = attn_lib.attention(p["xattn"], h, mode="train",
                                          cross_kv=cross_c, causal=False)
            else:
                o, _ = attn_lib.attention(p["xattn"], h, mode="train",
                                          kv_x=enc_out, causal=False)
            x = x + o
        h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        if kind.endswith("_moe"):
            o, aux = moe_lib.apply_moe(p["moe"], h, cfg.top_k, cfg.capacity_factor)
        else:
            o = apply_mlp(p["mlp"], h)
        if cross_c is not None:
            return x + o, {"self": new_c, "cross": cross_c}, aux
        return x + o, new_c, aux

    if kind in ("mamba", "mamba_moe"):
        h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
        o, new_c = ssm_lib.mamba(p["mamba"], h, mode=mode, cache=cache,
                                 chunk=cfg.ssm_chunk)
        x = x + o
        h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        if kind.endswith("_moe"):
            o, aux = moe_lib.apply_moe(p["moe"], h, cfg.top_k, cfg.capacity_factor)
        else:
            o = apply_mlp(p["mlp"], h)
        return x + o, new_c, aux

    if kind == "rwkv":
        rp = p["rwkv"]
        tm = {k[3:]: v for k, v in rp.items() if k.startswith("tm_")}
        cm = {k[3:]: v for k, v in rp.items() if k.startswith("cm_")}
        h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
        o, state, x_tm = ssm_lib.rwkv_time_mix(
            tm, h, n_heads=cfg.n_heads, head_dim=cfg.head_dim, mode=mode,
            cache=cache, chunk=cfg.rwkv_chunk)
        x = x + o
        h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        o, x_cm = ssm_lib.rwkv_channel_mix(
            cm, h, mode=mode,
            x_prev=cache.x_cm if (mode == "decode" and cache is not None) else None)
        x = x + o
        new_c = ssm_lib.RWKVCache(state=state, x_tm=x_tm.astype(jnp.bfloat16),
                                  x_cm=x_cm.astype(jnp.bfloat16)) \
            if state is not None else None
        return x, new_c, aux

    raise ValueError(kind)


def _encoder_forward(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    x = frames.astype(cfg.np_dtype)
    pos = jnp.arange(x.shape[1])
    # sinusoidal positions (frontend conv/pos-emb stubbed per spec)
    half = cfg.d_model // 2
    freqs = jnp.exp(-jnp.arange(half) / max(half - 1, 1) * jnp.log(10000.0))
    pe = jnp.concatenate([jnp.sin(pos[:, None] * freqs), jnp.cos(pos[:, None] * freqs)], -1)
    x = x + pe[None].astype(x.dtype)

    def body(x, layer_p):
        x, _, _ = _sublayer("enc_attn", layer_p, cfg, x, mode="train",
                            cache=None, pos=None, enc_out=None)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"]["scale"], cfg.norm_eps)


def model_forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  *, mode: str = "train",
                  cache: Optional[Params] = None,
                  pos: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Returns (logits, aux_loss, new_cache).

    batch: {"tokens": (B,S)} plus optional "frame_embeds" (audio) /
    "patch_embeds" (vlm, prepended to the token embeddings).
    """
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens).astype(cfg.np_dtype)

    enc_out = None
    if "enc_out" in batch:                # precomputed (cross_kv_cache path)
        enc_out = batch["enc_out"]
    elif cfg.encoder_layers and "frame_embeds" in batch:
        enc_out = _encoder_forward(params, cfg, batch["frame_embeds"])
    if cfg.n_patches and "patch_embeds" in batch and mode != "decode":
        pe = batch["patch_embeds"].astype(cfg.np_dtype)
        x = jnp.concatenate([pe, x], axis=1)
    x = shard(x, "batch", "seq", "embed_act")

    def period_body(carry, xs):
        xx, aux = carry
        layer_p, layer_c = xs
        # Megatron-style sequence parallelism on the residual stream: the
        # scan-saved carry (dominant train-memory term) shards seq over
        # 'model'; blocks gather/reduce-scatter around it (GSPMD-inserted).
        xx = shard(xx, "batch", "seq_outer", "embed_act")
        new_cs = {}
        for i, kind in enumerate(cfg.block_pattern):
            nm = f"s{i}_{kind}"
            c_in = layer_c[nm] if layer_c is not None else None
            xx, c_out, a = _sublayer(kind, layer_p[nm], cfg, xx, mode=mode,
                                     cache=c_in, pos=pos, enc_out=enc_out)
            new_cs[nm] = c_out if c_out is not None else c_in
            aux = aux + a
        return (xx, aux), new_cs

    if cfg.remat and mode == "train":
        if cfg.remat_policy == "dots":
            period_body = jax.checkpoint(
                period_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            period_body = jax.checkpoint(period_body)

    if cache is not None:
        (x, aux), new_cache = jax.lax.scan(
            period_body, (x, jnp.asarray(0.0, jnp.float32)),
            (params["layers"], cache))
    else:
        def body_nocache(carry, layer_p):
            out, cs = period_body(carry, (layer_p, None))
            return out, None
        (x, aux), _ = jax.lax.scan(
            body_nocache, (x, jnp.asarray(0.0, jnp.float32)), params["layers"])
        new_cache = None

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = lm_logits(params, x)
    return logits, aux, new_cache


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def prepare_cross_cache(params: Params, cfg: ModelConfig, cache: Params,
                        frame_embeds: jax.Array) -> Tuple[Params, jax.Array]:
    """Run the encoder ONCE and fill every attn_cross layer's CrossKV entry.
    Returns (cache, enc_out). This is the admission-time step that makes
    per-token decode encoder-free (EXPERIMENTS.md §Perf, whisper hillclimb)."""
    assert cfg.cross_kv_cache, "enable cfg.cross_kv_cache"
    enc_out = _encoder_forward(params, cfg, frame_embeds)

    def fill(layer_p):
        out = {}
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "attn_cross":
                out[f"s{i}_{kind}"] = attn_lib.make_cross_kv(
                    layer_p[f"s{i}_{kind}"]["xattn"], enc_out)
        return out

    cross = jax.vmap(fill)(params["layers"])          # stacked over periods
    new_cache = dict(cache)
    for i, kind in enumerate(cfg.block_pattern):
        nm = f"s{i}_{kind}"
        if kind == "attn_cross":
            entry = dict(cache[nm])
            entry["cross"] = cross[nm]
            new_cache[nm] = entry
    return new_cache, enc_out


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            aux_weight: float = 0.01) -> jax.Array:
    logits, aux, _ = model_forward(params, cfg, batch, mode="train")
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    if cfg.n_patches and "patch_embeds" in batch:
        # logits cover [patches | text]; train only on text positions
        logits = logits[:, cfg.n_patches:]
    loss = softmax_xent(logits, labels, mask)
    return loss + aux_weight * aux


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Params) -> Tuple[jax.Array, Params]:
    """Block prefill: one full-sequence forward that also fills the decode
    cache (attention K/V slots, SSM/RWKV states). Returns (logits, cache).
    Continue with serve_step(..., pos=prompt_len). SSM archs require
    prompt_len % cfg.ssm_chunk == 0 (state handoff)."""
    logits, _, new_cache = model_forward(params, cfg, batch, mode="prefill",
                                         cache=cache)
    return logits, new_cache


def serve_step(params: Params, cfg: ModelConfig, cache: Params,
               token: jax.Array, pos: jax.Array,
               extras: Optional[Dict[str, jax.Array]] = None
               ) -> Tuple[jax.Array, Params]:
    """One decode step: token (B,) at absolute position `pos` -> (logits (B,V),
    new_cache). `extras` carries encoder outputs for enc-dec models."""
    batch = {"tokens": token[:, None]}
    if extras:
        batch.update(extras)
    logits, _, new_cache = model_forward(params, cfg, batch, mode="decode",
                                         cache=cache, pos=pos)
    return logits[:, 0], new_cache
