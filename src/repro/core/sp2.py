"""Subproblem 2 (paper §V-B/C, Appendix D): optimize (p, B) given (f, s, T).

    min_{p,B} w1 Rg sum_n p_n d_n / G_n(p_n, B_n)
    s.t. sum B_n <= B, 0 <= B_n, pmin <= p_n <= pmax,
         G_n(p_n, B_n) >= r_n^min = d_n / (T - T_cmp_n)

Sum-of-ratios program solved with Jong's parametric transform (Theorem 1):
introduce (nu, beta) and iterate the damped Newton-like update of Algorithm 1
(eqs. 24-30) around an exact solve of the convex subtractive-form problem

    SP2_v2: min_{p,B} sum_n nu_n (p_n d_n - beta_n G_n(p_n, B_n))   (eq. 22)

The paper solves SP2_v2 with CVX, supported by the Theorem-2 closed forms.
We solve it EXACTLY without a generic solver, exploiting separability:

  * inner-inner: for fixed B_n, the optimal power is the stationary point
        p_int = (Lambda0_n - 1) N0 B_n / g_n,  Lambda0_n = beta_n g_n/(N0 d_n ln2)
    (eq. A.16 with tau=0) clipped to [max(pmin, p_rate(B)), pmax], where
    p_rate enforces the rate constraint (21a);
  * per-device: h_n(B) = nu_n (p*(B) d_n - beta_n G(p*(B), B)) is convex
    (partial minimization of a jointly convex function) and strictly
    decreasing, minimized by golden-section;
  * budget: the bandwidth cap binds; a bisection on its multiplier mu
    (exactly the mu of A.15) waterfills sum B_n = B.

`solve_sp2_v2_thm2` keeps the paper's literal Appendix-D path (Lambert-W dual
A.22/A.23, Theorem-2 closed forms) — used as a cross-check in tests; it agrees
with the exact solver whenever all rate constraints are tight.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .lambertw import lambertw0
from .types import SystemParams, Weights

Array = jnp.ndarray

_GOLD = 0.6180339887498949


def G(sys: SystemParams, p: Array, B: Array) -> Array:
    """G_n(p,B) = B log2(1 + g p / (N0 B)) — the rate (eq. 1), concave (Lemma 1)."""
    b = jnp.maximum(B, 1e-12)
    return b * jnp.log2(1.0 + sys.gain * p / (sys.noise_psd * b))


def r_min(sys: SystemParams, freq: Array, resolution: Array, T_round: Array) -> Array:
    """r_n^min = d_n / (T - R_l zeta s^2 c D / f)   (§V-B)."""
    t_cmp = sys.local_iters * sys.zeta * resolution ** 2 * sys.cycles * sys.samples \
        / jnp.maximum(freq, 1e-9)
    slack = jnp.maximum(T_round - t_cmp, 1e-9)
    return sys.bits / slack


def _clamp_rmin(sys: SystemParams, rmin: Array) -> Array:
    """Rates above the infinite-bandwidth asymptote g pmax/(N0 ln2) are
    unattainable at any bandwidth; clamp with margin (deadline soft-missed)."""
    asym = sys.gain * sys.p_max / (sys.noise_psd * jnp.log(2.0))
    return jnp.minimum(rmin, 0.95 * asym)


def _search_iters(dtype, f32_iters: int = 34, f64_iters: int = 56) -> int:
    """Iteration count for bracketing searches, matched to the compute dtype:
    past ~34 golden / ~30 bisection steps an f32 bracket is already below one
    ulp of its endpoints, so the f64 count just burns flops at fleet scale."""
    return f32_iters if jnp.dtype(dtype).itemsize <= 4 else f64_iters


def _mask_box(sys: SystemParams, b_lo: Array, b_hi: Array):
    """Collapse padded-out devices' bandwidth box to [0, 0]: their rate floor
    is 0 (zero bits) but `_b_min`'s bisection still leaves a ~1e-3 Hz crumb,
    and the clipped-power branch of dE/dB is negative, so unmasked pad lanes
    would both perturb the budget reductions and *attract* bandwidth in the
    dual search. With a [0, 0] box every inner bisection pins them at exactly
    0, which is neutral (bit-exact) in all the sum reductions."""
    if sys.active is None:
        return b_lo, b_hi
    zero = jnp.zeros((), b_lo.dtype)
    return (jnp.where(sys.active, b_lo, zero),
            jnp.where(sys.active, b_hi, zero))


def _b_min(sys: SystemParams, rmin: Array, iters: int | None = None) -> Array:
    """Smallest bandwidth at which G(pmax, B) >= rmin (G increasing in B)."""
    from jax import lax

    if iters is None:
        iters = _search_iters(rmin.dtype, f32_iters=30)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = G(sys, jnp.broadcast_to(jnp.asarray(sys.p_max, rmin.dtype),
                                     rmin.shape), mid) >= rmin
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo0 = jnp.full_like(rmin, 1e-3)
    hi0 = jnp.broadcast_to(jnp.asarray(sys.bandwidth_total, rmin.dtype),
                           rmin.shape)
    _, hi = lax.fori_loop(0, iters, body, (lo0, hi0))
    return hi


def _p_star(sys: SystemParams, beta: Array, rmin: Array, B: Array) -> Array:
    """Optimal power for fixed B in SP2_v2 (A.16 clipped to box & rate)."""
    N0, g, d = sys.noise_psd, sys.gain, sys.bits
    # denominator guard for padded lanes (d = 0): 0/0 here would hand the
    # BCD a NaN power whose NaN transmission time then poisons the *active*
    # lanes through SP1's max-reduction bounds. Real devices have
    # N0 d ln2 ~ 1e-16 >> tiny, so the guard is bit-exact for them.
    denom = jnp.maximum(N0 * d * jnp.log(2.0),
                        jnp.finfo(jnp.asarray(B).dtype).tiny)
    lam0 = beta * g / denom
    p_int = jnp.maximum(lam0 - 1.0, 0.0) * N0 * B / g
    theta_req = jnp.exp2(rmin / jnp.maximum(B, 1e-9)) - 1.0
    p_rate = theta_req * N0 * B / g
    return jnp.clip(p_int, jnp.maximum(sys.p_min, p_rate), sys.p_max)


def _h(sys: SystemParams, nu: Array, beta: Array, rmin: Array, B: Array) -> Array:
    """Per-device SP2_v2 objective h_n(B) after minimizing over p."""
    p = _p_star(sys, beta, rmin, B)
    return nu * (p * sys.bits - beta * G(sys, p, B))


def _golden_argmin(fn, lo: Array, hi: Array, iters: int | None = None) -> Array:
    """Memoized golden-section: the surviving interior point is reused, so
    each iteration evaluates `fn` exactly once (the textbook invariant; the
    naive two-evals-per-step variant doubles the dominant SP2 cost at fleet
    scale). Iteration count defaults to the dtype-matched `_search_iters`."""
    from jax import lax

    if iters is None:
        iters = _search_iters(jnp.asarray(lo).dtype)

    c0 = hi - _GOLD * (hi - lo)
    d0 = lo + _GOLD * (hi - lo)

    def body(_, carry):
        a, b, c, d, fc, fd = carry
        left = fc < fd                      # keep [a, d] else [c, b]
        a2 = jnp.where(left, a, c)
        b2 = jnp.where(left, d, b)
        # the surviving interior point becomes the far probe of the new
        # bracket; only the near probe is fresh
        c2 = jnp.where(left, b2 - _GOLD * (b2 - a2), d)
        d2 = jnp.where(left, c, a2 + _GOLD * (b2 - a2))
        x_new = jnp.where(left, c2, d2)
        f_new = fn(x_new)
        fc2 = jnp.where(left, f_new, fd)
        fd2 = jnp.where(left, fc, f_new)
        return a2, b2, c2, d2, fc2, fd2

    a, b, _, _, _, _ = lax.fori_loop(0, iters, body,
                                     (lo, hi, c0, d0, fn(c0), fn(d0)))
    return 0.5 * (a + b)


@jax.jit
def _sp2_v2_impl(sys: SystemParams, nu: Array, beta: Array,
                 rmin: Array) -> Tuple[Array, Array]:
    from jax import lax

    rmin = _clamp_rmin(sys, rmin)
    b_lo = _b_min(sys, rmin)
    b_lo, _ = _mask_box(sys, b_lo, b_lo)
    # if the rate floors alone exceed the budget the deadline is infeasible;
    # scale them to fit (best effort) so the dual search terminates.
    fit = jnp.minimum(1.0, 0.999 * sys.bandwidth_total / jnp.maximum(jnp.sum(b_lo), 1e-30))
    b_lo = b_lo * fit
    b_hi = jnp.maximum(jnp.broadcast_to(jnp.asarray(sys.bandwidth_total,
                                                    b_lo.dtype), b_lo.shape),
                       b_lo)
    b_lo, b_hi = _mask_box(sys, b_lo, b_hi)

    def B_of_mu(mu):
        return _golden_argmin(
            lambda B: _h(sys, nu, beta, rmin, B) + mu * B, b_lo, b_hi)

    def sum_B(mu):
        return jnp.sum(B_of_mu(mu))

    # h is strictly decreasing => the cap binds; find the multiplier mu (A.15).
    def expand(carry):
        mu_hi, _, i = carry
        return mu_hi * 8.0, sum_B(mu_hi * 8.0), i + 1

    def expand_cond(carry):
        mu_hi, s, i = carry
        return (s >= sys.bandwidth_total) & (i < 200)

    # mu literals pinned to the box dtype: a weak-f64 0.0 would promote the
    # golden/bisection carries (and ultimately the BCD state) out of an f32
    # system's dtype under x64
    mu_hi0 = jnp.asarray(1e-12, b_lo.dtype)
    mu_hi, _, _ = lax.while_loop(expand_cond, expand,
                                 (mu_hi0, sum_B(mu_hi0), jnp.asarray(0)))

    def bis(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        over = sum_B(mid) > sys.bandwidth_total
        return jnp.where(over, mid, lo), jnp.where(over, hi, mid)

    mu_lo, mu_hi = lax.fori_loop(0, _search_iters(b_lo.dtype, f32_iters=30),
                                 bis, (jnp.asarray(0.0, b_lo.dtype), mu_hi))
    B_opt = B_of_mu(mu_hi)  # the feasible end of the bracket

    # exact budget: scale surplus above the rate floors
    total = jnp.sum(B_opt)
    surplus = jnp.maximum(B_opt - b_lo, 0.0)
    need = total - sys.bandwidth_total
    scale = 1.0 - need / jnp.maximum(jnp.sum(surplus), 1e-30)
    B_shrunk = b_lo + surplus * jnp.clip(scale, 0.0, 1.0)
    B_opt = jnp.where(total > sys.bandwidth_total, B_shrunk,
                      B_opt * (sys.bandwidth_total / jnp.maximum(total, 1e-30)))
    p_opt = _p_star(sys, beta, rmin, B_opt)
    return p_opt, B_opt


def solve_sp2_v2(sys: SystemParams, w: Weights, nu: Array, beta: Array,
                 rmin: Array) -> Tuple[Array, Array]:
    """Exact solve of SP2_v2 via separable waterfilling. -> (p, B)."""
    return _sp2_v2_impl(sys, nu, beta, rmin)


# ----------------------------------------------------------------------------
# Beyond-paper: exact direct solve of SP2 (DESIGN.md §5, EXPERIMENTS.md §Perf)
#
# Because the per-device energy E(p) = p d / G(p, B) is strictly increasing in
# p, the optimal power always sits on the boundary: p* = max(pmin, p_rate(B)).
# Substituting it, E_n(B) = max(E_rate(B), E_pmin(B)) is the max of two convex
# decreasing functions, hence convex — SP2 collapses to a separable convex
# program over B with one budget constraint, solved EXACTLY by waterfilling.
# This yields the global optimum of SP2 directly (no parametric outer loop)
# and doubles as a correctness oracle for the paper-faithful Algorithm 1.
# ----------------------------------------------------------------------------

def _p_rate(sys: SystemParams, rmin: Array, B: Array) -> Array:
    """Power that makes the rate constraint tight at bandwidth B."""
    theta_req = jnp.exp2(rmin / jnp.maximum(B, 1e-9)) - 1.0
    return theta_req * sys.noise_psd * B / sys.gain


def _energy_of_B(sys: SystemParams, rmin: Array, B: Array) -> Array:
    """E_n(B) = p*(B) d / G(p*(B), B) with p* = max(pmin, p_rate(B))."""
    p = jnp.clip(_p_rate(sys, rmin, B), sys.p_min, sys.p_max)
    return p * sys.bits / jnp.maximum(G(sys, p, B), 1e-12)


def _denergy_dB(sys: SystemParams, rmin: Array, B: Array) -> Array:
    """dE_n/dB for E_n(B) = p~(B) d / G(p~(B), B), p~ = clip(p_rate, pmin,
    pmax) — the exact subdifferential selector for the waterfilling below.

    Piecewise (the same regimes as `_energy_of_B`):
      * rate branch (pmin <= p_rate <= pmax, G == rmin exactly):
          E = (2^x - 1) N0 B d / (g rmin), x = rmin/B
          dE/dB = (N0 d / (g rmin)) (2^x (1 - x ln2) - 1)        < 0
      * clipped branch (p = pc in {pmin, pmax} constant):
          dE/dB = -pc d G'(pc, B) / G(pc, B)^2,
          G' = (ln(1+t) - t/(1+t)) / ln2, t = g pc / (N0 B)      < 0
    """
    N0, g, d = sys.noise_psd, sys.gain, sys.bits
    ln2 = jnp.log(2.0)
    Bs = jnp.maximum(B, 1e-12)
    x = rmin / Bs
    ex = jnp.exp2(x)
    p_rate = (ex - 1.0) * N0 * Bs / g
    dE_rate = (N0 * d / (g * jnp.maximum(rmin, 1e-30))) \
        * (ex * (1.0 - x * ln2) - 1.0)
    pc = jnp.where(p_rate < sys.p_min, sys.p_min, sys.p_max)
    t = g * pc / (N0 * Bs)
    L = jnp.log1p(t)
    Gc = Bs * L / ln2
    Gp = (L - t / (1.0 + t)) / ln2
    dE_clip = -pc * d * Gp / jnp.maximum(Gc, 1e-12) ** 2
    on_rate = (p_rate >= sys.p_min) & (p_rate <= sys.p_max)
    return jnp.where(on_rate, dE_rate, dE_clip)


def _denergy2_dB2(sys: SystemParams, rmin: Array, B: Array) -> Array:
    """d^2E_n/dB^2 for the boundary-power energy `_energy_of_B` — the exact
    curvature of both branches of `_denergy_dB` (strictly positive: E is
    convex on each branch, which is what makes the Newton dual search and
    the implicit-gradient arrowhead solves below well-posed).

      * rate branch:    E'' = (N0 d / (g rmin)) 2^x (x ln2)^2 / B,  x = rmin/B
      * clipped branch: E'' = pc d (2 G'^2 - G'' G) / G^3,
                        G'' = -t^2 / (ln2 B (1+t)^2),  t = g pc / (N0 B)

    Used by the rtsafe-style Newton acceleration of `_sp2_direct_impl` and
    as the interior-lane curvature in `repro.diff.implicit`'s KKT
    linearization (parity-tested against `jax.grad` of `_denergy_dB`)."""
    N0, g, d = sys.noise_psd, sys.gain, sys.bits
    ln2 = jnp.log(2.0)
    Bs = jnp.maximum(B, 1e-12)
    x = rmin / Bs
    ex = jnp.exp2(x)
    p_rate = (ex - 1.0) * N0 * Bs / g
    d2_rate = (N0 * d / (g * jnp.maximum(rmin, 1e-30))) \
        * ex * (x * ln2) ** 2 / Bs
    pc = jnp.where(p_rate < sys.p_min, sys.p_min, sys.p_max)
    t = g * pc / (N0 * Bs)
    L = jnp.log1p(t)
    Gc = jnp.maximum(Bs * L / ln2, 1e-12)
    Gp = (L - t / (1.0 + t)) / ln2
    Gpp = -t ** 2 / (ln2 * Bs * (1.0 + t) ** 2)
    d2_clip = pc * d * (2.0 * Gp ** 2 - Gpp * Gc) / Gc ** 3
    on_rate = (p_rate >= sys.p_min) & (p_rate <= sys.p_max)
    return jnp.where(on_rate, d2_rate, d2_clip)


def sp2_stationarity(sys: SystemParams, rmin: Array, B: Array,
                     mu: Array) -> Array:
    """Per-lane KKT stationarity residual of the direct SP2 waterfilling:
    psi_n = dE_n/dB(B_n) + mu (zero on interior lanes at the optimum;
    positive when a lane is pinned at its rate floor b_min). Exported for
    `repro.diff.implicit`, which linearizes this residual (with the
    curvature `_denergy2_dB2`) to backpropagate through the SP2 solve."""
    return _denergy_dB(sys, _clamp_rmin(sys, rmin), B) + mu


def direct_eval_counts(dtype) -> int:
    """dE/dB evaluations per `solve_sp2_direct` dual search on the
    non-carried REFERENCE path (static): outer mu steps x inner
    phi'-bisection depth + the final polish. The carried-bracket path's
    count is data-dependent (certainty early-exit); `_sp2_direct_impl`
    returns it as its third output, and the BCD ledger surfaces it in the
    `sp2_iters` column — the bench artifact reports measured/reference."""
    outer = _search_iters(dtype, f32_iters=36)
    inner = _search_iters(dtype, f32_iters=24, f64_iters=48)
    return outer * inner + inner + 1   # +1: the mu_hi bracket-sizing eval


@partial(jax.jit, static_argnames=("carry_bracket", "newton"))
def _sp2_direct_impl(sys: SystemParams, rmin: Array,
                     carry_bracket: bool = True, newton: bool = True
                     ) -> Tuple[Array, Array, Array]:
    from jax import lax

    rmin = _clamp_rmin(sys, rmin)
    b_lo = _b_min(sys, rmin)
    b_lo, _ = _mask_box(sys, b_lo, b_lo)
    fit = jnp.minimum(1.0, 0.999 * sys.bandwidth_total / jnp.maximum(jnp.sum(b_lo), 1e-30))
    b_lo = b_lo * fit          # infeasible deadline -> best-effort floors
    b_hi = jnp.maximum(jnp.broadcast_to(jnp.asarray(sys.bandwidth_total,
                                                    b_lo.dtype), b_lo.shape),
                       b_lo)
    b_lo, b_hi = _mask_box(sys, b_lo, b_hi)
    inner = _search_iters(b_lo.dtype, f32_iters=24, f64_iters=48)
    # reference per-lane precision: `inner` halvings of the full box
    w_stop = (b_hi - b_lo) * (2.0 ** -inner)

    def bisect_step(mu, lo, hi):
        # one sign-bisection step on the convex phi(B) = E(B) + mu B
        # (E convex => phi' nondecreasing; converges to the kink when the
        # subdifferential straddles 0 there). One transcendental pair per
        # step vs the former golden section's value evaluations.
        mid = 0.5 * (lo + hi)
        pos = _denergy_dB(sys, rmin, mid) + mu >= 0.0
        return jnp.where(pos, lo, mid), jnp.where(pos, mid, hi)

    def bisect_B(mu, lo, hi, iters):
        # fixed-depth variant (the reference path's inner search); returns
        # the final interval, which still brackets the box-clipped root
        return lax.fori_loop(0, iters,
                             lambda _, c: bisect_step(mu, *c), (lo, hi))

    def search_B_newton(mu, lo, hi, x, ev, decide: bool):
        # rtsafe-style safeguarded Newton on the smooth branches of the
        # stationarity psi(B) = dE/dB(B) + mu, with the sign-bisection as
        # the fallback whenever the Newton candidate leaves the bracket
        # (at the rate/clipped-branch kink psi jumps, so the candidate
        # aims past it and the midpoint takes over — degrading to exactly
        # the safeguarded bisection). Every iteration evaluates the fused
        # (psi, psi') pair once per lane, counted once in `ev` like the
        # bisection's dE/dB eval. A lane converges when its accepted step
        # falls below the reference precision `w_stop`; its bracket then
        # collapses to the iterate, so the width-based exit, the certainty
        # sums and the final midpoint all see the Newton root.
        def cond(c):
            lo, hi, _, it = c
            undecided = jnp.any(hi - lo > w_stop) & (it < inner)
            if decide:
                sure = (jnp.sum(hi) < sys.bandwidth_total) \
                    | (jnp.sum(lo) > sys.bandwidth_total)
                return undecided & (~sure)
            return undecided

        def body(c):
            lo, hi, x, it = c
            psi = _denergy_dB(sys, rmin, x) + mu
            dpsi = jnp.maximum(_denergy2_dB2(sys, rmin, x),
                               jnp.finfo(x.dtype).tiny)
            pos = psi >= 0.0
            lo2 = jnp.where(pos, lo, x)
            hi2 = jnp.where(pos, x, hi)
            xn = x - psi / dpsi
            good = (xn > lo2) & (xn < hi2)
            x2 = jnp.where(good, xn, 0.5 * (lo2 + hi2))
            # converge a factor below the bisection's terminal precision:
            # the collapse pins the lane at the iterate, so its residual
            # error must sit well under the reference path's w_stop for
            # the 1e-6 objective-parity contract to hold at fleet sizes
            conv = jnp.abs(x2 - x) <= 0.125 * w_stop
            return (jnp.where(conv, x2, lo2), jnp.where(conv, x2, hi2),
                    x2, it + 1)

        lo, hi, x, it = lax.while_loop(
            cond, body, (lo, hi, jnp.clip(x, lo, hi),
                         jnp.zeros((), jnp.int32)))
        return lo, hi, x, ev + it

    def search_B(mu, lo, hi, ev, decide: bool):
        # carried-bracket inner search: bisect until (a) every lane reaches
        # the reference precision `w_stop`, or (b) with `decide`, the
        # interval SUMS already settle the budget predicate — i.e.
        # sum(hi) < B_total or sum(lo) > B_total brackets the true
        # sum B*(mu) strictly on one side, so the mu decision is certain
        # and further sharpening is wasted. During the mu search's long
        # exponent-descent phase (mu >> mu*, interval still the full box —
        # only the B *floor* tightens while `over` stays False) this exits
        # in a handful of steps instead of the full depth. `ev` counts
        # dE/dB evaluations (the bench artifact's measured eval count).
        def cond(c):
            lo, hi, it = c
            undecided = jnp.any(hi - lo > w_stop) & (it < inner)
            if decide:
                sure = (jnp.sum(hi) < sys.bandwidth_total) \
                    | (jnp.sum(lo) > sys.bandwidth_total)
                return undecided & (~sure)
            return undecided

        def body(c):
            lo, hi, it = c
            lo, hi = bisect_step(mu, lo, hi)
            return lo, hi, it + 1

        lo, hi, it = lax.while_loop(cond, body,
                                    (lo, hi, jnp.zeros((), jnp.int32)))
        return lo, hi, ev + it

    # The budget multiplier needs no bracket expansion: at
    # mu_hi = max_n -E_n'(b_lo) every device's phi' is nonnegative on the
    # whole box, so B(mu_hi) == b_lo and sum b_lo <= 0.999 B (by `fit`).
    # Padded lanes (box [0,0]) are excluded from the max — their clipped
    # branch slope is an arbitrary negative number.
    neg_slope = -_denergy_dB(sys, rmin, b_lo)
    if sys.active is not None:
        neg_slope = jnp.where(sys.active, neg_slope,
                              jnp.zeros((), b_lo.dtype))
    mu_hi = jnp.maximum(jnp.max(neg_slope), 1e-30) * (1.0 + 1e-3)
    outer = _search_iters(b_lo.dtype, f32_iters=36)
    mu_lo0 = jnp.asarray(0.0, b_lo.dtype)
    ev0 = jnp.ones((), jnp.int32)   # the mu_hi sizing evaluation

    if carry_bracket and newton:
        # Newton-accelerated carried path: same monotone (Blo, Bhi) bracket
        # carry as below, plus the previous inner search's iterate carried
        # as the next search's warm start — consecutive mu steps move B*
        # little, so warm-started Newton typically lands in a couple of
        # fused (psi, psi') evaluations where the bisection pays its full
        # certainty-exit depth.
        def bis(_, c):
            mu_lo, mu_up, Blo, Bhi, Bx, ev = c
            mid = 0.5 * (mu_lo + mu_up)
            lo2, hi2, x2, ev = search_B_newton(mid, Blo, Bhi, Bx, ev,
                                               decide=True)
            over = jnp.sum(0.5 * (lo2 + hi2)) > sys.bandwidth_total
            return (jnp.where(over, mid, mu_lo), jnp.where(over, mu_up, mid),
                    jnp.where(over, Blo, lo2),   # mu ceiling fell: floor up
                    jnp.where(over, hi2, Bhi),   # mu floor rose: ceiling dn
                    x2, ev)

        _, mu, Blo, Bhi, Bx, ev = lax.fori_loop(
            0, outer, bis,
            (mu_lo0, mu_hi, b_lo, b_hi, 0.5 * (b_lo + b_hi), ev0))
        lo_f, hi_f, _, ev = search_B_newton(mu, Blo, Bhi, Bx, ev,
                                            decide=False)
        B_opt = 0.5 * (lo_f + hi_f)
    elif carry_bracket:
        # B*(mu) is componentwise nonincreasing, so the mu interval
        # [mu_lo, mu_hi] always pins B*(mu) inside [B*(mu_hi), B*(mu_lo)]:
        # carry those bounds as (Blo, Bhi) and tighten the side whose mu
        # endpoint just moved with the freshly bisected interval. The
        # endpoint updates are valid regardless of how early the inner
        # search exited (lo2/hi2 always bracket B*(mid)), so the certainty
        # exit never loosens the invariant.
        def bis(_, c):
            mu_lo, mu_up, Blo, Bhi, ev = c
            mid = 0.5 * (mu_lo + mu_up)
            lo2, hi2, ev = search_B(mid, Blo, Bhi, ev, decide=True)
            over = jnp.sum(0.5 * (lo2 + hi2)) > sys.bandwidth_total
            return (jnp.where(over, mid, mu_lo), jnp.where(over, mu_up, mid),
                    jnp.where(over, Blo, lo2),   # mu ceiling fell: floor up
                    jnp.where(over, hi2, Bhi),   # mu floor rose: ceiling dn
                    ev)

        _, mu, Blo, Bhi, ev = lax.fori_loop(
            0, outer, bis, (mu_lo0, mu_hi, b_lo, b_hi, ev0))
        lo_f, hi_f, ev = search_B(mu, Blo, Bhi, ev, decide=False)
        B_opt = 0.5 * (lo_f + hi_f)
    else:
        # reference path (parity oracle for the carried bracket): every mu
        # step re-bisects the full [b_lo, b_hi] box at full depth
        def bis(_, carry):
            lo, hi, ev = carry
            mid = 0.5 * (lo + hi)
            blo, bhi = bisect_B(mid, b_lo, b_hi, inner)
            over = jnp.sum(0.5 * (blo + bhi)) > sys.bandwidth_total
            return (jnp.where(over, mid, lo), jnp.where(over, hi, mid),
                    ev + inner)

        _, mu, ev = lax.fori_loop(0, outer, bis, (mu_lo0, mu_hi, ev0))
        lo_f, hi_f = bisect_B(mu, b_lo, b_hi, inner)
        ev = ev + inner
        B_opt = 0.5 * (lo_f + hi_f)

    total = jnp.sum(B_opt)
    surplus = jnp.maximum(B_opt - b_lo, 0.0)
    scale = 1.0 - (total - sys.bandwidth_total) / jnp.maximum(jnp.sum(surplus), 1e-30)
    B_opt = jnp.where(total > sys.bandwidth_total,
                      b_lo + surplus * jnp.clip(scale, 0.0, 1.0), B_opt)
    p_opt = jnp.clip(_p_rate(sys, rmin, B_opt), sys.p_min, sys.p_max)
    return p_opt, B_opt, ev


def solve_sp2_direct(sys: SystemParams, rmin: Array,
                     carry_bracket: bool = True,
                     newton: bool = True) -> Tuple[Array, Array]:
    """Globally exact SP2 solve via the boundary-power reformulation.

    carry_bracket=True (default) reuses the monotone-in-mu B bracket across
    consecutive budget-bisection steps and exits each inner phi'-bisection
    as soon as its interval sums settle the budget predicate, cutting the
    dE/dB evaluation count several-fold at unchanged decision accuracy
    (measured count in the BCD ledger's `sp2_iters` column; reference count
    in `direct_eval_counts`). False keeps the full re-bisection per mu step
    as the parity oracle (objective agreement <= 1e-6, tested).

    newton=True (default) additionally warm-starts a safeguarded Newton
    iteration on the smooth pmin/rate branches of the stationarity inside
    each carried inner search (`_denergy2_dB2` curvature, sign-bisection
    fallback at the branch kink); only the carried path is accelerated —
    the reference path stays pure bisection as the parity oracle."""
    p, B, _ = _sp2_direct_impl(sys, rmin, carry_bracket, newton)
    return p, B


def _thm2_dual_mu(sys: SystemParams, j: Array, rmin: Array,
                  n_mu: int = 128, refine: int = 3) -> Array:
    """Root of g'(mu) (A.23) by a batched grid sweep through the waterfill
    kernel: each round evaluates n_mu candidate multipliers in one device
    pass and re-grids geometrically inside the sign-change bracket. Replaces
    the former 200-step bracket expansion + 96 scalar `float(gprime(mid))`
    bisections (hundreds of host syncs) with `1 + refine` batched sweeps."""
    from ..kernels import ops as kops

    B_total = jnp.asarray(sys.bandwidth_total, j.dtype)   # traced per-cell leaf
    # g'(mu) is strictly decreasing; mu -> 0+ gives W -> -1 (g' -> +inf).
    # For mu >> j, W+1 ~ ln(mu/j), so the root satisfies
    #   ln(mu*/j) ~ sum(rmin) ln2 / B_total;
    # size the bracket from that estimate (+10 nats for the -lnln(z) slack) —
    # tight deadlines can push the root arbitrarily high, which a fixed cap
    # would silently miss. Clamp so both hi and the kernel's in-lane ratio
    # q = mu/j stay finite in the dtype the sweep COMPUTES in (f32 on TPU,
    # regardless of j.dtype — see kernels.ops.waterfill_compute_dtype).
    cd = kops.waterfill_compute_dtype(j.dtype)
    lo = jnp.asarray(1e-30, j.dtype)
    base = 2.0 * jnp.max(j) + 1.0
    nats = jnp.sum(rmin) * jnp.log(2.0) / jnp.maximum(B_total, 1e-30) + 10.0
    logmax = 0.9 * float(np.log(float(jnp.finfo(cd).max)))
    cap = logmax + jnp.minimum(jnp.log(jnp.min(j)), 0.0) - jnp.log(base)
    hi = base * jnp.exp(jnp.minimum(nats, cap))
    g_lo = g_hi = None
    for _ in range(1 + refine):
        grid = jnp.geomspace(lo, hi, n_mu)
        g = kops.waterfill_gprime(grid, j, rmin, B_total)
        neg = g < 0.0
        idx = jnp.where(jnp.any(neg), jnp.maximum(jnp.argmax(neg), 1), n_mu - 1)
        lo, hi = grid[idx - 1], grid[idx]
        g_lo, g_hi = g[idx - 1], g[idx]
    # secant interpolation on the final bracket
    t = jnp.clip(g_lo / jnp.maximum(g_lo - g_hi, 1e-30), 0.0, 1.0)
    return (lo + t * (hi - lo)).astype(j.dtype)


def solve_sp2_v2_thm2(sys: SystemParams, w: Weights, nu: Array, beta: Array,
                      rmin: Array) -> Tuple[Array, Array]:
    """Paper-literal Appendix-D path: Lambert-W dual (A.22/A.23) + Theorem 2.
    Exact when every device's rate constraint is tight (tau_n > 0).

    The dual multiplier search runs through the batched
    `kernels.ops.waterfill_gprime` sweep (Pallas on TPU, the ref oracle on
    CPU) — fully device-resident, jit/vmap-compatible, no host syncs."""
    rmin = _clamp_rmin(sys, rmin)
    g_lin, d, N0 = sys.gain, sys.bits, sys.noise_psd
    j = nu * d * N0 / g_lin
    if sys.active is not None:
        # padded lanes have j = 0 (zero bits): their g'(mu) term is 0 either
        # way (rmin = 0), but the bracket sizing takes log(min(j)) — park
        # them at max(j) so the min/max reductions only see real devices
        j = jnp.where(sys.active, j, jnp.max(j))
    mu = _thm2_dual_mu(sys, j, rmin)

    W = lambertw0((mu - j) / (jnp.e * j))
    a_val = jnp.where(jnp.abs(W) > 1e-12,
                      (mu - j) * jnp.log(2.0) / jnp.where(jnp.abs(W) < 1e-12, 1.0, W),
                      jnp.e * j * jnp.log(2.0))          # (A.22) numerator
    tau = jnp.maximum(a_val - nu * beta, 0.0)
    a = nu * beta + tau
    # padded lanes have d = 0: an unguarded denominator makes Lam = inf and
    # p = clip(inf * B_opt=0) = NaN. With the guard Lam is finite-huge, so
    # B_opt = rmin/log2(Lam) = 0 and p clips to p_min. Real devices sit many
    # orders above tiny, so the guard is bit-exact for them.
    denom = jnp.maximum(N0 * d * nu * jnp.log(2.0),
                        jnp.finfo(jnp.asarray(rmin).dtype).tiny)
    Lam = jnp.maximum(a * g_lin / denom, 1.0 + 1e-12)
    B_opt = rmin / jnp.log2(Lam)                         # Theorem 2, tight branch
    total = jnp.sum(B_opt)
    B_opt = jnp.where(total > sys.bandwidth_total,
                      B_opt * (sys.bandwidth_total / jnp.maximum(total, 1e-30)),
                      B_opt)
    p_opt = jnp.clip((Lam - 1.0) * N0 * B_opt / g_lin, sys.p_min, sys.p_max)
    return p_opt, B_opt


# ----------------------------------------------------------------------------
# Outer Newton-like iteration (Algorithm 1)
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class SP2Result:
    power: Array
    bandwidth: Array
    nu: Array
    beta: Array
    iters: int
    residual: float


def _phi_norm(sys: SystemParams, w1, p, B, beta, nu) -> Array:
    rate_ = G(sys, p, B)
    phi1 = -p * sys.bits + beta * rate_            # eq. (24)
    phi2 = -w1 * sys.global_rounds + nu * rate_    # eq. (25)
    phi = jnp.concatenate([phi1, phi2])
    if sys.active is not None:   # padded lanes have no KKT residual
        phi = jnp.where(jnp.concatenate([sys.active, sys.active]), phi,
                        jnp.zeros((), phi.dtype))
    return jnp.linalg.norm(phi)


def _sp2_jong_core(sys: SystemParams, w1, rmin: Array, p0: Array, B0: Array,
                   max_iters: int, xi=0.5, eps=0.01, tol=1e-9, damping=0.5):
    """Traceable body of Algorithm 1 (callable from inside jitted BCD loops).
    Returns (p, B, nu, beta, iters, residual) — all on device."""
    from jax import lax

    rate0 = jnp.maximum(G(sys, p0, B0), 1e-9)
    nu0 = w1 * sys.global_rounds / rate0           # step 2
    beta0 = p0 * sys.bits / rate0
    res0 = _phi_norm(sys, w1, p0, B0, beta0, nu0)
    root_n = (np.sqrt(sys.n) if sys.active is None
              else jnp.sqrt(jnp.sum(sys.active.astype(p0.dtype))))
    scale = jnp.maximum(jnp.linalg.norm(sys.bits * sys.p_max)
                        + w1 * sys.global_rounds * root_n, 1.0)

    def cond(c):
        _, _, _, _, it, _, done = c
        return (~done) & (it < max_iters)

    def body(c):
        p, B, beta, nu, it, _, _ = c
        p_new, B_new = _sp2_v2_impl(sys, nu, beta, rmin)  # step 4 (exact solve)
        p = damping * p + (1.0 - damping) * p_new
        B = damping * B + (1.0 - damping) * B_new
        rate_ = jnp.maximum(G(sys, p, B), 1e-9)
        sigma1 = p * sys.bits / rate_ - beta          # eq. (29)
        sigma2 = w1 * sys.global_rounds / rate_ - nu
        # Algorithm 1 terminates when phi -> 0 at the freshly solved (p, B)
        # (a full Newton step makes the post-update residual 0 by construction).
        res = _phi_norm(sys, w1, p, B, beta, nu)
        done = res <= tol * scale

        def bt_cond(sc):                              # backtracking rule (28)
            _, found, i = sc
            return (~found) & (i < 30)

        def bt(sc):
            step, _, i = sc
            cand = _phi_norm(sys, w1, p, B, beta + step * sigma1,
                             nu + step * sigma2)
            ok = cand <= (1.0 - eps * step) * res
            return jnp.where(ok, step, step * xi), ok, i + 1

        # seeding found=done skips the line search when the outer loop is
        # about to terminate (the duals are frozen below anyway)
        step, _, _ = lax.while_loop(bt_cond, bt, (jnp.ones((), p.dtype),
                                                  done, jnp.zeros((), jnp.int32)))
        beta = jnp.where(done, beta, beta + step * sigma1)   # eq. (30)
        nu = jnp.where(done, nu, nu + step * sigma2)
        return p, B, beta, nu, it + 1, res, done

    p, B, beta, nu, it, res, _ = lax.while_loop(
        cond, body, (p0, B0, beta0, nu0, jnp.zeros((), jnp.int32), res0,
                     jnp.zeros((), bool)))
    return p, B, nu, beta, it, res


@partial(jax.jit, static_argnames=("max_iters",))
def _sp2_jong_impl(sys: SystemParams, w1, rmin: Array, p0: Array, B0: Array,
                   max_iters: int, xi, eps, tol, damping):
    return _sp2_jong_core(sys, w1, rmin, p0, B0, max_iters,
                          xi=xi, eps=eps, tol=tol, damping=damping)


def solve_sp2(sys: SystemParams, w: Weights, rmin: Array,
              p0: Array, B0: Array,
              max_iters: int = 30, xi: float = 0.5, eps: float = 0.01,
              tol: float = 1e-9, damping: float = 0.5) -> SP2Result:
    """Algorithm 1: Newton-like update of (beta, nu) around the SP2_v2 solver.

    `damping` relaxes the (p, B) iterates between outer steps. SP2_v2's argmin
    is non-unique in the slack-rate regime (near-linear tails of h_n), which
    makes the undamped fixed point oscillate between vertex allocations; a
    0.5 relaxation restores convergence while preserving the fixed points.
    The globally exact `solve_sp2_direct` is used as the oracle in tests.

    The whole iteration is one jitted `lax.while_loop` — no per-iteration
    host syncs (see `_sp2_jong_core` for the traceable form used by BCD).
    """
    p, B, nu, beta, it, res = _sp2_jong_impl(
        sys, jnp.asarray(w.w1, p0.dtype), rmin, p0, B0, max_iters,
        xi, eps, tol, damping)
    return SP2Result(power=p, bandwidth=B, nu=nu, beta=beta,
                     iters=int(it), residual=float(res))
