"""Unit + property tests for repro.core — the paper's resource allocator."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests degrade to skips
    from _hypothesis_stub import given, settings, st

from repro.core import (Allocation, Weights, allocate, allocate_fixed_deadline,
                        default_accuracy, feasible, initial_allocation,
                        make_system, objective, summarize)
from repro.core.accuracy import LogAccuracy, log_fit
from repro.core.energy import rate, t_cmp, t_trans, total_energy, total_time
from repro.core.lambertw import lambertw0
from repro.core.sp1 import solve_sp1, solve_sp1_fixed_T
from repro.core.sp2 import (G, _clamp_rmin, r_min, solve_sp2, solve_sp2_direct,
                            solve_sp2_v2)


def small_system(n=6, seed=0):
    return make_system(jax.random.PRNGKey(seed), n_devices=n)


# ---------------------------------------------------------------------------
# Lambert W
# ---------------------------------------------------------------------------

def test_lambertw_identity():
    z = jnp.concatenate([jnp.linspace(-0.36, 0.0, 50), jnp.logspace(-6, 6, 50)])
    w = lambertw0(z)
    np.testing.assert_allclose(np.asarray(w * jnp.exp(w)), np.asarray(z),
                               rtol=1e-9, atol=1e-12)


@given(st.floats(min_value=-0.367, max_value=1e8, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_lambertw_property(z):
    w = float(lambertw0(jnp.asarray(z)))
    assert w >= -1.0
    assert abs(w * np.exp(w) - z) <= 1e-6 * max(1.0, abs(z))


# ---------------------------------------------------------------------------
# System model sanity
# ---------------------------------------------------------------------------

def test_rate_monotone_in_power_and_bandwidth():
    sys = small_system()
    B = jnp.full((sys.n,), 4e5)
    p = jnp.full((sys.n,), 0.005)
    assert bool(jnp.all(rate(sys, B, 2 * p) > rate(sys, B, p)))
    assert bool(jnp.all(rate(sys, 2 * B, p) > rate(sys, B, p)))


def test_energy_time_positive():
    sys = small_system()
    a = initial_allocation(sys)
    assert float(total_energy(sys, a)) > 0
    assert float(total_time(sys, a)) > 0


# ---------------------------------------------------------------------------
# SP1 (water-filling KKT solve)
# ---------------------------------------------------------------------------

def test_sp1_satisfies_kkt_structure():
    sys = small_system(8)
    w = Weights(0.5, 0.5, 10.0).normalized()
    acc = default_accuracy()
    init = initial_allocation(sys)
    f, s, s_hat, T = solve_sp1(sys, w, acc, init.bandwidth, init.power)
    # boxes
    assert bool(jnp.all((f >= sys.f_min - 1) & (f <= sys.f_max * (1 + 1e-9))))
    assert bool(jnp.all((s_hat >= sys.s_lo - 1e-6) & (s_hat <= sys.s_hi + 1e-6)))
    # deadline holds with the relaxed s_hat and discrete s (T was lifted to cover)
    tt = t_trans(sys, init.bandwidth, init.power)
    mk = t_cmp(sys, f, s) + tt
    assert bool(jnp.all(mk <= T * (1 + 1e-6)))


def test_sp1_beats_grid():
    """SP1 objective (relaxed s) must match a dense grid search per device."""
    sys = small_system(4, seed=2)
    w = Weights(0.6, 0.4, 5.0).normalized()
    acc = default_accuracy()
    init = initial_allocation(sys)
    f, s, s_hat, T = solve_sp1(sys, w, acc, init.bandwidth, init.power)
    tt = np.asarray(t_trans(sys, init.bandwidth, init.power))
    q = np.asarray(sys.local_iters * sys.zeta * sys.cycles * sys.samples)
    alpha = w.w1 * sys.global_rounds * sys.kappa * q

    def obj(fv, sv, Tv):
        return (np.sum(alpha * sv ** 2 * fv ** 2) + w.w2 * sys.global_rounds * Tv
                - w.rho * np.sum(np.asarray(acc.value(jnp.asarray(sv)))))

    ours = obj(np.asarray(f), np.asarray(s_hat), float(T))
    # grid: for a range of T values, per-device minimal (f, s) meeting deadline
    fgrid = np.linspace(1e6, sys.f_max, 160)
    sgrid = np.linspace(sys.s_lo, sys.s_hi, 160)
    best = np.inf
    for Tv in np.linspace(float(T) * 0.5, float(T) * 2.0, 40):
        tot = w.w2 * sys.global_rounds * Tv
        ok = True
        for i in range(sys.n):
            mk = q[i] * sgrid[None, :] ** 2 / fgrid[:, None] + tt[i]
            feas = mk <= Tv
            if not feas.any():
                ok = False
                break
            per = (alpha[i] * sgrid[None, :] ** 2 * fgrid[:, None] ** 2
                   - w.rho * np.asarray(acc.value(jnp.asarray(sgrid)))[None, :])
            tot += float(per[feas].min())
        if ok:
            best = min(best, tot)
    assert ours <= best * (1 + 1e-3) + 1e-9


def test_sp1_concave_accuracy_model():
    sys = small_system(5, seed=3)
    w = Weights(0.5, 0.5, 30.0).normalized()
    acc = log_fit()
    init = initial_allocation(sys)
    f, s, s_hat, T = solve_sp1(sys, w, acc, init.bandwidth, init.power)
    assert bool(jnp.all(jnp.isfinite(f))) and bool(jnp.all(jnp.isfinite(s_hat)))
    # higher rho must not decrease resolutions
    w2 = Weights(0.5, 0.5, 300.0).normalized()
    _, s_big, s_hat_big, _ = solve_sp1(sys, w2, acc, init.bandwidth, init.power)
    assert bool(jnp.all(s_hat_big >= s_hat - 1e-6))


# ---------------------------------------------------------------------------
# SP2
# ---------------------------------------------------------------------------

def _rand_instance(seed, n=4):
    sys = small_system(n, seed=seed)
    key = jax.random.PRNGKey(seed + 100)
    f = jax.random.uniform(key, (n,), minval=3e8, maxval=sys.f_max)
    res = jnp.asarray(sys.resolutions)
    s = res[jax.random.randint(jax.random.PRNGKey(seed + 7), (n,), 0, 4)]
    T = float(jnp.max(t_cmp(sys, f, s))) * 1.5 + 0.02
    rmin = _clamp_rmin(sys, r_min(sys, f, s, jnp.asarray(T)))
    return sys, rmin


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_sp2_direct_feasible_and_beats_grid(seed):
    sys, rmin = _rand_instance(seed, n=3)
    p, B = solve_sp2_direct(sys, rmin)
    gain, bits, N0 = np.asarray(sys.gain), np.asarray(sys.bits), sys.noise_psd

    def Gnp(pv, Bv):
        return Bv * np.log2(1 + gain * pv / (N0 * Bv))

    assert np.all(Gnp(np.asarray(p), np.asarray(B)) >= np.asarray(rmin) * (1 - 1e-6))
    assert float(B.sum()) <= sys.bandwidth_total * (1 + 1e-6)
    ours = float(np.sum(np.asarray(p) * bits / Gnp(np.asarray(p), np.asarray(B))))

    shares = np.linspace(0.01, 0.98, 40)
    pg = np.linspace(sys.p_min, sys.p_max, 20)
    P = np.stack(np.meshgrid(pg, pg, pg, indexing="ij"), -1).reshape(-1, 3)
    best = np.inf
    for s1 in shares:
        for s2 in shares:
            s3 = 1.0 - s1 - s2
            if s3 <= 0.005:
                continue
            Brow = np.array([s1, s2, s3]) * sys.bandwidth_total
            rates = Gnp(P, Brow[None, :])
            feas = np.all(rates >= np.asarray(rmin)[None, :], -1)
            if feas.any():
                e = np.sum(P[feas] * bits / rates[feas], -1)
                best = min(best, float(e.min()))
    assert ours <= best * (1 + 1e-3)


@pytest.mark.parametrize("seed", [0, 5])
def test_sp2_jong_close_to_direct(seed):
    """Paper's Algorithm 1 (damped) should approach the exact optimum."""
    sys, rmin = _rand_instance(seed, n=6)
    init = initial_allocation(sys)
    w = Weights(0.5, 0.5, 1.0).normalized()
    r1 = solve_sp2(sys, w, rmin, init.power, init.bandwidth, max_iters=60)
    pd, Bd = solve_sp2_direct(sys, rmin)

    def energy(p, B):
        return float(jnp.sum(p * sys.bits / jnp.maximum(G(sys, p, B), 1e-12)))

    assert energy(r1.power, r1.bandwidth) <= energy(pd, Bd) * 2.0 + 1e-12
    # both feasible
    for p, B in [(r1.power, r1.bandwidth), (pd, Bd)]:
        assert bool(jnp.all(G(sys, p, B) >= rmin * (1 - 1e-6)))


def test_sp2_v2_inner_matches_grid():
    sys, rmin = _rand_instance(1, n=2)
    init = initial_allocation(sys)
    w = Weights(0.5, 0.5, 1.0).normalized()
    rate0 = G(sys, init.power, init.bandwidth)
    nu = w.w1 * sys.global_rounds / rate0
    beta = init.power * sys.bits / rate0
    p, B = solve_sp2_v2(sys, w, nu, beta, rmin)
    gain, bits, N0 = np.asarray(sys.gain), np.asarray(sys.bits), sys.noise_psd
    nuN, betaN = np.asarray(nu), np.asarray(beta)

    def Gnp(pv, Bv):
        return Bv * np.log2(1 + gain * pv / (N0 * Bv))

    def v2obj(pv, Bv):
        return np.sum(nuN * (pv * bits - betaN * Gnp(pv, Bv)), -1)

    ours = float(v2obj(np.asarray(p), np.asarray(B)))
    shares = np.linspace(0.002, 0.998, 300)
    pg = np.linspace(sys.p_min, sys.p_max, 50)
    P = np.stack(np.meshgrid(pg, pg, indexing="ij"), -1).reshape(-1, 2)
    best = np.inf
    for sh in shares:
        Brow = np.array([sh, 1 - sh]) * sys.bandwidth_total
        feas = np.all(Gnp(P, Brow[None, :]) >= np.asarray(rmin)[None, :], -1)
        if feas.any():
            best = min(best, float(v2obj(P[feas], Brow[None, :]).min()))
    assert ours <= best + abs(best) * 1e-3 + 1e-12


# ---------------------------------------------------------------------------
# Full BCD (Algorithm 2)
# ---------------------------------------------------------------------------

def test_bcd_converges_and_feasible():
    sys = small_system(10, seed=4)
    res = allocate(sys, Weights(0.5, 0.5, 1.0), max_iters=8)
    assert res.converged
    assert feasible(sys, res.allocation)
    objs = [h["objective"] for h in res.history]
    assert all(objs[i + 1] <= objs[i] + 1e-6 for i in range(len(objs) - 1))


def test_bcd_weight_tradeoff():
    """Higher w1 (energy emphasis) must not increase energy; higher w2 must
    not increase completion time (paper Fig. 3 trend)."""
    sys = small_system(12, seed=5)
    e_heavy = allocate(sys, Weights(0.9, 0.1, 1.0), max_iters=8)
    t_heavy = allocate(sys, Weights(0.1, 0.9, 1.0), max_iters=8)
    assert e_heavy.history[-1]["energy"] <= t_heavy.history[-1]["energy"] * (1 + 1e-6)
    assert t_heavy.history[-1]["time"] <= e_heavy.history[-1]["time"] * (1 + 1e-6)


def test_bcd_rho_monotone_resolution():
    """Larger rho must not decrease the chosen resolutions (Fig. 7 staircase)."""
    sys = small_system(10, seed=6)
    prev = None
    for rho in [1.0, 20.0, 60.0]:
        res = allocate(sys, Weights(0.5, 0.5, rho), max_iters=6)
        mean_s = float(jnp.mean(res.allocation.resolution))
        if prev is not None:
            assert mean_s >= prev - 1e-9
        prev = mean_s


def test_bcd_beats_minpixel_energy():
    """Paper Fig. 3(a): proposed beats MinPixel on energy by a wide margin."""
    from repro.core.baselines import min_pixel

    sys = small_system(15, seed=7)
    res = allocate(sys, Weights(0.5, 0.5, 1.0), max_iters=8)
    bench = min_pixel(sys, jax.random.PRNGKey(0), sweep="power")
    assert (float(total_energy(sys, res.allocation))
            < float(total_energy(sys, bench)))


def test_fixed_deadline_meets_deadline():
    sys = small_system(8, seed=8)
    T_total = 120.0
    res = allocate_fixed_deadline(sys, Weights(0.99, 0.01, 1.0), T_total, max_iters=8)
    assert float(total_time(sys, res.allocation)) <= T_total * 1.05
    assert feasible(sys, res.allocation)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=10, deadline=None)
def test_property_bcd_feasibility(seed):
    """Allocation is always feasible regardless of the instance draw."""
    sys = make_system(jax.random.PRNGKey(seed), n_devices=5)
    res = allocate(sys, Weights(0.5, 0.5, 10.0), max_iters=4)
    assert feasible(sys, res.allocation)
    assert float(jnp.sum(res.allocation.bandwidth)) <= sys.bandwidth_total * (1 + 1e-6)
