"""Accuracy models A_n(s) (paper §III-C).

The paper assumes A(s_1..s_N) = sum_n A_n(s_n) with each A_n concave and
nondecreasing in the frame resolution s_n, and evaluates a *linear* A_n whose
endpoints come from the YOLO accuracy-vs-resolution measurements of [16] /
the paper's own Fig. 7 (mAP at 160/320/480/640 px).

Beyond the paper (DESIGN.md §5): our SP1 solver only needs A_n' to be
computable and nonincreasing, so arbitrary concave models are supported;
we ship linear (paper-faithful), logarithmic, and power-law fits.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax.numpy as jnp

Array = jnp.ndarray

# mAP operating points in the YOLOv5m-on-COCO regime of the paper's Fig. 7
# (approximate values read off the figure; used as default accuracy data).
FIG7_RESOLUTIONS = (160.0, 320.0, 480.0, 640.0)
FIG7_MAP_YOLOV5M = (0.223, 0.321, 0.373, 0.402)
FIG7_MAP_YOLOV3TINY = (0.078, 0.130, 0.158, 0.170)


class AccuracyModel(Protocol):
    def value(self, s: Array) -> Array: ...
    def deriv(self, s: Array) -> Array: ...


@dataclasses.dataclass(frozen=True)
class LinearAccuracy:
    """A_n(s) = k * (s - s_lo) + a_lo  (paper Appendix B special case).

    Note: the paper writes k_hat = (A_{s1} - A_{sM})/(sM - s1), which is
    negative for an increasing accuracy; that is a sign typo — the working
    slope is (A_{sM} - A_{s1})/(sM - s1), which we use.
    """
    slope: float
    s_lo: float
    a_lo: float

    def value(self, s: Array) -> Array:
        return self.slope * (s - self.s_lo) + self.a_lo

    def deriv(self, s: Array) -> Array:
        return jnp.full_like(jnp.asarray(s, jnp.float64 if jnp.asarray(s).dtype == jnp.float64 else jnp.float32), self.slope)


@dataclasses.dataclass(frozen=True)
class LogAccuracy:
    """A_n(s) = a + b * log(s / s0); concave, nondecreasing for b >= 0."""
    a: float
    b: float
    s0: float

    def value(self, s: Array) -> Array:
        return self.a + self.b * jnp.log(s / self.s0)

    def deriv(self, s: Array) -> Array:
        return self.b / s


@dataclasses.dataclass(frozen=True)
class PowerAccuracy:
    """A_n(s) = a - c * s^(-q); concave for 0 < q <= 1? A'' = -c q(q+1) s^(-q-2) < 0. OK for c>0,q>0."""
    a: float
    c: float
    q: float

    def value(self, s: Array) -> Array:
        return self.a - self.c * jnp.power(s, -self.q)

    def deriv(self, s: Array) -> Array:
        return self.c * self.q * jnp.power(s, -self.q - 1.0)


def linear_from_endpoints(s_lo: float, s_hi: float, a_lo: float, a_hi: float) -> LinearAccuracy:
    return LinearAccuracy(slope=(a_hi - a_lo) / (s_hi - s_lo), s_lo=s_lo, a_lo=a_lo)


def default_accuracy(resolutions=FIG7_RESOLUTIONS, maps=FIG7_MAP_YOLOV5M) -> LinearAccuracy:
    """Paper-default linear model through the extreme Fig.-7 operating points."""
    return linear_from_endpoints(resolutions[0], resolutions[-1], maps[0], maps[-1])


def log_fit(resolutions=FIG7_RESOLUTIONS, maps=FIG7_MAP_YOLOV5M) -> LogAccuracy:
    """Least-squares log fit through the Fig.-7 points (beyond-paper concave model)."""
    import numpy as np
    x = np.log(np.asarray(resolutions) / resolutions[0])
    y = np.asarray(maps)
    b, a = np.polyfit(x, y, 1)
    return LogAccuracy(a=float(a), b=float(b), s0=float(resolutions[0]))


def menu_of(acc, default=FIG7_RESOLUTIONS) -> tuple:
    """The resolution menu an accuracy model was fitted on.

    Models that carry their own operating points (e.g. a fitted
    `repro.diff.surrogate.SurrogateAccuracy`) expose a `menu` attribute;
    everything else falls back to the paper's Fig. 7 grid."""
    menu = getattr(acc, "menu", None)
    return tuple(float(m) for m in menu) if menu else tuple(default)


def system_with_menu(sys, acc):
    """Re-key a `SystemParams` to the accuracy model's own resolution menu.

    `round_resolution` and `fl.simulator.map_resolution_to_dataset` snap
    onto `sys.resolutions`; a model fitted on a non-default menu must
    therefore travel WITH its menu or the solve silently re-snaps s to the
    Fig. 7 grid. Models without an attached menu leave the system
    untouched (no recompile: `resolutions` only changes when the menu
    genuinely differs)."""
    menu = getattr(acc, "menu", None)
    if not menu:
        return sys
    menu = tuple(float(m) for m in menu)
    return sys if menu == tuple(sys.resolutions) \
        else sys.replace(resolutions=menu)
