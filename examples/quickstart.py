"""Quickstart: allocate resources for an FL-MAR fleet and inspect the result.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (Weights, allocate, default_accuracy, feasible,
                        make_system, summarize)

key = jax.random.PRNGKey(0)
system = make_system(key, n_devices=20)          # paper §VII-A parameters
weights = Weights(w1=0.5, w2=0.5, rho=30.0)      # energy/time/accuracy trade

result = allocate(system, weights)               # Algorithm 2 (BCD)
alloc = result.allocation

print(f"converged={result.converged} in {result.iters} BCD iterations")
print(f"feasible={feasible(system, alloc)}")
print("per-device resolution choices:", sorted(set(alloc.resolution.tolist())))
for k, v in summarize(system, weights.normalized(), default_accuracy(), alloc).items():
    print(f"  {k}: {v:.5g}")
