"""Jamba-1.5-Large (398B total) — hybrid Mamba+attention 1:7 interleave with
16-expert top-2 MoE on alternating layers. [arXiv:2403.19887]

Period of 8 layers (9 periods x 8 = 72): the attention layer sits mid-period;
MoE on every other layer, mirroring the published block structure."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", arch_type="hybrid",
    n_layers=72, d_model=8192, n_heads=64, kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2,
    block_pattern=("mamba", "mamba_moe", "mamba", "mamba_moe",
                   "attn", "mamba_moe", "mamba", "mamba_moe"),
    d_state=16, d_conv=4,
    source="arXiv:2403.19887",
)
