"""Batched SP1 dual sweep kernel: Sigma_n lambda_n(T) over a whole T-grid.

SP1's KKT system (paper eqs. A.2-A.7) is solved by inverting the per-device
makespan map lambda -> T_n(lambda) and then finding the T at which
Sigma_n lambda_n(T) = w2 Rg. The seed solved this with a nested 56x56 scalar
bisection; this kernel evaluates the inner inversion for M candidate
deadlines over N devices in ONE pass — the SP1 analogue of the SP2
`waterfill` dual sweep, and the op `core.sp1`'s T-sweep drives.

For the paper's LinearAccuracy model the inner inversion is EXACT: with
k3 = 2 w1 Rg kappa and alpha = w1 Rg kappa q, the KKT stationarity gives
f(lam) = clip((lam/k3)^(1/3), fmin, fmax) and
s(lam) = clip(rho k / psi, s_lo, s_hi), psi = 2 alpha f^2 + 2 lam q / f, so
the compute time q s^2/f is piecewise closed-form in lam. Each clipping
regime inverts in closed form; we evaluate every regime's candidate, push it
through the exact forward map, and keep the smallest lambda among the
candidates with minimal makespan error (the bisection's left-edge convention
on flat segments, and exactly 0 for devices already meeting the deadline).

Grid (N/bn,), VMEM blocks of (q, tt) device parameters, the (M,) T-grid
replicated per step, scalar coefficients in SMEM, partial sums accumulated
into the (M,) output across sequential grid steps.

Oracle: kernels.ref.sp1_lambda_sum_ref (same closed form at full input
precision); parity vs the nested bisection is tested in tests/test_sp1_kkt.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# consts vector layout fed to the kernel (SMEM): index -> meaning
N_CONSTS = 8   # [k3, rho_slope, f_min, f_max, s_lo, s_hi, lam_hi, unused]


def lambda_of_T_linear(T, q, tt, k3, rhok, f_min, f_max, s_lo, s_hi, lam_hi):
    """Exact lambda_n(T) for LinearAccuracy; pure jnp, broadcasts over any
    shared shape of (T, q, tt). Scalars may be traced (per-cell leaves).

    Enumerates the clipping regimes of (f, s):
      f = F in {fmin, fmax}, s interior:  s = sqrt(t_c F / q),
          lam = (rhok/s - 2 alpha F^2) F / (2 q)
      s = S in {s_lo, s_hi}, f interior:  f = q S^2 / t_c, lam = k3 f^3
      both interior:  psi = 6 alpha f^2  =>  f^5 = q rhok^2 / (36 alpha^2 t_c)
    plus lam = 0 (device already meets the deadline). Candidates are clipped
    to [0, lam_hi] (nan -> lam_hi: unreachable t_c saturates the bracket like
    the bisection does), validated through the exact forward makespan, and
    the smallest lambda among the error-minimizing candidates is returned.
    """
    dt = jnp.result_type(T, q, tt)
    # division guards must be dtype-aware: a literal 1e-300 underflows to 0
    # in f32 and w1 == 0 (k3 == 0, a valid pure-latency weighting) would
    # turn the lam=0 candidate into cbrt(0/0) = NaN, poisoning the argmin
    tiny = jnp.asarray(jnp.finfo(dt).tiny, dt)
    t_c = jnp.maximum(T - tt, tiny)           # target compute time
    q_safe = jnp.maximum(q, tiny)
    alpha = 0.5 * k3 * q

    def makespan_err(lam):                    # exact forward map, vs target
        f = jnp.clip(jnp.cbrt(lam / jnp.maximum(k3, tiny)), f_min, f_max)
        psi = 2.0 * alpha * f ** 2 + 2.0 * lam * q / jnp.maximum(f, 1e-9)
        s = jnp.clip(rhok / jnp.maximum(psi, tiny), s_lo, s_hi)
        return jnp.abs(q * s ** 2 / jnp.maximum(f, 1e-9) - t_c)

    def cand_f_clipped(F):                    # f pinned at a box edge
        s = jnp.sqrt(t_c * F / q_safe)
        return (rhok / jnp.maximum(s, tiny) - 2.0 * alpha * F ** 2) \
            * F / (2.0 * q_safe)

    def cand_s_clipped(S):                    # s pinned at a box edge
        f = q * S ** 2 / t_c
        return k3 * f ** 3

    # both interior: f^5 = q rhok^2 / (36 alpha^2 t_c) with alpha = k3 q / 2,
    # i.e. f = (rhok / (3 k3))^(2/5) * (q t_c)^(-1/5). Factored this way so
    # kappa-scale coefficients never square: alpha^2 ~ 1e-45 underflows f32
    # (the fleet bench dtype) even though f itself is representable.
    f6 = (rhok / jnp.maximum(3.0 * k3, tiny)) ** 0.4 \
        * jnp.maximum(q * t_c, tiny) ** -0.2
    cands = jnp.stack(jnp.broadcast_arrays(
        jnp.zeros_like(t_c),
        cand_f_clipped(f_min), cand_f_clipped(f_max),
        cand_s_clipped(s_lo), cand_s_clipped(s_hi),
        k3 * f6 ** 3))
    cands = jnp.where(jnp.isnan(cands), lam_hi, jnp.clip(cands, 0.0, lam_hi))
    err = makespan_err(cands)
    best = jnp.min(err, axis=0)
    near = err <= best * (1.0 + 1e-6) + tiny
    lam = jnp.min(jnp.where(near, cands, jnp.inf), axis=0)
    # Strictly unattainable deadline (t_c below the q s_lo^2/f_max makespan
    # floor): every candidate ties at the floor, and the min-lambda rule
    # would pick the left edge of the clipped-flat region; the bisection
    # saturates its bracket instead. Match it so the closed form is a
    # drop-in for `_lambda_of_T` over the whole T axis, not just the
    # attainable range the sweep queries. (f and s agree either way — both
    # lambdas sit in the f=f_max, s=s_lo clip regime.)
    return jnp.where(q * s_lo ** 2 / jnp.maximum(f_max, 1e-9) > t_c,
                     lam_hi, lam)


def _sp1_kernel(T_ref, c_ref, q_ref, tt_ref, out_ref, *, dtype):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    T = T_ref[...].astype(dtype)              # (M,)
    q = q_ref[...].astype(dtype)              # (bn,)
    tt = tt_ref[...].astype(dtype)            # (bn,)
    lam = lambda_of_T_linear(
        T[:, None], q[None, :], tt[None, :],
        c_ref[0], c_ref[1], c_ref[2], c_ref[3], c_ref[4], c_ref[5], c_ref[6])
    out_ref[...] += jnp.sum(lam, axis=1).astype(out_ref.dtype)


def sp1_lambda_sum(T_grid: jax.Array, q: jax.Array, tt: jax.Array,
                   consts: jax.Array, *, block_n: int = 1024,
                   interpret: bool = False,
                   dtype=jnp.float32) -> jax.Array:
    """Sigma_n lambda_n(T) per candidate: T_grid (M,), q/tt (N,),
    consts (N_CONSTS,) -> (M,). Any N: the tail block is padded with
    (q=0, tt=0) lanes, for which every candidate ties at makespan 0 and the
    min-lambda rule returns exactly 0 — an implicit mask of the partial sum.

    dtype: in-kernel compute/output dtype, as for `waterfill.waterfill_gprime`.
    """
    N = q.shape[0]
    rem = (-N) % block_n
    if rem:
        q = jnp.concatenate([q, jnp.zeros((rem,), q.dtype)])
        tt = jnp.concatenate([tt, jnp.zeros((rem,), tt.dtype)])
        N += rem
    M = T_grid.shape[0]
    return pl.pallas_call(
        functools.partial(_sp1_kernel, dtype=dtype),
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((M,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((M,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((M,), dtype),
        interpret=interpret,
    )(T_grid.astype(dtype), consts.astype(dtype), q.astype(dtype),
      tt.astype(dtype))
