"""FedAvg server (paper §III): weighted parameter averaging across clients.

`run_federated` is the reference single-host loop. For datacenter-scale
federated *simulation* the same aggregation is expressed as a weighted psum
over the mesh 'data' axis in `repro.launch.train` (clients sharded across
devices) — the aggregation math here is the oracle for that path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.fl.client import local_train
from repro.fl.data import FLDataset, make_eval_set, render
from repro.models.cnn import accuracy as eval_accuracy
from repro.models.cnn import init_cnn

Params = dict


def fedavg(params_list: Sequence[Params], weights: jax.Array) -> Params:
    """w_global = sum_n (D_n / D) w_n   (the paper's global model, §III)."""
    wn = weights / jnp.sum(weights)

    def avg(*leaves):
        return sum(w * leaf for w, leaf in zip(wn, leaves))

    return jax.tree_util.tree_map(avg, *params_list)


@dataclasses.dataclass
class FLRunResult:
    params: Params
    round_accuracy: List[float]
    round_loss: List[float]


def run_federated(key: jax.Array, ds: FLDataset,
                  resolutions: Sequence[int],
                  global_rounds: int = 20, local_iters: int = 10,
                  lr: float = 0.05,
                  eval_every: int = 1, eval_n: int = 512,
                  eval_resolution: Optional[int] = None) -> FLRunResult:
    """FedAvg over `ds` with per-client frame resolutions from the allocator.

    resolutions: one rendering resolution per client (the allocator's s_n,
    mapped onto the dataset's resolution grid by the simulator).
    """
    k_init, k_eval = jax.random.split(key)
    params = init_cnn(k_init, num_classes=ds.num_classes)
    ev_imgs, ev_labels = make_eval_set(k_eval, ds, n=eval_n)
    # MAR deployment serves at the frame resolution the fleet runs at: eval at
    # the median allocated resolution unless overridden.
    ev_res = eval_resolution or int(sorted(resolutions)[len(resolutions) // 2])
    ev_imgs = render(ev_imgs, ev_res)

    # pre-render each client's shard at its allocated resolution
    client_data = [
        (render(ds.images[i], int(resolutions[i])), ds.labels[i])
        for i in range(ds.n_clients)
    ]
    sizes = jnp.asarray([float(ds.labels.shape[1])] * ds.n_clients)

    accs: List[float] = []
    losses: List[float] = []
    for r in range(global_rounds):
        updated, round_losses = [], []
        for i, (imgs, labels) in enumerate(client_data):
            p_i, loss_i = local_train(params, imgs, labels, lr, local_iters)
            updated.append(p_i)
            round_losses.append(float(loss_i))
        params = fedavg(updated, sizes)
        losses.append(sum(round_losses) / len(round_losses))
        if (r + 1) % eval_every == 0:
            accs.append(float(eval_accuracy(params, ev_imgs, ev_labels)))
    return FLRunResult(params=params, round_accuracy=accs, round_loss=losses)
