"""Mesh layer: shard the cell axis of a stacked fleet across local devices.

The fleet path of `repro.solve` vmaps the jitted BCD across cells on ONE
device; a region is C cells x N devices where C x N is millions of clients,
so the cell axis must spread over a device mesh (`Problem.mesh`). Two
execution modes (`SolverSpec.lockstep`):

  * `lockstep=True`: pure jit with `NamedSharding`-placed inputs — GSPMD
    partitions the vmapped solve along `cells`. The BCD `lax.while_loop`
    condition becomes a cross-device all-reduce, so every shard iterates
    until the globally slowest cell converges.
  * `lockstep=False` (default on a multi-device mesh): the same vmapped
    solver wrapped in `shard_map`, making the while_loop condition
    *shard-local* — a shard stops as soon as its own cells converge. Cells
    are solved by exactly the same select-masked program either way (the
    vmapped while_loop freezes converged lanes), so per-cell results are
    bit-identical between modes; only wall-clock differs. This is the
    "shard_map only if the BCD while_loop forces it" carve-out: the
    lockstep all-reduce is precisely what it buys back.

CPU dev recipe: XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.accuracy import AccuracyModel
from repro.core.bcd import FleetResult, _fleet_cell_fn
from repro.core.types import Allocation, SystemParams, Weights

Array = jnp.ndarray


def region_mesh(n_devices: Optional[int] = None, devices=None) -> Mesh:
    """1-D mesh over the local devices with axis name "cells" (the logical
    axis `sharding.partition.region_rules` maps onto it)."""
    devs = list(devices) if devices is not None else jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.array(devs), axis_names=("cells",))


def cell_specs(tree):
    """PartitionSpec pytree sharding every leaf's leading (cell) axis,
    derived from `sharding.partition.region_rules` (cells -> mesh axis,
    device and deeper axes shard-local)."""
    from repro.sharding.partition import logical_to_spec, region_rules

    rules = region_rules()
    return jax.tree_util.tree_map(
        lambda x: logical_to_spec(
            ("cells",) + ("device",) * (jnp.ndim(x) - 1), rules), tree)


def place_cells(tree, mesh: Mesh):
    """device_put every leaf with its cell axis sharded over `mesh`."""
    def put(x):
        x = jnp.asarray(x)
        spec = P("cells", *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, tree)


def pad_cells(tree, c_pad: int):
    """Pad every leaf's leading (cell) axis to `c_pad` by replicating the
    last cell — mesh shards must divide the cell count. Replicated cells
    cost duplicate work on the last shard only; callers slice them off."""
    def pad(x):
        x = jnp.asarray(x)
        c = x.shape[0]
        if c == c_pad:
            return x
        reps = jnp.broadcast_to(x[-1:], (c_pad - c,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)
    return jax.tree_util.tree_map(pad, tree)


@dataclasses.dataclass
class RegionResult:
    """A sharded fleet solve plus per-shard convergence stats.

    `stats` is gathered host-side lazily, ONCE, on first access (one
    device->host transfer of a packed (4 + 4*D,) array): the serving hot
    path — which only slices allocations back out — never pays the
    blocking sync, while monitoring callers still get the summary for
    free. The trailing 4*D block is the per-shard `SolveCounters`
    aggregation (summed bcd_iters/sp1_evals/sp2_evals and max residual
    over each shard's contiguous cell block, pad cells excluded) — the
    per-shard attribution the SLO plane and multi-host monitoring need
    without a second sync."""
    fleet: FleetResult
    _stats_packed: Array     # (4,) or (4 + 4*D,) device array, _pack_stats
    _n_cells: int
    _mesh_devices: int
    _stats_cache: Optional[dict] = dataclasses.field(default=None,
                                                     repr=False)

    @property
    def stats(self) -> dict:
        if self._stats_cache is None:
            vals = np.asarray(self._stats_packed)
            stats = dict(
                cells=self._n_cells, mesh_devices=self._mesh_devices,
                converged_frac=float(vals[0]), iters_max=int(vals[1]),
                iters_mean=float(vals[2]), objective_mean=float(vals[3]))
            if vals.shape[0] > 4:   # per-shard counter block (D, 4)
                shard = vals[4:].reshape(-1, 4)
                stats.update(
                    shard_bcd_iters=[float(x) for x in shard[:, 0]],
                    shard_sp1_evals=[float(x) for x in shard[:, 1]],
                    shard_sp2_evals=[float(x) for x in shard[:, 2]],
                    shard_residual_max=[float(x) for x in shard[:, 3]],
                    bcd_iters_total=float(shard[:, 0].sum()),
                    sp1_evals_total=float(shard[:, 1].sum()),
                    sp2_evals_total=float(shard[:, 2].sum()),
                    residual_max=float(shard[:, 3].max()))
            self._stats_cache = stats
        return self._stats_cache

    # convenience passthroughs so RegionResult reads like a FleetResult
    @property
    def allocation(self) -> Allocation:
        return self.fleet.allocation

    @property
    def objective(self) -> Array:
        return self.fleet.objective

    @property
    def iters(self) -> Array:
        return self.fleet.iters

    @property
    def converged(self) -> Array:
        return self.fleet.converged


@partial(jax.jit, static_argnames=("acc", "max_iters", "sp1_method",
                                   "sp2_method", "sp2_iters", "mesh",
                                   "lockstep", "with_init"))
def _region_solve_impl(sys_batch, warr, init, tol, acc: AccuracyModel,
                       max_iters: int, sp1_method: str, sp2_method: str,
                       sp2_iters: int, mesh: Mesh, lockstep: bool,
                       with_init: bool):
    """warr is the (C, 3) per-cell weights stack — a traced, cell-sharded
    operand, so mixed per-cell weights share this one jit cache entry."""
    fn = _fleet_cell_fn(acc, max_iters, tol, sp1_method, sp2_method,
                        sp2_iters, with_init)
    vf = jax.vmap(fn)
    args = (sys_batch, warr, init) if with_init else (sys_batch, warr)
    if lockstep or mesh.devices.size == 1:
        return vf(*args)
    in_specs = tuple(cell_specs(a) for a in args)
    return shard_map(vf, mesh=mesh, in_specs=in_specs,
                     out_specs=P("cells"), check_rep=False)(*args)


@partial(jax.jit, static_argnames=("acc", "max_iters", "sp2_method",
                                   "sp2_iters", "mesh", "lockstep"))
def _region_fixed_impl(sys_batch, warr, T_round, alloc0, tol,
                       acc: AccuracyModel, max_iters: int, sp2_method: str,
                       sp2_iters: int, mesh: Mesh, lockstep: bool):
    """Deadline-constrained sibling of `_region_solve_impl`: the vmapped
    `_fleet_fixed_cell_fn` under shard_map. The per-cell per-round deadline
    `T_round` (C,) is a traced, cell-sharded operand — heterogeneous
    budgets share this one jit cache entry."""
    from repro.core.bcd import _fleet_fixed_cell_fn

    fn = _fleet_fixed_cell_fn(acc, max_iters, tol, sp2_method, sp2_iters)
    vf = jax.vmap(fn)
    args = (sys_batch, warr, T_round, alloc0)
    if lockstep or mesh.devices.size == 1:
        return vf(*args)
    in_specs = tuple(cell_specs(a) for a in args)
    return shard_map(vf, mesh=mesh, in_specs=in_specs,
                     out_specs=P("cells"), check_rep=False)(*args)


def _pack_stats(fleet: FleetResult, n_shards: int = 1) -> Array:
    """Region summary stats packed into ONE device array — (4,) base
    stats plus, when the fleet carries `SolveCounters`, a (n_shards, 4)
    per-shard aggregation flattened behind them. The single lazy host
    transfer happens in `RegionResult.stats`.

    Shard attribution mirrors the mesh layout: cells are sharded in
    contiguous blocks of ceil(C / n_shards) (the `place_cells`
    NamedSharding), so shard d's block is rows [d*B, (d+1)*B) of the
    zero-padded counter matrix — pad cells contribute nothing (their
    replicated work on the last shard is an artifact of padding, not
    attributable solver effort). Effort columns (bcd_iters, sp1_evals,
    sp2_evals) are nansum'd per shard; the residual column is nanmax'd
    (a NaN residual marks a 0-iteration lane). All eager device ops on
    the already-computed result — no new compiled solve shapes."""
    dtype = jnp.asarray(fleet.objective).dtype
    base = jnp.stack([
        jnp.mean(fleet.converged.astype(dtype)),
        jnp.max(fleet.iters).astype(dtype),
        jnp.mean(fleet.iters.astype(dtype)),
        jnp.nanmean(fleet.objective),
    ])
    if fleet.counters is None:
        return base
    ctr = jnp.asarray(fleet.counters.data, dtype)       # (C, 4)
    C = ctr.shape[0]
    D = max(int(n_shards), 1)
    block = -(-C // D)
    pad = jnp.zeros((block * D - C, ctr.shape[1]), dtype)
    per_shard = jnp.concatenate([ctr, pad]).reshape(D, block, -1)
    effort = jnp.nansum(per_shard[..., :3], axis=1)     # (D, 3)
    resid = jnp.nanmax(per_shard[..., 3], axis=1)       # (D,)
    return jnp.concatenate(
        [base, jnp.concatenate([effort, resid[:, None]], axis=1).ravel()])


def _slice_fleet(fleet: FleetResult, n_cells: int) -> FleetResult:
    from repro.core.bcd import SolveCounters

    if int(fleet.iters.shape[0]) == n_cells:
        return fleet
    cut = lambda x: x[:n_cells]
    counters = fleet.counters
    if counters is not None:
        counters = SolveCounters(data=cut(counters.data),
                                 columns=counters.columns)
    return FleetResult(
        allocation=jax.tree_util.tree_map(cut, fleet.allocation),
        objective=cut(fleet.objective), iters=cut(fleet.iters),
        converged=cut(fleet.converged), history=cut(fleet.history),
        columns=fleet.columns, counters=counters)


def allocate_region(sys_batch: SystemParams, w: Weights,
                    acc: Optional[AccuracyModel] = None,
                    mesh: Optional[Mesh] = None,
                    max_iters: int = 20, tol: float = 1e-6,
                    init: Optional[Allocation] = None,
                    sp2_iters: int = 30, sp2_method: str = "direct",
                    sp1_method: str = "sweep",
                    lockstep: bool = False) -> RegionResult:
    """Deprecated shim: mesh-sharded fleet solve through `repro.solve`.

    Equivalent to ``solve(Problem(system=sys_batch, weights=w,
    mesh=mesh or region_mesh(), ...), SolverSpec(lockstep=...))``. Per-cell
    outputs are bit-identical to the single-device fleet path — sharding
    moves work, not math — and per-cell weights are a traced, cell-sharded
    operand (pass a sequence of `Weights` as `Problem.weights`).
    """
    from repro.api import Problem, SolverSpec, solve
    from repro.api.solve import _warn_deprecated

    _warn_deprecated("allocate_region",
                     "Problem(system=sys_batch, weights, mesh=mesh), "
                     "SolverSpec(lockstep=...)")
    return solve(Problem(system=sys_batch, weights=w, acc=acc, init=init,
                         mesh=mesh if mesh is not None else region_mesh()),
                 SolverSpec(max_iters=max_iters, tol=tol,
                            sp1_method=sp1_method, sp2_method=sp2_method,
                            sp2_iters=sp2_iters, lockstep=lockstep))


def run_rounds_region(key: jax.Array, sys_batch: SystemParams, w: Weights,
                      cfg, acc: Optional[AccuracyModel] = None,
                      init: Optional[Allocation] = None,
                      mesh: Optional[Mesh] = None,
                      lockstep: bool = False):
    """Deprecated shim: mesh-sharded round dynamics through `repro.solve`.

    Equivalent to ``solve(Problem(system=sys_batch, weights=w, rounds=cfg,
    key=key, mesh=mesh or region_mesh(), ...), SolverSpec(lockstep=...))``.
    Per-cell key splits match `run_rounds_fleet` (cell c consumes split c of
    `key`; replicated pad cells reuse the last real cell's key and are
    sliced off), so results agree with the single-device engine.
    """
    from repro.api import Problem, SolverSpec, solve
    from repro.api.solve import _warn_deprecated

    _warn_deprecated("run_rounds_region",
                     "Problem(system=sys_batch, weights, rounds=cfg, "
                     "key=key, mesh=mesh), SolverSpec(lockstep=...)")
    return solve(Problem(system=sys_batch, weights=w, acc=acc, init=init,
                         rounds=cfg, key=key,
                         mesh=mesh if mesh is not None else region_mesh()),
                 SolverSpec(lockstep=lockstep))


@partial(jax.jit, static_argnames=("acc", "cfg", "mesh", "lockstep",
                                   "with_init"))
def _region_rounds_impl(sys_batch, warr, keys, init_state, acc, cfg,
                        mesh: Mesh, lockstep: bool, with_init: bool):
    """warr is the (C, 3) per-cell weights stack (traced, cell-sharded)."""
    from repro.dynamics.engine import (_cell_engine, _init_carry_state,
                                       initial_allocation)

    def one(sysc, warr_c, kc, *st):
        st0 = st[0] if with_init else _init_carry_state(
            sysc, initial_allocation(sysc))
        return _cell_engine(sysc, warr_c, acc, kc, st0, cfg)

    vf = jax.vmap(one)
    args = (sys_batch, warr, keys) + ((init_state,) if with_init else ())
    if lockstep or mesh.devices.size == 1:
        return vf(*args)
    in_specs = tuple(cell_specs(a) for a in args)
    return shard_map(vf, mesh=mesh, in_specs=in_specs,
                     out_specs=P("cells"), check_rep=False)(*args)
