"""Test-suite configuration: enable x64 up front so module ordering cannot
change solver/kernel dtypes mid-suite (the allocator tests need f64
bisections; kernels pin their own compute dtypes)."""
import jax

jax.config.update("jax_enable_x64", True)
