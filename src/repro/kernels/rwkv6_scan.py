"""RWKV6 (Finch) chunked WKV Pallas TPU kernel.

Grid (B, H, T/L) — sequential over chunks on TPU, the (K,V) recurrent state
living in VMEM scratch across chunk steps. Within a chunk the recurrence is
evaluated in parallel form with log-space pairwise decays
exp(clw_{t-1} - clw_tau) (tau < t), which never overflow because the exponent
is always <= 0. head_dim-sized tiles keep the MXU busy ((L,K)x(K,K) dots).

Oracle: kernels.ref.rwkv6_ref (sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
                chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)       # (L, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)     # log decay, < 0
    u = u_ref[0].astype(jnp.float32)          # (K,)
    S0 = s_scr[...]                           # (K, V)

    clw = jnp.cumsum(lw, axis=0)              # inclusive (L, K)
    clw_prev = clw - lw                       # exclusive

    # o_init = (r * exp(clw_prev)) @ S0
    o = jax.lax.dot_general(r * jnp.exp(clw_prev), S0,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk: sum_{tau<t} (r_t * exp(clw_prev_t - clw_tau) . k_tau) v_tau
    L = r.shape[0]
    decay = clw_prev[:, None, :] - clw[None, :, :]          # (t, tau, K)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    fac = jnp.where(tri[..., None], jnp.exp(decay), 0.0)    # (t, tau, K)
    att = jnp.einsum("tk,tsk,sk->ts", r, fac, k)            # (t, tau)
    o = o + jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # bonus diagonal: o_t += (sum_i r_i u_i k_i) * v_t
    o = o + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v

    # state update: S_L = exp(clw_L) * S0 + sum_tau exp(clw_L - clw_tau) k_tau v_tau
    wL = jnp.exp(clw[-1])[:, None]                          # (K,1)
    kfac = jnp.exp(clw[-1][None, :] - clw) * k              # (L,K)
    s_scr[...] = wL * S0 + jax.lax.dot_general(
        kfac, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
               u: jax.Array, *, chunk: int = 64,
               interpret: bool = False) -> jax.Array:
    """r,k,v,logw: (B, T, H, K); u: (H, K). T % chunk == 0. -> o (B,T,H,K)."""
    B, T, H, K = r.shape
    assert T % chunk == 0
    n_chunks = T // chunk
    # (B, H, T, K) layout for blocking
    rr, kk, vv, lw = (t.transpose(0, 2, 1, 3) for t in (r, k, v, logw))

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, K), lambda b, h, j: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, K), lambda b, h, j: (b, h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, K), jnp.float32),
        scratch_shapes=[_vmem((K, K))],
        interpret=interpret,
    )(rr, kk, vv, lw, u)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
