"""Flash attention Pallas TPU kernel (GQA + causal + sliding window).

TPU-native formulation: grid (batch, q_heads, S/bq, T/bk) executed
sequentially over the last dimension, with the online-softmax running state
(m, l, acc) in VMEM scratch that persists across the kv-block sweep — the
standard TPU flash pattern (no warp-level primitives; the MXU sees
(bq, hd) x (hd, bk) tiles, hardware-aligned when bq, bk, hd are multiples
of 128 / the (8,128) VREG tiling).

Validated against kernels.ref.flash_attention_ref in interpret mode (CPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, n_kv_blocks: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, vd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i = pl.program_id(2)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                         # (bq, bk)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, T, hd). H % KV == 0. Returns (B,H,S,hd).

    S and T must be multiples of the block sizes (caller pads; masked rows are
    harmless because softmax normalizes per row)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    n_q, n_k = S // block_q, T // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=block_q, bk=block_k, n_kv_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, v.shape[-1]),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, v.shape[-1]),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, v.shape[-1]), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1)),                    # running row-max m
            _vmem((block_q, 1)),                    # running row-sum l
            _vmem((block_q, v.shape[-1])),          # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
