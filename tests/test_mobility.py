"""Mobility-trace determinism and handover-churn replay.

Traces are jitted scans keyed only by a PRNG key and a static config, so
the same key must produce bit-identical positions / gains / serving /
handover streams — in f32 and f64, single-cell and fleet-stacked, for
both waypoint models. Replay drives the traces through RegionAllocator
and must keep the handover-purge ledger consistent.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import (MobilityConfig, RegionAllocator, SolverSpec, Weights,
                   make_system, replay_mobility, simulate_mobility)
from repro.assoc import bs_grid
from repro.dynamics.mobility import trace_gains

W = Weights(0.5, 0.5, 1.0)


def _cfg(model, **kw):
    kw.setdefault("steps", 6)
    return MobilityConfig(model=model, **kw)


@pytest.mark.parametrize("model", ["rwp", "gauss_markov"])
@pytest.mark.parametrize("dtype", ["float32", "float64"])
@pytest.mark.parametrize("n_cells", [1, 4])
def test_trace_bit_determinism(model, dtype, n_cells):
    key = jax.random.PRNGKey(7)
    kw = dict(n_devices=10, n_cells=n_cells, cfg=_cfg(model), dtype=dtype)
    t1 = simulate_mobility(key, **kw)
    t2 = simulate_mobility(key, **kw)
    for name in ("positions", "gains", "serving", "handover"):
        a, b = np.asarray(getattr(t1, name)), np.asarray(getattr(t2, name))
        assert np.array_equal(a, b), name
    assert np.asarray(t1.positions).dtype == np.dtype(dtype)
    # a different key must actually move the sample
    t3 = simulate_mobility(jax.random.PRNGKey(8), **kw)
    assert not np.array_equal(np.asarray(t1.positions),
                              np.asarray(t3.positions))


@pytest.mark.parametrize("model", ["rwp", "gauss_markov"])
def test_trace_shapes_and_invariants(model):
    cfg = _cfg(model, steps=8, area_m=500.0)
    tr = simulate_mobility(jax.random.PRNGKey(3), n_devices=12, n_cells=3,
                           cfg=cfg)
    R, C, N = cfg.steps, 3, 12
    assert np.asarray(tr.positions).shape == (R, N, 2)
    assert np.asarray(tr.gains).shape == (R, C, N)
    assert np.asarray(tr.serving).shape == (R, N)
    assert np.asarray(tr.handover).shape == (R, N)
    assert tr.steps == R and tr.n_cells == C
    # positions never leave the arena
    assert (np.abs(np.asarray(tr.positions)) <= cfg.area_m / 2 + 1e-6).all()
    # gains positive and finite; serving is the argmax cell
    g = np.asarray(tr.gains)
    assert np.isfinite(g).all() and (g > 0).all()
    sv = np.asarray(tr.serving)
    assert ((sv >= 0) & (sv < C)).all()
    assert np.array_equal(sv, g.argmax(axis=1))
    # handover stream: row 0 is all-False, later rows flag serving changes
    ho = np.asarray(tr.handover)
    assert not ho[0].any()
    assert np.array_equal(ho[1:], sv[1:] != sv[:-1])


def test_trace_gains_shadowing_off_is_pure_pathloss():
    cfg = _cfg("rwp", shadowing_db=0.0)
    key = jax.random.PRNGKey(0)
    pos = jnp.zeros((2, 5, 2))
    bs = bs_grid(2, 1000.0)
    g = np.asarray(trace_gains(key, pos, bs, cfg))
    # identical positions in both rows -> identical deterministic gains
    assert np.array_equal(g[0], g[1])


def test_mobility_config_validation():
    with pytest.raises(ValueError, match="model"):
        MobilityConfig(model="teleport")
    with pytest.raises(ValueError, match="steps"):
        MobilityConfig(steps=0)
    with pytest.raises(ValueError, match="v_max"):
        MobilityConfig(v_min=3.0, v_max=2.0)
    with pytest.raises(ValueError, match="alpha"):
        MobilityConfig(alpha=1.5)
    with pytest.raises(ValueError):
        simulate_mobility(jax.random.PRNGKey(0), n_devices=4, n_cells=2,
                          bs_xy=jnp.zeros((3, 2)))


def test_replay_handover_accounting():
    """Handover churn through the region service: every handover purges at
    most two warm entries, the purge counter matches the service ledger,
    and the request count is steps x cells."""
    cfg = _cfg("rwp", steps=5, dt=5.0, v_min=10.0, v_max=60.0)
    tr = simulate_mobility(jax.random.PRNGKey(1), n_devices=20, n_cells=3,
                           cfg=cfg)
    base = make_system(jax.random.PRNGKey(2), n_devices=20)
    svc = RegionAllocator(w=W, cells_per_batch=4, min_bucket=16,
                          spec=SolverSpec(max_iters=6, tol=1e-4))
    rep = replay_mobility(svc, tr, base)
    assert rep["steps"] == cfg.steps and rep["cells"] == 3
    assert rep["requests"] == cfg.steps * 3
    assert rep["handover_purges"] == svc.stats["handover_purges"]
    assert rep["handover_purges"] <= 2 * rep["handovers"]
    assert rep["warm_solves"] + rep["cold_solves"] == rep["requests"]
    assert 0.0 <= rep["hit_rate"] <= 1.0
    # one padded batch shape for the whole replay
    assert len(rep["compiled_shapes"]) == 1


def test_replay_no_motion_no_purges():
    """A frozen trace (v=0 Gauss-Markov with no noise) never hands over,
    so the warm cache is never invalidated and steps>1 all hit."""
    cfg = MobilityConfig(model="gauss_markov", steps=4, alpha=1.0,
                         v_sigma=0.0, shadowing_db=0.0)
    tr = simulate_mobility(jax.random.PRNGKey(4), n_devices=12, n_cells=2,
                           cfg=cfg)
    assert not np.asarray(tr.handover).any()
    base = make_system(jax.random.PRNGKey(5), n_devices=12)
    svc = RegionAllocator(w=W, cells_per_batch=2, min_bucket=16,
                          spec=SolverSpec(max_iters=4, tol=1e-4))
    rep = replay_mobility(svc, tr, base)
    assert rep["handovers"] == 0
    assert rep["handover_purges"] == 0
    assert rep["cold_solves"] == 2          # first step only
    assert rep["warm_solves"] == (cfg.steps - 1) * 2
