"""Configuration / result types for cross-cell user association.

`AssocConfig` is frozen and hashable (like `SolverSpec` /
`dynamics.RoundsConfig`): setting `Problem.assoc = AssocConfig(...)`
routes the one `solve()` dispatcher to the BCD-over-association outer
loop (`assoc.loop.solve_assoc`). The knobs configure the *outer* loop
only — the inner per-cell resource solves keep taking everything from
`SolverSpec`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class AssocConfig:
    """Knobs of the BCD-over-association outer loop.

    outer_iters : max association steps. Each step proposes a greedy
        capacity-capped reassignment from the current marginal costs,
        re-solves the per-cell resources, and accepts only if the global
        weighted objective improves (so the realized objective is
        non-increasing by construction). 0 = solve the initial (static
        nearest-cell) association once and stop — the baseline.
    capacity : per-cell device cap — an int (every cell), a length-C tuple
        (per cell), or None (uncapped). The summed capacity must cover
        every active device.
    warm_start : warm-start each outer re-solve from the previous
        allocations (moved devices restart from the cold init values of
        their new cell; stayers keep their solution). False = every outer
        solve is cold — bit-reproducible from the assignment alone.
    """
    outer_iters: int = 8
    capacity: Optional[Union[int, Tuple[int, ...]]] = None
    warm_start: bool = True

    def __post_init__(self):
        if self.outer_iters < 0:
            raise ValueError("AssocConfig: outer_iters must be >= 0")
        cap = self.capacity
        if cap is None:
            return
        if isinstance(cap, (list, np.ndarray)):   # keep the dataclass hashable
            object.__setattr__(self, "capacity",
                               tuple(int(c) for c in np.asarray(cap)))
            cap = self.capacity
        caps = cap if isinstance(cap, tuple) else (cap,)
        if any(int(c) < 0 for c in caps):
            raise ValueError("AssocConfig: capacities must be >= 0")

    def per_cell_capacity(self, n_cells: int, n_devices: int) -> np.ndarray:
        """Resolve to an (C,) int array; None means 'fits everyone'."""
        if self.capacity is None:
            cap = np.full(n_cells, n_devices, dtype=np.int64)
        elif isinstance(self.capacity, tuple):
            if len(self.capacity) != n_cells:
                raise ValueError(
                    f"AssocConfig: {len(self.capacity)} capacities for "
                    f"{n_cells} cells")
            cap = np.asarray(self.capacity, dtype=np.int64)
        else:
            cap = np.full(n_cells, int(self.capacity), dtype=np.int64)
        return cap


@dataclasses.dataclass
class AssocResult:
    """Outcome of the association outer loop.

    `objectives[k]` is the accepted global weighted objective after the
    k-th accepted solve (index 0 = the initial association); the sequence
    is non-increasing by the accept/reject construction. `fleet` is the
    final accepted per-cell solve (a `FleetResult`, or a `RegionResult`
    when the problem carried a mesh) over the full (C, N) lanes — lane
    (c, n) is meaningful only where `assignment[n] == c`.
    """
    assignment: np.ndarray          # (N,) int32; -1 = inactive device
    fleet: object                   # FleetResult | RegionResult
    objective: float                # final accepted global objective
    objectives: List[float]         # per accepted solve, non-increasing
    moves: List[int]                # devices moved by each accepted step
    outer_iters: int                # association steps attempted
    converged: bool                 # reached a fixed point before the cap
