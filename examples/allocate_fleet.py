"""Metaverse-scale allocation, two ways:

1. `allocate_fleet`: the full BCD allocator (Algorithm 2) vmap'd across 64
   base-station cells x 2048 AR clients each — one XLA program, no Python
   loop over cells, convergence decided on device.
2. The raw closed-form SP2 path for a single 2^17-client region, with the
   Pallas waterfill kernel doing the batched dual sweep.

    PYTHONPATH=src python examples/allocate_fleet.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import Weights, allocate_fleet, make_fleet, make_system
from repro.core.energy import t_cmp
from repro.core.sp2 import r_min, solve_sp2_direct
from repro.kernels import ops

# --- 1. fleet BCD: 64 cells x 2048 devices in one vmap'd call -------------
C, N_CELL = 64, 2048
key = jax.random.PRNGKey(0)
fleet = make_fleet(key, n_cells=C, n_devices=N_CELL,
                   bandwidth_total=20e6 * N_CELL / 50)

t0 = time.time()
res = allocate_fleet(fleet, Weights(0.5, 0.5, 1.0), max_iters=3)
jax.block_until_ready(res.allocation.bandwidth)
print(f"allocate_fleet: {C} cells x {N_CELL} devices "
      f"({C * N_CELL} AR clients) in {time.time() - t0:.1f}s — "
      f"{int(jnp.sum(res.converged))}/{C} cells converged, "
      f"mean objective {float(jnp.mean(res.objective)):.4g}")

# --- 2. single giant region through the closed-form SP2 solver ------------
N = 1 << 17
system = make_system(key, n_devices=N, bandwidth_total=20e6 * (N / 50))

f = jnp.full((N,), 1e9)
s = jnp.full((N,), 320.0)
T = float(jnp.max(t_cmp(system, f, s))) * 1.2
rmin = r_min(system, f, s, jnp.asarray(T))

t0 = time.time()
p, B = solve_sp2_direct(system, rmin)
jax.block_until_ready(B)
print(f"direct SP2 for {N} devices: {time.time()-t0:.2f}s "
      f"(sum B = {float(B.sum())/1e6:.1f} MHz)")

# the kernelized dual sweep (128 candidate multipliers in one pass) — the
# same batched evaluation `solve_sp2_v2_thm2` now uses for its dual search
nu = jnp.ones((N,))
j = nu * system.bits * system.noise_psd / system.gain
mu = jnp.logspace(-12, -2, 128)
t0 = time.time()
g = ops.waterfill_gprime(mu, j, rmin, system.bandwidth_total, block_n=2048)
jax.block_until_ready(g)
print(f"waterfill dual sweep (128 mu x {N} devices): {time.time()-t0:.2f}s; "
      f"root bracket at mu~{float(mu[int(jnp.argmin(jnp.abs(g)))]):.2e}")
