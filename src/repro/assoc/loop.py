"""BCD-over-association: the cross-cell user association outer loop.

The paper fixes each device to one base station; its multi-cell follow-ups
(arXiv:2212.08324, arXiv:2301.12085) let devices pick a serving cell. This
module layers that choice over the existing per-cell `solve()`:

  1. *association step* — each device greedily picks the cell minimizing
     its marginal weighted cost given the current allocations, under
     per-cell capacity caps (`AssocConfig.capacity`);
  2. *resource step* — the per-cell resources are re-solved for the new
     association through the ONE `solve()` dispatcher.

Representation: a cross-cell problem is a stacked (C, N) `SystemParams`
whose row c holds every device's gain *to cell c*; an association is an
(N,) int array. Cell c's solvable view is the full N-device row with
``active[c, n] = (assign[n] == c)`` (`SystemParams.with_assignment`) —
the PR 4 masking machinery makes each lane solve exactly its members
bit-identically, and every association the loop visits reuses one
compiled (C, N) shape.

A proposed reassignment is accepted only if the realized global objective
(sum of per-cell weighted objectives) strictly improves, so the accepted
objective sequence is non-increasing by construction and the loop
terminates at a fixed point (no proposal, or a rejected one).

All outer-loop bookkeeping (cost matrices, greedy assignment) is
host-side float64 numpy with stable sorts — bit-deterministic across
runs, and off the device stream.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.accuracy import AccuracyModel, default_accuracy
from repro.core.bcd import initial_allocation
from repro.core.types import SystemParams

from .config import AssocConfig, AssocResult

Array = jnp.ndarray

_TINY_RATE = 1e-12   # same guards as core.energy.t_trans / t_cmp
_TINY_FREQ = 1e-9
_TINY_BAND = 1e-9


def _base_active(sysb: SystemParams) -> np.ndarray:
    """(N,) bool: devices that exist at all. A stacked base mask marks a
    device inactive only if NO cell could serve it (all-False column)."""
    N = int(jnp.asarray(sysb.gain).shape[1])
    if sysb.active is None:
        return np.ones(N, dtype=bool)
    return np.asarray(sysb.active).any(axis=0)


def _scal(sysb: SystemParams, name: str, C: int) -> np.ndarray:
    """Per-cell scalar leaf as a host (C, 1) float64 column."""
    v = np.asarray(getattr(sysb, name), np.float64)
    return np.broadcast_to(v.reshape(-1, 1) if v.ndim else v.reshape(1, 1),
                           (C, 1))


def marginal_costs(sysb: SystemParams, warr: np.ndarray, acc: AccuracyModel,
                   alloc, assign: np.ndarray) -> np.ndarray:
    """(C, N) marginal weighted cost of serving device n at cell c.

    The estimate a device n weighs when shopping for a cell c: an equal
    bandwidth share of c's spectrum among its current members (excluding n
    itself), full power/frequency, and n's current resolution from its
    serving cell's solve — i.e. eqs. (1)-(11) evaluated at the prospective
    operating point, combined with cell c's weights:

        cost = R_g (w1 (E_tx + E_cmp) + w2 (T_tx + T_cmp)) - rho a(s_n)

    This is a *proposal* heuristic only — the accept/reject step judges the
    re-solved objective, so an imperfect estimate can never regress the
    realized objective.
    """
    g = np.asarray(sysb.gain, np.float64)                     # (C, N)
    C, N = g.shape
    active = _base_active(sysb)
    cyc = np.asarray(sysb.cycles, np.float64)
    smp = np.asarray(sysb.samples, np.float64)
    bits = np.asarray(sysb.bits, np.float64)

    # device n's current resolution, read from its serving cell's lane
    res = np.asarray(alloc.resolution, np.float64)            # (C, N)
    s_dev = res[np.clip(assign, 0, C - 1), np.arange(N)]      # (N,)

    served = active & (assign >= 0)
    load = np.bincount(assign[served], minlength=C)           # (C,)
    member = assign[None, :] == np.arange(C)[:, None]         # (C, N)
    share = load[:, None] - member + 1.0                      # n joins cell c
    b = _scal(sysb, "bandwidth_total", C) / share
    p = _scal(sysb, "p_max", C)
    n0 = _scal(sysb, "noise_psd", C)
    r = b * np.log2(1.0 + g * p / (n0 * np.maximum(b, _TINY_BAND)))
    t_tx = bits / np.maximum(r, _TINY_RATE)
    e_tx = p * t_tx

    zeta = 1.0 / _scal(sysb, "s_standard", C) ** 2
    cycles_rt = _scal(sysb, "local_iters", C) * zeta \
        * s_dev[None, :] ** 2 * cyc * smp
    f = _scal(sysb, "f_max", C)
    t_cp = cycles_rt / np.maximum(f, _TINY_FREQ)
    e_cp = _scal(sysb, "kappa", C) * cycles_rt * f ** 2

    a_dev = np.asarray(acc.value(jnp.asarray(s_dev)), np.float64)[None, :]
    rg = _scal(sysb, "global_rounds", C)
    w = np.asarray(warr, np.float64).reshape(C, 3)
    cost = rg * (w[:, :1] * (e_tx + e_cp) + w[:, 1:2] * (t_tx + t_cp)) \
        - w[:, 2:3] * a_dev
    return cost


def greedy_assign(cost: np.ndarray, capacity: np.ndarray,
                  active: np.ndarray, order: np.ndarray) -> np.ndarray:
    """Capacity-capped greedy: devices (in `order`) each take their
    cheapest cell with remaining capacity. Stable sorts throughout, so the
    result is bit-deterministic. Raises if capacity cannot cover every
    active device."""
    C, N = cost.shape
    pref = np.argsort(cost, axis=0, kind="stable")            # (C, N)
    assign = np.full(N, -1, dtype=np.int32)
    load = np.zeros(C, dtype=np.int64)
    for n in order:
        if not active[n]:
            continue
        for c in pref[:, n]:
            if load[c] < capacity[c]:
                assign[n] = c
                load[c] += 1
                break
        else:
            raise ValueError(
                "greedy_assign: per-cell capacities cannot serve every "
                "active device (sum(capacity) < active count)")
    return assign


def nearest_assignment(sysb: SystemParams, capacity: np.ndarray
                       ) -> np.ndarray:
    """The static baseline: every device takes its strongest-gain cell
    (capacity-capped; strongest achievable devices place first)."""
    cost = -np.asarray(sysb.gain, np.float64)
    active = _base_active(sysb)
    order = np.argsort(cost.min(axis=0), kind="stable")
    return greedy_assign(cost, capacity, active, order)


def _cell_objectives(sysb: SystemParams, warr, acc: AccuracyModel,
                     alloc) -> np.ndarray:
    """(C,) realized per-cell weighted objective of `alloc` under the
    masked system — eq. (12) per cell, empty cells contribute exactly 0."""
    from repro.core.energy import total_accuracy, total_energy, total_time

    def one(sysc, alloc_c, w_c):
        e = total_energy(sysc, alloc_c)
        t = total_time(sysc, alloc_c)
        a = total_accuracy(acc, alloc_c, sysc.active)
        return w_c[0] * e + w_c[1] * t - w_c[2] * a

    return np.asarray(jax.vmap(one)(sysb, alloc, jnp.asarray(warr)),
                      np.float64)


def _warm_init(prev_alloc, cold_alloc, assign: np.ndarray,
               proposal: np.ndarray, C: int):
    """Warm start for the re-solve of `proposal`: lanes of devices that
    kept their cell reuse the previous solution; moved (and masked) lanes
    take the cold init of the new masked system (a moved device's old lane
    falls back to the masked start B=0, p=pmax, f=fmax, s=s_lo)."""
    from repro.core.types import Allocation

    stay = jnp.asarray((proposal == assign) & (proposal >= 0))
    keep = (jnp.asarray(proposal)[None, :]
            == jnp.arange(C)[:, None]) & stay[None, :]          # (C, N)

    def mix(prev, cold):
        return jnp.where(keep, jnp.asarray(prev), jnp.asarray(cold))

    return Allocation(
        bandwidth=mix(prev_alloc.bandwidth, cold_alloc.bandwidth),
        power=mix(prev_alloc.power, cold_alloc.power),
        freq=mix(prev_alloc.freq, cold_alloc.freq),
        resolution=mix(prev_alloc.resolution, cold_alloc.resolution),
        s_relaxed=None if prev_alloc.s_relaxed is None
        else mix(prev_alloc.s_relaxed, cold_alloc.resolution),
        T=prev_alloc.T)   # (C,): SP1 re-derives T on the first BCD step


def solve_assoc(problem, spec=None, assign0: Optional[np.ndarray] = None
                ) -> AssocResult:
    """Run the BCD-over-association outer loop on a stacked (C, N) problem.

    This is the driver behind ``solve(Problem(..., assoc=AssocConfig()))``;
    call it directly to seed a specific initial association (`assign0`,
    e.g. a previous result's fixed point). The inner per-cell solves go
    through the one `solve()` dispatcher — a `Problem.mesh` shards them
    over the region mesh unchanged.
    """
    from repro.api import Problem, SolverSpec, solve

    spec = SolverSpec() if spec is None else spec
    if spec.max_iters < 1:
        raise ValueError(
            "solve_assoc: the association loop scores re-solved objectives,"
            " so SolverSpec.max_iters must be >= 1")
    cfg = problem.assoc if problem.assoc is not None else AssocConfig()
    sysb = problem.system
    if jnp.ndim(sysb.gain) != 2:
        raise ValueError(
            "solve_assoc: association needs a stacked (C, N) system whose "
            "row c holds every device's gain to cell c (assoc.make_multicell)")
    C, N = (int(d) for d in jnp.asarray(sysb.gain).shape)
    acc = problem.acc if problem.acc is not None else default_accuracy()
    active = _base_active(sysb)
    capacity = cfg.per_cell_capacity(C, N)
    if int(capacity.sum()) < int(active.sum()):
        raise ValueError(
            f"solve_assoc: sum(capacity) = {int(capacity.sum())} cannot "
            f"serve {int(active.sum())} active devices")

    from repro.api.problem import weights_leaf
    warr = np.asarray(weights_leaf(problem.weights, np.float64, cells=C))

    def run(masked: SystemParams, init=None):
        res = solve(Problem(system=masked, weights=problem.weights,
                            acc=acc, init=init, mesh=problem.mesh), spec)
        fleet = res.fleet if hasattr(res, "fleet") else res
        return res, fleet

    if assign0 is None:
        assign = nearest_assignment(sysb, capacity)
    else:
        assign = np.asarray(assign0, np.int32).copy()
        load = np.bincount(assign[active & (assign >= 0)], minlength=C)
        if (load > capacity).any() or (active & (assign < 0)).any():
            raise ValueError("solve_assoc: assign0 is infeasible (capacity "
                             "overrun or unserved active device)")

    masked = sysb.with_assignment(jnp.asarray(assign))
    res, fleet = run(masked)
    obj = float(_cell_objectives(masked, warr, acc, fleet.allocation).sum())
    objectives, moves = [obj], []

    converged = False
    attempted = 0
    for it in range(cfg.outer_iters):
        attempted += 1
        # one obs span per outer association iteration: the inner re-solve's
        # own "solve" span nests under it, so a trace attributes outer-loop
        # time between proposal scoring and the re-solves
        with obs.span("assoc_iter", outer_iter=it):
            cost = marginal_costs(masked, warr, acc, fleet.allocation,
                                  assign)
            cur = cost[np.clip(assign, 0, C - 1), np.arange(N)]
            best = cost.min(axis=0)
            order = np.argsort(-(cur - best), kind="stable")   # biggest saver
            proposal = greedy_assign(cost, capacity, active, order)
            if np.array_equal(proposal, assign):
                converged = True
                break
            new_masked = sysb.with_assignment(jnp.asarray(proposal))
            init = None
            if cfg.warm_start:
                cold = jax.vmap(initial_allocation)(new_masked)
                init = _warm_init(fleet.allocation, cold, assign, proposal, C)
            new_res, new_fleet = run(new_masked, init=init)
            new_obj = float(_cell_objectives(new_masked, warr, acc,
                                             new_fleet.allocation).sum())
            if new_obj < obj:
                moves.append(int(np.sum(proposal != assign)))
                assign, masked = proposal, new_masked
                res, fleet, obj = new_res, new_fleet, new_obj
                objectives.append(obj)
            else:
                converged = True   # the greedy proposal no longer helps
                break
    else:
        # outer_iters == 0 never proposes: the init IS the fixed point asked
        converged = cfg.outer_iters == 0

    return AssocResult(assignment=assign, fleet=res, objective=obj,
                       objectives=objectives, moves=moves,
                       outer_iters=attempted, converged=converged)
