"""Mamba selective-scan Pallas TPU kernel (chunked, state in VMEM scratch).

Grid (B, D/bd, T/L) — sequential over chunks; the per-(channel-block) state
h (bd, N) persists in VMEM. Within a chunk the linear recurrence
h_t = a_t h_{t-1} + u_t is evaluated with the log-space prefix trick:
    cla_t = cumsum(log a), h_t = exp(cla_t) (h_0 + sum_{tau<=t} exp(-cla_tau) u_tau)
computed stably by factoring exp(cla_t - cla_tau) <= ... note a_t<1 makes
exp(-cla_tau) grow with tau; we therefore use the pairwise-difference form
via an in-chunk sequential fori over a SMALL fixed chunk (cheap: L<=64) —
each step is a fused (bd, N) FMA on VREGs, no MXU needed.

Oracle: kernels.ref.mamba_scan_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mamba_kernel(dt_ref, a_ref, b_ref, c_ref, x_ref, y_ref, h_scr, *,
                  chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)        # (L, bd)
    A = a_ref[...].astype(jnp.float32)        # (bd, N)
    Bt = b_ref[0].astype(jnp.float32)         # (L, N)
    Ct = c_ref[0].astype(jnp.float32)         # (L, N)
    x = x_ref[0].astype(jnp.float32)          # (L, bd)

    def step(t, carry):
        h, y = carry
        a = jnp.exp(dt[t][:, None] * A)                    # (bd, N)
        h = a * h + dt[t][:, None] * Bt[t][None, :] * x[t][:, None]
        y = y.at[t].set(h @ Ct[t])                         # (bd,)
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros((x.shape[0], x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, x.shape[0], step, (h0, y0))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)


def mamba_scan(dt: jax.Array, A: jax.Array, Bt: jax.Array, Ct: jax.Array,
               x: jax.Array, *, chunk: int = 64, block_d: int = 256,
               interpret: bool = False) -> jax.Array:
    """dt, x: (B,T,D); A: (D,N); Bt,Ct: (B,T,N). T % chunk == 0,
    D % block_d == 0. Returns y (B,T,D) float32."""
    B, T, D = x.shape
    N = A.shape[1]
    assert T % chunk == 0 and D % block_d == 0
    n_chunks, n_d = T // chunk, D // block_d

    kernel = functools.partial(_mamba_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, n_d, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((block_d, N), lambda b, d, j: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, j: (b, j, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, j: (b, j, d)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        scratch_shapes=[_vmem((block_d, N))],
        interpret=interpret,
    )(dt, A, Bt, Ct, x)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
