"""Whisper large-v3 — encoder-decoder audio model; the mel+conv frontend is a
STUB per the assignment: input_specs provides precomputed 1500-frame
embeddings. [arXiv:2212.04356]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", arch_type="audio",
    n_layers=32, d_model=1280, n_heads=20, kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    block_pattern=("attn_cross",),   # decoder: self-attn + cross-attn + mlp
    encoder_layers=32, encoder_ctx=1500,
    source="arXiv:2212.04356",
)
