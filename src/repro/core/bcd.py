"""Algorithm 2: full BCD resource-allocation loop (paper §V-D)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from . import energy as en
from .accuracy import AccuracyModel, default_accuracy
from .sp1 import solve_sp1, solve_sp1_fixed_T
from .sp2 import SP2Result, r_min, solve_sp2, solve_sp2_direct
from .types import Allocation, SystemParams, Weights

Array = jnp.ndarray


@dataclasses.dataclass
class BCDResult:
    allocation: Allocation
    objective: float
    history: List[dict]
    iters: int
    converged: bool


def initial_allocation(sys: SystemParams, key: Optional[jax.Array] = None,
                       bandwidth_frac: float = 1.0) -> Allocation:
    """Feasible start: p = pmax, B = B/N (paper init; Fig. 9 uses B/(2N))."""
    n = sys.n
    return Allocation(
        bandwidth=jnp.full((n,), sys.bandwidth_total / n * bandwidth_frac),
        power=jnp.full((n,), sys.p_max),
        freq=jnp.full((n,), sys.f_max),
        resolution=jnp.full((n,), sys.s_lo),
    )


def allocate(sys: SystemParams, w: Weights, acc: Optional[AccuracyModel] = None,
             max_iters: int = 20, tol: float = 1e-6,
             init: Optional[Allocation] = None,
             sp2_iters: int = 30, sp2_method: str = "direct") -> BCDResult:
    """Algorithm 2: alternate SP1 (f, s, T) and SP2 (p, B) until convergence.

    sp2_method: "direct" (exact boundary-power convex solve, beyond-paper,
    the default engine) or "jong" (the paper's Algorithm 1 Newton-like loop).
    """
    acc = acc if acc is not None else default_accuracy()
    w = w.normalized()
    alloc = init if init is not None else initial_allocation(sys)
    history: List[dict] = []
    prev = alloc.flat()
    converged = False
    k = 0
    for k in range(1, max_iters + 1):
        f, s, s_hat, T = solve_sp1(sys, w, acc, alloc.bandwidth, alloc.power)
        rmin = r_min(sys, f, s, T)
        if sp2_method == "direct":
            p_new, B_new = solve_sp2_direct(sys, rmin)
            sp2 = SP2Result(power=p_new, bandwidth=B_new, nu=None, beta=None,
                            iters=0, residual=0.0)
        else:
            sp2 = solve_sp2(sys, w, rmin, alloc.power, alloc.bandwidth,
                            max_iters=sp2_iters)
        alloc = Allocation(bandwidth=sp2.bandwidth, power=sp2.power,
                           freq=f, resolution=s, s_relaxed=s_hat, T=T)
        history.append(dict(
            iter=k,
            objective=float(en.objective(sys, w, acc, alloc)),
            energy=float(en.total_energy(sys, alloc)),
            time=float(en.total_time(sys, alloc)),
            accuracy=float(en.total_accuracy(acc, alloc)),
            sp2_iters=sp2.iters, sp2_residual=sp2.residual,
        ))
        cur = alloc.flat()
        rel = float(jnp.linalg.norm(cur - prev) / jnp.maximum(jnp.linalg.norm(prev), 1e-12))
        prev = cur
        if rel <= tol:
            converged = True
            break
    return BCDResult(allocation=alloc,
                     objective=history[-1]["objective"] if history else float("nan"),
                     history=history, iters=k, converged=converged)


def _optimal_split(sys: SystemParams, s: Array, bandwidth: Array,
                   T_round: float, iters: int = 48) -> Array:
    """Per-device golden-section over the transmission-time share tt of the
    round deadline:  E(tt) = kappa cyc^3 / (T-tt)^2 + E_trans_min(tt | B),
    both terms convex. Returns tt* clipped to the feasible window."""
    gold = 0.6180339887498949
    cyc = sys.local_iters * sys.zeta * s ** 2 * sys.cycles * sys.samples

    def energy(tt):
        f = jnp.clip(cyc / jnp.maximum(T_round - tt, 1e-9), sys.f_min, sys.f_max)
        e_cmp = sys.kappa * cyc * f ** 2
        r_req = sys.bits / jnp.maximum(tt, 1e-9)
        theta = jnp.exp2(r_req / jnp.maximum(bandwidth, 1e-9)) - 1.0
        p = jnp.clip(theta * sys.noise_psd * bandwidth / sys.gain,
                     sys.p_min, sys.p_max)
        return e_cmp + p * tt

    tt_min = sys.bits / jnp.maximum(
        bandwidth * jnp.log2(1.0 + sys.gain * sys.p_max
                             / (sys.noise_psd * jnp.maximum(bandwidth, 1e-9))),
        1e-12)
    a = jnp.minimum(tt_min, 0.95 * T_round)
    b = jnp.full_like(a, 0.95 * T_round)
    for _ in range(iters):
        c = b - gold * (b - a)
        d = a + gold * (b - a)
        left = energy(c) < energy(d)
        a = jnp.where(left, a, c)
        b = jnp.where(left, d, b)
    return jnp.clip(0.5 * (a + b), tt_min, 0.95 * T_round)


def allocate_fixed_deadline(sys: SystemParams, w: Weights, T_total: float,
                            acc: Optional[AccuracyModel] = None,
                            max_iters: int = 20, tol: float = 1e-6,
                            init: Optional[Allocation] = None,
                            bandwidth_frac: float = 1.0) -> BCDResult:
    """Deadline-constrained variant (Figs. 8-9): total completion time is a hard
    constraint, the objective is (mostly) energy: w1 ~ 0.99, w2 ~ 0.01."""
    acc = acc if acc is not None else default_accuracy()
    w = w.normalized()
    T_round = T_total / sys.global_rounds
    alloc = init if init is not None else initial_allocation(sys, bandwidth_frac=bandwidth_frac)
    history: List[dict] = []
    prev = alloc.flat()
    converged = False
    k = 0
    for k in range(1, max_iters + 1):
        f, s = solve_sp1_fixed_T(sys, w, acc, alloc.bandwidth, alloc.power, T_round)
        # Break the BCD split deadlock: with a hard deadline, SP1 pins
        # t_cmp = T - t_trans(current p, B), so SP2's rate floor equals the
        # current rate and (p, B) can never move. Re-derive the floor from the
        # per-device OPTIMAL compute/transmit split (convex in t_trans:
        # E_cmp = kappa cyc^3/(T-tt)^2 rises, E_trans falls; golden section).
        tt_opt = _optimal_split(sys, s, alloc.bandwidth, float(T_round))
        rmin = sys.bits / tt_opt
        p_new, B_new = solve_sp2_direct(sys, rmin)
        # recompute f against the achieved transmission time
        from .energy import rate as _rate
        tt_new = sys.bits / jnp.maximum(_rate(sys, B_new, p_new), 1e-12)
        cyc = sys.local_iters * sys.zeta * s ** 2 * sys.cycles * sys.samples
        f = jnp.clip(cyc / jnp.maximum(T_round - tt_new, 1e-9),
                     sys.f_min, sys.f_max)
        alloc = Allocation(bandwidth=B_new, power=p_new,
                           freq=f, resolution=s, T=jnp.asarray(T_round))
        history.append(dict(
            iter=k,
            energy=float(en.total_energy(sys, alloc)),
            time=float(en.total_time(sys, alloc)),
            accuracy=float(en.total_accuracy(acc, alloc)),
        ))
        cur = alloc.flat()
        rel = float(jnp.linalg.norm(cur - prev) / jnp.maximum(jnp.linalg.norm(prev), 1e-12))
        prev = cur
        if rel <= tol:
            converged = True
            break
    return BCDResult(allocation=alloc, objective=history[-1]["energy"],
                     history=history, iters=k, converged=converged)
