"""Local client training (the "R_l local iterations" of the paper's FL model).

A client trains on its own shard for `local_iters` full-batch gradient steps
(the paper's local iteration uses all D_n samples, §III), at the video-frame
resolution the allocator chose for it. jitted + vmap-able across clients.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.cnn import xent_loss

Params = dict


@partial(jax.jit, static_argnames=("local_iters",))
def local_train(params: Params, images: jax.Array, labels: jax.Array,
                lr: float, local_iters: int) -> Tuple[Params, jax.Array]:
    """Full-batch SGD for `local_iters` steps on one client's rendered data.

    images: (D_n, s, s, 1) already rendered at the allocated resolution.
    Returns (new_params, final_loss).
    """
    grad_fn = jax.value_and_grad(xent_loss)

    def step(carry, _):
        p, _ = carry
        loss, g = grad_fn(p, images, labels)
        p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
        return (p, loss), loss

    (params, loss), _ = jax.lax.scan(step, (params, jnp.asarray(0.0)),
                                     None, length=local_iters)
    return params, loss


def client_delta(params_before: Params, params_after: Params) -> Params:
    return jax.tree_util.tree_map(lambda a, b: b - a, params_before, params_after)
