"""Weight auto-tuning: descend the solver's own gradient to hit a target.

The paper's scalarization (w1 E + w2 T - rho A) leaves the operator with an
inverse problem: *which weights* make the realized allocation meet a latency
budget at minimum energy? With `solve_and_grad` the chain

    raw (w1, w2)  ->  normalized weights  ->  BCD fixed point  ->  (E, T)

is differentiable end to end, so the tuner is plain projected gradient
descent on the log-raw weights against the penalty scalarization

    L(w) = E(w) / E_ref  +  penalty * max(0, T(w) / target - 1)^2

(`E_ref` is the energy at the starting weights, making the two terms
commensurate). rho is held fixed: it prices accuracy, which the latency
budget says nothing about — but note the normalization divides rho by
w1 + w2, so jointly scaling (w1, w2) still re-weights accuracy and the
descent has two genuine degrees of freedom.

The loop runs on the host and re-enters the SAME jitted grad program each
step (weights are traced operands, never jit keys — zero recompiles); each
iterate is one solve + one backward pass. `target_from_slos` bridges the
SLO plane: an `obs.slo.LatencyObjective` threshold, interpreted as a
per-global-round deadline, becomes the tuner's `target_time` — drive the
tuner until the allocation the SLO would judge stops burning error budget.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..api.problem import Problem
from ..api.spec import SolverSpec
from ..core.types import Weights
from .implicit import solve_and_grad

__all__ = ["TuneResult", "target_from_slos", "tune_weights"]

#: log-space box for the raw (w1, w2) iterates: wide enough for any
#: sensible trade-off, tight enough to keep the normalized rho finite
_Z_LO, _Z_HI = math.log(1e-3), math.log(1e3)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of `tune_weights`.

    weights : the best raw `Weights` found (feed them straight back into a
        `Problem` — the solvers normalize internally).
    value : realized metrics at those weights (objective/energy/time/
        accuracy, host floats).
    target_time : the latency budget tuned against.
    met : whether the returned weights meet the budget (time <= target).
    steps : gradient steps actually taken.
    history : one dict per step (w1, w2, energy, time, loss, violation) —
        ready for plotting / assertions.
    """
    weights: Weights
    value: Dict[str, float]
    target_time: float
    met: bool
    steps: int
    history: Tuple[Dict[str, float], ...]


def target_from_slos(slos: Sequence, global_rounds: float = 1.0) -> float:
    """Latency budget implied by an SLO set (`obs.slo`).

    Scans for the first objective exposing `threshold_s` (a
    `LatencyObjective`) and scales it by `global_rounds`: the SLO speaks
    per-round service latency, the allocator's T is the full training
    makespan. Keeping the allocation's per-round share under the threshold
    is what drives that SLO's burn rate toward zero.
    """
    for slo in slos:
        src = getattr(slo, "source", slo)
        thr = getattr(src, "threshold_s", None)
        if thr is not None:
            return float(thr) * float(global_rounds)
    raise ValueError(
        "target_from_slos: no latency objective (threshold_s) in the SLO "
        "set — pass target_time explicitly")


def tune_weights(problem: Problem, spec: Optional[SolverSpec] = None, *,
                 target_time: Optional[float] = None,
                 slos: Optional[Sequence] = None,
                 steps: int = 24, lr: float = 0.3, penalty: float = 40.0,
                 adjoint_iters: int = 30) -> TuneResult:
    """Tune (w1, w2) so the realized allocation hits `target_time` at
    minimum energy (module docstring). Exactly one of `target_time` /
    `slos` must be given. Returns the best iterate seen: the lowest-energy
    feasible one, or the least-infeasible one when the budget was never
    met within `steps`.
    """
    if (target_time is None) == (slos is None):
        raise ValueError(
            "tune_weights: pass exactly one of target_time= or slos=")
    if target_time is None:
        target_time = target_from_slos(
            slos, float(np.max(np.asarray(problem.system.global_rounds))))
    if target_time <= 0:
        raise ValueError(f"tune_weights: target_time must be positive, "
                         f"got {target_time}")
    if problem.cells is not None:
        raise ValueError("tune_weights: single-cell problems only "
                         "(sweep fleets with diff.pareto instead)")

    w = problem.weights if isinstance(problem.weights, Weights) \
        else Weights(*np.asarray(problem.weights, float))
    wr = np.asarray([float(w.w1), float(w.w2), float(w.rho)], float)
    z = np.clip(np.log(wr[:2]), _Z_LO, _Z_HI)

    e_ref = None
    best = None          # (feasible, key, wr, value)
    history = []
    taken = 0
    for _ in range(steps):
        taken += 1
        wr[:2] = np.exp(z)
        g = solve_and_grad(
            dataclasses.replace(problem, weights=Weights(*wr)),
            spec, wrt=(), adjoint_iters=adjoint_iters)
        val = {m: float(v) for m, v in g.value.items()}
        energy, t = val["energy"], val["time"]
        if e_ref is None:
            e_ref = max(energy, 1e-30)
        viol = max(t / target_time - 1.0, 0.0)
        loss = energy / e_ref + penalty * viol ** 2
        history.append(dict(w1=wr[0], w2=wr[1], energy=energy, time=t,
                            loss=loss, violation=viol))
        if not math.isfinite(loss):
            break
        feasible = viol <= 0.0
        key = energy if feasible else viol
        if best is None or (feasible, ) > (best[0], ) \
                or (feasible == best[0] and key < best[1]):
            best = (feasible, key, wr.copy(), val)

        d_e = np.asarray(g.grads["energy"]["weights"], float)
        d_t = np.asarray(g.grads["time"]["weights"], float)
        d_l = d_e / e_ref + 2.0 * penalty * viol * d_t / target_time
        dz = d_l[:2] * wr[:2]             # chain rule through w = exp(z)
        if feasible and float(np.max(np.abs(dz))) < 1e-4:
            break                          # on budget, locally stationary
        z = np.clip(z - lr * dz, _Z_LO, _Z_HI)

    assert best is not None, "tune_weights: zero steps requested"
    feasible, _, wr_best, val_best = best
    return TuneResult(weights=Weights(*wr_best), value=val_best,
                      target_time=float(target_time), met=bool(feasible),
                      steps=taken, history=tuple(history))
