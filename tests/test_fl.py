"""FL substrate tests: FedAvg math, resolution mechanism, simulator ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests degrade to skips
    from _hypothesis_stub import given, settings, st

from repro.core import Weights, make_system
from repro.fl import (fedavg, local_train, make_eval_set,
                      make_federated_dataset, render, run_federated, simulate)
from repro.models.cnn import accuracy, apply_cnn, init_cnn, xent_loss


def test_fedavg_weighted_mean():
    p1 = {"a": jnp.ones((3,)), "b": {"c": jnp.zeros((2,))}}
    p2 = {"a": jnp.zeros((3,)), "b": {"c": jnp.ones((2,))}}
    avg = fedavg([p1, p2], jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(avg["a"]), 0.75)
    np.testing.assert_allclose(np.asarray(avg["b"]["c"]), 0.25)


def test_fedavg_single_client_equals_local():
    """With one client, FedAvg == plain local training (oracle property)."""
    key = jax.random.PRNGKey(0)
    ds = make_federated_dataset(key, n_clients=1, per_client=32,
                                num_classes=4, base_resolution=16)
    r = run_federated(jax.random.PRNGKey(1), ds, [16], global_rounds=3,
                      local_iters=2, lr=0.05, eval_n=64)
    k_init, _ = jax.random.split(jax.random.PRNGKey(1))  # mirror run_federated
    params = init_cnn(k_init, num_classes=4)
    imgs = render(ds.images[0], 16)
    for _ in range(3):
        params, _ = local_train(params, imgs, ds.labels[0], 0.05, 2)
    leaves1 = jax.tree_util.tree_leaves(r.params)
    leaves2 = jax.tree_util.tree_leaves(params)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_render_shapes_and_identity():
    key = jax.random.PRNGKey(2)
    ds = make_federated_dataset(key, n_clients=2, per_client=8,
                                base_resolution=16)
    assert render(ds.images, 8).shape == (2, 8, 8, 8, 1)
    np.testing.assert_array_equal(np.asarray(render(ds.images, 16)),
                                  np.asarray(ds.images))


def test_render_block_mean():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    y = render(x, 2)
    np.testing.assert_allclose(np.asarray(y)[0, :, :, 0],
                               [[2.5, 4.5], [10.5, 12.5]])


def test_resolution_accuracy_monotone_fast():
    """Low-res rendering must destroy class evidence (linear-probe check —
    fast proxy for the full training sweep in benchmarks fig7)."""
    key = jax.random.PRNGKey(3)
    ds = make_federated_dataset(key, n_clients=4, per_client=128,
                                num_classes=4, base_resolution=16)
    ev_i, ev_l = make_eval_set(jax.random.fold_in(key, 9), ds, n=512)

    def ridge_acc(res):
        tr = np.asarray(render(ds.images, res)).reshape(4 * 128, -1)
        te = np.asarray(render(ev_i, res)).reshape(512, -1)
        ytr = np.asarray(ds.labels).reshape(-1)
        # one-vs-all ridge regression
        A = tr.T @ tr + 1e-1 * np.eye(tr.shape[1])
        Y = np.eye(4)[ytr]
        Wm = np.linalg.solve(A, tr.T @ Y)
        pred = te @ Wm
        return float((pred.argmax(1) == np.asarray(ev_l)).mean())

    a4, a16 = ridge_acc(4), ridge_acc(16)
    assert a16 > a4 + 0.05, (a4, a16)


def test_noniid_hurts():
    key = jax.random.PRNGKey(4)
    kw = dict(n_clients=4, per_client=64, num_classes=4, base_resolution=16)
    ds_iid = make_federated_dataset(key, split="iid", **kw)
    ds_non = make_federated_dataset(key, split="noniid-1", **kw)
    r_iid = run_federated(jax.random.PRNGKey(5), ds_iid, [16] * 4,
                          global_rounds=8, local_iters=3, lr=0.1, eval_n=128)
    r_non = run_federated(jax.random.PRNGKey(5), ds_non, [16] * 4,
                          global_rounds=8, local_iters=3, lr=0.1, eval_n=128)
    assert r_iid.round_accuracy[-1] >= r_non.round_accuracy[-1] - 0.02


def test_simulator_ledger_consistent():
    key = jax.random.PRNGKey(6)
    sysp = make_system(key, n_devices=4)
    res = simulate(jax.random.fold_in(key, 1), sysp, Weights(0.5, 0.5, 10.0),
                   dataset_resolutions=(4, 8, 12, 16), global_rounds=2,
                   local_iters=2)
    led = res.ledger
    assert led["energy_total_J"] == pytest.approx(
        led["energy_per_round_J"] * 2, rel=1e-6)
    assert led["time_total_s"] > 0 and np.isfinite(led["final_accuracy"])


def test_cnn_resolution_agnostic():
    key = jax.random.PRNGKey(7)
    p = init_cnn(key, num_classes=5)
    for r in (4, 8, 16):
        x = jax.random.normal(key, (3, r, r, 1))
        assert apply_cnn(p, x).shape == (3, 5)


@given(st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_property_fedavg_preserves_scale(seed):
    key = jax.random.PRNGKey(seed)
    ps = [init_cnn(jax.random.fold_in(key, i), num_classes=3) for i in range(3)]
    wts = jnp.abs(jax.random.normal(key, (3,))) + 0.1
    avg = fedavg(ps, wts)
    for leaf, *others in zip(jax.tree_util.tree_leaves(avg),
                             *[jax.tree_util.tree_leaves(p) for p in ps]):
        lo = np.minimum.reduce([np.asarray(o) for o in others])
        hi = np.maximum.reduce([np.asarray(o) for o in others])
        assert (np.asarray(leaf) >= lo - 1e-6).all()
        assert (np.asarray(leaf) <= hi + 1e-6).all()
