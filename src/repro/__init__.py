"""repro — FL-MAR resource allocation as a production-scale JAX system.

One solver, one entry point::

    from repro import Problem, SolverSpec, Weights, make_system, solve

    sys_ = make_system(key, n_devices=50)
    res = solve(Problem(system=sys_, weights=Weights(0.5, 0.5, 1.0)),
                SolverSpec(max_iters=8, tol=1e-4))

`solve(problem, spec)` routes on `Problem` topology — single cell (BCD),
stacked ``(C, N)`` fleet (vmap), ``mesh`` (region shard_map), ``rounds``
(dynamics scan), ``deadline`` (Figs. 8-9 variant). `SolverSpec` is frozen
and hashable: it (plus shapes) is the entire jit-cache key. Weights are a
traced ``(3,)``/``(C, 3)`` operand — per-cell / per-request weights never
recompile.

Migration table (legacy shim -> unified call). Every legacy signature
still works, delegates verbatim (bit-identical results), and warns
`DeprecationWarning` once per process:

    ================================  =====================================
    legacy call                        solve(Problem(...), SolverSpec(...))
    ================================  =====================================
    allocate(sys, w, ...)              Problem(system=sys, weights=w)
    allocate_fleet(batch, w, ...)      Problem(system=batch, weights=w)
    allocate_region(batch, w, mesh)    Problem(system=batch, weights=w,
                                               mesh=mesh)
    run_rounds(key, sys, w, cfg)       Problem(system=sys, weights=w,
                                               rounds=cfg, key=key)
    run_rounds_fleet(key, batch, ...)  Problem(system=batch, weights=w,
                                               rounds=cfg, key=key)
    run_rounds_region(key, ..., mesh)  Problem(..., rounds=cfg, key=key,
                                               mesh=mesh)
    allocate_fixed_deadline(sys, w,    Problem(system=sys, weights=w,
        T_total, ...)                          deadline=T_total)
    ================================  =====================================

    old kwarg (any entry point)        SolverSpec field
    ================================  =====================================
    max_iters / tol                    max_iters / tol (tol validated
                                       against the 64-ulp rel-step floor)
    sp1_method / sp2_method            sp1_method / sp2_method
    sp2_iters                          sp2_iters
    keep_history                       keep_history
    lockstep (region)                  lockstep
    init / acc / w                     Problem.init / Problem.acc /
                                       Problem.weights (traced data,
                                       not cache keys)
    ================================  =====================================

Subpackages: `repro.core` (paper model + jitted solvers), `repro.region`
(bucketed, mesh-sharded serving), `repro.dynamics` (round engine +
mobility traces), `repro.assoc` (cross-cell user association),
`repro.fl` (FedAvg coupling), `repro.kernels` (Pallas kernels),
`repro.diff` (implicit-KKT gradients: `solve_and_grad`, weight
auto-tuning, Pareto sweeps, learned accuracy surrogates),
`repro.obs` (telemetry: spans, metrics, SLO plane, scrape endpoint).
"""
from repro.api import (Problem, SolverSpec, TolFloorWarning, WeightsLike,
                       rel_step_floor, solve, weights_leaf)
from repro.assoc import (AssocConfig, AssocResult, make_multicell,
                         solve_assoc)
from repro.core import (AccuracyModel, Allocation, BCDResult, FleetResult,
                        SystemParams, Weights, allocate,
                        allocate_fixed_deadline, allocate_fleet,
                        default_accuracy, make_fleet, make_system,
                        stack_systems)
from repro.dynamics import (MobilityConfig, MobilityTrace, RoundsConfig,
                            RoundsResult, replay_mobility, run_rounds,
                            run_rounds_fleet, simulate_mobility)
from repro.region import (AllocationRequest, CellResponse, CloseOnFull,
                          DeadlineSlack, MaxWait, PendingResponse,
                          RegionAllocator, RegionPipeline, RegionResult,
                          StageClocks, allocate_region, region_mesh,
                          run_rounds_region)

__all__ = [
    # unified API
    "Problem", "SolverSpec", "TolFloorWarning", "WeightsLike",
    "rel_step_floor", "solve", "weights_leaf",
    # core types + builders
    "AccuracyModel", "Allocation", "BCDResult", "FleetResult",
    "SystemParams", "Weights", "default_accuracy", "make_fleet",
    "make_system", "stack_systems",
    # dynamics / region
    "RoundsConfig", "RoundsResult", "AllocationRequest", "CellResponse",
    "RegionAllocator", "RegionResult", "region_mesh",
    # cross-cell association + mobility churn
    "AssocConfig", "AssocResult", "solve_assoc", "make_multicell",
    "MobilityConfig", "MobilityTrace", "simulate_mobility",
    "replay_mobility",
    # region serving pipeline (admission policies + async futures)
    "RegionPipeline", "PendingResponse", "StageClocks",
    "CloseOnFull", "MaxWait", "DeadlineSlack",
    # legacy shims (deprecated; see the migration table above)
    "allocate", "allocate_fixed_deadline", "allocate_fleet",
    "allocate_region", "run_rounds", "run_rounds_fleet",
    "run_rounds_region",
]
