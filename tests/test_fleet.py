"""Regression + parity tests for the jit-resident BCD stack:
  * allocate_fixed_deadline(max_iters=0) returns nan instead of IndexError
  * waterfill_gprime accepts N not divisible by block_n (padded tail block)
  * the kernelized thm2 dual search matches the old scalar float() bisection
  * allocate_fleet (vmap'd BCD) is consistent with per-cell allocate
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Weights, allocate, allocate_fixed_deadline,
                        allocate_fleet, feasible, make_fleet, make_system,
                        stack_systems)
from repro.core.lambertw import lambertw0
from repro.core.sp2 import G, _clamp_rmin, solve_sp2_v2_thm2
from repro.kernels import ops, ref
from repro.kernels.waterfill import waterfill_gprime as waterfill_raw


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_fixed_deadline_zero_iters_returns_nan():
    """max_iters=0 used to raise IndexError on history[-1]."""
    sysp = make_system(jax.random.PRNGKey(0), n_devices=4)
    res = allocate_fixed_deadline(sysp, Weights(0.99, 0.01, 1.0), 100.0,
                                  max_iters=0)
    assert res.iters == 0
    assert res.history == []
    assert np.isnan(res.objective)
    # the initial allocation is handed back untouched
    assert res.allocation.bandwidth.shape == (4,)


def test_allocate_zero_iters_returns_nan():
    sysp = make_system(jax.random.PRNGKey(0), n_devices=4)
    res = allocate(sysp, Weights(0.5, 0.5, 1.0), max_iters=0)
    assert res.iters == 0 and res.history == [] and np.isnan(res.objective)


def test_warm_start_converges_in_fewer_iterations():
    """allocate(init=...) on a slightly perturbed system must beat the cold
    start: the warm-started BCD re-uses the previous solution (the round-
    dynamics engine's per-round re-allocation path)."""
    w = Weights(0.5, 0.5, 1.0)
    sysp = make_system(jax.random.PRNGKey(40), n_devices=12)
    # tol=1e-8: at the default 1e-6 the cold BCD already converges in ~2
    # iterations and there is no headroom to demonstrate the warm start
    base = allocate(sysp, w, max_iters=40, tol=1e-8)
    assert base.converged
    # ~2% channel perturbation, as between consecutive correlated rounds
    bump = 1.0 + 0.02 * jnp.sin(jnp.arange(12.0))
    sys2 = sysp.replace(gain=sysp.gain * bump)
    cold = allocate(sys2, w, max_iters=40, tol=1e-8)
    warm = allocate(sys2, w, max_iters=40, tol=1e-8, init=base.allocation)
    assert warm.converged and cold.converged
    assert warm.iters < cold.iters, (warm.iters, cold.iters)
    # and lands at the same objective
    assert warm.objective == pytest.approx(cold.objective, rel=1e-4)


def test_allocate_fleet_warm_start_init():
    """allocate_fleet(init=...) warm-starts every cell; a perturbed fleet
    re-solve from the previous FleetResult takes fewer iterations."""
    w = Weights(0.5, 0.5, 1.0)
    fleet = make_fleet(jax.random.PRNGKey(41), n_cells=4, n_devices=16)
    base = allocate_fleet(fleet, w, max_iters=40, tol=1e-8)
    fleet2 = fleet.replace(gain=fleet.gain * 1.02)
    cold = allocate_fleet(fleet2, w, max_iters=40, tol=1e-8)
    warm = allocate_fleet(fleet2, w, max_iters=40, tol=1e-8,
                          init=base.allocation)
    # the warm start converges everywhere; cold may still be grinding at the
    # iteration cap — that asymmetry is the point
    assert bool(jnp.all(warm.converged))
    assert int(jnp.sum(warm.iters)) < int(jnp.sum(cold.iters))
    np.testing.assert_allclose(np.asarray(warm.objective),
                               np.asarray(cold.objective), rtol=1e-4)


@pytest.mark.parametrize("N,block", [(1000, 256), (7, 1024), (1500, 1024)])
def test_waterfill_padded_tail_matches_ref(N, block):
    """N % block_n != 0 used to hard-assert; the padded tail must be a no-op."""
    key = jax.random.PRNGKey(5)
    j = jnp.abs(jax.random.normal(key, (N,))) * 1e-3 + 1e-5
    rmin = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (N,))) * 1e5
    mu = jnp.logspace(-8, 0, 16)
    g_pal = ops.waterfill_gprime(mu, j, rmin, 20e6, block_n=block,
                                 impl="pallas")
    g_ref = ref.waterfill_gprime_ref(mu, j, rmin, 20e6)
    err = np.abs(np.asarray(g_pal - g_ref)) / np.maximum(np.abs(np.asarray(g_ref)), 1.0)
    # f32 kernel vs f64 oracle; the <=1e-5 acceptance bound is checked at
    # matched precision in test_waterfill_f64_interpret_parity
    assert err.max() <= 2e-5


def test_waterfill_f64_interpret_parity():
    """Acceptance bound: kernel vs oracle to <= 1e-5 relative error."""
    key = jax.random.PRNGKey(9)
    N = 768
    j = jnp.abs(jax.random.normal(key, (N,))) * 1e-3 + 1e-5
    rmin = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (N,))) * 1e5
    mu = jnp.logspace(-8, 0, 32)
    g = waterfill_raw(mu, j, rmin, 20e6, block_n=256, interpret=True,
                      dtype=jnp.float64)
    g_ref = ref.waterfill_gprime_ref(mu, j, rmin, 20e6)
    err = np.abs(np.asarray(g - g_ref)) / np.maximum(np.abs(np.asarray(g_ref)), 1.0)
    assert err.max() <= 1e-5


# ---------------------------------------------------------------------------
# thm2 kernelized dual search vs the old scalar bisection
# ---------------------------------------------------------------------------

def _scalar_bisection_thm2(sysp, nu, beta, rmin):
    """The pre-refactor host-side path: 200-step bracket expansion + 96
    float() bisections on g'(mu), then the Theorem-2 closed forms."""
    g_lin, d, N0 = np.asarray(sysp.gain), np.asarray(sysp.bits), sysp.noise_psd
    nu_np, beta_np = np.asarray(nu), np.asarray(beta)
    rm = np.asarray(rmin)
    j = nu_np * d * N0 / g_lin

    def gprime(mu):
        wv = np.asarray(lambertw0(jnp.asarray((mu - j) / (np.e * j))))
        return float(np.sum(rm * np.log(2.0) / np.maximum(wv + 1.0, 1e-12))
                     - sysp.bandwidth_total)

    lo, hi = 1e-30, float(j.max()) * 2.0 + 1.0
    for _ in range(200):
        if gprime(hi) < 0.0:
            break
        hi *= 4.0
    for _ in range(96):
        mid = 0.5 * (lo + hi)
        if gprime(mid) > 0.0:
            lo = mid
        else:
            hi = mid
    mu = 0.5 * (lo + hi)

    W = np.asarray(lambertw0(jnp.asarray((mu - j) / (np.e * j))))
    a_val = np.where(np.abs(W) > 1e-12,
                     (mu - j) * np.log(2.0) / np.where(np.abs(W) < 1e-12, 1.0, W),
                     np.e * j * np.log(2.0))
    tau = np.maximum(a_val - nu_np * beta_np, 0.0)
    a = nu_np * beta_np + tau
    Lam = np.maximum(a * g_lin / (N0 * d * nu_np * np.log(2.0)), 1.0 + 1e-12)
    B_opt = rm / np.log2(Lam)
    total = float(B_opt.sum())
    if total > sysp.bandwidth_total:
        B_opt = B_opt * sysp.bandwidth_total / total
    p_opt = np.clip((Lam - 1.0) * N0 * B_opt / g_lin, sysp.p_min, sysp.p_max)
    return p_opt, B_opt


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_thm2_kernelized_matches_scalar_bisection(seed):
    n = 8
    sysp = make_system(jax.random.PRNGKey(seed), n_devices=n)
    B0 = jnp.full((n,), sysp.bandwidth_total / n)
    p0 = jnp.full((n,), sysp.p_max)
    rmin = _clamp_rmin(sysp, 0.9 * G(sysp, p0, B0))
    w = Weights(0.5, 0.5, 1.0).normalized()
    rate0 = G(sysp, p0, B0)
    nu = w.w1 * sysp.global_rounds / rate0
    beta = sysp.p_max * sysp.bits / rate0

    p_k, B_k = solve_sp2_v2_thm2(sysp, w, nu, beta, rmin)
    p_s, B_s = _scalar_bisection_thm2(sysp, nu, beta, rmin)
    np.testing.assert_allclose(np.asarray(B_k), B_s, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(p_k), p_s, rtol=1e-4)


def test_thm2_dual_bracket_covers_tight_deadlines():
    """Tight deadlines push the dual root to mu ~ j * exp(sum(rmin) ln2 / B);
    the sweep's bracket is sized from that estimate, so regimes far above any
    fixed cap (here ~100 nats, root ~ 1e33) must still match the scalar
    oracle."""
    from repro.core.sp2 import _thm2_dual_mu

    n = 50
    sysp = make_system(jax.random.PRNGKey(0), n_devices=n)
    rmin = jnp.full((n,), 100.0 * sysp.bandwidth_total / (n * np.log(2.0)))
    w = Weights(0.5, 0.5, 1.0).normalized()
    rate0 = G(sysp, jnp.full((n,), sysp.p_max),
              jnp.full((n,), sysp.bandwidth_total / n))
    nu = w.w1 * sysp.global_rounds / rate0
    j = nu * sysp.bits * sysp.noise_psd / sysp.gain
    mu = float(_thm2_dual_mu(sysp, j, rmin))

    def gprime(m):
        wv = np.asarray(lambertw0(jnp.asarray((m - np.asarray(j)) / (np.e * np.asarray(j)))))
        return float(np.sum(np.asarray(rmin) * np.log(2.0)
                            / np.maximum(wv + 1.0, 1e-12)) - sysp.bandwidth_total)

    assert mu > 1e30                       # far above any fixed 4**40 cap
    assert gprime(mu * 0.999) > 0 > gprime(mu * 1.001)   # brackets the root


def test_thm2_is_jittable():
    """The dual search must be device-resident: tracing it must not leak a
    concretization error (the old float() path could not be jitted)."""
    n = 6
    sysp = make_system(jax.random.PRNGKey(3), n_devices=n)
    B0 = jnp.full((n,), sysp.bandwidth_total / n)
    p0 = jnp.full((n,), sysp.p_max)
    rmin = _clamp_rmin(sysp, 0.9 * G(sysp, p0, B0))
    w = Weights(0.5, 0.5, 1.0).normalized()
    rate0 = G(sysp, p0, B0)
    nu = w.w1 * sysp.global_rounds / rate0
    beta = sysp.p_max * sysp.bits / rate0
    f = jax.jit(lambda nu_, beta_, rm_: solve_sp2_v2_thm2(sysp, w, nu_, beta_, rm_))
    p_j, B_j = f(nu, beta, rmin)
    p_e, B_e = solve_sp2_v2_thm2(sysp, w, nu, beta, rmin)
    np.testing.assert_allclose(np.asarray(B_j), np.asarray(B_e), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(p_j), np.asarray(p_e), rtol=1e-12)


# ---------------------------------------------------------------------------
# fleet API
# ---------------------------------------------------------------------------

def test_fleet_matches_per_cell_allocate():
    """vmap'd BCD must agree with the scalar path cell by cell."""
    fleet = make_fleet(jax.random.PRNGKey(0), n_cells=3, n_devices=5)
    w = Weights(0.5, 0.5, 10.0)
    fr = allocate_fleet(fleet, w, max_iters=4)
    assert fr.objective.shape == (3,)
    for c in range(3):
        cell = jax.tree_util.tree_map(lambda x: x[c], fleet)
        single = allocate(cell, w, max_iters=4)
        assert single.iters == int(fr.iters[c])
        assert single.converged == bool(fr.converged[c])
        np.testing.assert_allclose(np.asarray(fr.allocation.bandwidth[c]),
                                   np.asarray(single.allocation.bandwidth),
                                   rtol=1e-10)
        np.testing.assert_allclose(np.asarray(fr.allocation.power[c]),
                                   np.asarray(single.allocation.power),
                                   rtol=1e-10)
        assert float(fr.objective[c]) == pytest.approx(single.objective,
                                                       rel=1e-10)
        assert feasible(cell, jax.tree_util.tree_map(lambda x: x[c],
                                                     fr.allocation))


def test_fleet_ledger_shape_and_nan_tail():
    fleet = make_fleet(jax.random.PRNGKey(1), n_cells=2, n_devices=4)
    fr = allocate_fleet(fleet, Weights(0.5, 0.5, 1.0), max_iters=6)
    assert fr.history.shape == (2, 6, len(fr.columns))
    for c in range(2):
        it = int(fr.iters[c])
        led = np.asarray(fr.history[c])
        assert np.isfinite(led[:it]).all()
        assert np.isnan(led[it:]).all()


def test_stack_systems_accepts_heterogeneous_scalars():
    """bandwidth_total/p_max & co are traced leaves now: mixed cell classes
    stack into (C,) scalar leaves instead of raising."""
    s1 = make_system(jax.random.PRNGKey(0), n_devices=4)
    s2 = make_system(jax.random.PRNGKey(1), n_devices=4, bandwidth_total=10e6,
                     p_max=0.01)
    fleet = stack_systems([s1, s2])
    np.testing.assert_allclose(np.asarray(fleet.bandwidth_total),
                               [s1.bandwidth_total, 10e6])
    np.testing.assert_allclose(np.asarray(fleet.p_max), [s1.p_max, 0.01])
    assert fleet.gain.shape == (2, 4)


def test_stack_systems_rejects_mismatched_resolutions():
    """The discrete s-menu is the remaining static aux datum: it fixes the
    rounding table shape, so cells must agree on it."""
    s1 = make_system(jax.random.PRNGKey(0), n_devices=4)
    s2 = make_system(jax.random.PRNGKey(1), n_devices=4,
                     resolutions=(160.0, 320.0))
    with pytest.raises(ValueError):
        stack_systems([s1, s2])


def test_heterogeneous_fleet_matches_per_cell_allocate():
    """A stacked fleet of cells with differing bandwidth/power budgets must
    agree with per-cell `allocate` element-wise (the vmap'd solve reads the
    per-cell scalar leaves, not a shared static config)."""
    fleet = make_fleet(jax.random.PRNGKey(3), n_cells=3, n_devices=5,
                       bandwidth_total=[8e6, 20e6, 45e6],
                       p_max=[0.01, 0.0158, 0.025])
    np.testing.assert_allclose(np.asarray(fleet.bandwidth_total),
                               [8e6, 20e6, 45e6])
    w = Weights(0.5, 0.5, 5.0)
    fr = allocate_fleet(fleet, w, max_iters=4)
    for c in range(3):
        cell = jax.tree_util.tree_map(lambda x: x[c], fleet)
        single = allocate(cell, w, max_iters=4)
        assert single.iters == int(fr.iters[c])
        np.testing.assert_allclose(np.asarray(fr.allocation.bandwidth[c]),
                                   np.asarray(single.allocation.bandwidth),
                                   rtol=1e-10)
        np.testing.assert_allclose(np.asarray(fr.allocation.power[c]),
                                   np.asarray(single.allocation.power),
                                   rtol=1e-10)
        np.testing.assert_allclose(np.asarray(fr.allocation.freq[c]),
                                   np.asarray(single.allocation.freq),
                                   rtol=1e-10)
        assert float(fr.objective[c]) == pytest.approx(single.objective,
                                                       rel=1e-10)
        assert feasible(cell, jax.tree_util.tree_map(lambda x: x[c],
                                                     fr.allocation))
    # the bandwidth budgets actually differ cell to cell in the solution
    sums = np.asarray(jnp.sum(fr.allocation.bandwidth, axis=1))
    np.testing.assert_allclose(sums, [8e6, 20e6, 45e6], rtol=1e-3)


def test_make_fleet_rejects_wrong_length_per_cell_override():
    with pytest.raises(ValueError):
        make_fleet(jax.random.PRNGKey(0), n_cells=3, n_devices=4,
                   bandwidth_total=[10e6, 20e6])


@pytest.mark.parametrize("sp1_method,sp2_method",
                         [("sweep", "direct"), ("bisect", "direct"),
                          ("sweep", "jong")])
def test_allocate_f32_system_under_x64(sp1_method, sp2_method):
    """An f32-leaf system must solve in f32 even with x64 enabled: the static
    resolutions menu and the mu-search literals are pinned to the system
    dtype, else the BCD while_loop carry silently promotes and trips the
    equal-carry-types check."""
    sysp = jax.tree_util.tree_map(lambda x: jnp.asarray(x, jnp.float32),
                                  make_system(jax.random.PRNGKey(0),
                                              n_devices=6))
    res = allocate(sysp, Weights(0.5, 0.5, 1.0), max_iters=4,
                   sp1_method=sp1_method, sp2_method=sp2_method)
    assert res.allocation.bandwidth.dtype == jnp.float32
    assert np.isfinite(res.objective)


def test_fleet_convergence_rate_default_config():
    """Regression for the 12/64 fleet convergence bug: with the dtype-aware
    rel-step floor (the raw 1e-6 tol sat below the f32 iterate noise floor)
    at least 90% of cells must report convergence on the default 8x256
    fleet config."""
    C, N = 8, 256
    fleet = make_fleet(jax.random.PRNGKey(31), n_cells=C, n_devices=N,
                       bandwidth_total=20e6 * N / 50)
    res = allocate_fleet(fleet, Weights(0.5, 0.5, 1.0), max_iters=12)
    conv = int(jnp.sum(res.converged))
    assert conv >= int(0.9 * C), f"only {conv}/{C} cells converged"
    # converged cells actually stopped early (the cap did not bind)
    assert int(jnp.max(res.iters)) < 12


def test_allocate_history_is_device_resident_ledger():
    """History rows materialize once, after the loop: iter indices contiguous,
    objective monotone nonincreasing, rel_step recorded."""
    sysp = make_system(jax.random.PRNGKey(2), n_devices=6)
    res = allocate(sysp, Weights(0.5, 0.5, 1.0), max_iters=6)
    assert [h["iter"] for h in res.history] == list(range(1, res.iters + 1))
    objs = [h["objective"] for h in res.history]
    assert all(objs[i + 1] <= objs[i] + 1e-6 for i in range(len(objs) - 1))
    assert all("rel_step" in h for h in res.history)
