"""Core datatypes for the FL-MAR resource-allocation system (paper §III).

All quantities are SI: Hz, watts, joules, seconds, bits, CPU cycles.
Vectors are length-N jnp arrays (one entry per MAR device).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) * 1e-3


# Paper §VII-A defaults.
DEFAULTS = dict(
    n_devices=50,
    area_m=500.0,             # devices uniform in a 500m x 500m square, BS at center
    bandwidth_total=20e6,     # B  (Hz)
    noise_psd=dbm_to_watt(-174.0),   # N0 (W/Hz)
    p_max=dbm_to_watt(12.0),  # 12 dBm
    p_min=dbm_to_watt(0.0),   # 0 dBm
    f_max=2e9,                # 2 GHz
    f_min=1e3,                # paper: 0 Hz; we use a tiny positive floor (see DESIGN.md)
    kappa=1e-28,              # effective switched capacitance
    cycles_lo=1e4,            # c_n ~ U[1,3]x1e4 cycles / standard sample
    cycles_hi=3e4,
    samples_per_device=500,   # D_n
    upload_bits=28.1e3,       # d_n
    local_iters=10,           # R_l
    global_rounds=100,        # R_g
    resolutions=(160.0, 320.0, 480.0, 640.0),   # s_bar_1..s_bar_M (pixels)
    s_standard=160.0,
    shadowing_db=8.0,
)


@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Static description of one FL-MAR system instance (N devices).

    Per-cell scalars (bandwidth_total, p_max, ...) are pytree *leaves*, not
    static aux data: `stack_systems`/`make_fleet` stack them into (C,) arrays
    so cells with different bandwidth/power budgets batch through one vmap'd
    solve (heterogeneous fleets). Only `resolutions` — which fixes array
    shapes and the discrete s-menu — stays static. Solver code must therefore
    treat these scalars as traced values (jnp ops, no float()/Python max).

    `active` is an optional (N,) bool mask marking padded-out devices
    (`region.batch.pad_system`): masked devices carry zero data/cycles/bits
    and are excluded from every cross-device reduction (SP1/SP2 duals,
    makespan, energy, accuracy, BCD convergence) so the active prefix solves
    bit-identically to the unpadded system. `active=None` (the default)
    means all devices are real and the solvers take their original,
    mask-free code paths."""
    # per-device arrays, shape (N,)
    gain: Array          # E[G_n] expected channel gain (linear)
    cycles: Array        # c_n cycles per standard sample
    samples: Array       # D_n
    bits: Array          # d_n upload size in bits
    # per-cell scalars (traced leaves; float or 0-d array per cell)
    bandwidth_total: float
    noise_psd: float
    p_min: float
    p_max: float
    f_min: float
    f_max: float
    kappa: float
    local_iters: float   # R_l
    global_rounds: float # R_g
    resolutions: tuple   # (s_bar_1..s_bar_M), ascending — static aux
    s_standard: float
    # optional (N,) bool: False = padded-out device (see pad_system)
    active: Optional[Array] = None

    @property
    def n(self) -> int:
        return int(self.gain.shape[0])

    @property
    def zeta(self) -> float:
        # zeta = 1 / s_standard^2  (paper eq. 7)
        return 1.0 / (self.s_standard ** 2)

    @property
    def s_lo(self) -> float:
        return float(self.resolutions[0])

    @property
    def s_hi(self) -> float:
        return float(self.resolutions[-1])

    def replace(self, **kw) -> "SystemParams":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------- per-cell views
    def cell(self, c: int, xp=jnp) -> "SystemParams":
        """Single-cell view of a stacked (C, N) system: row `c` of every
        leaf (arrays AND per-cell scalars). `xp=np` keeps the view on the
        host (the region planning idiom)."""
        if jnp.ndim(self.gain) != 2:
            raise ValueError("SystemParams.cell: system is not stacked (C, N)")
        take = {k: xp.asarray(getattr(self, k))[c]
                for k in _SYS_ARRAYS + _SYS_SCALARS}
        act = None if self.active is None else xp.asarray(self.active)[c]
        return SystemParams(**take, resolutions=self.resolutions, active=act)

    def with_assignment(self, assign, xp=jnp) -> "SystemParams":
        """Cross-cell active views under a device -> cell assignment.

        For a stacked (C, N) system whose row c holds every device's
        channel gain *to cell c*, an association is an (N,) int array
        (`assign[n]` = serving cell, -1 = unserved). The returned system
        carries ``active[c, n] = (assign[n] == c) & base_active[c, n]`` —
        the same masking machinery that makes padded solves bit-identical
        to unpadded ones (`region.batch.pad_system`) now makes each cell's
        lane solve exactly its member devices, at ONE compiled (C, N)
        shape for every association the outer loop visits."""
        if jnp.ndim(self.gain) != 2:
            raise ValueError(
                "SystemParams.with_assignment: system is not stacked (C, N)")
        C = int(jnp.asarray(self.gain).shape[0])
        assign = xp.asarray(assign)
        mask = assign[None, :] == xp.arange(C)[:, None]
        if self.active is not None:
            mask = mask & xp.asarray(self.active)
        return self.replace(active=mask)


@dataclasses.dataclass(frozen=True)
class Weights:
    """Objective weights (paper eq. 12). w1 + w2 is normalized to 1.

    Fields may be Python scalars (one cell) or (C,) arrays (per-cell weights
    in a stacked fleet). The solvers consume weights as a traced (3,)/(C, 3)
    array operand (`repro.api.weights_leaf`), never as a jit-cache key — so
    every cell/request can carry different weights at zero extra compiles."""
    w1: float
    w2: float
    rho: float

    def normalized(self) -> "Weights":
        s = self.w1 + self.w2
        try:
            bad = bool(np.any(np.asarray(s) <= 0))
        except jax.errors.TracerArrayConversionError:
            bad = False   # traced: feasibility is the caller's contract
        if bad:
            raise ValueError("w1 + w2 must be positive (paper §VII-A footnote)")
        return Weights(self.w1 / s, self.w2 / s, self.rho / s)


@dataclasses.dataclass
class Allocation:
    """A resource allocation decision: per-device arrays of shape (N,)."""
    bandwidth: Array   # B_n (Hz)
    power: Array       # p_n (W)
    freq: Array        # f_n (Hz)
    resolution: Array  # s_n (pixels), one of the discrete choices
    s_relaxed: Optional[Array] = None  # continuous \hat{s} before rounding
    T: Optional[Array] = None          # per-round makespan auxiliary variable

    def astuple(self):
        return (self.bandwidth, self.power, self.freq, self.resolution)

    def flat(self) -> Array:
        return jnp.concatenate([jnp.asarray(x).ravel() for x in self.astuple()])


jax.tree_util.register_pytree_node(
    Allocation,
    lambda a: ((a.bandwidth, a.power, a.freq, a.resolution, a.s_relaxed, a.T), None),
    lambda _, c: Allocation(*c),
)

# Numeric per-cell scalars: pytree LEAVES (traced; may differ per cell in a
# stacked fleet). `resolutions` is the only static aux datum. `active` is a
# child too: None (no mask) flattens to an empty subtree, an array mask to a
# leaf — systems in one stacked fleet must agree on having a mask or not.
_SYS_SCALARS = ("bandwidth_total", "noise_psd", "p_min", "p_max", "f_min",
                "f_max", "kappa", "local_iters", "global_rounds", "s_standard")
_SYS_ARRAYS = ("gain", "cycles", "samples", "bits")
_SYS_STATIC = ("resolutions",)
_SYS_LEAVES = _SYS_ARRAYS + _SYS_SCALARS + ("active",)

jax.tree_util.register_pytree_node(
    SystemParams,
    lambda s: (tuple(getattr(s, k) for k in _SYS_LEAVES),
               tuple(getattr(s, k) for k in _SYS_STATIC)),
    lambda aux, leaves: SystemParams(**dict(zip(_SYS_LEAVES, leaves)),
                                     **dict(zip(_SYS_STATIC, aux))),
)
