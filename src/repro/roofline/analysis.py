"""Three-term roofline analysis per (arch x shape x mesh).

    compute term    = FLOPs / (chips x peak)
    memory term     = HBM bytes / (chips x HBM bw)
    collective term = collective bytes / (chips x link bw)

Hardware constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.

IMPORTANT PROVENANCE NOTE: on this CPU dry-run backend, XLA's
`compiled.cost_analysis()` visits `while` bodies ONCE (verified empirically:
flops are constant in layer count), so compiler-reported FLOPs/bytes
undercount scanned-layer models by ~n_layers x. The terms below are therefore
ANALYTIC — explicit formulas over the architecture/shape/sharding — while the
compiler numbers and the HLO-parsed collective instruction mix are recorded
alongside as structural cross-checks (which collectives appear, where).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch.specs import SHAPES, LONG_WINDOW, adapt_config

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link (ICI)


# ---------------------------------------------------------------------------
# analytic per-layer forward FLOPs (per token unless noted)
# ---------------------------------------------------------------------------

def _attn_linear_flops(cfg: ModelConfig) -> float:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    if cfg.attention == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return 2 * D * cfg.q_lora_rank + 2 * cfg.q_lora_rank * H * qk \
            + 2 * D * cfg.kv_lora_rank \
            + 2 * cfg.kv_lora_rank * H * (cfg.qk_nope_dim + cfg.v_head_dim) \
            + 2 * D * cfg.qk_rope_dim + 2 * H * cfg.v_head_dim * D
    return 2 * D * (H + 2 * KV) * hd + 2 * H * hd * D


def _attn_quadratic_flops(cfg: ModelConfig, ctx: float) -> float:
    """Score+value flops per token attending to `ctx` keys."""
    if cfg.attention == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        per_head = qk + cfg.v_head_dim
    else:
        per_head = 2 * cfg.head_dim
    return 2 * cfg.n_heads * ctx * per_head


def _mlp_flops(cfg: ModelConfig) -> float:
    return 2 * 3 * cfg.d_model * cfg.d_ff


def _moe_flops(cfg: ModelConfig) -> float:
    return 2 * 3 * cfg.d_model * cfg.d_ff * cfg.top_k \
        + 2 * cfg.d_model * cfg.n_experts


def _mamba_flops(cfg: ModelConfig) -> float:
    D, Di, N = cfg.d_model, cfg.d_inner, cfg.d_state
    dtr = max(D // 16, 1)
    return (2 * D * Di) * 2 + 2 * Di * cfg.d_conv \
        + 2 * Di * dtr * 2 + 2 * Di * 2 * N + 9 * Di * N + 2 * Di * D


def _rwkv_flops(cfg: ModelConfig) -> float:
    D, H, K, F = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff
    chunk = cfg.rwkv_chunk
    wkv = 2 * H * K * K + 3 * H * chunk * K       # state update + intra-chunk
    return 5 * 2 * D * D + 2 * D * 64 * 2 + 2 * D * D + wkv \
        + 2 * D * F * 2 + 2 * D * D               # channel mix


_KIND_FLOPS = {
    "attn":       lambda c: _attn_linear_flops(c) + _mlp_flops(c),
    "attn_moe":   lambda c: _attn_linear_flops(c) + _moe_flops(c),
    "attn_cross": lambda c: 2 * _attn_linear_flops(c) + _mlp_flops(c),
    "enc_attn":   lambda c: _attn_linear_flops(c) + _mlp_flops(c),
    "mamba":      lambda c: _mamba_flops(c) + _mlp_flops(c),
    "mamba_moe":  lambda c: _mamba_flops(c) + _moe_flops(c),
    "rwkv":       lambda c: _rwkv_flops(c),
}


def _layer_params(cfg: ModelConfig, kind: str) -> float:
    """Approximate parameter count of one layer of `kind`."""
    D = cfg.d_model
    if cfg.attention == "mla" and kind.startswith("attn"):
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        attn = D * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qk \
            + D * cfg.kv_lora_rank \
            + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim) \
            + D * cfg.qk_rope_dim + cfg.n_heads * cfg.v_head_dim * D
    else:
        attn = D * (cfg.n_heads + 2 * cfg.kv_heads) * cfg.head_dim \
            + cfg.n_heads * cfg.head_dim * D
    mlp = 3 * D * cfg.d_ff
    moe = 3 * D * cfg.d_ff * cfg.n_experts + D * cfg.n_experts
    # in_proj + gate_proj + out_proj (= 3 D*Di) + dt lora + bc proj + conv/A/D
    mamba = 3 * D * cfg.d_inner + 2 * cfg.d_inner * max(D // 16, 1) \
        + cfg.d_inner * (cfg.d_state * 2 + cfg.d_conv + 2 + cfg.d_state)
    rwkv = 7 * D * D + 2 * D * cfg.d_ff + D * 64
    return {
        "attn": attn + mlp, "attn_moe": attn + moe,
        "attn_cross": 2 * attn + mlp, "enc_attn": attn + mlp,
        "mamba": mamba + mlp, "mamba_moe": mamba + moe, "rwkv": rwkv,
    }[kind]


def params_total(cfg: ModelConfig) -> float:
    per_period = sum(_layer_params(cfg, k) for k in cfg.block_pattern)
    total = per_period * cfg.n_periods + cfg.vocab_size * cfg.d_model
    if not cfg.tied_embeddings:
        total += cfg.vocab_size * cfg.d_model
    if cfg.encoder_layers:
        total += cfg.encoder_layers * _layer_params(cfg, "enc_attn")
    return float(total)


def params_active(cfg: ModelConfig) -> float:
    """Active-path params (MoE: top_k of n_experts)."""
    def active(kind):
        p = _layer_params(cfg, kind)
        if kind.endswith("_moe"):
            moe_p = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
            p = p - moe_p + moe_p * cfg.top_k / cfg.n_experts
        return p
    per_period = sum(active(k) for k in cfg.block_pattern)
    total = per_period * cfg.n_periods + cfg.vocab_size * cfg.d_model
    if not cfg.tied_embeddings:
        total += cfg.vocab_size * cfg.d_model
    if cfg.encoder_layers:
        total += cfg.encoder_layers * _layer_params(cfg, "enc_attn")
    return float(total)


# ---------------------------------------------------------------------------
# per-(arch, shape) analytic cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Costs:
    flops_global: float          # executed flops, whole step, all chips
    hbm_bytes_dev: float         # HBM traffic per device
    coll_bytes_dev: float        # collective bytes sent+received per device
    model_flops: float           # 6 N D (dense) / 6 N_active D (MoE), global
    tokens: float


def analytic_costs(arch: str, shape_name: str, multi_pod: bool = False,
                   expert_parallel: bool = True, accum_steps: int = 1,
                   cfg_overrides: Optional[dict] = None) -> Costs:
    cfg = adapt_config(get_config(arch), shape_name)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    kind = sh["kind"]
    chips = 512 if multi_pod else 256
    data_ax = 32 if multi_pod else 16
    model_ax = 16

    n_text = S - (cfg.n_patches or 0) if kind in ("train", "prefill") else 1
    tokens = float(B * (S if kind in ("train", "prefill") else 1))

    # context length each query attends to
    if kind in ("train", "prefill"):
        ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S / 2
    else:
        ctx = min(cfg.sliding_window or S, S)

    per_tok = sum(_KIND_FLOPS[k](cfg) for k in cfg.block_pattern) * cfg.n_periods
    attn_layers = sum(1 for k in cfg.block_pattern
                      if k in ("attn", "attn_moe", "attn_cross")) * cfg.n_periods
    quad = _attn_quadratic_flops(cfg, ctx) * attn_layers
    logits = 2 * cfg.d_model * cfg.vocab_size
    fwd_per_tok = per_tok + quad + logits

    enc_flops = 0.0
    if cfg.encoder_layers:
        enc_per_tok = _KIND_FLOPS["enc_attn"](cfg) \
            + _attn_quadratic_flops(cfg, cfg.encoder_ctx)
        enc_flops = enc_per_tok * cfg.encoder_ctx * B * cfg.encoder_layers
        if kind == "train":
            enc_flops *= 4.0 if cfg.remat else 3.0
    if cfg.n_patches and kind == "decode":
        pass  # vlm decode: no patch reprocessing (cache holds them)

    remat_mult = {"full": 4.0, "dots": 3.15}.get(cfg.remat_policy, 4.0)
    mult = (remat_mult if cfg.remat else 3.0) if kind == "train" else 1.0
    flops_global = fwd_per_tok * tokens * mult + (
        enc_flops if kind != "decode" else 0.0)

    if cfg.encoder_layers and kind == "decode":
        # cross-attention reads encoder ctx per decode step either way
        flops_global += _attn_quadratic_flops(cfg, cfg.encoder_ctx) \
            * attn_layers * B
        if not cfg.cross_kv_cache:
            # BASELINE: encoder re-run + cross K/V projections every step
            xkv = 2 * 2 * cfg.encoder_ctx * cfg.d_model \
                * cfg.n_heads * cfg.head_dim * attn_layers * B
            flops_global += enc_flops + xkv

    P_total = params_total(cfg)
    P_dev = P_total * 2 / chips                       # bf16 shard per device

    # HBM traffic per device
    if kind == "train":
        opt_traffic = (P_total / chips) * (4 + 8 + 8 + 8 + 4)   # p, mu, nu rw
        act = tokens / data_ax * cfg.d_model * 2 * cfg.n_layers * 12 / model_ax
        hbm = 3 * P_dev + opt_traffic + act
    elif kind == "prefill":
        act = tokens / data_ax * cfg.d_model * 2 * cfg.n_layers * 8 / model_ax
        hbm = P_dev + act
    else:
        cache_slots = min(cfg.sliding_window or S, S)
        kv_bytes = (cfg.kv_lora_rank + cfg.qk_rope_dim if cfg.attention == "mla"
                    else 2 * cfg.kv_heads * cfg.head_dim)
        elem_bytes = (1.0 + 4.0 / cfg.head_dim) if cfg.kv_cache_int8 else 2.0
        cache = B * cache_slots * kv_bytes * elem_bytes * attn_layers / chips
        hbm = P_dev + cache

    # EP is only real when the expert count divides the model axis; otherwise
    # the shape-aware sharding has already fallen back to TP experts.
    expert_parallel = expert_parallel and cfg.n_experts > 0 \
        and cfg.n_experts % model_ax == 0

    # collective bytes per device (baseline FSDP+TP sharding)
    act_layer = tokens / data_ax * cfg.d_model * 2   # bf16 residual per device-batch
    if kind == "train":
        # FSDP gathers repeat per microbatch under gradient accumulation
        fsdp = (2 * accum_steps + 1) * P_dev * (data_ax - 1) / data_ax
        sp = 4 * act_layer * (model_ax - 1) / model_ax * cfg.n_layers
        coll = fsdp + sp
    elif kind == "prefill":
        fsdp = P_dev * (data_ax - 1) / data_ax
        sp = 2 * act_layer * (model_ax - 1) / model_ax * cfg.n_layers
        coll = fsdp + sp
    else:
        # TP all-reduce of the (B_loc, D) residual per layer, fwd only
        coll = 2 * act_layer * (model_ax - 1) / model_ax * cfg.n_layers

    if cfg.n_experts and expert_parallel:
        # EP all-to-all: dispatch + combine of routed tokens (there and back).
        # With expert_parallel=False experts are FSDP+TP-sharded and computed
        # locally on batch-sharded tokens: no all-to-all at all (the expert
        # weight gathers are inside the fsdp term already).
        moe_layers = sum(1 for k in cfg.block_pattern if k.endswith("_moe")) \
            * cfg.n_periods
        a2a = 4 * (tokens / data_ax) * cfg.top_k * cfg.d_model * 2 * moe_layers \
            * (model_ax - 1) / model_ax
        coll += a2a * (2 if kind == "train" else 1)

    # MODEL_FLOPS: 6 N_active D for training (fwd+bwd), 2 N_active D for
    # inference kinds (fwd only)
    model_flops = (6.0 if kind == "train" else 2.0) * params_active(cfg) * tokens
    return Costs(flops_global=float(flops_global), hbm_bytes_dev=float(hbm),
                 coll_bytes_dev=float(coll), model_flops=float(model_flops),
                 tokens=tokens)


def roofline_terms(arch: str, shape_name: str, multi_pod: bool = False,
                   compiler_record: Optional[dict] = None,
                   expert_parallel: bool = True, accum_steps: int = 1,
                   cfg_overrides: Optional[dict] = None) -> Dict:
    chips = 512 if multi_pod else 256
    c = analytic_costs(arch, shape_name, multi_pod,
                       expert_parallel=expert_parallel,
                       accum_steps=accum_steps,
                       cfg_overrides=cfg_overrides)
    t_compute = c.flops_global / (chips * PEAK_FLOPS)
    t_memory = c.hbm_bytes_dev / HBM_BW
    t_coll = c.coll_bytes_dev / LINK_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dominant = max(terms, key=terms.get)
    out = dict(
        arch=arch, shape=shape_name, mesh="2x16x16" if multi_pod else "16x16",
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        dominant=dominant,
        model_flops=c.model_flops, exec_flops=c.flops_global,
        useful_ratio=c.model_flops / max(c.flops_global, 1.0),
        tokens=c.tokens,
    )
    if compiler_record:
        out["compiler"] = dict(
            flops=compiler_record.get("flops"),
            hbm_bytes=compiler_record.get("hbm_bytes"),
            collective_bytes=compiler_record.get("collectives", {}).get("total_bytes"),
            temp_bytes=compiler_record.get("temp_bytes"),
            compile_s=compiler_record.get("compile_s"),
        )
    return out


def load_dryrun(jsonl_path: str) -> Dict:
    recs = {}
    with open(jsonl_path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def full_table(jsonl_path: Optional[str] = None, multi_pod: bool = False):
    """Roofline rows for every supported (arch, shape)."""
    from repro.configs import ARCHS
    from repro.launch.specs import supported

    recs = load_dryrun(jsonl_path) if jsonl_path else {}
    mesh = "2x16x16" if multi_pod else "16x16"
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            if not supported(get_config(arch), shape):
                continue
            rows.append(roofline_terms(
                arch, shape, multi_pod,
                compiler_record=recs.get((arch, shape, mesh))))
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful FLOP ratio |")
    sep = "|---|---|---|---|---|---|---|"
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} |")
    return "\n".join(lines)
