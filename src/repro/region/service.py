"""Service layer: the synchronous facade over the region serving pipeline.

`RegionAllocator` keeps the historical blocking API — `submit` requests,
`flush`/`solve` return `{cell_id: CellResponse}` — as a thin facade over
the four-layer `RegionPipeline` (`region.pipeline`):

    admission  — per-bucket request queues + batch-closing policies
                 (`region.admission`: close-on-full / max-wait /
                 deadline-slack, per-request deadlines and priorities);
    planning   — the bucket/chunk planner (`region.planning`): pad mixed
                 pools onto the power-of-two bucket menu, warm-start from
                 the LRU `WarmStartCache`, fill short chunks with
                 all-inactive pad cells that converge in one masked
                 iteration;
    dispatch   — `solve()` enqueued asynchronously (`region.dispatch`):
                 results stay device futures, up to `pipeline_depth`
                 batches in flight, so batch k+1's host assembly overlaps
                 batch k's device compute;
    completion — one blocking gather per batch (`region.completion`),
                 resolving `PendingResponse` futures and writing the warm
                 cache.

The facade is *bit-identical* to the pre-pipeline monolith (parity-tested
in tests/test_region_pipeline.py): same bucket-ascending/arrival-order
grouping, same warm-start decisions (in-flight cells stall planning until
their solutions land in the cache), same responses. Only the overlap
changed — with `pipeline_depth >= 2` even the synchronous `solve()`
assembles chunk k+1 while chunk k computes.

Per-stage wall time (queue wait / plan / dispatch / device / gather) is
tracked in `RegionAllocator.clocks`; `stats` keeps the request/batch/
cache/shape counters — the acceptance signals for bucketing and warm
starts. For latency-shaped serving (p50/p99, Poisson/bursty traces) drive
the `RegionPipeline` directly: `submit()` returns futures and `poll()`
runs the batch-closing policy; see `benchmarks/run.py::serve_latency`.
"""
from __future__ import annotations

from typing import Dict, Hashable, Optional, Sequence

from repro.api import SolverSpec
from repro.core.accuracy import AccuracyModel
from repro.core.types import Weights

from .admission import (AllocationRequest, BatchPolicy, StageClocks)
from .batch import DEFAULT_MIN_BUCKET
from .completion import CellResponse, PendingResponse
from .pipeline import RegionPipeline

__all__ = ["AllocationRequest", "CellResponse", "RegionAllocator"]


class RegionAllocator:
    """Streaming allocation front-end: submit requests, flush batches.

    Parameters
    ----------
    w : the region's *default* objective weights; any request may override
        them with its own `AllocationRequest.w` (traced per request, zero
        extra compiles).
    spec : a `SolverSpec` with the static solver options — the jit-cache
        key shared by every batch this allocator solves.
    mesh : jax mesh to shard batches over (None = single-device fleet
        vmap); see `region_mesh`.
    cells_per_batch : fixed cell-axis length of every compiled solve.
    min_bucket : floor of the power-of-two device-count buckets.
    cache_size : max cells kept in the warm-start LRU.
    policy : admission batch-closing policy for the async path (default
        close-on-full; `flush`/`solve` force-close regardless).
    pipeline_depth : max dispatched-but-unmaterialized batches (1 = the
        old serial solve-then-gather loop; 2 = double buffering).
    max_iters / tol / sp* kwargs : legacy spellings of the SolverSpec
        fields, honored when `spec` is not given.
    """

    def __init__(self, w: Weights, acc: Optional[AccuracyModel] = None,
                 mesh=None, cells_per_batch: int = 32,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 cache_size: int = 4096,
                 spec: Optional[SolverSpec] = None,
                 policy: Optional[BatchPolicy] = None,
                 pipeline_depth: int = 2,
                 max_iters: Optional[int] = None, tol: Optional[float] = None,
                 sp2_iters: Optional[int] = None,
                 sp2_method: Optional[str] = None,
                 sp1_method: Optional[str] = None):
        if cells_per_batch < 1:
            raise ValueError("cells_per_batch must be >= 1")
        legacy = {k: v for k, v in dict(
            max_iters=max_iters, tol=tol, sp2_iters=sp2_iters,
            sp2_method=sp2_method, sp1_method=sp1_method).items()
            if v is not None}
        if spec is not None:
            if legacy:   # silently dropping either set would mislead
                raise ValueError(
                    f"RegionAllocator: pass the solver options through "
                    f"`spec` OR the legacy kwargs, not both (got spec and "
                    f"{sorted(legacy)})")
            self.spec = spec
        else:
            self.spec = SolverSpec(**legacy)
        self.w = w
        self.acc = acc
        self.mesh = mesh
        self.cells_per_batch = int(cells_per_batch)
        self.min_bucket = int(min_bucket)
        self.cache_size = int(cache_size)
        self.pipeline = RegionPipeline(
            w, acc=acc, mesh=mesh, cells_per_batch=cells_per_batch,
            min_bucket=min_bucket, cache_size=cache_size, spec=self.spec,
            policy=policy, max_in_flight=pipeline_depth)

    # ------------------------------------------------------------- stream
    def submit(self, request: AllocationRequest) -> PendingResponse:
        """Queue a request for the next `flush()`. The returned future can
        also be resolved directly (`.result()` force-drives the pipeline)."""
        return self.pipeline.submit(request)

    def flush(self) -> Dict[Hashable, CellResponse]:
        """Solve everything queued since the last flush."""
        return {r.cell_id: r for r in self.pipeline.drain()}

    # -------------------------------------------------------------- batch
    def solve(self, requests: Sequence[AllocationRequest]
              ) -> Dict[Hashable, CellResponse]:
        """Coalesce `requests` into bucketed batches and solve them all.

        Requests are grouped by device-count bucket; each group is chunked
        into fixed `cells_per_batch` solves (the jit-cache key is therefore
        just the bucket). Returns {cell_id: CellResponse}.
        """
        for r in requests:
            self.pipeline.submit(r)
        return {r.cell_id: r for r in self.pipeline.drain()}

    def invalidate(self, cell_id: Hashable) -> bool:
        """Drop a cell's warm-start cache entry (mobility handover: the
        member set changed, so its cached solution no longer maps to the
        pool). The next request for the cell cold-starts; the purge is
        counted in `stats["handover_purges"]`."""
        return self.pipeline.invalidate(cell_id)

    # -------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Serving tallies: request/batch/cache counts plus the PR 9
        solver and deadline aggregates (`cells_solved`, `cells_converged`,
        `deadline_hits`/`deadline_requests`, and `solver_counters` — summed
        bcd_iters/sp1_evals/sp2_evals across every materialized batch)."""
        return self.pipeline.stats

    @property
    def clocks(self) -> StageClocks:
        """Per-stage wall clocks (queue wait / plan / dispatch / device /
        gather) aggregated across the pipeline."""
        return self.pipeline.clocks

    @property
    def _cache(self):
        """Back-compat view of the warm-start LRU's underlying mapping."""
        return self.pipeline.cache._entries

    @property
    def solver_kw(self):
        """Legacy read-only view of the solver options (now a `SolverSpec`).
        A mapping proxy: the old in-place `solver_kw[...] = x` mutation
        raises instead of silently doing nothing — reconstruct the
        allocator (or pass `spec=`) to change solver options."""
        from types import MappingProxyType
        return MappingProxyType(dict(
            max_iters=self.spec.max_iters, tol=self.spec.tol,
            sp2_iters=self.spec.sp2_iters, sp2_method=self.spec.sp2_method,
            sp1_method=self.spec.sp1_method))

    @property
    def compiled_shapes(self) -> set:
        """Distinct (cells, devices) batch shapes solved so far — one jit
        cache entry each (the bucketing acceptance metric)."""
        return self.pipeline.compiled_shapes
