"""repro.fl — federated-learning substrate (FedAvg, data, system simulator)."""
from .client import client_delta, local_train
from .data import FLDataset, make_eval_set, make_federated_dataset, render
from .server import (FLRunResult, fedavg, fedavg_stale, resolve_eval_resolution,
                     run_federated, stale_weights)
from .simulator import SimResult, map_resolution_to_dataset, simulate

__all__ = ["client_delta", "local_train", "FLDataset", "make_eval_set",
           "make_federated_dataset", "render", "FLRunResult", "fedavg",
           "fedavg_stale", "resolve_eval_resolution", "run_federated",
           "stale_weights", "SimResult", "map_resolution_to_dataset",
           "simulate"]
