"""Observed region serving: the full `repro.obs` telemetry loop.

Runs a synthetic Poisson request trace (every request deadlined) through
`RegionAllocator` with a JSONL span/point recorder enabled, then:

  * feeds the per-stage samples (`StageClocks`) and end-to-end request
    latencies into the always-on metrics registry (fixed-bucket
    histograms — the same layout `benchmarks/compare.py` gates on);
  * serves the registry live over HTTP (`MetricsServer`: /metrics,
    /healthz, /slo) and scrapes itself — the scraped Prometheus text is
    round-tripped through `obs.parse_prometheus_text` before it is
    written, so the artifact is parser-validated;
  * evaluates the default SLO set (p99 serve latency, deadline-hit rate,
    BCD convergence) with multi-window burn rates and prints/writes the
    verdicts (slo.json);
  * wraps one served batch in an XLA profiler trace session
    (`obs.profile.trace` -> profile/ artifact dir);
  * writes the event stream to `events.jsonl` and the metrics snapshot to
    `metrics.jsonl` + Prometheus text, then prints the
    `python -m repro.obs.report` tables.

Every request event carries the solve's device-resident counters (BCD
iterations, SP1/SP2 dual evals, convergence residual) — the warm-start
effect is directly visible as the sp2_evals gap between cold and warm
requests.

    PYTHONPATH=src python examples/serve_observed.py

REPRO_SMOKE=1 shrinks the trace for CI. Artifacts land in the working
directory (override with REPRO_OBS_DIR). REPRO_OBS_PORT pins the scrape
port (default: ephemeral); REPRO_OBS_HOLD_S keeps the server up that many
seconds after the trace so an external scraper (CI's curl) can hit it.
"""
import json
import os
import time
import urllib.request

import jax
import numpy as np

from repro import SolverSpec, Weights, make_system, obs
from repro.obs.report import format_report, summarize
from repro.region import AllocationRequest, RegionAllocator

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
OUT_DIR = os.environ.get("REPRO_OBS_DIR", ".")
PORT = int(os.environ.get("REPRO_OBS_PORT", "0"))
HOLD_S = float(os.environ.get("REPRO_OBS_HOLD_S", "0"))
os.makedirs(OUT_DIR, exist_ok=True)
N_CELLS = 8 if SMOKE else 32
TARGET_REQUESTS = 16 if SMOKE else 128
RATE = 6.0
DRIFT = 0.01
DEADLINE_BUDGET_S = 30.0 if SMOKE else 10.0   # absolute, admission clock

events_path = os.path.join(OUT_DIR, "events.jsonl")
metrics_path = os.path.join(OUT_DIR, "metrics.jsonl")
prom_path = os.path.join(OUT_DIR, "metrics.prom")
scrape_path = os.path.join(OUT_DIR, "scrape.prom")
slo_path = os.path.join(OUT_DIR, "slo.json")
profile_dir = os.path.join(OUT_DIR, "profile")

rng = np.random.default_rng(11)
key = jax.random.PRNGKey(0)
pool_sizes = rng.choice([9, 14, 23, 40], size=N_CELLS)
cells = {cid: make_system(jax.random.fold_in(key, cid),
                          n_devices=int(pool_sizes[cid]))
         for cid in range(N_CELLS)}

svc = RegionAllocator(Weights(0.5, 0.5, 1.0), cells_per_batch=8,
                      min_bucket=16, spec=SolverSpec(tol=1e-4))

# SLO plane over the global registry the completion layer feeds; the
# MetricsServer exposes both raw series and verdicts while the trace runs
slo_plane = obs.SloPlane(obs.default_slos(
    latency_threshold_s=2.0 if SMOKE else 0.5,
    latency_objective=0.5, deadline_objective=0.9,
    convergence_objective=0.5))
server = obs.MetricsServer(slo_plane=slo_plane, port=PORT).start()
print(f"scrape endpoint up: {server.url('/metrics')} (+ /healthz /slo)")
slo_plane.observe()

served = 0
profiled = False
t0 = time.time()
# one recorder for the whole trace: every solve/plan/dispatch/materialize
# span, every stage sample, and one "request" point per served cell land
# in events.jsonl
with obs.recording(obs.JsonlRecorder(events_path)):
    with obs.span("serve_trace", trace="poisson", cells=N_CELLS):
        while served < TARGET_REQUESTS:
            k = int(min(rng.poisson(RATE), TARGET_REQUESTS - served,
                        N_CELLS))
            if k == 0:
                continue
            deadline = time.monotonic() + DEADLINE_BUDGET_S
            for cid in rng.choice(N_CELLS, size=k, replace=False):
                cid = int(cid)
                drift = 1.0 + DRIFT * float(rng.standard_normal())
                cells[cid] = cells[cid].replace(
                    gain=np.asarray(cells[cid].gain) * drift)
                svc.submit(AllocationRequest(cell_id=cid, sys=cells[cid],
                                             deadline=deadline))
            served += k
            if not profiled and served >= TARGET_REQUESTS // 2:
                # one profiled flush mid-trace: caches are warm, so the
                # session captures steady-state device work, not compiles
                profiled = True
                with obs.profile.trace(profile_dir, label="serve_flush"):
                    svc.flush()
            else:
                svc.flush()
            slo_plane.observe()
wall = time.time() - t0

# --- metric plane: fold the trace into the always-on registry -------------
clocks = svc.pipeline.clocks
for stage in clocks.STAGES:
    h = obs.REGISTRY.histogram("stage_seconds", stage=stage)
    h.observe_many(clocks.samples(stage))
events = obs.read_jsonl(events_path)
lat = obs.histogram("request_latency_seconds")
lat.observe_many(e["latency_s"] for e in events
                 if e.get("name") == "request" and "latency_s" in e)
obs.counter("requests_served").inc(served)
obs.gauge("serve_wall_seconds").set(wall)

# --- SLO verdicts + self-scrape (parser-validated wire artifacts) ---------
verdicts = slo_plane.check()
with open(slo_path, "w") as fh:
    json.dump(dict(slos=verdicts), fh, indent=1)

with urllib.request.urlopen(server.url("/metrics"), timeout=10) as resp:
    scraped = resp.read().decode()
samples = obs.parse_prometheus_text(scraped)   # raises if malformed
with open(scrape_path, "w") as fh:
    fh.write(scraped)

n_metrics = obs.write_metrics_jsonl(metrics_path)
with open(prom_path, "w") as fh:
    fh.write(obs.prometheus_text())

print(f"served {served} requests in {wall:.2f}s "
      f"({served / wall:.1f} req/s), "
      f"{len(events)} events -> {events_path}, "
      f"{n_metrics} metrics -> {metrics_path} (+ {prom_path})")
print(f"scraped {len(samples)} samples -> {scrape_path} "
      f"(parse_prometheus_text-validated); profiler trace -> "
      f"{profile_dir}/")
for v in verdicts:
    burns = " ".join(f"{w['name']}={w['burn_rate']:.2f}"
                     for w in v["windows"])
    ratio = ("n/a" if v["good_ratio"] is None
             else f"{100 * v['good_ratio']:.1f}%")
    print(f"SLO {v['name']}: {v['verdict']} (good {ratio}, "
          f"objective {100 * v['objective']:g}%, burn {burns})")

# warm-start effect straight from the per-request counters
req = [e for e in events if e.get("name") == "request"]
cold = [e["sp2_evals"] for e in req if not e["warm"]]
warm = [e["sp2_evals"] for e in req if e["warm"]]
if cold and warm:
    print(f"sp2 dual evals per solve: cold mean {np.mean(cold):.0f}, "
          f"warm mean {np.mean(warm):.0f} "
          f"(x{np.mean(cold) / np.mean(warm):.1f} warm-start saving)")

print()
print(format_report(summarize(events)))

if HOLD_S > 0:
    print(f"holding scrape endpoint for {HOLD_S:g}s "
          f"({server.url('/metrics')})", flush=True)
    time.sleep(HOLD_S)
server.stop()
