"""Subproblem 1 (paper §V-A, Appendix B): optimize (f, s, T) given (p, B).

    min_{f, s_hat, T}  w1 Rg sum_n alpha_n s_hat^2 f^2 + w2 Rg T - rho sum_n A_n(s_hat)
    s.t. f in [fmin, fmax], s_hat in [s_lo, s_hi],
         q_n s_hat^2 / f + T_trans_n <= T

KKT structure (paper eqs. A.2-A.7):
    f_n*(lambda)     = cbrt(lambda_n / (2 w1 Rg kappa))            clipped to box
    s_hat_n*(lambda) solves  s * (2 a_n f^2 + 2 lambda q_n / f) = rho A_n'(s)
    sum_n lambda_n   = w2 Rg

Instead of CVX on the dual (A.8) we solve the KKT system exactly by
water-filling on the scalar map T -> Sigma_n lambda_n(T), where lambda_n(T)
inverts the strictly decreasing per-device makespan T_n(lambda) (A.4/A.6
with the box clips folded in) and the outer root Sigma_n lambda_n(T) = w2 Rg
enforces the dual feasibility condition A.7. Two engines share that
formulation:

  * method="sweep" (default): a batched T-grid sweep — every round evaluates
    Sigma_n lambda_n(T) for a whole grid of candidate deadlines in one
    device pass through `kernels.ops.sp1_lambda_sum` (Pallas on TPU, the
    pure-jnp ref oracle on CPU) and re-grids geometrically inside the
    sign-change bracket, finishing with secant interpolation. For the
    paper's LinearAccuracy the inner inversion lambda_n(T) is CLOSED FORM
    (the clipping regimes of A.2/A.3 each invert exactly — see
    `kernels.sp1_sweep.lambda_of_T_linear`), so one sweep costs O(grid) per
    device instead of O(outer x inner) bisection steps; generic concave
    accuracy models run the same sweep with a vmapped per-grid-point
    bisection for lambda_n(T).
  * method="bisect": the original nested bisection (inner lambda, outer T),
    kept bit-stable as the parity oracle for the sweep.

This supports any concave accuracy model A_n, not just the paper's linear
special case (DESIGN.md §5). Fully jitted (lax.fori_loop bisections).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .accuracy import AccuracyModel, LinearAccuracy
from .types import SystemParams, Weights

Array = jnp.ndarray

_INNER_ITERS = 56
_OUTER_ITERS = 56
_S_ITERS = 48

# T-grid sweep shape: `_SWEEP_ROUNDS` rounds of `_SWEEP_POINTS`-point grids
# shrink the bracket by (points-1)^rounds; 3 x 16 resolves the ~18-nat
# default [T_lo, T_hi] range to ~5e-3 relative before the secant step
# (the objective is stationary in T at the root, so that is ~1e-8 relative
# on the objective — see the parity tests).
_SWEEP_POINTS = 16
_SWEEP_ROUNDS = 3
# generic (non-linear) accuracy models pay a full lambda-bisection per grid
# point, so sweep a coarser grid over one extra round — same total bracket
# reduction (11^4 > 15^3) at 48 instead of 64 bisection-backed evaluations
_SWEEP_POINTS_GENERIC = 12
_SWEEP_ROUNDS_GENERIC = 4


def _coeffs(sys: SystemParams, w: Weights):
    """alpha_n (energy coeff, incl. w1 Rg) and q_n (cycles per s^2)."""
    q = sys.local_iters * sys.zeta * sys.cycles * sys.samples
    alpha = w.w1 * sys.global_rounds * sys.kappa * q
    return alpha, q


def _f_of_lambda(sys: SystemParams, w: Weights, lam: Array) -> Array:
    # dtype-aware guard: 1e-300 underflows to 0 in f32, and w1 == 0 (pure
    # latency weighting) would make this cbrt(0/0) = NaN at lam = 0
    tiny = jnp.finfo(jnp.asarray(lam).dtype).tiny
    f_unc = jnp.cbrt(lam / jnp.maximum(
        2.0 * w.w1 * sys.global_rounds * sys.kappa, tiny))
    return jnp.clip(f_unc, sys.f_min, sys.f_max)


def _f_of_lambda_diff(sys: SystemParams, w: Weights, lam: Array) -> Array:
    """Value-identical (to 1 ulp) to `_f_of_lambda`, gradient-safe.

    The fused form cbrt(lam / denom) backpropagates -lam / denom^2, and
    denom = 2 w1 Rg kappa ~ 1e-27 underflows f32 when squared — every
    kappa/w1 cotangent becomes inf. Splitting the cbrt keeps the vjp on
    the cbrt scale (denom^(4/3) ~ 1e-36, representable), so the diff path
    (`sp1_stationarity`, `repro.diff`) uses this variant."""
    tiny = jnp.finfo(jnp.asarray(lam).dtype).tiny
    denom = jnp.maximum(2.0 * w.w1 * sys.global_rounds * sys.kappa, tiny)
    f_unc = jnp.cbrt(lam) / jnp.cbrt(denom)
    return jnp.clip(f_unc, sys.f_min, sys.f_max)


def _s_of_lambda(sys: SystemParams, w: Weights, acc: AccuracyModel, lam: Array) -> Array:
    """Solve s*(2 a f^2 + 2 lam q / f) = rho A'(s) on [s_lo, s_hi]."""
    alpha, q = _coeffs(sys, w)
    f = _f_of_lambda(sys, w, lam)
    psi = 2.0 * alpha * f ** 2 + 2.0 * lam * q / jnp.maximum(f, 1e-9)

    if isinstance(acc, LinearAccuracy):
        s_unc = w.rho * acc.slope / jnp.maximum(
            psi, jnp.finfo(jnp.asarray(psi).dtype).tiny)
        return jnp.clip(s_unc, sys.s_lo, sys.s_hi)

    def h(s):  # increasing in s (A concave)
        return s * psi - w.rho * acc.deriv(s)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        pos = h(mid) > 0
        return jnp.where(pos, lo, mid), jnp.where(pos, mid, hi)

    lo0 = jnp.full_like(lam, sys.s_lo)
    hi0 = jnp.full_like(lam, sys.s_hi)
    lo, hi = lax.fori_loop(0, _S_ITERS, body, (lo0, hi0))
    s = 0.5 * (lo + hi)
    s = jnp.where(h(lo0) >= 0, sys.s_lo, s)
    s = jnp.where(h(hi0) <= 0, sys.s_hi, s)
    return s


def _makespan_of_lambda(sys: SystemParams, w: Weights, acc: AccuracyModel,
                        lam: Array, tt: Array) -> Array:
    _, q = _coeffs(sys, w)
    f = _f_of_lambda(sys, w, lam)
    s = _s_of_lambda(sys, w, acc, lam)
    return q * s ** 2 / jnp.maximum(f, 1e-9) + tt


def _s_of_lambda_diff(sys: SystemParams, w: Weights, acc: AccuracyModel,
                      lam: Array, f: Array | None = None) -> Array:
    """Differentiable s*(lambda).

    For `LinearAccuracy` the closed form in `_s_of_lambda` is already smooth,
    so it is returned as-is. For generic accuracy models the fixed-iteration
    bisection has zero derivative, so the root is re-expressed as one Newton
    correction of the stop-gradient bisection solution: equal in value to
    solver precision, with the exact implicit-function-theorem derivative.
    Lanes clipped at the static [s_lo, s_hi] box keep the (constant) bound.

    `f` optionally supplies a precomputed (possibly lane-guarded) CPU
    frequency; callers that must avoid `_f_of_lambda`'s cbrt at lam = 0
    (infinite derivative) pass the guarded value — see `sp1_stationarity`.
    """
    alpha, q = _coeffs(sys, w)
    if f is None:
        f = _f_of_lambda_diff(sys, w, lam)
    psi = 2.0 * alpha * f ** 2 + 2.0 * lam * q / jnp.maximum(f, 1e-9)

    if isinstance(acc, LinearAccuracy):
        # floor at sqrt(tiny), not tiny: the division's vjp squares the
        # denominator, and tiny**2 underflows to 0 — a zero-coefficient
        # (padded) lane with psi = 0 would then emit 0 * inf = NaN through
        # the clip. Any psi below sqrt(tiny) clips to s_hi either way, so
        # the primal matches `_s_of_lambda` bit-for-bit.
        dt = jnp.asarray(psi).dtype
        s_unc = w.rho * acc.slope / jnp.maximum(
            psi, jnp.sqrt(jnp.finfo(dt).tiny))
        return jnp.clip(s_unc, sys.s_lo, sys.s_hi)

    s0 = lax.stop_gradient(_s_of_lambda(sys, w, acc, lam))
    h = s0 * psi - w.rho * acc.deriv(s0)          # traced residual at s0
    # h'(s) = psi - rho A''(s) > 0 (A concave), evaluated under stop-grad;
    # A'' per-element via a diagonal jvp of acc.deriv
    _, d2A = jax.jvp(acc.deriv, (s0,), (jnp.ones_like(s0),))
    hp = lax.stop_gradient(psi) - w.rho * lax.stop_gradient(d2A)
    hp = jnp.maximum(hp, jnp.finfo(s0.dtype).tiny)
    eps = 1e-9
    interior = (s0 > sys.s_lo * (1.0 + eps)) & (s0 < sys.s_hi * (1.0 - eps))
    return jnp.where(interior, s0 - h / hp, s0)


def sp1_stationarity(sys: SystemParams, w: Weights, acc: AccuracyModel,
                     lam: Array, T: Array, tt: Array, mask: Array | None = None):
    """SP1 KKT residuals at a candidate dual point (lam, T).

    Returns `(r_n, r_sum)` where `r_n = M_n(lam_n) - T` (per-device makespan
    equalization, meaningful on the active set lam_n > 0) and
    `r_sum = sum_n lam_n - w2 Rg` (dual budget, eq. (18)). Both residuals are
    differentiable in (lam, T, tt), the `SystemParams` leaves, and the
    weights — the resolution subproblem inside M_n goes through
    `_s_of_lambda_diff`. Exported for `repro.diff.implicit`, which corrects
    the stop-gradient bisection solve with one arrowhead Newton step on
    exactly these residuals.

    `mask` (optional boolean per-device) restricts the traced system to the
    SP1 active set: lanes outside it — lam_n = 0 fast lanes and padded
    inactive lanes — hold f = f_min with zero one-sided derivative, carry
    r_n = 0, and drop out of the dual budget sum. Required whenever any
    lam_n = 0: `_f_of_lambda`'s cbrt has an infinite derivative at 0, and
    even a zero cotangent times that is NaN.
    """
    _, q = _coeffs(sys, w)
    if mask is None:
        f = _f_of_lambda_diff(sys, w, lam)
        s = _s_of_lambda_diff(sys, w, acc, lam, f=f)
        r_n = q * s ** 2 / jnp.maximum(f, 1e-9) + tt - T
        r_sum = jnp.sum(lam) - w.w2 * sys.global_rounds
        return r_n, r_sum
    lam_s = jnp.where(mask, lam, jnp.ones_like(lam))
    f = _f_of_lambda_diff(sys, w, lam_s)
    f = jnp.where(mask, f, jnp.asarray(sys.f_min, f.dtype))
    s = _s_of_lambda_diff(sys, w, acc, lam_s, f=f)
    r_n = jnp.where(mask, q * s ** 2 / jnp.maximum(f, 1e-9) + tt - T,
                    jnp.zeros_like(lam))
    r_sum = jnp.sum(jnp.where(mask, lam, jnp.zeros_like(lam))) \
        - w.w2 * sys.global_rounds
    return r_n, r_sum


def _lambda_of_T(sys: SystemParams, w: Weights, acc: AccuracyModel,
                 T: Array, tt: Array, lam_hi: float) -> Array:
    """Per-device inverse of the decreasing map lambda -> T_n(lambda)."""
    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        too_slow = _makespan_of_lambda(sys, w, acc, mid, tt) > T
        return jnp.where(too_slow, mid, lo), jnp.where(too_slow, hi, mid)

    lo0 = jnp.zeros_like(tt)
    hi0 = jnp.full_like(tt, lam_hi)
    lo, hi = lax.fori_loop(0, _INNER_ITERS, body, (lo0, hi0))
    lam = 0.5 * (lo + hi)
    fast = _makespan_of_lambda(sys, w, acc, jnp.zeros_like(tt), tt) <= T
    return jnp.where(fast, 0.0, lam)


def round_resolution(sys: SystemParams, s_hat: Array) -> Array:
    """Discrete mapping of eq. (20): nearest resolution by midpoint thresholds."""
    # pin the static menu to the solve dtype: an f64 menu would silently
    # promote s (and everything downstream, incl. the BCD while_loop carry)
    # out of an f32 system's dtype
    res = jnp.asarray(sys.resolutions, s_hat.dtype)
    idx = jnp.argmin(jnp.abs(s_hat[:, None] - res[None, :]), axis=1)
    return res[idx]


def _sp1_bounds(sys: SystemParams, w: Weights, q: Array, tt: Array):
    """(lam_hi, target, T_lo, T_hi) shared by both SP1 engines."""
    lam_hi = jnp.maximum(jnp.maximum(
        2.0 * w.w1 * sys.global_rounds * sys.kappa * sys.f_max ** 3,
        w.w2 * sys.global_rounds), 1.0) * 1e4
    target = w.w2 * sys.global_rounds
    T_lo = jnp.max(q * sys.s_lo ** 2 / sys.f_max + tt) * (1.0 + 1e-12)
    T_hi = jnp.max(q * sys.s_hi ** 2 / jnp.maximum(sys.f_min, 1e-3) + tt) * 2.0
    return lam_hi, target, T_lo, jnp.asarray(T_hi, T_lo.dtype)


def _finish_sp1(sys: SystemParams, w: Weights, acc: AccuracyModel,
                q: Array, lam: Array, tt: Array, T: Array):
    f = _f_of_lambda(sys, w, lam)                      # eq. (19)
    s_hat = _s_of_lambda(sys, w, acc, lam)
    s = round_resolution(sys, s_hat)                   # eq. (20)
    # makespan consistent with the discrete s (feeds SP2's r_min)
    T_out = jnp.max(q * s ** 2 / jnp.maximum(f, 1e-9) + tt)
    return f, s, s_hat, jnp.maximum(T, T_out)


@partial(jax.jit, static_argnames=("acc",))
def _solve_sp1_impl(sys: SystemParams, warr: Array, acc: AccuracyModel,
                    tt: Array):
    """Nested-bisection engine (method="bisect") — the sweep's parity oracle."""
    w = Weights(warr[0], warr[1], warr[2])
    _, q = _coeffs(sys, w)
    lam_hi, target, T_lo, T_hi = _sp1_bounds(sys, w, q, tt)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        lam = _lambda_of_T(sys, w, acc, mid, tt, lam_hi)
        more_time = jnp.sum(lam) > target      # lambda too large -> raise T
        return jnp.where(more_time, mid, lo), jnp.where(more_time, hi, mid)

    lo, hi = lax.fori_loop(0, _OUTER_ITERS, body, (T_lo, T_hi))
    T = 0.5 * (lo + hi)
    lam = _lambda_of_T(sys, w, acc, T, tt, lam_hi)
    return _finish_sp1(sys, w, acc, q, lam, tt, T)


@partial(jax.jit, static_argnames=("acc",))
def _solve_sp1_sweep_impl(sys: SystemParams, warr: Array, acc: AccuracyModel,
                          tt: Array):
    """Batched T-grid sweep engine (method="sweep", the default).

    Each round evaluates Sigma_n lambda_n(T) for a whole geometric grid of
    candidate deadlines in one pass (`kernels.ops.sp1_lambda_sum` for
    LinearAccuracy, a vmapped lambda-bisection otherwise), narrows to the
    sign-change bracket of Sigma lambda - w2 Rg, and finishes with a secant
    step — replacing `_OUTER_ITERS` sequential outer bisections."""
    from ..kernels import ops as kops
    from ..kernels.sp1_sweep import N_CONSTS, lambda_of_T_linear

    w = Weights(warr[0], warr[1], warr[2])
    _, q = _coeffs(sys, w)
    lam_hi, target, T_lo, T_hi = _sp1_bounds(sys, w, q, tt)
    dtype = T_lo.dtype
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)

    linear = isinstance(acc, LinearAccuracy)
    if linear:
        k3 = 2.0 * w.w1 * sys.global_rounds * sys.kappa
        consts = jnp.zeros((N_CONSTS,), dtype).at[:7].set(jnp.stack([
            jnp.asarray(c, dtype) for c in
            (k3, w.rho * acc.slope, sys.f_min, sys.f_max,
             sys.s_lo, sys.s_hi, lam_hi)]))

        def lam_sum(grid):
            return kops.sp1_lambda_sum(grid, q, tt, consts).astype(dtype)

        n_grid, rounds = _SWEEP_POINTS, _SWEEP_ROUNDS
    else:
        def lam_sum(grid):
            return jax.vmap(lambda Tm: jnp.sum(
                _lambda_of_T(sys, w, acc, Tm, tt, lam_hi)))(grid)

        n_grid, rounds = _SWEEP_POINTS_GENERIC, _SWEEP_ROUNDS_GENERIC

    lo, hi = T_lo, T_hi
    S_lo = S_hi = None
    for _ in range(rounds):
        grid = jnp.geomspace(lo, hi, n_grid).astype(dtype)
        S = lam_sum(grid)
        # Sigma lambda(T) is nonincreasing in T; bracket its target crossing
        under = S < target
        idx = jnp.where(jnp.any(under), jnp.maximum(jnp.argmax(under), 1),
                        n_grid - 1)
        lo, hi = grid[idx - 1], grid[idx]
        S_lo, S_hi = S[idx - 1], S[idx]
    t = jnp.clip((S_lo - target) / jnp.maximum(S_lo - S_hi, tiny), 0.0, 1.0)
    T = lo + t * (hi - lo)

    if linear:
        lam = lambda_of_T_linear(T, q, tt, k3, w.rho * acc.slope,
                                 sys.f_min, sys.f_max, sys.s_lo, sys.s_hi,
                                 lam_hi)
    else:
        lam = _lambda_of_T(sys, w, acc, T, tt, lam_hi)
    return _finish_sp1(sys, w, acc, q, lam, tt, T)


_SP1_IMPLS = {"sweep": _solve_sp1_sweep_impl, "bisect": _solve_sp1_impl}


def dual_evals_per_iter(sp1_method: str, acc: AccuracyModel) -> int:
    """SP1 Sigma-lambda(T) dual evaluations one BCD iteration spends,
    counted at the candidate-deadline level (each evaluation inverts
    lambda(T) — closed form for LinearAccuracy under "sweep", an
    `_INNER_ITERS` bisection otherwise). Both engines have fixed trip
    counts and the method/accuracy class are jit static args, so the
    count is exact and known at trace time — `core.bcd` multiplies it by
    the traced iteration count to form the device-resident `sp1_evals`
    counter without adding any compiled work.

    The +1 is the final lambda(T) inversion at the bracketing result
    (the secant T for "sweep", the midpoint for "bisect")."""
    if sp1_method == "sweep":
        if isinstance(acc, LinearAccuracy):
            return _SWEEP_POINTS * _SWEEP_ROUNDS + 1
        return _SWEEP_POINTS_GENERIC * _SWEEP_ROUNDS_GENERIC + 1
    if sp1_method == "bisect":
        return _OUTER_ITERS + 1
    raise ValueError(f"sp1_method must be sweep|bisect, got {sp1_method!r}")


def solve_sp1(sys: SystemParams, w: Weights, acc: AccuracyModel,
              bandwidth: Array, power: Array, method: str = "sweep"
              ) -> Tuple[Array, Array, Array, Array]:
    """Returns (f, s_discrete, s_hat, T).  T is the per-round makespan consistent
    with the rounded resolution (used by SP2 for r_n^min).

    method: "sweep" (batched T-grid dual sweep, the default) or "bisect"
    (the original nested bisection, kept as the parity oracle)."""
    from .energy import rate

    if method not in _SP1_IMPLS:
        raise ValueError(f"method must be sweep|bisect, got {method!r}")
    tt = sys.bits / jnp.maximum(rate(sys, bandwidth, power), 1e-12)
    warr = jnp.asarray([w.w1, max(w.w2, 1e-9), w.rho], tt.dtype)
    return _SP1_IMPLS[method](sys, warr, acc, tt)


@partial(jax.jit, static_argnames=("acc",))
def _solve_sp1_fixed_impl(sys: SystemParams, warr: Array, acc: AccuracyModel,
                          tt: Array, T_round: Array):
    w = Weights(warr[0], warr[1], warr[2])
    alpha, q = _coeffs(sys, w)
    res = jnp.asarray(sys.resolutions, tt.dtype)            # (M,)
    budget = jnp.maximum(T_round - tt, 1e-9)[:, None]       # (N,1)
    f_req = q[:, None] * res[None, :] ** 2 / budget         # (N,M)
    feas = f_req <= sys.f_max * (1.0 + 1e-9)
    f_opt = jnp.clip(f_req, sys.f_min, sys.f_max)
    obj = alpha[:, None] * res[None, :] ** 2 * f_opt ** 2 - w.rho * acc.value(res)[None, :]
    obj = jnp.where(feas, obj, jnp.inf)
    pick = jnp.argmin(obj, axis=1)
    return f_opt[jnp.arange(tt.shape[0]), pick], res[pick]


def solve_sp1_fixed_T(sys: SystemParams, w: Weights, acc: AccuracyModel,
                      bandwidth: Array, power: Array, T_round: float
                      ) -> Tuple[Array, Array]:
    """Deadline-constrained variant used by the Fig. 8/9 comparisons: the round
    deadline is a hard constraint (no w2*T term). s is discrete with M options,
    so each device is solved *exactly* by enumeration: the smallest feasible
    f (energy rises with f) per option, then argmin over options of
    w1 Rg kappa q s^2 f^2 - rho A(s).  Returns (f, s)."""
    from .energy import rate

    tt = sys.bits / jnp.maximum(rate(sys, bandwidth, power), 1e-12)
    warr = jnp.asarray([w.w1, w.w2, w.rho], tt.dtype)
    return _solve_sp1_fixed_impl(sys, warr, acc, tt, jnp.asarray(T_round, tt.dtype))
