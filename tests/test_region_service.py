"""Service layer: RegionAllocator request coalescing, bucketing, LRU warm
starts. The acceptance trace itself (256 mixed-size requests, <= 4 compiled
shapes, warm hits <= 3 BCD iterations) runs at example scale in
examples/region_serve.py; here the same properties are checked at test
scale."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Weights, allocate, make_system
from repro.region import AllocationRequest, RegionAllocator, bucket_size

W = Weights(0.5, 0.5, 1.0)


def _req(cell_id, n, seed=None, drift=0.0):
    sysp = make_system(jax.random.PRNGKey(seed if seed is not None
                                          else 100 + cell_id), n_devices=n)
    if drift:
        sysp = sysp.replace(
            gain=sysp.gain * (1.0 + drift * jnp.sin(jnp.arange(float(n)) + cell_id)))
    return AllocationRequest(cell_id=cell_id, sys=sysp)


def _allocator(**kw):
    kw.setdefault("cells_per_batch", 4)
    kw.setdefault("min_bucket", 8)
    return RegionAllocator(W, **kw)


def test_mixed_size_trace_bucketing_and_warm_cache():
    """A mixed-size trace spanning pools of 5..60 devices compiles <= 4
    batch shapes; drifted re-requests hit the warm cache and re-solve in
    <= 3 BCD iterations."""
    svc = _allocator()
    sizes = [5, 7, 9, 14, 17, 25, 33, 50, 60, 12, 28, 6]
    reqs = [_req(i, n) for i, n in enumerate(sizes)]
    res = svc.solve(reqs)
    assert set(res) == set(range(len(sizes)))
    assert len(svc.compiled_shapes) <= 4
    assert svc.stats["cache_hits"] == 0
    assert all(r.converged and np.isfinite(r.objective)
               for r in res.values())
    assert all(not r.warm for r in res.values())
    # each response is unpadded back to the request's pool size
    for i, n in enumerate(sizes):
        assert res[i].allocation.bandwidth.shape == (n,)
        assert res[i].bucket == bucket_size(n, 8)

    # drifted re-requests: warm hits, <= 3 iterations, no new shapes
    shapes_before = set(svc.compiled_shapes)
    reqs2 = [_req(i, n, drift=0.02) for i, n in enumerate(sizes)]
    res2 = svc.solve(reqs2)
    assert all(r.warm for r in res2.values())
    assert max(r.iters for r in res2.values()) <= 3
    assert svc.compiled_shapes == shapes_before
    assert svc.stats["cache_hits"] == len(sizes)


def test_service_matches_direct_allocate():
    """A service response equals a direct `allocate` of the same cell (the
    padding bit-identity transfers through the vmapped batch to ~float
    precision)."""
    svc = _allocator()
    req = _req(0, 11)
    res = svc.solve([req])[0]
    direct = allocate(req.sys, W, max_iters=20, tol=1e-6)
    np.testing.assert_allclose(np.asarray(res.allocation.bandwidth),
                               np.asarray(direct.allocation.bandwidth),
                               rtol=1e-9)
    assert res.objective == pytest.approx(direct.objective, rel=1e-9)
    assert res.iters == direct.iters


def test_submit_flush_stream():
    svc = _allocator()
    for i in range(3):
        svc.submit(_req(i, 6))
    res = svc.flush()
    assert set(res) == {0, 1, 2}
    assert svc.flush() == {}   # queue drained
    assert svc.stats["requests"] == 3
    assert svc.stats["batches"] == 1   # one bucket, one chunk


def test_pool_resize_invalidates_cache_entry():
    """Same cell_id with a different device count must not warm-start from
    the stale (differently shaped) solution."""
    svc = _allocator()
    svc.solve([_req(7, 6)])
    res = svc.solve([_req(7, 9)])[7]
    assert not res.warm
    assert res.allocation.bandwidth.shape == (9,)


def test_lru_eviction():
    svc = _allocator(cache_size=2)
    svc.solve([_req(i, 6) for i in range(3)])   # one batch, 3 cells
    assert len(svc._cache) == 2
    # cell 0 was evicted (first in), cells 1-2 stay warm
    res = svc.solve([_req(i, 6, drift=0.01) for i in range(3)])
    assert not res[0].warm and res[1].warm and res[2].warm


def test_chunking_over_cells_per_batch():
    """More requests than cells_per_batch in one bucket split into chunks
    of the SAME compiled shape."""
    svc = _allocator(cells_per_batch=2)
    res = svc.solve([_req(i, 6) for i in range(5)])
    assert len(res) == 5
    assert svc.stats["batches"] == 3          # ceil(5 / 2)
    assert len(svc.compiled_shapes) == 1      # all (2, 8)
    assert svc.stats["cells_padded"] == 1     # the last chunk padded 1 cell
