"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced JAX ops, validating the exact code that compiles for TPU.
On TPU backends they compile natively. `REPRO_FORCE_INTERPRET=1` forces
interpret mode everywhere.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.ref import sp1_lambda_sum_ref as _sp1_sweep_ref
from repro.kernels.ref import waterfill_gprime_ref as _waterfill_ref
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv
from repro.kernels.sp1_sweep import sp1_lambda_sum as _sp1_sweep
from repro.kernels.waterfill import waterfill_gprime as _waterfill


def _interpret() -> bool:
    if os.environ.get("REPRO_FORCE_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, logw, u, *, chunk: int = 64):
    return _rwkv(r, k, v, logw, u, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "block_d"))
def mamba_scan(dt, A, Bt, Ct, x, *, chunk: int = 64, block_d: int = 256):
    return _mamba(dt, A, Bt, Ct, x, chunk=chunk, block_d=block_d,
                  interpret=_interpret())


def waterfill_compute_dtype(input_dtype):
    """Dtype the dual sweep actually computes in: f32 on TPU (no f64 on the
    VPU, and interpret mode still lowers through TPU XLA), the input dtype
    elsewhere. Callers sizing search brackets (core.sp2._thm2_dual_mu) must
    respect this, not the input dtype — an f64-sized bracket overflows the
    f32 kernel to NaN."""
    if jax.default_backend() == "tpu":
        return jnp.dtype(jnp.float32)
    return jnp.dtype(input_dtype)


def _resolve_impl(impl: str) -> str:
    """Shared "auto" resolution for the dual-sweep ops: native Pallas on TPU,
    the pure-jnp ref oracle on CPU, interpret-mode kernel bodies under
    REPRO_FORCE_INTERPRET=1. Resolved OUTSIDE the jit cache so flipping the
    env var between calls takes effect (impl is the static cache key)."""
    if impl not in ("auto", "pallas", "ref"):
        raise ValueError(f"impl must be auto|pallas|ref, got {impl!r}")
    if impl == "auto":
        return "pallas" if (jax.default_backend() == "tpu"
                            or os.environ.get("REPRO_FORCE_INTERPRET")) else "ref"
    return impl


@functools.partial(jax.jit, static_argnames=("block_n", "impl", "dtype"))
def _waterfill_dispatch(mu, j, rmin, B_total, *, block_n: int,
                        impl: str, dtype):
    if impl == "ref":
        return _waterfill_ref(mu.astype(dtype), j.astype(dtype),
                              rmin.astype(dtype), jnp.asarray(B_total, dtype))
    return _waterfill(mu, j, rmin, jnp.asarray(B_total, dtype),
                      block_n=block_n, interpret=_interpret(), dtype=dtype)


def waterfill_gprime(mu, j, rmin, B_total, *, block_n: int = 1024,
                     impl: str = "auto"):
    """Production entry for the SP2 dual sweep (used by `core.sp2`).

    impl: "auto" — native Pallas on TPU, the pure-jnp ref oracle on CPU
          (full input precision, no interpret-mode overhead); setting
          REPRO_FORCE_INTERPRET=1 routes "auto" through the interpret-mode
          kernel body instead.  "pallas" / "ref" force a path explicitly.
    B_total may be a traced scalar (a per-cell leaf in heterogeneous fleets).
    Computes in `waterfill_compute_dtype(mu.dtype)`.
    """
    return _waterfill_dispatch(mu, j, rmin, B_total, block_n=block_n,
                               impl=_resolve_impl(impl),
                               dtype=waterfill_compute_dtype(mu.dtype))


@functools.partial(jax.jit, static_argnames=("block_n", "impl", "dtype"))
def _sp1_sweep_dispatch(T_grid, q, tt, consts, *, block_n: int,
                        impl: str, dtype):
    if impl == "ref":
        return _sp1_sweep_ref(T_grid.astype(dtype), q.astype(dtype),
                              tt.astype(dtype), consts.astype(dtype))
    return _sp1_sweep(T_grid, q, tt, consts, block_n=block_n,
                      interpret=_interpret(), dtype=dtype)


def sp1_lambda_sum(T_grid, q, tt, consts, *, block_n: int = 1024,
                   impl: str = "auto"):
    """Production entry for the batched SP1 dual sweep (used by `core.sp1`):
    Sigma_n lambda_n(T) for M candidate deadlines in one device pass.

    T_grid: (M,) candidate round deadlines; q/tt: (N,) per-device cycle and
    transmission-time coefficients; consts: (sp1_sweep.N_CONSTS,) scalar
    coefficient vector (may be traced — per-cell leaves vary across a
    heterogeneous fleet). impl semantics match `waterfill_gprime`; computes
    in `waterfill_compute_dtype(T_grid.dtype)`.
    """
    return _sp1_sweep_dispatch(T_grid, q, tt, consts, block_n=block_n,
                               impl=_resolve_impl(impl),
                               dtype=waterfill_compute_dtype(T_grid.dtype))
