"""Metaverse-scale allocation, three ways:

1. `allocate_fleet`: the full BCD allocator (Algorithm 2) vmap'd across 64
   base-station cells x 2048 AR clients each — one XLA program, no Python
   loop over cells, convergence decided on device. SP1 runs the batched
   T-grid dual sweep (closed-form lambda inversion + one device pass per
   grid) instead of the nested 56x56 bisection.
2. A HETEROGENEOUS fleet: cells with different bandwidth / power budgets
   (macro, micro, and pico cell classes) batched through the same vmap —
   per-cell scalars are traced pytree leaves, not static config.
3. The raw closed-form SP2 path for a single 2^17-client region, with the
   Pallas waterfill kernel doing the batched dual sweep.

    PYTHONPATH=src python examples/allocate_fleet.py

REPRO_SMOKE=1 shrinks every section to CI-smoke size (~seconds).
"""
import os
import time

import jax
import jax.numpy as jnp

from repro import Problem, SolverSpec, Weights, make_fleet, make_system, solve
from repro.core.energy import t_cmp
from repro.core.sp2 import r_min, solve_sp2_direct
from repro.core.types import dbm_to_watt
from repro.kernels import ops

SMOKE = os.environ.get("REPRO_SMOKE") == "1"

# --- 1. fleet BCD: 64 cells x 2048 devices in one solve() call ------------
C, N_CELL = (4, 64) if SMOKE else (64, 2048)
key = jax.random.PRNGKey(0)
fleet = make_fleet(key, n_cells=C, n_devices=N_CELL,
                   bandwidth_total=20e6 * N_CELL / 50)

t0 = time.time()
# tol=1e-4: comfortably above the f32 rel-step floor (tighter requests
# are floored there; solve() warns once if you try)
res = solve(Problem(system=fleet, weights=Weights(0.5, 0.5, 1.0)),
            SolverSpec(max_iters=8, tol=1e-4))
jax.block_until_ready(res.allocation.bandwidth)
print(f"allocate_fleet: {C} cells x {N_CELL} devices "
      f"({C * N_CELL} AR clients) in {time.time() - t0:.1f}s — "
      f"{int(jnp.sum(res.converged))}/{C} cells converged, "
      f"mean objective {float(jnp.mean(res.objective)):.4g}")

# --- 2. heterogeneous fleet with PER-CELL weights -------------------------
# macro / micro / pico cell classes, each weighing energy vs latency
# differently — weights are a traced (C, 3) operand of the one compiled
# solve, so the mixed-demand fleet costs zero extra compiles
CH, N_H = (6, 64) if SMOKE else (12, 256)
classes = [(80e6, 12.0, Weights(0.2, 0.8, 1.0)),    # macro: latency-heavy
           (40e6, 8.0, Weights(0.5, 0.5, 10.0)),    # micro: balanced
           (10e6, 4.0, Weights(0.9, 0.1, 1.0))]     # pico: energy-heavy
bw = [classes[c % 3][0] for c in range(CH)]
pmax = [dbm_to_watt(classes[c % 3][1]) for c in range(CH)]
w_cells = [classes[c % 3][2] for c in range(CH)]
het = make_fleet(jax.random.fold_in(key, 1), n_cells=CH, n_devices=N_H,
                 bandwidth_total=bw, p_max=pmax)
t0 = time.time()
res_h = solve(Problem(system=het, weights=w_cells),
              SolverSpec(max_iters=8, tol=1e-4))
jax.block_until_ready(res_h.allocation.bandwidth)
obj = jnp.asarray(res_h.objective)
print(f"heterogeneous fleet: {CH} mixed cells (B {min(bw)/1e6:.0f}-"
      f"{max(bw)/1e6:.0f} MHz, per-cell weights) in {time.time() - t0:.1f}s "
      f"— {int(jnp.sum(res_h.converged))}/{CH} converged; per-class mean "
      "obj: " + ", ".join(f"{float(jnp.mean(obj[i::3])):.4g}"
                          for i in range(3)))

# --- 3. single giant region through the closed-form SP2 solver ------------
N = 1 << 12 if SMOKE else 1 << 17
system = make_system(key, n_devices=N, bandwidth_total=20e6 * (N / 50))

f = jnp.full((N,), 1e9)
s = jnp.full((N,), 320.0)
T = float(jnp.max(t_cmp(system, f, s))) * 1.2
rmin = r_min(system, f, s, jnp.asarray(T))

t0 = time.time()
p, B = solve_sp2_direct(system, rmin)
jax.block_until_ready(B)
print(f"direct SP2 for {N} devices: {time.time()-t0:.2f}s "
      f"(sum B = {float(B.sum())/1e6:.1f} MHz)")

# the kernelized dual sweep (128 candidate multipliers in one pass) — the
# same batched evaluation `solve_sp2_v2_thm2` now uses for its dual search
nu = jnp.ones((N,))
j = nu * system.bits * system.noise_psd / system.gain
mu = jnp.logspace(-12, -2, 128)
t0 = time.time()
g = ops.waterfill_gprime(mu, j, rmin, system.bandwidth_total, block_n=2048)
jax.block_until_ready(g)
print(f"waterfill dual sweep (128 mu x {N} devices): {time.time()-t0:.2f}s; "
      f"root bracket at mu~{float(mu[int(jnp.argmin(jnp.abs(g)))]):.2e}")
