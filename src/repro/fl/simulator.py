"""FL-MAR system simulator: couples the allocator (repro.core) to actual
federated training (repro.fl) and keeps the paper's energy/time ledger.

This is the end-to-end loop of the paper's Fig. 1:
    allocate -> each device trains locally at its allocated resolution /
    CPU frequency -> uploads over its allocated (p_n, B_n) channel ->
    FedAvg -> repeat; the ledger accumulates eqs. (2), (3), (8), (10).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import Allocation, SystemParams, Weights, allocate
from repro.core.accuracy import AccuracyModel, default_accuracy
from repro.core.energy import e_cmp, e_trans, t_cmp, t_trans
from repro.fl.data import FLDataset, make_federated_dataset
from repro.fl.server import FLRunResult, run_federated


def map_resolution_to_dataset(sys: SystemParams, resolution: jax.Array,
                              dataset_resolutions: Sequence[int]) -> List[int]:
    """Map the allocator's s_n (pixels on the paper's 160..640 grid) onto the
    dataset's rendering grid by index (s_bar_m <-> dataset_res_m)."""
    res = list(sys.resolutions)
    out = []
    for s in resolution.tolist():
        idx = min(range(len(res)), key=lambda m: abs(res[m] - s))
        idx = min(idx, len(dataset_resolutions) - 1)
        out.append(int(dataset_resolutions[idx]))
    return out


@dataclasses.dataclass
class SimResult:
    allocation: Allocation
    fl: FLRunResult
    ledger: Dict[str, float]


def simulate(key: jax.Array, sys: SystemParams, w: Weights,
             acc_model: Optional[AccuracyModel] = None,
             dataset: Optional[FLDataset] = None,
             dataset_resolutions: Sequence[int] = (8, 16, 24, 32),
             global_rounds: int = 10, local_iters: int = 5,
             lr: float = 0.05, split: str = "iid",
             unbalanced: bool = False) -> SimResult:
    """Allocate resources, run FedAvg at the allocated resolutions, and return
    the energy/time ledger implied by the allocation (paper eqs. 9 & 11)."""
    k_ds, k_fl = jax.random.split(key)
    if dataset is None:
        dataset = make_federated_dataset(
            k_ds, n_clients=sys.n, split=split, unbalanced=unbalanced)
    assert dataset.n_clients == sys.n, "one device per FL client"

    result = allocate(sys, w, acc=acc_model or default_accuracy(), max_iters=8)
    alloc = result.allocation
    ds_res = map_resolution_to_dataset(sys, alloc.resolution, dataset_resolutions)

    fl = run_federated(k_fl, dataset, ds_res,
                       global_rounds=global_rounds, local_iters=local_iters,
                       lr=lr)

    per_round_e = (e_trans(sys, alloc.bandwidth, alloc.power)
                   + e_cmp(sys, alloc.freq, alloc.resolution))
    per_round_t = jnp.max(t_cmp(sys, alloc.freq, alloc.resolution)
                          + t_trans(sys, alloc.bandwidth, alloc.power))
    ledger = dict(
        energy_per_round_J=float(jnp.sum(per_round_e)),
        time_per_round_s=float(per_round_t),
        energy_total_J=float(jnp.sum(per_round_e)) * global_rounds,
        time_total_s=float(per_round_t) * global_rounds,
        final_accuracy=fl.round_accuracy[-1] if fl.round_accuracy else float("nan"),
        mean_resolution=float(jnp.mean(alloc.resolution)),
    )
    return SimResult(allocation=alloc, fl=fl, ledger=ledger)
