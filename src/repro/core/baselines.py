"""Benchmark algorithms the paper compares against (§VII, Table I).

* MinPixel   — random resource allocation, s fixed at the minimum resolution
               (the paper's "Benchmark algorithm").
* RandPixel  — random resource allocation, random resolution.
* CommOnly   — optimize (p, B) only; f fixed from the deadline, s random (§VII-C).
* CompOnly   — optimize (f, s) only; p = pmax, B = B/N (§VII-C).
* Scheme1    — Yang et al. [11]: FDMA energy minimization under a deadline,
               without resolution optimization (s = standard).  Implemented as
               the deadline-constrained BCD with s pinned (faithful to how the
               paper performs the comparison in Fig. 9: same objective,
               no s_n variable).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .accuracy import AccuracyModel, default_accuracy
from .bcd import BCDResult, initial_allocation
from .sp1 import solve_sp1_fixed_T
from .sp2 import r_min, solve_sp2
from .types import Allocation, SystemParams, Weights


def min_pixel(sys: SystemParams, key: jax.Array, sweep: str = "power") -> Allocation:
    """Paper §VII-B benchmark: fixed s = s_lo; in the power sweep, f random in
    [0.1, 2] GHz and p = pmax; in the frequency sweep, p random and f = fmax;
    B = B/N either way."""
    n = sys.n
    if sweep == "power":
        freq = jax.random.uniform(key, (n,), minval=0.1e9, maxval=sys.f_max)
        power = jnp.full((n,), sys.p_max)
    else:
        freq = jnp.full((n,), sys.f_max)
        power = jax.random.uniform(key, (n,), minval=max(sys.p_min, 1e-4), maxval=sys.p_max)
    return Allocation(bandwidth=jnp.full((n,), sys.bandwidth_total / n),
                      power=power, freq=freq,
                      resolution=jnp.full((n,), sys.s_lo))


def rand_pixel(sys: SystemParams, key: jax.Array, sweep: str = "power") -> Allocation:
    k1, k2 = jax.random.split(key)
    base = min_pixel(sys, k1, sweep=sweep)
    res = jnp.asarray(sys.resolutions)
    idx = jax.random.randint(k2, (sys.n,), 0, len(sys.resolutions))
    return Allocation(bandwidth=base.bandwidth, power=base.power,
                      freq=base.freq, resolution=res[idx])


def comm_only(sys: SystemParams, w: Weights, T_total: float, key: jax.Array,
              acc: Optional[AccuracyModel] = None, max_iters: int = 10) -> Allocation:
    """§VII-C: only (p, B) optimized. f is pinned from constraint (13a):
    f_n = Rg Rl zeta s^2 c D / (T - Rg max(d/r)), s random."""
    acc = acc if acc is not None else default_accuracy()
    res = jnp.asarray(sys.resolutions)
    idx = jax.random.randint(key, (sys.n,), 0, len(sys.resolutions))
    s = res[idx]
    init = initial_allocation(sys)
    from .energy import rate
    r0 = rate(sys, init.bandwidth, init.power)
    T_round = T_total / sys.global_rounds
    tt0 = float(jnp.max(sys.bits / r0))
    cyc = sys.local_iters * sys.zeta * s ** 2 * sys.cycles * sys.samples
    f = jnp.clip(cyc / jnp.maximum(T_round - tt0, 1e-6), sys.f_min, sys.f_max)
    rmin = r_min(sys, f, s, jnp.asarray(T_round))
    p, B = init.power, init.bandwidth
    for _ in range(max_iters):
        sp2 = solve_sp2(sys, w.normalized(), rmin, p, B)
        p, B = sp2.power, sp2.bandwidth
    return Allocation(bandwidth=B, power=p, freq=f, resolution=s,
                      T=jnp.asarray(T_round))


def comp_only(sys: SystemParams, w: Weights, T_total: float,
              acc: Optional[AccuracyModel] = None) -> Allocation:
    """§VII-C: only (f, s) optimized; p = pmax, B = B/N."""
    acc = acc if acc is not None else default_accuracy()
    init = initial_allocation(sys)
    T_round = T_total / sys.global_rounds
    f, s = solve_sp1_fixed_T(sys, w.normalized(), acc, init.bandwidth, init.power, T_round)
    return Allocation(bandwidth=init.bandwidth, power=init.power, freq=f,
                      resolution=s, T=jnp.asarray(T_round))


def scheme1(sys: SystemParams, w: Weights, T_total: float,
            acc: Optional[AccuracyModel] = None) -> Allocation:
    """Yang et al. [11] comparison baseline ("Scheme 1"): FDMA energy
    minimization under a deadline WITHOUT joint bandwidth/power shaping and
    without a resolution variable (s = standard sample).

    Proxy implementation (the original's internals are not reproducible from
    [11] alone, noted in EXPERIMENTS.md): equal bandwidth B/N, maximum power,
    per-device minimum CPU frequency that meets the deadline — i.e. the
    deadline-feasible member of the non-joint family the paper compares
    against. The paper's own Fig. 9 advantage comes from jointly optimizing
    (p, B, f), which `allocate_fixed_deadline` (s pinned) provides."""
    from .energy import rate

    n = sys.n
    T_round = T_total / sys.global_rounds
    B = jnp.full((n,), sys.bandwidth_total / n)
    p = jnp.full((n,), sys.p_max)
    tt = sys.bits / jnp.maximum(rate(sys, B, p), 1e-12)
    s = jnp.full((n,), sys.s_standard)
    cyc = sys.local_iters * sys.zeta * s ** 2 * sys.cycles * sys.samples
    f = jnp.clip(cyc / jnp.maximum(T_round - tt, 1e-9), sys.f_min, sys.f_max)
    return Allocation(bandwidth=B, power=p, freq=f, resolution=s,
                      T=jnp.asarray(T_round))


def conference_version(sys: SystemParams, w: Weights, T_total: float,
                       max_iters: int = 10) -> BCDResult:
    """The paper's ICDCS conference algorithm [1]: joint (p, B, f) under a
    deadline, no resolution variable (s pinned to the standard sample) —
    what Fig. 9 actually compares against Scheme 1."""
    from repro.api import Problem, SolverSpec, solve

    pinned = sys.replace(resolutions=(sys.s_standard,))
    return solve(Problem(system=pinned, weights=Weights(w.w1, w.w2, 0.0),
                         acc=default_accuracy(), deadline=T_total),
                 SolverSpec(max_iters=max_iters))
