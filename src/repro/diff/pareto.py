"""Pareto-frontier sweeps over the weight simplex — one compiled program.

The energy/latency trade-off curve (the paper's Fig. 5 axis) is a sweep of
the scalarization weight w1 (with w2 = 1 - w1, rho fixed). Because weights
are traced *operands* of the solvers — never jit keys — the whole sweep
lowers to the fleet path: the single cell is replicated across a (C, N)
stack, the (C, 3) weight grid rides along, and `solve_and_grad`'s vmap
solves AND differentiates every point in ONE compiled program. The per-
point weight gradients come out for free (one linearization serves all
four metric cotangents), giving the frontier's local exchange rates
dE/dw, dT/dw alongside the frontier itself.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..api.problem import Problem
from ..api.spec import SolverSpec
from ..core.bcd import stack_systems
from ..core.types import Weights
from .implicit import METRICS, solve_and_grad

__all__ = ["ParetoResult", "pareto_front", "pareto_sweep", "weight_grid"]


def weight_grid(n: int = 17, rho: float = 0.3, lo: float = 0.05,
                hi: float = 0.95) -> np.ndarray:
    """(n, 3) raw weight rows walking the w1-w2 simplex edge: w1 linear in
    [lo, hi], w2 = 1 - w1, rho fixed. Endpoints stay off the degenerate
    corners — w1 or w2 = 0 collapses a whole objective term and the BCD
    map can lose its contraction there."""
    if not 0.0 < lo < hi < 1.0:
        raise ValueError(f"weight_grid: need 0 < lo < hi < 1, "
                         f"got ({lo}, {hi})")
    w1 = np.linspace(lo, hi, int(n))
    return np.stack([w1, 1.0 - w1, np.full(int(n), float(rho))], axis=-1)


def pareto_front(energy, time) -> np.ndarray:
    """Boolean non-dominated mask for jointly minimizing (energy, time).

    A point is on the front iff no other point is at least as good on both
    axes and strictly better on one. Ties keep both points. NaN entries
    (non-converged sweeps) never dominate and never join the front.
    """
    e = np.asarray(energy, float)
    t = np.asarray(time, float)
    if e.shape != t.shape or e.ndim != 1:
        raise ValueError(
            f"pareto_front: energy/time must be matching 1-D arrays, got "
            f"{e.shape} vs {t.shape}")
    ok = np.isfinite(e) & np.isfinite(t)
    mask = ok.copy()
    for i in np.nonzero(ok)[0]:
        dom = ok & (e <= e[i]) & (t <= t[i]) & ((e < e[i]) | (t < t[i]))
        if dom.any():
            mask[i] = False
    return mask


@dataclasses.dataclass(frozen=True)
class ParetoResult:
    """Outcome of `pareto_sweep` (host numpy, plot-ready).

    weights : the (n, 3) raw weight grid swept.
    value : metric -> (n,) realized values.
    grads : metric -> (n, 3) gradients w.r.t. the raw weight rows.
    converged : (n,) BCD convergence flags from the forward solve.
    front : (n,) non-dominated mask over (energy, time), restricted to
        converged points.
    """
    weights: np.ndarray
    value: Dict[str, np.ndarray]
    grads: Dict[str, np.ndarray]
    converged: np.ndarray
    front: np.ndarray


def pareto_sweep(problem: Problem, spec: Optional[SolverSpec] = None, *,
                 n: int = 17, rho: Optional[float] = None,
                 grid: Optional[np.ndarray] = None,
                 adjoint_iters: int = 30) -> ParetoResult:
    """Trace the energy/time frontier of a single-cell problem.

    Replicates the cell over an `n`-point weight grid (or an explicit
    `grid` of raw (n, 3) rows) and runs one vmapped solve-and-grad plus
    one vmapped forward solve (for the convergence flags). rho defaults
    to the problem's own accuracy weight.
    """
    if problem.cells is not None:
        raise ValueError("pareto_sweep: single-cell problems only")
    if grid is None:
        if rho is None:
            w = problem.weights
            rho = float(w.rho) if isinstance(w, Weights) \
                else float(np.asarray(w, float)[-1])
        grid = weight_grid(n, rho=rho)
    grid = np.asarray(grid, float)
    if grid.ndim != 2 or grid.shape[1] != 3:
        raise ValueError(f"pareto_sweep: grid must be (n, 3) raw weight "
                         f"rows, got {grid.shape}")
    c = grid.shape[0]

    stacked = stack_systems([problem.system] * c)
    swept = dataclasses.replace(problem, system=stacked,
                                weights=jnp.asarray(grid))
    g = solve_and_grad(swept, spec, wrt=(), adjoint_iters=adjoint_iters)

    from ..api.solve import solve   # local: avoid import cycle
    fwd = solve(swept, spec)
    converged = np.asarray(fwd.converged).astype(bool).reshape(c)

    value = {m: np.asarray(g.value[m], float) for m in METRICS}
    grads = {m: np.asarray(g.grads[m]["weights"], float) for m in METRICS}
    e = np.where(converged, value["energy"], np.nan)
    t = np.where(converged, value["time"], np.nan)
    return ParetoResult(weights=grid, value=value, grads=grads,
                        converged=converged,
                        front=pareto_front(e, t))
