"""Production mesh construction.

make_production_mesh is a FUNCTION (module import never touches jax device
state).  Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods,
512 chips as (pod=2, data=16, model=16); the 'pod' axis extends data
parallelism across the inter-pod links (DCN in practice).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke tests on the host CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
