"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay WKV.
[arXiv:2404.05892]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", arch_type="ssm",
    n_layers=24, d_model=2048, n_heads=32, kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    attention="none",
    block_pattern=("rwkv",),
    source="arXiv:2404.05892",
)
