"""Admission layer: the request queue in front of the region pipeline.

Requests enter the pipeline here and wait — per bucket — until a
*batch-closing policy* decides their batch is worth dispatching. The queue
tracks per-request enqueue times, deadlines, and priorities; when a batch
closes, its members are handed to the planning layer in
(priority desc, arrival) order and their queue wait is charged to the
pipeline's `StageClocks`.

Policies (`AllocationRequest.deadline`/`priority` feed them):

  * `CloseOnFull`   — close only when `cells_per_batch` requests are
    queued (plus the forced close of a `flush`). The throughput-greedy
    default: every dispatched chunk is fully occupied, so the compiled
    batch shape never solves avoidable pad cells.
  * `MaxWait`       — close-on-full OR when the oldest queued request has
    waited `max_wait` (in the caller's clock units — wall seconds with the
    default clock, logical ticks if the caller passes its own `now`).
    Bounds queue latency under trickle traffic.
  * `DeadlineSlack` — close-on-full OR when any queued request's deadline
    is within `slack` of `now`. The SLO-shaped policy: a batch closes
    exactly early enough for its tightest request.

The clock is caller-defined: every entry point takes `now` (defaulting to
`time.monotonic()`), so tests and benchmarks can drive the policies with
logical ticks and deadlines stay in one consistent unit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Hashable, List, Optional, Tuple

from repro.core.types import SystemParams, Weights

from .batch import DEFAULT_MIN_BUCKET, bucket_size


@dataclasses.dataclass
class AllocationRequest:
    """One cell asking for a (re-)allocation against its current channel
    snapshot. `cell_id` keys the warm-start cache: re-requests of the same
    cell (drifted gains, same device pool) re-solve from the previous
    solution. `w`, if set, overrides the allocator's default weights for
    this request only (traced — never a recompile). `deadline` (absolute,
    in the admission clock's units) and `priority` (larger first) feed the
    batch-closing policy and the within-batch ordering."""
    cell_id: Hashable
    sys: SystemParams
    w: Optional[Weights] = None
    deadline: Optional[float] = None
    priority: int = 0


@dataclasses.dataclass
class StageClocks:
    """Aggregate wall time spent in each pipeline stage (seconds, except
    `queue_wait_s`, which is in the admission clock's units — wall seconds
    unless the caller drives `now` itself).

      queue_wait_s : sum over requests of (batch close - submit)
      plan_s       : host-side pad/stack/warm-init batch assembly
      dispatch_s   : host time to trace/enqueue the solve (async dispatch)
      device_s     : dispatch -> compute observed ready (in-flight time;
                     an upper bound measured at the first blocking poll)
      gather_s     : device->host materialization of responses
    """
    queue_wait_s: float = 0.0
    plan_s: float = 0.0
    dispatch_s: float = 0.0
    device_s: float = 0.0
    gather_s: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class QueuedRequest:
    """A request waiting for its batch to close. `token` is an opaque
    caller payload carried through the queue — the pipeline stores the
    request's `PendingResponse` there so a closed batch can be bound back
    to the futures it serves."""
    request: AllocationRequest
    t_enqueue: float
    seq: int    # global arrival order: the FIFO tiebreak within a priority
    token: object = None


class BatchPolicy:
    """Decides when a bucket's pending requests close into a batch.

    `ready(queued, now, cells_per_batch)` sees the bucket's queue in
    arrival order and returns True to close a batch of (up to)
    `cells_per_batch` requests now. A forced `flush` closes everything
    regardless of the policy."""

    def ready(self, queued: List[QueuedRequest], now: float,
              cells_per_batch: int) -> bool:
        raise NotImplementedError


class CloseOnFull(BatchPolicy):
    """Close only full batches (flush drains the rest)."""

    def ready(self, queued, now, cells_per_batch):
        return len(queued) >= cells_per_batch


class MaxWait(BatchPolicy):
    """Close on full, or when the oldest request has waited `max_wait`."""

    def __init__(self, max_wait: float):
        if max_wait < 0:
            raise ValueError(f"MaxWait: max_wait must be >= 0, got {max_wait}")
        self.max_wait = float(max_wait)

    def ready(self, queued, now, cells_per_batch):
        if len(queued) >= cells_per_batch:
            return True
        return bool(queued) and now - queued[0].t_enqueue >= self.max_wait


class DeadlineSlack(BatchPolicy):
    """Close on full, or when any queued deadline is within `slack` of now.

    Requests without a deadline never trigger the early close (they ride
    along when a deadlined neighbor closes the batch, or when it fills)."""

    def __init__(self, slack: float = 0.0):
        self.slack = float(slack)

    def ready(self, queued, now, cells_per_batch):
        if len(queued) >= cells_per_batch:
            return True
        return any(q.request.deadline is not None
                   and q.request.deadline - now <= self.slack
                   for q in queued)


class AdmissionQueue:
    """Per-bucket request queues + the batch-closing policy.

    `submit` files a request under its device-count bucket;
    `close_ready(now)` asks the policy which batches to close and returns
    them as `(bucket, [QueuedRequest, ...])` groups — each at most
    `cells_per_batch` long, ordered by (priority desc, arrival), buckets in
    ascending order (the same deterministic grouping the synchronous
    `RegionAllocator.solve` always produced for equal priorities)."""

    def __init__(self, cells_per_batch: int,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 policy: Optional[BatchPolicy] = None,
                 clocks: Optional[StageClocks] = None):
        if cells_per_batch < 1:
            raise ValueError("cells_per_batch must be >= 1")
        self.cells_per_batch = int(cells_per_batch)
        self.min_bucket = int(min_bucket)
        self.policy = policy if policy is not None else CloseOnFull()
        self.clocks = clocks if clocks is not None else StageClocks()
        self._queues: Dict[int, List[QueuedRequest]] = {}
        self._seq = 0

    def submit(self, request: AllocationRequest,
               now: Optional[float] = None, token: object = None) -> int:
        """Queue a request; returns the bucket it was filed under."""
        now = time.monotonic() if now is None else now
        bucket = bucket_size(request.sys.n, self.min_bucket)
        self._queues.setdefault(bucket, []).append(
            QueuedRequest(request, now, self._seq, token))
        self._seq += 1
        return bucket

    @property
    def pending(self) -> int:
        """Requests queued but not yet closed into a batch."""
        return sum(len(q) for q in self._queues.values())

    def close_ready(self, now: Optional[float] = None, force: bool = False
                    ) -> List[Tuple[int, List[QueuedRequest]]]:
        """Close every batch the policy (or `force`) says is ready.

        Returns `(bucket, [QueuedRequest, ...])` groups — each at most
        `cells_per_batch` long, ordered by (priority desc, arrival),
        buckets ascending (the deterministic grouping the synchronous
        `RegionAllocator.solve` always produced for equal priorities)."""
        now = time.monotonic() if now is None else now
        closed: List[Tuple[int, List[QueuedRequest]]] = []
        for bucket in sorted(self._queues):
            queue = self._queues[bucket]
            while queue and (force or self.policy.ready(
                    queue, now, self.cells_per_batch)):
                # stable sort: FIFO within equal priorities, so the default
                # (all priority 0) reproduces pure arrival order
                queue.sort(key=lambda e: (-e.request.priority, e.seq))
                take = queue[:self.cells_per_batch]
                queue = queue[self.cells_per_batch:]
                self._queues[bucket] = queue
                for e in take:
                    self.clocks.queue_wait_s += max(0.0, now - e.t_enqueue)
                closed.append((bucket, take))
        return closed
