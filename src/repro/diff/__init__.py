"""Differentiable allocation: implicit KKT gradients through the BCD fixed
point, weight auto-tuning against scalarized targets, Pareto-frontier
sweeps over the weight simplex, and learned accuracy surrogates fitted from
realized FL training curves. See ROADMAP "Differentiable allocation"."""
from .implicit import (DEFAULT_WRT, METRICS, GradResult,  # noqa: F401
                       solve_and_grad)
from .pareto import (ParetoResult, pareto_front, pareto_sweep,  # noqa: F401
                     weight_grid)
from .surrogate import (SurrogateAccuracy, fit_from_training,  # noqa: F401
                        fit_surrogate, problem_with_surrogate)
from .tune import TuneResult, target_from_slos, tune_weights  # noqa: F401

__all__ = [
    "DEFAULT_WRT", "METRICS", "GradResult", "ParetoResult",
    "SurrogateAccuracy", "TuneResult", "fit_from_training", "fit_surrogate",
    "pareto_front", "pareto_sweep", "problem_with_surrogate",
    "solve_and_grad", "target_from_slos", "tune_weights", "weight_grid",
]
