"""Test-suite configuration: enable x64 up front so module ordering cannot
change solver/kernel dtypes mid-suite (the allocator tests need f64
bisections; kernels pin their own compute dtypes).

Hypothesis (optional — property tests skip without it) runs under named
profiles: "ci" is fully pinned (derandomized, no deadline, bounded
examples) so the quick CI job is reproducible run-to-run; "dev" keeps
random exploration locally but drops the per-example deadline, which jit
compilation on first draw would always blow. Select with
HYPOTHESIS_PROFILE=ci (the quick CI job does)."""
import os

import jax
import pytest

jax.config.update("jax_enable_x64", True)

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class CompileCounter:
    """Counts XLA backend compiles via the jax.monitoring event stream.

    Shared by the jit-cache discipline tests (`test_api_cache`) and the
    observability guard (`test_obs`): a `count` delta of zero around a
    warmed trace proves the trace added no compiled shapes."""

    def __init__(self):
        self.count = 0
        jax.monitoring.register_event_duration_secs_listener(self._on_event)

    def _on_event(self, name, duration, **kw):
        if name == _COMPILE_EVENT:
            self.count += 1

    def unregister(self):
        # deregister ONLY our listener — clear_event_listeners() would wipe
        # listeners other modules (or jax internals) registered
        from jax._src import monitoring as _mon

        for attr in ("_unregister_event_duration_listener_by_callback",):
            fn = getattr(_mon, attr, None)
            if fn is not None:
                fn(self._on_event)
                return
        listeners = getattr(_mon, "_event_duration_secs_listeners", None)
        if listeners is not None and self._on_event in listeners:
            listeners.remove(self._on_event)


@pytest.fixture(scope="module")
def compile_counter():
    c = CompileCounter()
    yield c
    c.unregister()


try:
    from hypothesis import HealthCheck, settings
except ImportError:
    pass
else:
    settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=20,
        suppress_health_check=list(HealthCheck))
    settings.register_profile(
        "dev", deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
