"""repro.region — region-scale sharded allocation service (beyond paper).

The paper solves one cell of N MAR devices; this package scales the
unified `repro.solve` dispatcher to a *region* — many heterogeneous cells,
millions of clients — as a pipelined serving stack:

  * mesh   (`region.mesh`):  shard the cell axis of a stacked fleet across
    a device mesh — set `Problem.mesh` (built with `region_mesh`) and
    `solve` runs the vmapped BCD under shard_map with shard-local
    convergence exit (`SolverSpec.lockstep=True` keeps the pure-jit GSPMD
    path). `allocate_region`/`run_rounds_region` survive as deprecated
    shims;
  * batch  (`region.batch`): pad mixed-size cell pools onto a power-of-two
    bucket menu with masked devices (`pad_system`, `bucket_size`) so real
    traffic compiles into a handful of shapes; `inactive_system` builds
    the all-masked filler cells short chunks pad with;
  * the serving pipeline (`region.pipeline`): four layers —
    **admission** (`region.admission`: per-bucket queues, deadlines,
    priorities, pluggable batch-closing policies), **planning**
    (`region.planning`: the bucket/chunk planner + warm-start LRU),
    **dispatch** (`region.dispatch`: async `solve()` enqueue, double-
    buffered in-flight batches), and **completion** (`region.completion`:
    one blocking gather per batch resolving `PendingResponse` futures) —
    with per-stage `StageClocks`;
  * service (`region.service`): `RegionAllocator`, the synchronous facade
    over the pipeline (submit/flush/solve, bit-identical to the
    pre-pipeline monolith). Requests take PER-REQUEST `Weights` — a
    traced (C, 3) operand of the one compiled solve, so a mixed-demand
    region costs zero extra compiles (the jit-cache key is `SolverSpec` +
    the bucket menu, nothing else).

CPU dev recipe: XLA_FLAGS=--xla_force_host_platform_device_count=8 makes
one host expose 8 devices for the mesh (see ROADMAP "Region service").
"""
from .admission import (AdmissionQueue, AllocationRequest, BatchPolicy,
                        CloseOnFull, DeadlineSlack, MaxWait, StageClocks)
from .batch import bucket_size, inactive_system, pad_allocation, pad_system
from .completion import CellResponse, PendingResponse
from .mesh import (RegionResult, allocate_region, cell_specs, pad_cells,
                   place_cells, region_mesh, run_rounds_region)
from .pipeline import RegionPipeline
from .planning import BatchPlan, BatchPlanner, WarmStartCache, group_requests
from .service import RegionAllocator

__all__ = [
    "bucket_size", "inactive_system", "pad_allocation", "pad_system",
    "RegionResult", "allocate_region", "cell_specs", "pad_cells",
    "place_cells", "region_mesh", "run_rounds_region",
    "AdmissionQueue", "AllocationRequest", "BatchPolicy", "CloseOnFull",
    "DeadlineSlack", "MaxWait", "StageClocks",
    "BatchPlan", "BatchPlanner", "WarmStartCache", "group_requests",
    "CellResponse", "PendingResponse", "RegionPipeline", "RegionAllocator",
]
