"""End-to-end FL-MAR: allocate -> federated training at allocated resolutions
-> energy/time/accuracy ledger (the paper's Fig. 1 loop).

    PYTHONPATH=src python examples/fl_mar_train.py
"""
from repro.launch.flmar import main

main(["--devices", "8", "--rounds", "25", "--rho", "40",
      "--per-client", "64"])
