"""Train a ~100M-param LM (reduced qwen2 family scaled up) for a few hundred
steps on the synthetic pipeline — the end-to-end training driver.

    PYTHONPATH=src python examples/lm_pretrain.py
"""
from repro.configs import get_config
from repro.launch.train import main

main(["--arch", "internlm2-20b", "--reduced", "--steps", "200",
      "--batch", "8", "--seq", "128", "--lr", "3e-3", "--log-every", "20"])
