"""`SolverSpec` — the single, hashable description of *how* to solve.

Every static solver option that used to be re-threaded positionally through
seven entry-point signatures (`max_iters`/`tol`/`sp1_method`/`sp2_method`/
`sp2_iters`/`keep_history`/`lockstep`/dtype policy) lives here, once. The
spec is a frozen dataclass, so it is hashable and equality-comparable: two
solves with equal specs (and equal topology/bucket shapes) share one jit
cache entry, and *only* spec/topology changes can trigger a recompile —
weights and channel state are traced operands and never key the cache.

Tolerance validation happens at construction: the BCD convergence check
floors the relative-step tolerance at 64 ulps of the carry dtype (see
`core.bcd._bcd_while`), so a tol below that floor cannot buy a tighter
solution — in f32 anything below ~7.6e-6 just runs at the floor. An
explicit `dtype` makes that a hard error; with the default follow-the-system
policy a sub-f32-floor tol warns once (the system might still be f64).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import numpy as np

_SP1_METHODS = ("sweep", "bisect")
_SP2_METHODS = ("direct", "jong")
_DTYPES = ("float32", "float64")

#: the BCD rel-step tolerance floor, in ulps of the solve dtype
REL_STEP_FLOOR_ULPS = 64

#: the library-default tol. Effectively "64-ulp floor or 1e-6, whichever is
#: looser": the BCD loop clamps at the floor, and `warn_tol_floor` stays
#: silent for this exact value so a default-configured f32 solve does not
#: warn about a tolerance nobody chose. Any OTHER sub-floor tol warns.
DEFAULT_TOL = 1e-6


class TolFloorWarning(UserWarning):
    """The requested tol sits below the solve dtype's rel-step floor: the
    solve runs, but convergence is effectively decided at the floor.
    Filterable: ``warnings.simplefilter("ignore", TolFloorWarning)``."""

# one warning per distinct (tol, dtype) per process — a spec is constructed
# on every legacy-shim call, and repeating the warning thousands of times
# in a request loop would bury it
_TOL_WARNED: set = set()


def rel_step_floor(dtype) -> float:
    """The smallest meaningful BCD tolerance for `dtype`: 64 ulps. Movement
    below this is solver bracketing noise, not progress (the PR 2 fleet
    convergence fix). f32: ~7.6e-6, f64: ~1.4e-14."""
    return float(REL_STEP_FLOOR_ULPS * np.finfo(dtype).eps)


def _validate_tol(tol: float, dtype: Optional[str]) -> None:
    if tol <= 0.0:
        raise ValueError(f"SolverSpec: tol must be positive, got {tol}")
    if dtype is not None:
        floor = rel_step_floor(dtype)
        if tol < floor:
            raise ValueError(
                f"SolverSpec: tol={tol:g} is below the {dtype} rel-step "
                f"floor of {REL_STEP_FLOOR_ULPS} ulps = {floor:.3g}; the BCD "
                f"convergence check cannot resolve steps below it, so this "
                f"tol can never report a tighter solution. Raise tol to "
                f">= {floor:.3g} or set dtype='float64'.")
        return
    # dtype follows the system (resolved at solve() time — see
    # `warn_tol_floor`); a tol below even the f64 floor can never converge
    # under ANY dtype, so that much is a construction-time error
    f64_floor = rel_step_floor(np.float64)
    if tol < f64_floor:
        raise ValueError(
            f"SolverSpec: tol={tol:g} is below the float64 rel-step floor "
            f"of {REL_STEP_FLOOR_ULPS} ulps = {f64_floor:.3g} — no dtype "
            f"can report convergence at this tolerance.")


def warn_tol_floor(tol: float, dtype) -> None:
    """Solve-time companion of the construction check: once the solve dtype
    is known, warn (once per (tol, dtype) per process) when `tol` sits below
    its rel-step floor — the solve will run, but convergence is effectively
    decided at the floor, not at `tol` (the PR 4 caveat: in f32, any tol
    below ~7.6e-6 silently behaves like 7.6e-6). The library default
    `DEFAULT_TOL` is exempt: it is documented as floor-or-1e-6, and warning
    on a tolerance the user never chose would train everyone to filter
    `TolFloorWarning` away."""
    if tol == DEFAULT_TOL:
        return
    dtype = np.dtype(dtype)
    key = (float(tol), dtype.name)
    if key in _TOL_WARNED:
        return
    floor = rel_step_floor(dtype)
    if tol >= floor:
        return
    _TOL_WARNED.add(key)
    warnings.warn(
        f"SolverSpec: tol={tol:g} is below the {dtype.name} rel-step floor "
        f"of {REL_STEP_FLOOR_ULPS} ulps = {floor:.3g}; the BCD convergence "
        f"check is floored there, so the effective tolerance is "
        f"{floor:.3g}. Raise tol (or set SolverSpec.dtype='float64') to "
        f"silence this.", TolFloorWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Static solver configuration — the single jit-cache key.

    Fields
    ------
    max_iters : outer BCD iteration cap (0 = return the init untouched,
        objective NaN).
    tol : relative-step convergence tolerance, floored at
        `rel_step_floor(dtype)` inside the loop (validated here). The
        default (`DEFAULT_TOL`) means "the floor or 1e-6, whichever is
        looser"; any explicitly chosen sub-floor tol warns
        `TolFloorWarning` once at solve time.
    sp1_method : "sweep" (batched T-grid dual sweep, default) or "bisect"
        (nested bisection, the sweep's parity oracle). The fixed-deadline
        variant has no T search, so this field is inert there.
    sp2_method : "direct" (exact boundary-power convex solve, default) or
        "jong" (the paper's Algorithm 1).
    sp2_iters : inner iteration cap for sp2_method="jong".
    keep_history : materialize the per-iteration ledger host-side
        (single-cell results only; False skips the device->host copy — the
        serving hot path).
    lockstep : region meshes only — True keeps the pure-jit GSPMD path
        whose BCD while_loop all-reduces across shards; False (default)
        runs shard_map with shard-local convergence exit.
    dtype : None (follow the system's leaf dtype, default), "float32", or
        "float64" — an explicit policy casts system/init leaves before the
        solve and makes the tol floor check a hard error.
    """
    max_iters: int = 20
    tol: float = DEFAULT_TOL
    sp1_method: str = "sweep"
    sp2_method: str = "direct"
    sp2_iters: int = 30
    keep_history: bool = True
    lockstep: bool = False
    dtype: Optional[str] = None

    def __post_init__(self):
        if self.sp1_method not in _SP1_METHODS:
            raise ValueError(
                f"SolverSpec: sp1_method must be one of {_SP1_METHODS}, "
                f"got {self.sp1_method!r}")
        if self.sp2_method not in _SP2_METHODS:
            raise ValueError(
                f"SolverSpec: sp2_method must be one of {_SP2_METHODS}, "
                f"got {self.sp2_method!r}")
        if self.dtype is not None and self.dtype not in _DTYPES:
            raise ValueError(
                f"SolverSpec: dtype must be None or one of {_DTYPES}, "
                f"got {self.dtype!r}")
        if self.max_iters < 0:
            raise ValueError("SolverSpec: max_iters must be >= 0")
        if self.sp2_iters < 1:
            raise ValueError("SolverSpec: sp2_iters must be >= 1")
        _validate_tol(float(self.tol), self.dtype)

    def replace(self, **kw) -> "SolverSpec":
        return dataclasses.replace(self, **kw)
