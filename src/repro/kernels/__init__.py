"""Pallas TPU kernels for the compute hot spots + jit wrappers (ops) and
pure-jnp oracles (ref)."""
