"""Span/trace recorder: the event stream side of `repro.obs`.

A *span* is a named, timed region of host code (`with obs.span("plan")`),
nested via a per-thread stack into parent/child trees; a *point* is an
instantaneous structured event (`obs.point("request", cell_id=...)`).
Both are emitted to the installed `Recorder` as plain dicts — one JSON
object per event in the JSONL sinks — and are attributed to the enclosing
span through deterministic integer ids.

The default recorder is `NOOP`: `span()` then returns one cached null
context manager and `point()` returns immediately, so instrumented hot
paths cost a single global load + attribute check per site (benchmarked
as the `obs_overhead.*` BENCH rows; asserted < 2% of serve req/s).

When a recorder IS enabled, spans additionally enter
`jax.named_scope(name)` and `jax.profiler.TraceAnnotation(name)`, so any
tracing/dispatch performed inside a span shows up under the span's name
in XLA profiles (neither affects the jit cache — compile-count-guarded in
tests/test_obs.py). Entering a span is host-side bookkeeping only; it
never blocks on device work.

Event schema conventions (relied on by `obs.report` and the determinism
tests):

  * every event has `"type"` ("span" | "point"), `"name"`, `"span"` (its
    own id for spans, the enclosing span id for points; -1 at top level),
    and `"parent"` (enclosing span id, -1 at top level);
  * wall-clock fields are exactly `"ts"` (absolute seconds) and keys
    ending in `"_s"` (durations/offsets): `strip_timing` drops them, and
    everything that remains must be bit-deterministic for same-seed runs
    (tested) — do not put nondeterministic payloads in other keys;
  * span/event ids restart from 0 whenever a recorder is installed
    (`set_recorder`), so two same-seed runs emit identical id sequences.
"""
from __future__ import annotations

import json
import queue as _queue
import threading
import time
from typing import Any, Dict, Iterable, List, Optional

__all__ = [
    "Recorder", "NoopRecorder", "MemoryRecorder", "JsonlRecorder",
    "NOOP", "enabled", "get_recorder", "set_recorder", "recording",
    "span", "point", "strip_timing", "TIMING_KEY", "read_jsonl",
]


def TIMING_KEY(key: str) -> bool:
    """Is `key` a wall-clock field (excluded from determinism contracts)?"""
    return key == "ts" or key.endswith("_s")


def strip_timing(event: Dict[str, Any]) -> Dict[str, Any]:
    """The deterministic projection of an event: drop `ts` and `*_s`."""
    return {k: v for k, v in event.items() if not TIMING_KEY(k)}


class Recorder:
    """Event sink base class. `enabled` gates every instrumentation site:
    a disabled recorder must never receive `emit`."""

    enabled: bool = False

    def emit(self, event: Dict[str, Any]) -> None:   # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass


class NoopRecorder(Recorder):
    """The default: drops everything, `enabled` False."""

    def emit(self, event):   # pragma: no cover - never called when wired
        pass


class MemoryRecorder(Recorder):
    """Buffers events in `self.events` (a list of dicts) — the test/report
    recorder, and the cheapest enabled sink."""

    enabled = True

    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def emit(self, event):
        self.events.append(event)


class JsonlRecorder(Recorder):
    """Streams one JSON object per line to `path` (append mode so several
    runs can share a trace file; pass `fresh=True` to truncate).

    Writes happen on a dedicated daemon writer thread fed by a bounded
    queue: `emit` on the serving thread is one non-blocking `put` (JSON
    serialization AND the file write are both off the hot path). A full
    queue — the writer can't keep up — drops the event and counts it in
    `dropped_events` (mirrored to the `obs_events_dropped` counter in the
    global metrics registry) instead of stalling the pipeline. `close()`
    flushes: it joins the writer after a sentinel, so every queued event
    is on disk when `obs.recording(...)` exits. Events keep their emit
    order — a single writer drains the queue FIFO."""

    enabled = True

    _SENTINEL = object()

    def __init__(self, path: str, fresh: bool = True,
                 queue_size: int = 8192):
        self.path = path
        self.dropped_events = 0
        self._fh = open(path, "w" if fresh else "a")
        self._queue: _queue.Queue = _queue.Queue(maxsize=int(queue_size))
        self._closed = False
        # test hook: clearing this gate stalls the writer so queue-full
        # drops become deterministic; set by default (a no-op wait)
        self._drain_gate = threading.Event()
        self._drain_gate.set()
        self._writer = threading.Thread(target=self._drain, daemon=True,
                                        name="obs-jsonl-writer")
        self._writer.start()

    def emit(self, event):
        if self._closed:
            self._count_drop()
            return
        try:
            self._queue.put_nowait(event)
        except _queue.Full:
            self._count_drop()

    def _count_drop(self) -> None:
        self.dropped_events += 1
        from .metrics import counter

        counter("obs_events_dropped").inc()

    def _drain(self) -> None:
        while True:
            event = self._queue.get()
            self._drain_gate.wait()
            if event is self._SENTINEL:
                return
            self._fh.write(json.dumps(event, default=_json_default))
            self._fh.write("\n")

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._queue.put(self._SENTINEL)   # blocking: the flush marker
        self._writer.join()
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


def _json_default(x):
    """Last-resort JSON coercion: numpy/jax scalars -> python, else repr."""
    item = getattr(x, "item", None)
    if item is not None:
        try:
            return item()
        except (TypeError, ValueError):
            pass
    return repr(x)


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL event stream (skips blank lines)."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# global recorder + span stack
# ---------------------------------------------------------------------------

NOOP = NoopRecorder()
_RECORDER: Recorder = NOOP

_tls = threading.local()


def _stack() -> List[int]:
    st = getattr(_tls, "spans", None)
    if st is None:
        st = _tls.spans = []
    return st


class _Ids:
    """Deterministic event/span id allocator, reset on recorder install."""

    def __init__(self):
        self.next = 0
        self.lock = threading.Lock()

    def take(self) -> int:
        with self.lock:
            i = self.next
            self.next += 1
        return i


_IDS = _Ids()


def get_recorder() -> Recorder:
    return _RECORDER


def enabled() -> bool:
    return _RECORDER.enabled


def set_recorder(rec: Optional[Recorder]) -> Recorder:
    """Install `rec` (None -> the no-op recorder) and reset span ids;
    returns the previously installed recorder."""
    global _RECORDER
    prev = _RECORDER
    _RECORDER = rec if rec is not None else NOOP
    _IDS.__init__()
    _tls.spans = []
    return prev


class recording:
    """Context manager: install a recorder for a scoped run.

        with obs.recording(obs.JsonlRecorder("events.jsonl")) as rec:
            ... serve ...
        # previous recorder restored, sink closed
    """

    def __init__(self, rec: Recorder):
        self.rec = rec
        self._prev: Optional[Recorder] = None

    def __enter__(self) -> Recorder:
        self._prev = set_recorder(self.rec)
        return self.rec

    def __exit__(self, *exc):
        set_recorder(self._prev)
        self.rec.close()
        return False


# ---------------------------------------------------------------------------
# spans and points
# ---------------------------------------------------------------------------

class _NullSpan:
    """The cached disabled-path context manager: zero allocation per use."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One enabled span: times the region, threads parent/child ids, and
    names the region for XLA profiles via jax.named_scope/TraceAnnotation."""

    __slots__ = ("rec", "name", "attrs", "id", "parent", "t0", "ts",
                 "_scopes")

    def __init__(self, rec: Recorder, name: str, attrs: Dict[str, Any]):
        self.rec = rec
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        import jax

        st = _stack()
        self.parent = st[-1] if st else -1
        self.id = _IDS.take()
        st.append(self.id)
        self._scopes = (jax.named_scope(self.name),
                        jax.profiler.TraceAnnotation(self.name))
        for s in self._scopes:
            s.__enter__()
        self.ts = time.time()
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        dur = time.monotonic() - self.t0
        for s in reversed(self._scopes):
            s.__exit__(*exc)
        st = _stack()
        if st and st[-1] == self.id:
            st.pop()
        ev = dict(type="span", name=self.name, span=self.id,
                  parent=self.parent, ts=self.ts, dur_s=dur)
        if self.attrs:
            ev.update(self.attrs)
        self.rec.emit(ev)
        return False


def span(name: str, **attrs):
    """Context manager timing a named region. Near-free when no recorder
    is enabled (returns one cached null object). Keyword attrs land on the
    emitted event — keep them deterministic (see module docstring)."""
    rec = _RECORDER
    if not rec.enabled:
        return _NULL_SPAN
    return _Span(rec, name, attrs)


def point(name: str, **fields) -> None:
    """Emit one instantaneous structured event under the current span.
    No-op (one global load + attribute check) when disabled."""
    rec = _RECORDER
    if not rec.enabled:
        return
    st = _stack()
    parent = st[-1] if st else -1
    ev = dict(type="point", name=name, span=parent, parent=parent,
              ts=time.time())
    ev.update(fields)
    rec.emit(ev)
