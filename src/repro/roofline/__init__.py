from .analysis import (Costs, analytic_costs, full_table, load_dryrun,
                       markdown_table, params_active, params_total,
                       roofline_terms, PEAK_FLOPS, HBM_BW, LINK_BW)
