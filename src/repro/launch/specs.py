"""Input specs for every (architecture x input-shape) pair: weak-type-correct
ShapeDtypeStructs — shardable stand-ins, no device allocation.

INPUT SHAPES (assignment):
    train_4k     seq=4096    global_batch=256   (training)
    prefill_32k  seq=32768   global_batch=32    (inference prefill)
    decode_32k   seq=32768   global_batch=128   (decode: 1 token + 32k cache)
    long_500k    seq=524288  global_batch=1     (long-context decode)

Decode shapes lower `serve_step` (single token + cache); `long_500k` requires
sub-quadratic attention — dense archs run it with the sliding-window variant
(config flag), whisper skips it (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SHAPES: Dict[str, dict] = {
    "train_4k":    dict(kind="train",   seq=4096,    batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,   batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,   batch=128),
    "long_500k":   dict(kind="decode",  seq=524288,  batch=1),
}

LONG_WINDOW = 4096          # sliding window used for the long_500k variant


def adapt_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Shape-specific config adjustments (documented in DESIGN.md §4):
    long_500k forces a sliding-window attention variant on dense archs."""
    if shape_name == "long_500k":
        if cfg.arch_type == "audio":
            raise ValueError(
                "whisper-large-v3 skips long_500k: enc-dec full attention has "
                "no meaningful 500k sliding-window decode (DESIGN.md §4)")
        if cfg.attention != "none" and cfg.sliding_window is None:
            cfg = cfg.replace(sliding_window=LONG_WINDOW)
    return cfg


def supported(cfg: ModelConfig, shape_name: str) -> bool:
    return not (shape_name == "long_500k" and cfg.arch_type == "audio")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStructs for the model inputs of this shape."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    if sh["kind"] in ("train", "prefill"):
        text = S
        out: Dict[str, Any] = {}
        if cfg.n_patches:
            text = S - cfg.n_patches
            out["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.encoder_layers:
            out["frame_embeds"] = _sds((B, cfg.encoder_ctx, cfg.d_model), jnp.bfloat16)
        out["tokens"] = _sds((B, text), jnp.int32)
        return out
    # decode: one token + absolute position (+ encoder frames for enc-dec)
    out = {"token": _sds((B,), jnp.int32), "pos": _sds((), jnp.int32)}
    if cfg.encoder_layers:
        if cfg.cross_kv_cache:
            # optimized path: encoder ran once at admission; decode only needs
            # enc_out for nothing — cross K/V live in the cache. (enc_out kept
            # out of the step entirely.)
            pass
        else:
            out["frame_embeds"] = _sds((B, cfg.encoder_ctx, cfg.d_model), jnp.bfloat16)
    return out


def decode_cache_len(cfg: ModelConfig, shape_name: str) -> int:
    S = SHAPES[shape_name]["seq"]
    return min(cfg.sliding_window, S) if cfg.sliding_window else S
