"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", arch_type="dense",
    n_layers=62, d_model=2560, n_heads=40, kv_heads=40, head_dim=64,
    d_ff=6400, vocab_size=73448,
    attention="mla",
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    block_pattern=("attn",),
    source="hf:openbmb/MiniCPM3-4B",
)
