"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    n_experts=8, top_k=2,
    block_pattern=("attn_moe",),
    sliding_window=4096,           # native SWA -> ring KV cache, long_500k OK
    rope_theta=1e6,
    source="arXiv:2401.04088",
)
