"""DBRX (132B total) — fine-grained 16-expert top-4 MoE.
[hf:databricks/dbrx-base]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", arch_type="moe",
    n_layers=40, d_model=6144, n_heads=48, kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    n_experts=16, top_k=4,
    block_pattern=("attn_moe",),
    rope_theta=5e5,
    source="hf:databricks/dbrx-base",
)
