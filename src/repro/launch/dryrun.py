import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init, and the dry-run needs 512 placeholder host devices to
# build the production meshes.  (Tests/benches must NOT import this module.)

# Multi-pod dry-run: lower + compile every (arch x input-shape) on the
# production meshes, print memory/cost analyses, and dump roofline inputs.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both --out dryrun.json

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.launch.specs import SHAPES, adapt_config, batch_specs, decode_cache_len, supported
from repro.launch.steps import make_serve_step, make_train_step, make_prefill_step
from repro.models.transformer import init_cache, init_model
from repro.optim import AdamW, AdamWState
from repro.sharding.partition import (fsdp_tp_rules, param_pspecs,
                                      param_shardings, use_rules)

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _result_bytes(shape_str: str) -> int:
    """Bytes of an HLO result type like 'bf16[16,128,512]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum per-device result bytes of every collective op in post-SPMD HLO."""
    out = {op: {"count": 0, "bytes": 0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\S+) ([\w\-]+)", ls)
        if not m:
            continue
        opname = m.group(2)
        for op in COLLECTIVE_OPS:
            if opname == op or opname.startswith(op + "-"):
                b = _result_bytes(m.group(1))
                out[op]["count"] += 1
                out[op]["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _batch_shardings(specs: Dict[str, Any], mesh, multi_pod: bool):
    data = ("pod", "data") if multi_pod else ("data",)
    out = {}
    for k, v in specs.items():
        if k == "pos" or v.shape == ():
            out[k] = NamedSharding(mesh, P())
        elif v.shape[0] == 1:       # batch=1 (long_500k): replicate
            out[k] = NamedSharding(mesh, P(*([None] * len(v.shape))))
        else:
            out[k] = NamedSharding(mesh, P(data, *([None] * (len(v.shape) - 1))))
    return out


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               rules_override: Optional[dict] = None,
               cfg_overrides: Optional[dict] = None,
               accum_steps: int = 1,
               verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) and return the roofline record."""
    t0 = time.time()
    cfg = adapt_config(get_config(arch), shape_name)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = fsdp_tp_rules(multi_pod, seq_shard_decode=(kind == "decode"))
    if rules_override:
        rules.update(rules_override)

    key = jax.random.PRNGKey(0)
    params_abs = jax.eval_shape(lambda k: init_model(k, cfg), key)
    psh = param_shardings(params_abs, mesh, rules)

    specs = batch_specs(cfg, shape_name)
    bsh = _batch_shardings(specs, mesh, multi_pod)

    with mesh, use_rules(rules, mesh_axis_sizes(mesh)):
        if kind == "train":
            step, opt = make_train_step(cfg, accum_steps=accum_steps)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            osh = AdamWState(step=NamedSharding(mesh, P()), mu=psh, nu=psh)
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs)
        elif kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(psh, bsh))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            step = make_serve_step(cfg)
            B = sh["batch"]
            slots = decode_cache_len(cfg, shape_name)
            cache_abs = jax.eval_shape(
                lambda: init_cache(cfg, B, slots))
            csh = param_shardings(cache_abs, mesh, rules)
            extras = {k: v for k, v in specs.items()
                      if k in ("frame_embeds", "enc_out")}
            esh = {k: bsh[k] for k in extras} or None
            args = (params_abs, cache_abs, specs["token"], specs["pos"])
            in_sh = (psh, csh, bsh["token"], bsh["pos"])
            if extras:
                jitted = jax.jit(step, in_shardings=in_sh + (esh,),
                                 donate_argnums=(1,))
                lowered = jitted.lower(*args, extras)
            else:
                jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=(1,))
                lowered = jitted.lower(*args)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    record = dict(
        arch=arch, shape=shape_name, kind=kind,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=int(mesh.devices.size),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        peak_bytes=int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
        collectives=coll,
    )
    if verbose:
        print(f"== {arch} x {shape_name} on {record['mesh']} "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        print(f"   memory: args={record['argument_bytes']/2**30:.2f}GiB "
              f"out={record['output_bytes']/2**30:.2f}GiB "
              f"temp={record['temp_bytes']/2**30:.2f}GiB")
        print(f"   cost: flops={record['flops']:.3e} "
              f"bytes={record['hbm_bytes']:.3e}")
        print(f"   collectives: {coll['total_bytes']/2**20:.1f} MiB "
              + " ".join(f"{op}:{coll[op]['count']}" for op in COLLECTIVE_OPS
                         if coll[op]["count"]))
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    pairs = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    ok = skipped = failed = 0
    for a, s, mp in pairs:
        if not supported(get_config(a), s):
            print(f"-- skip {a} x {s} (documented skip, DESIGN.md §4)")
            skipped += 1
            continue
        try:
            rec = lower_pair(a, s, mp)
            ok += 1
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
        except Exception as e:
            failed += 1
            print(f"!! FAIL {a} x {s} multi_pod={mp}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=3)
    print(f"\ndry-run summary: {ok} ok, {skipped} skipped, {failed} failed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
