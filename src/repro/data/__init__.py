from .pipeline import SyntheticLM, Prefetcher, make_pipeline, shard_for_host
