"""Federated LM fine-tuning driven by the allocator (DESIGN.md §2).

Each FL client trains a shared reduced LM locally; the paper's allocator
decides each client's token budget (the LM analogue of the frame resolution
s_n — budget ∝ s^2) and the wireless (p, B) schedule; FedAvg merges rounds.

    PYTHONPATH=src python examples/fedavg_lm.py
"""
import jax
import jax.numpy as jnp

from repro import Problem, SolverSpec, Weights, solve
from repro.configs import ARCHS
from repro.core.costmodel import arch_system
from repro.core.energy import e_cmp, e_trans, round_time
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.transformer import init_model
from repro.optim import SGD

N_CLIENTS = 4
ROUNDS = 5
LOCAL_STEPS = 3

cfg = ARCHS["internlm2-20b"].reduced()
key = jax.random.PRNGKey(0)

# 1) allocate: c_n from the architecture's cost model (DESIGN.md §2)
system = arch_system(key, "internlm2-20b", n_devices=N_CLIENTS)
result = solve(Problem(system=system, weights=Weights(0.5, 0.5, 3e4)),
               SolverSpec(max_iters=4))
alloc = result.allocation
res_grid = list(system.resolutions)
budgets = [32 * (1 + res_grid.index(float(s))) for s in alloc.resolution]
print("per-client token budgets (from allocated s_n):", budgets)

# 2) federated training at the allocated budgets
params = init_model(key, cfg)
opt = SGD(lr=0.3)
# NOTE: no donation — the global params are re-used by every client each round
step_fn, _ = make_train_step(cfg, opt)
step_fn = jax.jit(step_fn)

streams = [iter(SyntheticLM(cfg.vocab_size, 4, max(budgets), seed=i))
           for i in range(N_CLIENTS)]

for r in range(ROUNDS):
    updated, losses = [], []
    for c in range(N_CLIENTS):
        p_c = params
        o_c = opt.init(p_c)
        for _ in range(LOCAL_STEPS):
            batch = next(streams[c])
            toks = jnp.asarray(batch["tokens"][:, : budgets[c]])
            p_c, o_c, m = step_fn(p_c, o_c, {"tokens": toks})
        updated.append(p_c)
        losses.append(float(m["loss"]))
    # FedAvg (equal client weights here)
    params = jax.tree_util.tree_map(
        lambda *leaves: sum(l.astype(jnp.float32) for l in leaves).astype(leaves[0].dtype)
        / len(leaves), *updated)
    print(f"round {r+1}: client losses {[round(l, 3) for l in losses]}")

e = float(jnp.sum(e_trans(system, alloc.bandwidth, alloc.power)
                  + e_cmp(system, alloc.freq, alloc.resolution))) * ROUNDS
print(f"simulated fleet energy for {ROUNDS} rounds: {e:.4g} J; "
      f"round makespan {float(round_time(system, alloc)):.3f} s")
