"""Metrics registry: counters, gauges, and fixed-bucket latency histograms.

Unlike the span stream (recorder.py), metrics are always-on aggregates:
incrementing a counter or observing a histogram costs a dict lookup and a
float add whether or not a recorder is installed. They answer "what were
the p50/p90/p99 and totals of this run" without retaining per-event data.

Histograms use one fixed, log-spaced bucket layout (`DEFAULT_BOUNDS`)
shared by every latency metric in the repo, so histogram-derived
percentiles are comparable across runs and across BENCH artifacts. The
bucket growth factor is ~7% — below the 15% regression gate enforced by
`benchmarks/compare.py` — so quantization error cannot mask or fake a
regression.

Identity is `(name, labels)` with labels a sorted tuple of `(k, v)`
pairs, mirroring the Prometheus data model; `export.prometheus_text`
renders the registry in text exposition format and
`export.metrics_jsonl` as one JSON object per metric.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "REGISTRY", "counter", "gauge", "histogram", "DEFAULT_BOUNDS",
]

LabelPairs = Tuple[Tuple[str, str], ...]


def _freeze(labels: Dict[str, str]) -> LabelPairs:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _log_bounds(lo: float, hi: float, factor: float) -> Tuple[float, ...]:
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * factor)
    return tuple(out)


# 100 µs .. ~100 s at ~7% growth (~200 buckets + overflow). Fixed for the
# whole repo: see module docstring for why the factor sits below the
# compare.py regression gate.
DEFAULT_BOUNDS: Tuple[float, ...] = _log_bounds(1e-4, 100.0, 1.07)


@dataclass
class Counter:
    """Monotonic float total."""

    name: str
    labels: LabelPairs = ()
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("Counter.inc amount must be >= 0")
        self.value += amount


@dataclass
class Gauge:
    """Last-write-wins instantaneous value."""

    name: str
    labels: LabelPairs = ()
    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    `bounds[i]` is the inclusive upper edge of bucket i; one overflow
    bucket catches everything above `bounds[-1]`. `percentile` linearly
    interpolates within the winning bucket, which is accurate to the
    bucket growth factor (~7% with `DEFAULT_BOUNDS`) — tight enough for
    the 15% regression gate, and stable because the layout never moves.

    Non-finite observations (NaN/inf — e.g. a latency computed from a
    clock that never ticked) are counted in `dropped` and excluded from
    every aggregate: a single NaN would otherwise defeat both min/max
    comparisons, land in an arbitrary bucket, and poison `sum`, `mean`,
    and every derived percentile for the rest of the run.
    """

    def __init__(self, name: str, labels: LabelPairs = (),
                 bounds: Sequence[float] = DEFAULT_BOUNDS):
        self.name = name
        self.labels = labels
        self.bounds: Tuple[float, ...] = tuple(bounds)
        self.buckets: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.dropped = 0

    def observe(self, value: float) -> None:
        v = float(value)
        if not math.isfinite(v):
            self.dropped += 1
            return
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.buckets[self._index(v)] += 1

    def observe_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.observe(v)

    def _index(self, v: float) -> int:
        lo, hi = 0, len(self.bounds)
        while lo < hi:          # first bound >= v (bisect on the edges)
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        return lo

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """q in [0, 100]. NaN when empty; exact at the recorded min/max
        endpoints, bucket-interpolated in between."""
        if not self.count:
            return math.nan
        if q <= 0:
            return self.min
        if q >= 100:
            return self.max
        rank = q / 100.0 * self.count
        cum = 0
        for i, n in enumerate(self.buckets):
            if not n:
                continue
            prev_cum = cum
            cum += n
            if cum >= rank:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min) if lo < self.min <= hi else lo
                hi = min(hi, self.max) if lo <= self.max < hi else hi
                frac = (rank - prev_cum) / n
                return lo + (hi - lo) * frac
        return self.max

    def percentiles(self, qs: Sequence[float] = (50.0, 90.0, 99.0)
                    ) -> Dict[str, float]:
        return {f"p{q:g}": self.percentile(q) for q in qs}


class MetricsRegistry:
    """Process-wide store of metric instances keyed by (name, labels).

    `get`-style accessors create on first use, so instrumentation sites
    never need registration boilerplate. `reset()` drops everything —
    benches and tests call it between A/B arms.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, LabelPairs], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelPairs], Gauge] = {}
        self._histograms: Dict[Tuple[str, LabelPairs], Histogram] = {}

    def counter(self, name: str, **labels: str) -> Counter:
        key = (name, _freeze(labels))
        with self._lock:
            m = self._counters.get(key)
            if m is None:
                m = self._counters[key] = Counter(name, key[1])
        return m

    def gauge(self, name: str, **labels: str) -> Gauge:
        key = (name, _freeze(labels))
        with self._lock:
            m = self._gauges.get(key)
            if m is None:
                m = self._gauges[key] = Gauge(name, key[1])
        return m

    def histogram(self, name: str, bounds: Sequence[float] = DEFAULT_BOUNDS,
                  **labels: str) -> Histogram:
        key = (name, _freeze(labels))
        with self._lock:
            m = self._histograms.get(key)
            if m is None:
                m = self._histograms[key] = Histogram(name, key[1], bounds)
        return m

    def counters(self) -> List[Counter]:
        return list(self._counters.values())

    def gauges(self) -> List[Gauge]:
        return list(self._gauges.values())

    def histograms(self) -> List[Histogram]:
        return list(self._histograms.values())

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


REGISTRY = MetricsRegistry()


def counter(name: str, **labels: str) -> Counter:
    """Get-or-create a counter in the global registry."""
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: str) -> Gauge:
    """Get-or-create a gauge in the global registry."""
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels: str) -> Histogram:
    """Get-or-create a histogram (DEFAULT_BOUNDS) in the global registry."""
    return REGISTRY.histogram(name, **labels)
