"""Pallas-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# interpret-mode kernel sweeps are CPU-heavy; deselected in quick CI
pytestmark = pytest.mark.slow


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA 2:1
    (1, 8, 1, 128, 128),     # MQA
])
@pytest.mark.parametrize("window", [None, 128])
def test_flash_attention_sweep(B, H, KV, S, hd, dtype, window):
    key = jax.random.PRNGKey(0)
    q = (jax.random.normal(key, (B, H, S, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, hd)) * 0.3).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, hd)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 2, 128, 64)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,L,H,K,chunk", [
    (1, 64, 2, 32, 32),
    (2, 128, 4, 64, 64),
])
def test_rwkv6_scan_sweep(B, L, H, K, chunk):
    key = jax.random.PRNGKey(2)
    r = jax.random.normal(key, (B, L, H, K)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, H, K)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, H, K))
    logw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                      (B, L, H, K)) * 0.5 - 0.5)
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, K)) * 0.3
    out = ops.rwkv6_scan(r, k, v, logw, u, chunk=chunk)
    exp, _ = ref.rwkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


def test_rwkv6_strong_decay_stable():
    """Strong decay (log w << 0) must not overflow the chunked form."""
    key = jax.random.PRNGKey(3)
    B, L, H, K = 1, 128, 2, 32
    r = jax.random.normal(key, (B, L, H, K)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, H, K)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, H, K))
    logw = jnp.full((B, L, H, K), -8.0)   # near-total forgetting
    u = jnp.zeros((H, K))
    out = ops.rwkv6_scan(r, k, v, logw, u, chunk=64)
    exp, _ = ref.rwkv6_ref(r, k, v, logw, u)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,T,D,N,chunk,bd", [
    (1, 64, 128, 8, 32, 128),
    (2, 128, 256, 16, 64, 128),
])
def test_mamba_scan_sweep(B, T, D, N, chunk, bd):
    key = jax.random.PRNGKey(4)
    dt = jax.nn.softplus(jax.random.normal(key, (B, T, D)) - 1)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 1), (D, N)) * 0.3)
    Bt = jax.random.normal(jax.random.fold_in(key, 2), (B, T, N)) * 0.5
    Ct = jax.random.normal(jax.random.fold_in(key, 3), (B, T, N)) * 0.5
    x = jax.random.normal(jax.random.fold_in(key, 4), (B, T, D))
    y = ops.mamba_scan(dt, A, Bt, Ct, x, chunk=chunk, block_d=bd)
    ye, _ = ref.mamba_scan_ref(dt, A, Bt, Ct, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,block", [(1024, 256), (4096, 1024)])
def test_waterfill_sweep(N, block):
    key = jax.random.PRNGKey(5)
    j = jnp.abs(jax.random.normal(key, (N,))) * 1e-3 + 1e-5
    rmin = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (N,))) * 1e5
    mu = jnp.logspace(-6, 1, 16)
    # impl="pallas" keeps the kernel body under test ("auto" routes to the
    # ref oracle on CPU, which would compare the oracle against itself)
    g1 = ops.waterfill_gprime(mu, j, rmin, 20e6, block_n=block, impl="pallas")
    g2 = ref.waterfill_gprime_ref(mu, j, rmin, 20e6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1.0)


def test_model_chunked_attention_matches_ref():
    """The XLA-path chunked attention in models/ must agree with the oracle."""
    from repro.models.attention import _chunked_attn
    key = jax.random.PRNGKey(6)
    B, S, H, KV, hd = 2, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, hd)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    out = _chunked_attn(q, k, v, causal=True, window=64, scale=hd ** -0.5,
                        chunk=128)
    # oracle works in (B,H,S,hd) layout
    exp = ref.flash_attention_ref(q.transpose(0, 2, 1, 3),
                                  k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3),
                                  causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(exp.transpose(0, 2, 1, 3)),
                               rtol=2e-5, atol=2e-5)
