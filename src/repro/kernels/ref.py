"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B,H,S,hd); k,v: (B,KV,T,*). Plain masked softmax attention."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    kh = jnp.repeat(k, G, axis=1)      # (B,H,T,hd)
    vh = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vh.astype(jnp.float32)).astype(q.dtype)


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
              u: jax.Array, s0: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Sequential WKV6 recurrence (the definitional form).
    r,k,v,logw: (B,L,H,K) f32; u: (H,K). Returns (o (B,L,H,K), s_L (B,H,K,K)).
        o_t = r_t . (S_{t-1} + u * k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    B, L, H, K = r.shape
    r, k, v, logw = (t.astype(jnp.float32) for t in (r, k, v, logw))
    u = u.astype(jnp.float32)
    S = jnp.zeros((B, H, K, K), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, lwt = inp
        o = jnp.einsum("bhk,bhkv->bhv", rt, S) \
            + jnp.einsum("bhk,bhv->bhv", rt * u[None] * kt, vt)
        S = jnp.exp(lwt)[..., None] * S + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S, o

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, logw))
    S_end, os = jax.lax.scan(step, S, xs)
    return os.transpose(1, 0, 2, 3), S_end


def mamba_scan_ref(dt: jax.Array, A: jax.Array, Bt: jax.Array, Ct: jax.Array,
                   x: jax.Array, h0: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Sequential selective-scan recurrence.
    dt, x: (B,L,D); A: (D,N); Bt, Ct: (B,L,N). Returns (y (B,L,D), h_L (B,D,N)).
        h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t;   y_t = h_t . C_t
    """
    Bsz, L, D = x.shape
    N = A.shape[1]
    dt, Bt, Ct, x = (t.astype(jnp.float32) for t in (dt, Bt, Ct, x))
    A = A.astype(jnp.float32)
    h = jnp.zeros((Bsz, D, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        dtt, bt, ct, xt = inp
        a = jnp.exp(dtt[..., None] * A)                     # (B,D,N)
        h = a * h + dtt[..., None] * bt[:, None, :] * xt[..., None]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (dt.transpose(1, 0, 2), Bt.transpose(1, 0, 2),
          Ct.transpose(1, 0, 2), x.transpose(1, 0, 2))
    h_end, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2), h_end


def sp1_lambda_sum_ref(T_grid: jax.Array, q: jax.Array, tt: jax.Array,
                       consts: jax.Array) -> jax.Array:
    """Sigma_n lambda_n(T) for each candidate deadline (the SP1 dual sweep):
    T_grid: (M,); q, tt: (N,); consts: (N_CONSTS,) as laid out in
    `sp1_sweep`. Returns (M,). Full input precision, no kernel padding."""
    from repro.kernels.sp1_sweep import lambda_of_T_linear

    lam = lambda_of_T_linear(
        T_grid[:, None], q[None, :], tt[None, :],
        consts[0], consts[1], consts[2], consts[3], consts[4], consts[5],
        consts[6])
    return jnp.sum(lam, axis=1)


def waterfill_gprime_ref(mu: jax.Array, j: jax.Array, rmin: jax.Array,
                         B_total) -> jax.Array:
    """g'(mu) for each candidate mu (the SP2 dual derivative, eq. A.23):
    mu: (M,); j, rmin: (N,). Returns (M,)."""
    from repro.core.lambertw import lambertw0

    z = (mu[:, None] - j[None, :]) / (jnp.e * j[None, :])
    w = lambertw0(z)
    return jnp.sum(rmin[None, :] * jnp.log(2.0)
                   / jnp.maximum(w + 1.0, 1e-12), axis=1) - B_total
