"""Implicit KKT gradients through the BCD fixed point (`core/bcd.py`).

The allocator's forward pass is a `lax.while_loop` over block-coordinate
steps x -> Phi(x, theta), x = (B, p), where Phi is one SP1 (f, s, T given
transmission times) + SP2 (p, B given rate floors) sweep and theta collects
the differentiable problem data: the raw weight vector (w1, w2, rho) and any
float `SystemParams` leaves (gain, cycles, bandwidth_total, kappa, ...).
Unrolling that loop for reverse-mode AD would be both expensive (hundreds of
bisection iterations per BCD step) and *wrong* — the inner solves are
fixed-iteration bisections whose iterates have zero derivative.

Instead we differentiate implicitly at the solved point:

* the fixed point is wrapped in a `jax.custom_vjp` whose backward pass
  solves the adjoint system u = v + Phi_x^T u and then pulls u back through
  Phi_theta. The default is a truncated Neumann series (`adjoint_iters`
  applications of the one-step pullback); `adjoint_iters=0` switches to an
  exact dense solve of (I - Phi_x^T) u = v over the (B, p) state (2N
  unknowns). One linearization of Phi serves all four metric cotangents.
* inside Phi, every inner bisection (SP1's nested dual search, SP2's budget
  multiplier, the rate-floor `_b_min`) runs under `stop_gradient` and is
  followed by one Newton/arrowhead correction on the exported stationarity
  residuals (`core.sp1.sp1_stationarity`, `core.sp2.sp2_stationarity`):
  equal in value to solver precision, exact implicit-function-theorem
  derivative.

Subgradient conventions (see ROADMAP "Differentiable allocation"):

* `round_resolution` is piecewise-constant: the discrete s carries zero
  gradient a.e., so the accuracy metric's gradient is the (a.e. correct)
  zero subgradient except through lanes still moving the relaxed s-hat.
* box clips (f, s, p at their bounds) contribute one-sided zero derivatives;
  the makespan/total-time `max` routes gradient to the argmax lane.
* active sets (lam_n > 0 in SP1, B_n above its rate floor in SP2) are frozen
  at the solved point: gradients are exact within the current active set's
  validity region, and at an active-set flip (a nondifferentiable point of
  the true solution map) we return the current set's one-sided derivative.

Saturated-regime caveat. The BCD equilibrium of this model family generically
saturates the bandwidth budget with the fit-scaled rate floors (sum b_min ~
0.999 B_total, power at/near p_max on every lane — the w2*T pressure keeps
re-tightening T until the floors reconsume the budget, at ANY bandwidth
scale). At such fixed points the one-step map has near-unit neutral modes
and the forward program's finite differences include discrete-solver
trajectory effects (the carried-bracket SP2 search freezes each lane at the
budget-bisection step where it converged) that no linearization at the
solved point reproduces. Consequences, measured against central FD of the
full solve in f64: gradients w.r.t. weights and the SP1-side leaves (kappa,
cycles, samples, local_iters, global_rounds, s_standard) agree to ~1e-6;
gradients w.r.t. the channel-side leaves (gain, bits, noise_psd, p_max,
bandwidth_total) are the one-sided KKT derivative and track program FD in
sign and magnitude but only to a few percent. Treat channel-side gradients
as descent directions, not certified sensitivities.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from ..api.problem import Problem
from ..api.spec import SolverSpec
from ..core import energy as en
from ..core.accuracy import AccuracyModel, default_accuracy
from ..core.bcd import _allocate_impl, _init_carry_state, initial_allocation
from ..core.energy import rate as _rate
from ..core.sp1 import (_OUTER_ITERS, _coeffs, _f_of_lambda_diff,
                        _lambda_of_T, _s_of_lambda_diff, _sp1_bounds,
                        round_resolution, sp1_stationarity)
from ..core.sp2 import (G, _b_min, _clamp_rmin, _denergy2_dB2, _denergy_dB,
                        _p_rate, _sp2_direct_impl, r_min, sp2_stationarity)
from ..core.types import (_SYS_ARRAYS, _SYS_SCALARS, Allocation, SystemParams,
                          Weights)

Array = jnp.ndarray

#: SystemParams leaves differentiated by default (ISSUE 10 contract).
DEFAULT_WRT = ("gain", "cycles", "bandwidth_total", "kappa")

#: Metric order in the stacked output / gradient rows.
METRICS = ("objective", "energy", "time", "accuracy")


def _stop_tree(tree):
    return jax.tree_util.tree_map(lax.stop_gradient, tree)


# ---------------------------------------------------------------------------
# differentiable one-step map Phi (SP1 + SP2 with IFT-corrected inner solves)
# ---------------------------------------------------------------------------

def _sp1_diff(sys: SystemParams, warr: Array, acc: AccuracyModel, tt: Array):
    """Differentiable replica of `core.sp1._solve_sp1_impl`.

    The nested T/lambda bisection runs under stop_gradient (bit-compatible
    with the forward "bisect" engine); the KKT point (lam, T) then gets one
    arrowhead Newton step on the traced `sp1_stationarity` residuals, which
    restores the exact implicit derivative of the dual water-filling system

        M_n(lam_n) = T   (lam_n > 0),      sum_n lam_n = w2 Rg.
    """
    sg = lax.stop_gradient
    # mirror bcd's warr_sp1 clamp (w2 > 0 keeps the dual target positive)
    w = Weights(warr[0], jnp.maximum(warr[1], 1e-9), warr[2])
    sys0 = _stop_tree(sys)
    w0 = Weights(sg(w.w1), sg(w.w2), sg(w.rho))
    tt0 = sg(tt)

    _, q0 = _coeffs(sys0, w0)
    lam_hi, target0, T_lo, T_hi = _sp1_bounds(sys0, w0, q0, tt0)

    def body(_, c):
        lo, hi = c
        mid = 0.5 * (lo + hi)
        lam = _lambda_of_T(sys0, w0, acc, mid, tt0, lam_hi)
        more_time = jnp.sum(lam) > target0
        return jnp.where(more_time, mid, lo), jnp.where(more_time, hi, mid)

    lo, hi = lax.fori_loop(0, _OUTER_ITERS, body, (T_lo, T_hi))
    T0 = 0.5 * (lo + hi)
    lam0 = _lambda_of_T(sys0, w0, acc, T0, tt0, lam_hi)

    # SP1 active set: fast lanes snap lam = 0 (complementary slackness) and
    # padded lanes are inactive by construction. Both must be masked OUT of
    # every traced recomputation: _f_of_lambda's cbrt has an infinite
    # derivative at lam = 0 and would turn even zero cotangents into NaN.
    eff = lam0 > 0.0
    if sys.active is not None:
        eff = eff & sys.active

    # traced residuals at the stop-grad KKT point ...
    r_n, r_sum = sp1_stationarity(sys, w, acc, lam0, T0, tt, mask=eff)
    # ... and the per-device makespan slope M'_n < 0 (diagonal jvp at the
    # stop-grad point; the corrected closed forms inside sp1_stationarity
    # carry the true derivative where the raw bisections would carry zero)
    def mk(lam):
        return sp1_stationarity(sys0, w0, acc, lam, T0, tt0, mask=eff)[0]

    _, dM = jax.jvp(mk, (lam0,), (jnp.ones_like(lam0),))

    # devices holding the makespan-equalization constraint with a
    # responsive slope get the arrowhead correction; the rest keep lam = 0
    act = eff & (dM < -1e-30)
    inv = jnp.where(act, 1.0 / jnp.where(act, dM, -1.0), 0.0)
    denom = jnp.sum(inv)
    ok = jnp.abs(denom) > 1e-30
    # arrowhead solve of the linearized system:
    #   M'_n dlam_n - dT = -r_n  (active n),   sum dlam = -r_sum
    dT = jnp.where(ok,
                   (jnp.sum(jnp.where(act, r_n, 0.0) * inv) - r_sum)
                   / jnp.where(ok, denom, 1.0),
                   jnp.zeros_like(T0))
    dlam = jnp.where(act, (dT - r_n) * inv, 0.0)
    lam = lam0 + dlam
    T = T0 + dT

    # guarded primal recovery: active lanes track the smooth closed forms,
    # lam = 0 lanes hold the one-sided f = f_min (matching the forward's
    # clip(cbrt(0))) and keep s*'s genuine smooth dependence through psi
    lam_s = jnp.where(eff, lam, jnp.ones_like(lam))
    f = _f_of_lambda_diff(sys, w, lam_s)
    f = jnp.where(eff, f, jnp.asarray(sys.f_min, f.dtype))
    s_hat = _s_of_lambda_diff(sys, w, acc, lam, f=f)
    # discrete snap: piecewise-constant in theta -> stop-grad (zero a.e.)
    s_disc = round_resolution(sys0, sg(s_hat))
    _, q = _coeffs(sys, w)
    T_out = jnp.max(q * s_disc ** 2 / jnp.maximum(f, 1e-9) + tt)
    return f, s_disc, s_hat, jnp.maximum(T, T_out)


def _sp2_diff(sys: SystemParams, rmin: Array) -> Tuple[Array, Array]:
    """Differentiable replica of `core.sp2._sp2_direct_impl`.

    The forward solve runs under stop_gradient and the replica is built
    AROUND its output B0, so the replica equals the forward bit-for-bit at
    the linearization point (crucial: the adjoint solve amplifies any
    base-point inconsistency along the budget-coupling direction). Traced
    structure, lane by lane at the frozen solved point:

    * rate-floor lanes (B0 = b_min, the p_max kink where the clipped and
      rate branches of E_n meet): B tracks the traced root of
      G(p_max, b) = rmin (stop-grad bisection + one Newton step);
    * fit-floor lanes (B0 at the scaled floor b_lo = fit * b_min): B tracks
      the traced floor;
    * every other lane: B tracks the root of dE_n/dB + mu_n = 0 via one
      Newton step at the frozen branch. The per-lane multiplier is
      mu_n = c_n * mu_hi with c_n frozen: the forward's carried-bracket
      search collapses each lane at the budget-bisection step where its
      Newton iterate converged, so lanes hold slightly DIFFERENT effective
      multipliers — all dyadic fractions c_n of the traced bracket ceiling
      mu_hi(theta) = 1.001 * max_n -E_n'(b_lo) (the fraction is a.e.
      locally constant, the ceiling carries the true sensitivity).

    Finally the forward's exact-budget projection is applied in delta form:
    the traced budget violation is redistributed over the lanes'
    frozen surplus shares, B += (B_total - sum B) * sg(surplus / sum
    surplus). This keeps sum B = B_total as a traced identity (the forward
    enforces it to machine precision every step) without the forward
    expression's division by the tiny traced surplus mass, which would
    amplify base-point noise ~1000x.
    """
    sg = lax.stop_gradient
    sys0 = _stop_tree(sys)
    rmin_c = _clamp_rmin(sys, rmin)
    rmin0 = sg(rmin_c)

    _, B0, _ = _sp2_direct_impl(sys0, sg(rmin), True, True)
    dtype = B0.dtype

    # differentiable rate floor b_min: Newton-correct the stop-grad
    # bisection root of G(p_max, b) = rmin
    b0 = _b_min(sys0, rmin0)
    t = sys0.gain * sys0.p_max / (sys0.noise_psd * jnp.maximum(b0, 1e-12))
    GB = jnp.maximum((jnp.log1p(t) - t / (1.0 + t)) / jnp.log(2.0), 1e-30)
    pmax_b = jnp.broadcast_to(jnp.asarray(sys.p_max, dtype), B0.shape)
    b_min = b0 - (G(sys, pmax_b, b0) - rmin_c) / GB
    active = sys.active if sys.active is not None \
        else jnp.full(B0.shape, True)
    b_min = jnp.where(active, b_min, jnp.zeros((), dtype))
    b_min0 = sg(b_min)
    # ... then replicate the forward's best-effort fit scaling for the box
    fit = jnp.minimum(1.0, 0.999 * sys.bandwidth_total
                      / jnp.maximum(jnp.sum(b_min), 1e-30))
    b_lo = b_min * fit
    b_lo0 = sg(b_lo)

    # frozen lane classification at the solved point (module docstring)
    atkink = active & (jnp.abs(B0 - b_min0) <= 1e-6 * jnp.maximum(b_min0,
                                                                  1e-30))
    atfloor = active & ~atkink & (B0 <= b_lo0 * (1.0 + 1e-6))
    interior = active & ~atkink & ~atfloor

    # per-lane effective multiplier mu_n = c_n * mu_hi (docstring): the
    # frozen fraction comes from the forward's own slope at B0, the traced
    # ceiling from the forward's mu_hi sizing rule
    neg_slope = -_denergy_dB(sys, rmin_c, b_lo)
    neg_slope = jnp.where(active, neg_slope, jnp.zeros((), dtype))
    mu_hi = jnp.maximum(jnp.max(neg_slope), 1e-30) * (1.0 + 1e-3)
    mu_lane0 = jnp.maximum(-_denergy_dB(sys0, rmin0, B0), 0.0)
    mu_eff = sg(mu_lane0 / sg(mu_hi)) * mu_hi

    # one Newton step of root tracking on the frozen smooth branch:
    # g_n = dE/dB(B0) + mu_eff is exactly zero at the base point
    g_n = _denergy_dB(sys, rmin_c, B0) + mu_eff
    E2 = jnp.maximum(sg(_denergy2_dB2(sys0, rmin0, B0)),
                     jnp.finfo(dtype).tiny)
    B_int = B0 - g_n / E2
    B = jnp.where(atkink, b_min,
                  jnp.where(atfloor, b_lo,
                            jnp.where(interior, B_int,
                                      jnp.zeros((), dtype))))
    # exact-budget projection, delta form with frozen surplus shares
    surplus0 = jnp.where(active, jnp.maximum(sg(B0) - b_lo0, 0.0),
                         jnp.zeros((), dtype))
    wgt = surplus0 / jnp.maximum(jnp.sum(surplus0), 1e-30)
    B = B + wgt * (sys.bandwidth_total - jnp.sum(B))
    B = jnp.where(active, B, jnp.zeros((), dtype))
    p = jnp.clip(_p_rate(sys, rmin_c, B), sys.p_min, sys.p_max)
    return B, p


def _phi_step(x, sys: SystemParams, warr: Array, acc: AccuracyModel):
    """One differentiable BCD step (mirrors `bcd._allocate_impl`'s `step`).

    Returns the next (B, p) plus the SP1 side outputs (f, s, s_hat, T)."""
    B, p = x
    tt = sys.bits / jnp.maximum(_rate(sys, B, p), 1e-12)
    f, s_disc, s_hat, T = _sp1_diff(sys, warr, acc, tt)
    rmin = r_min(sys, f, s_disc, T)
    B2, p2 = _sp2_diff(sys, rmin)
    return (B2, p2), (f, s_disc, s_hat, T)


def _step_metrics(x, sys: SystemParams, warr: Array, acc: AccuracyModel):
    """Stacked (objective, energy, time, accuracy) + the realized Allocation,
    evaluated through one differentiable BCD step at the fixed point."""
    (B2, p2), (f, s_disc, s_hat, T) = _phi_step(x, sys, warr, acc)
    alloc = Allocation(bandwidth=B2, power=p2, freq=f, resolution=s_disc,
                       s_relaxed=s_hat, T=T)
    E = en.total_energy(sys, alloc)
    Tt = en.total_time(sys, alloc)
    A = en.total_accuracy(acc, alloc, sys.active)
    obj = warr[0] * E + warr[1] * Tt - warr[2] * A
    return jnp.stack([obj, E, Tt, A]), alloc


# ---------------------------------------------------------------------------
# the custom_vjp fixed point + the jitted grad program
# ---------------------------------------------------------------------------

def _normalize_weights(wr: Array) -> Array:
    # same contract as `api.problem.weights_leaf` / `Weights.normalized()`:
    # every component divides by w1 + w2 (rho included)
    return wr / (wr[0] + wr[1])


def _cell_grad(sysc: SystemParams, lv, wr, initc, acc, spec: SolverSpec,
               wrt, adjoint_iters: int):
    """Metrics + per-metric gradients for one cell. `lv` duplicates the
    `wrt` leaves of `sysc` as the differentiated operands."""
    alloc0 = initc if initc is not None else initial_allocation(sysc)
    state0 = _init_carry_state(sysc, alloc0)

    def build(lv_):
        return sysc.replace(**dict(zip(wrt, lv_)))

    @jax.custom_vjp
    def fp(lv_, warr):
        sys = build(lv_)
        out = _allocate_impl(sys, warr, acc, state0, spec.max_iters,
                             spec.tol, spec.sp1_method, spec.sp2_method,
                             spec.sp2_iters)
        return out[0], out[1]

    def fwd(lv_, warr):
        x = fp(lv_, warr)
        return x, (x, lv_, warr)

    def bwd(res, v):
        x, lv_, warr = res

        def phi(xx, l_, w_):
            return _phi_step(xx, build(l_), w_, acc)[0]

        _, pull = jax.vjp(phi, x, lv_, warr)
        if adjoint_iters > 0:
            # Neumann adjoint: u = sum_k (Phi_x^T)^k v solves u = v + Phi_x^T u
            u = lax.fori_loop(
                0, adjoint_iters,
                lambda _, u_: jax.tree_util.tree_map(jnp.add, v, pull(u_)[0]),
                v)
        else:
            # exact adjoint: the state is only (B, p) — 2N unknowns — so we
            # materialize Phi_x by jacrev and solve (I - Phi_x^T) u = v
            # directly. The budget-coupling direction puts an eigenvalue of
            # Phi_x near 1, which stalls the Neumann series but is perfectly
            # well-posed for a dense solve.
            flat_x, unravel = ravel_pytree(x)

            def phi_flat(xf):
                return ravel_pytree(phi(unravel(xf), lv_, warr))[0]

            J = jax.jacrev(phi_flat)(flat_x)
            vf, _ = ravel_pytree(v)
            eye = jnp.eye(flat_x.size, dtype=flat_x.dtype)
            u = unravel(jnp.linalg.solve(eye - J.T, vf))
        _, d_lv, d_wr = pull(u)
        return d_lv, d_wr

    fp.defvjp(fwd, bwd)

    def m(lv_, wr_):
        warr = _normalize_weights(wr_)
        x = fp(lv_, warr)
        return _step_metrics(x, build(lv_), warr, acc)

    mvec, vjp_fun, alloc = jax.vjp(m, lv, wr, has_aux=True)
    eye = jnp.eye(len(METRICS), dtype=mvec.dtype)
    d_lv, d_wr = jax.vmap(vjp_fun)(eye)   # one linearization, 4 cotangents
    return mvec, d_lv, d_wr, alloc


@partial(jax.jit,
         static_argnames=("acc", "spec", "wrt", "adjoint_iters", "fleet"))
def _solve_and_grad_impl(sysp, leaf_vals, warr_raw, init, acc, spec, wrt,
                         adjoint_iters, fleet):
    def cell(sysc, lv, wr, initc):
        return _cell_grad(sysc, lv, wr, initc, acc, spec, wrt, adjoint_iters)

    if fleet:
        return jax.vmap(cell)(sysp, leaf_vals, warr_raw, init)
    return cell(sysp, leaf_vals, warr_raw, init)


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradResult:
    """Value + gradients of the realized allocation metrics.

    value : dict metric -> scalar (single cell) or (C,) array (fleet) for
        each of `METRICS` = (objective, energy, time, accuracy).
    grads : dict metric -> {"weights": (3,)/(C, 3) gradient w.r.t. the RAW
        (w1, w2, rho) vector (the normalization Jacobian is included), plus
        one entry per `wrt` leaf with that leaf's shape}.
    allocation : the realized `Allocation` (per-cell arrays under a fleet).
    wrt : the SystemParams leaf names differentiated.
    """
    value: Dict[str, Array]
    grads: Dict[str, Dict[str, Array]]
    allocation: Allocation
    wrt: Tuple[str, ...]


def _raw_weights(w, dtype, cells: Optional[int]) -> Array:
    """Raw (UNnormalized) (3,)/(C, 3) weight operand — gradients are taken
    w.r.t. these entries, with the w1+w2 normalization inside the program."""
    if isinstance(w, Weights):
        arr = jnp.stack([jnp.asarray(w.w1, dtype), jnp.asarray(w.w2, dtype),
                         jnp.asarray(w.rho, dtype)], axis=-1)
    elif isinstance(w, (list, tuple)) and w and isinstance(w[0], Weights):
        arr = jnp.asarray([[wc.w1, wc.w2, wc.rho] for wc in w], dtype)
    else:
        arr = jnp.asarray(w, dtype)
    if arr.ndim == 0 or arr.shape[-1] != 3 or arr.ndim > 2:
        raise ValueError(
            f"solve_and_grad: weights must lower to (3,) or (C, 3), got "
            f"shape {jnp.shape(arr)}")
    if cells is None:
        if arr.ndim != 1:
            raise ValueError(
                "solve_and_grad: single-cell problem, but weights have a "
                f"cell axis ({arr.shape})")
        return arr
    if arr.ndim == 1:
        arr = jnp.broadcast_to(arr, (cells, 3))
    if arr.shape[0] != cells:
        raise ValueError(
            f"solve_and_grad: {arr.shape[0]} weight rows for {cells} cells")
    return arr


def _take_metric(x, i: int, fleet: bool):
    return x[:, i] if fleet else x[i]


def solve_and_grad(problem: Problem, spec: Optional[SolverSpec] = None, *,
                   wrt: Tuple[str, ...] = DEFAULT_WRT,
                   adjoint_iters: int = 30) -> GradResult:
    """Solve the allocation problem AND differentiate the realized metrics.

    Returns the (objective, energy, time, accuracy) of the BCD fixed point
    together with their gradients w.r.t. the raw weight vector and the
    requested `SystemParams` leaves, computed by implicit differentiation
    of the KKT conditions (module docstring). Composes with per-cell weight
    batches: a stacked (C, N) system with (C, 3) weights differentiates in
    ONE compiled program (the same vmap plumbing as `solve`).

    Parameters
    ----------
    problem : a plain BCD `Problem` (no mesh / rounds / deadline / assoc).
    spec : `SolverSpec` for the forward solve. For finite-difference-grade
        smoothness use `sp1_method="bisect"` with a tight `tol` in f64 —
        the backward pass linearizes the bisect engine's KKT point.
    wrt : SystemParams leaf names to differentiate (float leaves only).
    adjoint_iters : number of matrix-free Neumann iterations for the
        adjoint fixed point (error decays like the BCD contraction factor
        to this power on the contractive subspace); 0 switches to an exact
        dense solve of the 2N-dim adjoint system. The Neumann default is
        deliberately truncated: at saturated fixed points (module
        docstring) the exact resolvent amplifies the neutral modes where
        the one-step linearization is least trustworthy.

    Notes
    -----
    `accuracy` responds to theta only through the discrete resolution menu,
    so its gradient is the a.e.-correct zero subgradient almost everywhere
    (the relaxed s-hat is exposed via `result.allocation.s_relaxed`).
    """
    spec = SolverSpec() if spec is None else spec
    if problem.mesh is not None or problem.rounds is not None \
            or problem.deadline is not None or problem.assoc is not None:
        raise ValueError(
            "solve_and_grad: only plain BCD problems are differentiable "
            "(mesh/rounds/deadline/assoc topologies are not)")
    for name in wrt:
        if name not in _SYS_SCALARS + _SYS_ARRAYS:
            raise ValueError(
                f"solve_and_grad: unknown SystemParams leaf {name!r}; "
                f"differentiable leaves are {_SYS_SCALARS + _SYS_ARRAYS}")
    wrt = tuple(wrt)

    from ..api.solve import _apply_dtype   # local: avoid import cycle
    sysp, init = _apply_dtype(problem.system, problem.init, spec.dtype)
    acc = problem.acc if problem.acc is not None else default_accuracy()
    cells = problem.cells
    dtype = jnp.asarray(sysp.gain).dtype
    leaf_vals = tuple(jnp.asarray(getattr(sysp, k), dtype) for k in wrt)
    warr_raw = _raw_weights(problem.weights, dtype, cells)

    mvec, d_lv, d_wr, alloc = _solve_and_grad_impl(
        sysp, leaf_vals, warr_raw, init, acc, spec, wrt,
        int(adjoint_iters), cells is not None)

    fleet = cells is not None
    value = {m: _take_metric(mvec, i, fleet) for i, m in enumerate(METRICS)}
    grads = {}
    for i, m in enumerate(METRICS):
        g = {"weights": _take_metric(d_wr, i, fleet)}
        for k, name in enumerate(wrt):
            g[name] = _take_metric(d_lv[k], i, fleet)
        grads[m] = g
    return GradResult(value=value, grads=grads, allocation=alloc, wrt=wrt)
