"""FL-MAR system simulator: couples the allocator (repro.core) to actual
federated training (repro.fl) and keeps the paper's energy/time ledger.

This is the end-to-end loop of the paper's Fig. 1:
    allocate -> each device trains locally at its allocated resolution /
    CPU frequency -> uploads over its allocated (p_n, B_n) channel ->
    FedAvg -> repeat; the ledger accumulates eqs. (2), (3), (8), (10).

The per-round physics runs through the jit-resident round-dynamics engine
(`repro.dynamics.run_rounds`): one `lax.scan` over the R global rounds with
optional sampled channel gains, warm-started re-allocation, and a
straggler/dropout/staleness participation model whose realized per-device
codes feed the staleness-weighted FedAvg in `repro.fl.server`. The default
(static channels, full participation) reproduces the historical
allocate-once ledger.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.api import Problem, SolverSpec, solve
from repro.core import Allocation, SystemParams, Weights
from repro.core.accuracy import AccuracyModel, default_accuracy
from repro.dynamics import RoundsConfig, RoundsResult
from repro.fl.data import FLDataset, make_federated_dataset
from repro.fl.server import FLRunResult, run_federated


def map_resolution_to_dataset(sys: SystemParams, resolution: jax.Array,
                              dataset_resolutions: Sequence[int]) -> jax.Array:
    """Map the allocator's s_n onto the dataset's rendering grid by
    RELATIVE menu position (rank), not raw index.

    The snap targets `sys.resolutions` — whatever menu the system actually
    solves on, e.g. one attached by a fitted surrogate
    (`repro.diff.surrogate`) — and the menu rank is then rescaled onto the
    dataset grid, so a 6-point solver menu and a 4-point dataset grid still
    correspond monotonically end to end. Menus of equal length reduce to
    the historical index-for-index mapping exactly.

    Pure jnp (argmin snap onto the resolution menu), so it is jit-safe and
    usable inside a scan; returns an int32 array of dataset resolutions."""
    resolution = jnp.asarray(resolution)
    menu = jnp.asarray(sys.resolutions, resolution.dtype)
    idx = jnp.argmin(jnp.abs(resolution[..., None] - menu), axis=-1)
    n_menu = max(len(sys.resolutions) - 1, 1)
    n_ds = len(dataset_resolutions) - 1
    j = jnp.round(idx.astype(resolution.dtype) * (n_ds / n_menu))
    return jnp.take(jnp.asarray(dataset_resolutions, jnp.int32),
                    j.astype(jnp.int32))


@dataclasses.dataclass
class SimResult:
    allocation: Allocation
    fl: FLRunResult
    ledger: Dict[str, float]
    rounds: Optional[RoundsResult] = None


def simulate(key: jax.Array, sys: SystemParams, w: Weights,
             acc_model: Optional[AccuracyModel] = None,
             dataset: Optional[FLDataset] = None,
             dataset_resolutions: Sequence[int] = (8, 16, 24, 32),
             global_rounds: int = 10, local_iters: int = 5,
             lr: float = 0.05, split: str = "iid",
             unbalanced: bool = False,
             dynamics: Optional[RoundsConfig] = None,
             spec: Optional[SolverSpec] = None) -> SimResult:
    """Allocate resources, run FedAvg at the allocated resolutions, and return
    the realized energy/time ledger (paper eqs. 9 & 11).

    dynamics: optional RoundsConfig for the round engine (channel fading,
    stragglers, staleness); `rounds` is forced to `global_rounds` so the
    physics and the FL training see the same number of rounds. The default
    is the static/full-participation config, which reproduces the historical
    allocate-once ledger.

    spec: SolverSpec for the seeding cold solve (default: the historical
    max_iters=8 calibration). Allocation physics runs through the unified
    `repro.solve` dispatcher; the per-round solver options come from
    `dynamics` itself.
    """
    # keep the historical 2-way split so same-seed dataset/FL streams still
    # reproduce pre-engine runs; the dynamics stream is a fresh fold
    k_ds, k_fl = jax.random.split(key)
    k_dyn = jax.random.fold_in(key, 2)
    if dataset is None:
        dataset = make_federated_dataset(
            k_ds, n_clients=sys.n, split=split, unbalanced=unbalanced)
    assert dataset.n_clients == sys.n, "one device per FL client"

    acc = acc_model if acc_model is not None else default_accuracy()
    # one full cold solve seeds the engine either way: the static path holds
    # it fixed (bcd_iters=0 — the historical allocate-once ledger, no
    # per-round re-solve), the dynamics path warm-starts round 1 from it so
    # no round ever trains on an unconverged cold-capped allocation
    seed_spec = spec if spec is not None else SolverSpec(max_iters=8)
    init = solve(Problem(system=sys, weights=w, acc=acc), seed_spec).allocation
    if dynamics is None:
        cfg = RoundsConfig(rounds=global_rounds, bcd_iters=0)
    else:
        cfg = dynamics
        if cfg.rounds != global_rounds:
            cfg = dataclasses.replace(cfg, rounds=global_rounds)
    rr = solve(Problem(system=sys, weights=w, acc=acc, init=init,
                       rounds=cfg, key=k_dyn))
    alloc = rr.allocation
    # clients pre-render at the ROUND-0 resolutions: round 0's training can't
    # see the final round's channel state (under the static default all
    # rounds allocate identically, so this is the historical behavior)
    ds_res = map_resolution_to_dataset(sys, rr.resolutions[0],
                                       dataset_resolutions)

    staleness = None if dynamics is None else rr.staleness
    fl = run_federated(k_fl, dataset, ds_res,
                       global_rounds=global_rounds, local_iters=local_iters,
                       lr=lr, staleness=staleness,
                       staleness_decay=cfg.staleness_decay)

    ledger = dict(
        rr.totals(),
        final_accuracy=fl.round_accuracy[-1] if fl.round_accuracy else float("nan"),
        mean_resolution=float(jnp.mean(rr.resolutions)),
    )
    return SimResult(allocation=alloc, fl=fl, ledger=ledger, rounds=rr)
