"""ModelConfig: one dataclass describing every architecture in the zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer pattern: kinds repeated over n_layers // len(pattern) periods.
    # kinds: attn, attn_moe, attn_cross (dec w/ cross-attn), enc_attn,
    #        mamba, mamba_moe, rwkv
    block_pattern: Tuple[str, ...] = ("attn",)

    # attention
    attention: str = "gqa"         # gqa | mla | none
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # native SWA window (tokens)
    rope_theta: float = 1e4

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM
    d_state: int = 16
    d_conv: int = 4
    ssm_chunk: int = 256
    rwkv_chunk: int = 64

    # encoder-decoder (whisper): encoder layers use 'enc_attn'
    encoder_layers: int = 0
    encoder_ctx: int = 0           # e.g. 1500 audio frames

    # VLM: prefix patch embeddings (anyres tiling handled by the frontend stub)
    n_patches: int = 0

    # decode-path optimization (EXPERIMENTS.md §Perf): cache the encoder
    # output and per-layer cross-attention K/V instead of recomputing the
    # encoder every decode step
    cross_kv_cache: bool = False
    # int8 KV cache (per-slot/head scales): halves decode HBM traffic (§Perf)
    kv_cache_int8: bool = False

    norm_eps: float = 1e-5
    tied_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: bool = True             # checkpoint each scanned period in training
    # 'full' recomputes everything in bwd; 'dots' saves matmul outputs
    # (less recompute, more memory) — §Perf hillclimb knob
    remat_policy: str = "full"

    # citation for the config numbers
    source: str = ""

    @property
    def np_dtype(self):
        return dict(bfloat16=jnp.bfloat16, float32=jnp.float32)[self.dtype]

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, \
            f"{self.name}: n_layers {self.n_layers} not divisible by pattern {len(self.block_pattern)}"
        return self.n_layers // len(self.block_pattern)

    @property
    def d_inner(self) -> int:      # mamba inner width
        return 2 * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (spec: <=2 periods,
        d_model<=512, <=4 experts)."""
        pat = self.block_pattern
        d_model = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4)
        head_dim = d_model // n_heads
        kv = min(self.kv_heads, n_heads)
        kv = max(1, n_heads // max(1, self.n_heads // max(self.kv_heads, 1)))
        return self.replace(
            n_layers=len(pat) * (2 if len(pat) == 1 else 1),
            d_model=d_model, n_heads=n_heads,
            kv_heads=min(kv, n_heads), head_dim=head_dim,
            d_ff=min(self.d_ff, 256), vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            q_lora_rank=min(self.q_lora_rank, 32) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 16) if self.kv_lora_rank else 0,
            qk_nope_dim=min(self.qk_nope_dim, 16) if self.qk_nope_dim else 0,
            qk_rope_dim=min(self.qk_rope_dim, 8) if self.qk_rope_dim else 0,
            v_head_dim=min(self.v_head_dim, 32) if self.v_head_dim else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_ctx=min(self.encoder_ctx, 32) if self.encoder_ctx else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            ssm_chunk=32, rwkv_chunk=16, remat=False,
        )
