"""AdamW + SGD + LR schedules in pure JAX (pytree-based, optax-style API).

Optimizer states are float32 regardless of parameter dtype (bf16 training);
update() returns new params cast back to their original dtypes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params: Params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros, params),
                          nu=jax.tree_util.tree_map(zeros, params))

    def update(self, grads: Params, state: AdamWState, params: Params
               ) -> Tuple[Params, AdamWState]:
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-2
    momentum: float = 0.0

    def init(self, params: Params):
        if self.momentum == 0.0:
            return AdamWState(step=jnp.zeros((), jnp.int32), mu=None, nu=None)
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros, params), nu=None)

    def update(self, grads, state, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.momentum == 0.0:
            new_p = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, AdamWState(step=step, mu=None, nu=None)
        def upd(g, m, p):
            m = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
        flat = jax.tree_util.tree_map(upd, grads, state.mu, params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step=step, mu=new_m, nu=None)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, base_lr * (1 - frac))
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda l: l * scale, tree), norm
