"""Token data pipeline: deterministic synthetic LM streams with host-side
sharding and background prefetch.

Real deployments plug a tokenized corpus in by replacing `SyntheticLM` with a
reader exposing the same `__iter__ -> {"tokens": (B, S) int32}` protocol; the
sharding/prefetch layers are source-agnostic.  The synthetic stream is a
mixture of Zipf-distributed unigrams and deterministic n-gram motifs so that a
trained model exhibits a falling loss (useful for end-to-end driver checks).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic token stream."""
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_motifs: int = 64
    motif_len: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipf unigram table
        ranks = np.arange(1, self.vocab_size + 1)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._motifs = rng.integers(0, self.vocab_size,
                                    size=(self.n_motifs, self.motif_len))
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed + 1)
        while True:
            toks = rng.choice(self.vocab_size, p=self._probs,
                              size=(self.batch, self.seq_len)).astype(np.int32)
            # overwrite random spans with motifs (learnable structure)
            n_spans = max(self.seq_len // (4 * self.motif_len), 1)
            for b in range(self.batch):
                starts = rng.integers(0, self.seq_len - self.motif_len,
                                      size=n_spans)
                picks = rng.integers(0, self.n_motifs, size=n_spans)
                for st, pk in zip(starts, picks):
                    toks[b, st: st + self.motif_len] = self._motifs[pk]
            yield {"tokens": toks}


def shard_for_host(batch: Dict[str, np.ndarray], host_index: int,
                   host_count: int) -> Dict[str, np.ndarray]:
    """Slice the global batch to this host's shard (multi-host data loading)."""
    def sl(x):
        per = x.shape[0] // host_count
        return x[host_index * per: (host_index + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


class Prefetcher:
    """Background-thread prefetch with a bounded queue."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        while True:
            item = self._q.get()
            if item is self._done:
                return
            yield item


def make_pipeline(vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                  host_index: int = 0, host_count: int = 1,
                  prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    src = SyntheticLM(vocab_size=vocab_size, batch=batch, seq_len=seq_len,
                      seed=seed)
    it = (shard_for_host(b, host_index, host_count) for b in src)
    return iter(Prefetcher(it, depth=prefetch)) if prefetch else it
