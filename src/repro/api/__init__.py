"""repro.api — the unified solver API (one `solve()`, one `SolverSpec`).

    from repro import Problem, SolverSpec, solve

    res = solve(Problem(system=sys, weights=Weights(0.5, 0.5, 1.0)),
                SolverSpec(max_iters=8, tol=1e-4))

`SolverSpec` carries every static solver option (the jit-cache key);
`Problem` carries the data (system, traced weights, warm start, mesh,
rounds config, deadline); `solve` routes on topology. See the package
docstrings of `api.spec`, `api.problem`, and `api.solve`.
"""
from .problem import Problem, WeightsLike, weights_leaf
from .solve import solve
from .spec import (REL_STEP_FLOOR_ULPS, SolverSpec, TolFloorWarning,
                   rel_step_floor)

__all__ = ["Problem", "SolverSpec", "TolFloorWarning", "WeightsLike",
           "solve", "weights_leaf", "REL_STEP_FLOOR_ULPS", "rel_step_floor"]
