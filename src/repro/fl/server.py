"""FedAvg server (paper §III): weighted parameter averaging across clients.

`run_federated` is the reference single-host loop. For datacenter-scale
federated *simulation* the same aggregation is expressed as a weighted psum
over the mesh 'data' axis in `repro.launch.train` (clients sharded across
devices) — the aggregation math here is the oracle for that path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.client import local_train
from repro.fl.data import FLDataset, make_eval_set, render
from repro.models.cnn import accuracy as eval_accuracy
from repro.models.cnn import init_cnn

Params = dict


def fedavg(params_list: Sequence[Params], weights: jax.Array) -> Params:
    """w_global = sum_n (D_n / D) w_n   (the paper's global model, §III)."""
    wn = weights / jnp.sum(weights)

    def avg(*leaves):
        return sum(w * leaf for w, leaf in zip(wn, leaves))

    return jax.tree_util.tree_map(avg, *params_list)


def stale_weights(sizes: jax.Array, staleness: jax.Array,
                  decay: float) -> jax.Array:
    """Staleness-discounted FedAvg mass: D_n * decay^k for an update that
    arrives k rounds late (k = 0 is on time)."""
    return jnp.asarray(sizes) * jnp.asarray(decay) ** jnp.asarray(staleness)


def fedavg_stale(global_params: Params, updates: Sequence[Params],
                 eff_weights: Sequence[float],
                 total_weight: float) -> Params:
    """Staleness-aware aggregation hook for the round-dynamics engine.

    Updates arriving this round aggregate with their (already discounted)
    effective mass; the mass that did not arrive — dropped devices plus the
    discount lost to staleness — anchors to the current global model, so
    full on-time participation reduces exactly to plain `fedavg` and an
    empty arrival set leaves the model unchanged.
    """
    if not updates:
        return global_params
    anchor = max(float(total_weight) - float(sum(eff_weights)), 0.0)
    return fedavg(list(updates) + [global_params],
                  jnp.asarray(list(eff_weights) + [anchor]))


def resolve_eval_resolution(eval_resolution: Optional[int],
                            resolutions: Sequence[int]) -> int:
    """Explicit `is None` check: `eval_resolution or median` silently
    swallowed a falsy-zero override into the median fallback. An explicit
    invalid resolution (< 1 pixel would ZeroDivisionError inside `render`)
    now fails loudly instead."""
    if eval_resolution is not None:
        if int(eval_resolution) < 1:
            raise ValueError(
                f"eval_resolution must be >= 1 pixel, got {eval_resolution}")
        return int(eval_resolution)
    rs = sorted(int(r) for r in resolutions)
    return rs[len(rs) // 2]


@dataclasses.dataclass
class FLRunResult:
    params: Params
    round_accuracy: List[float]
    round_loss: List[float]


def run_federated(key: jax.Array, ds: FLDataset,
                  resolutions: Sequence[int],
                  global_rounds: int = 20, local_iters: int = 10,
                  lr: float = 0.05,
                  eval_every: int = 1, eval_n: int = 512,
                  eval_resolution: Optional[int] = None,
                  staleness=None, staleness_decay: float = 0.5) -> FLRunResult:
    """FedAvg over `ds` with per-client frame resolutions from the allocator.

    resolutions: one rendering resolution per client (the allocator's s_n,
    mapped onto the dataset's resolution grid by the simulator).
    staleness: optional (global_rounds, n_clients) int array from the
    round-dynamics engine (`RoundsResult.staleness`): -1 = the client's
    update is lost this round (dropout / dropped straggler), 0 = arrives on
    time, k > 0 = arrives k rounds late with its FedAvg mass discounted by
    staleness_decay**k (late clients still train, from the global model of
    the round they started).
    """
    k_init, k_eval = jax.random.split(key)
    params = init_cnn(k_init, num_classes=ds.num_classes)
    ev_imgs, ev_labels = make_eval_set(k_eval, ds, n=eval_n)
    # MAR deployment serves at the frame resolution the fleet runs at: eval at
    # the median allocated resolution unless overridden.
    ev_res = resolve_eval_resolution(eval_resolution, resolutions)
    ev_imgs = render(ev_imgs, ev_res)

    # pre-render each client's shard at its allocated resolution
    client_data = [
        (render(ds.images[i], int(resolutions[i])), ds.labels[i])
        for i in range(ds.n_clients)
    ]
    sizes = jnp.asarray([float(ds.labels.shape[1])] * ds.n_clients)

    accs: List[float] = []
    losses: List[float] = []
    if staleness is not None:
        staleness = np.asarray(staleness)
    total_w = float(jnp.sum(sizes))
    pending: dict = {}   # arrival round -> [(params, discounted weight)]
    for r in range(global_rounds):
        updated, weights, round_losses = [], [], []
        for i, (imgs, labels) in enumerate(client_data):
            code = 0 if staleness is None else int(staleness[r][i])
            if code < 0:   # update lost this round: client doesn't contribute
                continue
            if code > 0 and r + code >= global_rounds:
                continue   # would arrive after the run ends: skip the train
            p_i, loss_i = local_train(params, imgs, labels, lr, local_iters)
            round_losses.append(float(loss_i))
            if code == 0:
                updated.append(p_i)
                if staleness is not None:   # plain path aggregates by sizes
                    weights.append(float(sizes[i]))
            else:          # stale: arrives `code` rounds later, discounted
                w_eff = float(stale_weights(sizes[i], code, staleness_decay))
                pending.setdefault(r + code, []).append((p_i, w_eff))
        if staleness is None:
            params = fedavg(updated, sizes)
        else:
            arrivals = pending.pop(r, [])
            updated += [p for p, _ in arrivals]
            weights += [w for _, w in arrivals]
            params = fedavg_stale(params, updated, weights, total_w)
        losses.append(sum(round_losses) / len(round_losses)
                      if round_losses else float("nan"))
        if (r + 1) % eval_every == 0:
            accs.append(float(eval_accuracy(params, ev_imgs, ev_labels)))
    return FLRunResult(params=params, round_accuracy=accs, round_loss=losses)
