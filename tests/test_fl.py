"""FL substrate tests: FedAvg math, resolution mechanism, simulator ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests degrade to skips
    from _hypothesis_stub import given, settings, st

from repro.core import Weights, make_system
from repro.fl import (fedavg, fedavg_stale, local_train, make_eval_set,
                      make_federated_dataset, map_resolution_to_dataset,
                      render, resolve_eval_resolution, run_federated, simulate)
from repro.models.cnn import accuracy, apply_cnn, init_cnn, xent_loss


def test_fedavg_weighted_mean():
    p1 = {"a": jnp.ones((3,)), "b": {"c": jnp.zeros((2,))}}
    p2 = {"a": jnp.zeros((3,)), "b": {"c": jnp.ones((2,))}}
    avg = fedavg([p1, p2], jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(avg["a"]), 0.75)
    np.testing.assert_allclose(np.asarray(avg["b"]["c"]), 0.25)


def test_fedavg_single_client_equals_local():
    """With one client, FedAvg == plain local training (oracle property)."""
    key = jax.random.PRNGKey(0)
    ds = make_federated_dataset(key, n_clients=1, per_client=32,
                                num_classes=4, base_resolution=16)
    r = run_federated(jax.random.PRNGKey(1), ds, [16], global_rounds=3,
                      local_iters=2, lr=0.05, eval_n=64)
    k_init, _ = jax.random.split(jax.random.PRNGKey(1))  # mirror run_federated
    params = init_cnn(k_init, num_classes=4)
    imgs = render(ds.images[0], 16)
    for _ in range(3):
        params, _ = local_train(params, imgs, ds.labels[0], 0.05, 2)
    leaves1 = jax.tree_util.tree_leaves(r.params)
    leaves2 = jax.tree_util.tree_leaves(params)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_render_shapes_and_identity():
    key = jax.random.PRNGKey(2)
    ds = make_federated_dataset(key, n_clients=2, per_client=8,
                                base_resolution=16)
    assert render(ds.images, 8).shape == (2, 8, 8, 8, 1)
    np.testing.assert_array_equal(np.asarray(render(ds.images, 16)),
                                  np.asarray(ds.images))


def test_render_block_mean():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    y = render(x, 2)
    np.testing.assert_allclose(np.asarray(y)[0, :, :, 0],
                               [[2.5, 4.5], [10.5, 12.5]])


def test_resolution_accuracy_monotone_fast():
    """Low-res rendering must destroy class evidence (linear-probe check —
    fast proxy for the full training sweep in benchmarks fig7)."""
    key = jax.random.PRNGKey(3)
    ds = make_federated_dataset(key, n_clients=4, per_client=128,
                                num_classes=4, base_resolution=16)
    ev_i, ev_l = make_eval_set(jax.random.fold_in(key, 9), ds, n=512)

    def ridge_acc(res):
        tr = np.asarray(render(ds.images, res)).reshape(4 * 128, -1)
        te = np.asarray(render(ev_i, res)).reshape(512, -1)
        ytr = np.asarray(ds.labels).reshape(-1)
        # one-vs-all ridge regression
        A = tr.T @ tr + 1e-1 * np.eye(tr.shape[1])
        Y = np.eye(4)[ytr]
        Wm = np.linalg.solve(A, tr.T @ Y)
        pred = te @ Wm
        return float((pred.argmax(1) == np.asarray(ev_l)).mean())

    a4, a16 = ridge_acc(4), ridge_acc(16)
    assert a16 > a4 + 0.05, (a4, a16)


def test_noniid_hurts():
    key = jax.random.PRNGKey(4)
    kw = dict(n_clients=4, per_client=64, num_classes=4, base_resolution=16)
    ds_iid = make_federated_dataset(key, split="iid", **kw)
    ds_non = make_federated_dataset(key, split="noniid-1", **kw)
    r_iid = run_federated(jax.random.PRNGKey(5), ds_iid, [16] * 4,
                          global_rounds=8, local_iters=3, lr=0.1, eval_n=128)
    r_non = run_federated(jax.random.PRNGKey(5), ds_non, [16] * 4,
                          global_rounds=8, local_iters=3, lr=0.1, eval_n=128)
    assert r_iid.round_accuracy[-1] >= r_non.round_accuracy[-1] - 0.02


def test_simulator_ledger_consistent():
    key = jax.random.PRNGKey(6)
    sysp = make_system(key, n_devices=4)
    res = simulate(jax.random.fold_in(key, 1), sysp, Weights(0.5, 0.5, 10.0),
                   dataset_resolutions=(4, 8, 12, 16), global_rounds=2,
                   local_iters=2)
    led = res.ledger
    assert led["energy_total_J"] == pytest.approx(
        led["energy_per_round_J"] * 2, rel=1e-6)
    assert led["time_total_s"] > 0 and np.isfinite(led["final_accuracy"])


def test_eval_resolution_zero_is_not_median():
    """`eval_resolution or median` swallowed the falsy 0 override into the
    median; an explicit 0 now fails loudly (render would ZeroDivisionError)
    instead of silently evaluating at the median resolution."""
    with pytest.raises(ValueError, match="eval_resolution"):
        resolve_eval_resolution(0, [4, 8, 16])
    assert resolve_eval_resolution(None, [4, 8, 16]) == 8
    assert resolve_eval_resolution(4, [4, 8, 16]) == 4
    # works on jnp arrays of resolutions too (the vectorized mapper output)
    assert resolve_eval_resolution(None, jnp.asarray([16, 4, 8])) == 8


def test_map_resolution_to_dataset_vectorized():
    """jnp argmin snap onto the menu: jit-safe, returns an int array."""
    sysp = make_system(jax.random.PRNGKey(20), n_devices=4)
    # menu is (160, 320, 480, 640); dataset grid is (4, 8, 12, 16)
    s = jnp.asarray([150.0, 320.0, 500.0, 640.0])
    out = map_resolution_to_dataset(sysp, s, (4, 8, 12, 16))
    assert jnp.issubdtype(out.dtype, jnp.integer)
    np.testing.assert_array_equal(np.asarray(out), [4, 8, 12, 16])
    # shorter dataset menus map by relative rank (menu-aware, monotone)
    out2 = map_resolution_to_dataset(sysp, s, (4, 8))
    np.testing.assert_array_equal(np.asarray(out2), [4, 4, 8, 8])
    # a non-default (surrogate-fitted) menu maps by ITS OWN ranks: no
    # re-snapping to the Fig. 7 grid
    sys6 = sysp.replace(resolutions=(100.0, 200.0, 300.0, 400.0, 500.0,
                                     600.0))
    s6 = jnp.asarray([100.0, 290.0, 610.0])
    out6 = map_resolution_to_dataset(sys6, s6, (4, 8, 12, 16))
    np.testing.assert_array_equal(np.asarray(out6), [4, 8, 16])
    # jit-safe (usable inside a scan)
    out3 = jax.jit(
        lambda r: map_resolution_to_dataset(sysp, r, (4, 8, 12, 16)))(s)
    np.testing.assert_array_equal(np.asarray(out3), np.asarray(out))


def test_fedavg_stale_anchor_semantics():
    p1 = {"a": jnp.ones((2,))}
    p2 = {"a": jnp.zeros((2,))}
    glob = {"a": jnp.full((2,), 0.5)}
    # full on-time participation == plain fedavg
    out = fedavg_stale(glob, [p1, p2], [3.0, 1.0], 4.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.75)
    # nothing arrives -> global unchanged
    out = fedavg_stale(glob, [], [], 4.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.5)
    # discounted mass anchors to the global model: one update of mass 2
    # (decayed from 4) against total 4 -> half update, half anchor
    out = fedavg_stale(glob, [p1], [2.0], 4.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.75)


def test_run_federated_staleness_codes():
    key = jax.random.PRNGKey(21)
    ds = make_federated_dataset(key, n_clients=2, per_client=16,
                                num_classes=3, base_resolution=8)
    # client 1 always lost -> equivalent to training client 0 alone
    stale = np.zeros((3, 2), np.int32)
    stale[:, 1] = -1
    r = run_federated(jax.random.PRNGKey(22), ds, [8, 8], global_rounds=3,
                      local_iters=2, lr=0.05, eval_n=32, staleness=stale)
    ds1 = make_federated_dataset(key, n_clients=2, per_client=16,
                                 num_classes=3, base_resolution=8)
    # a stale code defers client 1's influence but keeps the run finite;
    # an arrival past the horizon (round 2 + lateness 2 >= 3) is pruned
    stale2 = np.zeros((3, 2), np.int32)
    stale2[0, 1] = 1
    stale2[2, 1] = 2
    r2 = run_federated(jax.random.PRNGKey(22), ds1, [8, 8], global_rounds=3,
                       local_iters=2, lr=0.05, eval_n=32, staleness=stale2)
    for res in (r, r2):
        assert len(res.round_loss) == 3
        assert np.isfinite(res.round_accuracy[-1])
    # all updates lost in a round -> params freeze through that round
    stale3 = -np.ones((2, 2), np.int32)
    r3 = run_federated(jax.random.PRNGKey(22), ds1, [8, 8], global_rounds=2,
                       local_iters=2, lr=0.05, eval_n=32, staleness=stale3)
    assert np.isnan(r3.round_loss[0])
    assert r3.round_accuracy[0] == r3.round_accuracy[1]


def test_simulate_dynamics_end_to_end():
    """The dynamics path threads engine staleness codes into run_federated:
    the rounds override, the (R, N) staleness shape, and a finite FL run."""
    from repro.dynamics import RoundsConfig

    key = jax.random.PRNGKey(30)
    sysp = make_system(key, n_devices=4)
    cfg = RoundsConfig(rounds=99, channel_mode="markov", drift_rho=0.9,
                       bcd_iters=3, bcd_tol=1e-3, participation="stale",
                       dropout_prob=0.2, deadline_slack=0.99)
    res = simulate(jax.random.fold_in(key, 1), sysp, Weights(0.5, 0.5, 10.0),
                   dataset_resolutions=(4, 8, 12, 16), global_rounds=3,
                   local_iters=2, dynamics=cfg)
    # rounds forced to global_rounds regardless of the config's value
    assert res.rounds.ledger.shape[0] == 3
    assert res.rounds.staleness.shape == (3, 4)
    assert len(res.fl.round_loss) == 3
    assert np.isfinite(res.ledger["final_accuracy"])
    assert 0.0 <= res.ledger["mean_arrived_frac"] <= 1.0
    # with a 20% dropout over 3x4 device-rounds, some codes should be lost
    codes = np.asarray(res.rounds.staleness)
    assert codes.min() >= -1 and codes.max() <= cfg.max_staleness


def test_cnn_resolution_agnostic():
    key = jax.random.PRNGKey(7)
    p = init_cnn(key, num_classes=5)
    for r in (4, 8, 16):
        x = jax.random.normal(key, (3, r, r, 1))
        assert apply_cnn(p, x).shape == (3, 5)


@given(st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_property_fedavg_preserves_scale(seed):
    key = jax.random.PRNGKey(seed)
    ps = [init_cnn(jax.random.fold_in(key, i), num_classes=3) for i in range(3)]
    wts = jnp.abs(jax.random.normal(key, (3,))) + 0.1
    avg = fedavg(ps, wts)
    for leaf, *others in zip(jax.tree_util.tree_leaves(avg),
                             *[jax.tree_util.tree_leaves(p) for p in ps]):
        lo = np.minimum.reduce([np.asarray(o) for o in others])
        hi = np.maximum.reduce([np.asarray(o) for o in others])
        assert (np.asarray(leaf) >= lo - 1e-6).all()
        assert (np.asarray(leaf) <= hi + 1e-6).all()
