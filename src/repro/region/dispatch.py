"""Dispatch layer: launch a planned batch and return it *in flight*.

JAX dispatch is asynchronous: `solve()` on a planned batch enqueues the
compiled computation and returns device arrays immediately — futures, not
values. The old monolith squandered that by calling `np.asarray` on each
chunk's results before assembling the next one, serializing host assembly
behind device compute. This layer keeps the results as device futures
inside an `InFlightBatch`; the completion layer materializes them later
(one blocking gather per batch), so the pipeline can plan/stack/enqueue
batch k+1 on the host while batch k is still computing — double-buffered
batches with `RegionPipeline.max_in_flight` bounding the queue depth.

Host time spent tracing/enqueueing the solve is charged to
`StageClocks.dispatch_s`; the in-flight window is observed by the
completion layer (`device_s`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

from repro.api import Problem, SolverSpec, solve
from repro.core.accuracy import AccuracyModel, default_accuracy
from repro.core.bcd import FleetResult

from .admission import StageClocks
from .planning import BatchPlan


@dataclasses.dataclass
class InFlightBatch:
    """A dispatched batch whose results are still device futures.

    `result` leaves are unmaterialized device arrays; `pending` holds the
    `PendingResponse` futures bound to the plan's real lanes (aligned by
    index). `seq` is the dispatch order — the completion order the
    synchronous facade reproduces."""
    plan: BatchPlan
    result: FleetResult
    t_dispatched: float
    seq: int
    pending: List[Any] = dataclasses.field(default_factory=list)
    materialized: bool = False


class Dispatcher:
    """Run planned batches through the one `solve()` dispatcher.

    The jit-cache key of every dispatch is (spec, topology, bucket) only —
    per-request weights ride along as a traced (C, 3) operand. `mesh=None`
    solves on the default device (fleet vmap); a mesh shards the cell axis
    (`region_mesh`, shard-local early exit unless `spec.lockstep`).
    """

    def __init__(self, spec: SolverSpec,
                 acc: Optional[AccuracyModel] = None, mesh=None,
                 clocks: Optional[StageClocks] = None):
        self.spec = spec
        self.acc = acc if acc is not None else default_accuracy()
        self.mesh = mesh
        self.clocks = clocks if clocks is not None else StageClocks()
        self._seq = 0

    def dispatch(self, plan: BatchPlan) -> InFlightBatch:
        """Enqueue one batch solve; returns without blocking on results."""
        t0 = time.monotonic()
        res = solve(Problem(system=plan.sys_batch, weights=plan.weights,
                            acc=self.acc, init=plan.init_batch,
                            mesh=self.mesh), self.spec)
        fleet = res.fleet if hasattr(res, "fleet") else res
        t1 = time.monotonic()
        self.clocks.record("dispatch", t1 - t0)
        batch = InFlightBatch(plan=plan, result=fleet, t_dispatched=t1,
                              seq=self._seq)
        self._seq += 1
        return batch
