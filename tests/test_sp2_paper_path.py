"""Cross-checks of the paper-literal Appendix-D SP2 path (Lambert-W dual +
Theorem-2 closed forms) against the exact solver, on rate-TIGHT instances
where Theorem 2's tight branch is exact."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Weights, make_system
from repro.core.sp2 import (G, _clamp_rmin, solve_sp2_direct, solve_sp2_v2,
                            solve_sp2_v2_thm2)


def _tight_instance(seed=0, n=8):
    """An instance where the deadline leaves just enough rate headroom that
    every device's rate constraint binds at the optimum."""
    sysp = make_system(jax.random.PRNGKey(seed), n_devices=n)
    # demand most of what maximum power can deliver at an equal split
    B0 = jnp.full((n,), sysp.bandwidth_total / n)
    p0 = jnp.full((n,), sysp.p_max)
    rmin = _clamp_rmin(sysp, 0.9 * G(sysp, p0, B0))
    return sysp, rmin


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_thm2_matches_exact_inner_when_tight(seed):
    sysp, rmin = _tight_instance(seed)
    w = Weights(0.5, 0.5, 1.0).normalized()
    rate0 = G(sysp, jnp.full((sysp.n,), sysp.p_max),
              jnp.full((sysp.n,), sysp.bandwidth_total / sysp.n))
    nu = w.w1 * sysp.global_rounds / rate0
    beta = sysp.p_max * sysp.bits / rate0

    p_t, B_t = solve_sp2_v2_thm2(sysp, w, nu, beta, rmin)
    p_e, B_e = solve_sp2_v2(sysp, w, nu, beta, rmin)

    def v2obj(p, B):
        return float(jnp.sum(nu * (p * sysp.bits - beta * G(sysp, p, B))))

    # both feasible for the rate floor, thm2 within 2% of the exact optimum
    assert bool(jnp.all(G(sysp, p_t, B_t) >= rmin * (1 - 1e-3)))
    exact, lit = v2obj(p_e, B_e), v2obj(p_t, B_t)
    assert lit <= exact + abs(exact) * 0.02 + 1e-12


@pytest.mark.parametrize("seed", [0, 3])
def test_direct_beats_or_ties_thm2_energy(seed):
    """Global-exactness sanity: the direct solver's transmission energy is
    never worse than the Appendix-D construction's."""
    sysp, rmin = _tight_instance(seed)
    w = Weights(0.5, 0.5, 1.0).normalized()
    rate0 = G(sysp, jnp.full((sysp.n,), sysp.p_max),
              jnp.full((sysp.n,), sysp.bandwidth_total / sysp.n))
    nu = w.w1 * sysp.global_rounds / rate0
    beta = sysp.p_max * sysp.bits / rate0
    p_t, B_t = solve_sp2_v2_thm2(sysp, w, nu, beta, rmin)
    p_d, B_d = solve_sp2_direct(sysp, rmin)

    def energy(p, B):
        return float(jnp.sum(p * sysp.bits / jnp.maximum(G(sysp, p, B), 1e-12)))

    assert energy(p_d, B_d) <= energy(p_t, B_t) * (1 + 1e-6)


def test_thm2_bandwidth_formula_consistency():
    """At the dual optimum, the tight-branch bandwidth of Theorem 2 equals
    r_min ln2/(W+1): sum over all-tight devices ~= B (the identity that makes
    the mu-bisection a bandwidth waterfilling)."""
    sysp, rmin = _tight_instance(5)
    w = Weights(0.5, 0.5, 1.0).normalized()
    rate0 = G(sysp, jnp.full((sysp.n,), sysp.p_max),
              jnp.full((sysp.n,), sysp.bandwidth_total / sysp.n))
    nu = w.w1 * sysp.global_rounds / rate0
    beta = sysp.p_max * sysp.bits / rate0
    p_t, B_t = solve_sp2_v2_thm2(sysp, w, nu, beta, rmin)
    total = float(jnp.sum(B_t))
    assert total == pytest.approx(sysp.bandwidth_total, rel=0.02)
