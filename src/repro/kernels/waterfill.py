"""Metaverse-scale allocator kernel: batched SP2 dual sweep (paper eq. A.23).

Evaluates g'(mu) for M candidate multipliers over N devices in one pass —
the inner loop of the bandwidth waterfilling at fleet scale (N ~ 10^5..10^6
AR clients per base-station region). Grid (N/bn,), VMEM block of device
parameters, Lambert-W by Halley iteration on VREGs, partial sums accumulated
into the (M,) output across sequential grid steps.

Oracle: kernels.ref.waterfill_gprime_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lambertw_vec(z, iters: int = 24):
    zc = jnp.maximum(z, -0.36787944117144233)
    p = jnp.sqrt(jnp.maximum(2.0 * (jnp.e * zc + 1.0), 0.0))
    w_branch = -1.0 + p - p * p / 3.0 + 11.0 * p ** 3 / 72.0
    lz = jnp.log(jnp.maximum(zc, 1e-300))
    llz = jnp.log(jnp.maximum(lz, 1e-300))
    w_big = lz - llz + llz / jnp.maximum(lz, 1e-12)
    w_small = zc * (1.0 - zc + 1.5 * zc * zc)
    w = jnp.where(zc < -0.25, w_branch, jnp.where(zc > 3.0, w_big, w_small))
    w = jnp.maximum(w, -1.0 + 1e-12)
    for _ in range(iters):
        ew = jnp.exp(w)
        f = w * ew - zc
        wp1 = w + 1.0
        denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1)
        w = jnp.maximum(w - f / jnp.where(jnp.abs(denom) < 1e-300, 1e-300, denom),
                        -1.0 + 1e-15)
    return w


def _waterfill_kernel(mu_ref, j_ref, rmin_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mu = mu_ref[...].astype(jnp.float32)       # (M,)
    j = j_ref[...].astype(jnp.float32)         # (bn,)
    rmin = rmin_ref[...].astype(jnp.float32)   # (bn,)
    z = (mu[:, None] - j[None, :]) / (jnp.e * j[None, :])   # (M, bn)
    w = _lambertw_vec(z)
    part = jnp.sum(rmin[None, :] * jnp.log(2.0)
                   / jnp.maximum(w + 1.0, 1e-12), axis=1)   # (M,)
    out_ref[...] += part


def waterfill_gprime(mu: jax.Array, j: jax.Array, rmin: jax.Array,
                     B_total: float, *, block_n: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """g'(mu) per candidate: mu (M,), j/rmin (N,) -> (M,). N % block_n == 0."""
    N = j.shape[0]
    assert N % block_n == 0, (N, block_n)
    M = mu.shape[0]
    sums = pl.pallas_call(
        _waterfill_kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((M,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((M,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((M,), jnp.float32),
        interpret=interpret,
    )(mu.astype(jnp.float32), j.astype(jnp.float32), rmin.astype(jnp.float32))
    return sums - B_total
