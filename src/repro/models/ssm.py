"""State-space / linear-recurrence mixers: Mamba (selective scan, for Jamba)
and RWKV6 "Finch" (data-dependent decay WKV).

Both use CHUNKED scans for train/prefill: lax.scan over sequence chunks with
an in-chunk parallel form, carrying O(1) recurrent state — this is what makes
`long_500k` decode trivially memory-feasible for these families and keeps the
lowered HLO small (one chunk body).  Decode is the single-step recurrence.

Numerical care: decays live in log space; in-chunk pairwise decay factors are
exp(logw_t - logw_tau) with tau <= t, always <= 1 — no overflow for any decay
magnitude.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.partition import shard


# ===========================================================================
# Mamba (selective SSM)
# ===========================================================================

class MambaCache(NamedTuple):
    conv: jax.Array      # (B, K_conv-1, Di) last inputs for the causal conv
    h: jax.Array         # (B, Di, N) recurrent state


def init_mamba(key: jax.Array, d_model: int, d_inner: int, d_state: int = 16,
               d_conv: int = 4, dt_rank: Optional[int] = None,
               dtype=jnp.bfloat16) -> dict:
    dt_rank = dt_rank or max(d_model // 16, 1)
    ks = jax.random.split(key, 8)
    sd = (1.0 / d_model) ** 0.5
    return dict(
        in_proj=(jax.random.normal(ks[0], (d_model, d_inner)) * sd).astype(dtype),
        gate_proj=(jax.random.normal(ks[1], (d_model, d_inner)) * sd).astype(dtype),
        conv_w=(jax.random.normal(ks[2], (d_conv, d_inner)) * 0.2).astype(dtype),
        conv_b=jnp.zeros((d_inner,), dtype),
        a_log=jnp.log(jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                       (d_inner, d_state))),
        d=jnp.ones((d_inner,), jnp.float32),
        dt_w=(jax.random.normal(ks[3], (d_inner, dt_rank)) * sd).astype(dtype),
        dt_proj=(jax.random.normal(ks[4], (dt_rank, d_inner)) * (dt_rank ** -0.5)).astype(dtype),
        dt_bias=jnp.log(jnp.expm1(jnp.full((d_inner,), 0.01))).astype(jnp.float32),
        bc_proj=(jax.random.normal(ks[5], (d_inner, 2 * d_state)) * sd).astype(dtype),
        out_proj=(jax.random.normal(ks[6], (d_inner, d_model)) * (1.0 / d_inner) ** 0.5).astype(dtype),
    )


def _mamba_conv_chunk(xc: jax.Array, tail: jax.Array, w: jax.Array,
                      b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise conv over one chunk. xc (B,L,Di), tail (B,K-1,Di)."""
    K = w.shape[0]
    xext = jnp.concatenate([tail, xc], axis=1)             # (B, L+K-1, Di)
    out = sum(xext[:, i: i + xc.shape[1], :] * w[i] for i in range(K)) + b
    return out, xext[:, -(K - 1):, :]


def _ssm_chunk(h0: jax.Array, dt: jax.Array, A: jax.Array, Bt: jax.Array,
               Ct: jax.Array, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """In-chunk parallel selective scan.
    h0 (B,Di,N); dt,x (B,L,Di); A (Di,N); Bt,Ct (B,L,N) -> (y (B,L,Di), hL)."""
    # per-step log decay and input
    la = dt[..., None] * A                                  # (B,L,Di,N)  (<0)
    u = dt[..., None] * Bt[:, :, None, :] * x[..., None]    # (B,L,Di,N)
    cla = jnp.cumsum(la, axis=1)                            # inclusive cumulative
    # contribution of h0 at step t: exp(cla_t) * h0
    from_h0 = jnp.exp(cla) * h0[:, None]
    # contribution of u_tau at t: exp(cla_t - cla_tau) * u_tau, tau <= t
    # use an associative scan to avoid the L^2 blowup in N:
    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2
    _, acc = jax.lax.associative_scan(comb, (la, u), axis=1)
    h = from_h0 + acc                                       # (B,L,Di,N)
    y = jnp.einsum("bldn,bln->bld", h, Ct)
    return y, h[:, -1]


def mamba(p: dict, x: jax.Array, *, mode: str = "train",
          cache: Optional[MambaCache] = None, chunk: int = 256
          ) -> Tuple[jax.Array, Optional[MambaCache]]:
    """x (B,S,D) -> (out (B,S,D), cache').  mode 'decode' needs S==1."""
    B, S, D = x.shape
    Di, N = p["a_log"].shape
    A = -jnp.exp(p["a_log"])                                # (Di,N)

    xin = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = jnp.einsum("bsd,de->bse", x, p["gate_proj"])
    xin = shard(xin, "batch", "seq", "inner")

    if mode == "decode":
        assert S == 1 and cache is not None
        conv_in = jnp.concatenate([cache.conv, xin], axis=1)   # (B,K,Di)
        Kc = p["conv_w"].shape[0]
        xc = jnp.einsum("bke,ke->be", conv_in[:, -Kc:], p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)
        dt = jax.nn.softplus(
            jnp.einsum("be,er->br", xc, p["dt_w"]) @ p["dt_proj"]
            + p["dt_bias"]).astype(jnp.float32)             # (B,Di)
        bc = jnp.einsum("be,en->bn", xc, p["bc_proj"])
        Bt, Ct = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
        a = jnp.exp(dt[..., None] * A)                      # (B,Di,N)
        h = a * cache.h + dt[..., None] * Bt[:, None, :] * xc.astype(jnp.float32)[..., None]
        y = jnp.einsum("bdn,bn->bd", h, Ct) + p["d"] * xc.astype(jnp.float32)
        out = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None]
        out = jnp.einsum("bse,ed->bsd", out, p["out_proj"])
        return out, MambaCache(conv=conv_in[:, 1:], h=h)

    # train / prefill: chunked scan
    pad = (-S) % chunk
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
    L = xin.shape[1]
    n_chunks = L // chunk
    xin_c = xin.reshape(B, n_chunks, chunk, Di).transpose(1, 0, 2, 3)
    Kc = p["conv_w"].shape[0]
    conv0 = jnp.zeros((B, Kc - 1, Di), xin.dtype)
    h0 = jnp.zeros((B, Di, N), jnp.float32)

    def step(carry, xc):
        tail, h = carry
        xconv, tail = _mamba_conv_chunk(xc, tail, p["conv_w"], p["conv_b"])
        xconv = jax.nn.silu(xconv)
        dt = jax.nn.softplus(
            jnp.einsum("ble,er->blr", xconv, p["dt_w"]) @ p["dt_proj"]
            + p["dt_bias"]).astype(jnp.float32)
        bc = jnp.einsum("ble,en->bln", xconv, p["bc_proj"]).astype(jnp.float32)
        Bt, Ct = jnp.split(bc, 2, axis=-1)
        y, h = _ssm_chunk(h, dt, A, Bt, Ct, xconv.astype(jnp.float32))
        y = y + p["d"] * xconv.astype(jnp.float32)
        return (tail, h), y.astype(x.dtype)

    (tail_end, h_end), ys = jax.lax.scan(step, (conv0, h0), xin_c)
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, Di)[:, :S]
    out = y * jax.nn.silu(z)
    new_cache = None
    if mode == "prefill" and cache is not None:
        # state handoff to decode requires an unpadded scan (padded steps
        # would evolve h through the conv bias path)
        assert pad == 0, f"prefill-with-cache needs S % chunk == 0 (S={S})"
        new_cache = MambaCache(conv=tail_end, h=h_end)
    return jnp.einsum("bse,ed->bsd", out, p["out_proj"]), new_cache


def init_mamba_cache(batch: int, d_inner: int, d_state: int = 16,
                     d_conv: int = 4, dtype=jnp.bfloat16) -> MambaCache:
    return MambaCache(conv=jnp.zeros((batch, d_conv - 1, d_inner), dtype),
                      h=jnp.zeros((batch, d_inner, d_state), jnp.float32))


# ===========================================================================
# RWKV6 (Finch): WKV with data-dependent decay
# ===========================================================================

class RWKVCache(NamedTuple):
    state: jax.Array     # (B, H, K, V) wkv state
    x_tm: jax.Array      # (B, D) previous token (time-mix shift)
    x_cm: jax.Array      # (B, D) previous token (channel-mix shift)


def init_rwkv_time_mix(key: jax.Array, d_model: int, n_heads: int,
                       head_dim: int, lora_rank: int = 64,
                       dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 9)
    sd = (1.0 / d_model) ** 0.5
    H, K = n_heads, head_dim
    return dict(
        r_proj=(jax.random.normal(ks[0], (d_model, H, K)) * sd).astype(dtype),
        k_proj=(jax.random.normal(ks[1], (d_model, H, K)) * sd).astype(dtype),
        v_proj=(jax.random.normal(ks[2], (d_model, H, K)) * sd).astype(dtype),
        g_proj=(jax.random.normal(ks[3], (d_model, H, K)) * sd).astype(dtype),
        # decay = exp(-exp(w0 + x @ lora_a @ lora_b))  (data-dependent, Finch)
        w_lora_a=(jax.random.normal(ks[4], (d_model, lora_rank)) * sd).astype(dtype),
        w_lora_b=(jax.random.normal(ks[5], (lora_rank, H, K)) * 0.01).astype(dtype),
        w0=jnp.full((H, K), -0.6, jnp.float32),
        u=(jax.random.normal(ks[6], (H, K)) * 0.1).astype(jnp.float32),
        o_proj=(jax.random.normal(ks[7], (H, K, d_model)) * sd).astype(dtype),
        mix_r=jnp.full((d_model,), 0.5, jnp.float32),
        mix_k=jnp.full((d_model,), 0.5, jnp.float32),
        mix_v=jnp.full((d_model,), 0.5, jnp.float32),
        mix_w=jnp.full((d_model,), 0.5, jnp.float32),
        mix_g=jnp.full((d_model,), 0.5, jnp.float32),
    )


def init_rwkv_channel_mix(key: jax.Array, d_model: int, d_ff: int,
                          dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    sd = (1.0 / d_model) ** 0.5
    return dict(
        ffn_k=(jax.random.normal(ks[0], (d_model, d_ff)) * sd).astype(dtype),
        ffn_v=(jax.random.normal(ks[1], (d_ff, d_model)) * (1.0 / d_ff) ** 0.5).astype(dtype),
        ffn_r=(jax.random.normal(ks[2], (d_model, d_model)) * sd).astype(dtype),
        mix_k=jnp.full((d_model,), 0.5, jnp.float32),
        mix_r=jnp.full((d_model,), 0.5, jnp.float32),
    )


def _shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """Token shift: prepend x_prev, drop last. x (B,S,D), x_prev (B,D)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _wkv_chunk(S0: jax.Array, r, k, v, logw, u) -> Tuple[jax.Array, jax.Array]:
    """One chunk of WKV6.  S0 (B,H,K,V); r,k,v,logw (B,L,H,K); u (H,K).
    o_t = r_t . (S_{t-1} + u * k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T.
    Returns (o (B,L,H,V), S_L)."""
    Lc = r.shape[1]
    clw = jnp.cumsum(logw, axis=1)                         # inclusive (B,L,H,K)
    clw_prev = clw - logw                                   # exclusive  = L_{t-1}
    # from initial state: r_t . (exp(clw_prev_t) * S0)
    o_init = jnp.einsum("blhk,bhkv->blhv", r * jnp.exp(clw_prev), S0)
    # intra-chunk pairs tau < t: factor exp(clw_prev_t - clw_tau)
    decay = clw_prev[:, :, None] - clw[:, None, :]          # (B, t, tau, H, K)
    mask = (jnp.arange(Lc)[:, None] > jnp.arange(Lc)[None, :])[None, :, :, None, None]
    fac = jnp.exp(jnp.where(mask, decay, -jnp.inf))        # masked to 0
    o_intra = jnp.einsum("blhk,blthk,bthk,bthv->blhv", r, fac, k, v)
    # bonus diagonal term
    o_diag = jnp.einsum("blhk,blhk,blhv->blhv", r, u[None, None] * k, v)
    o = o_init + o_intra + o_diag
    # state update
    SL = jnp.exp(clw[:, -1])[..., None] * S0 + \
        jnp.einsum("blhk,blhv->bhkv", jnp.exp(clw[:, -1:] - clw) * k, v)
    return o, SL


def rwkv_time_mix(p: dict, x: jax.Array, *, n_heads: int, head_dim: int,
                  mode: str = "train", cache: Optional[RWKVCache] = None,
                  chunk: int = 64) -> Tuple[jax.Array, Optional[jax.Array],
                                            Optional[jax.Array]]:
    """Returns (out, new_state, new_x_prev). x (B,S,D)."""
    B, S, D = x.shape
    H, K = n_heads, head_dim
    x_prev = cache.x_tm if (mode == "decode" and cache is not None) \
        else jnp.zeros((B, D), x.dtype)
    xs = _shift(x, x_prev) if mode != "decode" else x_prev[:, None]

    xr = _mix(x, xs, p["mix_r"]).astype(x.dtype)
    xk = _mix(x, xs, p["mix_k"]).astype(x.dtype)
    xv = _mix(x, xs, p["mix_v"]).astype(x.dtype)
    xw = _mix(x, xs, p["mix_w"]).astype(x.dtype)
    xg = _mix(x, xs, p["mix_g"]).astype(x.dtype)

    r = jnp.einsum("bsd,dhk->bshk", xr, p["r_proj"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", xk, p["k_proj"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", xv, p["v_proj"]).astype(jnp.float32)
    g = jnp.einsum("bsd,dhk->bshk", xg, p["g_proj"])
    lora = jnp.einsum("bsd,dr->bsr", xw, p["w_lora_a"])
    ww = p["w0"] + jnp.einsum("bsr,rhk->bshk", lora, p["w_lora_b"]).astype(jnp.float32)
    logw = -jnp.exp(ww)                                     # log decay, < 0

    if mode == "decode":
        assert S == 1 and cache is not None
        S0 = cache.state
        # o_j = sum_i r_i (S0_ij + u_i k_i v_j);  S1 = diag(w) S0 + k v^T
        o = jnp.einsum("bhk,bhkv->bhv", r[:, 0], S0) \
            + jnp.einsum("bhk,bhv->bhv", r[:, 0] * p["u"][None] * k[:, 0], v[:, 0])
        S1 = jnp.exp(logw[:, 0])[..., None] * S0 \
            + jnp.einsum("bhk,bhv->bhkv", k[:, 0], v[:, 0])
        out = (o[:, None] * jax.nn.silu(g).astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bshv,hvd->bsd", out, p["o_proj"])
        return out, S1, x[:, -1]

    # chunked scan
    pad = (-S) % chunk
    if pad:
        r, k, v, logw = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                         for t in (r, k, v, logw))
    L = r.shape[1]
    nch = L // chunk
    def resh(t):
        return t.reshape(B, nch, chunk, H, K).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = map(resh, (r, k, v, logw))
    S0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(Sc, inp):
        rr, kk, vv, ww_ = inp
        o, Sn = _wkv_chunk(Sc, rr, kk, vv, ww_, p["u"])
        return Sn, o

    S_end, os = jax.lax.scan(step, S0, (rc, kc, vc, wc))
    o = os.transpose(1, 0, 2, 3, 4).reshape(B, L, H, K)[:, :S]
    out = (o * jax.nn.silu(g).astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshv,hvd->bsd", out, p["o_proj"])
    return out, S_end, x[:, -1]


def rwkv_channel_mix(p: dict, x: jax.Array, *, mode: str = "train",
                     x_prev: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    xp = x_prev if (mode == "decode" and x_prev is not None) \
        else jnp.zeros((B, D), x.dtype)
    xs = _shift(x, xp) if mode != "decode" else xp[:, None]
    xk = _mix(x, xs, p["mix_k"]).astype(x.dtype)
    xr = _mix(x, xs, p["mix_r"]).astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["ffn_k"])
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["ffn_v"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["ffn_r"]))
    return rr * vv, x[:, -1]


def init_rwkv_cache(batch: int, d_model: int, n_heads: int, head_dim: int
                    ) -> RWKVCache:
    return RWKVCache(state=jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
                     x_tm=jnp.zeros((batch, d_model), jnp.bfloat16),
                     x_cm=jnp.zeros((batch, d_model), jnp.bfloat16))
