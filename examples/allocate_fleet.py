"""Metaverse-scale allocation: 2^17 AR clients through the closed-form
allocator, with the Pallas waterfill kernel doing the dual sweep.

    PYTHONPATH=src python examples/allocate_fleet.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import Weights, make_system
from repro.core.sp2 import r_min, solve_sp2_direct
from repro.kernels import ops

N = 1 << 17
key = jax.random.PRNGKey(0)
system = make_system(key, n_devices=N, bandwidth_total=20e6 * (N / 50))

f = jnp.full((N,), 1e9)
s = jnp.full((N,), 320.0)
from repro.core.energy import t_cmp
T = float(jnp.max(t_cmp(system, f, s))) * 1.2
rmin = r_min(system, f, s, jnp.asarray(T))

t0 = time.time()
p, B = solve_sp2_direct(system, rmin)
jax.block_until_ready(B)
print(f"direct SP2 for {N} devices: {time.time()-t0:.2f}s "
      f"(sum B = {float(B.sum())/1e6:.1f} MHz)")

# the kernelized dual sweep (64 candidate multipliers in one pass)
nu = jnp.ones((N,))
j = nu * system.bits * system.noise_psd / system.gain
mu = jnp.logspace(-12, -2, 64)
t0 = time.time()
g = ops.waterfill_gprime(mu, j, rmin, system.bandwidth_total, block_n=2048)
jax.block_until_ready(g)
print(f"waterfill kernel (64 mu x {N} devices): {time.time()-t0:.2f}s; "
      f"root bracket at mu~{float(mu[int(jnp.argmin(jnp.abs(g)))]):.2e}")
