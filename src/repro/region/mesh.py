"""Mesh layer: shard the cell axis of a stacked fleet across local devices.

`allocate_fleet` vmaps the jitted BCD across cells on ONE device; a region
is C cells x N devices where C x N is millions of clients, so the cell axis
must spread over a device mesh. Two execution modes:

  * `lockstep=True`: pure jit with `NamedSharding`-placed inputs — GSPMD
    partitions the vmapped solve along `cells`. The BCD `lax.while_loop`
    condition becomes a cross-device all-reduce, so every shard iterates
    until the globally slowest cell converges.
  * `lockstep=False` (default on a multi-device mesh): the same vmapped
    solver wrapped in `shard_map`, making the while_loop condition
    *shard-local* — a shard stops as soon as its own cells converge. Cells
    are solved by exactly the same select-masked program either way (the
    vmapped while_loop freezes converged lanes), so per-cell results are
    bit-identical between modes; only wall-clock differs. This is the
    "shard_map only if the BCD while_loop forces it" carve-out: the
    lockstep all-reduce is precisely what it buys back.

CPU dev recipe: XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.accuracy import AccuracyModel, default_accuracy
from repro.core.bcd import FleetResult, _fleet_cell_fn, _fleet_result
from repro.core.types import Allocation, SystemParams, Weights

Array = jnp.ndarray


def region_mesh(n_devices: Optional[int] = None, devices=None) -> Mesh:
    """1-D mesh over the local devices with axis name "cells" (the logical
    axis `sharding.partition.region_rules` maps onto it)."""
    devs = list(devices) if devices is not None else jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.array(devs), axis_names=("cells",))


def cell_specs(tree):
    """PartitionSpec pytree sharding every leaf's leading (cell) axis,
    derived from `sharding.partition.region_rules` (cells -> mesh axis,
    device and deeper axes shard-local)."""
    from repro.sharding.partition import logical_to_spec, region_rules

    rules = region_rules()
    return jax.tree_util.tree_map(
        lambda x: logical_to_spec(
            ("cells",) + ("device",) * (jnp.ndim(x) - 1), rules), tree)


def place_cells(tree, mesh: Mesh):
    """device_put every leaf with its cell axis sharded over `mesh`."""
    def put(x):
        x = jnp.asarray(x)
        spec = P("cells", *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, tree)


def pad_cells(tree, c_pad: int):
    """Pad every leaf's leading (cell) axis to `c_pad` by replicating the
    last cell — mesh shards must divide the cell count. Replicated cells
    cost duplicate work on the last shard only; callers slice them off."""
    def pad(x):
        x = jnp.asarray(x)
        c = x.shape[0]
        if c == c_pad:
            return x
        reps = jnp.broadcast_to(x[-1:], (c_pad - c,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)
    return jax.tree_util.tree_map(pad, tree)


@dataclasses.dataclass
class RegionResult:
    """A sharded fleet solve plus per-shard convergence stats.

    `stats` is gathered host-side lazily, ONCE, on first access (one
    device->host transfer of a packed (4,) array): the serving hot path —
    which only slices allocations back out — never pays the blocking
    sync, while monitoring callers still get the summary for free."""
    fleet: FleetResult
    _stats_packed: Array     # (4,) device array, see _pack_stats
    _n_cells: int
    _mesh_devices: int
    _stats_cache: Optional[dict] = dataclasses.field(default=None,
                                                     repr=False)

    @property
    def stats(self) -> dict:
        if self._stats_cache is None:
            vals = np.asarray(self._stats_packed)
            self._stats_cache = dict(
                cells=self._n_cells, mesh_devices=self._mesh_devices,
                converged_frac=float(vals[0]), iters_max=int(vals[1]),
                iters_mean=float(vals[2]), objective_mean=float(vals[3]))
        return self._stats_cache

    # convenience passthroughs so RegionResult reads like a FleetResult
    @property
    def allocation(self) -> Allocation:
        return self.fleet.allocation

    @property
    def objective(self) -> Array:
        return self.fleet.objective

    @property
    def iters(self) -> Array:
        return self.fleet.iters

    @property
    def converged(self) -> Array:
        return self.fleet.converged


@partial(jax.jit, static_argnames=("acc", "max_iters", "sp1_method",
                                   "sp2_method", "sp2_iters", "mesh",
                                   "lockstep", "with_init"))
def _region_solve_impl(sys_batch, warr, init, tol, acc: AccuracyModel,
                       max_iters: int, sp1_method: str, sp2_method: str,
                       sp2_iters: int, mesh: Mesh, lockstep: bool,
                       with_init: bool):
    fn = _fleet_cell_fn(warr, acc, max_iters, tol, sp1_method, sp2_method,
                        sp2_iters, with_init)
    vf = jax.vmap(fn)
    args = (sys_batch, init) if with_init else (sys_batch,)
    if lockstep or mesh.devices.size == 1:
        return vf(*args)
    in_specs = tuple(cell_specs(a) for a in args)
    return shard_map(vf, mesh=mesh, in_specs=in_specs,
                     out_specs=P("cells"), check_rep=False)(*args)


def _pack_stats(fleet: FleetResult) -> Array:
    """Per-shard convergence stats packed into one (4,) device array; the
    host transfer happens lazily in RegionResult.stats."""
    dtype = jnp.asarray(fleet.objective).dtype
    return jnp.stack([
        jnp.mean(fleet.converged.astype(dtype)),
        jnp.max(fleet.iters).astype(dtype),
        jnp.mean(fleet.iters.astype(dtype)),
        jnp.nanmean(fleet.objective),
    ])


def _slice_fleet(fleet: FleetResult, n_cells: int) -> FleetResult:
    if int(fleet.iters.shape[0]) == n_cells:
        return fleet
    cut = lambda x: x[:n_cells]
    return FleetResult(
        allocation=jax.tree_util.tree_map(cut, fleet.allocation),
        objective=cut(fleet.objective), iters=cut(fleet.iters),
        converged=cut(fleet.converged), history=cut(fleet.history))


def allocate_region(sys_batch: SystemParams, w: Weights,
                    acc: Optional[AccuracyModel] = None,
                    mesh: Optional[Mesh] = None,
                    max_iters: int = 20, tol: float = 1e-6,
                    init: Optional[Allocation] = None,
                    sp2_iters: int = 30, sp2_method: str = "direct",
                    sp1_method: str = "sweep",
                    lockstep: bool = False) -> RegionResult:
    """`allocate_fleet` with the cell axis sharded over a device mesh.

    The stacked-cell pytree is placed with `NamedSharding` over `cells`
    (padding the cell count up to a mesh multiple by replicating the last
    cell; replicas are sliced off the result). Per-cell outputs are
    bit-identical to single-device `allocate_fleet` — sharding moves work,
    not math. `stats` carries the per-shard convergence summary, gathered
    host-side once, lazily, on first access (the serving hot path never
    pays the sync).
    """
    mesh = mesh if mesh is not None else region_mesh()
    acc = acc if acc is not None else default_accuracy()
    w = w.normalized()
    C = int(jnp.asarray(sys_batch.gain).shape[0])
    D = int(mesh.devices.size)
    Cp = -(-C // D) * D
    sysb = place_cells(pad_cells(sys_batch, Cp), mesh)
    initb = None if init is None else place_cells(pad_cells(init, Cp), mesh)
    dtype = jnp.asarray(sysb.gain).dtype
    warr = jnp.asarray([w.w1, w.w2, w.rho], dtype)
    out = _region_solve_impl(sysb, warr, initb, jnp.asarray(tol, dtype), acc,
                             max_iters, sp1_method, sp2_method, sp2_iters,
                             mesh, lockstep, init is not None)
    fleet = _slice_fleet(_fleet_result(out, max_iters, dtype), C)
    return RegionResult(fleet=fleet, _stats_packed=_pack_stats(fleet),
                        _n_cells=C, _mesh_devices=int(mesh.devices.size))


def run_rounds_region(key: jax.Array, sys_batch: SystemParams, w: Weights,
                      cfg, acc: Optional[AccuracyModel] = None,
                      init: Optional[Allocation] = None,
                      mesh: Optional[Mesh] = None,
                      lockstep: bool = False):
    """`dynamics.run_rounds_fleet` with the cell axis sharded over a mesh.

    Per-cell key splits match `run_rounds_fleet` (cell c consumes split c of
    `key`; replicated pad cells reuse the last real cell's key and are
    sliced off), so results agree with the single-device engine.
    """
    from repro.dynamics.config import RoundsResult
    from repro.dynamics.engine import (_check_simulation_init,
                                       _init_carry_state, _result)

    mesh = mesh if mesh is not None else region_mesh()
    acc = acc if acc is not None else default_accuracy()
    w = w.normalized()
    _check_simulation_init(cfg, init)
    C = int(jnp.asarray(sys_batch.gain).shape[0])
    D = int(mesh.devices.size)
    Cp = -(-C // D) * D
    dtype = jnp.asarray(sys_batch.gain).dtype
    warr = jnp.asarray([w.w1, w.w2, w.rho], dtype)
    keys = pad_cells(jax.random.split(key, C), Cp)
    sysb = place_cells(pad_cells(sys_batch, Cp), mesh)
    keysb = place_cells(keys, mesh)
    init_state = None if init is None else jax.vmap(_init_carry_state)(
        sys_batch, init)
    initb = None if init_state is None else place_cells(
        pad_cells(init_state, Cp), mesh)
    out = _region_rounds_impl(sysb, warr, keysb, initb, acc, cfg, mesh,
                              lockstep, init_state is not None)
    res = _result(out)
    cut = lambda x: x[:C]
    return RoundsResult(
        allocation=jax.tree_util.tree_map(cut, res.allocation),
        ledger=cut(res.ledger), staleness=cut(res.staleness),
        gains=cut(res.gains), resolutions=cut(res.resolutions),
        columns=res.columns)


@partial(jax.jit, static_argnames=("acc", "cfg", "mesh", "lockstep",
                                   "with_init"))
def _region_rounds_impl(sys_batch, warr, keys, init_state, acc, cfg,
                        mesh: Mesh, lockstep: bool, with_init: bool):
    from repro.dynamics.engine import (_cell_engine, _init_carry_state,
                                       initial_allocation)

    def one(sysc, kc, *st):
        st0 = st[0] if with_init else _init_carry_state(
            sysc, initial_allocation(sysc))
        return _cell_engine(sysc, warr, acc, kc, st0, cfg)

    vf = jax.vmap(one)
    args = (sys_batch, keys) + ((init_state,) if with_init else ())
    if lockstep or mesh.devices.size == 1:
        return vf(*args)
    in_specs = tuple(cell_specs(a) for a in args)
    return shard_map(vf, mesh=mesh, in_specs=in_specs,
                     out_specs=P("cells"), check_rep=False)(*args)
