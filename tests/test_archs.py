"""Per-architecture smoke tests (assignment deliverable f): instantiate the
REDUCED variant of each family, run one forward + one train step + one decode
step on CPU, assert output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch.steps import make_train_step
from repro.models.transformer import (init_cache, init_model, lm_loss,
                                      model_forward, serve_step)
from repro.optim import AdamW

# full-architecture forward/train/decode sweeps: minutes of CPU, deselected
# in the quick CI job via -m "not slow"
pytestmark = pytest.mark.slow

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, key, B=2, S=32):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder_layers:
        b["frame_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_ctx, cfg.d_model)) * 0.1
    if cfg.n_patches:
        b["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_and_shapes(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.d_model <= 512 and (not cfg.n_experts or cfg.n_experts <= 4)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    logits, aux, _ = model_forward(params, cfg, batch, mode="train")
    S_out = S + (cfg.n_patches or 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = ARCHS[arch].reduced()
    key = jax.random.PRNGKey(1)
    params = init_model(key, cfg)
    opt = AdamW(lr=1e-3)
    step, _ = make_train_step(cfg, opt)
    opt_state = opt.init(params)
    batch = _batch(cfg, key)
    params2, opt_state, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)), jax.tree_util.tree_map(
            lambda a, b: jnp.any(a.astype(jnp.float32) != b.astype(jnp.float32)),
            params, params2), False)
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    key = jax.random.PRNGKey(2)
    params = init_model(key, cfg)
    B = 2
    cache = init_cache(cfg, B, 64)
    extras = None
    if cfg.encoder_layers:
        extras = {"frame_embeds": jax.random.normal(
            key, (B, cfg.encoder_ctx, cfg.d_model)) * 0.1}
    tok = jax.random.randint(key, (B,), 0, cfg.vocab_size)
    logits, cache = serve_step(params, cfg, cache, tok, jnp.asarray(0), extras)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits2, cache = serve_step(params, cfg, cache, tok, jnp.asarray(1), extras)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "rwkv6-1.6b", "minicpm3-4b"])
def test_decode_matches_prefill(arch):
    """Greedy decode logits must match the full-sequence forward at each
    position (cache correctness)."""
    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        # capacity drops differ between a 12-token prefill and 1-token decode;
        # raise capacity so the test isolates CACHE correctness
        cfg = cfg.replace(capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    params = init_model(key, cfg)
    B, S = 1, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = model_forward(params, cfg, {"tokens": toks},
                                      mode="prefill")
    cache = init_cache(cfg, B, 32)
    dec = []
    for t in range(S):
        lg, cache = serve_step(params, cfg, cache, toks[:, t], jnp.asarray(t))
        dec.append(lg)
    dec_logits = jnp.stack(dec, 1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_loss_decreases_reduced_lm():
    """A reduced dense model must learn the synthetic pipeline's structure."""
    from repro.data import make_pipeline

    cfg = ARCHS["internlm2-20b"].reduced()
    key = jax.random.PRNGKey(4)
    params = init_model(key, cfg)
    opt = AdamW(lr=3e-3)
    step, _ = make_train_step(cfg, opt)
    step = jax.jit(step, donate_argnums=(0, 1))
    opt_state = opt.init(params)
    losses = []
    for i, b in enumerate(make_pipeline(cfg.vocab_size, 4, 64, prefetch=0)):
        if i >= 30:
            break
        params, opt_state, m = step(params, opt_state,
                                    {"tokens": jnp.asarray(b["tokens"])})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "minicpm3-4b", "rwkv6-1.6b",
                                  "jamba-1.5-large-398b", "internlm2-20b"])
def test_block_prefill_matches_stepwise(arch):
    """Block prefill (one forward filling the cache) must hand off state
    identical to token-by-token decode prefill."""
    from repro.models.transformer import prefill

    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=8.0)
    cfg = cfg.replace(ssm_chunk=8, rwkv_chunk=8)
    key = jax.random.PRNGKey(5)
    params = init_model(key, cfg)
    B, P = 1, 16  # P divisible by ssm_chunk
    toks = jax.random.randint(key, (B, P + 4), 0, cfg.vocab_size)

    # path A: block prefill then decode
    cache_a = init_cache(cfg, B, 32)
    _, cache_a = prefill(params, cfg, {"tokens": toks[:, :P]}, cache_a)
    # path B: stepwise decode prefill
    cache_b = init_cache(cfg, B, 32)
    for t in range(P):
        _, cache_b = serve_step(params, cfg, cache_b, toks[:, t], jnp.asarray(t))

    la, ca = None, cache_a
    for t in range(P, P + 4):
        la, cache_a = serve_step(params, cfg, cache_a, toks[:, t], jnp.asarray(t))
        lb, cache_b = serve_step(params, cfg, cache_b, toks[:, t], jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=4e-2, atol=4e-2)
