"""Learned accuracy surrogate + weight auto-tuning (PR 10): shape
properties of `fit_surrogate` (monotone nondecreasing, concave in s, mean
preservation) as hypothesis property tests, the non-default-menu
round-trip through `round_resolution` / `map_resolution_to_dataset`
(satellite c), `solve()` compatibility of `SurrogateAccuracy`, and smoke
coverage for `tune_weights` / `pareto_sweep`.
"""
import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro import Problem, SolverSpec, Weights, make_system, solve
from repro.core.accuracy import FIG7_RESOLUTIONS, menu_of
from repro.core.sp1 import round_resolution
from repro.diff import (SurrogateAccuracy, fit_surrogate, pareto_front,
                        pareto_sweep, problem_with_surrogate, solve_and_grad,
                        tune_weights, weight_grid)
from repro.fl.simulator import map_resolution_to_dataset

SPEC = SolverSpec(sp1_method="bisect", tol=1e-9, max_iters=200)
MENU6 = (100.0, 200.0, 300.0, 400.0, 500.0, 600.0)


def _sys(n=6, key=0):
    return make_system(jax.random.PRNGKey(key), n_devices=n)


# ---------------------------------------------------------------------------
# fit_surrogate: shape properties (hypothesis, stub-degradable)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1.0),
                min_size=4, max_size=8),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_fit_surrogate_monotone_and_concave(accs, seed):
    rng = np.random.default_rng(seed)
    menu = np.sort(rng.uniform(50.0, 1000.0, size=len(accs)))
    menu += np.arange(len(accs))          # strictly increasing
    model = fit_surrogate(menu, accs, menu=tuple(menu))

    grid = np.geomspace(menu[0], menu[-1], 64)
    v = np.asarray(model.value(grid))
    d = np.asarray(model.deriv(grid))
    # monotone nondecreasing in s
    assert np.all(np.diff(v) >= -1e-9), v
    assert np.all(d >= -1e-12), d
    # concave in s: dA/ds nonincreasing along increasing s
    assert np.all(np.diff(d) <= 1e-9), d
    # fitted values reproduce the isotonic+concave projection's mean
    fitted = np.asarray(model.value(np.asarray(menu)))
    np.testing.assert_allclose(fitted.mean(), np.mean(accs), atol=1e-8)


def test_fit_surrogate_exact_on_clean_concave_data():
    menu = np.asarray(FIG7_RESOLUTIONS, float)
    accs = 0.9 - 0.5 / np.sqrt(menu / 100.0)      # concave, increasing
    model = fit_surrogate(menu, accs)
    np.testing.assert_allclose(np.asarray(model.value(menu)), accs,
                               atol=1e-8)
    assert menu_of(model) == tuple(menu)


def test_surrogate_requires_two_knots():
    with pytest.raises(ValueError):
        SurrogateAccuracy(knots=(1.0,), values=(0.5,), menu=(100.0,))


# ---------------------------------------------------------------------------
# menu round-trip (satellite c): non-default menus survive the snap
# ---------------------------------------------------------------------------

def test_problem_with_surrogate_installs_menu_and_solves():
    accs = [0.3, 0.45, 0.55, 0.6, 0.63, 0.65]
    model = fit_surrogate(MENU6, accs, menu=MENU6)
    prob = problem_with_surrogate(
        Problem(system=_sys(), weights=Weights(0.5, 0.5, 0.3)), model)
    assert prob.system.resolutions == MENU6
    r = solve(prob, SPEC)
    res = np.asarray(r.allocation.resolution)
    assert set(np.unique(res)).issubset(set(MENU6)), res


def test_round_resolution_respects_installed_menu():
    sysp = _sys().replace(resolutions=MENU6)
    snapped = round_resolution(sysp, jnp.asarray([90.0, 260.0, 640.0]))
    np.testing.assert_allclose(np.asarray(snapped), [100.0, 300.0, 600.0])


def test_map_resolution_rank_relative_on_long_menu():
    sysp = _sys().replace(resolutions=MENU6)
    ds = map_resolution_to_dataset(
        sysp, jnp.asarray([100.0, 290.0, 610.0]), (4, 8, 12, 16))
    np.testing.assert_array_equal(np.asarray(ds), [4, 8, 16])


def test_map_resolution_identity_when_lengths_match():
    sysp = _sys()   # default Fig. 7 menu, len 4
    menu = jnp.asarray(sysp.resolutions)
    ds = map_resolution_to_dataset(sysp, menu, (8, 16, 24, 32))
    np.testing.assert_array_equal(np.asarray(ds), [8, 16, 24, 32])


def test_surrogate_gradients_finite():
    accs = [0.3, 0.45, 0.55, 0.6, 0.63, 0.65]
    model = fit_surrogate(MENU6, accs, menu=MENU6)
    prob = problem_with_surrogate(
        Problem(system=_sys(), weights=Weights(0.5, 0.5, 0.3)), model)
    g = solve_and_grad(prob, SPEC, wrt=("kappa",))
    assert np.isfinite(float(g.value["objective"]))
    assert np.isfinite(float(g.grads["objective"]["kappa"]))
    assert np.all(np.isfinite(np.asarray(g.grads["objective"]["weights"])))


# ---------------------------------------------------------------------------
# tune_weights: a mis-weighted scenario is pulled onto its latency budget
# ---------------------------------------------------------------------------

def test_tune_weights_meets_latency_target():
    prob = Problem(system=_sys(n=8, key=3), weights=Weights(0.9, 0.1, 0.3))
    # total-time metric (global_rounds x per-round makespan) — the units
    # tune_weights budgets against
    t0 = float(solve_and_grad(prob, SPEC, wrt=()).value["time"])
    target = 0.9 * t0
    out = tune_weights(prob, SPEC, target_time=target, steps=16)
    assert out.met, out
    assert float(out.target_time) == pytest.approx(target)
    # the tuned weights actually deliver the promised operating point
    tuned = solve_and_grad(
        dataclasses.replace(prob, weights=out.weights),
        SPEC, wrt=())
    assert float(tuned.value["time"]) <= target * (1 + 1e-6)
    assert out.steps <= 16 and len(out.history) == out.steps


def test_tune_weights_arg_validation():
    prob = Problem(system=_sys(), weights=Weights(0.5, 0.5, 0.3))
    with pytest.raises(ValueError):
        tune_weights(prob, SPEC)                       # neither target
    with pytest.raises(ValueError):
        tune_weights(prob, SPEC, target_time=1.0, slos=())   # both


# ---------------------------------------------------------------------------
# pareto_sweep: one compiled fleet program, non-dominated frontier
# ---------------------------------------------------------------------------

def test_pareto_sweep_frontier():
    prob = Problem(system=_sys(n=6, key=3), weights=Weights(0.5, 0.5, 0.3))
    res = pareto_sweep(prob, SPEC, n=7)
    assert res.weights.shape == (7, 3)
    e = np.asarray(res.value["energy"], float)
    t = np.asarray(res.value["time"], float)
    assert np.all(np.isfinite(e)) and np.all(np.isfinite(t))
    assert res.front.any()
    # every frontier point is genuinely non-dominated
    for i in np.flatnonzero(res.front):
        dominated = (e <= e[i]) & (t <= t[i]) & ((e < e[i]) | (t < t[i]))
        assert not dominated.any(), i


def test_pareto_front_mask_math():
    e = np.asarray([3.0, 2.0, 1.0, 2.5, np.nan])
    t = np.asarray([1.0, 2.0, 3.0, 2.5, 0.5])
    front = pareto_front(e, t)
    np.testing.assert_array_equal(front, [True, True, True, False, False])


def test_weight_grid_shape_and_normalizable():
    g = weight_grid(n=9, rho=0.25)
    assert g.shape == (9, 3)
    assert np.all(g[:, 2] == 0.25)
    assert np.all(g[:, :2] > 0)
