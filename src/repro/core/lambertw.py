"""Principal-branch Lambert W in pure JAX (needed by SP2's dual, eq. A.22).

W0(z) for z >= -1/e, via a branch-aware initial guess + Halley iterations.
Accurate to ~1e-12 in float64 across the domain used by the allocator.
"""
from __future__ import annotations

import jax.numpy as jnp

_INV_E = -0.36787944117144233  # -1/e


def lambertw0(z, iters: int = 24):
    z = jnp.asarray(z)
    zc = jnp.maximum(z, _INV_E)  # clamp below branch point (callers guard)

    # --- initial guess -----------------------------------------------------
    # near the branch point: w ~ -1 + p - p^2/3 + 11 p^3/72, p = sqrt(2(e z + 1))
    p = jnp.sqrt(jnp.maximum(2.0 * (jnp.e * zc + 1.0), 0.0))
    w_branch = -1.0 + p - p * p / 3.0 + 11.0 * p ** 3 / 72.0
    # large z: asymptotic L1 - L2 + L2/L1
    lz = jnp.log(jnp.maximum(zc, 1e-300))
    llz = jnp.log(jnp.maximum(lz, 1e-300))
    w_big = lz - llz + llz / jnp.maximum(lz, 1e-12)
    # moderate z: series around 0
    w_small = zc * (1.0 - zc + 1.5 * zc * zc)
    w = jnp.where(zc < -0.25, w_branch, jnp.where(zc > 3.0, w_big, w_small))
    w = jnp.maximum(w, -1.0 + 1e-12)

    # --- Halley refinement -------------------------------------------------
    for _ in range(iters):
        ew = jnp.exp(w)
        f = w * ew - zc
        wp1 = w + 1.0
        denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1)
        step = f / jnp.where(jnp.abs(denom) < 1e-300, 1e-300, denom)
        w = jnp.maximum(w - step, -1.0 + 1e-15)
    return w
