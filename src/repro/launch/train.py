"""LM training driver (single-host or mesh).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-20b --reduced \
        --steps 50 --batch 8 --seq 256

Runs the same `train_step` the dry-run lowers, on real data from the
synthetic pipeline, with checkpointing. On this CPU container use --reduced;
on a real slice drop it and point --mesh at the production topology.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import get_config
from repro.data import make_pipeline
from repro.launch.steps import make_train_step
from repro.models.transformer import init_model
from repro.optim import AdamW, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size}")

    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, max(args.steps // 10, 1), args.steps))
    step_fn, _ = make_train_step(cfg, opt)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    opt_state = opt.init(params)

    pipe = make_pipeline(cfg.vocab_size, args.batch, args.seq, seed=0)
    t0 = time.time()
    losses = []
    for i, batch in enumerate(pipe):
        if i >= args.steps:
            break
        b = {"tokens": jnp.asarray(batch["tokens"])}
        if cfg.n_patches:
            b["patch_embeds"] = jnp.zeros((args.batch, cfg.n_patches, cfg.d_model),
                                          cfg.np_dtype)
        if cfg.encoder_layers:
            b["frame_embeds"] = jnp.zeros((args.batch, cfg.encoder_ctx, cfg.d_model),
                                          cfg.np_dtype)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {i+1}: loss={losses[-1]:.4f} "
                  f"({dt/(i+1):.2f}s/step)")
    print(f"loss first->last: {losses[0]:.4f} -> {losses[-1]:.4f}")
    if args.ckpt:
        save(args.ckpt, {"params": params}, step=args.steps)
        print(f"checkpoint written to {args.ckpt}")
    return losses


if __name__ == "__main__":
    main()
