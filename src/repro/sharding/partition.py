"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes to
mesh axes, plus helpers to build NamedShardings for parameter pytrees.

Parameters are nested dicts whose leaf *paths* determine logical axes via
`PARAM_AXIS_PATTERNS` (we own every init function, so paths are closed-world).
Activation constraints go through `shard()` which consults the active rule set
(a context set by the launcher; a no-op outside any mesh).
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]
Rules = Dict[str, MeshAxes]

# ---------------------------------------------------------------------------
# Rule sets.  Logical axes used across the model zoo:
#   batch, seq, embed, vocab, heads, kv_heads, head_dim, mlp, experts,
#   expert_mlp, inner (ssm inner width), state (ssm state), layers, window
# ---------------------------------------------------------------------------

def fsdp_tp_rules(multi_pod: bool, expert_parallel: bool = True,
                  seq_shard_decode: bool = False) -> Rules:
    """Default production rules: FSDP over 'data', tensor/expert parallel over
    'model'; the 'pod' axis (if present) extends the data axis."""
    data: MeshAxes = ("pod", "data") if multi_pod else "data"
    rules: Rules = {
        "batch": data,
        "seq": None,
        "embed": "data",          # FSDP shard of params' embed dim
        "embed_act": None,        # activations keep embed replicated
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "experts": "model" if expert_parallel else None,
        "expert_mlp": None if expert_parallel else "model",
        "inner": "model",
        "state": None,
        "layers": None,
        "kv_seq": "model" if seq_shard_decode else None,
        "pod_batch": data,
        # Megatron-style sequence parallelism: the residual stream between
        # blocks is sharded over 'model' along seq, so scan-saved activations
        # (the dominant training-memory term) shrink by the TP degree.
        "seq_outer": "model",
        "cache_batch": data,
    }
    return rules


def region_rules() -> Rules:
    """Allocator-side rules for the region service (`repro.region`): a
    stacked fleet's leading cell axis shards over the 1-D "cells" mesh
    (`region.region_mesh`); the per-device axis — and everything below it —
    stays local to a shard (cells are independent programs, so sharding
    inside a cell would only buy all-reduces). The BCD while_loop makes
    GSPMD lockstep across shards; `region.allocate_region` therefore runs
    the vmapped solver under shard_map with these same specs."""
    return {
        "cells": "cells",     # stacked base-station cells -> mesh axis
        "device": None,       # per-MAR-device axis: shard-local
        "rounds": None,       # dynamics ledgers: time stays local
    }


_ACTIVE: threading.local = threading.local()


@contextlib.contextmanager
def use_rules(rules: Optional[Rules], axis_sizes: Optional[Dict[str, int]] = None):
    prev = getattr(_ACTIVE, "rules", None)
    prev_sz = getattr(_ACTIVE, "axis_sizes", None)
    _ACTIVE.rules = rules
    _ACTIVE.axis_sizes = axis_sizes
    try:
        yield
    finally:
        _ACTIVE.rules = prev
        _ACTIVE.axis_sizes = prev_sz


def active_rules() -> Optional[Rules]:
    return getattr(_ACTIVE, "rules", None)


def active_axis_sizes() -> Optional[Dict[str, int]]:
    return getattr(_ACTIVE, "axis_sizes", None)


def logical_to_spec(axes: Sequence[Optional[str]], rules: Rules) -> P:
    parts = []
    used = set()
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        # avoid reusing a mesh axis twice in one spec (illegal in GSPMD)
        flat = tuple(m) if isinstance(m, tuple) else ((m,) if m else ())
        if any(f in used for f in flat):
            m = None
        for f in flat:
            used.add(f)
        parts.append(m)
    return P(*parts)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical axes (no-op w/o rules).
    Mesh axes that do not divide the corresponding dim are dropped."""
    rules = active_rules()
    if rules is None:
        return x
    sizes = active_axis_sizes()
    if sizes is not None:
        spec = shape_aware_spec(axes, x.shape, rules, sizes, repair=False)
    else:
        spec = logical_to_spec(axes, rules)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def _axes_prod(m: MeshAxes, sizes: Dict[str, int]) -> int:
    flat = tuple(m) if isinstance(m, tuple) else ((m,) if m else ())
    n = 1
    for a in flat:
        n *= sizes.get(a, 1)
    return n


def shape_aware_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                     rules: Rules, sizes: Dict[str, int],
                     repair: bool = True) -> P:
    """logical_to_spec + divisibility: a mesh axis that does not divide its dim
    is dropped; with `repair`, dropped axes are relocated to the first
    unsharded dim they do divide (e.g. kv_heads=8 on model=16 moves the
    'model' axis onto head_dim)."""
    parts: list = []
    used: set = set()
    dropped: list = []
    for dim, ax in enumerate(axes):
        m = rules.get(ax) if ax is not None else None
        flat = tuple(m) if isinstance(m, tuple) else ((m,) if m else ())
        flat = tuple(a for a in flat if a is not None)
        if any(a in used for a in flat):
            flat = ()
        if flat and shape[dim] % _axes_prod(flat, sizes) != 0:
            # try a prefix of the tuple that still divides
            while flat and shape[dim] % _axes_prod(flat, sizes) != 0:
                dropped.append(flat[-1])
                flat = flat[:-1]
        for a in flat:
            used.add(a)
        parts.append(flat if len(flat) > 1 else (flat[0] if flat else None))
    if repair:
        for a in dropped:
            if a in used:
                continue
            # right-to-left, never the stacked-layers dim: relocating a mesh
            # axis onto 'layers' would shard the scan's per-iteration slice
            # across devices (SPMD full-remat pathology).
            for dim in range(len(parts) - 1, -1, -1):
                if axes[dim] == "layers":
                    continue
                if parts[dim] is None and shape[dim] % sizes.get(a, 1) == 0 \
                        and shape[dim] >= sizes.get(a, 1):
                    parts[dim] = a
                    used.add(a)
                    break
    return P(*parts)


# ---------------------------------------------------------------------------
# Parameter path -> logical axes.  Longest-match regex on '/'-joined paths.
# Shapes listed for the stacked-layer ('layers' leading axis) convention.
# ---------------------------------------------------------------------------

PARAM_AXIS_PATTERNS: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / head
    (r"embed/tokens$",        ("vocab", "embed")),
    (r"lm_head/w$",           ("embed", "vocab")),
    (r"pos_embed/w$",         (None, "embed")),
    # attention (stacked over layers)
    (r"attn/wq$",             ("layers", "embed", "heads", "head_dim")),
    (r"attn/wk$",             ("layers", "embed", "kv_heads", "head_dim")),
    (r"attn/wv$",             ("layers", "embed", "kv_heads", "head_dim")),
    (r"attn/wo$",             ("layers", "heads", "head_dim", "embed")),
    (r"attn/bq$",             ("layers", "heads", "head_dim")),
    (r"attn/bk$",             ("layers", "kv_heads", "head_dim")),
    (r"attn/bv$",             ("layers", "kv_heads", "head_dim")),
    # MLA
    (r"attn/wq_a$",           ("layers", "embed", None)),
    (r"attn/wq_b$",           ("layers", None, "heads", "head_dim")),
    (r"attn/wkv_a$",          ("layers", "embed", None)),
    (r"attn/wkv_b$",          ("layers", None, "heads", "head_dim")),
    (r"attn/wk_rope$",        ("layers", "embed", "head_dim")),
    # dense mlp
    (r"mlp/wi$",              ("layers", "embed", "mlp")),
    (r"mlp/wg$",              ("layers", "embed", "mlp")),
    (r"mlp/wo$",              ("layers", "mlp", "embed")),
    # moe
    (r"moe/router$",          ("layers", "embed", "experts")),
    (r"moe/wi$",              ("layers", "experts", "embed", "expert_mlp")),
    (r"moe/wg$",              ("layers", "experts", "embed", "expert_mlp")),
    (r"moe/wo$",              ("layers", "experts", "expert_mlp", "embed")),
    # mamba
    (r"mamba/in_proj$",       ("layers", "embed", "inner")),
    (r"mamba/gate_proj$",     ("layers", "embed", "inner")),
    (r"mamba/conv_w$",        ("layers", None, "inner")),
    (r"mamba/conv_b$",        ("layers", "inner")),
    (r"mamba/a_log$",         ("layers", "inner", "state")),
    (r"mamba/d$",             ("layers", "inner")),
    (r"mamba/dt_w$",          ("layers", "inner", None)),
    (r"mamba/dt_proj$",       ("layers", None, "inner")),
    (r"mamba/dt_bias$",       ("layers", "inner")),
    (r"mamba/bc_proj$",       ("layers", "inner", None)),
    (r"mamba/out_proj$",      ("layers", "inner", "embed")),
    # rwkv6
    (r"rwkv/r_proj$",         ("layers", "embed", "heads", "head_dim")),
    (r"rwkv/k_proj$",         ("layers", "embed", "heads", "head_dim")),
    (r"rwkv/v_proj$",         ("layers", "embed", "heads", "head_dim")),
    (r"rwkv/g_proj$",         ("layers", "embed", "heads", "head_dim")),
    (r"rwkv/w_proj$",         ("layers", "embed", "heads", "head_dim")),
    (r"rwkv/w_lora_a$",       ("layers", "embed", None)),
    (r"rwkv/w_lora_b$",       ("layers", None, "heads", "head_dim")),
    (r"rwkv/u$",              ("layers", "heads", "head_dim")),
    (r"rwkv/o_proj$",         ("layers", "heads", "head_dim", "embed")),
    (r"rwkv/mix_.*$",         ("layers", "embed")),
    (r"rwkv/ffn_k$",          ("layers", "embed", "mlp")),
    (r"rwkv/ffn_v$",          ("layers", "mlp", "embed")),
    (r"rwkv/ffn_r$",          ("layers", "embed", "embed_act")),
    # norms & misc small
    (r"(^|/)norm[123]?/scale$", ("layers", None)),
    (r"final_norm/scale$",    (None,)),
    (r"proj/w$",              ("embed", "embed_act")),   # modality projector
    # ---- decode caches (leading axis = stacked periods) ----
    (r"/k$",                  ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim")),
    (r"/v$",                  ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim")),
    (r"/qk$",                 ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim")),
    (r"/qv$",                 ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim")),
    (r"/k_scale$",            ("layers", "cache_batch", "kv_seq", "kv_heads")),
    (r"/v_scale$",            ("layers", "cache_batch", "kv_seq", "kv_heads")),
    (r"/xk$",                 ("layers", "cache_batch", "kv_seq", "heads", "head_dim")),
    (r"/xv$",                 ("layers", "cache_batch", "kv_seq", "heads", "head_dim")),
    (r"/c_kv$",               ("layers", "cache_batch", "kv_seq", None)),
    (r"/k_rope$",             ("layers", "cache_batch", "kv_seq", None)),
    (r"/conv$",               ("layers", "cache_batch", None, "inner")),
    (r"/h$",                  ("layers", "cache_batch", "inner", "state")),
    (r"/state$",              ("layers", "cache_batch", "heads", None, None)),
    (r"/x_tm$",               ("layers", "cache_batch", None)),
    (r"/x_cm$",               ("layers", "cache_batch", None)),
)


def axes_for_path(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pat, axes in PARAM_AXIS_PATTERNS:
        if re.search(pat, path):
            if len(axes) == ndim:
                return axes
            if len(axes) == ndim + 1 and axes[0] == "layers":
                return axes[1:]          # unstacked variant (enc/dec singles)
            if len(axes) == ndim - 1:
                return ("layers",) + tuple(axes)
    return tuple([None] * ndim)          # replicate by default


def _iter_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_paths(v, f"{prefix}/{k}" if prefix else k)
    elif hasattr(tree, "_fields"):      # NamedTuple (caches)
        for k in tree._fields:
            yield from _iter_paths(getattr(tree, k), f"{prefix}/{k}" if prefix else k)
    else:
        yield prefix, tree


def param_logical_axes(params) -> Dict[str, Tuple[Optional[str], ...]]:
    return {path: axes_for_path(path, leaf.ndim)
            for path, leaf in _iter_paths(params)}


def param_pspecs(params, rules: Rules, axis_sizes: Optional[Dict[str, int]] = None):
    """Pytree of PartitionSpec matching `params`' structure. With axis_sizes,
    specs are shape-aware (divisibility-checked + greedy repair)."""
    def rec(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rec(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rec(getattr(tree, k), f"{prefix}/{k}" if prefix else k)
                                for k in tree._fields))
        axes = axes_for_path(prefix, tree.ndim)
        if axis_sizes is not None:
            return shape_aware_spec(axes, tree.shape, rules, axis_sizes)
        return logical_to_spec(axes, rules)
    return rec(params)


def param_shardings(params, mesh: Mesh, rules: Rules):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map(lambda spec: NamedSharding(mesh, spec),
                                  param_pspecs(params, rules, sizes),
                                  is_leaf=lambda x: isinstance(x, P))
