"""Resolution-agnostic CNN classifier — the FL-MAR client model.

Stands in for the paper's "modified YOLOv5m" (§VII-B): the conv trunk accepts
any square frame resolution (the paper's s_n knob) and global-average-pools
before the head, so one parameter set trains across resolutions — exactly the
mechanism the paper's accuracy-vs-resolution experiments rely on.

Pure JAX (no flax): params are nested dicts, apply is a jitted function.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Dict[str, jax.Array]]


def _conv(x, w, b, stride=1):
    out = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def init_cnn(key: jax.Array, num_classes: int = 10, in_channels: int = 1,
             widths: Sequence[int] = (16, 32, 64)) -> Params:
    params: Params = {}
    cin = in_channels
    for i, cout in enumerate(widths):
        key, k1, k2 = jax.random.split(key, 3)
        fan_in = 3 * 3 * cin
        params[f"conv{i}"] = dict(
            w=jax.random.normal(k1, (3, 3, cin, cout)) * (2.0 / fan_in) ** 0.5,
            b=jnp.zeros((cout,)),
        )
        cin = cout
    key, k1 = jax.random.split(key)
    params["head"] = dict(
        w=jax.random.normal(k1, (cin, num_classes)) * (1.0 / cin) ** 0.5,
        b=jnp.zeros((num_classes,)),
    )
    return params


def apply_cnn(params: Params, images: jax.Array) -> jax.Array:
    """images: (B, H, W, C) any H=W resolution -> (B, num_classes) logits."""
    x = images
    n_convs = sum(1 for k in params if k.startswith("conv"))
    for i in range(n_convs):
        p = params[f"conv{i}"]
        x = _conv(x, p["w"], p["b"], stride=1)
        x = jax.nn.relu(x)
        # downsample while the spatial extent allows
        if x.shape[1] >= 2:
            x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    x = jnp.mean(x, axis=(1, 2))          # global average pool: resolution-free
    h = params["head"]
    return x @ h["w"] + h["b"]


def xent_loss(params: Params, images: jax.Array, labels: jax.Array) -> jax.Array:
    logits = apply_cnn(params, images)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(params: Params, images: jax.Array, labels: jax.Array) -> jax.Array:
    logits = apply_cnn(params, images)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
