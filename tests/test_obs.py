"""The unified telemetry layer (`repro.obs`): recorder/span/point units,
metrics + exporters, the report CLI, device-resident solver counters, the
StageClocks sample rework, and the three cross-cutting guarantees of the
PR: (a) same-seed runs emit identical event streams modulo timing,
(b) instrumentation adds ZERO compiled shapes recorder on or off
(via the shared `compile_counter` fixture), and (c) the disabled-path
overhead of the instrumentation sites is < 2% of serve wall time.
"""
import math
import time
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from repro import (AllocationRequest, Problem, RegionAllocator, SolverSpec,
                   Weights, make_system, solve, obs)
from repro.core.bcd import allocate, allocate_fleet, stack_systems
from repro.region.admission import StageClocks

W = Weights(0.5, 0.5, 1.0)


@pytest.fixture(autouse=True)
def _clean_recorder():
    """Every test starts and ends on the default no-op recorder."""
    obs.set_recorder(None)
    yield
    obs.set_recorder(None)


def _mk_cells(sizes, seed=0):
    key = jax.random.PRNGKey(seed)
    return [(f"cell{i}-{n}", make_system(jax.random.fold_in(key, i),
                                         n_devices=n))
            for i, n in enumerate(sizes)]


def _serve(cells, spec, w=W, cells_per_batch=2):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        svc = RegionAllocator(w, cells_per_batch=cells_per_batch,
                              min_bucket=8, spec=spec)
        for cid, s in cells:
            svc.submit(AllocationRequest(cell_id=cid, sys=s))
        return svc.flush()


# ---------------------------------------------------------------------------
# recorder / spans / points
# ---------------------------------------------------------------------------

def test_span_nesting_and_ids():
    rec = obs.MemoryRecorder()
    obs.set_recorder(rec)
    with obs.span("outer", tag="a"):
        with obs.span("inner"):
            obs.point("evt", k=3)
    obs.set_recorder(None)

    assert [e["name"] for e in rec.events] == ["evt", "inner", "outer"]
    evt, inner, outer = rec.events
    assert outer["parent"] == -1 and outer["span"] == 0
    assert inner["parent"] == outer["span"] and inner["span"] == 1
    assert evt["span"] == inner["span"] and evt["type"] == "point"
    assert outer["tag"] == "a" and evt["k"] == 3
    assert outer["dur_s"] >= inner["dur_s"] >= 0.0


def test_span_ids_reset_on_install():
    for _ in range(2):
        rec = obs.MemoryRecorder()
        obs.set_recorder(rec)
        with obs.span("s"):
            pass
        assert rec.events[0]["span"] == 0


def test_disabled_path_is_inert():
    assert not obs.enabled()
    s1 = obs.span("anything", big_attr=list(range(100)))
    s2 = obs.span("else")
    assert s1 is s2            # one cached null context manager
    with s1:
        assert obs.point("evt", x=1) is None


def test_strip_timing():
    ev = dict(type="point", name="x", span=0, parent=0,
              ts=123.0, dur_s=0.5, latency_s=0.1, iters=3, stage="plan")
    assert obs.strip_timing(ev) == dict(type="point", name="x", span=0,
                                        parent=0, iters=3, stage="plan")


def test_jsonl_recorder_roundtrip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with obs.recording(obs.JsonlRecorder(path)):
        with obs.span("run", n=np.int64(2)):     # numpy scalars coerce
            obs.point("evt", v=np.float64(1.5))
    events = obs.read_jsonl(path)
    assert [e["name"] for e in events] == ["evt", "run"]
    assert events[0]["v"] == 1.5 and events[1]["n"] == 2


def test_recording_restores_previous():
    outer = obs.MemoryRecorder()
    obs.set_recorder(outer)
    with obs.recording(obs.MemoryRecorder()) as inner:
        obs.point("inner_evt")
    obs.point("outer_evt")
    obs.set_recorder(None)
    assert [e["name"] for e in inner.events] == ["inner_evt"]
    assert [e["name"] for e in outer.events] == ["outer_evt"]


# ---------------------------------------------------------------------------
# metrics + exporters
# ---------------------------------------------------------------------------

def test_counter_and_gauge():
    reg = obs.MetricsRegistry()
    c = reg.counter("requests", stage="plan")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    assert reg.counter("requests", stage="plan") is c     # get-or-create
    assert reg.counter("requests", stage="gather") is not c
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0


def test_histogram_percentiles_accuracy():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-4.0, sigma=0.8, size=4000)   # ~1.8e-2 s
    h = obs.Histogram("lat")
    h.observe_many(vals)
    assert h.count == 4000
    for q in (50, 90, 99):
        exact = np.percentile(vals, q)
        got = h.percentile(q)
        # bucket growth is 7%: interpolated percentiles must sit inside it
        assert abs(got - exact) / exact < 0.07, (q, got, exact)
    assert h.percentile(0) == vals.min()
    assert h.percentile(100) == vals.max()
    assert math.isnan(obs.Histogram("empty").percentile(50))


def test_prometheus_text_and_jsonl_export():
    reg = obs.MetricsRegistry()
    reg.counter("req", stage="plan").inc(3)
    reg.gauge("depth").set(2)
    h = reg.histogram("lat")
    h.observe_many([0.001, 0.002, 0.004, 5.0])
    text = obs.prometheus_text(reg)
    assert 'req_total{stage="plan"} 3.0' in text
    assert "# TYPE req_total counter" in text
    assert "depth 2.0" in text
    assert 'le="+Inf"} 4' in text
    assert "lat_count 4" in text

    records = obs.metrics_jsonl(reg)
    kinds = {r["kind"] for r in records}
    assert kinds == {"counter", "gauge", "histogram"}
    hist = next(r for r in records if r["kind"] == "histogram")
    assert hist["count"] == 4 and hist["min"] == 0.001 and hist["max"] == 5.0
    assert "p99" in hist


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------

def test_report_cli_renders_tables(tmp_path, capsys):
    from repro.obs import report

    path = str(tmp_path / "events.jsonl")
    with obs.recording(obs.JsonlRecorder(path)):
        with obs.span("solve"):
            obs.point("stage", stage="plan", dur_s=0.002)
            obs.point("stage", stage="gather", dur_s=0.001)
            obs.point("request", cell_id="c0", bucket=8, warm=False,
                      iters=3, converged=True, batch_seq=0,
                      bcd_iters=3.0, sp1_evals=147.0, sp2_evals=122.0,
                      residual=1e-7, latency_s=0.015)
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert "== spans ==" in out and "solve" in out
    assert "== pipeline stages ==" in out and "plan" in out
    assert "== request latency ==" in out and "end_to_end" in out
    assert "== per-request solver counters ==" in out
    assert "bcd_iters" in out and "sp2_evals" in out
    assert "p50_ms" in out and "p99_ms" in out


# ---------------------------------------------------------------------------
# device-resident solver counters
# ---------------------------------------------------------------------------

def test_single_solve_counters_match_history():
    sysp = make_system(jax.random.PRNGKey(1), n_devices=6)
    res = allocate(sysp, W, max_iters=8, keep_history=True)
    ctr = res.counters
    assert ctr is not None
    d = ctr.as_dict()
    assert set(d) == {"bcd_iters", "sp1_evals", "sp2_evals", "residual"}
    assert d["bcd_iters"] == res.iters
    assert d["sp2_evals"] == sum(row["sp2_iters"] for row in res.history)
    assert d["residual"] == pytest.approx(res.history[-1]["rel_step"])
    from repro.core.sp1 import dual_evals_per_iter
    from repro.core.accuracy import default_accuracy
    per = dual_evals_per_iter("sweep", default_accuracy())
    assert d["sp1_evals"] == res.iters * per


def test_fleet_counters_shape_and_slicing():
    key = jax.random.PRNGKey(2)
    batch = stack_systems([make_system(jax.random.fold_in(key, i),
                                       n_devices=6) for i in range(3)])
    res = allocate_fleet(batch, W, max_iters=8)
    assert res.counters is not None
    assert res.counters.data.shape == (3, 4)
    iters = np.asarray(res.counters.col("bcd_iters"))
    np.testing.assert_array_equal(iters, np.asarray(res.iters, float))
    assert np.all(np.asarray(res.counters.col("sp2_evals")) > 0)


def test_zero_iter_solve_counters():
    sysp = make_system(jax.random.PRNGKey(3), n_devices=6)
    res = allocate(sysp, W, max_iters=0)
    d = res.counters.as_dict()
    assert d["bcd_iters"] == 0 and d["sp1_evals"] == 0
    assert d["sp2_evals"] == 0 and math.isnan(d["residual"])


def test_rounds_ledger_sp2_evals_column():
    from repro.dynamics import RoundsConfig
    from repro.dynamics.config import ROUND_COLS

    assert ROUND_COLS[-1] == "sp2_evals"
    sysp = make_system(jax.random.PRNGKey(4), n_devices=6)
    cfg = RoundsConfig(rounds=3, bcd_iters=6)
    res = solve(Problem(system=sysp, weights=W, rounds=cfg,
                        key=jax.random.PRNGKey(5)))
    ev = np.asarray(res.ledger[:, ROUND_COLS.index("sp2_evals")])
    assert np.all(ev > 0)
    # warm-started re-allocation rounds must not cost more dual evals
    # than the cold round-0 solve (the warm-start attribution claim)
    assert np.all(ev[1:] <= ev[0])


# ---------------------------------------------------------------------------
# StageClocks: per-sample semantics + deprecated aggregate shims
# ---------------------------------------------------------------------------

def test_stage_clocks_samples_and_shims():
    clocks = StageClocks()
    clocks.record("plan", 0.002)
    clocks.record("plan", 0.004)
    assert clocks.samples("plan") == [0.002, 0.004]
    assert clocks.count("plan") == 2
    assert clocks.total("plan") == pytest.approx(0.006)
    # deprecated aggregate read
    assert clocks.plan_s == pytest.approx(0.006)
    # deprecated aggregate `+=` records the delta as one more sample
    clocks.plan_s += 0.003
    assert clocks.count("plan") == 3
    assert clocks.samples("plan")[-1] == pytest.approx(0.003)
    # historical as_dict key set is unchanged
    assert set(clocks.as_dict()) == {f"{s}_s" for s in StageClocks.STAGES}
    p = clocks.percentiles("plan")
    assert set(p) == {"p50", "p90", "p99"}
    assert 0.002 <= p["p50"] <= 0.004
    assert math.isnan(clocks.percentiles("gather")["p50"])


def test_stage_clocks_emit_obs_points():
    rec = obs.MemoryRecorder()
    obs.set_recorder(rec)
    clocks = StageClocks()
    clocks.record("dispatch", 0.001)
    obs.set_recorder(None)
    clocks.record("gather", 0.001)      # disabled again: no event
    stages = [e for e in rec.events if e["name"] == "stage"]
    assert len(stages) == 1
    assert stages[0]["stage"] == "dispatch"
    assert stages[0]["dur_s"] == pytest.approx(0.001)


# ---------------------------------------------------------------------------
# end-to-end: serve trace telemetry, determinism, jit-cache guard, overhead
# ---------------------------------------------------------------------------

_SPEC = SolverSpec(max_iters=4, tol=1e-4)


def _trace_events(cells, spec):
    rec = obs.MemoryRecorder()
    with obs.recording(rec):
        _serve(cells, spec)
    return rec.events


def test_serve_trace_emits_full_telemetry():
    cells = _mk_cells([5, 7, 8])
    events = _trace_events(cells, _SPEC)
    names = {e["name"] for e in events}
    assert {"solve", "plan", "dispatch", "materialize",
            "stage", "request"} <= names
    requests = [e for e in events if e["name"] == "request"]
    assert {e["cell_id"] for e in requests} == {c for c, _ in cells}
    for r in requests:
        for k in ("bucket", "warm", "iters", "converged", "batch_seq",
                  "bcd_iters", "sp1_evals", "sp2_evals", "residual",
                  "latency_s"):
            assert k in r, k
        assert r["bcd_iters"] == r["iters"]
        assert r["latency_s"] >= 0.0
    solves = [e for e in events if e["name"] == "solve"]
    assert all(e["topology"] in ("bcd_fleet", "bcd_region")
               for e in solves)


def test_same_seed_runs_emit_identical_streams():
    cells = _mk_cells([5, 7, 8, 9])
    ev1 = [obs.strip_timing(e) for e in _trace_events(cells, _SPEC)]
    ev2 = [obs.strip_timing(e) for e in _trace_events(cells, _SPEC)]
    assert ev1 == ev2
    assert len(ev1) > 0


def test_recorder_adds_no_compiled_shapes(compile_counter):
    cells = _mk_cells([5, 7, 8, 9], seed=7)
    # warm-up with the recorder OFF: all compilation happens here
    _serve(cells, _SPEC)
    _serve(cells, _SPEC)
    before = compile_counter.count
    _serve(cells, _SPEC)                       # recorder off
    with obs.recording(obs.MemoryRecorder()):  # recorder ON, same trace
        _serve(cells, _SPEC)
    assert compile_counter.count == before, (
        f"telemetry triggered {compile_counter.count - before} recompiles")


def test_noop_recorder_overhead_under_2_percent():
    """The disabled instrumentation sites must cost < 2% of serve wall
    time. Deterministically: measure the per-call cost of a disabled
    span()/point(), count how many telemetry events the same trace emits
    when enabled (an upper bound on disabled-path site hits), and compare
    the product against the measured serve wall time."""
    cells = _mk_cells([5, 7, 8, 9, 12, 16], seed=11)
    _serve(cells, _SPEC)           # compile + warm caches
    _serve(cells, _SPEC)

    t0 = time.perf_counter()
    _serve(cells, _SPEC)
    wall = time.perf_counter() - t0

    rec = obs.MemoryRecorder()
    with obs.recording(rec):
        _serve(cells, _SPEC)
    n_sites = len(rec.events)
    assert n_sites > 0

    reps = 20000
    t0 = time.perf_counter()
    for _ in range(reps):
        with obs.span("x"):
            pass
        obs.point("x")
    per_site = (time.perf_counter() - t0) / (2 * reps)

    overhead = n_sites * per_site
    assert overhead < 0.02 * wall, (
        f"no-op telemetry {overhead * 1e6:.1f}us over {n_sites} sites vs "
        f"{wall * 1e3:.1f}ms serve wall ({overhead / wall:.2%})")


# ---------------------------------------------------------------------------
# Histogram non-finite guard + background JsonlRecorder (PR 9 satellites)
# ---------------------------------------------------------------------------

def test_histogram_drops_non_finite():
    h = obs.Histogram("lat")
    h.observe(0.01)
    h.observe(float("nan"))
    h.observe(float("inf"))
    h.observe(float("-inf"))
    h.observe(0.02)
    assert h.count == 2
    assert h.dropped == 3
    assert h.sum == pytest.approx(0.03)
    assert math.isfinite(h.percentile(50))
    # the exporters surface the drop count instead of hiding it
    reg = obs.MetricsRegistry()
    hh = reg.histogram("lat")
    hh.observe(1.0)
    hh.observe(float("nan"))
    text = obs.prometheus_text(reg)
    assert "lat_dropped_total 1" in text
    rec = next(r for r in obs.metrics_jsonl(reg) if r["kind"] == "histogram")
    assert rec["dropped"] == 1


def test_histogram_observe_many_mixed_finiteness():
    h = obs.Histogram("lat")
    h.observe_many([0.001, float("nan"), 0.002, float("inf")])
    assert h.count == 2 and h.dropped == 2


def test_jsonl_recorder_background_flush(tmp_path):
    """Events written through the bounded queue land on disk, in emit
    order, once the recorder closes (recording() closes it)."""
    path = str(tmp_path / "bg.jsonl")
    with obs.recording(obs.JsonlRecorder(path)):
        for i in range(500):
            obs.point("evt", i=i)
    events = obs.read_jsonl(path)
    assert [e["i"] for e in events] == list(range(500))


def test_jsonl_recorder_drops_when_queue_full(tmp_path):
    """A stalled writer (deterministically held by the test gate) makes
    emits drop instead of blocking; the drops are counted locally and in
    the global obs_events_dropped counter; close() still flushes what
    queued."""
    path = str(tmp_path / "drop.jsonl")
    rec = obs.JsonlRecorder(path, queue_size=4)
    base = obs.counter("obs_events_dropped").value
    rec._drain_gate.clear()              # stall the writer
    # let the writer park on the gate holding one dequeued event
    rec.emit({"i": -1})
    deadline = time.perf_counter() + 5.0
    while rec._queue.qsize() and time.perf_counter() < deadline:
        time.sleep(0.001)
    for i in range(4):                   # refill the queue exactly
        rec.emit({"i": i})
    rec.emit({"i": 99})                  # queue full -> dropped
    rec.emit({"i": 100})
    assert rec.dropped_events == 2
    assert obs.counter("obs_events_dropped").value == base + 2
    rec._drain_gate.set()
    rec.close()
    got = [e["i"] for e in obs.read_jsonl(path)]
    assert got == [-1, 0, 1, 2, 3]
    rec.emit({"i": 101})                 # emit-after-close counts as drop
    assert rec.dropped_events == 3
