"""Diff a fresh benchmark --json artifact against a committed baseline.

    PYTHONPATH=src python -m benchmarks.compare FRESH.json BASELINE.json \
        [--factor 2.0] [--latency-factor 1.15] [--slo] [--strict]

Rows are matched by name; a fresh row slower than `factor` x the baseline
`us_per_call` emits a GitHub-Actions `::warning::` annotation (plain text on
a terminal). Non-blocking by design: the exit code is always 0 — this is a
perf-trajectory tripwire, not a gate (CI hosts differ from the recording
host, so absolute walls drift; >2x on the same row is worth a look).

Rows whose `derived` field carries `k=v;k=v` pairs get a second, tighter
check: histogram-derived `p50_ms`/`p99_ms` values regressing beyond
`latency_factor` (default 1.15) are flagged the same way. The latency
histograms use ~7%-wide buckets (`repro.obs.DEFAULT_BOUNDS`), so bucket
quantization alone can never trip the 15% gate.

`--slo` adds a verdict gate on the SLO-carrying rows (the `slo.*` bench
rows): a fresh row whose `slo_breaches` count exceeds the baseline's, or
whose `slo_<name>_ok` flag flipped 1 -> 0 (an objective that used to hold
now breaches), counts as a regression — warning by default, exit 1 under
`--strict` like every other regression.

`--strict` flips that: exit 1 when any row regresses beyond the factor (or
the artifacts are unreadable). It exists for the bench re-record protocol —
when BENCH_*.json is re-recorded on the SAME host (e.g. after the solve()
unification, median-of-3), the new artifact must show no per-row regression
beyond the tripwire against the committed one before replacing it.
"""
from __future__ import annotations

import json
import sys


def load_rows(path: str) -> dict:
    with open(path) as fh:
        data = json.load(fh)
    return {r["name"]: r for r in data.get("rows", [])}


def parse_derived(derived) -> dict:
    """The numeric pairs of a `derived` string: "p50_ms=40;req_s=1027.1;
    speedup_vs_sync=1.61x" -> {"p50_ms": 40.0, ...} (non-numeric and
    bare-string parts are skipped)."""
    out = {}
    for part in str(derived or "").split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k.strip()] = float(v.strip().rstrip("x"))
        except ValueError:
            pass
    return out


def slo_regressions(name: str, fd: dict, bd: dict) -> list:
    """SLO verdict regressions between one row's fresh/baseline derived
    pairs: more breaches than the baseline, or any `slo_*_ok` flag that
    flipped 1 -> 0. Returns human-readable descriptions (empty = ok)."""
    out = []
    if "slo_breaches" in fd and "slo_breaches" in bd \
            and fd["slo_breaches"] > bd["slo_breaches"]:
        out.append(f"slo_breaches {bd['slo_breaches']:.0f} -> "
                   f"{fd['slo_breaches']:.0f}")
    for key in sorted(bd):
        if key.startswith("slo_") and key.endswith("_ok") \
                and bd[key] >= 1.0 and fd.get(key, 1.0) < 1.0:
            out.append(f"{key} flipped 1 -> 0 ({name} now breaching)")
    return out


def compare(fresh_path: str, base_path: str, factor: float = 2.0,
            strict: bool = False, latency_factor: float = 1.15,
            slo: bool = False) -> int:
    try:
        fresh, base = load_rows(fresh_path), load_rows(base_path)
    except (OSError, ValueError, KeyError) as e:
        # stay non-blocking even when an artifact is missing or malformed
        # (e.g. the fresh bench step itself failed under continue-on-error)
        print(f"::warning::benchmarks.compare: cannot read artifacts: {e}")
        return 1 if strict else 0
    common = sorted(set(fresh) & set(base))
    if not common:
        print(f"::warning::benchmarks.compare: no common rows between "
              f"{fresh_path} and {base_path}")
        return 1 if strict else 0
    n_slow = 0
    for name in common:
        try:
            f_us = max(float(fresh[name]["us_per_call"]), 1.0)
            b_us = max(float(base[name]["us_per_call"]), 1.0)
        except (KeyError, TypeError, ValueError) as e:
            print(f"::warning::bench row {name}: malformed ({e})")
            continue
        ratio = f_us / b_us
        status = "ok"
        if ratio > factor:
            n_slow += 1
            status = "SLOW"
            print(f"::warning::bench row {name} regressed {ratio:.2f}x "
                  f"({b_us / 1e6:.2f}s -> {f_us / 1e6:.2f}s)")
        # histogram-derived latency gate: p50/p99 regress beyond
        # latency_factor (tighter than the wall tripwire — the fixed
        # bucket layout makes these comparable run-to-run)
        fd, bd = (parse_derived(fresh[name].get("derived")),
                  parse_derived(base[name].get("derived")))
        for key in ("p50_ms", "p99_ms"):
            if key in fd and bd.get(key, 0.0) > 0.0:
                lratio = fd[key] / bd[key]
                if lratio > latency_factor:
                    n_slow += 1
                    status = "SLOW"
                    print(f"::warning::bench row {name} {key} regressed "
                          f"{lratio:.2f}x ({bd[key]:.0f}ms -> "
                          f"{fd[key]:.0f}ms)")
        if slo:
            for msg in slo_regressions(name, fd, bd):
                n_slow += 1
                status = "SLOW"
                print(f"::warning::bench row {name} SLO regressed: {msg}")
        print(f"{name}: {ratio:.2f}x vs baseline [{status}]")
    only_base = sorted(set(base) - set(fresh))
    if only_base:
        print(f"baseline-only rows (not re-run): {', '.join(only_base)}")
        if strict:
            # a truncated fresh artifact (a bench step crashed mid-record)
            # must not replace a fuller baseline just because the rows that
            # DID record look fine
            print(f"::warning::--strict: fresh artifact is missing "
                  f"{len(only_base)} baseline row(s)")
            n_slow += len(only_base)
    print(f"# compared {len(common)} rows, {n_slow} regressed "
          f"beyond {factor:.1f}x or missing")
    return 1 if (strict and n_slow) else 0


def main() -> None:
    args = sys.argv[1:]
    factor = 2.0
    latency_factor = 1.15
    strict = "--strict" in args
    if strict:
        args.remove("--strict")
    slo = "--slo" in args
    if slo:
        args.remove("--slo")
    for flag, default in (("--factor", factor),
                          ("--latency-factor", latency_factor)):
        if flag not in args:
            continue
        i = args.index(flag)
        try:
            value = float(args[i + 1])
        except (IndexError, ValueError):
            if strict:
                # the gate must enforce the threshold the operator asked
                # for — a silent fallback would weaken it
                sys.exit(f"benchmarks.compare: bad {flag} value under "
                         "--strict")
            print(f"::warning::benchmarks.compare: bad {flag} value, "
                  f"using {default}")
            value = default
        args = args[:i] + args[i + 2:]
        if flag == "--factor":
            factor = value
        else:
            latency_factor = value
    if len(args) != 2:
        # still exit 0 unless --strict: must never break the CI pipeline
        print("::warning::usage: python -m benchmarks.compare FRESH.json "
              "BASELINE.json [--factor F] [--latency-factor L] [--slo] "
              "[--strict]")
        sys.exit(1 if strict else 0)
    sys.exit(compare(args[0], args[1], factor, strict, latency_factor,
                     slo))


if __name__ == "__main__":
    main()
