"""repro.region — region-scale sharded allocation service (beyond paper).

The paper solves one cell of N MAR devices; this package scales the
unified `repro.solve` dispatcher to a *region* — many heterogeneous cells,
millions of clients — in three layers:

  * mesh   (`region.mesh`):  shard the cell axis of a stacked fleet across
    a device mesh — set `Problem.mesh` (built with `region_mesh`) and
    `solve` runs the vmapped BCD under shard_map with shard-local
    convergence exit (`SolverSpec.lockstep=True` keeps the pure-jit GSPMD
    path). `allocate_region`/`run_rounds_region` survive as deprecated
    shims;
  * batch  (`region.batch`): pad mixed-size cell pools onto a power-of-two
    bucket menu with masked devices (`pad_system`, `bucket_size`) so real
    traffic compiles into a handful of shapes;
  * service (`region.service`): a streaming front-end (`RegionAllocator`)
    that coalesces allocation requests into bucketed shard-ready batches,
    warm-starts re-requests from an LRU cache of previous solutions, and
    takes PER-REQUEST `Weights` — a traced (C, 3) operand of the one
    compiled solve, so a mixed-demand region costs zero extra compiles
    (the jit-cache key is `SolverSpec` + the bucket menu, nothing else).

CPU dev recipe: XLA_FLAGS=--xla_force_host_platform_device_count=8 makes
one host expose 8 devices for the mesh (see ROADMAP "Region service").
"""
from .batch import bucket_size, pad_allocation, pad_system
from .mesh import (RegionResult, allocate_region, cell_specs, pad_cells,
                   place_cells, region_mesh, run_rounds_region)
from .service import AllocationRequest, CellResponse, RegionAllocator

__all__ = [
    "bucket_size", "pad_allocation", "pad_system",
    "RegionResult", "allocate_region", "cell_specs", "pad_cells",
    "place_cells", "region_mesh", "run_rounds_region",
    "AllocationRequest", "CellResponse", "RegionAllocator",
]
