"""Fallback shims so property tests degrade to skips when `hypothesis` is not
installed (minimal containers). Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, st
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def shim():
            pytest.skip("hypothesis not installed")
        shim.__name__ = fn.__name__
        shim.__doc__ = fn.__doc__
        return shim
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategies:
    """Any strategy constructor resolves to a no-op placeholder."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
