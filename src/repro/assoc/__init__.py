"""repro.assoc — cross-cell user association (BCD-over-association).

The multi-cell scenario axis (arXiv:2212.08324 / 2301.12085): devices
pick their serving cell. An association step (greedy marginal-cost cell
choice under per-cell capacity caps) alternates with per-cell resource
re-solves through the one `solve()` dispatcher; a stacked (C, N) system
plus `Problem.assoc = AssocConfig(...)` routes it.

Public API:
    AssocConfig, AssocResult        outer-loop knobs / outcome
    solve_assoc                     the outer loop (direct entry; `solve`
                                    delegates here on Problem.assoc)
    nearest_assignment              the static strongest-gain baseline
    make_multicell, bs_grid,        shared-geometry scenario builders
    cross_gains
"""
from .config import AssocConfig, AssocResult
from .loop import (greedy_assign, marginal_costs, nearest_assignment,
                   solve_assoc)
from .scenario import bs_grid, cross_gains, make_multicell

__all__ = ["AssocConfig", "AssocResult", "solve_assoc",
           "nearest_assignment", "greedy_assign", "marginal_costs",
           "bs_grid", "cross_gains", "make_multicell"]
