"""Padding/masking invariants of the region batching layer (region.batch).

The service pads every cell pool to a power-of-two bucket with masked
devices; the whole design rests on the claim that padding is *invisible* to
the real devices. Three layers of checks:

  * bit-identity: with the default (direct) SP2 engine, the active prefix
    of a padded solve — per-device B/p/f/s AND the iteration trajectory —
    is bit-identical to the unpadded solve, across sweep/bisect SP1 and
    f32/f64. (The reported ledger *scalars* may differ by ~1 ulp: XLA's
    reduce association changes with the padded shape, so sums of the same
    active values plus zero lanes can round differently. They are checked
    to ulp-scale tolerance instead.)
  * KKT/feasibility on the active prefix: budget, boxes, menu membership,
    and SP1 dual feasibility Sigma lambda = w2 Rg at the returned deadline.
  * neutrality of the pad lanes themselves: B = 0 exactly, zero energy.

Deterministic cases run everywhere; the hypothesis sweep degrades to a
skip via tests/_hypothesis_stub.py when hypothesis is absent.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.core import Weights, allocate, feasible, make_system
from repro.core.accuracy import default_accuracy
from repro.core.energy import e_cmp, e_trans
from repro.core.sp1 import _coeffs, _lambda_of_T, _sp1_bounds
from repro.region.batch import bucket_size, pad_allocation, pad_system

_FIELDS = ("bandwidth", "power", "freq", "resolution")


def _cast(sysp, dtype):
    return jax.tree_util.tree_map(lambda x: jnp.asarray(x, dtype), sysp)


def _prefix_bit_identical(res, res_pad, n):
    for f in _FIELDS:
        a = np.asarray(getattr(res.allocation, f))
        b = np.asarray(getattr(res_pad.allocation, f))[:n]
        np.testing.assert_array_equal(a, b, err_msg=f"active prefix of {f}")
    assert res.iters == res_pad.iters
    assert res.converged == res_pad.converged


def _scalars_ulp_close(res, res_pad, dtype):
    # reduce-association tolerance: ~a few ulps of the solve dtype
    rel = 64 * float(jnp.finfo(dtype).eps)
    assert res_pad.objective == pytest.approx(res.objective, rel=rel)


def _pad_lanes_neutral(sysp_pad, res_pad, n):
    B = np.asarray(res_pad.allocation.bandwidth)[n:]
    np.testing.assert_array_equal(B, np.zeros_like(B))
    e = np.asarray(
        e_trans(sysp_pad, res_pad.allocation.bandwidth,
                res_pad.allocation.power)
        + e_cmp(sysp_pad, res_pad.allocation.freq,
                res_pad.allocation.resolution))[n:]
    np.testing.assert_array_equal(e, np.zeros_like(e))


def _check_prefix_kkt(sysp, w, res_pad, n, lam_tol=1e-3):
    """Feasibility + SP1 dual feasibility of the active prefix, evaluated
    on the UNPADDED system (the prefix is what the cell actually gets)."""
    alloc = jax.tree_util.tree_map(
        lambda x: x[:n] if jnp.ndim(x) else x, res_pad.allocation)
    assert feasible(sysp, alloc)
    w = w.normalized()
    acc = default_accuracy()
    from repro.core.energy import rate

    tt = sysp.bits / jnp.maximum(
        rate(sysp, alloc.bandwidth, alloc.power), 1e-12)
    _, q = _coeffs(sysp, w)
    f = np.asarray(alloc.freq)
    s_hat = np.asarray(res_pad.allocation.s_relaxed)[:n]
    mk_hat = np.asarray(q) * s_hat ** 2 / np.maximum(f, 1e-9) + np.asarray(tt)
    lam_hi, target, T_lo, _ = _sp1_bounds(sysp, w, q, tt)
    lam = _lambda_of_T(sysp, w, acc, jnp.asarray(mk_hat.max()), tt,
                       float(lam_hi))
    total, target = float(jnp.sum(lam)), float(target)
    if mk_hat.max() <= float(T_lo) * (1 + 1e-9):
        assert total <= target * (1 + lam_tol)
    else:
        assert total == pytest.approx(target, rel=lam_tol)


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
@pytest.mark.parametrize("sp1_method", ["sweep", "bisect"])
def test_padding_bit_identical_active_prefix(dtype, sp1_method):
    n, n_pad = 7, 16
    sysp = _cast(make_system(jax.random.PRNGKey(3), n_devices=n), dtype)
    w = Weights(0.5, 0.5, 5.0)
    res = allocate(sysp, w, max_iters=6, sp1_method=sp1_method)
    spad = pad_system(sysp, n_pad)
    res_pad = allocate(spad, w, max_iters=6, sp1_method=sp1_method)
    _prefix_bit_identical(res, res_pad, n)
    _scalars_ulp_close(res, res_pad, dtype)
    _pad_lanes_neutral(spad, res_pad, n)
    _check_prefix_kkt(sysp, w, res_pad, n)


def test_pad_to_same_size_attaches_mask_only():
    """n_pad == N: the solve must be untouched, the mask all-True."""
    n = 6
    sysp = make_system(jax.random.PRNGKey(5), n_devices=n)
    spad = pad_system(sysp, n)
    assert spad.active is not None and bool(jnp.all(spad.active))
    w = Weights(0.5, 0.5, 1.0)
    res = allocate(sysp, w, max_iters=5)
    res_pad = allocate(spad, w, max_iters=5)
    _prefix_bit_identical(res, res_pad, n)


@pytest.mark.parametrize("sp2_method", ["jong"])
def test_padding_jong_engine_close(sp2_method):
    """The paper-literal Algorithm 1 engine is not bit-stable under padding
    (its damped dual trajectory feels the reduce association through the
    backtracking norms) but must stay finite and land at the same point."""
    n = 7
    sysp = make_system(jax.random.PRNGKey(3), n_devices=n)
    w = Weights(0.5, 0.5, 5.0)
    res = allocate(sysp, w, max_iters=6, sp2_method=sp2_method)
    res_pad = allocate(pad_system(sysp, 12), w, max_iters=6,
                       sp2_method=sp2_method)
    np.testing.assert_allclose(
        np.asarray(res_pad.allocation.bandwidth)[:n],
        np.asarray(res.allocation.bandwidth), rtol=1e-4)
    assert res_pad.objective == pytest.approx(res.objective, rel=1e-6)


def test_warm_start_padding_parity():
    """pad_allocation fills pad lanes at the masked fixed point, so a padded
    warm re-solve matches the unpadded warm re-solve bit for bit."""
    n, n_pad = 12, 16
    sysp = make_system(jax.random.PRNGKey(40), n_devices=n)
    w = Weights(0.5, 0.5, 1.0)
    base = allocate(sysp, w, max_iters=40, tol=1e-8)
    assert base.converged
    bump = 1.0 + 0.02 * jnp.sin(jnp.arange(float(n)))
    sys2 = sysp.replace(gain=sysp.gain * bump)
    warm = allocate(sys2, w, max_iters=40, tol=1e-8, init=base.allocation)
    spad = pad_system(sys2, n_pad)
    init_pad = pad_allocation(base.allocation, n_pad, spad)
    warm_pad = allocate(spad, w, max_iters=40, tol=1e-8, init=init_pad)
    _prefix_bit_identical(warm, warm_pad, n)
    assert warm_pad.iters <= 3   # the service warm-hit acceptance bound


def test_keep_history_false_skips_ledger_materialization():
    """allocate(keep_history=False): no history rows, same objective (the
    service hot path skips the device->host ledger copy)."""
    sysp = make_system(jax.random.PRNGKey(2), n_devices=6)
    w = Weights(0.5, 0.5, 1.0)
    full = allocate(sysp, w, max_iters=6)
    lean = allocate(sysp, w, max_iters=6, keep_history=False)
    assert lean.history == []
    assert lean.objective == full.objective
    assert lean.iters == full.iters and lean.converged == full.converged
    # max_iters=0 stays nan, not an IndexError
    empty = allocate(sysp, w, max_iters=0, keep_history=False)
    assert empty.history == [] and np.isnan(empty.objective)


def test_bucket_size_policy():
    assert bucket_size(1, min_bucket=16) == 16
    assert bucket_size(16, min_bucket=16) == 16
    assert bucket_size(17, min_bucket=16) == 32
    assert bucket_size(50) == 64
    assert bucket_size(65) == 128
    assert bucket_size(2048) == 2048
    with pytest.raises(ValueError):
        bucket_size(0)
    # a 1..1024 device-count trace needs at most 5 compiled shapes
    assert len({bucket_size(n) for n in range(1, 1025)}) == 5


def test_pad_system_validates():
    sysp = make_system(jax.random.PRNGKey(0), n_devices=5)
    with pytest.raises(ValueError):
        pad_system(sysp, 4)
    spad = pad_system(sysp, 9)
    assert spad.n == 9
    assert np.asarray(spad.active).tolist() == [True] * 5 + [False] * 4
    np.testing.assert_array_equal(np.asarray(spad.bits)[5:], 0.0)
    # re-padding a padded system keeps the original mask prefix
    spad2 = pad_system(spad, 12)
    assert np.asarray(spad2.active).tolist() == [True] * 5 + [False] * 7


# ---------------------------------------------------------------------------
# hypothesis property sweep (skips when hypothesis is absent)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 10), pad=st.integers(1, 12),
       w1=st.floats(0.05, 0.95), rho=st.floats(0.0, 30.0),
       seed=st.integers(0, 15), sp1=st.sampled_from(["sweep", "bisect"]))
def test_padding_property(n, pad, w1, rho, seed, sp1):
    sysp = make_system(jax.random.PRNGKey(seed), n_devices=n)
    w = Weights(w1, 1.0 - w1, rho)
    res = allocate(sysp, w, max_iters=6, sp1_method=sp1)
    res_pad = allocate(pad_system(sysp, n + pad), w, max_iters=6,
                       sp1_method=sp1)
    _prefix_bit_identical(res, res_pad, n)
    _scalars_ulp_close(res, res_pad, jnp.float64)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(3, 8), pad=st.integers(1, 8), seed=st.integers(0, 7))
def test_padding_property_f32(n, pad, seed):
    sysp = _cast(make_system(jax.random.PRNGKey(seed), n_devices=n),
                 jnp.float32)
    w = Weights(0.5, 0.5, 5.0)
    res = allocate(sysp, w, max_iters=6)
    res_pad = allocate(pad_system(sysp, n + pad), w, max_iters=6)
    _prefix_bit_identical(res, res_pad, n)
    _scalars_ulp_close(res, res_pad, jnp.float32)
