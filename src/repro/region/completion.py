"""Completion layer: materialize in-flight batches into `CellResponse`s.

The only blocking device->host transfer of the whole pipeline happens
here, once per batch: wait for the batch's device arrays, gather the
scalar fields (iters/converged/objective) in one `np.asarray` each, slice
every real lane's allocation back to its unpadded (N,) shape, write the
solutions into the warm-start cache, and resolve the batch's
`PendingResponse` futures.

`PendingResponse` is the caller-facing future: `result()` materializes on
demand (forcing dispatch first if the request is still queued), so callers
can hold responses from several in-flight batches and consume them in any
order — materializing batch k+2 never waits on batch k.

Stage clocks: the in-flight window (dispatch -> compute observed ready,
an upper bound measured at the first blocking poll) is recorded as a
per-batch "device" sample; the host-side gather/slice/cache-write time as
a "gather" sample (see `StageClocks`).

Telemetry: with a `repro.obs` recorder enabled, materializing a batch
emits one "request" point per real lane carrying the cell id, warm/bucket
facts, the solve's device counters (BCD iterations, SP1/SP2 dual evals,
residual), the end-to-end `latency_s` (submit -> materialize; wall-clock
— meaningful when the admission clock is the default `time.monotonic`),
and — for deadlined requests — `deadline_hit` (same clock caveat).

The always-on metric plane is fed here too (the SLO plane's inputs): per
batch, `region_solve_cells` / `region_solve_converged_cells` counters,
the `region_request_latency_seconds` histogram, deadline hit/miss/request
counters, and the summed solver-effort counters
(`region_solver_{bcd_iters,sp1_evals,sp2_evals}`). The packed (C, 4)
counter matrix costs ONE extra host transfer per batch — a few hundred
bytes read after the batch is already blocked on — and the same sums land
in `RegionPipeline.stats["solver_counters"]` when the pipeline passes its
stats dict in.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Hashable, List, Optional

import jax
import numpy as np

from repro import obs
from repro.core.types import Allocation

from .admission import AllocationRequest, StageClocks
from .dispatch import InFlightBatch
from .planning import WarmStartCache


@dataclasses.dataclass
class CellResponse:
    cell_id: Hashable
    allocation: Allocation   # unpadded (N,) leaves
    objective: float
    iters: int
    converged: bool
    warm: bool               # served from the warm-start cache
    bucket: int              # padded device count this cell solved at


class PendingResponse:
    """A future for one request's `CellResponse`.

    Lifecycle: queued (in admission) -> in flight (bound to a dispatched
    batch) -> done. `result()` drives whatever remains: a queued request
    force-pumps the pipeline, an in-flight one materializes only its own
    batch."""

    def __init__(self, request: AllocationRequest, pipeline):
        self.request = request
        self.cell_id = request.cell_id
        self.t_enqueue: Optional[float] = None   # set at admission
        self._pipeline = pipeline
        self._batch: Optional[InFlightBatch] = None
        self._lane: int = -1
        self._response: Optional[CellResponse] = None

    @property
    def dispatched(self) -> bool:
        return self._batch is not None

    def done(self) -> bool:
        return self._response is not None

    def result(self) -> CellResponse:
        if self._response is None:
            self._pipeline._force(self)
        assert self._response is not None
        return self._response

    def _bind(self, batch: InFlightBatch, lane: int) -> None:
        self._batch = batch
        self._lane = lane
        batch.pending.append(self)


def materialize(batch: InFlightBatch, cache: WarmStartCache,
                clocks: StageClocks,
                stats: Optional[dict] = None) -> List[CellResponse]:
    """Gather one batch host-side and resolve its futures (idempotent).
    `stats`, when given (the pipeline's dict), accumulates solver-effort
    counter sums and deadline/convergence tallies alongside the metric
    registry."""
    if batch.materialized:
        return [p._response for p in batch.pending]
    plan, res = batch.plan, batch.result
    t0 = time.monotonic()
    jax.block_until_ready(res.allocation.bandwidth)
    t1 = time.monotonic()
    clocks.record("device", max(0.0, t1 - batch.t_dispatched))
    # one host transfer per field for the whole batch, then pure-numpy
    # slicing: enqueueing jnp slice ops here would append them to the TAIL
    # of the device stream — behind the next in-flight batch's solve — and
    # re-serialize exactly the pipeline this layer exists to overlap
    iters = np.asarray(res.iters)
    conv = np.asarray(res.converged)
    objs = np.asarray(res.objective)
    a = res.allocation
    bw, pw = np.asarray(a.bandwidth), np.asarray(a.power)
    fq, sr = np.asarray(a.freq), np.asarray(a.resolution)
    s_rel = None if a.s_relaxed is None else np.asarray(a.s_relaxed)
    T = None if a.T is None else np.asarray(a.T)
    responses: List[CellResponse] = []
    for c, (r, hit) in enumerate(zip(plan.requests, plan.warm)):
        n = r.sys.n
        alloc = Allocation(
            bandwidth=bw[c, :n], power=pw[c, :n],
            freq=fq[c, :n], resolution=sr[c, :n],
            s_relaxed=None if s_rel is None else s_rel[c, :n],
            T=None if T is None else T[c])
        cache.store(r.cell_id, n, alloc)
        responses.append(CellResponse(
            cell_id=r.cell_id, allocation=alloc,
            objective=float(objs[c]), iters=int(iters[c]),
            converged=bool(conv[c]), warm=hit, bucket=plan.bucket))
    # the packed (C, 4) counter matrix: one small host transfer per batch
    # (the batch is already blocked on above), feeding the always-on SLO
    # metrics, the pipeline stats, and — while recording — request points
    ctr = None if res.counters is None else np.asarray(res.counters.data)
    ccols = None if res.counters is None else res.counters.columns
    t_done = time.monotonic()
    n_real = len(plan.requests)
    _record_metrics(batch, ctr, ccols, conv, n_real, t_done, stats)
    if obs.enabled():
        for pending in batch.pending:
            r = responses[pending._lane]
            fields = dict(cell_id=str(r.cell_id), bucket=r.bucket,
                          warm=r.warm, iters=r.iters,
                          converged=r.converged, batch_seq=batch.seq)
            if ctr is not None:
                fields.update({c: float(v) for c, v in
                               zip(ccols, ctr[pending._lane])})
            if pending.t_enqueue is not None:
                fields["latency_s"] = max(0.0, t_done - pending.t_enqueue)
            if pending.request.deadline is not None:
                fields["deadline_hit"] = bool(
                    t_done <= pending.request.deadline)
            obs.point("request", **fields)
    for pending in batch.pending:
        pending._response = responses[pending._lane]
    batch.materialized = True
    clocks.record("gather", time.monotonic() - t1)
    return responses


def _record_metrics(batch: InFlightBatch, ctr, ccols,
                    conv: np.ndarray, n_real: int, t_done: float,
                    stats: Optional[dict]) -> None:
    """Always-on metric-plane accounting for one materialized batch: the
    counters/histograms the SLO plane (`obs.slo.default_slos`) evaluates.
    Deadline hits compare `time.monotonic()` against the request deadline
    — meaningful when the admission clock is the default one (the same
    caveat the `latency_s` event field carries)."""
    conv_real = int(np.sum(conv[:n_real]))
    obs.counter("region_solve_cells").inc(n_real)
    obs.counter("region_solve_converged_cells").inc(conv_real)
    lat_h = obs.histogram("region_request_latency_seconds")
    dl_hits = dl_total = 0
    for pending in batch.pending:
        if pending.t_enqueue is not None:
            lat_h.observe(max(0.0, t_done - pending.t_enqueue))
        if pending.request.deadline is not None:
            dl_total += 1
            dl_hits += bool(t_done <= pending.request.deadline)
    if dl_total:
        obs.counter("region_deadline_requests").inc(dl_total)
        obs.counter("region_deadline_hits").inc(dl_hits)
        obs.counter("region_deadline_misses").inc(dl_total - dl_hits)
    sums = {}
    if ctr is not None:
        real = ctr[:n_real]
        for i, col in enumerate(ccols):
            if col == "residual":
                continue
            s = float(np.nansum(real[:, i]))
            sums[col] = s
            obs.counter(f"region_solver_{col}").inc(s)
    if stats is not None:
        stats["cells_solved"] = stats.get("cells_solved", 0) + n_real
        stats["cells_converged"] = (stats.get("cells_converged", 0)
                                    + conv_real)
        stats["deadline_requests"] = (stats.get("deadline_requests", 0)
                                      + dl_total)
        stats["deadline_hits"] = stats.get("deadline_hits", 0) + dl_hits
        agg = stats.setdefault("solver_counters", {})
        for col, s in sums.items():
            agg[col] = agg.get(col, 0.0) + s
