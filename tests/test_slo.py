"""SLO plane + wire surface + profiling (PR 9): burn-rate window math on
logical clocks, the latency-threshold bucket snap, the HTTP scrape
endpoints round-tripped through the Prometheus text parser, SLO verdicts
over a live pipelined serve trace, the zero-new-compiles guard with the
whole plane installed, and the XLA trace/cost profiling helpers.
"""
import json
import math
import os
import time
import urllib.error
import urllib.request
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from repro import (AllocationRequest, Problem, RegionAllocator, SolverSpec,
                   Weights, make_fleet, make_system, obs)
from repro.obs import (BurnWindow, DEFAULT_WINDOWS, LatencyObjective,
                       MetricsServer, RatioObjective, SLO, SloPlane,
                       default_slos, parse_prometheus_text,
                       prometheus_text)

W = Weights(0.5, 0.5, 1.0)
_SPEC = SolverSpec(max_iters=4, tol=1e-4)


def _get(url: str):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.status, resp.headers.get("Content-Type"), resp.read()


# ---------------------------------------------------------------------------
# burn-rate window math (logical clocks, exact values)
# ---------------------------------------------------------------------------

_WINDOWS = (BurnWindow("fast", 10.0, 1.0), BurnWindow("slow", 100.0, 0.5))


def _ratio_plane(objective=0.9):
    reg = obs.MetricsRegistry()
    slo = SLO("hit_rate", objective,
              RatioObjective("good", "total"), _WINDOWS)
    return reg, SloPlane([slo], registry=reg)


def test_burn_rate_exact_and_multi_window_and():
    # objective 0.5: the error budget is exactly representable, so the
    # burn == max_burn_rate boundary below is exact, not epsilon-luck
    reg, plane = _ratio_plane(objective=0.5)
    plane.observe(now=0.0)
    reg.counter("total").inc(100)
    reg.counter("good").inc(75)           # bad ratio 0.25 -> burn 0.5
    [v] = plane.check(now=10.0)
    by = {w["name"]: w for w in v["windows"]}
    assert by["fast"]["burn_rate"] == pytest.approx(0.5)
    assert by["slow"]["burn_rate"] == pytest.approx(0.5)
    # breach is strict: burn == max_burn_rate (slow: 0.5) is not a breach
    assert not by["slow"]["breach"] and v["verdict"] == "ok"
    assert v["good_ratio"] == pytest.approx(0.75)
    assert v["budget_remaining"] == pytest.approx(0.5)

    reg.counter("total").inc(100)         # all 100 bad: cumulative 125/200
    [v] = plane.check(now=12.0)
    by = {w["name"]: w for w in v["windows"]}
    # fast window start t=2: nearest sample not newer is t=0 (all history)
    assert by["fast"]["burn_rate"] == pytest.approx(1.25)
    assert by["fast"]["breach"] and by["slow"]["breach"]
    assert v["verdict"] == "breach"
    assert reg.gauge("slo_breaching", slo="hit_rate").value == 1.0
    assert reg.gauge("slo_burn_rate", slo="hit_rate",
                     window="fast").value == pytest.approx(1.25)


def test_burn_rate_windows_difference_correct_samples():
    """The fast window must difference against the newest sample at least
    `seconds` old — NOT the whole history — once the ring spans it."""
    reg, plane = _ratio_plane()
    plane.observe(now=0.0)
    reg.counter("total").inc(100)         # 100 bad before t=50
    plane.observe(now=50.0)
    reg.counter("total").inc(100)
    reg.counter("good").inc(100)          # 100 good after t=50
    [v] = plane.check(now=61.0)
    by = {w["name"]: w for w in v["windows"]}
    # fast (start 51): delta vs the t=50 sample -> all good, burn 0
    assert by["fast"]["burn_rate"] == pytest.approx(0.0)
    # slow (start -39): falls back to the oldest sample -> 100/200 bad
    assert by["slow"]["burn_rate"] == pytest.approx(5.0)
    # warn: some but not all windows breach
    assert v["verdict"] == "warn"
    assert reg.gauge("slo_breaching", slo="hit_rate").value == 0.0


def test_no_data_and_idle_traffic_verdicts():
    reg, plane = _ratio_plane()
    [v] = plane.check(now=0.0)
    assert v["verdict"] == "no_data"
    assert v["good_ratio"] is None and v["budget_remaining"] is None
    assert all(w["burn_rate"] == 0.0 for w in v["windows"])
    reg.counter("total").inc(10)
    reg.counter("good").inc(10)
    [v] = plane.check(now=1.0)
    assert v["verdict"] == "ok"
    # traffic stops: every later window burns at 0, verdict stays ok
    [v] = plane.check(now=500.0)
    assert v["verdict"] == "ok"
    assert all(w["burn_rate"] == 0.0 for w in v["windows"])


def test_slo_validation():
    with pytest.raises(ValueError):
        SLO("bad", 1.0, RatioObjective("g", "t"))
    with pytest.raises(ValueError):
        SloPlane([SLO("dup", 0.9, RatioObjective("g", "t")),
                  SLO("dup", 0.9, RatioObjective("g2", "t2"))])


def test_latency_objective_threshold_snaps_up():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat")
    bounds = h.bounds
    i = int(np.searchsorted(bounds, 0.5))
    edge = bounds[i]                      # the snapped threshold
    assert edge >= 0.5 and edge / 0.5 < 1.08
    h.observe(edge * 0.999)               # good under the snapped edge
    h.observe(edge * 1.001)               # bad: next bucket up
    h.observe(0.001)
    obj = LatencyObjective("lat", 0.5)
    good, total = obj.counts(reg)
    assert (good, total) == (2.0, 3.0)
    # a threshold above the whole layout counts everything good
    good, total = LatencyObjective("lat", bounds[-1] * 10).counts(reg)
    assert (good, total) == (3.0, 3.0)


def test_default_slos_shape():
    slos = default_slos()
    assert [s.name for s in slos] == ["serve_latency_p99",
                                      "deadline_hit_rate",
                                      "bcd_convergence"]
    assert all(s.windows == DEFAULT_WINDOWS for s in slos)


# ---------------------------------------------------------------------------
# wire surface: scrape endpoints + Prometheus text parser round-trip
# ---------------------------------------------------------------------------

def test_http_scrape_roundtrip():
    reg = obs.MetricsRegistry()
    reg.counter("req", stage="plan").inc(3)
    reg.gauge("depth").set(2)
    reg.histogram("lat").observe_many([0.001, 0.004, 2.0])
    with MetricsServer(registry=reg) as srv:
        status, ctype, body = _get(srv.url("/metrics"))
        assert status == 200 and ctype.startswith("text/plain")
        parsed = parse_prometheus_text(body.decode())
        # the scrape's own counter is in the scrape it served
        assert parsed[("obs_scrapes_total",
                       (("path", "/metrics"),))] == 1.0
        # byte-for-byte agreement with the in-process exporter
        assert parsed == parse_prometheus_text(prometheus_text(reg))
        assert parsed[("req_total", (("stage", "plan"),))] == 3.0
        assert parsed[("lat_count", ())] == 3.0

        status, ctype, body = _get(srv.url("/healthz"))
        hz = json.loads(body)
        assert status == 200 and hz["status"] == "ok"
        assert hz["uptime_s"] >= 0.0

        status, _, body = _get(srv.url("/slo"))
        assert status == 200 and json.loads(body) == {"slos": []}

        with pytest.raises(urllib.error.HTTPError) as err:
            _get(srv.url("/nope"))
        assert err.value.code == 404
        assert "/metrics" in err.value.read().decode()
    assert not srv.running


def test_http_slo_endpoint_serves_verdicts():
    reg, plane = _ratio_plane()
    reg.counter("total").inc(50)
    reg.counter("good").inc(49)
    with MetricsServer(registry=reg, slo_plane=plane) as srv:
        _, _, body = _get(srv.url("/slo"))
        slos = json.loads(body)["slos"]
        assert [s["name"] for s in slos] == ["hit_rate"]
        assert slos[0]["verdict"] in ("ok", "warn", "breach")
        assert slos[0]["total"] == 50.0
        # the check() behind the scrape published its gauges too
        _, _, body = _get(srv.url("/metrics"))
        parsed = parse_prometheus_text(body.decode())
        assert ("slo_good_ratio", (("slo", "hit_rate"),)) in parsed


def test_parse_prometheus_text_rejects_garbage():
    assert parse_prometheus_text("# HELP x\n\n") == {}
    with pytest.raises(ValueError):
        parse_prometheus_text("!!! not a sample line\n")


# ---------------------------------------------------------------------------
# live pipelined serve: SLO verdicts + scrape during traffic, compile guard
# ---------------------------------------------------------------------------

def _mk_cells(sizes, seed=0):
    key = jax.random.PRNGKey(seed)
    return [(f"cell{i}-{n}", make_system(jax.random.fold_in(key, i),
                                         n_devices=n))
            for i, n in enumerate(sizes)]


def _serve_deadlined(cells, deadline_slack=60.0):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        svc = RegionAllocator(W, cells_per_batch=2, min_bucket=8,
                              spec=_SPEC)
        now = time.monotonic()
        for cid, s in cells:
            svc.submit(AllocationRequest(cell_id=cid, sys=s,
                                         deadline=now + deadline_slack))
        return svc.flush()


def test_live_serve_slo_verdicts_and_scrape():
    cells = _mk_cells([5, 7, 8, 9], seed=3)
    plane = SloPlane(default_slos())      # global registry: the real wiring
    plane.observe()
    base = obs.counter("region_deadline_requests").value
    with MetricsServer(slo_plane=plane) as srv:
        responses = _serve_deadlined(cells)
        assert len(responses) == len(cells)
        _, _, body = _get(srv.url("/metrics"))
        parsed = parse_prometheus_text(body.decode())
        assert parsed[("region_deadline_requests_total", ())] \
            == base + len(cells)
        assert parsed[("region_solve_cells_total", ())] > 0
        _, _, body = _get(srv.url("/slo"))
        slos = {s["name"]: s for s in json.loads(body)["slos"]}
        assert set(slos) == {"serve_latency_p99", "deadline_hit_rate",
                             "bcd_convergence"}
        dl = slos["deadline_hit_rate"]
        assert dl["total"] >= len(cells) and dl["verdict"] != "no_data"
        assert slos["bcd_convergence"]["verdict"] != "no_data"
        for s in slos.values():
            for w in s["windows"]:
                assert math.isfinite(w["burn_rate"])


def test_pipeline_stats_carry_solver_and_deadline_tallies():
    cells = _mk_cells([5, 7], seed=5)
    svc_stats = None
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        svc = RegionAllocator(W, cells_per_batch=2, min_bucket=8,
                              spec=_SPEC)
        now = time.monotonic()
        svc.submit(AllocationRequest(cell_id=cells[0][0], sys=cells[0][1],
                                     deadline=now + 60.0))
        svc.submit(AllocationRequest(cell_id=cells[1][0], sys=cells[1][1],
                                     deadline=now - 1.0))   # already late
        svc.flush()
        svc_stats = svc.stats
    assert svc_stats["cells_solved"] == 2
    assert 0 <= svc_stats["cells_converged"] <= 2
    assert svc_stats["deadline_requests"] == 2
    assert svc_stats["deadline_hits"] == 1
    ctr = svc_stats["solver_counters"]
    assert ctr["bcd_iters"] > 0 and ctr["sp2_evals"] > 0


def test_slo_plane_and_scrape_add_no_compiles(compile_counter):
    cells = _mk_cells([5, 7, 8, 9], seed=7)
    _serve_deadlined(cells)               # warm-up: all compilation here
    _serve_deadlined(cells)
    before = compile_counter.count
    plane = SloPlane(default_slos())
    with MetricsServer(slo_plane=plane) as srv:
        plane.observe()
        _serve_deadlined(cells)
        _get(srv.url("/metrics"))
        _get(srv.url("/slo"))
        plane.check()
    assert compile_counter.count == before, (
        f"SLO/scrape plane triggered {compile_counter.count - before} "
        f"recompiles")


# ---------------------------------------------------------------------------
# profiling plane: trace sessions + compiled-cost gauges
# ---------------------------------------------------------------------------

def test_profile_trace_session(tmp_path):
    import jax.numpy as jnp
    from repro.obs import profile

    reg = obs.MetricsRegistry()
    logdir = str(tmp_path / "trace")
    rec = obs.MemoryRecorder()
    with obs.recording(rec):
        with profile.trace(logdir, label="unit", registry=reg) as d:
            assert d == logdir
            with profile.trace(logdir, registry=reg) as nested:
                assert nested is None     # one session at a time
            jnp.dot(jnp.ones((32, 32)), jnp.ones((32, 32))).block_until_ready()
    assert reg.gauge("profiler_trace_seconds", label="unit").value > 0.0
    assert reg.counter("profiler_traces").value == 1.0
    assert any(e["name"] == "profile" for e in rec.events)
    assert os.listdir(logdir)             # the trace artifact exists


def test_record_cost_gauges(tmp_path):
    import jax.numpy as jnp
    from repro.obs import profile

    reg = obs.MetricsRegistry()

    def f(x):
        return jnp.dot(x, x)

    cost = profile.record_cost("dot.64", f, jnp.ones((64, 64)),
                               registry=reg)
    if cost is None:
        pytest.skip("backend has no cost model")
    assert cost["flops"] > 0
    assert reg.gauge("xla_cost_flops", shape="dot.64").value == cost["flops"]
    assert reg.gauge("xla_cost_bytes",
                     shape="dot.64").value == cost["bytes_accessed"]


def test_solve_cost_shapes_and_guardrails():
    from repro.dynamics import RoundsConfig
    from repro.obs import profile

    reg = obs.MetricsRegistry()
    sysp = make_system(jax.random.PRNGKey(0), n_devices=6)
    cost = profile.solve_cost(Problem(system=sysp, weights=W),
                              spec=_SPEC, registry=reg)
    if cost is not None:
        assert cost["flops"] > 0
        assert reg.gauge("xla_cost_flops", shape="solve.bcd.N6").value > 0

    fleet = make_fleet(jax.random.PRNGKey(1), n_cells=3, n_devices=6)
    cost = profile.solve_cost(Problem(system=fleet, weights=W),
                              spec=_SPEC, registry=reg)
    if cost is not None:
        assert reg.gauge("xla_cost_flops",
                         shape="solve.fleet.C3.N6").value > 0

    with pytest.raises(ValueError):
        profile.solve_cost(
            Problem(system=sysp, weights=W,
                    rounds=RoundsConfig(rounds=2),
                    key=jax.random.PRNGKey(2)), spec=_SPEC)


# ---------------------------------------------------------------------------
# compare.py --slo verdict gate
# ---------------------------------------------------------------------------

def test_compare_slo_gate():
    import pathlib
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))
    try:
        from benchmarks.compare import parse_derived, slo_regressions
    finally:
        _sys.path.pop(0)
    base = parse_derived("slo_breaches=0;slo_deadline_hit_rate_ok=1;"
                         "slo_bcd_convergence_ok=1;deadline_hit_rate=1.000")
    good = parse_derived("slo_breaches=0;slo_deadline_hit_rate_ok=1;"
                         "slo_bcd_convergence_ok=1;deadline_hit_rate=0.979")
    assert slo_regressions("slo.serve.R48", good, base) == []
    bad = parse_derived("slo_breaches=2;slo_deadline_hit_rate_ok=0;"
                        "slo_bcd_convergence_ok=1")
    msgs = slo_regressions("slo.serve.R48", bad, base)
    assert len(msgs) == 2
    assert any("slo_breaches" in m for m in msgs)
    assert any("slo_deadline_hit_rate_ok" in m for m in msgs)
    # a flag the baseline never had (new SLO) is not a regression
    extra = parse_derived("slo_breaches=0;slo_deadline_hit_rate_ok=1;"
                          "slo_bcd_convergence_ok=1;slo_new_ok=0")
    assert slo_regressions("slo.serve.R48", extra, base) == []


# ---------------------------------------------------------------------------
# SloObserver: timer-driven observe() daemon (PR 10 satellite)
# ---------------------------------------------------------------------------

def test_slo_observer_logical_clock_and_shutdown():
    from repro.obs import MetricsRegistry, SloObserver

    reg = MetricsRegistry()
    plane = SloPlane(default_slos(), registry=reg)
    ticks = [0.0]

    def clock():
        ticks[0] += 1.0
        return ticks[0]

    obs_d = SloObserver(plane, period_s=0.01, clock=clock)
    assert not obs_d.running
    obs_d.start()
    assert obs_d.running
    deadline = time.monotonic() + 5.0
    while obs_d.ticks < 3 and time.monotonic() < deadline:
        time.sleep(0.005)
    t0 = time.monotonic()
    obs_d.stop(timeout=5.0)
    # stop() returns promptly (Event interrupts the sleep, no period wait)
    assert time.monotonic() - t0 < 1.0
    assert not obs_d.running
    assert obs_d.ticks >= 3
    # samples landed in the plane's rings with the injected timestamps
    ring = plane._rings[plane.slos[0].name]
    assert len(ring) == obs_d.ticks
    assert ring[0][0] == 1.0 and ring[1][0] == 2.0
    # idempotent stop, restartable handle is NOT promised — but stop twice
    # must not raise
    obs_d.stop()


def test_slo_observer_context_manager_and_period_guard():
    from repro.obs import MetricsRegistry, SloObserver

    reg = MetricsRegistry()
    plane = SloPlane(default_slos(), registry=reg)
    with SloObserver(plane, period_s=0.01) as obs_d:
        deadline = time.monotonic() + 5.0
        while obs_d.ticks < 1 and time.monotonic() < deadline:
            time.sleep(0.005)
    assert not obs_d.running and obs_d.ticks >= 1
    with pytest.raises(ValueError):
        SloObserver(plane, period_s=0.0)


def test_metrics_server_starts_and_stops_observer():
    from repro.obs import MetricsRegistry

    reg = MetricsRegistry()
    plane = SloPlane(default_slos(), registry=reg)
    srv = MetricsServer(registry=reg, slo_plane=plane, observe_period_s=0.01)
    with srv:
        assert srv.observer is not None and srv.observer.running
        deadline = time.monotonic() + 5.0
        while srv.observer.ticks < 1 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert srv.observer.ticks >= 1
        # /slo still serves while the observer samples in the background
        status, _, body = _get(srv.url("/slo"))
        assert status == 200 and "slos" in json.loads(body)
    assert srv.observer is None

    # no plane -> no observer, even with a period configured
    srv2 = MetricsServer(registry=reg, observe_period_s=0.01)
    with srv2:
        assert srv2.observer is None
