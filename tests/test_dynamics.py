"""Round-dynamics engine tests: static-channel parity with the allocate-once
ledger, channel sampling/drift statistics, participation models, the async
staleness queue, and fleet/single-cell consistency."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Weights, allocate, allocate_fleet, make_fleet,
                        make_system, stack_systems)
from repro.core.energy import e_cmp, e_trans, t_cmp, t_trans
from repro.dynamics import (RoundsConfig, queue_step, run_rounds,
                            run_rounds_fleet, staleness_of)

W = Weights(0.5, 0.5, 1.0)


def _per_round_ledger(sysp, alloc):
    e = float(jnp.sum(e_trans(sysp, alloc.bandwidth, alloc.power)
                      + e_cmp(sysp, alloc.freq, alloc.resolution)))
    t = float(jnp.max(t_cmp(sysp, alloc.freq, alloc.resolution)
                      + t_trans(sysp, alloc.bandwidth, alloc.power)))
    return e, t


# ---------------------------------------------------------------------------
# acceptance: static/full/no-staleness reproduces the allocate-once ledger
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp1_method", ["sweep", "bisect"])
def test_static_parity_with_allocate_once(sp1_method):
    sysp = make_system(jax.random.PRNGKey(0), n_devices=8)
    res = allocate(sysp, W, max_iters=8, sp1_method=sp1_method)
    e_ref, t_ref = _per_round_ledger(sysp, res.allocation)

    cfg = RoundsConfig(rounds=4, bcd_iters=8, sp1_method=sp1_method)
    rr = run_rounds(jax.random.PRNGKey(1), sysp, W, cfg)
    np.testing.assert_allclose(np.asarray(rr.col("energy")), e_ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(rr.col("time")), t_ref, rtol=1e-5)
    # full participation: everything arrives, nothing is late or dropped
    assert np.all(np.asarray(rr.col("arrived_frac")) == 1.0)
    assert np.all(np.asarray(rr.col("n_late")) == 0)
    assert np.all(np.asarray(rr.staleness) == 0)
    # static channel: the realized gains are the expected gains, every round
    np.testing.assert_array_equal(np.asarray(rr.gains),
                                  np.broadcast_to(np.asarray(sysp.gain),
                                                  rr.gains.shape))
    # and the per-round resolution record is constant == the final allocation
    assert rr.resolutions.shape == (4, 8)
    np.testing.assert_array_equal(
        np.asarray(rr.resolutions),
        np.broadcast_to(np.asarray(rr.allocation.resolution),
                        rr.resolutions.shape))


def test_bcd_iters_zero_simulates_init_unchanged():
    """bcd_iters=0 is the allocate-once mode: the init allocation is held
    fixed and only the channel/participation dynamics play out."""
    sysp = make_system(jax.random.PRNGKey(2), n_devices=6)
    res = allocate(sysp, W, max_iters=8)
    cfg = RoundsConfig(rounds=3, bcd_iters=0)
    rr = run_rounds(jax.random.PRNGKey(3), sysp, W, cfg, init=res.allocation)
    e_ref, t_ref = _per_round_ledger(sysp, res.allocation)
    np.testing.assert_allclose(np.asarray(rr.col("energy")), e_ref, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(rr.col("time")), t_ref, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(rr.allocation.bandwidth),
                               np.asarray(res.allocation.bandwidth))
    assert np.all(np.asarray(rr.col("bcd_iters")) == 0)


# ---------------------------------------------------------------------------
# channel dynamics
# ---------------------------------------------------------------------------

def test_iid_sampling_varies_rounds_and_preserves_mean():
    sysp = make_system(jax.random.PRNGKey(4), n_devices=64)
    cfg = RoundsConfig(rounds=24, channel_mode="iid", bcd_iters=2)
    rr = run_rounds(jax.random.PRNGKey(5), sysp, W, cfg)
    g = np.asarray(rr.gains)                       # (R, N)
    assert np.std(g, axis=0).min() > 0.0           # every device fades
    # lognormal: E[log g] = log E[g] - sigma^2/2, std[log g] = sigma
    sigma = 8.0 * np.log(10.0) / 10.0
    logdev = np.log(g) - np.log(np.asarray(sysp.gain))[None, :]
    assert abs(logdev.mean() + sigma ** 2 / 2) < 5 * sigma / np.sqrt(g.size)
    assert abs(logdev.std() - sigma) < 0.1 * sigma
    # re-allocation responds: the realized energies move round to round
    assert np.std(np.asarray(rr.col("energy"))) > 0.0


def test_markov_drift_is_correlated_across_rounds():
    sysp = make_system(jax.random.PRNGKey(6), n_devices=48)
    logs = {}
    for mode, rho in [("markov", 0.95), ("iid", 0.0)]:
        cfg = RoundsConfig(rounds=32, channel_mode=mode, drift_rho=rho,
                           bcd_iters=0)
        rr = run_rounds(jax.random.PRNGKey(7), sysp, W, cfg,
                        init=allocate(sysp, W, max_iters=4).allocation)
        logs[mode] = np.log(np.asarray(rr.gains))

    def lag1(x):   # mean per-device lag-1 autocorrelation of log-gain
        d = x - x.mean(axis=0, keepdims=True)
        num = (d[1:] * d[:-1]).sum(axis=0)
        den = (d * d).sum(axis=0)
        return float(np.mean(num / np.maximum(den, 1e-30)))

    assert lag1(logs["markov"]) > 0.6
    assert abs(lag1(logs["iid"])) < 0.3


# ---------------------------------------------------------------------------
# participation models
# ---------------------------------------------------------------------------

def test_dropout_reduces_energy_and_marks_devices():
    sysp = make_system(jax.random.PRNGKey(8), n_devices=32)
    full = run_rounds(jax.random.PRNGKey(9), sysp, W,
                      RoundsConfig(rounds=6, bcd_iters=4))
    half = run_rounds(jax.random.PRNGKey(9), sysp, W,
                      RoundsConfig(rounds=6, bcd_iters=4, dropout_prob=0.5))
    assert float(jnp.sum(half.col("n_dropped"))) > 0
    assert float(jnp.sum(half.col("energy"))) < float(jnp.sum(full.col("energy")))
    codes = np.asarray(half.staleness)
    dropped = codes == -1
    assert dropped.any() and (~dropped).any()
    assert float(jnp.min(half.col("arrived_frac"))) < 1.0


def test_straggler_drop_mode():
    sysp = make_system(jax.random.PRNGKey(10), n_devices=16)
    cfg = RoundsConfig(rounds=5, bcd_iters=4, participation="drop",
                       deadline_slack=0.98)
    rr = run_rounds(jax.random.PRNGKey(11), sysp, W, cfg)
    # the allocator equalizes makespans near T, so a <1 slack creates misses
    assert float(jnp.sum(rr.col("n_late"))) > 0
    assert float(jnp.max(rr.col("arrived_frac"))) < 1.0
    # dropped stragglers are marked lost, never stale
    assert set(np.unique(np.asarray(rr.staleness))) <= {-1, 0}
    # the realized round time never exceeds the deadline the server enforces
    t = np.asarray(rr.col("time"))
    assert np.all(t > 0)


def test_stale_mode_defers_mass_with_decay():
    sysp = make_system(jax.random.PRNGKey(12), n_devices=16)
    kw = dict(rounds=8, bcd_iters=4, participation="stale",
              deadline_slack=0.98, max_staleness=3)
    rr = run_rounds(jax.random.PRNGKey(13), sysp, W,
                    RoundsConfig(staleness_decay=1.0, **kw))
    codes = np.asarray(rr.staleness)
    assert codes.max() >= 1 and codes.min() >= 0   # no dropout: nothing lost
    assert codes.max() <= 3
    # undecayed stale mass is conserved: total arrived over R rounds can trail
    # the full-participation total only by what is still in flight at the end
    w_total = float(jnp.sum(sysp.samples))
    arrived = float(jnp.sum(rr.col("arrived_frac"))) * w_total
    in_flight_bound = 3 * w_total
    assert arrived <= 8 * w_total + 1e-6
    assert arrived >= 8 * w_total - in_flight_bound
    # decay < 1 strictly reduces the arrived mass when anything is late
    rr_dec = run_rounds(jax.random.PRNGKey(13), sysp, W,
                        RoundsConfig(staleness_decay=0.5, **kw))
    if float(jnp.sum(rr_dec.col("n_late"))) > 0:
        assert (float(jnp.sum(rr_dec.col("arrived_frac")))
                < float(jnp.sum(rr.col("arrived_frac"))))


def test_staleness_of_buckets():
    d = jnp.asarray(2.0)
    t = jnp.asarray([0.5, 2.0, 2.1, 4.0, 4.1, 100.0])
    k = staleness_of(t, d, 3)
    np.testing.assert_array_equal(np.asarray(k), [0, 0, 1, 1, 2, 3])


def test_queue_step_pop_shift_push():
    qw = jnp.asarray([1.0, 2.0, 3.0])
    qu = jnp.asarray([10.0, 20.0, 30.0])
    idx = jnp.asarray([0, 2, 0], jnp.int32)
    pw = jnp.asarray([5.0, 7.0, 0.0])
    pu = jnp.asarray([50.0, 70.0, 0.0])
    qw2, qu2, pop_w, pop_u = queue_step(qw, qu, idx, pw, pu)
    assert float(pop_w) == 1.0 and float(pop_u) == 10.0
    np.testing.assert_allclose(np.asarray(qw2), [2.0 + 5.0, 3.0, 7.0])
    np.testing.assert_allclose(np.asarray(qu2), [20.0 + 50.0, 30.0, 70.0])
    # mass conservation: popped + kept == old total + pushed
    assert float(pop_w + qw2.sum()) == pytest.approx(float(qw.sum() + pw.sum()))


# ---------------------------------------------------------------------------
# fleet engine
# ---------------------------------------------------------------------------

def test_fleet_matches_per_cell_runs():
    fleet = make_fleet(jax.random.PRNGKey(14), n_cells=3, n_devices=6)
    cfg = RoundsConfig(rounds=4, bcd_iters=4, channel_mode="markov",
                       participation="stale", deadline_slack=0.99)
    key = jax.random.PRNGKey(15)
    rf = run_rounds_fleet(key, fleet, W, cfg)
    assert rf.ledger.shape == (3, 4, len(rf.columns))
    cells = [jax.tree_util.tree_map(lambda x: x[c], fleet) for c in range(3)]
    # the sp2_evals column is an *effort* counter from the SP2 dual search's
    # certainty early-exit AND the warm-started Newton polish: both stopping
    # predicates sit on reduction results that can differ by an ulp between
    # the vmapped and single-cell lowerings, and a flipped Newton exit moves
    # the count by a whole inner search (~tens of evals) while every
    # solution column stays bit-stable — compare it with relative slack
    ev_col = rf.columns.index("sp2_evals")
    sol_cols = [i for i in range(len(rf.columns)) if i != ev_col]
    for c, kc in enumerate(jax.random.split(key, 3)):
        rc = run_rounds(kc, cells[c], W, cfg)
        lf, lc = np.asarray(rf.ledger[c]), np.asarray(rc.ledger)
        np.testing.assert_allclose(lf[:, sol_cols], lc[:, sol_cols],
                                   rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(lf[:, ev_col], lc[:, ev_col],
                                   rtol=0.2, atol=8)
        np.testing.assert_array_equal(np.asarray(rf.staleness[c]),
                                      np.asarray(rc.staleness))


def test_fleet_warm_init_round1_converges_fast():
    """Warm-starting the engine from a solved fleet makes round 1 cheap."""
    fleet = make_fleet(jax.random.PRNGKey(16), n_cells=2, n_devices=8)
    cold = allocate_fleet(fleet, W, max_iters=20)
    assert bool(jnp.all(cold.converged))
    cfg = RoundsConfig(rounds=2, bcd_iters=6)
    rr = run_rounds_fleet(jax.random.PRNGKey(17), fleet, W, cfg,
                          init=cold.allocation)
    iters_r1 = np.asarray(rr.col("bcd_iters"))[:, 0]
    assert np.all(iters_r1 <= 2)
    assert np.all(np.asarray(rr.col("bcd_converged")) == 1.0)


def test_config_validation():
    with pytest.raises(ValueError):
        RoundsConfig(channel_mode="rayleigh")
    with pytest.raises(ValueError):
        RoundsConfig(participation="sometimes")
    with pytest.raises(ValueError):
        RoundsConfig(rounds=0)
    # bcd_iters=0 never solves -> a straggler deadline needs an init with T
    # (silently everything-late garbage otherwise)
    sysp = make_system(jax.random.PRNGKey(18), n_devices=4)
    cfg = RoundsConfig(rounds=2, bcd_iters=0, participation="drop")
    with pytest.raises(ValueError, match="makespan T"):
        run_rounds(jax.random.PRNGKey(19), sysp, W, cfg)
    from repro.core.types import Allocation
    bad = Allocation(bandwidth=sysp.gain, power=sysp.gain, freq=sysp.gain,
                     resolution=sysp.gain)   # T=None
    with pytest.raises(ValueError, match="makespan T"):
        run_rounds(jax.random.PRNGKey(19), sysp, W, cfg, init=bad)
