"""Bucketed request batching: pad mixed-size cell pools onto a power-of-two
shape menu so region traffic compiles into a handful of XLA programs.

Real traffic arrives as cell pools of mixed device counts; compiling one
program per distinct N would blow the jit cache (and the compile budget) on
the service hot path. Instead every pool is padded up to `bucket_size(N)` —
the next power of two, floored at `min_bucket` — with *masked* devices:

  * zero data (cycles = samples = bits = 0): a padded device computes and
    uploads nothing, so its SP1 dual contribution is exactly 0 (the
    `sp1_lambda_sum` kernel's documented zero-lane property) and its
    makespan is 0;
  * zero bandwidth demand: `sys.active` collapses its SP2 box to [0, 0], so
    it is pinned at B = 0 and is bit-neutral in every budget reduction;
  * excluded from makespan/energy/accuracy via the `active` mask threaded
    through the sp1/sp2/BCD reductions (see `core.types.SystemParams`).

The active prefix of a padded solve is bit-identical to the unpadded solve
(property-tested in tests/test_region_padding.py across sweep/bisect SP1
and f32/f64).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import Allocation, SystemParams

DEFAULT_MIN_BUCKET = 64


def bucket_size(n: int, min_bucket: int = DEFAULT_MIN_BUCKET) -> int:
    """Smallest power-of-two >= n, floored at `min_bucket`: the compiled
    batch-shape menu for mixed-size cell pools. A trace spanning device
    counts up to 16x the floor compiles at most 5 distinct shapes."""
    if n <= 0:
        raise ValueError(f"bucket_size: need n >= 1, got {n}")
    return max(min_bucket, 1 << (n - 1).bit_length())


def _pad_tail(x, pad: int, fill, xp=jnp):
    x = xp.asarray(x)
    return xp.concatenate([x, xp.full((pad,), fill, x.dtype)])


def pad_system(sys: SystemParams, n_pad: int, xp=jnp) -> SystemParams:
    """Pad a SystemParams to `n_pad` devices with masked, data-free lanes.

    The result always carries an `active` mask (all-True over the original
    prefix), even when n_pad == N — so systems from different pools stack
    into one batch with a consistent pytree structure. Padded lanes get
    gain = 1 (any positive value; it only guards divisions), zero cycles/
    samples/bits, and active = False.

    `xp` picks the array namespace: the default jnp enqueues device ops;
    the planning layer passes numpy so batch assembly stays host-side and
    never rides (or blocks on) the device stream. Padding is pure data
    movement, so both namespaces produce bit-identical operands."""
    n = sys.n
    if n_pad < n:
        raise ValueError(f"pad_system: n_pad={n_pad} < n={n}")
    pad = n_pad - n
    active = sys.active if sys.active is not None \
        else xp.ones((n,), bool)
    return sys.replace(
        gain=_pad_tail(sys.gain, pad, 1.0, xp),
        cycles=_pad_tail(sys.cycles, pad, 0.0, xp),
        samples=_pad_tail(sys.samples, pad, 0.0, xp),
        bits=_pad_tail(sys.bits, pad, 0.0, xp),
        active=xp.concatenate([xp.asarray(active),
                               xp.zeros((pad,), bool)]),
    )


def inactive_system(template: SystemParams, xp=jnp) -> SystemParams:
    """An all-masked batch filler shaped like `template`: every lane
    inactive, zero data (gain = 1 to guard divisions).

    Short chunks pad their cell axis with these instead of replicating a
    real cell: a fully inactive cell sits at the masked fixed point, so its
    BCD lane's (masked) rel-step is exactly 0 and the lane reports
    convergence after ONE iteration — the `SystemParams.active` zero-lane
    path — instead of burning a full re-solve of cell 0. Real lanes of the
    vmapped batch are bit-unaffected (per-cell programs are independent)."""
    n = template.n
    dt = xp.asarray(template.gain).dtype
    return template.replace(
        gain=xp.ones((n,), dt),
        cycles=xp.zeros((n,), dt),
        samples=xp.zeros((n,), dt),
        bits=xp.zeros((n,), dt),
        active=xp.zeros((n,), bool),
    )


def pad_allocation(alloc: Allocation, n_pad: int,
                   sys: SystemParams, xp=jnp) -> Allocation:
    """Pad a warm-start Allocation to `n_pad` devices.

    Pad lanes are filled with the masked solve's fixed point (B = 0,
    p = p_min, f = f_min, s = s_hi): warm-starting there contributes zero
    movement to the (masked) BCD rel-step, so a cached solution behaves
    exactly like its unpadded warm start. `sys` supplies the box values
    (p_min/f_min/s_hi may be per-cell traced leaves)."""
    n = xp.asarray(alloc.bandwidth).shape[0]
    pad = int(n_pad) - int(n)
    if pad < 0:
        raise ValueError(f"pad_allocation: n_pad={n_pad} < n={n}")
    if pad == 0:
        return alloc
    dt = xp.asarray(alloc.bandwidth).dtype

    def tail(fill):
        return xp.full((pad,), fill, dt)

    return Allocation(
        bandwidth=xp.concatenate([xp.asarray(alloc.bandwidth), tail(0.0)]),
        power=xp.concatenate([xp.asarray(alloc.power, dt), tail(sys.p_min)]),
        freq=xp.concatenate([xp.asarray(alloc.freq, dt), tail(sys.f_min)]),
        resolution=xp.concatenate([xp.asarray(alloc.resolution, dt),
                                   tail(sys.s_hi)]),
        s_relaxed=None if alloc.s_relaxed is None else xp.concatenate(
            [xp.asarray(alloc.s_relaxed, dt), tail(sys.s_hi)]),
        T=alloc.T,
    )
