"""Profiling plane: programmatic XLA trace sessions + compiled-cost gauges.

Two tools, both opt-in (nothing here runs on the serving path):

  * `trace(logdir, label=...)` — a context manager around
    `jax.profiler.start_trace`/`stop_trace`, span-keyed: the session is
    wrapped in an `obs.span("profile", label=...)`, so device work done
    inside shows up under the enclosing span names (`recorder._Span`
    already enters `TraceAnnotation` per span). One session at a time —
    a nested `trace` is a no-op yielding ``None`` (JAX raises on double
    start; serving loops shouldn't). The session's wall time lands in
    the `profiler_trace_seconds{label=...}` gauge and each completed
    session bumps `profiler_traces`.

  * `record_cost(label, fn, *args, ...)` — AOT-lower `fn` for the given
    arguments (`jax.jit(fn).lower(...).compile()`) and record the XLA
    cost analysis (FLOPs, bytes accessed) as
    `xla_cost_flops{shape=label}` / `xla_cost_bytes{shape=label}` gauges,
    so BENCH artifacts track compute-per-shape across PRs. Lowering
    compiles a fresh program by design — call it from benches, never
    from the serving path (the serve-time zero-new-compiles guard in
    tests/test_slo.py covers the SLO/scrape plane, which never imports
    this module's lowering).

`Compiled.cost_analysis()` is backend-dependent: it may return a list of
per-computation dicts, a bare dict, or raise on backends without a cost
model. `record_cost` normalizes all three (returns ``None`` — and records
nothing — when no cost model is available).
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Dict, Optional

from . import recorder as _rec
from .metrics import MetricsRegistry, REGISTRY

__all__ = ["trace", "record_cost", "solve_cost"]

_TRACE_LOCK = threading.Lock()
_TRACE_ACTIVE = False


@contextlib.contextmanager
def trace(logdir: str, label: str = "trace",
          registry: Optional[MetricsRegistry] = None):
    """Profile the enclosed block into `logdir` (TensorBoard/perfetto
    format). Yields the logdir, or ``None`` when a session is already
    active (nested use degrades to a plain pass-through)."""
    global _TRACE_ACTIVE
    import jax

    with _TRACE_LOCK:
        if _TRACE_ACTIVE:
            nested = True
        else:
            _TRACE_ACTIVE = True
            nested = False
    if nested:
        yield None
        return
    reg = registry if registry is not None else REGISTRY
    try:
        with _rec.span("profile", label=label):
            jax.profiler.start_trace(logdir)
            t0 = time.monotonic()
            try:
                yield logdir
            finally:
                jax.profiler.stop_trace()
                dur = time.monotonic() - t0
                reg.gauge("profiler_trace_seconds", label=label).set(dur)
                reg.counter("profiler_traces").inc()
    finally:
        with _TRACE_LOCK:
            _TRACE_ACTIVE = False


def _normalize_cost(cost) -> Optional[Dict[str, float]]:
    """One flat {key: float} from whatever `cost_analysis()` returned."""
    if cost is None:
        return None
    if isinstance(cost, (list, tuple)):
        merged: Dict[str, float] = {}
        for entry in cost:
            if not isinstance(entry, dict):
                continue
            for k, v in entry.items():
                try:
                    merged[k] = merged.get(k, 0.0) + float(v)
                except (TypeError, ValueError):
                    pass
        return merged or None
    if isinstance(cost, dict):
        out = {}
        for k, v in cost.items():
            try:
                out[k] = float(v)
            except (TypeError, ValueError):
                pass
        return out or None
    return None


def record_cost(label: str, fn, *args,
                registry: Optional[MetricsRegistry] = None,
                static_argnames=(), **kwargs) -> Optional[Dict[str, float]]:
    """AOT-compile `fn(*args, **kwargs)` and record its XLA cost analysis.

    Returns the normalized cost dict (always containing ``flops`` and
    ``bytes_accessed`` keys, 0.0 when the backend reports neither), or
    ``None`` when the backend has no cost model. `fn` may also be an
    already-jitted function — it is lowered as-is."""
    import jax

    jitted = fn if hasattr(fn, "lower") else jax.jit(
        fn, static_argnames=static_argnames)
    try:
        compiled = jitted.lower(*args, **kwargs).compile()
        cost = _normalize_cost(compiled.cost_analysis())
    except Exception:   # no cost model / unsupported backend: degrade
        return None
    if cost is None:
        return None
    flops = cost.get("flops", 0.0)
    nbytes = cost.get("bytes accessed", 0.0)
    out = dict(cost)
    out["flops"] = flops
    out["bytes_accessed"] = nbytes
    reg = registry if registry is not None else REGISTRY
    reg.gauge("xla_cost_flops", shape=label).set(flops)
    reg.gauge("xla_cost_bytes", shape=label).set(nbytes)
    return out


def solve_cost(problem, spec=None,
               registry: Optional[MetricsRegistry] = None
               ) -> Optional[Dict[str, float]]:
    """Cost analysis for the compiled program `solve(problem, spec)` would
    run, keyed ``solve.<topology>.C<cells>.N<devices>`` (single-cell and
    unsharded (C, N) fleet topologies; mesh/rounds/assoc problems are out
    of scope — profile those with `trace`). Never executes the solve."""
    import jax
    import jax.numpy as jnp

    from repro.api.problem import weights_leaf
    from repro.api.solve import _apply_dtype, _topology_label
    from repro.api.spec import SolverSpec
    from repro.core.accuracy import default_accuracy
    from repro.core.bcd import (_allocate_impl, _fleet_cell_fn,
                                _init_carry_state, initial_allocation)

    spec = SolverSpec() if spec is None else spec
    topo = _topology_label(problem)
    if topo not in ("bcd", "bcd_fleet"):
        raise ValueError(
            f"solve_cost: only single-cell and fleet topologies are "
            f"supported, got {topo!r}")
    sysp, init = _apply_dtype(problem.system, problem.init, spec.dtype)
    acc = problem.acc if problem.acc is not None else default_accuracy()
    gain = jnp.asarray(sysp.gain)
    if topo == "bcd":
        alloc0 = init if init is not None else initial_allocation(sysp)
        state0 = _init_carry_state(sysp, alloc0)
        warr = weights_leaf(problem.weights, state0[0].dtype)
        label = f"solve.bcd.N{gain.shape[0]}"
        cost = record_cost(
            label, _allocate_impl, sysp, warr, acc, state0,
            spec.max_iters, spec.tol, spec.sp1_method, spec.sp2_method,
            spec.sp2_iters, registry=registry)
        return cost
    C, N = int(gain.shape[0]), int(gain.shape[1])
    warr = weights_leaf(problem.weights, gain.dtype, cells=C)
    fn = _fleet_cell_fn(acc, spec.max_iters, spec.tol, spec.sp1_method,
                        spec.sp2_method, spec.sp2_iters,
                        with_init=init is not None)
    vf = jax.jit(jax.vmap(fn))
    label = f"solve.fleet.C{C}.N{N}"
    args = (sysp, warr) if init is None else (sysp, warr, init)
    return record_cost(label, vf, *args, registry=registry)
