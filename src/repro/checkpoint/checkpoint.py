"""Msgpack pytree checkpointing with sharding-aware restore.

Format: a directory with `manifest.msgpack` (tree structure, shapes, dtypes)
and one raw buffer file per leaf. Restore accepts an optional sharding pytree
and uses jax.device_put per leaf, so restoring under a mesh re-shards.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    elif hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            yield from _flatten(getattr(tree, k), f"{prefix}/{k}")
    else:
        yield prefix, tree


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    os.makedirs(path, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        fn = name.replace("/", "__") + ".bin"
        manifest["leaves"][name] = dict(
            file=fn, shape=list(arr.shape),
            dtype=(str(arr.dtype) if arr.dtype != jnp.bfloat16 else "bfloat16"))
        with open(os.path.join(path, fn), "wb") as f:
            if arr.dtype == jnp.bfloat16:
                f.write(arr.view(np.uint16).tobytes())
            else:
                f.write(arr.tobytes())
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))


def restore(path: str, like: Any, shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: matching pytree of NamedSharding."""
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves = dict(_flatten(like))
    shard_map_ = dict(_flatten(shardings)) if shardings is not None else {}

    out = {}
    for name, meta in manifest["leaves"].items():
        with open(os.path.join(path, meta["file"]), "rb") as f:
            raw = f.read()
        if meta["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(meta["shape"]).view()
            arr = jnp.asarray(arr).view(jnp.bfloat16).reshape(meta["shape"])
        else:
            arr = jnp.asarray(np.frombuffer(raw, np.dtype(meta["dtype"]))
                              .reshape(meta["shape"]))
        if name in shard_map_:
            arr = jax.device_put(arr, shard_map_[name])
        out[name] = arr

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}/{k}" if prefix else str(k))
                    for k in tree}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rebuild(getattr(tree, k), f"{prefix}/{k}")
                                for k in tree._fields))
        if isinstance(tree, (tuple, list)):
            return type(tree)(rebuild(v, f"{prefix}/{i}")
                              for i, v in enumerate(tree))
        return out[prefix]

    return rebuild(like)


def latest_step(path: str) -> Optional[int]:
    mp = os.path.join(path, "manifest.msgpack")
    if not os.path.exists(mp):
        return None
    with open(mp, "rb") as f:
        return msgpack.unpackb(f.read()).get("step")
