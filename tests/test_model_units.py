"""Unit tests for model components: MoE routing, SSM chunking invariances,
attention caches, sharding spec resolution, optimizer, data, checkpoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (KVCache, attention, init_attention,
                                    init_kv_cache)
from repro.sharding.partition import (axes_for_path, fsdp_tp_rules,
                                      shape_aware_spec)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_top1_equals_single_expert():
    """With E=1, top-1 MoE must equal the expert MLP applied to all tokens."""
    key = jax.random.PRNGKey(0)
    D, F = 16, 32
    p = moe_lib.init_moe(key, D, F, n_experts=1, dtype=jnp.float32)
    x = jax.random.normal(key, (2, 8, D))
    out, aux = moe_lib.apply_moe(p, x, top_k=1, capacity_factor=8.0)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"][0])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"][0])
    exp = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["wo"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-4,
                               atol=1e-5)


def test_moe_gates_renormalized_and_capacity_drops():
    key = jax.random.PRNGKey(1)
    D, F, E = 8, 16, 4
    p = moe_lib.init_moe(key, D, F, n_experts=E, dtype=jnp.float32)
    x = jax.random.normal(key, (1, 16, D))
    out_full, _ = moe_lib.apply_moe(p, x, top_k=2, capacity_factor=8.0)
    out_tight, _ = moe_lib.apply_moe(p, x, top_k=2, capacity_factor=0.25)
    # tight capacity drops tokens -> different (smaller-energy) output
    assert np.isfinite(np.asarray(out_tight)).all()
    assert float(jnp.linalg.norm(out_tight)) <= float(jnp.linalg.norm(out_full)) + 1e-3


def test_moe_grad_flows_to_router():
    key = jax.random.PRNGKey(2)
    p = moe_lib.init_moe(key, 8, 16, n_experts=4, dtype=jnp.float32)
    x = jax.random.normal(key, (1, 8, 8))

    def loss(p):
        out, aux = moe_lib.apply_moe(p, x, top_k=2)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.linalg.norm(g["router"])) > 0


# ---------------------------------------------------------------------------
# SSM chunk invariance
# ---------------------------------------------------------------------------

def test_mamba_chunk_invariance():
    """Chunked mamba must be invariant to the chunk size."""
    key = jax.random.PRNGKey(3)
    D = 32
    p = ssm_lib.init_mamba(key, d_model=16, d_inner=D, d_state=4,
                           dtype=jnp.float32)
    x = jax.random.normal(key, (2, 48, 16)) * 0.5
    y1, _ = ssm_lib.mamba(p, x, mode="train", chunk=8)
    y2, _ = ssm_lib.mamba(p, x, mode="train", chunk=48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-2,
                               atol=2e-3)


def test_rwkv_chunk_invariance():
    key = jax.random.PRNGKey(4)
    p = ssm_lib.init_rwkv_time_mix(key, 32, n_heads=2, head_dim=16,
                                   dtype=jnp.float32)
    x = jax.random.normal(key, (1, 32, 32)) * 0.5
    o1, s1, _ = ssm_lib.rwkv_time_mix(p, x, n_heads=2, head_dim=16, chunk=8)
    o2, s2, _ = ssm_lib.rwkv_time_mix(p, x, n_heads=2, head_dim=16, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3,
                               atol=1e-4)


def test_mamba_decode_matches_train():
    key = jax.random.PRNGKey(5)
    p = ssm_lib.init_mamba(key, d_model=16, d_inner=32, d_state=4,
                           dtype=jnp.float32)
    x = jax.random.normal(key, (1, 8, 16)) * 0.5
    y_train, _ = ssm_lib.mamba(p, x, mode="train", chunk=8)
    cache = ssm_lib.init_mamba_cache(1, 32, 4, dtype=jnp.float32)
    outs = []
    for t in range(8):
        y, cache = ssm_lib.mamba(p, x[:, t:t + 1], mode="decode", cache=cache)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# attention caches
# ---------------------------------------------------------------------------

def test_ring_cache_sliding_window_decode():
    """Ring-buffer decode must equal full-cache decode restricted to the
    window (the long_500k memory mechanism)."""
    key = jax.random.PRNGKey(6)
    D, H, KV, hd = 32, 4, 2, 8
    p = init_attention(key, D, H, KV, hd, dtype=jnp.float32)
    W = 8
    T = 20
    xs = jax.random.normal(key, (1, T, D)) * 0.5
    ring = init_kv_cache(1, W, KV, hd, jnp.float32)
    full = init_kv_cache(1, T, KV, hd, jnp.float32)
    for t in range(T):
        o_ring, ring = attention(p, xs[:, t:t + 1], mode="decode", cache=ring,
                                 pos=jnp.asarray(t), window=W)
        o_full, full = attention(p, xs[:, t:t + 1], mode="decode", cache=full,
                                 pos=jnp.asarray(t), window=None)
        if t >= W:
            continue  # full-cache path has no window; compare only while equal
        np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# sharding spec resolution
# ---------------------------------------------------------------------------

def test_shape_aware_divisibility_repair():
    rules = fsdp_tp_rules(False)
    sizes = {"data": 16, "model": 16}
    # kv_heads=8 not divisible by model=16 -> relocated to head_dim
    spec = shape_aware_spec(("layers", "embed", "kv_heads", "head_dim"),
                            (48, 6144, 8, 128), rules, sizes)
    assert spec == jax.sharding.PartitionSpec(None, "data", None, "model")
    # never relocated onto the layers dim
    spec2 = shape_aware_spec(("layers", "embed", "kv_heads", "head_dim"),
                             (48, 6144, 8, 100), rules, sizes)
    assert spec2[0] is None


def test_axes_for_path_known_params():
    assert axes_for_path("layers/s0_attn/attn/wq", 4) == \
        ("layers", "embed", "heads", "head_dim")
    assert axes_for_path("embed/tokens", 2) == ("vocab", "embed")
    assert axes_for_path("layers/s0_attn/moe/wi", 4) == \
        ("layers", "experts", "embed", "expert_mlp")
    # unknown -> replicated
    assert axes_for_path("something/unknown", 2) == (None, None)


def test_logical_rules_no_duplicate_axis():
    from repro.sharding.partition import logical_to_spec
    rules = fsdp_tp_rules(True)
    spec = logical_to_spec(("batch", "pod_batch"), rules)
    flat = []
    for part in spec:
        if isinstance(part, tuple):
            flat += list(part)
        elif part:
            flat.append(part)
    assert len(flat) == len(set(flat))


def test_int8_kv_cache_decode_close_to_fp():
    """Quantized KV decode must track the full-precision path closely."""
    key = jax.random.PRNGKey(7)
    D, H, KV, hd = 32, 4, 2, 16
    p = init_attention(key, D, H, KV, hd, dtype=jnp.float32)
    T = 12
    xs = jax.random.normal(key, (1, T, D)) * 0.5
    from repro.models.attention import init_kv_cache as ikc
    fp = ikc(1, T, KV, hd, jnp.float32)
    q8 = ikc(1, T, KV, hd, jnp.float32, quantized=True)
    errs = []
    for t in range(T):
        o_fp, fp = attention(p, xs[:, t:t + 1], mode="decode", cache=fp,
                             pos=jnp.asarray(t))
        o_q8, q8 = attention(p, xs[:, t:t + 1], mode="decode", cache=q8,
                             pos=jnp.asarray(t))
        errs.append(float(jnp.max(jnp.abs(o_fp - o_q8))))
    scale = float(jnp.max(jnp.abs(xs)))
    assert max(errs) < 0.05 * scale, errs
