"""Serving driver: prefill a batch of prompts, then batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models.transformer import init_cache, init_model, model_forward


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)

    B, P = args.batch, args.prompt_len
    max_seq = P + args.gen
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, max_seq)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    extras = None
    if cfg.encoder_layers:
        extras = {"frame_embeds": jnp.zeros((B, cfg.encoder_ctx, cfg.d_model),
                                            cfg.np_dtype)}

    # block prefill: one forward fills the decode cache
    from repro.models.transformer import prefill as block_prefill
    pf = jax.jit(lambda pr, c, b: block_prefill(pr, cfg, b, c))
    t0 = time.time()
    pbatch = {"tokens": prompts}
    if extras:
        pbatch.update(extras)
    logits_all, cache = pf(params, cache, pbatch)
    jax.block_until_ready(logits_all)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits_all[:, P - 1], -1)
    out = [tok]
    t0 = time.time()
    for t in range(P, P + args.gen - 1):
        logits, cache = serve(params, cache, tok, jnp.asarray(t), extras)
        tok = jnp.argmax(logits, -1)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"{cfg.name}: prefill {P} toks in {t_prefill:.2f}s, "
          f"decoded {args.gen} toks in {t_decode:.2f}s "
          f"({args.gen * B / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample generation (token ids):", gen[0, :12].tolist())
    return gen


if __name__ == "__main__":
    main()
