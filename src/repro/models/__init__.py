from repro.models.cnn import accuracy, apply_cnn, init_cnn, xent_loss
