"""Channel substrate tests: `sample_gain` statistics (previously exported but
untested), dtype preservation, key determinism, and the shadowing drift."""
import jax

jax.config.update("jax_enable_x64", True)   # match test_fleet/test_dynamics

import jax.numpy as jnp
import numpy as np

from repro.core import sample_gain
from repro.core.channel import (drift_shadowing, expected_gain,
                                shadowing_sigma, shadowing_to_gain)

SHADOW_DB = 8.0
SIGMA = SHADOW_DB * np.log(10.0) / 10.0


def test_sample_gain_lognormal_statistics():
    """E[sample] == expected and std(log sample / expected) == sigma."""
    n = 200_000
    expected = jnp.full((n,), 3e-9)
    g = np.asarray(sample_gain(jax.random.PRNGKey(0), expected, SHADOW_DB))
    assert (g > 0).all()
    # linear-scale mean: lognormal with E[X]=1 has var exp(sigma^2)-1
    rel_se = np.sqrt((np.exp(SIGMA ** 2) - 1.0) / n)
    assert abs(g.mean() / 3e-9 - 1.0) < 5 * rel_se
    # log-scale: mean log(g/expected) = -sigma^2/2, std = sigma (tight check)
    logdev = np.log(g / 3e-9)
    assert abs(logdev.mean() + SIGMA ** 2 / 2) < 5 * SIGMA / np.sqrt(n)
    assert abs(logdev.std() - SIGMA) < 0.01 * SIGMA


def test_sample_gain_zero_shadowing_is_identity():
    expected = jnp.asarray([1e-9, 2e-9, 3e-9])
    g = sample_gain(jax.random.PRNGKey(1), expected, 0.0)
    np.testing.assert_allclose(np.asarray(g), np.asarray(expected), rtol=1e-6)


def test_sample_gain_dtype_preservation():
    """The sample dtype follows `expected`, even when x64 is enabled."""
    for dtype in (jnp.float32, jnp.float64):
        expected = jnp.ones((16,), dtype) * 1e-9
        g = sample_gain(jax.random.PRNGKey(2), expected, SHADOW_DB)
        assert g.dtype == dtype, (g.dtype, dtype)


def test_sample_gain_determinism_under_key_splitting():
    expected = jnp.ones((32,)) * 1e-9
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    a = np.asarray(sample_gain(k1, expected, SHADOW_DB))
    b = np.asarray(sample_gain(k1, expected, SHADOW_DB))
    c = np.asarray(sample_gain(k2, expected, SHADOW_DB))
    np.testing.assert_array_equal(a, b)          # same key -> same draw
    assert np.any(a != c)                        # sibling key -> fresh draw
    # and independent of other consumers of the parent key
    np.testing.assert_array_equal(
        a, np.asarray(sample_gain(jax.random.split(key)[0], expected,
                                  SHADOW_DB)))


def test_shadowing_to_gain_mean_folding():
    """shadowing_to_gain(expected, 0) sits below expected by exactly the
    folded-in lognormal mean factor."""
    expected = jnp.asarray([2e-9])
    g0 = float(shadowing_to_gain(expected, jnp.zeros((1,)), SHADOW_DB)[0])
    assert g0 < 2e-9
    np.testing.assert_allclose(g0 * np.exp(SIGMA ** 2 / 2), 2e-9, rtol=1e-6)
    assert shadowing_sigma(SHADOW_DB) == float(SIGMA)


def test_drift_shadowing_stationary_and_correlated():
    """AR(1) drift: rho=1 is frozen, rho=0 is iid, and the stationary std
    stays ~1 so E[gain] is preserved through shadowing_to_gain."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (50_000,))
    x1 = drift_shadowing(jax.random.fold_in(key, 1), x, 1.0)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x))
    x0 = drift_shadowing(jax.random.fold_in(key, 2), x, 0.0)
    corr = np.corrcoef(np.asarray(x), np.asarray(x0))[0, 1]
    assert abs(corr) < 0.02
    xr = drift_shadowing(jax.random.fold_in(key, 3), x, 0.9)
    assert abs(float(jnp.std(xr)) - 1.0) < 0.02
    assert np.corrcoef(np.asarray(x), np.asarray(xr))[0, 1] > 0.85


def test_expected_gain_positive_and_deterministic():
    g1 = expected_gain(jax.random.PRNGKey(5), 64, 500.0, SHADOW_DB)
    g2 = expected_gain(jax.random.PRNGKey(5), 64, 500.0, SHADOW_DB)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert (np.asarray(g1) > 0).all()
