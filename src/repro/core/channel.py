"""Wireless channel substrate (paper §VII-A).

Pathloss model: 128.1 + 37.6 log10(d_km) dB plus 8 dB lognormal shadow
fading; devices uniform in a square area with the base station at the
center; FDMA uplink; N0 = -174 dBm/Hz.

The paper optimizes against the *expected* channel gain E[G_n]
(justified via Jensen's inequality, §III-B); `expected_gain` provides it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .types import DEFAULTS, SystemParams


def device_positions(key: jax.Array, n: int, area_m: float) -> jax.Array:
    """Uniform positions in [-area/2, area/2]^2; BS at origin. Returns (n,2) meters."""
    return (jax.random.uniform(key, (n, 2)) - 0.5) * area_m


def pathloss_db(distance_m: jax.Array) -> jax.Array:
    d_km = jnp.maximum(distance_m, 1.0) / 1000.0
    return 128.1 + 37.6 * jnp.log10(d_km)


def expected_gain(key: jax.Array, n: int, area_m: float,
                  shadowing_db: float) -> jax.Array:
    """E[G_n]: linear-scale expected gain with lognormal shadowing.

    For shadowing X ~ N(0, sigma^2) in dB, E[10^(X/10)] = exp((sigma*ln10/10)^2/2);
    we fold that factor into the expectation rather than sampling it, matching
    the paper's use of E[G_n] in eqs. (1)-(2).
    """
    kp, = jax.random.split(key, 1)
    pos = device_positions(kp, n, area_m)
    dist = jnp.linalg.norm(pos, axis=-1)
    pl_db = pathloss_db(dist)
    sigma = shadowing_db * jnp.log(10.0) / 10.0
    shadow_mean = jnp.exp(sigma ** 2 / 2.0)
    return 10.0 ** (-pl_db / 10.0) * shadow_mean


def shadowing_sigma(shadowing_db: float) -> float:
    """Natural-log sigma of the lognormal shadow fading (sigma_dB -> ln)."""
    return shadowing_db * float(np.log(10.0)) / 10.0


def shadowing_to_gain(expected: jax.Array, x: jax.Array,
                      shadowing_db: float) -> jax.Array:
    """Map a standard-normal shadowing state x to a gain realization.

    `expected` already folds in the lognormal mean E[10^(X/10)]
    (see `expected_gain`), so we divide it back out before applying the
    realization: E_x[shadowing_to_gain(expected, x, db)] == expected.
    """
    x = jnp.asarray(x)
    sigma = jnp.asarray(shadowing_sigma(shadowing_db), x.dtype)
    shadow_mean = jnp.exp(sigma ** 2 / 2.0)
    return expected / shadow_mean * jnp.exp(sigma * x)


def sample_gain(key: jax.Array, expected: jax.Array, shadowing_db: float) -> jax.Array:
    """Draw one iid realization g_{n,r} of the channel for a global round.

    Dtype follows `expected` (the fleet may run f32 under x64)."""
    expected = jnp.asarray(expected)
    z = jax.random.normal(key, expected.shape, expected.dtype)
    return shadowing_to_gain(expected, z, shadowing_db)


def drift_shadowing(key: jax.Array, x: jax.Array, rho: float) -> jax.Array:
    """One AR(1) Gauss-Markov step of the standard-normal shadowing state:
    x' = rho x + sqrt(1 - rho^2) z, z ~ N(0, 1) — the Gudmundson-style
    mobility/pathloss drift model (round-to-round correlated fading). The
    stationary law stays N(0, 1), so `shadowing_to_gain` keeps
    E[gain] == expected at every round."""
    x = jnp.asarray(x)
    rho = jnp.asarray(rho, x.dtype)
    z = jax.random.normal(key, x.shape, x.dtype)
    return rho * x + jnp.sqrt(jnp.maximum(1.0 - rho ** 2, 0.0)) * z


def make_system(key: jax.Array, n_devices: int | None = None, **overrides) -> SystemParams:
    """Build a SystemParams with the paper's §VII-A parameterization."""
    cfg = dict(DEFAULTS)
    cfg.update(overrides)
    n = int(n_devices if n_devices is not None else cfg["n_devices"])
    k_gain, k_cyc = jax.random.split(key)
    gain = expected_gain(k_gain, n, cfg["area_m"], cfg["shadowing_db"])
    cycles = jax.random.uniform(k_cyc, (n,), minval=cfg["cycles_lo"], maxval=cfg["cycles_hi"])
    return SystemParams(
        gain=gain,
        cycles=cycles,
        samples=jnp.full((n,), float(cfg["samples_per_device"])),
        bits=jnp.full((n,), float(cfg["upload_bits"])),
        bandwidth_total=float(cfg["bandwidth_total"]),
        noise_psd=float(cfg["noise_psd"]),
        p_min=float(cfg["p_min"]),
        p_max=float(cfg["p_max"]),
        f_min=float(cfg["f_min"]),
        f_max=float(cfg["f_max"]),
        kappa=float(cfg["kappa"]),
        local_iters=float(cfg["local_iters"]),
        global_rounds=float(cfg["global_rounds"]),
        resolutions=tuple(float(s) for s in cfg["resolutions"]),
        s_standard=float(cfg["s_standard"]),
    )


def make_fleet(key: jax.Array, n_cells: int, n_devices: int,
               **overrides) -> SystemParams:
    """C independent cells drawn with the §VII-A parameterization, stacked
    into one batched SystemParams with (C, N) array leaves and (C,) scalar
    leaves for `allocate_fleet`.

    Heterogeneous fleets: a scalar override given as a length-C sequence
    (list/tuple/array) is distributed cell-by-cell, e.g.
    ``make_fleet(key, 3, 64, bandwidth_total=[10e6, 20e6, 40e6])`` builds a
    fleet of three different cell classes."""
    from .bcd import stack_systems

    per_cell = {}
    for k, v in list(overrides.items()):
        if isinstance(v, (list, tuple, np.ndarray, jnp.ndarray)) \
                and k != "resolutions" and jnp.ndim(v) > 0:
            vals = list(v)
            if len(vals) != n_cells:
                raise ValueError(
                    f"make_fleet: per-cell override {k!r} has {len(vals)} "
                    f"entries for {n_cells} cells")
            per_cell[k] = [float(x) for x in vals]
            del overrides[k]
    keys = jax.random.split(key, n_cells)
    return stack_systems([
        make_system(kc, n_devices=n_devices,
                    **{k: v[c] for k, v in per_cell.items()}, **overrides)
        for c, kc in enumerate(keys)])
