"""Pipelined serving stack (admission -> planning -> dispatch ->
completion): facade bit-parity with the serial depth-1 path, inactive
pad lanes leaving real lanes bit-identical, out-of-order future
completion, batch-closing policies under a logical clock, warm-start
coherence for in-flight cells, and LRU / stage-clock accounting."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro import Problem, SolverSpec, solve
from repro.core import Weights, make_system
from repro.core.bcd import initial_allocation, stack_systems
from repro.region import (AllocationRequest, CloseOnFull, DeadlineSlack,
                          MaxWait, RegionAllocator, RegionPipeline,
                          WarmStartCache, inactive_system, pad_system)
from repro.region.planning import BatchPlanner, _full_allocation

W = Weights(0.5, 0.5, 1.0)
SPEC = SolverSpec(max_iters=8, tol=1e-5)


def _req(cell_id, n, seed=None, drift=0.0, **kw):
    sysp = make_system(jax.random.PRNGKey(seed if seed is not None
                                          else 100 + hash(cell_id) % 1000),
                       n_devices=n)
    if drift:
        sysp = sysp.replace(gain=sysp.gain * (1.0 + drift))
    return AllocationRequest(cell_id=cell_id, sys=sysp, **kw)


def _pipeline(**kw):
    kw.setdefault("cells_per_batch", 2)
    kw.setdefault("min_bucket", 8)
    kw.setdefault("spec", SPEC)
    return RegionPipeline(W, **kw)


def _resp_equal(a, b):
    if (a.cell_id, a.objective, a.iters, a.converged, a.warm,
            a.bucket) != (b.cell_id, b.objective, b.iters, b.converged,
                          b.warm, b.bucket):
        return False
    eq = jax.tree_util.tree_map(
        lambda x, y: bool(jnp.all(jnp.asarray(x) == jnp.asarray(y))),
        a.allocation, b.allocation)
    return all(jax.tree_util.tree_leaves(eq))


# ---------------------------------------------------------------------------
# bit-parity: pipelining changes timing, never results
# ---------------------------------------------------------------------------

def test_pipeline_depth_is_bit_invisible():
    """The same trace through depth 1 (the old serial solve-then-gather
    loop) and depth 3 produces bit-identical responses and identical
    cache/shape accounting."""
    sizes = [5, 9, 6, 14, 7, 12, 11, 6, 30]
    traces = []
    for depth in (1, 3):
        svc = RegionAllocator(W, cells_per_batch=2, min_bucket=8, spec=SPEC,
                              pipeline_depth=depth)
        out1 = svc.solve([_req(i, n) for i, n in enumerate(sizes)])
        out2 = svc.solve([_req(i, n, drift=0.01)
                          for i, n in enumerate(sizes)])
        stats = dict(svc.stats)
        stats["shapes"] = set(stats["shapes"])
        traces.append((out1, out2, stats))
    (a1, a2, sa), (b1, b2, sb) = traces
    assert sa == sb
    for out_a, out_b in ((a1, b1), (a2, b2)):
        assert set(out_a) == set(out_b)
        for cid in out_a:
            assert _resp_equal(out_a[cid], out_b[cid]), cid
    assert all(r.warm for r in a2.values())


def test_inactive_pad_lanes_keep_real_lanes_bit_identical():
    """A short chunk padded with all-inactive filler cells solves its real
    lanes bit-identically to the old replicate-cell-0 padding (vmapped
    per-cell programs are independent), while the filler lane itself
    converges after one masked iteration."""
    C, bucket = 4, 8
    reqs = [_req(i, 6) for i in range(3)]
    padded = [pad_system(r.sys, bucket) for r in reqs]
    inits = [_full_allocation(initial_allocation(p)) for p in padded]

    def batch(filler_sys, filler_init):
        sys_b = stack_systems(padded + [filler_sys])
        init_b = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                        *(inits + [filler_init]))
        return solve(Problem(system=sys_b, weights=[W] * C, init=init_b),
                     SPEC)

    new = batch(inactive_system(padded[0]),
                _full_allocation(initial_allocation(
                    inactive_system(padded[0]))))
    old = batch(padded[0], inits[0])
    for leaf_new, leaf_old in zip(
            jax.tree_util.tree_leaves(new.allocation),
            jax.tree_util.tree_leaves(old.allocation)):
        np.testing.assert_array_equal(np.asarray(leaf_new)[:3],
                                      np.asarray(leaf_old)[:3])
    np.testing.assert_array_equal(np.asarray(new.objective[:3]),
                                  np.asarray(old.objective[:3]))
    np.testing.assert_array_equal(np.asarray(new.iters[:3]),
                                  np.asarray(old.iters[:3]))
    # the all-inactive lane sits at the masked fixed point: one iteration
    assert int(new.iters[3]) == 1 and bool(new.converged[3])


# ---------------------------------------------------------------------------
# futures: out-of-order completion
# ---------------------------------------------------------------------------

def test_out_of_order_result_materializes_only_its_batch():
    pipe = _pipeline(max_in_flight=4)
    futs = [pipe.submit(_req(i, 6)) for i in range(4)]   # 2 batches of 2
    batches = pipe.pump(force=True)
    assert len(batches) == 2 and pipe.in_flight == 2
    assert all(f.dispatched and not f.done() for f in futs)

    late = futs[3].result()            # batch 2 first
    assert futs[3].done() and futs[2].done()
    assert not futs[0].done() and not futs[1].done()
    assert batches[1].materialized and not batches[0].materialized
    assert pipe.in_flight == 1
    assert late.cell_id == 3

    early = futs[0].result()           # batch 1 afterwards — still fine
    assert early.cell_id == 0 and pipe.in_flight == 0
    assert all(f.done() for f in futs)


def test_result_on_queued_request_forces_dispatch():
    pipe = _pipeline()
    fut = pipe.submit(_req("solo", 6))
    assert not fut.dispatched and pipe.pending == 1
    res = fut.result()
    assert res.cell_id == "solo" and fut.done()
    assert pipe.pending == 0 and pipe.in_flight == 0


def test_depth_bound_evicts_oldest():
    pipe = _pipeline(max_in_flight=1)
    futs = [pipe.submit(_req(i, 6)) for i in range(4)]
    pipe.pump(force=True)
    assert pipe.in_flight == 1          # batch 1 was force-materialized
    assert futs[0].done() and futs[1].done()
    assert not futs[3].done()


# ---------------------------------------------------------------------------
# admission policies under a logical clock
# ---------------------------------------------------------------------------

def test_close_on_full_waits_for_full_batches():
    pipe = _pipeline(policy=CloseOnFull())
    pipe.submit(_req(0, 6), now=0.0)
    assert pipe.poll(now=1e9) == []     # partial batch never closes
    pipe.submit(_req(1, 6), now=2.0)
    (batch,) = pipe.poll(now=3.0)
    assert batch.plan.n_real == 2 and pipe.pending == 0


def test_max_wait_closes_partial_batches():
    pipe = _pipeline(policy=MaxWait(10.0))
    pipe.submit(_req(0, 6), now=0.0)
    assert pipe.poll(now=9.0) == []
    (batch,) = pipe.poll(now=10.0)      # oldest waited exactly max_wait
    assert batch.plan.n_real == 1
    # the wait was charged to the admission clock in logical units
    assert pipe.clocks.queue_wait_s == pytest.approx(10.0)


def test_deadline_slack_closes_for_tight_requests():
    pipe = _pipeline(cells_per_batch=3, policy=DeadlineSlack(slack=5.0))
    pipe.submit(_req(0, 6), now=0.0)                       # no deadline
    pipe.submit(_req(1, 6, deadline=20.0), now=0.0)
    assert pipe.poll(now=10.0) == []                       # 10 > slack
    (batch,) = pipe.poll(now=15.0)                         # 5 <= slack
    assert batch.plan.n_real == 2                          # rides along
    with pytest.raises(ValueError):
        MaxWait(-1.0)


def test_priority_orders_within_batch():
    pipe = _pipeline(cells_per_batch=3)
    pipe.submit(_req("lo", 6, priority=0), now=0.0)
    pipe.submit(_req("hi", 6, priority=5), now=0.0)
    pipe.submit(_req("mid", 6, priority=1), now=0.0)
    (batch,) = pipe.pump(now=0.0, force=True)
    assert [r.cell_id for r in batch.plan.requests] == ["hi", "mid", "lo"]


# ---------------------------------------------------------------------------
# warm-start coherence + LRU accounting
# ---------------------------------------------------------------------------

def test_in_flight_cell_stalls_replan_until_cache_written():
    """A re-request of a cell whose solve is still in flight must wait for
    that solution to land in the cache — the second batch plans warm, same
    as the synchronous path."""
    pipe = _pipeline(cells_per_batch=1, max_in_flight=2)
    pipe.submit(_req("x", 6, seed=1))
    (first,) = pipe.pump(force=True)
    assert pipe.in_flight == 1 and not first.materialized
    pipe.submit(_req("x", 6, seed=1, drift=0.01))
    (second,) = pipe.pump(force=True)
    assert first.materialized            # drained before planning "x" again
    assert second.plan.warm == [True]
    out = pipe.drain()
    assert [r.warm for r in out] == [False, True]
    assert out[1].iters <= 3


def test_duplicate_cell_id_in_one_solve_keeps_last_response():
    svc = RegionAllocator(W, cells_per_batch=1, min_bucket=8, spec=SPEC)
    res = svc.solve([_req("dup", 6, seed=3),
                     _req("dup", 6, seed=3, drift=0.02)])
    assert set(res) == {"dup"}
    assert res["dup"].warm               # dict keeps the later chunk's row
    assert svc.stats["requests"] == 2 and svc.stats["batches"] == 2


def test_warm_cache_resize_purge_frees_capacity():
    cache = WarmStartCache(2)
    alloc = initial_allocation(make_system(jax.random.PRNGKey(0),
                                           n_devices=4))
    cache.store("a", 4, alloc)
    cache.store("b", 4, alloc)
    assert cache.lookup("b", 4) is alloc and cache.hits == 1
    # pool resize: the stale entry is purged immediately, not just missed
    assert cache.lookup("a", 8) is None
    assert cache.resize_purges == 1 and cache.misses == 1
    assert "a" not in cache and len(cache) == 1
    # the freed slot absorbs a new cell without evicting "b"
    cache.store("c", 4, alloc)
    assert cache.evictions == 0 and "b" in cache
    cache.store("d", 4, alloc)           # now over capacity: "b" is LRU
    assert cache.evictions == 1 and "b" not in cache
    with pytest.raises(ValueError):
        WarmStartCache(0)


def test_interleaved_buckets_warm_hit_accounting():
    """Re-requests interleaved across two buckets all warm-hit; hit/miss
    counters add up across the pipeline and the cache agree."""
    svc = RegionAllocator(W, cells_per_batch=2, min_bucket=8, spec=SPEC)
    sizes = {0: 6, 1: 12, 2: 7, 3: 14}
    svc.solve([_req(i, n) for i, n in sizes.items()])
    res = svc.solve([_req(i, n, drift=0.01) for i, n in sizes.items()])
    assert all(r.warm for r in res.values())
    assert svc.stats["cache_hits"] == 4
    assert svc.stats["cache_misses"] == 4
    assert svc.pipeline.cache.hits == 4
    assert svc.pipeline.cache.misses == 4
    assert len(svc.compiled_shapes) == 2   # (2, 8) and (2, 16)


# ---------------------------------------------------------------------------
# stage clocks
# ---------------------------------------------------------------------------

def test_stage_clocks_cover_all_four_layers():
    pipe = _pipeline()
    for i in range(4):
        pipe.submit(_req(i, 6), now=float(i))
    out = pipe.drain(now=10.0)
    assert len(out) == 4
    clocks = pipe.clocks.as_dict()
    assert set(clocks) == {"queue_wait_s", "plan_s", "dispatch_s",
                           "device_s", "gather_s"}
    # logical admission clock: waits are 10-0, 10-1, 10-2, 10-3
    assert clocks["queue_wait_s"] == pytest.approx(34.0)
    for key in ("plan_s", "dispatch_s", "device_s", "gather_s"):
        assert clocks[key] > 0.0, key


# ---------------------------------------------------------------------------
# handover invalidation (mobility churn hook)
# ---------------------------------------------------------------------------

def test_handover_invalidate_purges_and_counts():
    """invalidate() drops exactly the named cell's warm entry, counts it in
    handover_purges (pipeline stats AND cache counter), and forces the next
    request for that cell to re-solve cold."""
    svc = RegionAllocator(W, cells_per_batch=2, min_bucket=8, spec=SPEC)
    svc.solve([_req("a", 6, seed=1), _req("b", 6, seed=2)])
    assert svc.stats["handover_purges"] == 0

    assert svc.invalidate("a") is True
    assert svc.invalidate("a") is False     # already gone: not double-counted
    assert svc.invalidate("nope") is False  # unknown cell: a no-op
    assert svc.stats["handover_purges"] == 1
    assert svc.pipeline.cache.handover_purges == 1

    res = svc.solve([_req("a", 6, seed=1, drift=0.01),
                     _req("b", 6, seed=2, drift=0.01)])
    assert not res["a"].warm                # purged -> cold re-solve
    assert res["b"].warm                    # untouched cell stays warm


def test_handover_invalidate_materializes_in_flight_batch():
    """An invalidation racing an in-flight async batch must not let the
    stale store resurrect: the pending batch is materialized first, then
    purged, so the next solve is cold."""
    pipe = _pipeline(cells_per_batch=1, max_in_flight=2)
    pipe.submit(_req("x", 6, seed=9))
    pipe.pump(force=True)
    assert pipe._in_flight                  # batch launched, not gathered
    assert pipe.invalidate("x") is True
    assert not pipe._in_flight              # forced materialization
    assert pipe.stats["handover_purges"] == 1
    out = pipe.drain()
    assert len(out) == 1 and out[0].cell_id == "x" and out[0].converged
    pipe.submit(_req("x", 6, seed=9, drift=0.01))
    resp = pipe.drain()[0]
    assert not resp.warm
