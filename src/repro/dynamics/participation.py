"""Participation models: stragglers, dropouts, and the async staleness queue.

All functions are pure jnp on fixed shapes so they live inside the round
engine's `lax.scan` without host syncs. The staleness queue is a fixed-size
(K,) ring: slot j holds the aggregate mass arriving j+1 rounds from now.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

Array = jnp.ndarray


def staleness_of(t_dev: Array, deadline: Array, max_staleness: int) -> Array:
    """Rounds of lateness per device: an update whose realized round time
    t_n lands in (k * deadline, (k+1) * deadline] arrives k rounds late.
    On-time devices (t_n <= deadline) get 0; lateness clips to
    `max_staleness` (updates later than that are dropped by the caller or
    arrive at the clip)."""
    t = jnp.asarray(t_dev)
    d = jnp.maximum(jnp.asarray(deadline, t.dtype), jnp.finfo(t.dtype).tiny)
    k = jnp.ceil(t / d) - 1.0
    return jnp.clip(k, 0, max_staleness).astype(jnp.int32)


def queue_step(queue_w: Array, queue_u: Array, push_idx: Array,
               push_w: Array, push_u: Array
               ) -> Tuple[Array, Array, Array, Array]:
    """One round of the staleness queue.

    Pops slot 0 (mass arriving this round), shifts the ring left, and
    scatter-adds the newly late mass: a device k rounds late this round is
    pushed at index k-1 of the shifted queue (it arrives at round r+k, which
    is k-1 rounds after round r+1).

    queue_w / queue_u: (K,) aggregate FedAvg weight / utility mass.
    push_idx: (N,) int32 in [0, K); push_w / push_u: (N,) masses (0 where a
    device is not late). Returns (queue_w', queue_u', popped_w, popped_u).
    """
    pop_w, pop_u = queue_w[0], queue_u[0]
    zero = jnp.zeros((1,), queue_w.dtype)
    qw = jnp.concatenate([queue_w[1:], zero]).at[push_idx].add(push_w)
    qu = jnp.concatenate([queue_u[1:], zero]).at[push_idx].add(push_u)
    return qw, qu, pop_w, pop_u
