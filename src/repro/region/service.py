"""Service layer: a streaming front-end for region-scale allocation.

`RegionAllocator` accepts a stream of `AllocationRequest`s (one per cell:
the cell's current SystemParams snapshot), coalesces them into bucketed,
shard-ready batches, and returns per-cell results:

  * **bucketing**: each request's device pool is padded to
    `bucket_size(N)` (power of two, floored) so a mixed-size trace
    compiles a handful of XLA programs instead of one per distinct N;
  * **fixed batch shape**: each solve batches exactly `cells_per_batch`
    cells (short batches are padded by replicating a cell and sliced off),
    so the compiled-shape count is #buckets, independent of traffic;
  * **warm starts**: an LRU cache keyed by cell identity holds the last
    solution per cell; a re-request of a drifted cell re-solves from it in
    ~2 BCD iterations instead of a cold ~8-25 (PR 3's measurement);
  * **per-request weights**: each `AllocationRequest` may carry its own
    `Weights` (multi-cell mixed-demand deployments: every cell weighs
    energy/latency/accuracy differently). Weights are a traced (C, 3)
    operand of the jitted solve, so mixed weights add ZERO compiled
    shapes — only `SolverSpec` + the bucket menu key the jit cache;
  * **sharding**: batches run through `repro.solve` — sharded over the
    mesh when one is given (shard-local early exit), plain fleet vmap
    when `mesh=None`.

`stats` tracks requests, cache hits, batches, and the set of compiled batch
shapes — the acceptance signal for the bucketing policy.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Problem, SolverSpec, solve
from repro.core.accuracy import AccuracyModel, default_accuracy
from repro.core.bcd import initial_allocation, stack_systems
from repro.core.types import Allocation, SystemParams, Weights

from .batch import DEFAULT_MIN_BUCKET, bucket_size, pad_allocation, pad_system
from .mesh import RegionResult

Array = jnp.ndarray


@dataclasses.dataclass
class AllocationRequest:
    """One cell asking for a (re-)allocation against its current channel
    snapshot. `cell_id` keys the warm-start cache: re-requests of the same
    cell (drifted gains, same device pool) re-solve from the previous
    solution. `w`, if set, overrides the allocator's default weights for
    this request only (traced — never a recompile)."""
    cell_id: Hashable
    sys: SystemParams
    w: Optional[Weights] = None


@dataclasses.dataclass
class CellResponse:
    cell_id: Hashable
    allocation: Allocation   # unpadded (N,) leaves
    objective: float
    iters: int
    converged: bool
    warm: bool               # served from the warm-start cache
    bucket: int              # padded device count this cell solved at


class RegionAllocator:
    """Streaming allocation front-end: submit requests, flush batches.

    Parameters
    ----------
    w : the region's *default* objective weights; any request may override
        them with its own `AllocationRequest.w` (traced per request, zero
        extra compiles — the PR 4 fragmentation caveat is closed).
    spec : a `SolverSpec` with the static solver options — the jit-cache
        key shared by every batch this allocator solves.
    mesh : jax mesh to shard batches over (None = single-device fleet
        vmap); see `region_mesh`.
    cells_per_batch : fixed cell-axis length of every compiled solve.
    min_bucket : floor of the power-of-two device-count buckets.
    cache_size : max cells kept in the warm-start LRU.
    max_iters / tol / sp* kwargs : legacy spellings of the SolverSpec
        fields, honored when `spec` is not given.
    """

    def __init__(self, w: Weights, acc: Optional[AccuracyModel] = None,
                 mesh=None, cells_per_batch: int = 32,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 cache_size: int = 4096,
                 spec: Optional[SolverSpec] = None,
                 max_iters: Optional[int] = None, tol: Optional[float] = None,
                 sp2_iters: Optional[int] = None,
                 sp2_method: Optional[str] = None,
                 sp1_method: Optional[str] = None):
        if cells_per_batch < 1:
            raise ValueError("cells_per_batch must be >= 1")
        self.w = w
        self.acc = acc if acc is not None else default_accuracy()
        self.mesh = mesh
        self.cells_per_batch = int(cells_per_batch)
        self.min_bucket = int(min_bucket)
        self.cache_size = int(cache_size)
        legacy = {k: v for k, v in dict(
            max_iters=max_iters, tol=tol, sp2_iters=sp2_iters,
            sp2_method=sp2_method, sp1_method=sp1_method).items()
            if v is not None}
        if spec is not None:
            if legacy:   # silently dropping either set would mislead
                raise ValueError(
                    f"RegionAllocator: pass the solver options through "
                    f"`spec` OR the legacy kwargs, not both (got spec and "
                    f"{sorted(legacy)})")
            self.spec = spec
        else:
            self.spec = SolverSpec(**legacy)
        # cell_id -> (n_devices, Allocation with (n,) leaves incl. T)
        self._cache: "OrderedDict[Hashable, Tuple[int, Allocation]]" = \
            OrderedDict()
        self._pending: List[AllocationRequest] = []
        self.stats = dict(requests=0, batches=0, cache_hits=0,
                          cache_misses=0, cells_padded=0,
                          shapes=set())

    # ------------------------------------------------------------- stream
    def submit(self, request: AllocationRequest) -> None:
        """Queue a request for the next `flush()`."""
        self._pending.append(request)

    def flush(self) -> Dict[Hashable, CellResponse]:
        """Solve everything queued since the last flush."""
        reqs, self._pending = self._pending, []
        return self.solve(reqs)

    # -------------------------------------------------------------- batch
    def solve(self, requests: Sequence[AllocationRequest]
              ) -> Dict[Hashable, CellResponse]:
        """Coalesce `requests` into bucketed batches and solve them all.

        Requests are grouped by device-count bucket; each group is chunked
        into fixed `cells_per_batch` solves (the jit-cache key is therefore
        just the bucket). Returns {cell_id: CellResponse}.
        """
        out: Dict[Hashable, CellResponse] = {}
        by_bucket: Dict[int, List[AllocationRequest]] = {}
        for r in requests:
            by_bucket.setdefault(
                bucket_size(r.sys.n, self.min_bucket), []).append(r)
        for bucket in sorted(by_bucket):
            group = by_bucket[bucket]
            for i in range(0, len(group), self.cells_per_batch):
                chunk = group[i:i + self.cells_per_batch]
                out.update(self._solve_chunk(chunk, bucket))
        self.stats["requests"] += len(requests)
        return out

    def _warm_init(self, req: AllocationRequest, padded: SystemParams,
                   bucket: int) -> Tuple[Optional[Allocation], bool]:
        cached = self._cache.get(req.cell_id)
        if cached is None or cached[0] != req.sys.n:
            return None, False   # unknown cell or its pool was resized
        self._cache.move_to_end(req.cell_id)
        return pad_allocation(cached[1], bucket, padded), True

    def _solve_chunk(self, chunk: Sequence[AllocationRequest], bucket: int
                     ) -> Dict[Hashable, CellResponse]:
        C = self.cells_per_batch
        padded = [pad_system(r.sys, bucket) for r in chunk]
        inits, warm = [], []
        w_cells = [r.w if r.w is not None else self.w for r in chunk]
        for r, ps in zip(chunk, padded):
            init, hit = self._warm_init(r, ps, bucket)
            if init is None:
                init = initial_allocation(ps)
            if init.s_relaxed is None or init.T is None:
                dt = jnp.asarray(init.bandwidth).dtype
                init = Allocation(
                    bandwidth=init.bandwidth, power=init.power,
                    freq=init.freq, resolution=init.resolution,
                    s_relaxed=init.resolution if init.s_relaxed is None
                    else init.s_relaxed,
                    T=jnp.zeros((), dt) if init.T is None else init.T)
            inits.append(init)
            warm.append(hit)
        # fixed batch shape: short chunks replicate cell 0 (sliced off)
        n_real = len(chunk)
        while len(padded) < C:
            padded.append(padded[0])
            inits.append(inits[0])
            w_cells.append(w_cells[0])
        sys_batch = stack_systems(padded)
        init_batch = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *inits)
        # one solve() per chunk: per-request weights ride along as a traced
        # (C, 3) operand — the jit-cache key is (spec, topology, bucket) only
        res = solve(Problem(system=sys_batch, weights=w_cells, acc=self.acc,
                            init=init_batch, mesh=self.mesh), self.spec)
        if isinstance(res, RegionResult):
            res = res.fleet
        self.stats["batches"] += 1
        self.stats["shapes"].add((C, bucket))
        self.stats["cells_padded"] += C - n_real
        self.stats["cache_hits"] += sum(warm)
        self.stats["cache_misses"] += n_real - sum(warm)

        # one host gather for the whole chunk's scalar fields
        iters = np.asarray(res.iters[:n_real])
        conv = np.asarray(res.converged[:n_real])
        objs = np.asarray(res.objective[:n_real])
        out: Dict[Hashable, CellResponse] = {}
        for c, (r, hit) in enumerate(zip(chunk, warm)):
            n = r.sys.n
            a = res.allocation
            alloc = Allocation(
                bandwidth=a.bandwidth[c, :n], power=a.power[c, :n],
                freq=a.freq[c, :n], resolution=a.resolution[c, :n],
                s_relaxed=None if a.s_relaxed is None
                else a.s_relaxed[c, :n],
                T=None if a.T is None else a.T[c])
            self._remember(r.cell_id, n, alloc)
            out[r.cell_id] = CellResponse(
                cell_id=r.cell_id, allocation=alloc,
                objective=float(objs[c]), iters=int(iters[c]),
                converged=bool(conv[c]), warm=hit, bucket=bucket)
        return out

    # -------------------------------------------------------------- cache
    def _remember(self, cell_id: Hashable, n: int, alloc: Allocation):
        self._cache[cell_id] = (n, alloc)
        self._cache.move_to_end(cell_id)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    @property
    def solver_kw(self):
        """Legacy read-only view of the solver options (now a `SolverSpec`).
        A mapping proxy: the old in-place `solver_kw[...] = x` mutation
        raises instead of silently doing nothing — reconstruct the
        allocator (or pass `spec=`) to change solver options."""
        from types import MappingProxyType
        return MappingProxyType(dict(
            max_iters=self.spec.max_iters, tol=self.spec.tol,
            sp2_iters=self.spec.sp2_iters, sp2_method=self.spec.sp2_method,
            sp1_method=self.spec.sp1_method))

    @property
    def compiled_shapes(self) -> set:
        """Distinct (cells, devices) batch shapes solved so far — one jit
        cache entry each (the bucketing acceptance metric)."""
        return set(self.stats["shapes"])
