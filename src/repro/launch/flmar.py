"""FL-MAR end-to-end driver: the paper's full loop (Fig. 1).

    PYTHONPATH=src python -m repro.launch.flmar --devices 10 --rounds 20 \
        --w1 0.5 --w2 0.5 --rho 30

Allocates (B, p, f, s) with Algorithm 2, runs FedAvg at the allocated
resolutions, and prints the energy/time/accuracy ledger vs the MinPixel and
RandPixel benchmarks.
"""
from __future__ import annotations

import argparse

import jax

from repro.core import Weights, make_system, summarize, default_accuracy
from repro.core.baselines import min_pixel, rand_pixel
from repro.fl import make_federated_dataset, simulate
from repro.fl.simulator import map_resolution_to_dataset
from repro.fl.server import run_federated


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--local-iters", type=int, default=4)
    ap.add_argument("--w1", type=float, default=0.5)
    ap.add_argument("--w2", type=float, default=0.5)
    ap.add_argument("--rho", type=float, default=30.0)
    ap.add_argument("--split", default="iid",
                    choices=["iid", "noniid-1", "noniid-2"])
    ap.add_argument("--per-client", type=int, default=64)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    sysp = make_system(key, n_devices=args.devices)
    w = Weights(args.w1, args.w2, args.rho)
    ds = make_federated_dataset(jax.random.fold_in(key, 1),
                                n_clients=args.devices,
                                per_client=args.per_client,
                                base_resolution=16, split=args.split)

    res = simulate(jax.random.fold_in(key, 2), sysp, w, dataset=ds,
                   dataset_resolutions=(4, 8, 12, 16),
                   global_rounds=args.rounds, local_iters=args.local_iters)
    print(f"== proposed allocator (w1={args.w1}, w2={args.w2}, rho={args.rho})")
    for k, v in res.ledger.items():
        print(f"   {k}: {v:.5g}")

    for name, alloc in [("MinPixel", min_pixel(sysp, jax.random.fold_in(key, 3))),
                        ("RandPixel", rand_pixel(sysp, jax.random.fold_in(key, 4)))]:
        ds_res = map_resolution_to_dataset(sysp, alloc.resolution, (4, 8, 12, 16))
        fl = run_federated(jax.random.fold_in(key, 2), ds, ds_res,
                           global_rounds=args.rounds,
                           local_iters=args.local_iters)
        s = summarize(sysp, w.normalized(), default_accuracy(), alloc)
        print(f"== {name}: energy={s['energy_J']:.4g}J time={s['time_s']:.4g}s "
              f"FL-acc={fl.round_accuracy[-1]:.3f}")
    return res


if __name__ == "__main__":
    main()
