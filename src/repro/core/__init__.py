"""repro.core — the paper's contribution: FL-MAR joint resource allocation.

The solver entry point is the unified `repro.solve(Problem, SolverSpec)`
(see the migration table in the `repro` package docstring); this package
holds the model (types, energy/accuracy, SP1/SP2, the jitted BCD impls)
and the system builders.

Public API:
    make_system / make_fleet  build SystemParams per the paper's §VII-A
                              setup (single cell / stacked (C, N) fleet)
    Weights, Allocation       objective weights / decision variables —
                              weights are traced solver *data*, scalar or
                              per-cell (C,), never a jit-cache key
    stack_systems             batch heterogeneous cells into one pytree
    objective, summarize      system-model evaluation (eqs. 1-13)
    allocate, allocate_fleet, allocate_fixed_deadline
                              deprecated shims over `repro.solve`
                              (bit-identical; warn once per process)
"""
from .accuracy import (AccuracyModel, LinearAccuracy, LogAccuracy,
                       default_accuracy, linear_from_endpoints, log_fit)
from .bcd import (BCDResult, FleetResult, allocate, allocate_fixed_deadline,
                  allocate_fleet, initial_allocation, stack_systems)
from .channel import (drift_shadowing, expected_gain, make_fleet, make_system,
                      sample_gain, shadowing_to_gain)
from .energy import (feasible, objective, round_time, summarize,
                     total_accuracy, total_energy, total_time)
from .types import Allocation, SystemParams, Weights, dbm_to_watt

__all__ = [
    "AccuracyModel", "LinearAccuracy", "LogAccuracy", "default_accuracy",
    "linear_from_endpoints", "log_fit", "BCDResult", "FleetResult",
    "allocate", "allocate_fixed_deadline", "allocate_fleet",
    "initial_allocation", "stack_systems", "drift_shadowing", "expected_gain",
    "make_fleet", "make_system", "sample_gain", "shadowing_to_gain",
    "feasible", "objective", "round_time",
    "summarize", "total_accuracy", "total_energy", "total_time",
    "Allocation", "SystemParams", "Weights", "dbm_to_watt",
]
