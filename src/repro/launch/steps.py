"""Jittable step functions (train / prefill / serve) shared by the real
drivers and the multi-pod dry-run."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import lm_loss, model_forward, serve_step
from repro.optim import AdamW, clip_by_global_norm


def make_train_step(cfg: ModelConfig, optimizer: Optional[AdamW] = None,
                    clip: float = 1.0, accum_steps: int = 1):
    """accum_steps > 1 splits the global batch into microbatches scanned
    inside one jit step (gradient accumulation): live activations shrink by
    the accumulation factor at the cost of re-gathering FSDP shards per
    microbatch (§Perf memory lever for the MoE trains)."""
    opt = optimizer or AdamW(lr=3e-4)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(lm_loss)(params, cfg, batch)
        else:
            def split(x):
                a = accum_steps
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(lm_loss)(params, cfg, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.asarray(0.0, jnp.float32), g0), micro)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _, _ = model_forward(params, cfg, batch, mode="prefill")
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def _serve(params, cache, token, pos, extras=None):
        return serve_step(params, cfg, cache, token, pos, extras)

    return _serve
