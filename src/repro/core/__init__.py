"""repro.core — the paper's contribution: FL-MAR joint resource allocation.

Public API:
    make_system            build a SystemParams per the paper's §VII-A setup
    Weights, Allocation    objective weights / decision variables
    allocate               Algorithm 2 (BCD over SP1 + SP2)
    allocate_fixed_deadline  deadline-constrained variant (Figs. 8-9)
    objective, summarize   system-model evaluation (eqs. 1-13)
"""
from .accuracy import (AccuracyModel, LinearAccuracy, LogAccuracy,
                       default_accuracy, linear_from_endpoints, log_fit)
from .bcd import (BCDResult, FleetResult, allocate, allocate_fixed_deadline,
                  allocate_fleet, initial_allocation, stack_systems)
from .channel import (drift_shadowing, expected_gain, make_fleet, make_system,
                      sample_gain, shadowing_to_gain)
from .energy import (feasible, objective, round_time, summarize,
                     total_accuracy, total_energy, total_time)
from .types import Allocation, SystemParams, Weights, dbm_to_watt

__all__ = [
    "AccuracyModel", "LinearAccuracy", "LogAccuracy", "default_accuracy",
    "linear_from_endpoints", "log_fit", "BCDResult", "FleetResult",
    "allocate", "allocate_fixed_deadline", "allocate_fleet",
    "initial_allocation", "stack_systems", "drift_shadowing", "expected_gain",
    "make_fleet", "make_system", "sample_gain", "shadowing_to_gain",
    "feasible", "objective", "round_time",
    "summarize", "total_accuracy", "total_energy", "total_time",
    "Allocation", "SystemParams", "Weights", "dbm_to_watt",
]
