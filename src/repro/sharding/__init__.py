from .partition import (PARAM_AXIS_PATTERNS, active_axis_sizes, active_rules, axes_for_path,
                        fsdp_tp_rules, logical_to_spec, param_logical_axes,
                        param_pspecs, param_shardings, region_rules,
                        shape_aware_spec, shard, use_rules)
