"""Carried-B-bracket SP2-direct dual search (ROADMAP inner-loop item).

The budget-multiplier bisection re-solved every inner phi'-bisection from
the full [b_lo, B_total] box; the carried variant reuses the monotone-in-mu
bracket [B*(mu_hi), B*(mu_lo)] and exits each inner search as soon as its
interval sums settle the budget predicate. Checks:

  * objective parity <= 1e-6 vs the non-carried reference across deadline
    slacks, sizes and dtypes (the satellite acceptance bound);
  * the measured dE/dB eval count (returned by the impl, surfaced in the
    BCD ledger's sp2_iters column) sits well below the reference's static
    count from `direct_eval_counts`;
  * end-to-end `allocate` agreement between the two paths.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Weights, allocate, make_system
from repro.core.energy import t_cmp
from repro.core.sp2 import (G, _sp2_direct_impl, direct_eval_counts, r_min,
                            solve_sp2_direct)


def _trans_energy(sysp, p, B):
    return float(jnp.sum(p * sysp.bits
                         / jnp.maximum(G(sysp, p, B), 1e-12)))


def _sp2_case(dtype, seed, n, slack):
    sysp = make_system(jax.random.PRNGKey(seed), n_devices=n,
                       bandwidth_total=20e6 * n / 50)
    sysp = jax.tree_util.tree_map(lambda x: jnp.asarray(x, dtype), sysp)
    f = jnp.full((n,), 1e9, dtype)
    s = jnp.full((n,), 320.0, dtype)
    T = float(jnp.max(t_cmp(sysp, f, s))) * slack
    return sysp, r_min(sysp, f, s, jnp.asarray(T, dtype))


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
@pytest.mark.parametrize("n", [8, 50, 200])
@pytest.mark.parametrize("slack", [1.05, 1.2, 2.0])
def test_carried_bracket_objective_parity(dtype, n, slack):
    sysp, rmin = _sp2_case(dtype, seed=0, n=n, slack=slack)
    p_c, B_c = solve_sp2_direct(sysp, rmin)
    p_r, B_r = solve_sp2_direct(sysp, rmin, carry_bracket=False)
    e_c, e_r = _trans_energy(sysp, p_c, B_c), _trans_energy(sysp, p_r, B_r)
    assert abs(e_c - e_r) / max(abs(e_r), 1e-30) <= 1e-6
    # both respect the budget and the rate floors
    for B, p in ((B_c, p_c), (B_r, p_r)):
        assert float(jnp.sum(B)) <= float(sysp.bandwidth_total) * (1 + 1e-6)
        assert bool(jnp.all(G(sysp, p, B) >= rmin * (1 - 1e-5)))


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_carried_bracket_eval_count_drop(dtype):
    """The certainty exit must cut the dE/dB eval count at least 3x below
    the reference's static outer x inner budget (measured ~6-14x)."""
    sysp, rmin = _sp2_case(dtype, seed=1, n=50, slack=1.2)
    _, _, ev = _sp2_direct_impl(sysp, rmin, True)
    ref = direct_eval_counts(dtype)
    assert int(ev) * 3 <= ref, (int(ev), ref)
    _, _, ev_ref = _sp2_direct_impl(sysp, rmin, False)
    assert int(ev_ref) == ref   # the bookkeeping matches the reference path


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_allocate_parity_carried_vs_reference(seed):
    """End-to-end BCD: monkeypatch the reference path in and compare."""
    import repro.core.bcd as bcd_mod
    import repro.core.sp2 as sp2_mod

    sysp = make_system(jax.random.PRNGKey(30 + seed), n_devices=24)
    w = Weights(0.5, 0.5, 5.0)
    res = allocate(sysp, w, max_iters=8)
    orig = sp2_mod._sp2_direct_impl
    ref_impl = lambda sys_, rmin_: orig(sys_, rmin_, False)
    sp2_mod._sp2_direct_impl = ref_impl
    bcd_mod._sp2_direct_impl = ref_impl
    try:
        res_ref = allocate(sysp, w, max_iters=8)
    finally:
        sp2_mod._sp2_direct_impl = orig
        bcd_mod._sp2_direct_impl = orig
    rel = abs(res.objective - res_ref.objective) \
        / max(abs(res_ref.objective), 1e-30)
    assert rel <= 1e-6


def test_ledger_carries_measured_eval_count():
    """sp2_iters ledger column = measured dual-search eval count, positive
    and below the static reference count every iteration."""
    sysp = make_system(jax.random.PRNGKey(4), n_devices=10)
    res = allocate(sysp, Weights(0.5, 0.5, 1.0), max_iters=5)
    ref = direct_eval_counts(jnp.float64)
    for row in res.history:
        assert 0 < row["sp2_iters"] < ref


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
@pytest.mark.parametrize("slack", [1.05, 1.2, 2.0])
def test_newton_polish_parity_and_eval_drop(dtype, slack):
    """Warm-started Newton on the smooth pmin-branch stationarity (PR 10):
    same transmit energy as the safeguarded sign-bisection to <= 1e-6,
    budget/rate feasibility intact, and strictly fewer dE/dB evals than
    the bisection-only carried path."""
    sysp, rmin = _sp2_case(dtype, seed=2, n=50, slack=slack)
    p_n, B_n, ev_n = _sp2_direct_impl(sysp, rmin, True, True)
    p_b, B_b, ev_b = _sp2_direct_impl(sysp, rmin, True, False)
    e_n, e_b = _trans_energy(sysp, p_n, B_n), _trans_energy(sysp, p_b, B_b)
    assert abs(e_n - e_b) / max(abs(e_b), 1e-30) <= 1e-6
    assert float(jnp.sum(B_n)) <= float(sysp.bandwidth_total) * (1 + 1e-6)
    assert bool(jnp.all(G(sysp, p_n, B_n) >= rmin * (1 - 1e-5)))
    # Newton never costs extra evals; on tight deadlines (the pmin branch
    # the satellite targets) it must strictly cut them
    assert int(ev_n) <= int(ev_b), (int(ev_n), int(ev_b))
    if slack <= 1.2:
        assert int(ev_n) < int(ev_b), (int(ev_n), int(ev_b))
