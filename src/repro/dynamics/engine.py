"""Jit-resident round-dynamics engine.

The paper's system model (Fig. 1) is a *repeated* FL loop; the static
allocator optimizes one round against expected channel gains and multiplies
the ledger by R_g. This engine runs the R rounds explicitly as **one jitted
`lax.scan`** — per round it

  1. samples per-device channel gains (`core.channel.sample_gain`, or the
     AR(1) Gauss-Markov drift `core.channel.drift_shadowing`),
  2. re-solves the allocation with a **warm-started BCD** (the previous
     round's allocation is the init, so re-allocation costs a couple of
     iterations instead of a cold solve),
  3. applies a participation model (straggler deadline misses, random
     dropouts, async staleness — see `dynamics.participation`), and
  4. accumulates the realized energy/time/accuracy-proxy ledger into a
     fixed-size (R, cols) array on device — no host syncs inside the scan.

`run_rounds_fleet` vmaps the engine across stacked cells (see
`core.bcd.stack_systems`): R rounds x C cells x N devices is a single XLA
program. With static channels, full participation and no staleness the
per-round ledger reproduces the allocate-once ledger of `fl/simulator.py`
(parity-tested to <=1e-5). ROADMAP: "Channel dynamics" + "Async FL rounds".
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import energy as en
from repro.core.accuracy import AccuracyModel
from repro.core.bcd import (_COUNTER_COLS, _allocate_impl, _init_carry_state,
                            initial_allocation)
from repro.core.channel import drift_shadowing, sample_gain, shadowing_to_gain
from repro.core.types import Allocation, SystemParams, Weights

from .config import ROUND_COLS, RoundsConfig, RoundsResult
from .participation import queue_step, staleness_of

Array = jnp.ndarray


def _masked_max(x: Array, mask: Array) -> Array:
    return jnp.max(jnp.where(mask, x, jnp.zeros((), x.dtype)))


def _cell_engine(sys: SystemParams, warr: Array, acc: AccuracyModel,
                 key: jax.Array, state0, cfg: RoundsConfig):
    """One cell's R-round scan. Returns (final BCD state, ledger (R, cols),
    staleness codes (R, N) int32, realized gains (R, N), allocated
    resolutions (R, N))."""
    dtype = state0[0].dtype
    n = sys.gain.shape[0]
    K = cfg.max_staleness
    Dw = jnp.asarray(sys.samples, dtype)
    w_total = jnp.maximum(jnp.sum(Dw), jnp.finfo(dtype).tiny)
    wobj = Weights(warr[0], warr[1], warr[2])
    decay = jnp.asarray(cfg.staleness_decay, dtype)

    k_shadow, k_rounds = jax.random.split(key)
    shadow0 = (jax.random.normal(k_shadow, (n,), dtype)
               if cfg.channel_mode == "markov" else jnp.zeros((n,), dtype))
    keys = jax.random.split(k_rounds, cfg.rounds)

    def step(carry, kr):
        state, shadow, qw, qu = carry
        k_gain, k_drop = jax.random.split(kr)

        # (1) channel realization for this round
        if cfg.channel_mode == "static":
            g = sys.gain
        elif cfg.channel_mode == "iid":
            g = sample_gain(k_gain, sys.gain, cfg.shadowing_db)
        else:  # markov
            shadow = drift_shadowing(k_gain, shadow, cfg.drift_rho)
            g = shadowing_to_gain(sys.gain, shadow, cfg.shadowing_db)
        sys_r = sys.replace(gain=g)

        # (2) warm-started re-allocation (bcd_iters=0 keeps the carried init)
        state_in = state if cfg.warm_start else _init_carry_state(
            sys_r, initial_allocation(sys_r))
        B, p, f, s, s_hat, T, iters, conv, _, counters = _allocate_impl(
            sys_r, warr, acc, state_in, cfg.bcd_iters, cfg.bcd_tol,
            cfg.sp1_method, cfg.sp2_method, cfg.sp2_iters)
        state = (B, p, f, s, s_hat, T)
        alloc = Allocation(bandwidth=B, power=p, freq=f, resolution=s,
                           s_relaxed=s_hat, T=T)

        # realized per-device round time / energy under this round's gains
        t_dev = (en.t_cmp(sys_r, f, s) + en.t_trans(sys_r, B, p)).astype(dtype)
        e_dev = (en.e_cmp(sys_r, f, s) + en.e_trans(sys_r, B, p)).astype(dtype)
        util_dev = jnp.asarray(acc.value(s), dtype)

        # (3) participation
        if cfg.dropout_prob > 0.0:
            active = ~jax.random.bernoulli(k_drop, cfg.dropout_prob, (n,))
        else:
            active = jnp.ones((n,), bool)
        if sys.active is not None:   # padded-out lanes never participate
            active &= sys.active
        deadline = jnp.asarray(cfg.deadline_slack, dtype) * T

        if cfg.participation == "full":
            late = jnp.zeros((n,), bool)
            arrived_u = jnp.sum(jnp.where(active, util_dev, 0.0))
            arrived_w = jnp.sum(jnp.where(active, Dw, 0.0))
            time_r = _masked_max(t_dev, active)
            code = jnp.where(active, 0, -1).astype(jnp.int32)
        else:
            # lateness and the queued staleness must agree, so both derive
            # from the same bucketing (a one-ulp-late device would otherwise
            # get late=True with kst=0 and desync the ledger from the queue)
            kst = staleness_of(t_dev, deadline, K)
            late = active & (kst > 0)
            ontime = active & ~late
            closes_at = jnp.where(jnp.any(late), deadline,
                                  _masked_max(t_dev, ontime))
            if cfg.participation == "drop":
                arrived_u = jnp.sum(jnp.where(ontime, util_dev, 0.0))
                arrived_w = jnp.sum(jnp.where(ontime, Dw, 0.0))
                time_r = closes_at
                code = jnp.where(ontime, 0, -1).astype(jnp.int32)
            else:  # stale: late mass arrives k rounds later, decay^k weighted
                disc = decay ** kst.astype(dtype)
                qw, qu, pop_w, pop_u = queue_step(
                    qw, qu, jnp.maximum(kst - 1, 0),
                    jnp.where(late, Dw * disc, 0.0),
                    jnp.where(late, util_dev * disc, 0.0))
                arrived_u = jnp.sum(jnp.where(ontime, util_dev, 0.0)) + pop_u
                arrived_w = jnp.sum(jnp.where(ontime, Dw, 0.0)) + pop_w
                time_r = closes_at
                code = jnp.where(active, jnp.where(late, kst, 0), -1)
                code = code.astype(jnp.int32)

        # (4) realized ledger row
        row = jnp.stack([
            en.objective(sys_r, wobj, acc, alloc).astype(dtype),
            jnp.sum(jnp.where(active, e_dev, 0.0)),
            time_r,
            arrived_u,
            arrived_w / w_total,
            jnp.sum(late).astype(dtype),
            jnp.sum(~active).astype(dtype),
            iters.astype(dtype),
            conv.astype(dtype),
            # per-round SP2 dual-eval effort from the solve's device
            # counters (ROUND_COLS "sp2_evals"): attribution for the
            # warm-start claim — re-allocation rounds should spend fewer
            # evals than a cold solve
            counters[_COUNTER_COLS.index("sp2_evals")],
        ])
        return (state, shadow, qw, qu), (row, code, g.astype(dtype), s)

    q0 = jnp.zeros((K,), dtype)
    (state, _, _, _), (ledger, codes, gains, res) = lax.scan(
        step, (state0, shadow0, q0, q0), keys)
    return state, ledger, codes, gains, res


@partial(jax.jit, static_argnames=("acc", "cfg"))
def _run_rounds_impl(sys, warr, acc, key, state0, cfg):
    return _cell_engine(sys, warr, acc, key, state0, cfg)


@partial(jax.jit, static_argnames=("acc", "cfg"))
def _run_rounds_fleet_impl(sys_batch, warr, acc, keys, init_state, cfg):
    """warr is the (C, 3) per-cell weights stack — a traced vmapped operand,
    so mixed per-cell weights share this one jit cache entry."""
    if init_state is None:
        def one(sysc, warr_c, kc):
            st = _init_carry_state(sysc, initial_allocation(sysc))
            return _cell_engine(sysc, warr_c, acc, kc, st, cfg)
        return jax.vmap(one)(sys_batch, warr, keys)

    def one(sysc, warr_c, kc, st):
        return _cell_engine(sysc, warr_c, acc, kc, st, cfg)
    return jax.vmap(one)(sys_batch, warr, keys, init_state)


def _result(out) -> RoundsResult:
    state, ledger, codes, gains, res = out
    B, p, f, s, s_hat, T = state
    alloc = Allocation(bandwidth=B, power=p, freq=f, resolution=s,
                       s_relaxed=s_hat, T=T)
    return RoundsResult(allocation=alloc, ledger=ledger, staleness=codes,
                        gains=gains, resolutions=res, columns=ROUND_COLS)


def _check_simulation_init(cfg: RoundsConfig, init: Optional[Allocation]):
    """bcd_iters=0 never solves, so the straggler deadline comes entirely
    from the init's makespan T — without one, deadline=0 and every device
    would silently read as late every round."""
    if (cfg.bcd_iters == 0 and cfg.participation != "full"
            and (init is None or init.T is None)):
        raise ValueError(
            "run_rounds: bcd_iters=0 with a straggler participation model "
            f"({cfg.participation!r}) needs an init allocation carrying a "
            "makespan T (e.g. BCDResult.allocation from allocate)")


def run_rounds(key: jax.Array, sys: SystemParams, w: Weights,
               cfg: RoundsConfig,
               acc: Optional[AccuracyModel] = None,
               init: Optional[Allocation] = None) -> RoundsResult:
    """Deprecated shim: the single-cell round scan through `repro.solve`.

    Equivalent to ``solve(Problem(system=sys, weights=w, rounds=cfg,
    key=key, init=init))``. With `cfg.bcd_iters == 0` the init is
    *simulated* unchanged each round (no re-allocation) and must carry a
    makespan `T` for the straggler deadline.
    """
    from repro.api import Problem, solve
    from repro.api.solve import _warn_deprecated

    _warn_deprecated("run_rounds",
                     "Problem(system, weights, rounds=cfg, key=key)")
    return solve(Problem(system=sys, weights=w, acc=acc, init=init,
                         rounds=cfg, key=key))


def run_rounds_fleet(key: jax.Array, sys_batch: SystemParams, w: Weights,
                     cfg: RoundsConfig,
                     acc: Optional[AccuracyModel] = None,
                     init: Optional[Allocation] = None) -> RoundsResult:
    """Deprecated shim: the fleet round scan through `repro.solve`.

    Equivalent to ``solve(Problem(system=sys_batch, weights=w, rounds=cfg,
    key=key, init=init))``. Cell c consumes the c-th split of `key`, so
    results match per-cell `run_rounds` calls with those keys. Per-cell
    weights: pass a sequence of `Weights` as `Problem.weights`.
    """
    from repro.api import Problem, solve
    from repro.api.solve import _warn_deprecated

    _warn_deprecated("run_rounds_fleet",
                     "Problem(system=sys_batch, weights, rounds=cfg, "
                     "key=key)")
    return solve(Problem(system=sys_batch, weights=w, acc=acc, init=init,
                         rounds=cfg, key=key))
