"""Qwen2-72B — dense GQA decoder with QKV bias. [arXiv:2407.10671]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True,
    block_pattern=("attn",),
    rope_theta=1e6,
    tied_embeddings=False,
    source="arXiv:2407.10671",
)
