"""Cross-cell association + mobility churn demo.

Part 1 — BCD-over-association: on a bandwidth-heterogeneous 3x3-cell
region, the static nearest-cell (max-gain) association overloads the
central cells while fat-pipe neighbours idle. `solve(Problem(...,
assoc=AssocConfig(...)))` alternates greedy re-association (marginal
weighted cost, per-cell capacity caps) with per-cell BCD re-solves and
accepts moves only on strict global-objective improvement — so its
realized objective is non-increasing and must beat the static baseline.

Part 2 — mobility churn: a seeded random-waypoint trace moves the
devices, handovers flow into `RegionAllocator.invalidate` as warm-cache
purges, and the replay reports the measured hit rate and warm/cold
re-solve cost under movement.

    PYTHONPATH=src python examples/assoc_mobility.py

REPRO_SMOKE=1 shrinks both traces for CI.
"""
import os
import time

import jax
import numpy as np

from repro import (AssocConfig, MobilityConfig, Problem, RegionAllocator,
                   SolverSpec, Weights, make_multicell, make_system,
                   replay_mobility, simulate_mobility, solve)
from repro.assoc import nearest_assignment

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
C = 4 if SMOKE else 9
N = 24 if SMOKE else 96
STEPS = 5 if SMOKE else 30

W = Weights(0.5, 0.5, 5.0)
SPEC = SolverSpec(max_iters=6, tol=1e-4)
key = jax.random.PRNGKey(0)

# ------------------------------------------------------- association loop
# per-cell bandwidth spread ~8x: nearest-gain association ignores it
bands = [5e6 * (1 + 7 * c / max(C - 1, 1)) for c in range(C)]
sysb = make_multicell(key, n_cells=C, n_devices=N, bandwidth_total=bands)

t0 = time.time()
res = solve(Problem(system=sysb, weights=W,
                    assoc=AssocConfig(outer_iters=8)), SPEC)
wall = time.time() - t0

baseline = res.objectives[0]      # outer iter 0 = static nearest solve
print(f"region: {C} cells x {N} devices, bandwidth "
      f"{min(bands) / 1e6:.0f}-{max(bands) / 1e6:.0f} MHz")
print(f"static nearest-cell objective : {baseline:.4g}")
print(f"BCD-over-association objective: {res.objective:.4g} "
      f"({res.outer_iters} outer iters, moves/iter {res.moves}, "
      f"{wall:.1f}s)")
assert res.objective <= baseline
assert all(b < a for a, b in zip(res.objectives, res.objectives[1:]))
cap = AssocConfig().per_cell_capacity(C, N)
load = np.bincount(np.asarray(res.assignment), minlength=C)
print(f"per-cell load after re-association: {load.tolist()}")
assert (load <= np.asarray(cap)).all()
if res.moves:
    gain_pct = 100.0 * (baseline - res.objective) / abs(baseline)
    print(f"realized objective win over static baseline: {gain_pct:.1f}%")
print("acceptance: objective non-increasing, capacity respected OK")

# ------------------------------------------------------- mobility churn
cfg = MobilityConfig(model="rwp", steps=STEPS, dt=2.0,
                     v_min=2.0, v_max=20.0)
trace = simulate_mobility(jax.random.PRNGKey(1), n_devices=N, n_cells=C,
                          cfg=cfg)
base = make_system(jax.random.PRNGKey(2), n_devices=N)
svc = RegionAllocator(W, cells_per_batch=4, min_bucket=16, spec=SPEC)

t0 = time.time()
rep = replay_mobility(svc, trace, base)
wall = time.time() - t0

print(f"\nmobility: {rep['steps']} steps, {rep['handovers']} handovers, "
      f"{rep['handover_purges']} warm-cache purges "
      f"({rep['requests']} requests in {wall:.1f}s)")
print(f"warm-cache hit rate under churn: {rep['hit_rate']:.0%} "
      f"(warm {rep['warm_solves']} / cold {rep['cold_solves']})")
if rep["warm_solves"]:
    print(f"mean re-solve iters: warm {rep['mean_warm_iters']:.1f}, "
          f"cold {rep['mean_cold_iters']:.1f}")
print(f"compiled batch shapes: {rep['compiled_shapes']}")

assert rep["handover_purges"] == svc.stats["handover_purges"]
assert rep["handover_purges"] <= 2 * rep["handovers"]
assert len(rep["compiled_shapes"]) <= 5
print("acceptance: purge ledger consistent, <= 5 compiled shapes OK")
