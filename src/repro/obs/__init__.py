"""`repro.obs` — unified telemetry: spans, metrics, exporters, report.

The subsystem has two independent planes, deliberately not exported from
`repro.__all__` (import `repro.obs` directly):

  * **event plane** (`recorder`): `span()` context managers and `point()`
    events streamed to an installable `Recorder` (memory / JSONL). Off by
    default — the no-op recorder makes every instrumentation site a
    single predicate check, benchmarked < 2% of serve throughput.
  * **metric plane** (`metrics` + `export`): always-on counters, gauges,
    and fixed-bucket latency histograms in a global registry, exported
    as Prometheus text or metrics JSONL.
  * **SLO & profiling plane** (`slo` + `http` + `profile`): declarative
    objectives with multi-window burn-rate verdicts evaluated over the
    metric plane, a stdlib background HTTP exporter (`GET /metrics`,
    `/healthz`, `/slo` — the repo's wire surface), and opt-in XLA
    profiler trace sessions + compiled-cost gauges.

Device-resident solver counters (BCD iterations, SP1/SP2 dual evals,
convergence residuals) live in `core/bcd.py` as a `counters` leaf of the
jitted result pytree — they stay on device until someone reads them, add
no host syncs and no compiled shapes, and the region/dynamics layers feed
them into this module's per-request events when a recorder is enabled.

Typical use::

    from repro import obs

    with obs.recording(obs.JsonlRecorder("events.jsonl")):
        with obs.span("serve", trace="poisson"):
            ... run the pipeline ...
    # then: python -m repro.obs.report events.jsonl

See `examples/serve_observed.py` for the end-to-end walkthrough.
"""
from .recorder import (
    Recorder, NoopRecorder, MemoryRecorder, JsonlRecorder, NOOP,
    enabled, get_recorder, set_recorder, recording,
    span, point, strip_timing, read_jsonl,
)
from .metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
    counter, gauge, histogram, DEFAULT_BOUNDS,
)
from .export import (prometheus_text, metrics_jsonl, write_metrics_jsonl,
                     parse_prometheus_text)
from .slo import (SLO, SloObserver, SloPlane, BurnWindow, DEFAULT_WINDOWS,
                  LatencyObjective, RatioObjective, default_slos)
from .http import MetricsServer
from . import profile

__all__ = [
    # recorder / spans
    "Recorder", "NoopRecorder", "MemoryRecorder", "JsonlRecorder", "NOOP",
    "enabled", "get_recorder", "set_recorder", "recording",
    "span", "point", "strip_timing", "read_jsonl",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "counter", "gauge", "histogram", "DEFAULT_BOUNDS",
    # exporters
    "prometheus_text", "metrics_jsonl", "write_metrics_jsonl",
    "parse_prometheus_text",
    # SLO plane + wire surface + profiling
    "SLO", "SloObserver", "SloPlane", "BurnWindow", "DEFAULT_WINDOWS",
    "LatencyObjective", "RatioObjective", "default_slos",
    "MetricsServer", "profile",
]
