"""LLaVA-NeXT 34B — VLM: language decoder consuming anyres patch embeddings;
the ViT/SigLIP vision tower + projector is a STUB per the assignment
(input_specs provides projected patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf scaled to the 34B card]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", arch_type="vlm",
    n_layers=60, d_model=7168, n_heads=56, kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    block_pattern=("attn",),
    n_patches=2880,                 # anyres: 4 tiles + base, 576 each
    rope_theta=5e6,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
