"""Planning layer: turn a closed batch of requests into one solve-ready plan.

This is the bucket/chunk planner extracted from the old monolithic
`RegionAllocator.solve()`: group requests by power-of-two device bucket
(`group_requests`, buckets ascending, arrival order within a bucket — the
deterministic grouping the synchronous API always produced), then assemble
each chunk into a fixed-shape `BatchPlan`:

  * every cell's pool is padded to the bucket with masked devices
    (`pad_system`) and warm-started from the `WarmStartCache` when its
    previous solution is still pool-compatible;
  * short chunks are padded to `cells_per_batch` with **all-inactive filler
    cells** (`inactive_system`) instead of replicating a real cell: a fully
    masked cell sits at the masked fixed point, so its BCD lane's rel-step
    is exactly 0 and the lane reports convergence after ONE iteration —
    under the shard-local early exit a shard of pad lanes stops
    immediately instead of re-solving cell 0. Real lanes are
    bit-unaffected (vmapped per-cell programs are independent);
  * per-request weights are collected into the traced (C, 3) operand list
    (pad lanes carry the planner's default weights — sliced off).

All assembly here is HOST-side numpy (the `xp=np` mode of `pad_system` /
`initial_allocation` / `stack_systems`): eager `jnp` ops would enqueue
onto the single device stream, where they (and, past the CPU client's
in-flight cap, the *enqueue calls themselves*) queue behind the previous
batch's solve — serializing exactly the overlap the dispatch layer's
double buffering exists to create. Padding/stacking is pure data movement
and the init is IEEE-exact elementwise math, so the numpy-assembled
operands are bit-identical to the device-assembled ones; the jitted solve
transfers them on dispatch. Host time spent in `plan()` is charged to
`StageClocks.plan_s`.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.bcd import initial_allocation, stack_systems
from repro.core.types import Allocation, SystemParams, Weights

from .admission import AllocationRequest, StageClocks
from .batch import (DEFAULT_MIN_BUCKET, bucket_size, inactive_system,
                    pad_allocation, pad_system)


class WarmStartCache:
    """LRU of previous solutions keyed by cell id: cell_id -> (n, Allocation).

    A re-request of a known cell whose device pool is unchanged warm-starts
    from its last solution (~2 BCD iterations instead of a cold ~4-8). A
    re-request with a *resized* pool can never use the cached solution (the
    shapes differ), so the lookup purges the dead entry immediately instead
    of letting it occupy LRU capacity until overwritten (`resize_purges`
    counts these).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("WarmStartCache: capacity must be >= 1")
        self.capacity = int(capacity)
        self._entries: "OrderedDict[Hashable, Tuple[int, Allocation]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.resize_purges = 0
        self.evictions = 0
        self.handover_purges = 0

    def lookup(self, cell_id: Hashable, n: int) -> Optional[Allocation]:
        """The cell's cached solution if still pool-compatible, else None
        (purging a stale entry whose pool was resized)."""
        cached = self._entries.get(cell_id)
        if cached is None:
            self.misses += 1
            return None
        if cached[0] != n:
            # the dead entry would never be served again — free its slot now
            del self._entries[cell_id]
            self.resize_purges += 1
            self.misses += 1
            return None
        self._entries.move_to_end(cell_id)
        self.hits += 1
        return cached[1]

    def purge(self, cell_id: Hashable) -> bool:
        """Drop a cell's entry outright (mobility handover: the member set
        changed, so the cached solution maps to the wrong devices — even a
        same-size pool must cold-start). Counted in `handover_purges`;
        returns whether an entry was actually dropped."""
        if cell_id in self._entries:
            del self._entries[cell_id]
            self.handover_purges += 1
            return True
        return False

    def store(self, cell_id: Hashable, n: int, alloc: Allocation) -> None:
        self._entries[cell_id] = (int(n), alloc)
        self._entries.move_to_end(cell_id)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, cell_id: Hashable) -> bool:
        return cell_id in self._entries


@dataclasses.dataclass
class BatchPlan:
    """One solve-ready batch: fixed (cells_per_batch, bucket) shapes.

    `requests`/`warm` cover only the `n_real` real lanes (in solve order);
    lanes `n_real..C-1` are all-inactive filler cells."""
    requests: List[AllocationRequest]
    bucket: int
    sys_batch: SystemParams      # (C, bucket) leaves
    init_batch: Allocation       # (C, bucket) leaves
    weights: List[Weights]       # length C
    warm: List[bool]             # length n_real
    n_real: int


def group_requests(requests: Sequence[AllocationRequest],
                   cells_per_batch: int,
                   min_bucket: int = DEFAULT_MIN_BUCKET
                   ) -> List[Tuple[int, List[AllocationRequest]]]:
    """The synchronous grouping: by device-count bucket (ascending), chunked
    to `cells_per_batch` in arrival order. Each `(bucket, chunk)` is one
    compiled-shape solve."""
    by_bucket: Dict[int, List[AllocationRequest]] = {}
    for r in requests:
        by_bucket.setdefault(bucket_size(r.sys.n, min_bucket), []).append(r)
    out: List[Tuple[int, List[AllocationRequest]]] = []
    for bucket in sorted(by_bucket):
        group = by_bucket[bucket]
        for i in range(0, len(group), cells_per_batch):
            out.append((bucket, group[i:i + cells_per_batch]))
    return out


def _pin_floats(tree, dt):
    """Convert a pytree to host numpy with float leaves pinned to `dt` —
    numpy would otherwise widen python-float scalars (box bounds, the
    bandwidth split) to f64 where eager jnp (x32 mode) made them f32,
    silently forking the solve's jit key per array namespace."""
    def conv(x):
        a = np.asarray(x)
        return a.astype(dt) if a.dtype.kind == "f" and a.dtype != dt else a

    return jax.tree_util.tree_map(conv, tree)


def _host_system(sys: SystemParams) -> SystemParams:
    """Pull a request's system to host numpy once, so every downstream
    assembly op stays off the device stream."""
    return _pin_floats(sys, np.asarray(sys.gain).dtype)


def _full_allocation(init: Allocation) -> Allocation:
    """Normalize a warm/cold init to carry s_relaxed and T leaves."""
    if init.s_relaxed is not None and init.T is not None:
        return init
    dt = np.asarray(init.bandwidth).dtype
    return Allocation(
        bandwidth=init.bandwidth, power=init.power,
        freq=init.freq, resolution=init.resolution,
        s_relaxed=init.resolution if init.s_relaxed is None
        else init.s_relaxed,
        T=np.zeros((), dt) if init.T is None else init.T)


class BatchPlanner:
    """Assemble `(bucket, chunk)` groups into fixed-shape `BatchPlan`s.

    Owns the warm-start policy (via the shared `WarmStartCache`) and the
    pad-lane strategy; charges its host time to `clocks.plan_s`.
    """

    def __init__(self, w: Weights, cache: WarmStartCache,
                 cells_per_batch: int,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 clocks: Optional[StageClocks] = None):
        if cells_per_batch < 1:
            raise ValueError("cells_per_batch must be >= 1")
        self.w = w
        self.cache = cache
        self.cells_per_batch = int(cells_per_batch)
        self.min_bucket = int(min_bucket)
        self.clocks = clocks if clocks is not None else StageClocks()

    def group(self, requests: Sequence[AllocationRequest]
              ) -> List[Tuple[int, List[AllocationRequest]]]:
        return group_requests(requests, self.cells_per_batch,
                              self.min_bucket)

    def plan(self, chunk: Sequence[AllocationRequest],
             bucket: int) -> BatchPlan:
        """Pad/stack one chunk (<= cells_per_batch requests of one bucket)
        into a solve-ready plan. Warm flags reflect the cache at *plan*
        time — the pipeline must not plan a cell whose previous solve is
        still in flight (see `RegionPipeline._dirty`)."""
        t0 = time.monotonic()
        C = self.cells_per_batch
        if not 0 < len(chunk) <= C:
            raise ValueError(
                f"plan: chunk of {len(chunk)} requests for "
                f"cells_per_batch={C}")
        padded = [pad_system(_host_system(r.sys), bucket, xp=np)
                  for r in chunk]
        dt = np.asarray(padded[0].gain).dtype
        inits: List[Allocation] = []
        warm: List[bool] = []
        weights = [r.w if r.w is not None else self.w for r in chunk]
        for r, ps in zip(chunk, padded):
            cached = self.cache.lookup(r.cell_id, r.sys.n)
            if cached is None:
                inits.append(_pin_floats(_full_allocation(
                    initial_allocation(ps, xp=np)), dt))
                warm.append(False)
            else:
                inits.append(_pin_floats(_full_allocation(
                    pad_allocation(cached, bucket, ps, xp=np)), dt))
                warm.append(True)
        n_real = len(chunk)
        if n_real < C:
            # all-inactive filler lanes: converge in one masked iteration
            filler_sys = inactive_system(padded[0], xp=np)
            filler_init = _pin_floats(_full_allocation(
                initial_allocation(filler_sys, xp=np)), dt)
            padded.extend([filler_sys] * (C - n_real))
            inits.extend([filler_init] * (C - n_real))
            weights.extend([self.w] * (C - n_real))
        sys_batch = stack_systems(padded, xp=np)
        init_batch = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *inits)
        self.clocks.record("plan", time.monotonic() - t0)
        return BatchPlan(requests=list(chunk), bucket=int(bucket),
                         sys_batch=sys_batch, init_batch=init_batch,
                         weights=weights, warm=warm, n_real=n_real)
