"""Round dynamics: allocate-once vs per-round warm re-allocation under fading.

The paper allocates once against the *expected* channel gain E[G_n] and
multiplies the single-round ledger by R_g. Under realized fading the channel
a device actually sees each round swings by several dB, so the static
allocation overshoots energy on good rounds and misses the deadline on bad
ones. The round-dynamics engine (`repro.dynamics`) re-solves the allocation
each round from the previous round's solution — a couple of warm BCD
iterations — against the sampled gains.

    PYTHONPATH=src python examples/rounds_dynamics.py

Prints the realized per-round ledger of three policies on the same channel
trace: static allocate-once, warm per-round re-allocation, and warm
re-allocation with stragglers + async staleness. REPRO_SMOKE=1 shrinks the
trace for CI.
"""
import os

import jax
import jax.numpy as jnp

from repro import Problem, SolverSpec, Weights, make_system, solve
from repro.dynamics import RoundsConfig

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
N, R = (8, 4) if SMOKE else (24, 16)
key = jax.random.PRNGKey(0)
sysp = make_system(key, n_devices=N)
w = Weights(0.5, 0.5, 1.0)

# one cold solve against E[G_n]: the static policy, and the warm init
base = solve(Problem(system=sysp, weights=w), SolverSpec(max_iters=12))
print(f"cold solve: {base.iters} BCD iters, objective {base.objective:.4g}")

fading = dict(rounds=R, channel_mode="markov", drift_rho=0.9, bcd_tol=1e-3)
policies = {
    # bcd_iters=0: hold the static allocation fixed, just realize the fading
    "static-once": RoundsConfig(bcd_iters=0, **fading),
    # re-solve each round, warm-started from the previous round
    "re-allocate": RoundsConfig(bcd_iters=3, **fading),
    # same, plus dropouts and async staleness for deadline misses
    "re-alloc+async": RoundsConfig(bcd_iters=3, participation="stale",
                                   dropout_prob=0.05, deadline_slack=1.0,
                                   staleness_decay=0.5, **fading),
}

print(f"\n{'policy':>15} {'energy(J)':>10} {'time(s)':>9} {'mean obj':>10} "
      f"{'arrived':>8} {'conv':>5}")
for name, cfg in policies.items():
    # the same solve() entry point: a rounds config routes to the dynamics
    # scan, the PRNG key drives the per-round channel sampling
    rr = solve(Problem(system=sysp, weights=w, rounds=cfg,
                       key=jax.random.PRNGKey(1), init=base.allocation))
    tot = rr.totals()
    print(f"{name:>15} {tot['energy_total_J']:>10.4g} "
          f"{tot['time_total_s']:>9.4g} "
          f"{float(jnp.mean(rr.col('objective'))):>10.4g} "
          f"{tot['mean_arrived_frac']:>8.2f} "
          f"{tot['rounds_converged']:>3d}/{R}")

# per-round view of the async policy (the loop's last rr is that run)
print("\nasync policy, per-round (first 8):")
print(f"{'round':>5} {'energy(J)':>10} {'time(s)':>8} {'late':>5} "
      f"{'dropped':>7} {'arrived':>8}")
for r in range(min(8, R)):
    print(f"{r:>5} {float(rr.col('energy')[r]):>10.4g} "
          f"{float(rr.col('time')[r]):>8.4g} "
          f"{int(rr.col('n_late')[r]):>5d} "
          f"{int(rr.col('n_dropped')[r]):>7d} "
          f"{float(rr.col('arrived_frac')[r]):>8.2f}")
