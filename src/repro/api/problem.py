"""`Problem` — the single description of *what* to solve.

A Problem bundles the system snapshot, the objective weights, and the
optional extras (warm-start init, accuracy model, device mesh, round
dynamics, deadline) that used to be scattered across seven entry-point
signatures. The `solve` dispatcher routes purely on Problem topology:

  * ``system.gain`` 1-D            -> single-cell BCD
  * ``system.gain`` 2-D (C, N)     -> fleet vmap
  * ``mesh`` set                   -> region shard_map
  * ``rounds`` set                 -> round-dynamics scan
  * ``deadline`` set               -> deadline-constrained BCD (Figs. 8-9)

Weights are *data*, not configuration: `weights_leaf` lowers them to a
traced ``(3,)`` / ``(C, 3)`` array operand of the jitted solvers, so every
cell (and every request in a serving trace) can weigh energy / latency /
accuracy differently with **zero** extra compiles — only `SolverSpec` and
shapes key the jit cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accuracy import AccuracyModel
from repro.core.types import Allocation, SystemParams, Weights

Array = jnp.ndarray

#: anything `weights_leaf` lowers: a Weights (scalar or (C,)-array fields),
#: a per-cell sequence of Weights, or a raw (3,)/(C, 3) array-like
WeightsLike = Union[Weights, Sequence[Weights], Array, Sequence[float]]


def weights_leaf(w: WeightsLike, dtype, cells: Optional[int] = None) -> Array:
    """Lower weights to the traced array the jitted solvers consume.

    Returns a normalized ``(3,)`` array (single cell) or ``(C, 3)`` array
    (stacked topologies, with scalar weights broadcast to every cell).
    `Weights` instances are normalized via `Weights.normalized()` (host
    float64, exactly as the legacy entry points did — bit-parity); raw
    arrays are normalized along their last axis.
    """
    # the two Weights branches assemble host floats — build them in numpy:
    # an eager jnp.stack here is a device computation that, on the region
    # serving hot path, queues behind (and blocks on) an in-flight batch
    # solve. Falls back to jnp when a field is already device-resident
    # (e.g. traced (C,) fields).
    if isinstance(w, Weights):
        w = w.normalized()
        try:
            arr = np.stack([np.asarray(w.w1, dtype), np.asarray(w.w2, dtype),
                            np.asarray(w.rho, dtype)], axis=-1)
        except (TypeError, jax.errors.TracerArrayConversionError):
            arr = jnp.stack([jnp.asarray(w.w1, dtype),
                             jnp.asarray(w.w2, dtype),
                             jnp.asarray(w.rho, dtype)], axis=-1)
    elif isinstance(w, (list, tuple)) and w and isinstance(w[0], Weights):
        rows = [wc.normalized() for wc in w]
        try:
            arr = np.asarray([[wc.w1, wc.w2, wc.rho] for wc in rows], dtype)
        except (TypeError, jax.errors.TracerArrayConversionError):
            arr = jnp.asarray([[wc.w1, wc.w2, wc.rho] for wc in rows], dtype)
    else:
        arr = jnp.asarray(w, dtype)
        if arr.ndim == 0 or arr.shape[-1] != 3:
            raise ValueError(
                f"weights_leaf: expected (3,) or (C, 3) (w1, w2, rho) "
                f"values, got shape {arr.shape}")
        s = arr[..., 0] + arr[..., 1]
        try:
            bad = bool(jnp.any(s <= 0))
        except jax.errors.TracerBoolConversionError:
            bad = False   # traced: feasibility is the caller's contract
        if bad:   # same contract as Weights.normalized()
            raise ValueError(
                "w1 + w2 must be positive (paper §VII-A footnote)")
        arr = arr / s[..., None]
    if arr.ndim > 2:
        raise ValueError(f"weights_leaf: too many axes ({arr.shape})")
    if cells is None:
        if arr.ndim != 1:
            raise ValueError(
                f"weights_leaf: single-cell problem, but weights have a "
                f"cell axis ({arr.shape})")
        return arr
    if arr.ndim == 1:
        # follow arr's namespace: a host-assembled row stays host-side
        xp = np if isinstance(arr, np.ndarray) else jnp
        return xp.broadcast_to(arr, (cells, 3))
    if arr.shape[0] != cells:
        raise ValueError(
            f"weights_leaf: {arr.shape[0]} weight rows for {cells} cells")
    return arr


@dataclasses.dataclass
class Problem:
    """One allocation problem: system + weights + optional extras.

    Fields
    ------
    system : a `SystemParams` — 1-D ``gain`` is one cell, 2-D ``(C, N)``
        leaves (from `stack_systems`/`make_fleet`) a fleet.
    weights : objective weights — a `Weights`, a per-cell sequence of
        `Weights`, or a raw (3,)/(C, 3) array. Traced per request; never a
        jit-cache key.
    acc : accuracy model (default `default_accuracy()`).
    init : warm-start `Allocation` (leaves shaped like the system).
    mesh : a jax `Mesh` to shard the cell axis over (stacked systems only).
    rounds : a `dynamics.RoundsConfig` — solve becomes the R-round
        dynamics scan; per-round solver options (bcd_iters/bcd_tol/
        sp*_method) come from the config, which is itself the static jit
        key for the scan.
    key : PRNG key for the dynamics channel/participation sampling
        (required when `rounds` is set).
    deadline : total completion-time budget T_total — solve becomes the
        deadline-constrained variant (single cell, stacked fleet, or
        mesh-sharded region).
    bandwidth_frac : initial bandwidth split fraction for the
        deadline-constrained cold start (Fig. 9 uses 0.5).
    assoc : an `assoc.AssocConfig` — solve becomes the BCD-over-association
        outer loop on a stacked (C, N) cross-cell system (row c = every
        device's gain to cell c; see `assoc.make_multicell`). Composes
        with `mesh` (inner solves shard); exclusive with rounds/deadline.
    """
    system: SystemParams
    weights: WeightsLike
    acc: Optional[AccuracyModel] = None
    init: Optional[Allocation] = None
    mesh: Optional[Any] = None
    rounds: Optional[Any] = None
    key: Optional[jax.Array] = None
    deadline: Optional[float] = None
    bandwidth_frac: float = 1.0
    assoc: Optional[Any] = None

    @property
    def cells(self) -> Optional[int]:
        """C for a stacked (C, N) system, None for a single cell."""
        ndim = jnp.ndim(self.system.gain)
        if ndim == 1:
            return None
        if ndim == 2:
            return int(jnp.asarray(self.system.gain).shape[0])
        raise ValueError(
            f"Problem: system.gain must be (N,) or (C, N), got "
            f"{jnp.asarray(self.system.gain).shape}")
