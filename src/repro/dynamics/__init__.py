"""repro.dynamics — jit-resident FL round-dynamics engine.

Runs R global rounds as one `lax.scan`: per-round sampled channel gains
(iid or AR(1) Gauss-Markov drift), warm-started BCD re-allocation, and a
straggler/dropout/async-staleness participation model, with the realized
energy/time/accuracy ledger accumulated on device. See `dynamics.engine`
for the system picture and ROADMAP ("Channel dynamics", "Async FL rounds").

Public API:
    RoundsConfig, RoundsResult, ROUND_COLS   configuration / result types
    run_rounds                               one cell, R rounds, one scan
    run_rounds_fleet                         vmapped across stacked cells
    staleness_of, queue_step                 participation-model primitives
    MobilityConfig, MobilityTrace            mobility traces (RWP /
    simulate_mobility, replay_mobility       Gauss-Markov) + the handover
                                             churn replay hook
"""
from .config import ROUND_COLS, RoundsConfig, RoundsResult
from .engine import run_rounds, run_rounds_fleet
from .mobility import (MobilityConfig, MobilityTrace, replay_mobility,
                       simulate_mobility, trace_gains)
from .participation import queue_step, staleness_of

__all__ = ["ROUND_COLS", "RoundsConfig", "RoundsResult", "run_rounds",
           "run_rounds_fleet", "queue_step", "staleness_of",
           "MobilityConfig", "MobilityTrace", "simulate_mobility",
           "replay_mobility", "trace_gains"]
