"""Per-architecture cost models: the bridge between the paper's c_n
("CPU cycles per standard sample", eq. 4) and the model zoo.

The paper derives the O(s^2) scaling of per-sample compute from the CNN time
complexity (eqs. 5-6). For the assigned architectures the same role is played
by FLOPs-per-sample of the local workload; `cycles_per_standard_sample`
converts analytic forward+backward FLOPs into "cycles" at a nominal
device throughput so the allocator sees each architecture through the same
c_n interface.

`token_budget(s)` generalizes the resolution knob: the paper's square frame of
s x s pixels maps to a token count proportional to s^2 (ViT-style patching for
VLM frames, mel-frame count for audio, sequence length for LMs), preserving
the paper's quadratic cost-vs-resolution hook.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

FLOPS_PER_CYCLE = 8.0      # nominal client device: flops retired per "cycle"
PATCH = 16                 # ViT-style patch edge for frame -> token conversion


def dense_layer_flops(d_model: int, d_ff: int, n_heads: int, kv_heads: int,
                      head_dim: int, seq: int) -> float:
    """Analytic forward FLOPs for one transformer layer at sequence length seq."""
    qkv = 2 * seq * d_model * (n_heads + 2 * kv_heads) * head_dim
    attn = 2 * 2 * seq * seq * n_heads * head_dim          # scores + values
    out = 2 * seq * n_heads * head_dim * d_model
    mlp = 2 * 3 * seq * d_model * d_ff                     # gated MLP
    return float(qkv + attn + out + mlp)


@dataclasses.dataclass(frozen=True)
class ArchCost:
    name: str
    flops_per_token: float      # fwd flops per token (active params path)
    params_active: float
    params_total: float

    def flops_per_sample(self, tokens_per_sample: int, training: bool = True) -> float:
        mult = 3.0 if training else 1.0  # bwd ~ 2x fwd
        return self.flops_per_token * tokens_per_sample * mult

    def cycles_per_standard_sample(self, tokens_per_sample: int,
                                   training: bool = True) -> float:
        """The paper's c_n for this architecture's local workload."""
        return self.flops_per_sample(tokens_per_sample, training) / FLOPS_PER_CYCLE


def tokens_for_resolution(s_pixels: float, patch: int = PATCH) -> int:
    """Frame of s x s pixels -> token budget (O(s^2), matching eq. 7)."""
    return max(int(s_pixels / patch) ** 2, 1)


def arch_system(key, arch_name: str, n_devices: int = 20,
                device_flops_per_cycle: float = 8192.0,
                samples_per_device: int = 4, local_iters: int = 1,
                **overrides):
    """Build a SystemParams whose c_n comes from an assigned architecture's
    cost model — the DESIGN.md §2 integration: the paper's 'CPU cycles per
    standard sample' becomes FLOPs-per-sample of the local training workload
    at the standard frame's token budget, at a device NPU throughput of
    `device_flops_per_cycle` flops/cycle (default: 8 TFLOP/s @ 1 GHz).

    The allocator then trades the architecture's real compute intensity
    against channel conditions — heavier local models push their devices
    toward lower frame resolutions at equal objective weights."""
    from repro.configs import get_config

    from .channel import make_system
    from .types import DEFAULTS

    cost = from_config(get_config(arch_name))
    std_tokens = tokens_for_resolution(DEFAULTS["s_standard"])
    c = cost.flops_per_sample(std_tokens, training=True) / device_flops_per_cycle
    kw = dict(cycles_lo=c * 0.9, cycles_hi=c * 1.1,
              samples_per_device=samples_per_device, local_iters=local_iters)
    kw.update(overrides)
    return make_system(key, n_devices=n_devices, **kw)


def from_config(cfg) -> ArchCost:
    """Build an ArchCost from a repro.configs model config (duck-typed)."""
    seq = 1  # per-token costs: use seq=1 for the linear terms, attn added by caller
    d = cfg.d_model
    head_dim = cfg.head_dim
    qkv = 2 * d * (cfg.n_heads + 2 * cfg.kv_heads) * head_dim
    out = 2 * cfg.n_heads * head_dim * d
    if getattr(cfg, "n_experts", 0):
        mlp = 2 * 3 * d * cfg.d_ff * cfg.top_k
        expert_params = cfg.n_layers * 3 * d * cfg.d_ff * cfg.n_experts
        active_mlp_params = cfg.n_layers * 3 * d * cfg.d_ff * cfg.top_k
    else:
        mlp = 2 * 3 * d * cfg.d_ff
        expert_params = cfg.n_layers * 3 * d * cfg.d_ff
        active_mlp_params = expert_params
    per_layer = qkv + out + mlp
    embed = 2 * d * cfg.vocab_size
    flops_per_token = cfg.n_layers * per_layer + embed
    attn_params = cfg.n_layers * (d * (cfg.n_heads + 2 * cfg.kv_heads) * head_dim
                                  + cfg.n_heads * head_dim * d)
    params_total = expert_params + attn_params + d * cfg.vocab_size
    params_active = active_mlp_params + attn_params + d * cfg.vocab_size
    return ArchCost(name=cfg.name, flops_per_token=float(flops_per_token),
                    params_active=float(params_active), params_total=float(params_total))
