"""The pipelined region serving stack: admission -> planning -> dispatch ->
completion, with overlapped asynchronous batches.

`RegionPipeline` wires the four layers around one shared `StageClocks` and
one `WarmStartCache`:

  * **admission** (`region.admission`): `submit()` files the request under
    its device-count bucket and returns a `PendingResponse` future; a
    pluggable `BatchPolicy` (close-on-full, max-wait, deadline-slack)
    decides when a bucket's queue closes into a batch.
  * **planning** (`region.planning`): closed batches are padded/stacked
    into fixed-shape `BatchPlan`s, warm-started from the LRU cache.
  * **dispatch** (`region.dispatch`): plans are enqueued through the one
    `solve()` dispatcher WITHOUT blocking — results stay device futures in
    an `InFlightBatch`. Up to `max_in_flight` batches ride the device
    queue concurrently (double buffering by default), so batch k+1's host
    assembly overlaps batch k's device compute.
  * **completion** (`region.completion`): one blocking gather per batch,
    on demand — `PendingResponse.result()`, an explicit `drain()`, or the
    depth bound materializing the oldest batch before a new one is
    planned.

Warm-start coherence: a batch whose results are not yet materialized has
not written the cache, so planning a re-request of an *in-flight* cell
would silently cold-start it (and desync from the synchronous semantics).
The pipeline tracks in-flight cell ids (`_dirty`) and materializes
in-flight batches, oldest first, until the conflict clears — traces where
a cell is requested at most once per batch window (the normal shape) never
stall.

The synchronous `RegionAllocator` (`region.service`) is a thin facade over
this class; `pump()`/`poll()` + `PendingResponse` are the asynchronous
surface for callers that own their event loop.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, Hashable, List, Optional

from repro import obs
from repro.api import SolverSpec
from repro.core.accuracy import AccuracyModel
from repro.core.types import Weights

from .admission import (AdmissionQueue, AllocationRequest, BatchPolicy,
                        StageClocks)
from .batch import DEFAULT_MIN_BUCKET
from .completion import CellResponse, PendingResponse, materialize
from .dispatch import Dispatcher, InFlightBatch
from .planning import BatchPlanner, WarmStartCache


class RegionPipeline:
    """Asynchronous four-layer serving pipeline for region allocation.

    Parameters mirror `RegionAllocator` plus:

    policy : the admission batch-closing policy (default `CloseOnFull`).
    max_in_flight : how many dispatched batches may be unmaterialized at
        once (>= 1). 1 degenerates to the old serial solve-then-gather
        loop; 2 (default) double-buffers host assembly against device
        compute.
    """

    def __init__(self, w: Weights, acc: Optional[AccuracyModel] = None,
                 mesh=None, cells_per_batch: int = 32,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 cache_size: int = 4096,
                 spec: Optional[SolverSpec] = None,
                 policy: Optional[BatchPolicy] = None,
                 max_in_flight: int = 2):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        self.w = w
        self.spec = spec if spec is not None else SolverSpec()
        self.cells_per_batch = int(cells_per_batch)
        self.min_bucket = int(min_bucket)
        self.max_in_flight = int(max_in_flight)
        self.clocks = StageClocks()
        self.cache = WarmStartCache(cache_size)
        self.admission = AdmissionQueue(cells_per_batch, min_bucket,
                                        policy, clocks=self.clocks)
        self.planner = BatchPlanner(w, self.cache, cells_per_batch,
                                    min_bucket, clocks=self.clocks)
        self.dispatcher = Dispatcher(self.spec, acc, mesh,
                                     clocks=self.clocks)
        self._in_flight: Deque[InFlightBatch] = deque()
        self._dirty: Dict[Hashable, int] = {}   # in-flight cell -> count
        self._unclaimed: List[PendingResponse] = []
        self.stats = dict(requests=0, batches=0, cache_hits=0,
                          cache_misses=0, cells_padded=0,
                          handover_purges=0, shapes=set(),
                          cells_solved=0, cells_converged=0,
                          deadline_hits=0, deadline_requests=0,
                          solver_counters={})

    # ------------------------------------------------------------ streaming
    def submit(self, request: AllocationRequest,
               now: Optional[float] = None) -> PendingResponse:
        """Admit one request; returns its future. Nothing is dispatched
        until `pump()`/`poll()` closes a batch (or `result()` forces it)."""
        now = time.monotonic() if now is None else now
        pending = PendingResponse(request, self)
        pending.t_enqueue = now   # end-to-end latency origin (obs events)
        self.admission.submit(request, now, token=pending)
        self._unclaimed.append(pending)
        self.stats["requests"] += 1
        return pending

    def poll(self, now: Optional[float] = None) -> List[InFlightBatch]:
        """Policy-driven pump: close and dispatch whatever the batch policy
        says is ready at `now`. Call this from the serving event loop."""
        return self.pump(now=now, force=False)

    def pump(self, now: Optional[float] = None,
             force: bool = False) -> List[InFlightBatch]:
        """Close ready batches (all of them when `force`), plan and
        dispatch each — materializing oldest in-flight batches first
        whenever dispatching would exceed `max_in_flight`. Returns the
        batches dispatched by this call."""
        dispatched: List[InFlightBatch] = []
        for bucket, entries in self.admission.close_ready(now, force):
            # warm-start coherence: a cell still in flight has not written
            # its solution to the cache yet — drain oldest-first until the
            # conflict clears (no-op for traces without in-window repeats)
            while self._in_flight and any(
                    e.request.cell_id in self._dirty for e in entries):
                self._materialize(self._in_flight[0])
            # depth bound BEFORE planning: at max_in_flight=1 this batch's
            # assembly starts only after the previous gather — exactly the
            # old serial solve-then-gather loop (the bench baseline); at
            # >= 2 the previous batch keeps computing underneath it
            while len(self._in_flight) >= self.max_in_flight:
                self._materialize(self._in_flight[0])
            with obs.span("plan", bucket=bucket, n_real=len(entries)):
                plan = self.planner.plan([e.request for e in entries],
                                         bucket)
            with obs.span("dispatch", bucket=bucket):
                batch = self.dispatcher.dispatch(plan)
            for lane, e in enumerate(entries):
                e.token._bind(batch, lane)
            for r in plan.requests:
                self._dirty[r.cell_id] = self._dirty.get(r.cell_id, 0) + 1
            self.stats["batches"] += 1
            self.stats["shapes"].add((self.cells_per_batch, plan.bucket))
            self.stats["cells_padded"] += self.cells_per_batch - plan.n_real
            self.stats["cache_hits"] += sum(plan.warm)
            self.stats["cache_misses"] += plan.n_real - sum(plan.warm)
            self._in_flight.append(batch)
            dispatched.append(batch)
        return dispatched

    def invalidate(self, cell_id: Hashable) -> bool:
        """Handover invalidation: drop `cell_id`'s warm-start entry (its
        member set changed under mobility, so the cached solution maps to
        the wrong devices — a same-size pool would otherwise warm-hit with
        a stale mapping). A batch still in flight for the cell is
        materialized first so its store cannot resurrect the stale entry.
        Returns whether an entry was dropped; `stats["handover_purges"]`
        mirrors the cache counter."""
        while self._in_flight and cell_id in self._dirty:
            self._materialize(self._in_flight[0])
        purged = self.cache.purge(cell_id)
        self.stats["handover_purges"] = self.cache.handover_purges
        return purged

    def drain(self, now: Optional[float] = None) -> List[CellResponse]:
        """Force-close everything queued, materialize everything in flight,
        and claim all outstanding futures. Responses come back in
        (dispatch order, lane order) — exactly the completion order of the
        old synchronous solve loop."""
        self.pump(now=now, force=True)
        while self._in_flight:
            self._materialize(self._in_flight[0])
        claimed, self._unclaimed = self._unclaimed, []
        claimed.sort(key=lambda p: (p._batch.seq, p._lane))
        return [p.result() for p in claimed]

    # ------------------------------------------------------------ internals
    def _materialize(self, batch: InFlightBatch) -> None:
        with obs.span("materialize", batch_seq=batch.seq):
            materialize(batch, self.cache, self.clocks, self.stats)
        try:
            self._in_flight.remove(batch)
        except ValueError:
            pass   # already removed by an out-of-order result()
        for r in batch.plan.requests:
            left = self._dirty.get(r.cell_id, 0) - 1
            if left <= 0:
                self._dirty.pop(r.cell_id, None)
            else:
                self._dirty[r.cell_id] = left

    def _force(self, pending: PendingResponse) -> None:
        """Drive one future to completion: dispatch its batch if still
        queued, then materialize only that batch (out-of-order OK)."""
        if pending._batch is None:
            self.pump(force=True)
        if pending._batch is None:   # pragma: no cover - defensive
            raise RuntimeError(
                "PendingResponse: request never left the admission queue")
        if not pending._batch.materialized:
            self._materialize(pending._batch)

    # ------------------------------------------------------------ accounting
    @property
    def pending(self) -> int:
        """Requests admitted but not yet dispatched."""
        return self.admission.pending

    @property
    def in_flight(self) -> int:
        """Dispatched batches not yet materialized."""
        return len(self._in_flight)

    @property
    def compiled_shapes(self) -> set:
        """Distinct (cells, devices) batch shapes dispatched so far — one
        jit cache entry each (the bucketing acceptance metric)."""
        return set(self.stats["shapes"])
