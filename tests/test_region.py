"""Region mesh layer: sharded allocate_region / run_rounds_region.

Sharding moves work, not math: per-cell results must match single-device
`allocate_fleet` (lockstep GSPMD and shard_map early-exit modes are the
same select-masked program). Multi-device assertions run when the host
exposes >= 2 devices (CI forces 8 via
XLA_FLAGS=--xla_force_host_platform_device_count=8); a subprocess test
covers the forced-8-device path even from a single-device parent.
"""
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Weights, allocate_fleet, make_fleet
from repro.dynamics import RoundsConfig, run_rounds_fleet
from repro.region import (allocate_region, cell_specs, pad_cells,
                          region_mesh, run_rounds_region)

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _fleet(C=6, N=16, seed=2):
    return make_fleet(jax.random.PRNGKey(seed), n_cells=C, n_devices=N)


def test_allocate_region_matches_fleet_any_mesh():
    """Whatever the local device count (incl. 1), allocate_region agrees
    with allocate_fleet bit for bit — C=6 also exercises cell padding on
    meshes whose size does not divide it."""
    fleet = _fleet()
    w = Weights(0.5, 0.5, 1.0)
    base = allocate_fleet(fleet, w, max_iters=6)
    reg = allocate_region(fleet, w, max_iters=6)
    np.testing.assert_array_equal(np.asarray(base.allocation.bandwidth),
                                  np.asarray(reg.allocation.bandwidth))
    np.testing.assert_array_equal(np.asarray(base.iters),
                                  np.asarray(reg.iters))
    np.testing.assert_array_equal(np.asarray(base.objective),
                                  np.asarray(reg.objective))
    assert reg.stats["cells"] == 6
    assert reg.stats["mesh_devices"] == jax.device_count()
    assert 0.0 <= reg.stats["converged_frac"] <= 1.0
    assert np.isfinite(reg.stats["objective_mean"])


def test_lockstep_and_shardmap_agree():
    fleet = _fleet(C=4, N=12, seed=5)
    w = Weights(0.5, 0.5, 10.0)
    a = allocate_region(fleet, w, max_iters=5, lockstep=True)
    b = allocate_region(fleet, w, max_iters=5, lockstep=False)
    np.testing.assert_array_equal(np.asarray(a.allocation.bandwidth),
                                  np.asarray(b.allocation.bandwidth))
    np.testing.assert_array_equal(np.asarray(a.iters), np.asarray(b.iters))


def test_region_warm_start_init():
    fleet = _fleet(C=3, N=10, seed=7)
    w = Weights(0.5, 0.5, 1.0)
    base = allocate_region(fleet, w, max_iters=30, tol=1e-6)
    fleet2 = fleet.replace(gain=fleet.gain * 1.02)
    warm = allocate_region(fleet2, w, max_iters=30, tol=1e-6,
                           init=base.fleet.allocation)
    assert bool(jnp.all(warm.converged))
    assert warm.stats["iters_max"] <= 3


def test_run_rounds_region_matches_fleet():
    fleet = _fleet(C=5, N=12, seed=3)
    w = Weights(0.5, 0.5, 1.0)
    base = allocate_fleet(fleet, w, max_iters=6)
    cfg = RoundsConfig(rounds=3, channel_mode="markov", bcd_iters=2,
                       participation="stale", dropout_prob=0.05)
    rrf = run_rounds_fleet(jax.random.PRNGKey(7), fleet, w, cfg,
                           init=base.allocation)
    rrr = run_rounds_region(jax.random.PRNGKey(7), fleet, w, cfg,
                            init=base.allocation)
    np.testing.assert_allclose(np.asarray(rrf.ledger),
                               np.asarray(rrr.ledger), rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(rrf.staleness),
                                  np.asarray(rrr.staleness))


@multi_device
def test_region_output_is_sharded_over_cells():
    """Acceptance: the solve really shards the cell axis — the output's
    NamedSharding splits cells across the mesh devices and each addressable
    shard holds C/D cells (sharding introspection, not just parity)."""
    mesh = region_mesh()
    D = int(mesh.devices.size)
    C, N = 2 * D, 12
    fleet = _fleet(C=C, N=N, seed=9)
    reg = allocate_region(fleet, Weights(0.5, 0.5, 1.0), max_iters=4,
                          mesh=mesh)
    B = reg.fleet.allocation.bandwidth
    assert B.shape == (C, N)
    assert len(B.sharding.device_set) == D
    shard_shapes = {s.data.shape for s in B.addressable_shards}
    assert shard_shapes == {(C // D, N)}
    # per-cell scalars shard over cells too
    assert {s.data.shape for s in reg.fleet.objective.addressable_shards} \
        == {(C // D,)}


@multi_device
def test_sharded_matches_single_device_objectives():
    """Acceptance: 8-device allocate_region vs 1-device allocate_fleet
    per-cell objectives to <= 1e-5."""
    mesh = region_mesh()
    C = 2 * int(mesh.devices.size)
    fleet = _fleet(C=C, N=16, seed=11)
    w = Weights(0.5, 0.5, 1.0)
    single = allocate_fleet(fleet, w, max_iters=6)   # default device only
    reg = allocate_region(fleet, w, max_iters=6, mesh=mesh)
    np.testing.assert_allclose(np.asarray(reg.objective),
                               np.asarray(single.objective), rtol=1e-5)


def test_cell_specs_use_region_rules():
    from jax.sharding import PartitionSpec as P

    fleet = _fleet(C=2, N=4)
    specs = cell_specs(fleet)
    assert specs.gain == P("cells", None)
    assert specs.bandwidth_total == P("cells")


def test_pad_cells_replicates_last_cell():
    fleet = _fleet(C=3, N=4)
    padded = pad_cells(fleet, 5)
    assert padded.gain.shape == (5, 4)
    np.testing.assert_array_equal(np.asarray(padded.gain[3]),
                                  np.asarray(padded.gain[2]))


@pytest.mark.slow
def test_forced_eight_device_parity_subprocess():
    """Full acceptance path on any host: force an 8-device CPU platform in
    a subprocess, shard a fleet over it, and check per-cell objective
    parity (<= 1e-5) plus cell-axis sharding introspection."""
    code = r"""
import os, jax, numpy as np
assert jax.device_count() == 8, jax.device_count()
jax.config.update("jax_enable_x64", True)
from repro.core import Weights, allocate_fleet, make_fleet
from repro.region import allocate_region, region_mesh
fleet = make_fleet(jax.random.PRNGKey(11), n_cells=8, n_devices=24)
w = Weights(0.5, 0.5, 1.0)
single = allocate_fleet(fleet, w, max_iters=6)
reg = allocate_region(fleet, w, max_iters=6, mesh=region_mesh())
np.testing.assert_allclose(np.asarray(reg.objective),
                           np.asarray(single.objective), rtol=1e-5)
B = reg.fleet.allocation.bandwidth
assert len(B.sharding.device_set) == 8, B.sharding
assert {s.data.shape for s in B.addressable_shards} == {(1, 24)}
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# mesh-sharded deadline solves (Figs. 8-9 variant under shard_map)
# ---------------------------------------------------------------------------

def test_mesh_deadline_matches_unsharded_fleet():
    """solve(deadline=..., mesh=...) — previously NotImplementedError —
    now shards `_solve_fixed_fleet` over cells and must agree bit for bit
    with the unsharded path, for scalar and per-cell deadlines, in both
    lockstep modes. C=6 exercises padding on non-dividing mesh sizes."""
    from repro import Problem, SolverSpec, solve

    fleet = _fleet(C=6, N=12, seed=7)
    w = Weights(0.5, 0.5, 1.0)
    spec = SolverSpec(max_iters=5, tol=1e-5)
    per_cell = 120.0 + 10.0 * jnp.arange(6, dtype=jnp.float64)
    for deadline in (150.0, per_cell):
        base = solve(Problem(system=fleet, weights=w, deadline=deadline),
                     spec)
        for lockstep in (False, True):
            reg = solve(Problem(system=fleet, weights=w, deadline=deadline,
                                mesh=region_mesh()),
                        SolverSpec(max_iters=5, tol=1e-5,
                                   lockstep=lockstep))
            for leaf, ref in zip(
                    jax.tree_util.tree_leaves(reg.allocation),
                    jax.tree_util.tree_leaves(base.allocation)):
                np.testing.assert_array_equal(np.asarray(leaf),
                                              np.asarray(ref))
            np.testing.assert_array_equal(np.asarray(reg.iters),
                                          np.asarray(base.iters))
            assert reg.fleet.columns == base.columns   # fixed-T ledger kept
            assert reg.stats["cells"] == 6


# ---------------------------------------------------------------------------
# per-shard solver-counter aggregation (RegionResult.stats)
# ---------------------------------------------------------------------------

def test_region_stats_per_shard_counters_sum_to_fleet():
    """RegionResult.stats carries a per-shard aggregation of the device
    counters; summed over shards it must reproduce the unsharded fleet's
    totals. bcd_iters is exact (iters parity is bit-for-bit); the dual-eval
    effort counters ride the data-dependent early exit, so shard_map mode
    gets an integer slack of a few evals per cell."""
    C = 6
    fleet = _fleet(C=C, N=12, seed=13)
    w = Weights(0.5, 0.5, 1.0)
    base = allocate_fleet(fleet, w, max_iters=6)
    assert base.counters is not None
    ctr = np.asarray(base.counters.data)           # (C, 4)
    for lockstep in (True, False):
        reg = allocate_region(fleet, w, max_iters=6, lockstep=lockstep)
        st = reg.stats
        D = st["mesh_devices"]
        for k in ("shard_bcd_iters", "shard_sp1_evals", "shard_sp2_evals",
                  "shard_residual_max"):
            assert len(st[k]) == D, (k, st[k])
        # totals are the shard sums by construction
        for col, key in ((0, "bcd_iters"), (1, "sp1_evals"),
                         (2, "sp2_evals")):
            assert st[f"{key}_total"] == pytest.approx(
                sum(st[f"shard_{key}"]))
        assert st["bcd_iters_total"] == pytest.approx(
            float(np.nansum(ctr[:, 0])))
        slack = 4 * C                              # early-exit attribution
        assert abs(st["sp1_evals_total"]
                   - float(np.nansum(ctr[:, 1]))) <= slack
        assert abs(st["sp2_evals_total"]
                   - float(np.nansum(ctr[:, 2]))) <= slack
        assert st["residual_max"] == pytest.approx(
            float(np.nanmax(ctr[:, 3])), rel=1e-6)


def test_region_shard_blocks_match_mesh_layout():
    """Shard attribution follows the contiguous ceil(C/D) block layout of
    `place_cells`: recomputing the blocks host-side from the unsharded
    counters reproduces every per-shard entry (pad cells contribute 0)."""
    mesh = region_mesh()
    D = int(mesh.devices.size)
    C = max(2 * D - 1, 3)                          # force padding when D>1
    fleet = _fleet(C=C, N=10, seed=17)
    w = Weights(0.5, 0.5, 1.0)
    reg = allocate_region(fleet, w, max_iters=6, mesh=mesh, lockstep=True)
    ctr = np.asarray(reg.fleet.counters.data)      # (C, 4) sharded result
    block = -(-C // D)
    pad = np.zeros((block * D - C, 4))
    blocks = np.concatenate([ctr, pad]).reshape(D, block, 4)
    st = reg.stats
    np.testing.assert_allclose(st["shard_bcd_iters"],
                               np.nansum(blocks[..., 0], axis=1))
    np.testing.assert_allclose(st["shard_sp1_evals"],
                               np.nansum(blocks[..., 1], axis=1))
    np.testing.assert_allclose(st["shard_sp2_evals"],
                               np.nansum(blocks[..., 2], axis=1))
