"""Differentiable allocation walkthrough: Pareto sweep + weight auto-tune.

Three sections over one cell, all powered by `repro.diff` (PR 10):

  1. **Pareto sweep** — replicate the cell across a (w1, w2) weight grid
     and solve the whole sweep as ONE vmapped fleet program
     (`diff.pareto_sweep`), then print the energy/latency frontier with
     the per-point dE/dw1 sensitivities that implicit KKT
     differentiation provides for free.
  2. **Weight auto-tune** — start from a deliberately mis-weighted
     scenario (w1=0.9: all-energy, latency ignored), give
     `diff.tune_weights` a latency budget of 0.9x that operating point,
     and watch projected gradient descent on log-weights walk the cell
     onto its budget.
  3. **Gradient check** — one `solve_and_grad` call vs central finite
     differences of the forward `solve()` on kappa, printed side by
     side (f64).

    PYTHONPATH=src python examples/pareto_sweep.py

REPRO_SMOKE=1 shrinks the grid and tuning steps to CI-smoke size.
"""
import dataclasses
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro import Problem, SolverSpec, Weights, make_system, solve
from repro.diff import pareto_sweep, solve_and_grad, tune_weights

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
N_GRID = 7 if SMOKE else 17
N_DEV = 6 if SMOKE else 10
STEPS = 8 if SMOKE else 24

SPEC = SolverSpec(sp1_method="bisect", tol=1e-10, max_iters=200)


def _cast64(sysp):
    d = {}
    for f in dataclasses.fields(sysp):
        v = getattr(sysp, f.name)
        if f.name in ("resolutions", "active") or v is None:
            d[f.name] = v
        else:
            d[f.name] = jnp.asarray(v, jnp.float64)
    return type(sysp)(**d)


def main():
    sysp = _cast64(make_system(jax.random.PRNGKey(3), n_devices=N_DEV))

    # -- 1. Pareto sweep: the whole weight grid in one compiled program --
    prob = Problem(system=sysp, weights=Weights(0.5, 0.5, 0.3))
    sweep = pareto_sweep(prob, SPEC, n=N_GRID)
    e = np.asarray(sweep.value["energy"], float)
    t = np.asarray(sweep.value["time"], float)
    de_dw1 = np.asarray(sweep.grads["energy"][:, 0], float)
    print(f"== Pareto sweep ({N_GRID} weight points, one vmapped solve) ==")
    print(f"{'w1':>6} {'w2':>6} {'energy':>10} {'time':>10} "
          f"{'dE/dw1':>12} {'front':>6}")
    for i in range(N_GRID):
        w1, w2 = sweep.weights[i, 0], sweep.weights[i, 1]
        mark = "  *" if sweep.front[i] else ""
        print(f"{w1:6.3f} {w2:6.3f} {e[i]:10.3f} {t[i]:10.3f} "
              f"{de_dw1[i]:12.4f} {mark:>6}")
    n_front = int(sweep.front.sum())
    print(f"frontier: {n_front}/{N_GRID} non-dominated points, "
          f"energy {e[sweep.front].min():.2f}..{e[sweep.front].max():.2f} J "
          f"vs time {t[sweep.front].min():.2f}..{t[sweep.front].max():.2f} s")

    # -- 2. Auto-tune a mis-weighted cell onto a latency budget ----------
    bad = Problem(system=sysp, weights=Weights(0.9, 0.1, 0.3))
    g0 = solve_and_grad(bad, SPEC, wrt=())
    t0 = float(g0.value["time"])
    target = 0.9 * t0
    print(f"\n== Weight auto-tune (budget = 0.9 x T0) ==")
    print(f"start:  w=(0.900, 0.100)  T={t0:.3f}s  "
          f"E={float(g0.value['energy']):.3f}J  budget={target:.3f}s")
    out = tune_weights(bad, SPEC, target_time=target, steps=STEPS)
    w = out.weights
    print(f"tuned:  w=({float(w.w1):.3f}, {float(w.w2):.3f})  "
          f"T={out.value['time']:.3f}s  E={out.value['energy']:.3f}J  "
          f"met={out.met}  ({out.steps} steps)")
    for i, h in enumerate(out.history):
        print(f"  step {i:2d}: w1={h['w1']:.3f} "
              f"T={h['time']:8.3f} E={h['energy']:8.3f} "
              f"loss={h['loss']:.4f}")
    if not out.met:
        raise SystemExit("tuner failed to meet the latency budget")

    # -- 3. Implicit gradient vs central finite differences --------------
    g = solve_and_grad(prob, SPEC, wrt=("kappa",))
    v = float(sysp.kappa)
    h = v * 1e-6

    def obj(kv):
        return float(solve(Problem(system=sysp.replace(kappa=kv),
                                   weights=prob.weights), SPEC).objective)

    fd = (obj(v + h) - obj(v - h)) / (2 * h)
    ad = float(g.grads["objective"]["kappa"])
    rel = abs(ad - fd) / max(abs(fd), 1e-12)
    print(f"\n== Gradient check (kappa, f64) ==")
    print(f"implicit-KKT: {ad: .6e}   central FD: {fd: .6e}   "
          f"rel err {rel:.2e}")
    if rel > 1e-3:
        raise SystemExit(f"gradient parity failed: rel err {rel:.2e}")
    print("OK")


if __name__ == "__main__":
    main()
