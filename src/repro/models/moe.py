"""Mixture-of-Experts layer: top-k router + capacity-based dispatch
(GShard/Switch style), expert- or tensor-parallel via logical axes.

Dispatch is the dense one-hot einsum formulation: with capacity
C = ceil(k * tokens * capacity_factor / E) the expert compute is
E * C * mlp_flops ~= k * tokens * mlp_flops — the correct *active* FLOPs
(important for the roofline numbers; a dropless "all experts see all tokens"
formulation would inflate compute by E/k).

Router: softmax over experts, top-k, renormalized gates; load-balance aux
loss (Switch-style mean(gates) . mean(assignment) * E).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.sharding.partition import shard


def init_moe(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    sd = (2.0 / (d_model + d_ff)) ** 0.5
    return dict(
        router=(jax.random.normal(ks[0], (d_model, n_experts)) * 0.02).astype(jnp.float32),
        wi=(jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * sd).astype(dtype),
        wg=(jax.random.normal(ks[2], (n_experts, d_model, d_ff)) * sd).astype(dtype),
        wo=(jax.random.normal(ks[3], (n_experts, d_ff, d_model)) * sd).astype(dtype),
    )


def _top_k_gates(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits (..., E) -> (gates (..., E) sparse renormalized, aux_loss)."""
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)
    gates = jnp.zeros_like(probs)
    gates = jnp.put_along_axis(gates, top_idx, top_vals, axis=-1, inplace=False)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean((gates > 0).astype(jnp.float32).reshape(-1, E), axis=0)
    aux = jnp.sum(me * ce) * E
    return gates, aux


def apply_moe(p: dict, x: jax.Array, top_k: int,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss). Capacity-based top-k dispatch.

    Gather/scatter formulation: token indices are scattered into per-expert
    capacity slots (an overflow slot absorbs drops), then tokens are GATHERED
    (B,E,C,D) — O(S) memory instead of the O(S^2) one-hot dispatch einsum."""
    B, S, D = x.shape
    E = p["router"].shape[-1]
    cap = max(int(top_k * S * capacity_factor / E), 1)     # per-batch-row capacity

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    gates, aux = _top_k_gates(logits, top_k)               # (B,S,E)

    assigned = gates > 0
    pos_in_e = jnp.cumsum(assigned.astype(jnp.int32), axis=1) - 1   # (B,S,E)
    keep = assigned & (pos_in_e < cap)
    slot = jnp.where(keep, pos_in_e, cap)                  # cap = overflow slot

    b_ix = jnp.arange(B)[:, None, None]
    e_ix = jnp.arange(E)[None, None, :]
    s_ix = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, E))
    sidx = jnp.zeros((B, E, cap + 1), jnp.int32)
    sidx = sidx.at[b_ix, e_ix, slot].set(s_ix, mode="drop")
    filled = jnp.zeros((B, E, cap + 1), jnp.bool_)
    filled = filled.at[b_ix, e_ix, slot].set(keep, mode="drop")
    sidx, filled = sidx[..., :cap], filled[..., :cap]      # (B,E,C)

    # gate value of each filled slot
    gsel = jnp.take_along_axis(gates.transpose(0, 2, 1), sidx, axis=2)
    gsel = jnp.where(filled, gsel, 0.0).astype(x.dtype)    # (B,E,C)

    xe = x[jnp.arange(B)[:, None, None], sidx]             # gather (B,E,C,D)
    xe = jnp.where(filled[..., None], xe, 0)
    xe = shard(xe, "batch", "experts", None, None)
    h = jnp.einsum("becd,edf->becf", xe, p["wi"])
    g = jnp.einsum("becd,edf->becf", xe, p["wg"])
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("becf,efd->becd", h, p["wo"])          # (B,E,C,D)
    ye = shard(ye, "batch", "experts", None, None)

    out = jnp.zeros_like(x)
    out = out.at[jnp.arange(B)[:, None, None], sidx].add(
        ye * gsel[..., None], mode="drop")                 # weighted combine
    return out, aux
