"""Assigned-architecture configs (--arch <id>). Every config cites its source.

`get_config(name)` returns the full production config; `.reduced()` gives the
CPU smoke-test variant (2 layers-ish, d_model<=128, <=4 experts).
"""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .qwen2_72b import CONFIG as qwen2_72b
from .minicpm3_4b import CONFIG as minicpm3_4b
from .rwkv6_1b6 import CONFIG as rwkv6_1b6
from .whisper_large_v3 import CONFIG as whisper_large_v3
from .jamba_1_5_large import CONFIG as jamba_1_5_large
from .dbrx_132b import CONFIG as dbrx_132b
from .llava_next_34b import CONFIG as llava_next_34b
from .granite_34b import CONFIG as granite_34b
from .internlm2_20b import CONFIG as internlm2_20b
from .flmar_cnn import CONFIG as flmar_cnn

ARCHS: Dict[str, ModelConfig] = {
    "mixtral-8x7b": mixtral_8x7b,
    "qwen2-72b": qwen2_72b,
    "minicpm3-4b": minicpm3_4b,
    "rwkv6-1.6b": rwkv6_1b6,
    "whisper-large-v3": whisper_large_v3,
    "jamba-1.5-large-398b": jamba_1_5_large,
    "dbrx-132b": dbrx_132b,
    "llava-next-34b": llava_next_34b,
    "granite-34b": granite_34b,
    "internlm2-20b": internlm2_20b,
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ModelConfig", "ARCHS", "get_config", "flmar_cnn"]
