"""Exporters for the metrics registry: Prometheus text + metrics JSONL.

Two renderings of the same `MetricsRegistry` snapshot:

  * `prometheus_text(registry)` — Prometheus text exposition format
    (counters as `*_total`, histograms as cumulative `_bucket{le=...}`
    series plus `_sum`/`_count`), suitable for a textfile collector or a
    scrape endpoint;
  * `metrics_jsonl(registry)` / `write_metrics_jsonl(path)` — one JSON
    object per metric with explicit percentiles, the format CI uploads
    as an artifact and `benchmarks/compare.py` can diff.

Both snapshot under no lock beyond the registry's own accessors: metric
mutation is monotone, so a torn read is at worst one observation stale.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, List, Optional, Tuple

from .metrics import MetricsRegistry, REGISTRY

__all__ = ["prometheus_text", "metrics_jsonl", "write_metrics_jsonl",
           "parse_prometheus_text"]


def _name(raw: str) -> str:
    """Prometheus metric names allow [a-zA-Z0-9_:] only."""
    return "".join(c if c.isalnum() or c in "_:" else "_" for c in raw)


def _labels(pairs, extra: str = "") -> str:
    parts = [f'{_name(k)}="{v}"' for k, v in pairs]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _num(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v)


def prometheus_text(registry: Optional[MetricsRegistry] = None) -> str:
    """Render the registry in Prometheus text exposition format."""
    reg = registry if registry is not None else REGISTRY
    lines: List[str] = []
    seen_type = set()

    def header(name: str, kind: str):
        if name not in seen_type:
            seen_type.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for c in reg.counters():
        n = _name(c.name) + "_total"
        header(n, "counter")
        lines.append(f"{n}{_labels(c.labels)} {_num(c.value)}")

    for g in reg.gauges():
        n = _name(g.name)
        header(n, "gauge")
        lines.append(f"{n}{_labels(g.labels)} {_num(g.value)}")

    for h in reg.histograms():
        n = _name(h.name)
        header(n, "histogram")
        cum = 0
        for bound, count in zip(h.bounds, h.buckets):
            cum += count
            if count:   # sparse exposition: emit only occupied edges + +Inf
                le = 'le="%s"' % _num(bound)
                lines.append(f"{n}_bucket{_labels(h.labels, le)} {cum}")
        le_inf = 'le="+Inf"'
        lines.append(f"{n}_bucket{_labels(h.labels, le_inf)} {h.count}")
        lines.append(f"{n}_sum{_labels(h.labels)} {_num(h.sum)}")
        lines.append(f"{n}_count{_labels(h.labels)} {h.count}")
        if h.dropped:
            # non-finite observations excluded from the series above
            nd = n + "_dropped_total"
            header(nd, "counter")
            lines.append(f"{nd}{_labels(h.labels)} {h.dropped}")

    return "\n".join(lines) + ("\n" if lines else "")


_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$')
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="([^"]*)"')


def parse_prometheus_text(text: str
                          ) -> Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                                    float]:
    """Parse Prometheus text exposition back into
    `{(name, sorted (k, v) label pairs): value}` — the round-trip check
    for `prometheus_text` (scrape smoke tests, the compare.py SLO gate).

    Covers the subset this repo emits: one sample per line, `# TYPE`/`#`
    comment lines skipped, label values quoted without escapes. Malformed
    sample lines raise ValueError — a scrape endpoint that stops parsing
    should fail loudly."""
    out: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"parse_prometheus_text: bad sample {line!r}")
        labels = tuple(sorted(
            (k, v) for k, v in _LABEL_RE.findall(m.group("labels") or "")))
        out[(m.group("name"), labels)] = float(m.group("value"))
    return out


def metrics_jsonl(registry: Optional[MetricsRegistry] = None
                  ) -> List[Dict[str, Any]]:
    """The registry as a list of JSON-ready dicts, one per metric.

    Histogram records carry derived p50/p90/p99 so downstream consumers
    (CI artifacts, `benchmarks/compare.py`) never re-implement bucket
    interpolation.
    """
    reg = registry if registry is not None else REGISTRY
    out: List[Dict[str, Any]] = []
    for c in reg.counters():
        out.append(dict(kind="counter", name=c.name, labels=dict(c.labels),
                        value=c.value))
    for g in reg.gauges():
        out.append(dict(kind="gauge", name=g.name, labels=dict(g.labels),
                        value=g.value))
    for h in reg.histograms():
        rec = dict(kind="histogram", name=h.name, labels=dict(h.labels),
                   count=h.count, sum=h.sum)
        if h.dropped:
            rec["dropped"] = h.dropped
        if h.count:
            rec.update(min=h.min, max=h.max, mean=h.mean,
                       **h.percentiles((50.0, 90.0, 99.0)))
        out.append(rec)
    return out


def write_metrics_jsonl(path: str,
                        registry: Optional[MetricsRegistry] = None) -> int:
    """Write `metrics_jsonl` records to `path`; returns the record count."""
    records = metrics_jsonl(registry)
    with open(path, "w") as fh:
        for rec in records:
            fh.write(json.dumps(rec))
            fh.write("\n")
    return len(records)
