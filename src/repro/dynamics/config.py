"""Round-dynamics configuration and result types.

`RoundsConfig` is a frozen (hashable) dataclass so the whole configuration is
a single static jit argument — every field change recompiles the engine once
and the scan itself stays free of host-side branching.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp

from repro.core.types import Allocation

Array = jnp.ndarray

# per-round ledger column order (one row per global round). sp2_evals is
# the round's SP2 dual-eval count from the solver's device counters
# (`core.bcd._COUNTER_COLS`) — warm-started rounds should spend fewer
# evals than a cold re-solve.
ROUND_COLS = ("objective", "energy", "time", "accuracy", "arrived_frac",
              "n_late", "n_dropped", "bcd_iters", "bcd_converged",
              "sp2_evals")

_CHANNEL_MODES = ("static", "iid", "markov")
_PARTICIPATION_MODES = ("full", "drop", "stale")


@dataclasses.dataclass(frozen=True)
class RoundsConfig:
    """Static configuration of the round engine (see `dynamics.engine`).

    channel_mode:
        "static" — every round sees the expected gain E[G_n] (the paper's
        Jensen setting; reproduces the allocate-once ledger),
        "iid"    — fresh lognormal shadowing per round (`sample_gain`),
        "markov" — AR(1) Gauss-Markov shadowing drift (`drift_shadowing`),
        round-to-round correlation `drift_rho`.
    participation:
        "full"  — every active device's update aggregates this round,
        "drop"  — deadline misses (realized makespan > deadline_slack * T)
        are discarded,
        "stale" — deadline misses arrive k rounds later with FedAvg mass
        discounted by staleness_decay**k (k <= max_staleness).
    dropout_prob: iid probability a device sits a round out entirely
        (no training, no energy spent, no update).
    bcd_iters: warm-started BCD iterations per round; 0 disables
        re-allocation (pure simulation of the init allocation — the init
        must then carry a makespan T for the straggler deadline).
    """
    rounds: int = 10
    # channel dynamics
    channel_mode: str = "static"
    shadowing_db: float = 8.0
    drift_rho: float = 0.9
    # warm-started per-round re-allocation; warm_start=False re-solves from
    # the paper's cold init every round (the ablation baseline — a cold BCD
    # needs ~2-3x the iterations of a warm re-solve under correlated fading)
    bcd_iters: int = 8
    bcd_tol: float = 1e-6
    warm_start: bool = True
    sp1_method: str = "sweep"
    sp2_method: str = "direct"
    sp2_iters: int = 30
    # participation model
    participation: str = "full"
    dropout_prob: float = 0.0
    deadline_slack: float = 1.0
    max_staleness: int = 4
    staleness_decay: float = 0.5

    def __post_init__(self):
        if self.channel_mode not in _CHANNEL_MODES:
            raise ValueError(f"channel_mode must be one of {_CHANNEL_MODES}")
        if self.participation not in _PARTICIPATION_MODES:
            raise ValueError(
                f"participation must be one of {_PARTICIPATION_MODES}")
        if self.rounds < 1:
            raise ValueError("rounds must be >= 1")
        if self.max_staleness < 1:
            raise ValueError("max_staleness must be >= 1")
        if not 0.0 <= self.drift_rho <= 1.0:
            raise ValueError("drift_rho must be in [0, 1] (AR(1) stability)")
        if not 0.0 <= self.dropout_prob < 1.0:
            raise ValueError("dropout_prob must be in [0, 1)")
        if not 0.0 < self.staleness_decay <= 1.0:
            raise ValueError("staleness_decay must be in (0, 1]")
        if self.deadline_slack <= 0.0:
            raise ValueError("deadline_slack must be positive")
        if self.bcd_iters == 0 and not self.warm_start:
            # nothing would ever be solved: the engine would simulate the
            # paper cold init (T=0) forever, deadline 0, everything late
            raise ValueError("bcd_iters=0 requires warm_start=True "
                             "(it simulates the carried init allocation)")


@dataclasses.dataclass
class RoundsResult:
    """Output of `run_rounds` (leading axis R) / `run_rounds_fleet` (C, R).

    allocation: the final round's Allocation — (N,) leaves (fleet: (C, N)).
    ledger:     (R, len(ROUND_COLS)) per-round scalars (fleet: (C, R, cols)).
    staleness:  (R, N) int32 per-device participation code: -1 = update lost
                (dropout, or deadline miss in "drop" mode), 0 = arrived on
                time, k > 0 = arrives k rounds late ("stale" mode).
    gains:      (R, N) realized channel gains each round.
    resolutions: (R, N) per-round allocated frame resolutions s_n (round r's
                training ran at resolutions[r], not at the final round's).
    """
    allocation: Allocation
    ledger: Array
    staleness: Array
    gains: Array
    resolutions: Array
    columns: tuple = ROUND_COLS

    def col(self, name: str) -> Array:
        return self.ledger[..., self.columns.index(name)]

    def totals(self) -> Dict[str, float]:
        """Aggregate energy/time ledger (single-cell results only)."""
        if self.ledger.ndim != 2:
            raise ValueError(
                "totals() is per-cell: index a fleet result's leading cell "
                "axis first (ledger has shape "
                f"{tuple(self.ledger.shape)})")
        e, t = self.col("energy"), self.col("time")
        return dict(
            energy_total_J=float(jnp.sum(e)),
            time_total_s=float(jnp.sum(t)),
            energy_per_round_J=float(jnp.mean(e)),
            time_per_round_s=float(jnp.mean(t)),
            mean_arrived_frac=float(jnp.mean(self.col("arrived_frac"))),
            rounds_converged=int(jnp.sum(self.col("bcd_converged") > 0)),
        )
