"""Property harness for the cross-cell association outer loop.

The BCD-over-association loop has sharp invariants, checked here both
deterministically and (when hypothesis is installed) property-style over
random scenarios:

  * partition — every active device is served by exactly one cell;
  * capacity — per-cell caps are never exceeded;
  * descent — the accepted global weighted objective is non-increasing
    across outer iterations (the accept/reject construction);
  * fixed point — re-running from a converged assignment does not move it;
  * degeneration — outer_iters=0 reproduces the fixed-association fleet
    solve of the initial (static nearest) assignment bit-identically.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro import (AssocConfig, Problem, SolverSpec, Weights, solve,
                   solve_assoc)
from repro.assoc import make_multicell, nearest_assignment
from repro.assoc.loop import _base_active, greedy_assign, marginal_costs

W = Weights(0.5, 0.5, 5.0)
SPEC = SolverSpec(max_iters=6, tol=1e-5)


def _scenario(seed=0, C=3, N=24, **kw):
    kw.setdefault("bandwidth_total", [5e6 * (c + 1) for c in range(C)])
    return make_multicell(jax.random.PRNGKey(seed), n_cells=C, n_devices=N,
                          **kw)


def _alloc_equal(a, b):
    eq = jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)
    return all(jax.tree_util.tree_leaves(eq))


def _check_invariants(sysb, res, capacity=None):
    C, N = np.asarray(sysb.gain).shape
    assign = np.asarray(res.assignment)
    active = _base_active(sysb)
    # partition: every active device in exactly one cell, inactive unserved
    assert assign.shape == (N,)
    assert ((assign >= 0) & (assign < C))[active].all()
    assert (assign[~active] == -1).all()
    # capacity respected
    load = np.bincount(assign[active], minlength=C)
    cap = AssocConfig(capacity=capacity).per_cell_capacity(C, N) \
        if capacity is not None else np.full(C, N)
    assert (load <= cap).all(), (load, cap)
    # monotone accepted objective, finite
    objs = np.asarray(res.objectives)
    assert np.isfinite(objs).all()
    assert (np.diff(objs) < 0).all()   # accepted only on strict improvement
    assert res.objective == objs[-1]


# ---------------------------------------------------------------------------
# deterministic invariant checks
# ---------------------------------------------------------------------------

def test_assoc_partition_and_capacity():
    sysb = _scenario()
    res = solve_assoc(Problem(system=sysb, weights=W,
                              assoc=AssocConfig(outer_iters=6)), SPEC)
    _check_invariants(sysb, res)


def test_assoc_capacity_caps_bind():
    C, N = 3, 24
    sysb = _scenario(C=C, N=N)
    cap = -(-N // C) + 1   # tight-ish per-cell cap
    res = solve_assoc(Problem(system=sysb, weights=W,
                              assoc=AssocConfig(outer_iters=6,
                                                capacity=cap)), SPEC)
    _check_invariants(sysb, res, capacity=cap)


def test_assoc_capacity_infeasible_raises():
    sysb = _scenario(C=3, N=24)
    with pytest.raises(ValueError, match="capacity"):
        solve_assoc(Problem(system=sysb, weights=W,
                            assoc=AssocConfig(capacity=(3, 3, 3))), SPEC)


def test_assoc_objective_monotone_and_improves():
    """On a bandwidth-heterogeneous region, BCD-over-association beats the
    static nearest-gain baseline (= objectives[0])."""
    sysb = _scenario(seed=1, C=3, N=32)
    res = solve_assoc(Problem(system=sysb, weights=W,
                              assoc=AssocConfig(outer_iters=8)), SPEC)
    _check_invariants(sysb, res)
    if res.moves:   # a move was accepted -> strict win over the baseline
        assert res.objective < res.objectives[0]


def test_assoc_fixed_point_stable_under_rerun():
    sysb = _scenario(seed=2)
    cfg = AssocConfig(outer_iters=10, warm_start=False)
    p = Problem(system=sysb, weights=W, assoc=cfg)
    run1 = solve_assoc(p, SPEC)
    assert run1.converged
    run2 = solve_assoc(p, SPEC, assign0=run1.assignment)
    assert np.array_equal(run2.assignment, run1.assignment)
    assert run2.moves == []
    assert run2.objective == pytest.approx(run1.objective)


def test_assoc_outer0_bitparity_with_fleet_solve():
    """assoc disabled (outer_iters=0) IS the fixed-association fleet solve
    of the nearest assignment — bit-identical allocations."""
    sysb = _scenario(seed=3)
    res = solve_assoc(Problem(system=sysb, weights=W,
                              assoc=AssocConfig(outer_iters=0)), SPEC)
    assert res.converged and res.outer_iters == 0
    cap = AssocConfig().per_cell_capacity(*np.asarray(sysb.gain).shape)
    assert np.array_equal(res.assignment, nearest_assignment(sysb, cap))
    masked = sysb.with_assignment(jnp.asarray(res.assignment))
    direct = solve(Problem(system=masked, weights=W), SPEC)
    assert _alloc_equal(res.fleet.allocation, direct.allocation)
    assert np.array_equal(np.asarray(res.fleet.iters),
                          np.asarray(direct.iters))


def test_assoc_routes_through_solve_dispatcher():
    sysb = _scenario(seed=4)
    cfg = AssocConfig(outer_iters=4, warm_start=False)
    via_solve = solve(Problem(system=sysb, weights=W, assoc=cfg), SPEC)
    direct = solve_assoc(Problem(system=sysb, weights=W, assoc=cfg), SPEC)
    assert np.array_equal(via_solve.assignment, direct.assignment)
    assert via_solve.objectives == direct.objectives
    assert _alloc_equal(via_solve.fleet.allocation, direct.fleet.allocation)


def test_assoc_validation_errors():
    sysb = _scenario()
    single = sysb.cell(0)
    with pytest.raises(ValueError, match="stacked"):
        solve(Problem(system=single, weights=W, assoc=AssocConfig()), SPEC)
    with pytest.raises(ValueError, match="exclusive"):
        solve(Problem(system=sysb, weights=W, assoc=AssocConfig(),
                      deadline=100.0), SPEC)
    with pytest.raises(ValueError, match="max_iters"):
        solve(Problem(system=sysb, weights=W, assoc=AssocConfig()),
              SolverSpec(max_iters=0))
    with pytest.raises(ValueError, match="outer_iters"):
        AssocConfig(outer_iters=-1)


def test_with_assignment_mask_semantics():
    sysb = _scenario(C=3, N=8)
    assign = np.array([0, 1, 2, 0, 1, 2, -1, 0], np.int32)
    masked = sysb.with_assignment(assign)
    act = np.asarray(masked.active)
    assert act.shape == (3, 8)
    for n, c in enumerate(assign):
        col = np.zeros(3, bool)
        if c >= 0:
            col[c] = True
        assert np.array_equal(act[:, n], col)
    # composes with an existing base mask
    base = sysb.replace(active=jnp.zeros((3, 8), bool).at[:, :4].set(True))
    act2 = np.asarray(base.with_assignment(assign).active)
    assert not act2[:, 4:].any()


def test_cell_view_indexes_every_leaf():
    sysb = _scenario(C=3, N=8)
    c1 = sysb.cell(1)
    assert np.asarray(c1.gain).shape == (8,)
    assert np.array_equal(np.asarray(c1.gain), np.asarray(sysb.gain)[1])
    assert float(c1.bandwidth_total) == float(
        np.asarray(sysb.bandwidth_total)[1])
    assert c1.resolutions == sysb.resolutions
    # a single-cell view is solvable as-is
    r = solve(Problem(system=c1, weights=W), SolverSpec(max_iters=2))
    assert r.iters == 2


def test_greedy_assign_deterministic_and_capped():
    rng = np.random.default_rng(0)
    cost = rng.standard_normal((4, 20))
    cap = np.array([5, 5, 5, 5])
    active = np.ones(20, bool)
    order = np.arange(20)
    a1 = greedy_assign(cost, cap, active, order)
    a2 = greedy_assign(cost, cap, active, order)
    assert np.array_equal(a1, a2)
    assert (np.bincount(a1, minlength=4) <= cap).all()


def test_marginal_costs_shape_and_finiteness():
    sysb = _scenario(C=3, N=16)
    cap = AssocConfig().per_cell_capacity(3, 16)
    assign = nearest_assignment(sysb, cap)
    masked = sysb.with_assignment(jnp.asarray(assign))
    fleet = solve(Problem(system=masked, weights=W), SPEC)
    from repro.api.problem import weights_leaf
    from repro.core.accuracy import default_accuracy
    warr = np.asarray(weights_leaf(W, np.float64, cells=3))
    cost = marginal_costs(masked, warr, default_accuracy(),
                          fleet.allocation, assign)
    assert cost.shape == (3, 16)
    assert np.isfinite(cost).all()


# ---------------------------------------------------------------------------
# hypothesis property suite (skips when hypothesis is unavailable)
# ---------------------------------------------------------------------------

@settings(max_examples=10)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_cells=st.integers(min_value=2, max_value=4),
       n_devices=st.integers(min_value=6, max_value=24))
def test_property_association_invariants(seed, n_cells, n_devices):
    """Partition + capacity + monotone descent over random scenarios."""
    sysb = make_multicell(jax.random.PRNGKey(seed), n_cells=n_cells,
                          n_devices=n_devices)
    res = solve_assoc(Problem(system=sysb, weights=W,
                              assoc=AssocConfig(outer_iters=4)),
                      SolverSpec(max_iters=4, tol=1e-4))
    _check_invariants(sysb, res)


@settings(max_examples=10)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_property_capacity_never_exceeded(seed):
    rng = np.random.default_rng(seed)
    C, N = 4, 20
    cost = rng.standard_normal((C, N))
    cap = rng.integers(5, N, size=C)
    while cap.sum() < N:
        cap[rng.integers(C)] += 1
    assign = greedy_assign(cost, cap, np.ones(N, bool), np.arange(N))
    assert (assign >= 0).all()
    assert (np.bincount(assign, minlength=C) <= cap).all()


@settings(max_examples=5)
@given(seed=st.integers(min_value=0, max_value=2 ** 10))
def test_property_fixed_point_rerun(seed):
    sysb = make_multicell(jax.random.PRNGKey(seed), n_cells=3, n_devices=12)
    cfg = AssocConfig(outer_iters=8, warm_start=False)
    p = Problem(system=sysb, weights=W, assoc=cfg)
    spec = SolverSpec(max_iters=4, tol=1e-4)
    run1 = solve_assoc(p, spec)
    if not run1.converged:
        return   # cap hit before the fixed point; nothing to re-run
    run2 = solve_assoc(p, spec, assign0=run1.assignment)
    assert np.array_equal(run2.assignment, run1.assignment)
