"""Wire surface: a stdlib background HTTP exporter for the telemetry.

The repo's first network endpoint — everything before this PR exported
telemetry as files (metrics JSONL / Prometheus textfiles). `MetricsServer`
runs a `http.server.ThreadingHTTPServer` on a daemon thread and serves:

  * ``GET /metrics``  — the live registry in Prometheus text exposition
    format (`obs.export.prometheus_text`), scrape-ready;
  * ``GET /healthz``  — liveness JSON (status, uptime, scrape count);
  * ``GET /slo``      — burn-rate verdicts from an attached
    `slo.SloPlane` (`{"slos": [...]}`; empty list when none attached).

Serving is pure host-side Python over the always-on registry: a scrape
never touches JAX, never blocks on device work, and never compiles
anything (compile-count-guarded in tests/test_slo.py). Registry reads are
lock-free snapshots — metric mutation is monotone, so a torn read is at
worst one observation stale (same contract as `obs.export`).

Default bind is loopback with an ephemeral port (`port=0`); read the
bound port from `server.port` after `start()`. Use as a context manager
for scoped serving::

    with MetricsServer(slo_plane=plane) as srv:
        print(srv.url("/metrics"))
        ... serve traffic ...
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .export import prometheus_text
from .metrics import MetricsRegistry, REGISTRY

__all__ = ["MetricsServer", "PROMETHEUS_CONTENT_TYPE"]

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _Handler(BaseHTTPRequestHandler):
    # set per-server via the factory in MetricsServer.start
    server_ref: "MetricsServer"

    def do_GET(self):   # noqa: N802 - BaseHTTPRequestHandler API
        srv = self.server_ref
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            srv.registry.counter("obs_scrapes", path="/metrics").inc()
            body = prometheus_text(srv.registry).encode()
            self._reply(200, PROMETHEUS_CONTENT_TYPE, body)
        elif path == "/healthz":
            srv.registry.counter("obs_scrapes", path="/healthz").inc()
            body = json.dumps(dict(
                status="ok",
                uptime_s=time.monotonic() - srv._t_start)).encode()
            self._reply(200, "application/json", body)
        elif path == "/slo":
            srv.registry.counter("obs_scrapes", path="/slo").inc()
            plane = srv.slo_plane
            verdicts = plane.check() if plane is not None else []
            body = json.dumps(dict(slos=verdicts),
                              allow_nan=False).encode()
            self._reply(200, "application/json", body)
        else:
            self._reply(404, "application/json",
                        b'{"error": "not found", '
                        b'"paths": ["/metrics", "/healthz", "/slo"]}')

    def _reply(self, code: int, ctype: str, body: bytes) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):   # silence per-request stderr spam
        pass


class MetricsServer:
    """Background scrape endpoint over one registry (+ optional SLO plane).

    Parameters
    ----------
    registry : the `MetricsRegistry` to expose (default: the global one).
    slo_plane : an `slo.SloPlane` whose `check()` backs ``/slo``.
    host, port : bind address; `port=0` (default) picks an ephemeral port,
        available as `self.port` after `start()`.
    observe_period_s : when set (and an `slo_plane` is attached), `start()`
        also spins up an `slo.SloObserver` daemon sampling the plane's
        burn-rate rings every that many seconds, so ``/slo`` verdicts stay
        window-accurate even when nobody scrapes and the serving loop
        stalls. The observer is stopped (cleanly, mid-sleep) on `stop()`.
    observe_clock : injectable clock for that observer (tests drive the
        rings with logical ticks).
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 slo_plane=None, host: str = "127.0.0.1", port: int = 0,
                 observe_period_s: Optional[float] = None,
                 observe_clock=None):
        self.registry = registry if registry is not None else REGISTRY
        self.slo_plane = slo_plane
        self.host = host
        self.port = int(port)
        self.observe_period_s = observe_period_s
        self.observe_clock = observe_clock
        self.observer = None
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._t_start = time.monotonic()

    def start(self) -> "MetricsServer":
        if self._httpd is not None:
            return self
        handler = type("BoundHandler", (_Handler,), dict(server_ref=self))
        self._httpd = ThreadingHTTPServer((self.host, self.port), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._t_start = time.monotonic()
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True, name="obs-http")
        self._thread.start()
        if self.observe_period_s is not None and self.slo_plane is not None:
            from .slo import SloObserver   # local: no import cycle at load
            self.observer = SloObserver(self.slo_plane,
                                        period_s=self.observe_period_s,
                                        clock=self.observe_clock).start()
        return self

    def stop(self) -> None:
        if self._httpd is None:
            return
        if self.observer is not None:
            self.observer.stop()
            self.observer = None
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join()
        self._httpd = None
        self._thread = None

    @property
    def running(self) -> bool:
        return self._httpd is not None

    def url(self, path: str = "/metrics") -> str:
        if not path.startswith("/"):
            path = "/" + path
        return f"http://{self.host}:{self.port}{path}"

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False
