"""Test-suite configuration: enable x64 up front so module ordering cannot
change solver/kernel dtypes mid-suite (the allocator tests need f64
bisections; kernels pin their own compute dtypes).

Hypothesis (optional — property tests skip without it) runs under named
profiles: "ci" is fully pinned (derandomized, no deadline, bounded
examples) so the quick CI job is reproducible run-to-run; "dev" keeps
random exploration locally but drops the per-example deadline, which jit
compilation on first draw would always blow. Select with
HYPOTHESIS_PROFILE=ci (the quick CI job does)."""
import os

import jax

jax.config.update("jax_enable_x64", True)

try:
    from hypothesis import HealthCheck, settings
except ImportError:
    pass
else:
    settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=20,
        suppress_health_check=list(HealthCheck))
    settings.register_profile(
        "dev", deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
