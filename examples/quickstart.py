"""Quickstart: allocate resources for an FL-MAR cell through the unified
solver API and inspect the result.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import Problem, SolverSpec, Weights, make_system, solve
from repro.core import default_accuracy, feasible, summarize

key = jax.random.PRNGKey(0)
system = make_system(key, n_devices=20)          # paper §VII-A parameters
weights = Weights(w1=0.5, w2=0.5, rho=30.0)      # energy/time/accuracy trade

# one entry point: Problem says WHAT (system + weights), SolverSpec says HOW.
# tol=1e-4 sits above the f32 rel-step floor (~7.6e-6) — a tighter tol on an
# f32 system would be floored there (and solve() says so, once)
result = solve(Problem(system=system, weights=weights), SolverSpec(tol=1e-4))
alloc = result.allocation

print(f"converged={result.converged} in {result.iters} BCD iterations")
print(f"feasible={feasible(system, alloc)}")
print("per-device resolution choices:", sorted(set(alloc.resolution.tolist())))
for k, v in summarize(system, weights.normalized(), default_accuracy(), alloc).items():
    print(f"  {k}: {v:.5g}")
