"""SLO plane: declarative objectives + multi-window burn-rate verdicts.

The metric plane (`obs.metrics`) already aggregates everything a serving
deployment produces — fixed-bucket latency histograms, deadline and
convergence counters. This module evaluates *objectives* directly over
those aggregates, Google-SRE style:

  * an `SLO` binds a name, a target good-event ratio (`objective`, e.g.
    0.99 -> a 1% error budget), a **source** that reads cumulative
    `(good, total)` event counts out of a `MetricsRegistry`, and a set of
    `BurnWindow`s;
  * `SloPlane.check(now)` snapshots every source, computes the burn rate
    over each window — ``(bad_delta / total_delta) / error_budget``, i.e.
    how many times faster than "exactly on budget" the budget is being
    spent — and returns JSON-ready verdicts. A window with no traffic
    burns at 0. The verdict is the classic multi-window AND: ``breach``
    only when EVERY window exceeds its `max_burn_rate` (fast window =
    it's happening now, slow window = it's not a blip), ``warn`` when
    some but not all do, ``ok`` otherwise, ``no_data`` before the first
    event.

Sources (both pure registry reads — no device work, no compiles):

  * `LatencyObjective`: good = observations at or under `threshold_s` in
    a histogram. The threshold snaps UP to the nearest bucket edge of the
    shared `DEFAULT_BOUNDS` layout (<= ~7%, the bucket growth factor), so
    the count is exact with respect to the snapped threshold.
  * `RatioObjective`: good/total from two counters (deadline hits vs
    deadlined requests, converged cells vs solved cells, ...).

Windows are measured on the caller's clock: every `observe`/`check`
takes `now` (default `time.monotonic()`), so tests drive burn-rate math
with logical ticks. `check` also publishes its verdicts back into the
registry (`slo_good_ratio`, `slo_burn_rate{window=...}`,
`slo_budget_remaining`, `slo_breaching` gauges) so a `/metrics` scrape
sees SLO state next to the raw series.

`default_slos()` returns the repo's three serving objectives (p99 solve
latency, request deadline-hit rate, per-round BCD convergence rate) over
the metric names the region completion layer maintains.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import Histogram, MetricsRegistry, REGISTRY

__all__ = [
    "BurnWindow", "DEFAULT_WINDOWS", "LatencyObjective", "RatioObjective",
    "SLO", "SloObserver", "SloPlane", "default_slos",
]


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One burn-rate alerting window: the budget-spend rate averaged over
    the trailing `seconds` must stay under `max_burn_rate` (1.0 = spending
    exactly the whole budget over the objective period)."""
    name: str
    seconds: float
    max_burn_rate: float


# fast window catches an active incident, slow window filters blips —
# the standard 14.4x/6x pair scaled to serving-bench horizons
DEFAULT_WINDOWS: Tuple[BurnWindow, ...] = (
    BurnWindow("fast", 60.0, 14.4),
    BurnWindow("slow", 600.0, 6.0),
)


@dataclasses.dataclass(frozen=True)
class LatencyObjective:
    """good = histogram observations <= `threshold_s` (snapped up to the
    next bucket edge); total = all finite observations. `labels` must
    match the instrument site exactly (sorted (k, v) pairs)."""
    metric: str
    threshold_s: float
    labels: Tuple[Tuple[str, str], ...] = ()

    def counts(self, registry: MetricsRegistry) -> Tuple[float, float]:
        h: Histogram = registry.histogram(self.metric, **dict(self.labels))
        good = 0
        for bound, n in zip(h.bounds, h.buckets):
            good += n
            if bound >= self.threshold_s:
                break
        else:
            good = h.count   # threshold above the layout: everything good
        return float(good), float(h.count)

    def describe(self) -> Dict[str, object]:
        return dict(kind="latency", metric=self.metric,
                    threshold_s=self.threshold_s, labels=dict(self.labels))


@dataclasses.dataclass(frozen=True)
class RatioObjective:
    """good/total from two cumulative counters (e.g. deadline hits over
    deadlined requests)."""
    good_metric: str
    total_metric: str
    labels: Tuple[Tuple[str, str], ...] = ()

    def counts(self, registry: MetricsRegistry) -> Tuple[float, float]:
        lbl = dict(self.labels)
        return (registry.counter(self.good_metric, **lbl).value,
                registry.counter(self.total_metric, **lbl).value)

    def describe(self) -> Dict[str, object]:
        return dict(kind="ratio", good_metric=self.good_metric,
                    total_metric=self.total_metric, labels=dict(self.labels))


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative objective: `source.counts(registry)` must keep its
    good ratio at or above `objective` (error budget = 1 - objective)."""
    name: str
    objective: float
    source: object               # LatencyObjective | RatioObjective | duck
    windows: Tuple[BurnWindow, ...] = DEFAULT_WINDOWS

    def __post_init__(self):
        if not 0.0 < self.objective < 1.0:
            raise ValueError(
                f"SLO {self.name!r}: objective must be in (0, 1), got "
                f"{self.objective} (1.0 leaves a zero error budget — no "
                f"finite burn rate exists)")

    @property
    def error_budget(self) -> float:
        return 1.0 - self.objective


class SloPlane:
    """Evaluates a set of `SLO`s over one registry with windowed history.

    The plane keeps, per SLO, a ring of `(t, good, total)` snapshots taken
    by `observe()` (call it from the serving loop — once per flush/poll is
    plenty) and closed by `check()`. Burn rates difference the latest
    snapshot against the newest sample at least `window.seconds` old; a
    ring that doesn't yet span the window falls back to its oldest sample
    (the whole observed history), so short traces still get verdicts.
    """

    def __init__(self, slos: Sequence[SLO],
                 registry: Optional[MetricsRegistry] = None,
                 max_samples: int = 4096):
        names = [s.name for s in slos]
        if len(set(names)) != len(names):
            raise ValueError(f"SloPlane: duplicate SLO names in {names}")
        self.slos: Tuple[SLO, ...] = tuple(slos)
        self.registry = registry if registry is not None else REGISTRY
        self.max_samples = int(max_samples)
        self._rings: Dict[str, List[Tuple[float, float, float]]] = {
            s.name: [] for s in self.slos}

    # ------------------------------------------------------------ sampling
    def observe(self, now: Optional[float] = None) -> None:
        """Snapshot every SLO's cumulative (good, total) at `now`."""
        now = time.monotonic() if now is None else float(now)
        for slo in self.slos:
            good, total = slo.source.counts(self.registry)
            ring = self._rings[slo.name]
            ring.append((now, float(good), float(total)))
            if len(ring) > self.max_samples:
                # decimate the old half: keeps coverage of long horizons
                # without unbounded memory
                del ring[1:len(ring) // 2:2]

    # ------------------------------------------------------------ verdicts
    def check(self, now: Optional[float] = None) -> List[Dict[str, object]]:
        """Evaluate every SLO; returns JSON-ready verdict dicts (and
        mirrors them into `slo_*` gauges in the registry)."""
        now = time.monotonic() if now is None else float(now)
        self.observe(now)
        out: List[Dict[str, object]] = []
        for slo in self.slos:
            out.append(self._check_one(slo, now))
        return out

    def _check_one(self, slo: SLO, now: float) -> Dict[str, object]:
        ring = self._rings[slo.name]
        t_last, good, total = ring[-1]
        budget = slo.error_budget
        windows = []
        n_breach = 0
        for w in slo.windows:
            t0, g0, n0 = self._sample_at(ring, now - w.seconds)
            dg, dn = good - g0, total - n0
            burn = ((dn - dg) / dn) / budget if dn > 0 else 0.0
            breach = burn > w.max_burn_rate
            n_breach += bool(breach)
            windows.append(dict(name=w.name, seconds=w.seconds,
                                burn_rate=burn,
                                max_burn_rate=w.max_burn_rate,
                                breach=breach))
        if total <= 0:
            verdict = "no_data"
            good_ratio = None
            budget_remaining = None
        else:
            good_ratio = good / total
            budget_remaining = 1.0 - (1.0 - good_ratio) / budget
            verdict = ("breach" if n_breach == len(windows) and windows
                       else "warn" if n_breach else "ok")
        self._publish(slo, good_ratio, budget_remaining, windows, verdict)
        return dict(name=slo.name, objective=slo.objective,
                    source=slo.source.describe(),
                    good=good, total=total, good_ratio=good_ratio,
                    budget_remaining=budget_remaining,
                    windows=windows, verdict=verdict)

    @staticmethod
    def _sample_at(ring, t: float) -> Tuple[float, float, float]:
        """The newest sample no newer than `t` (the window-start state);
        the oldest sample when the ring doesn't reach back that far."""
        best = ring[0]
        for s in ring:
            if s[0] > t:
                break
            best = s
        return best

    def _publish(self, slo: SLO, good_ratio, budget_remaining, windows,
                 verdict: str) -> None:
        reg = self.registry
        if good_ratio is not None:
            reg.gauge("slo_good_ratio", slo=slo.name).set(good_ratio)
            reg.gauge("slo_budget_remaining",
                      slo=slo.name).set(budget_remaining)
        for w in windows:
            reg.gauge("slo_burn_rate", slo=slo.name,
                      window=w["name"]).set(w["burn_rate"])
        reg.gauge("slo_breaching",
                  slo=slo.name).set(1.0 if verdict == "breach" else 0.0)
        reg.counter("slo_checks", slo=slo.name).inc()


class SloObserver:
    """Timer-driven `SloPlane.observe()` on a daemon thread.

    Burn-rate windows need *regularly spaced* ring samples: a plane only
    sampled from the serving loop goes blind exactly when serving stalls —
    the incident the SLOs exist to catch. The observer decouples sampling
    from traffic: every `period_s` it calls `plane.observe(clock())`.

    * `clock` is injectable (default `time.monotonic`): tests drive
      burn-rate math with logical ticks and never sleep through windows.
    * the loop waits on a `threading.Event`, so `stop()` interrupts a
      sleeping observer immediately — no stray period-length hang at
      shutdown (`MetricsServer` stops its observer on exit).
    * sampling is pure registry reads (no device work, no compiles), so a
      short period is cheap; `ticks` counts completed observations.

    Use standalone (`start()`/`stop()`, or as a context manager) or let
    `MetricsServer(observe_period_s=...)` own one.
    """

    def __init__(self, plane: SloPlane, period_s: float = 5.0,
                 clock=None):
        if period_s <= 0:
            raise ValueError(
                f"SloObserver: period_s must be positive, got {period_s}")
        self.plane = plane
        self.period_s = float(period_s)
        self.clock = clock if clock is not None else time.monotonic
        self.ticks = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self) -> None:
        # one sample up front: short-lived runs still get a ring entry
        while True:
            self.plane.observe(self.clock())
            self.ticks += 1
            if self._stop.wait(self.period_s):
                return

    def start(self) -> "SloObserver":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="slo-observer")
        self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = None) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout)
        self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "SloObserver":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


def default_slos(latency_threshold_s: float = 0.5,
                 latency_objective: float = 0.99,
                 deadline_objective: float = 0.95,
                 convergence_objective: float = 0.90,
                 windows: Tuple[BurnWindow, ...] = DEFAULT_WINDOWS
                 ) -> Tuple[SLO, ...]:
    """The repo's three serving objectives over the metric names the
    region completion layer maintains (`region.completion`):

      * serve_latency_p99 — request latency under `latency_threshold_s`
        for `latency_objective` of requests;
      * deadline_hit_rate — deadlined requests materialized before their
        deadline;
      * bcd_convergence   — cells whose BCD solve converged.
    """
    return (
        SLO("serve_latency_p99", latency_objective,
            LatencyObjective("region_request_latency_seconds",
                             latency_threshold_s), windows),
        SLO("deadline_hit_rate", deadline_objective,
            RatioObjective("region_deadline_hits",
                           "region_deadline_requests"), windows),
        SLO("bcd_convergence", convergence_objective,
            RatioObjective("region_solve_converged_cells",
                           "region_solve_cells"), windows),
    )
