"""InternLM2-20B — dense GQA decoder. [arXiv:2403.17297]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", arch_type="dense",
    n_layers=48, d_model=6144, n_heads=48, kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92544,
    block_pattern=("attn",),
    rope_theta=1e6,
    source="arXiv:2403.17297",
)
