"""Implicit-KKT gradient parity (PR 10): `repro.diff.solve_and_grad`
against central finite differences of the forward `solve()` oracle on all
three topologies (single cell, stacked fleet with per-cell weights, padded
cell), pad-lane gradient zeroing, loose descent-direction checks for the
one-sided channel leaves, and the zero-new-compiled-shapes guard.

FD parity runs in float64 (the suite enables x64 in conftest) with a
tight forward spec so the bisection floor sits well below the FD step.
"""
import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

from repro import Problem, SolverSpec, Weights, make_system, solve
from repro.diff import solve_and_grad
from repro.region.batch import pad_system

SPEC = SolverSpec(sp1_method="bisect", tol=1e-11, max_iters=300)
RTOL = 1e-3
LEAVES = ("kappa", "cycles", "samples")


def _cast64(sysp):
    d = {}
    for f in dataclasses.fields(sysp):
        v = getattr(sysp, f.name)
        if f.name in ("resolutions", "active") or v is None:
            d[f.name] = v
        else:
            d[f.name] = jnp.asarray(v, jnp.float64)
    return type(sysp)(**d)


def _single():
    sysp = _cast64(make_system(jax.random.PRNGKey(3), n_devices=8))
    return Problem(system=sysp, weights=Weights(0.4, 0.6, 0.3))


def _padded():
    base = _cast64(make_system(jax.random.PRNGKey(3), n_devices=6))
    return Problem(system=pad_system(base, 8), weights=Weights(0.4, 0.6, 0.3))


def _fleet():
    cells = [_cast64(make_system(jax.random.PRNGKey(k), n_devices=8))
             for k in (3, 5, 9)]
    stack = jtu.tree_map(lambda *xs: jnp.stack(xs), *cells)
    ws = [Weights(0.4, 0.6, 0.3), Weights(0.5, 0.5, 0.2),
          Weights(0.3, 0.7, 0.4)]
    return Problem(system=stack, weights=ws), cells, ws


def _obj(problem):
    return solve(problem, SPEC).objective


def _fd_leaf(problem, name, mask=None, rel=1e-6):
    """Central FD of solve()'s objective w.r.t. one SystemParams leaf."""
    sysp = problem.system
    v = jnp.asarray(getattr(sysp, name))
    if v.ndim == 0:
        h = abs(float(v)) * rel
        op = _obj(dataclasses.replace(
            problem, system=sysp.replace(**{name: v + h})))
        om = _obj(dataclasses.replace(
            problem, system=sysp.replace(**{name: v - h})))
        return (op - om) / (2 * h)
    out = []
    for i in range(v.shape[0]):
        if mask is not None and not bool(mask[i]):
            out.append(0.0)
            continue
        h = max(abs(float(v[i])), 1e-12) * rel
        op = _obj(dataclasses.replace(
            problem, system=sysp.replace(**{name: v.at[i].add(h)})))
        om = _obj(dataclasses.replace(
            problem, system=sysp.replace(**{name: v.at[i].add(-h)})))
        out.append(float((op - om) / (2 * h)))
    return jnp.asarray(out)


def _fd_weights(problem, rel=1e-6):
    wr = jnp.asarray([problem.weights.w1, problem.weights.w2,
                      problem.weights.rho], jnp.float64)
    out = []
    for i in range(3):
        h = float(wr[i]) * rel
        wp = Weights(*[float(x) for x in wr.at[i].add(h)])
        wm = Weights(*[float(x) for x in wr.at[i].add(-h)])
        op = _obj(dataclasses.replace(problem, weights=wp))
        om = _obj(dataclasses.replace(problem, weights=wm))
        out.append(float((op - om) / (2 * h)))
    return jnp.asarray(out)


def _assert_close(ad, fd, rtol=RTOL, floor=1e-12):
    ad, fd = np.asarray(ad, float), np.asarray(fd, float)
    denom = np.maximum(np.abs(fd), floor)
    rel = np.max(np.abs(ad - fd) / denom)
    assert rel <= rtol, f"max rel err {rel:.3e} (ad={ad}, fd={fd})"


# ---------------------------------------------------------------------------
# value consistency: solve_and_grad's primal IS the forward solve
# ---------------------------------------------------------------------------

def test_value_matches_solve_single_and_padded():
    for prob in (_single(), _padded()):
        g = solve_and_grad(prob, SPEC, wrt=("kappa",))
        r = solve(prob, SPEC)
        np.testing.assert_allclose(float(g.value["objective"]),
                                   float(r.objective), rtol=1e-8)
        np.testing.assert_allclose(np.asarray(g.allocation.freq),
                                   np.asarray(r.allocation.freq), rtol=1e-8)


def test_value_matches_solve_fleet():
    probf, _, _ = _fleet()
    g = solve_and_grad(probf, SPEC, wrt=("kappa",))
    r = solve(probf, SPEC)
    np.testing.assert_allclose(np.asarray(g.value["objective"]),
                               np.asarray(r.objective), rtol=1e-8)


# ---------------------------------------------------------------------------
# FD parity: single cell
# ---------------------------------------------------------------------------

def test_single_cell_weights_fd_parity():
    prob = _single()
    g = solve_and_grad(prob, SPEC, wrt=())
    _assert_close(g.grads["objective"]["weights"], _fd_weights(prob))


@pytest.mark.parametrize("leaf", LEAVES)
def test_single_cell_leaf_fd_parity(leaf):
    prob = _single()
    g = solve_and_grad(prob, SPEC, wrt=LEAVES)
    _assert_close(g.grads["objective"][leaf], _fd_leaf(prob, leaf))


# ---------------------------------------------------------------------------
# FD parity: fleet with per-cell weights (one vmapped program)
# ---------------------------------------------------------------------------

def test_fleet_kappa_fd_parity_per_cell():
    probf, cells, ws = _fleet()
    gf = solve_and_grad(probf, SPEC, wrt=("kappa",))
    for c in range(3):
        v = float(cells[c].kappa)
        h = v * 1e-6

        def obj_c(kv):
            cc = [cells[i].replace(kappa=jnp.asarray(kv, jnp.float64))
                  if i == c else cells[i] for i in range(3)]
            st = jtu.tree_map(lambda *xs: jnp.stack(xs), *cc)
            return float(solve(Problem(system=st, weights=ws),
                               SPEC).objective[c])

        fd = (obj_c(v + h) - obj_c(v - h)) / (2 * h)
        ad = float(gf.grads["objective"]["kappa"][c])
        _assert_close(ad, fd)


def test_fleet_weights_fd_parity_cell0():
    probf, cells, ws = _fleet()
    gf = solve_and_grad(probf, SPEC, wrt=())
    wr = jnp.asarray([ws[0].w1, ws[0].w2, ws[0].rho], jnp.float64)
    for i in range(3):
        h = float(wr[i]) * 1e-6

        def obj_w(wv):
            wmod = [Weights(*[float(x) for x in wv]) if c == 0 else ws[c]
                    for c in range(3)]
            return float(solve(dataclasses.replace(probf, weights=wmod),
                               SPEC).objective[0])

        fd = (obj_w(wr.at[i].add(h)) - obj_w(wr.at[i].add(-h))) / (2 * h)
        ad = float(gf.grads["objective"]["weights"][0, i])
        _assert_close(ad, fd)


# ---------------------------------------------------------------------------
# FD parity: padded cell (inactive lanes must not contaminate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("leaf", LEAVES)
def test_padded_leaf_fd_parity(leaf):
    prob = _padded()
    g = solve_and_grad(prob, SPEC, wrt=LEAVES)
    mask = np.asarray(prob.system.active)
    fd = _fd_leaf(prob, leaf, mask=None if leaf == "kappa" else mask)
    _assert_close(g.grads["objective"][leaf], fd)


def test_padded_pad_lane_grads_exactly_zero():
    prob = _padded()
    g = solve_and_grad(prob, SPEC, wrt=("cycles", "samples", "gain"))
    pad = ~np.asarray(prob.system.active)
    for m in ("objective", "energy", "time", "accuracy"):
        for leaf in ("cycles", "samples", "gain"):
            lanes = np.asarray(g.grads[m][leaf])[pad]
            assert np.all(lanes == 0.0), (m, leaf, lanes)


def test_padded_weights_fd_parity():
    prob = _padded()
    g = solve_and_grad(prob, SPEC, wrt=())
    _assert_close(g.grads["objective"]["weights"], _fd_weights(prob))


# ---------------------------------------------------------------------------
# channel-side leaves: one-sided KKT derivatives — descent directions only
# ---------------------------------------------------------------------------

def test_gain_grad_finite_and_descent_direction():
    prob = _single()
    g = solve_and_grad(prob, SPEC, wrt=("gain",))
    gg = np.asarray(g.grads["objective"]["gain"])
    assert np.all(np.isfinite(gg))
    # better channel never makes the realized objective worse
    assert np.all(gg <= 1e-9), gg


# ---------------------------------------------------------------------------
# compile-count guard: repeat solves add zero compiled shapes
# ---------------------------------------------------------------------------

def test_grad_no_new_compiled_shapes(compile_counter):
    prob = _single()
    solve_and_grad(prob, SPEC, wrt=LEAVES)          # warm the cache
    before = compile_counter.count
    g = solve_and_grad(prob, SPEC, wrt=LEAVES)
    jax.block_until_ready(g.value["objective"])
    assert compile_counter.count == before, (
        f"{compile_counter.count - before} recompiles on a repeated "
        "solve_and_grad with identical shapes/spec")
