"""Launch-layer + roofline unit tests (no 512-device env needed: these test
the pure functions the dry-run composes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.specs import (LONG_WINDOW, SHAPES, adapt_config, batch_specs,
                                decode_cache_len, supported)
from repro.roofline import analytic_costs, roofline_terms


def test_shapes_table_matches_assignment():
    assert SHAPES["train_4k"] == dict(kind="train", seq=4096, batch=256)
    assert SHAPES["prefill_32k"] == dict(kind="prefill", seq=32768, batch=32)
    assert SHAPES["decode_32k"] == dict(kind="decode", seq=32768, batch=128)
    assert SHAPES["long_500k"] == dict(kind="decode", seq=524288, batch=1)


def test_supported_matrix():
    skips = [(a, s) for a in ARCHS for s in SHAPES
             if not supported(get_config(a), s)]
    assert skips == [("whisper-large-v3", "long_500k")]


def test_long_500k_forces_sliding_window_on_dense():
    cfg = adapt_config(get_config("qwen2-72b"), "long_500k")
    assert cfg.sliding_window == LONG_WINDOW
    # native SWA arch keeps its own window
    cfg2 = adapt_config(get_config("mixtral-8x7b"), "long_500k")
    assert cfg2.sliding_window == 4096
    # attention-free arch untouched
    cfg3 = adapt_config(get_config("rwkv6-1.6b"), "long_500k")
    assert cfg3.sliding_window is None


def test_batch_specs_shapes():
    # llava train: patches + text = 4096 total positions
    cfg = adapt_config(get_config("llava-next-34b"), "train_4k")
    sp = batch_specs(cfg, "train_4k")
    assert sp["tokens"].shape == (256, 4096 - cfg.n_patches)
    assert sp["patch_embeds"].shape == (256, cfg.n_patches, cfg.d_model)
    # whisper decode baseline carries frames; optimized variant does not
    wcfg = adapt_config(get_config("whisper-large-v3"), "decode_32k")
    assert "frame_embeds" in batch_specs(wcfg, "decode_32k")
    assert "frame_embeds" not in batch_specs(
        wcfg.replace(cross_kv_cache=True), "decode_32k")


def test_decode_cache_len_ring_vs_full():
    mix = adapt_config(get_config("mixtral-8x7b"), "long_500k")
    assert decode_cache_len(mix, "long_500k") == 4096          # ring buffer
    qw = adapt_config(get_config("qwen2-72b"), "decode_32k")
    assert decode_cache_len(qw, "decode_32k") == 32768         # full cache


def test_roofline_terms_positive_and_dominant():
    for arch in ["qwen2-72b", "mixtral-8x7b", "rwkv6-1.6b"]:
        for shape in ["train_4k", "decode_32k"]:
            r = roofline_terms(arch, shape)
            assert r["t_compute_s"] > 0 and r["t_memory_s"] > 0
            assert r["dominant"] in ("compute", "memory", "collective")
            assert 0 < r["useful_ratio"] <= 1.05


def test_roofline_multipod_scales_compute_down():
    s1 = roofline_terms("qwen2-72b", "train_4k", multi_pod=False)
    s2 = roofline_terms("qwen2-72b", "train_4k", multi_pod=True)
    assert s2["t_compute_s"] == pytest.approx(s1["t_compute_s"] / 2, rel=0.01)


def test_ep_only_when_divisible():
    """mixtral (E=8) cannot EP on a model axis of 16: its baseline collective
    term must not include an all-to-all component (the compiled-HLO-verified
    behaviour of the shape-aware repair)."""
    mix_ep = analytic_costs("mixtral-8x7b", "train_4k", expert_parallel=True)
    mix_noep = analytic_costs("mixtral-8x7b", "train_4k", expert_parallel=False)
    assert mix_ep.coll_bytes_dev == pytest.approx(mix_noep.coll_bytes_dev)
    dbrx_ep = analytic_costs("dbrx-132b", "train_4k", expert_parallel=True)
    dbrx_noep = analytic_costs("dbrx-132b", "train_4k", expert_parallel=False)
    assert dbrx_ep.coll_bytes_dev > 3 * dbrx_noep.coll_bytes_dev


def test_accum_reduces_nothing_but_fsdp():
    a1 = analytic_costs("dbrx-132b", "train_4k", expert_parallel=False)
    a8 = analytic_costs("dbrx-132b", "train_4k", expert_parallel=False,
                        accum_steps=8)
    assert a8.flops_global == pytest.approx(a1.flops_global)
    assert a8.coll_bytes_dev > a1.coll_bytes_dev


def test_grad_accum_matches_full_batch():
    """accum_steps must be loss/grad-equivalent to the full batch (up to
    accumulation-order numerics)."""
    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_model
    from repro.optim import AdamW

    cfg = ARCHS["internlm2-20b"].reduced().replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    opt = AdamW(lr=1e-3)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}

    s1, _ = make_train_step(cfg, opt, accum_steps=1)
    s2, _ = make_train_step(cfg, opt, accum_steps=2)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)


def test_checkpoint_roundtrip_with_opt_state():
    import tempfile

    from repro.checkpoint import restore, save
    from repro.models.transformer import init_model
    from repro.optim import AdamW

    cfg = ARCHS["rwkv6-1.6b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = AdamW()
    state = {"params": params, "opt": opt.init(params)}
    with tempfile.TemporaryDirectory() as d:
        save(d, state, step=7)
        back = restore(d, state)
        from repro.checkpoint import latest_step
        assert latest_step(d) == 7
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_pipeline_deterministic_and_sharded():
    from repro.data import SyntheticLM, shard_for_host

    a = next(iter(SyntheticLM(1000, 8, 64, seed=3)))
    b = next(iter(SyntheticLM(1000, 8, 64, seed=3)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    sh0 = shard_for_host(a, 0, 2)
    sh1 = shard_for_host(a, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([sh0["tokens"], sh1["tokens"]]), a["tokens"])
