"""Common transformer building blocks (pure JAX, dict-pytree params)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.partition import shard


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int) -> dict:
    return dict(scale=jnp.ones((d,), jnp.float32))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    sd_in = (2.0 / (d_model + d_ff)) ** 0.5
    return dict(
        wi=(jax.random.normal(k1, (d_model, d_ff)) * sd_in).astype(dtype),
        wg=(jax.random.normal(k2, (d_model, d_ff)) * sd_in).astype(dtype),
        wo=(jax.random.normal(k3, (d_ff, d_model)) * sd_in).astype(dtype),
    )


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    g = jnp.einsum("...d,df->...f", x, p["wg"])
    h = jax.nn.silu(g) * h
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(key: jax.Array, vocab: int, d_model: int, dtype=jnp.bfloat16,
               tied_head: bool = True) -> dict:
    k1, k2 = jax.random.split(key)
    p = dict(embed=dict(tokens=(jax.random.normal(k1, (vocab, d_model)) * 0.02).astype(dtype)))
    if not tied_head:
        p["lm_head"] = dict(w=(jax.random.normal(k2, (d_model, vocab)) * 0.02).astype(dtype))
    return p


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embed"]["tokens"], tokens, axis=0)


def lm_logits(p: dict, x: jax.Array) -> jax.Array:
    if "lm_head" in p:
        logits = jnp.einsum("...d,dv->...v", x, p["lm_head"]["w"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, p["embed"]["tokens"])
    return shard(logits.astype(jnp.float32), "batch", "seq", "vocab")


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy; logits (..., V) float32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(ll.dtype)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
